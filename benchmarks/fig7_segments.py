"""Figure 7: learned segment counts vs sample rate (generalization —
fewer segments at lower s; PGM more stable than greedy FITing)."""

from __future__ import annotations

import numpy as np

from repro.core import fit_sampled
from repro.core.mechanisms import FITingMechanism, PGMMechanism

from .datasets import iot

RATES = (1.0, 0.5, 0.1, 0.05, 0.01, 0.005)


def run(n=None, seed=0, eps=128):
    keys = iot(n)
    y = np.arange(len(keys), dtype=np.float64)
    rows = []
    for method, factory in (
        ("fiting", lambda: FITingMechanism(eps=eps)),
        ("pgm", lambda: PGMMechanism(eps=eps, recursive=False)),
    ):
        for s in RATES:
            if s >= 1.0:
                mech = factory().fit(keys, y)
            else:
                mech = fit_sampled(factory, keys, y, rate=s,
                                   rng=np.random.default_rng(seed),
                                   refinalize=False)
            rows.append({"name": f"{method}.s{s}",
                         "us": 0.0,
                         "segments": mech.plm.n_segments})
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "fig7")
