"""Concurrent-serving sweep: lookup tail latency UNDER ingest through
the snapshot-isolated ``EpochPipeline``, YCSB-style read/write mixes x
query skews.

Each row drives one (read_frac, zipf) workload: rounds of a write burst
(``ingest`` of fresh odd keys into the live index — the snapshot keeps
serving epoch N) interleaved with timed lookup calls (zipf-skewed over
the key space), one ``publish`` per round.  Reported per row:

* ``p50_us`` / ``p99_us`` — per-lookup-call latency percentiles OVER
  the whole run, i.e. including the calls that land while the live
  index is mid-epoch and the pinned-snapshot host path serves (the tail
  this sweep exists to guard: without isolation those calls would
  either block or read torn state);
* ``ingest_keys_per_s`` — write throughput achieved between lookups.

Correctness is asserted before timing: a snapshot lookup issued during
the write burst must be bit-identical to the quiesced pre-burst answer
at the same epoch.

Writes ``BENCH_serving.json`` at the repo root (full-size runs only,
same rule as the other trajectory files), gated higher-is-worse on
``p99_us`` at 1.25x by ``benchmarks.run`` — the sweep guards the tail
cost of serving under churn (snapshot pin/COW, WAL-less pipeline
overhead, publish swaps), not absolute device throughput.
"""

from __future__ import annotations

import copy
import json
import os
import pathlib
import time

import numpy as np

from repro.core import Index
from repro.serving import EpochPipeline

_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _reps(reps):
    return reps * 3 if os.environ.get("BENCH_NIGHTLY") == "1" else reps


def _zipf_sampler(n_items, theta, rng):
    """Bounded Zipf(theta) over ranks 0..n_items-1 (theta=0 uniform),
    via the inverse CDF of the truncated Zipfian pmf — the YCSB
    request-skew model."""
    if theta <= 0.0:
        return lambda size: rng.integers(0, n_items, size)
    w = 1.0 / np.power(np.arange(1, n_items + 1, dtype=np.float64), theta)
    cdf = np.cumsum(w / w.sum())
    # ranks spread over the key space (YCSB hashes items; a raw rank->
    # sorted-key identity would alias skew with router/segment locality)
    perm = rng.permutation(n_items)
    return lambda size: perm[np.searchsorted(cdf, rng.random(size))]


def _run_mix(base, keys, read_frac, theta, *, rounds, writes_per_round,
             q_size, reps, rng):
    """One (read_frac, zipf) cell: best-of-``reps`` full runs, each a
    fresh deepcopy of ``base`` so ingest state never leaks across
    reps."""
    best = None
    n_lookup_calls = max(1, int(round(
        (read_frac / max(1.0 - read_frac, 1e-9)) * writes_per_round
        / q_size)))
    sample = _zipf_sampler(keys.size, theta, rng)
    # fresh odd keys (midpoints), disjoint from the base key grid
    fresh = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    rng.shuffle(fresh)
    need = rounds * writes_per_round
    assert fresh.size >= need, "not enough gap midpoints for the sweep"
    for _ in range(reps):
        idx = copy.deepcopy(base)
        pipe = EpochPipeline(idx)
        lat_ns = []
        t_ingest = 0.0
        off = 0
        # isolation probe: quiesced answers at the published epoch must
        # be reproduced bit-for-bit by every mid-burst snapshot lookup
        probe = keys[sample(256)]
        want = pipe.lookup(probe)
        for _ in range(rounds):
            wk = fresh[off: off + writes_per_round]
            off += writes_per_round
            t0 = time.perf_counter()
            pipe.ingest(wk, (1_000_000 + np.arange(wk.size)).astype(
                np.int64))
            t_ingest += time.perf_counter() - t0
            got = pipe.lookup(probe)  # mid-burst: snapshot path
            assert got.epoch == want.epoch
            assert np.array_equal(np.asarray(got.payloads),
                                  np.asarray(want.payloads))
            for _ in range(n_lookup_calls):
                q = keys[sample(q_size)]
                t0 = time.perf_counter_ns()
                pipe.lookup(q)
                lat_ns.append(time.perf_counter_ns() - t0)
            pipe.publish()
            want = pipe.lookup(probe)  # re-anchor at the new epoch
        lat = np.asarray(lat_ns, np.float64) / 1e3  # us per lookup call
        row = {
            "p50_us": float(np.percentile(lat, 50)),
            "p99_us": float(np.percentile(lat, 99)),
            "ingest_keys_per_s": need / max(t_ingest, 1e-9),
            "lookup_calls": int(lat.size),
            "publishes": pipe.stats["publishes"],
            "snapshot_lookups": pipe.stats["snapshot_lookups"],
        }
        pipe.close()
        if best is None or row["p99_us"] < best["p99_us"]:
            best = row
    return best


def run(n=None, seed=0, read_fracs=(0.95, 0.5), zipfs=(0.0, 0.99),
        write=True):
    n_keys = min(n, 150_000) if n else 150_000
    rng = np.random.default_rng(seed)
    # even integer grid: every midpoint is a representable fresh key
    keys = np.unique(rng.choice(2 ** 21, n_keys, replace=False)
                     ).astype(np.float64) * 2.0
    base = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    rounds, writes_per_round, q_size = 8, 1_024, 2_048
    reps = _reps(2)
    rows = []
    for rf in read_fracs:
        for z in zipfs:
            cell = _run_mix(base, keys, rf, z, rounds=rounds,
                            writes_per_round=writes_per_round,
                            q_size=q_size, reps=reps, rng=rng)
            rows.append({
                "name": f"r{int(rf * 100)}.z{z:g}",
                "us": cell["p99_us"],
                "read_frac": rf,
                "zipf": z,
                **cell,
            })
    if write and n is None:  # reduced sweeps never overwrite the record
        out_rows = [
            {"batch": f"serve.{r['name']}", "read_frac": r["read_frac"],
             "zipf": r["zipf"], "p50_us": r["p50_us"],
             "p99_us": r["p99_us"],
             "ingest_keys_per_s": r["ingest_keys_per_s"]}
            for r in rows
        ]
        payload = {
            "benchmark": "serving.lookup_under_ingest",
            "dataset": "uniform_even_int_2e22",
            "note": ("EpochPipeline snapshot-isolated serving: p50/p99 "
                     "per-lookup-call latency measured WHILE write "
                     "bursts build the next epoch (mid-burst snapshot "
                     "answers asserted bit-identical to the quiesced "
                     "published epoch before timing); YCSB-style "
                     "read_frac x bounded-Zipf skew grid, one publish "
                     "per round, best-of-reps"),
            "rows": out_rows,
            "p99_us_max": float(max(r["p99_us"] for r in rows)),
        }
        (_ROOT / "BENCH_serving.json").write_text(
            json.dumps(payload, indent=2))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "serving")
