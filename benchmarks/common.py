"""Shared measurement harness for the paper-figure benchmarks.

All times ns/query over vectorized numpy batches (single-core container;
ratios — not absolute ns vs the paper's C++ — are the comparable
quantity, stated in EXPERIMENTS.md).
"""

from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core import LearnedIndex
from repro.core.mdl import mae as mae_fn
from repro.core.sampling import exponential_search


def time_ns_per(fn, n_items: int, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter_ns()
        fn()
        best = min(best, time.perf_counter_ns() - t0)
    return best / n_items


def measure(index: LearnedIndex, queries: np.ndarray,
            payload_bytes_per_key: int = 16) -> Dict[str, float]:
    """T_build/T_predict/T_correct/T_overall (ns/query), size, MAE."""
    keys = index.keys
    n_q = len(queries)

    t_pred = time_ns_per(lambda: index.predict(queries), n_q)
    y_hat = index.predict(queries)

    probes_per_q = 0.0
    if index.gapped is not None:
        t_overall = time_ns_per(lambda: index.gapped.lookup_batch(queries), n_q)
        slots = np.searchsorted(index.gapped.slot_key, keys, "right") - 1
        m = mae_fn(slots, index.predict(keys))
        size = (index.gapped.n_slots * payload_bytes_per_key
                + index.gapped.link_stats()[0] * payload_bytes_per_key
                + 8 * index.mech.param_count())
        _, probes = exponential_search(index.gapped.slot_key, queries,
                                       index.predict(queries))
        probes_per_q = probes / n_q
    else:
        t_correct_only = time_ns_per(
            lambda: exponential_search(keys, queries, y_hat)[0], n_q)
        t_overall = t_pred + t_correct_only
        m = mae_fn(np.arange(len(keys)), index.predict(keys))
        size = (len(keys) * payload_bytes_per_key
                + 8 * index.mech.param_count())
        _, probes = exponential_search(keys, queries, y_hat)
        probes_per_q = probes / n_q

    t_correct = max(t_overall - t_pred, 0.0)
    return {
        "build_ns": index.build_seconds * 1e9,
        "predict_ns": t_pred,
        "correct_ns": t_correct,
        "overall_ns": t_overall,
        "size_bytes": float(size),
        "mae": m,
        "probes_per_q": probes_per_q,
    }


def btree_measure(index: LearnedIndex, queries: np.ndarray) -> Dict[str, float]:
    """B+Tree: predict = fence walk, correct = in-page binary search."""
    mech = index.mech
    n_q = len(queries)
    t_pred = time_ns_per(lambda: mech.predict(queries), n_q)
    pred = mech.predict(queries)

    def correct():
        page = (pred // mech.page_size).astype(np.int64) * mech.page_size
        # binary scan within the page (vectorized searchsorted per page)
        return exponential_search(index.keys, queries, pred)[0]

    t_corr = time_ns_per(correct, n_q)
    return {
        "build_ns": index.build_seconds * 1e9,
        "predict_ns": t_pred,
        "correct_ns": t_corr,
        "overall_ns": t_pred + t_corr,
        "size_bytes": float(mech.size_bytes()),
        "mae": mae_fn(np.arange(len(index.keys)), mech.predict(index.keys)),
    }


def emit(rows, prefix: str):
    """Print ``name,us_per_call,derived`` CSV lines (run.py contract)."""
    out = []
    for r in rows:
        r = dict(r)
        name = f"{prefix}.{r.pop('name')}"
        if "overall_ns" in r:
            us = r["overall_ns"] / 1e3
        elif "us" in r:
            us = r.pop("us")
        else:
            us = 0.0
        derived = ";".join(f"{k}={v:.6g}" if isinstance(v, float)
                           else f"{k}={v}" for k, v in r.items())
        line = f"{name},{us:.4f},{derived}"
        print(line)
        out.append(line)
    return out
