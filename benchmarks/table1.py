"""Table 1: B+Tree vs RMI vs FITing-Tree vs PGM on the IoT-like dataset.
Columns: T_build, T_predict, T_correct, T_overall, index size, MAE."""

from __future__ import annotations

import numpy as np

from repro.core import LearnedIndex

from .common import btree_measure, measure
from .datasets import iot


def run(n=None, seed=0):
    keys = iot(n)
    rng = np.random.default_rng(seed)
    queries = rng.choice(keys, min(200_000, len(keys)))
    rows = []
    configs = [
        ("btree", dict(method="btree", page_size=256)),
        ("rmi", dict(method="rmi", n_leaf=max(100, len(keys) // 200))),
        ("fiting", dict(method="fiting", eps=128)),
        ("pgm", dict(method="pgm", eps=128)),
    ]
    for name, kw in configs:
        idx = LearnedIndex.build(keys, **kw)
        m = btree_measure(idx, queries) if name == "btree" else \
            measure(idx, queries)
        if hasattr(idx.mech, "plm") and idx.mech.plm is not None:
            m["segments"] = idx.mech.plm.n_segments
        rows.append({"name": name, **m})
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "table1")
