"""Figure 6: sampling sweep — MAE / build time / query time vs sample
rate (the 78x construction-speedup claim lives here), with the
exponential-search probe counts surfaced per row.

Also writes ``BENCH_build.json`` at the repo root — the construction
trajectory behind the regression gate: per sample_rate, total build
time split into mechanism LEARNING (base fit + Eq.3 targets + step-3
refit, O(n_s) after the sampled-end-to-end change) vs physical
PLACEMENT (O(n) always), the learn speedup vs the full-rate build
(the gated metric — a ratio of two arms sharing this run's machine
state, so container-load swings cancel), a bit-identity check of the
sampled build's answers against the full build, and the MDL score +
choice of the ``core.tuning`` auto-tuner on the same keys.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core import Index, LearnedIndex
from repro.core.tuning import autotune

from .common import measure
from .datasets import iot

_ROOT = pathlib.Path(__file__).resolve().parents[1]

RATES = (1.0, 0.5, 0.1, 0.05, 0.01, 0.005, 0.0025, 0.001)
BUILD_RATES = (1.0, 0.1, 0.01)
GAP_RHO = 0.15


def _bit_identity(full: Index, samp: Index, keys: np.ndarray,
                  rng: np.random.Generator) -> bool:
    """Sampled-then-refinalized answers == full-build answers (present
    AND absent queries) — the §4 exactness contract."""
    q = rng.choice(keys, min(20_000, len(keys)))
    miss = np.setdiff1d(keys[:-1] + np.diff(keys) * 0.5, keys)[:4000]
    qs = np.concatenate([q, miss])
    a = full.lookup(qs)
    b = samp.lookup(qs)
    return bool(np.array_equal(np.asarray(a.payloads),
                               np.asarray(b.payloads))
                and np.array_equal(np.asarray(a.found),
                                   np.asarray(b.found)))


def run_build(keys: np.ndarray, seed: int = 0, method: str = "pgm",
              eps: float = 128.0, write: bool = True):
    """The BENCH_build.json sweep: gapped builds across BUILD_RATES."""
    rng = np.random.default_rng(seed)
    rows = []
    full = None
    full_learn = None
    for s in BUILD_RATES:
        idx = Index.build(keys, method=method, eps=eps, gap_rho=GAP_RHO,
                          sample_rate=s,
                          rng=np.random.default_rng(seed + 1))
        t = idx.gapped.build_timings
        if s == 1.0:
            full, full_learn = idx, t["learn_seconds"]
        rows.append({
            "batch": f"s{s}",
            "sample_rate": s,
            "build_ms": idx.build_seconds * 1e3,
            "learn_ms": t["learn_seconds"] * 1e3,
            "place_ms": t["place_seconds"] * 1e3,
            "n_fit": t["n_fit"],
            "learn_speedup": (full_learn / max(t["learn_seconds"], 1e-9)
                              if full_learn else 1.0),
            "bit_identical": (True if s == 1.0
                              else _bit_identity(full, idx, keys, rng)),
        })
    queries = rng.choice(keys, min(50_000, len(keys)))
    tuned = autotune(keys, queries=queries, dynamic=True,
                     rng=np.random.default_rng(seed + 2))
    payload = {
        "n": int(len(keys)),
        "method": method,
        "gap_rho": GAP_RHO,
        "rows": rows,
        "learn_speedup_max": max(r["learn_speedup"] for r in rows),
        "auto_method": tuned.method,
        "auto_mech_kwargs": tuned.mech_kwargs,
        "auto_mdl": tuned.score,
        "auto_hoeffding_eps": tuned.hoeffding_eps,
    }
    if write:
        (_ROOT / "BENCH_build.json").write_text(json.dumps(payload, indent=2))
    return payload


def run(n=None, seed=0, method="pgm", eps=256):
    keys = iot(n)
    rng = np.random.default_rng(seed)
    queries = rng.choice(keys, min(100_000, len(keys)))
    rows = []
    build_full = None
    for s in RATES:
        idx = LearnedIndex.build(keys, method=method, eps=eps,
                                 sample_rate=s,
                                 rng=np.random.default_rng(seed))
        m = measure(idx, queries)
        if s == 1.0:
            build_full = m["build_ns"]
        m["build_speedup"] = (build_full / m["build_ns"]
                              if build_full else 1.0)
        m["segments"] = idx.mech.plm.n_segments
        rows.append({"name": f"{method}.s{s}", **m})
    # reduced sweeps (n override / BENCH_FAST) never overwrite the record
    build = run_build(keys, seed=seed, write=n is None)
    for r in build["rows"]:
        rows.append({
            "name": f"build.{r['batch']}",
            "us": r["build_ms"] * 1e3,
            "learn_ms": r["learn_ms"],
            "place_ms": r["place_ms"],
            "learn_speedup": r["learn_speedup"],
            "bit_identical": r["bit_identical"],
        })
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "fig6")
