"""Figure 6: sampling sweep — MAE / build time / query time vs sample
rate (the 78x construction-speedup claim lives here)."""

from __future__ import annotations

import numpy as np

from repro.core import LearnedIndex

from .common import measure
from .datasets import iot

RATES = (1.0, 0.5, 0.1, 0.05, 0.01, 0.005, 0.0025, 0.001)


def run(n=None, seed=0, method="pgm", eps=256):
    keys = iot(n)
    rng = np.random.default_rng(seed)
    queries = rng.choice(keys, min(100_000, len(keys)))
    rows = []
    build_full = None
    for s in RATES:
        idx = LearnedIndex.build(keys, method=method, eps=eps,
                                 sample_rate=s,
                                 rng=np.random.default_rng(seed))
        m = measure(idx, queries)
        if s == 1.0:
            build_full = m["build_ns"]
        m["build_speedup"] = (build_full / m["build_ns"]
                              if build_full else 1.0)
        m["segments"] = idx.mech.plm.n_segments
        rows.append({"name": f"{method}.s{s}", **m})
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "fig6")
