"""Figure 8: smallest "safe" sample size n_safe vs the alpha knob.

Theory (Thm. 1): n_safe = O(alpha^2 log^2 E) => log n_safe linear in
log alpha.  We binary-search the smallest rate keeping MAE within 2x of
the full build, per alpha setting, and report the log-log slope.
"""

from __future__ import annotations

import numpy as np

from repro.core import LearnedIndex
from repro.core.mdl import mae as mae_fn

from .datasets import iot

# alpha proxies: eps inversely proportional (FIT/PGM); n_leaf proportional
SWEEPS = {
    "pgm": [("eps", e) for e in (1024, 256, 64, 16)],
    "fiting": [("eps", e) for e in (1024, 256, 64, 16)],
    "rmi": [("n_leaf", l) for l in (250, 1000, 4000, 16000)],
}
RATES = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5)


def _mae_of(keys, method, kw, rate, seed):
    idx = LearnedIndex.build(keys, method=method, sample_rate=rate,
                             rng=np.random.default_rng(seed), **kw)
    return mae_fn(np.arange(len(keys)), idx.predict(keys))


def run(n=None, seed=0, tol=2.0):
    keys = iot(n)
    rows = []
    for method, knobs in SWEEPS.items():
        for pname, pval in knobs:
            kw = {pname: pval}
            full = _mae_of(keys, method, kw, 1.0, seed)
            n_safe = len(keys)
            for rate in RATES:  # smallest rate with non-degraded MAE
                m = _mae_of(keys, method, kw, rate, seed)
                if m <= tol * max(full, 1.0):
                    n_safe = max(2, int(rate * len(keys)))
                    break
            rows.append({"name": f"{method}.{pname}{pval}", "us": 0.0,
                         "n_safe": n_safe, "full_mae": full})
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "fig8")
