"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  BENCH_N scales dataset size
(default 400k keys); BENCH_FAST=1 runs a reduced sweep for CI.

The kernel module additionally writes ``BENCH_kernel.json`` at the repo
root (before/after ns-per-query + fallback rate of the single-pass
compacted query path) — the perf trajectory tracked across PRs.
"""

from __future__ import annotations

import os
import sys
import time
import traceback

# must precede the first jax import (see kernel_bench): per-op thread
# handoff costs more than it returns on this container's 2 cores
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

from . import (fig4_tradeoff, fig6_sampling, fig7_segments, fig8_nsafe,
               fig9_gaps, fig11_dynamic, kernel_bench, table1)
from .common import emit

MODULES = [
    ("table1", table1),
    ("fig4", fig4_tradeoff),
    ("fig6", fig6_sampling),
    ("fig7", fig7_segments),
    ("fig8", fig8_nsafe),
    ("fig9", fig9_gaps),
    ("fig11", fig11_dynamic),
    ("kernel", kernel_bench),
]


def main() -> None:
    fast = os.environ.get("BENCH_FAST", "0") == "1"
    n = 60_000 if fast else None
    print("name,us_per_call,derived")
    failures = 0
    for prefix, mod in MODULES:
        t0 = time.time()
        try:
            rows = mod.run(n=n)
            emit(rows, prefix)
            print(f"# {prefix}: {len(rows)} rows in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001 — keep the suite going
            failures += 1
            print(f"# {prefix} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
