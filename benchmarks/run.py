"""Benchmark driver — one module per paper table/figure, plus the
perf-trajectory gate.

Prints ``name,us_per_call,derived`` CSV.  BENCH_N scales dataset size
(default 400k keys); BENCH_FAST=1 runs a reduced sweep for CI (the
regression gate is skipped — sizes differ — but schemas still validate);
BENCH_NO_GATE=1 skips the gate entirely.

``--nightly`` is the full-timing mode: the reduced-sweep and no-gate
escape hatches are ignored, every module runs at full size, the timing
harness triples its best-of reps (exported as BENCH_NIGHTLY=1, consumed
by kernel_bench's ``_reps``) for lower-variance trajectory records, and
the regression gate always runs.  ``--smoke`` stays the cheap tier-1
entry: committed-schema validation plus tiny-shape read-path AND
fused-ingest bit-identity checks, no timing, no file writes.

Four trajectory files are written at the repo root (kernel_bench the
first two, fig11_dynamic the third, shard_bench the fourth), all
validated and gated here after the sweep:

* ``BENCH_kernel.json`` — single-pass engine ns/query (before/after);
* ``BENCH_api.json``    — ``Index`` handle ingest-to-queryable latency,
  delta-updated device sync vs full refreeze (bit-identical lookups);
* ``BENCH_ingest.json`` — §5.3 batched-vs-sequential insert sweep with
  per-batch contested-replay fractions (the per-key demotion
  partition's signature metric) plus the fused-abort telemetry;
* ``BENCH_shard.json``  — sharded fan-out vs single-device sweep
  (shards x queries), router mispredict fraction, rebalance cost.

The gate fails the run when a fresh ns/query (or delta-path latency)
regresses more than 1.25x against the RECORDED trajectory (the committed
JSON loaded before the sweep overwrites it), when a schema field is
missing, or when the delta/refreeze lookups stop being bit-identical.
"""

from __future__ import annotations

import json
import os
import pathlib
import sys
import time
import traceback

# must precede the first jax import (see kernel_bench): per-op thread
# handoff costs more than it returns on this container's 2 cores
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

from . import (fig4_tradeoff, fig6_sampling, fig7_segments, fig8_nsafe,
               fig9_gaps, fig11_dynamic, kernel_bench, serving_bench,
               shard_bench, table1)
from .common import emit

_ROOT = pathlib.Path(__file__).resolve().parents[1]

MODULES = [
    ("table1", table1),
    ("fig4", fig4_tradeoff),
    ("fig6", fig6_sampling),
    ("fig7", fig7_segments),
    ("fig8", fig8_nsafe),
    ("fig9", fig9_gaps),
    ("fig11", fig11_dynamic),
    ("kernel", kernel_bench),
    ("shard", shard_bench),
    ("serving", serving_bench),
]

# trajectory schema: file -> (metric key, direction, required row keys).
# direction "higher_is_worse" gates ns/query-style metrics; the api file
# gates on the delta-vs-refreeze SPEEDUP ("lower_is_worse") because both
# arms share each run's machine state, so the ratio cancels the ~2x
# container-load swings that raw milliseconds carry between sweeps.
TRAJECTORIES = {
    "BENCH_kernel.json": (
        "after_ns_per_query", "higher_is_worse",
        {"batch", "before_ns_per_query", "after_ns_per_query", "speedup",
         "fallback_rate", "oracle_escapes"},
    ),
    "BENCH_api.json": (
        "speedup", "lower_is_worse",
        {"batch", "mutation_frac", "delta_ms", "refreeze_ms", "speedup",
         "bit_identical"},
    ),
    # the ingest file gates on the batched-vs-sequential SPEEDUP (both
    # arms share each run's machine state, so the ratio cancels
    # container-load swings) — a contested-fraction regression shows up
    # there directly, since the scalar replay dominates the batched
    # arm's cost
    "BENCH_ingest.json": (
        "speedup", "lower_is_worse",
        {"batch", "contested_frac", "insert_seq_ns", "insert_batch_ns",
         "speedup"},
    ),
    # the shard file gates on the fan-out-vs-single-device SPEEDUP
    # (shared machine state per run, the ratio cancels container-load
    # swings): it guards the dispatch overhead of the route/exchange/
    # unsort choreography around the fused per-shard search
    "BENCH_shard.json": (
        "speedup", "lower_is_worse",
        {"batch", "shards", "queries", "sharded_ns_per_q",
         "single_ns_per_q", "speedup", "router_mispredict_frac"},
    ),
    # the serving file gates on the p99 lookup-call latency UNDER
    # concurrent ingest (higher-is-worse): the snapshot-isolation tail
    # is exactly what a pin/COW or publish-path regression inflates
    "BENCH_serving.json": (
        "p99_us", "higher_is_worse",
        {"batch", "read_frac", "zipf", "p50_us", "p99_us",
         "ingest_keys_per_s"},
    ),
    # the build file gates on the sampled-vs-full mechanism-LEARNING
    # speedup (lower-is-worse; both arms share each run's machine
    # state, so the ratio cancels container-load swings): it guards the
    # §4 sampled-end-to-end construction path — learning cost must keep
    # scaling with the sample, not n — and every row's bit_identical
    # flag asserts the sampled build answers exactly like the full one
    "BENCH_build.json": (
        "learn_speedup", "lower_is_worse",
        {"batch", "sample_rate", "build_ms", "learn_ms", "place_ms",
         "learn_speedup", "bit_identical"},
    ),
}
# required TOP-LEVEL fields per trajectory file (beyond "rows"):
# the kernel file must RECORD its small-batch crossover so the gate can
# see the fused path losing the regime this sweep exists to guard
TOP_LEVEL_REQUIRED = {
    "BENCH_kernel.json": {"crossover_vs_oracle_queries"},
    # the ingest file must RECORD its aggregate speedup and worst-batch
    # contested fraction so the trajectory shows both at a glance, plus
    # the fused-abort telemetry (how often the write graph vetoed, and
    # why on the crafted crowded-batch probe)
    "BENCH_ingest.json": {"speedup_geomean", "contested_frac_max",
                          "fused_aborts_total", "fused_abort_reasons"},
    # the shard file must RECORD the rebalance (split) cost and the
    # worst router mispredict fraction alongside the per-row sweep
    "BENCH_shard.json": {"rebalance_ms", "router_mispredict_frac_max"},
    # the serving file must RECORD its worst tail so the trajectory
    # shows the serving p99 envelope at a glance
    "BENCH_serving.json": {"p99_us_max"},
    # the build file must RECORD the best learn speedup plus the
    # auto-tuner's pick and MDL score, so the self-tuning trajectory is
    # visible at a glance
    "BENCH_build.json": {"learn_speedup_max", "auto_method", "auto_mdl"},
}
REGRESSION_FACTOR = 1.25


def _load_trajectories() -> dict:
    recorded = {}
    for name in TRAJECTORIES:
        p = _ROOT / name
        if p.exists():
            try:
                recorded[name] = json.loads(p.read_text())
            except json.JSONDecodeError:
                recorded[name] = None  # malformed on disk: schema-gate it
    return recorded


def check_trajectories(recorded: dict, *, regressions: bool = True) -> list:
    """Validate fresh BENCH_*.json schemas and (optionally) compare
    against the recorded trajectory.  Returns a list of error strings."""
    errors = []
    for name, (metric, direction, required) in TRAJECTORIES.items():
        p = _ROOT / name
        if not p.exists():
            errors.append(f"{name}: missing after sweep")
            continue
        try:
            fresh = json.loads(p.read_text())
        except json.JSONDecodeError as e:
            errors.append(f"{name}: invalid JSON ({e})")
            continue
        rows = fresh.get("rows")
        if not isinstance(rows, list) or not rows:
            errors.append(f"{name}: schema — 'rows' missing or empty")
            continue
        for key in TOP_LEVEL_REQUIRED.get(name, ()):
            if key not in fresh:
                errors.append(f"{name}: schema — top-level '{key}' missing")
        for i, row in enumerate(rows):
            missing = required - set(row)
            if missing:
                errors.append(f"{name}: row {i} missing {sorted(missing)}")
            if "bit_identical" in required and not row.get("bit_identical",
                                                           False):
                errors.append(
                    f"{name}: row {i} ({row.get('batch')}) lookups not "
                    "bit-identical between the compared arms")
        old = recorded.get(name)
        if not regressions or not old:
            continue
        old_rows = {r.get("batch"): r for r in old.get("rows", [])}
        for row in rows:
            ref = old_rows.get(row.get("batch"))
            if not ref or metric not in ref or metric not in row:
                continue
            if direction == "higher_is_worse":
                bad = row[metric] > REGRESSION_FACTOR * ref[metric]
            else:
                bad = row[metric] < ref[metric] / REGRESSION_FACTOR
            if bad:
                errors.append(
                    f"{name}: {row['batch']} {metric} regressed "
                    f"{row[metric]:.1f} vs recorded {ref[metric]:.1f} "
                    f"(beyond {REGRESSION_FACTOR}x)")
    return errors


def smoke() -> None:
    """``python -m benchmarks.run --smoke`` — cheap CI gate called from
    scripts/tier1.sh: validates the COMMITTED trajectory schemas (so
    benchmark schema drift fails tier-1 without paying for a timed
    sweep) and runs tiny-shape sanity checks — read path (fused /
    oracle / both Pallas kernels bit-identical; fused scheduling
    engaged) and write path (fused single-dispatch ingest bit-identical
    to sequential insert(); adopted device buffers answer the new
    keys).  No timing, no gate, no file writes."""
    # same validator the timed sweep uses, pointed at the COMMITTED
    # files (no recorded baseline -> no regression compare)
    errors = check_trajectories({}, regressions=False)

    # tiny-shape sanity: the whole fused read path on a toy index
    import numpy as np

    from repro.core import Index
    from repro.kernels import QueryEngine, batched_lookup, \
        from_learned_index

    rng = np.random.default_rng(0)
    keys = np.unique(rng.choice(2 ** 22, 20_000, replace=False)
                     ).astype(np.float64)
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    arrs = from_learned_index(idx)
    plm = idx.mech.plm
    q = np.concatenate([rng.choice(keys, 1500),
                        rng.choice(keys, 200) + 0.5,
                        [keys[0] - 5.0, keys[-1] + 5.0]])
    out_o, *_ = batched_lookup(arrs, plm.err_lo, q, backend="oracle")
    for be in ("fused", "fused-pallas", "pallas"):
        out, *_ = batched_lookup(arrs, plm.err_lo, q, backend=be,
                                 err_hi_by_seg=plm.err_hi, interpret=True)
        if not np.array_equal(np.asarray(out), np.asarray(out_o)):
            errors.append(f"smoke: backend {be} diverged from the oracle")
    eng = QueryEngine.from_index(idx)
    out, *_ = eng.lookup(q)
    if eng.last_stage != "fused":
        errors.append(f"smoke: engine scheduled {eng.last_stage!r}, "
                      "expected 'fused'")
    if not np.array_equal(np.asarray(out), np.asarray(out_o)):
        errors.append("smoke: engine fused lookup diverged from oracle")

    # tiny-shape fused-ingest sanity: the single-dispatch write path
    # commits bit-identically to sequential insert() and the adopted
    # device buffers answer the new keys exactly
    import copy

    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    batch = mids[:: max(1, len(mids) // 600)][:512]
    pays = 30_000_000 + np.arange(len(batch))
    a = copy.deepcopy(idx)
    a.fused_ingest_enabled = True  # force the fused arm (CPU auto: off)
    a.sync_device()
    rep = a.ingest(batch, pays)
    if rep.device != "fused":
        errors.append(f"smoke: ingest took device={rep.device!r}, "
                      "expected the fused single dispatch")
    b = copy.deepcopy(idx)
    for k, p in zip(batch, pays):
        b.insert(float(k), int(p))
    ga, gb = a.gapped, b.gapped
    if not (np.array_equal(ga.slot_key, gb.slot_key)
            and np.array_equal(ga.occupied, gb.occupied)
            and np.array_equal(ga.payload[ga.occupied],
                               gb.payload[gb.occupied])
            and np.array_equal(ga.lookup_batch(batch),
                               gb.lookup_batch(batch))):
        errors.append("smoke: fused ingest state diverged from "
                      "sequential insert()")
    res = a.lookup(batch, backend="fused", queries_sorted=True)
    if not np.array_equal(np.asarray(res.payloads), pays):
        errors.append("smoke: post-fused-ingest device lookup diverged")

    # tiny-shape sharded sanity: the fan-out (degenerate D=1 on the
    # single smoke device) and the grouped host route both answer
    # bit-identically to the single-device handle above
    sharded = Index.build(keys, shards=3, method="pgm", eps=64,
                          gap_rho=0.2)
    res_f = sharded.lookup(q, backend="fanout")
    res_h = sharded.lookup(q[:200])
    want = idx.lookup(q)
    if not (np.array_equal(np.asarray(res_f.payloads),
                           np.asarray(want.payloads))
            and np.array_equal(np.asarray(res_f.found),
                               np.asarray(want.found))):
        errors.append("smoke: sharded fan-out diverged from the "
                      "single-device handle")
    if not np.array_equal(np.asarray(res_h.payloads),
                          np.asarray(want.payloads)[:200]):
        errors.append("smoke: sharded grouped-host route diverged")

    # tiny-shape sampled-build sanity: the §4 sampled-end-to-end build
    # (mechanism learning on the sample only, refinalized bounds) must
    # answer bit-identically to the full-data build, and a retrain
    # under the epoch pipeline must keep the pinned snapshot's answers
    # frozen until publish
    samp = Index.build(keys, method="pgm", eps=64, gap_rho=0.2,
                       sample_rate=0.05, rng=np.random.default_rng(11))
    want_full = idx.lookup(q)
    got_samp = samp.lookup(q)
    if not (np.array_equal(np.asarray(want_full.payloads),
                           np.asarray(got_samp.payloads))
            and np.array_equal(np.asarray(want_full.found),
                               np.asarray(got_samp.found))):
        errors.append("smoke: sampled build diverged from the full build")
    if samp.gapped.build_timings["n_fit"] >= len(keys) // 2:
        errors.append("smoke: sampled build fit on the full key set "
                      "(learning did not scale with the sample)")
    from repro.serving import EpochPipeline as _EP
    with _EP(samp) as sp:
        pre = sp.lookup(q[:256])
        fresh_keys = mids[-64:]
        sp.ingest(fresh_keys, 40_000_000 + np.arange(64))
        sp.retrain(sample_rate=0.05, rng=np.random.default_rng(12))
        held = sp.lookup(q[:256])
        if not (held.epoch == pre.epoch
                and np.array_equal(np.asarray(held.payloads),
                                   np.asarray(pre.payloads))):
            errors.append("smoke: retrain leaked into the pinned "
                          "snapshot before publish")
        sp.publish()
        post = sp.lookup(fresh_keys)
        if not (post.found.all()
                and np.array_equal(np.asarray(post.payloads),
                                   40_000_000 + np.arange(64))):
            errors.append("smoke: post-retrain publish lost ingested keys")

    # deterministic fault-injection sanity: snapshot-isolated serving,
    # injected-abort absorption, and crash recovery (snapshot + WAL-tail
    # replay with a torn trailing record) must reproduce the acked state
    # bit-for-bit on a tiny index
    import tempfile

    from repro.robustness import FaultInjector, InvariantAuditor, \
        tear_tail
    from repro.serving import EpochPipeline, IngestWAL, MicroBatchQueue, \
        recover_index

    with tempfile.TemporaryDirectory() as td:
        skeys = np.unique(rng.choice(2 ** 20, 5_000, replace=False)
                          ).astype(np.float64) * 2.0
        sidx = Index.build(skeys, method="pgm", eps=64, gap_rho=0.2)
        wal_path = f"{td}/ingest.wal"
        auditor = InvariantAuditor()
        pipe = EpochPipeline(sidx, wal=IngestWAL(wal_path),
                             auditor=auditor, audit_every=1)
        pipe.checkpoint(td, step=0)
        fresh = np.setdiff1d(skeys[:-1] + np.rint(np.diff(skeys) * 0.5),
                             skeys)
        b1, b2, b3 = fresh[:64], fresh[64:128], fresh[128:192]
        inj = FaultInjector({("ingest", 0): "abort"})
        q = MicroBatchQueue(pipe, faults=inj, ingest_retries=2,
                            retry_backoff_ms=0.1)
        t = q.submit_ingest(b1, (10_000 + np.arange(64)).astype(np.int64))
        rep = q.result(t)
        if q.stats["ingest_retries"] != 1 or rep.n != 64:
            errors.append("smoke: injected ingest abort was not absorbed "
                          "by exactly one retry")
        snap_res = pipe.lookup(b1[:8])  # pinned epoch-0 snapshot serves
        if snap_res.found.any() or snap_res.epoch != 0:
            errors.append("smoke: snapshot isolation leaked in-flight "
                          "ingest into the served epoch")
        pipe.publish()
        pipe.ingest(b2, (20_000 + np.arange(64)).astype(np.int64))
        pipe.publish()
        acked = pipe.lookup(np.concatenate([b1, b2]))
        pipe.ingest(b3, (30_000 + np.arange(64)).astype(np.int64))
        tear_tail(wal_path, 7)  # torn mid-record crash: b3 un-acked
        rec, info = recover_index(td, wal_path)
        got = rec.lookup(np.concatenate([b1, b2]))
        if not (info["torn"] and info["replayed"] == 2
                and np.array_equal(np.asarray(got.payloads),
                                   np.asarray(acked.payloads))
                and got.found.all()):
            errors.append("smoke: crash recovery (snapshot + torn-WAL "
                          "replay) diverged from the acked state")
        if rec.lookup(b3[:8]).found.any():
            errors.append("smoke: recovery replayed a torn (un-acked) "
                          "record")
        auditor.assert_ok(rec)
        if auditor.violations:
            errors.append("smoke: invariant auditor flagged "
                          f"{auditor.violations}")
        pipe.close()

    # repro-lint sanity: the static analyzer imports, walks the whole
    # installed package, flags a seeded violation, and stays cheap
    # enough for tier-1 (well under 10s — it is pure-AST, no tracing)
    t0 = time.perf_counter()
    import repro
    from repro.analysis import lint_paths, lint_source

    live = [f for f in lint_paths([os.path.dirname(repro.__file__)])
            if not f.suppressed]
    if live:
        errors.append(f"smoke: repro-lint found {len(live)} violation(s) "
                      f"in the installed package: {live[0].render()}")
    seeded = lint_source(
        "import jax, numpy as np\n\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return np.asarray(x) + 1\n",
        "src/repro/kernels/smoke_fixture.py")
    if not any(f.rule == "trace-host-sync" for f in seeded):
        errors.append("smoke: repro-lint missed a seeded host-sync "
                      "violation (analyzer inert)")
    lint_s = time.perf_counter() - t0
    if lint_s > 10.0:
        errors.append(f"smoke: repro-lint took {lint_s:.1f}s "
                      "(tier-1 budget is 10s)")

    for e in errors:
        print(f"# SMOKE: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print("# SMOKE: trajectory schemas valid, tiny-shape engine sanity "
          "and fault-injection/recovery checks OK", file=sys.stderr)


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        smoke()
        return
    nightly = "--nightly" in sys.argv[1:]
    if nightly:
        # full-timing mode: no reduced sweep, no gate opt-out, 3x reps
        os.environ["BENCH_NIGHTLY"] = "1"
        os.environ.pop("BENCH_FAST", None)
        os.environ.pop("BENCH_NO_GATE", None)
    fast = os.environ.get("BENCH_FAST", "0") == "1"
    gate = os.environ.get("BENCH_NO_GATE", "0") != "1"
    n = 60_000 if fast else None
    recorded = _load_trajectories() if gate else {}
    print("name,us_per_call,derived")
    failures = 0
    for prefix, mod in MODULES:
        t0 = time.time()
        try:
            rows = mod.run(n=n)
            emit(rows, prefix)
            print(f"# {prefix}: {len(rows)} rows in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:  # noqa: BLE001 — keep the suite going
            failures += 1
            print(f"# {prefix} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
    if gate:
        errors = check_trajectories(recorded, regressions=not fast)
        for e in errors:
            print(f"# GATE: {e}", file=sys.stderr)
        if errors:
            failures += 1
            # the sweep already overwrote the trajectory files; restore
            # the recorded baseline so a regressed run cannot launder
            # itself into the record and pass on re-run
            for name, old in recorded.items():
                if old is not None:
                    (_ROOT / name).write_text(json.dumps(old, indent=2))
                    print(f"# GATE: {name} restored to the recorded "
                          "baseline", file=sys.stderr)
        else:
            print("# GATE: trajectories valid, no >"
                  f"{REGRESSION_FACTOR}x regressions", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
