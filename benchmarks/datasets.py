"""Synthetic analogs of the paper's datasets (Weblogs/IoT/OSM are not
redistributable offline; these match size-class and distributional
character — see DESIGN.md §6).  Sizes scale with env BENCH_N
(default 400k keys; the paper's ratios, not absolute ns, are the target).
"""

from __future__ import annotations

import os

import numpy as np

BENCH_N = int(os.environ.get("BENCH_N", 400_000))


def weblogs(n: int = None, seed: int = 0) -> np.ndarray:
    """Bursty periodic request timestamps (school-schedule pattern)."""
    n = n or BENCH_N
    rng = np.random.default_rng(seed)
    lam = 1.0 + 4.0 * (np.sin(np.linspace(0, 60 * np.pi, n)) ** 2)
    gaps = rng.exponential(1.0, n) * lam
    gaps *= 1.0 + 12.0 * (rng.random(n) < 0.01)  # outage bursts
    return np.unique(np.cumsum(gaps))


def iot(n: int = None, seed: int = 1) -> np.ndarray:
    """Noisy multi-source sensor timestamps: piecewise activity regimes,
    outages, and per-source clock jitter (complex temporal patterns —
    paper §6.1 notes IoT is harder than Weblogs)."""
    n = n or BENCH_N
    rng = np.random.default_rng(seed)
    parts = []
    for i, scale in enumerate((0.3, 1.0, 3.0, 10.0)):
        m = n // 4
        # activity regime changes every ~m/50 events (bursts + quiet)
        n_regimes = 50
        rates = rng.lognormal(0.0, 1.2, n_regimes)
        reg = np.repeat(rates, m // n_regimes + 1)[:m]
        gaps = rng.exponential(scale, m) * reg
        gaps *= 1.0 + 50.0 * (rng.random(m) < 0.002)  # outages
        t = np.cumsum(gaps)
        t += rng.normal(0, scale * 0.05, m)  # collection jitter
        parts.append(t)
    return np.unique(np.concatenate(parts))


def longitude(n: int = None, seed: int = 2) -> np.ndarray:
    """Beta-mixture longitudes (population clusters)."""
    n = n or BENCH_N
    rng = np.random.default_rng(seed)
    a = rng.beta(2, 5, n // 3) * 360 - 180
    b = rng.beta(8, 2, n // 3) * 360 - 180
    c = rng.normal(10, 30, n - 2 * (n // 3))
    return np.unique(np.concatenate([a, b, np.clip(c, -180, 180)]))


def latilong(n: int = None, seed: int = 3) -> np.ndarray:
    """Compound keys: 90*latitude + longitude (paper's construction)."""
    n = n or BENCH_N
    rng = np.random.default_rng(seed)
    lat = rng.beta(5, 5, n) * 180 - 90
    lon = rng.beta(2, 5, n) * 360 - 180
    return np.unique(90.0 * lat + lon)


DATASETS = {
    "weblogs": weblogs,
    "iot": iot,
    "longitude": longitude,
    "latilong": latilong,
}
