"""Figures 9+10: gap insertion — static performance across (s, rho).

Reports overall/predict/correct query times, MAE, index size vs the
no-gap baseline (the paper's 1.59x overall / ~2x correction speedups).
"""

from __future__ import annotations

import numpy as np

from repro.core import LearnedIndex

from .common import measure
from .datasets import iot

RHOS = (0.0, 0.05, 0.2, 0.5)
RATES = (1.0, 0.1, 0.01)


def run(n=None, seed=0, method="pgm", eps=128):
    keys = iot(n)
    rng = np.random.default_rng(seed)
    queries = rng.choice(keys, min(100_000, len(keys)))
    rows = []
    base_overall = None
    for s in RATES:
        for rho in RHOS:
            idx = LearnedIndex.build(
                keys, method=method, eps=eps, sample_rate=s, gap_rho=rho,
                rng=np.random.default_rng(seed))
            m = measure(idx, queries)
            if s == 1.0 and rho == 0.0:
                base_overall = m["overall_ns"]
            m["query_speedup"] = (base_overall / m["overall_ns"]
                                  if base_overall else 1.0)
            if idx.gapped is not None:
                m["gap_fraction"] = idx.gapped.gap_fraction
                m["chained"], m["max_chain"] = idx.gapped.link_stats()
            rows.append({"name": f"{method}.s{s}.rho{rho}", **m})
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "fig9")
