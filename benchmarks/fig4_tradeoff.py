"""Figures 4+5: trade-offs as the alpha knob moves.

alpha proxies (paper §6.2): B+Tree page size and FIT/PGM eps are
inversely proportional to alpha; RMI #layer-2 models is proportional.
Emits (size, overall time) and (predict time, correct time, MAE) curves.
"""

from __future__ import annotations

import numpy as np

from repro.core import LearnedIndex

from .common import btree_measure, measure
from .datasets import iot


def run(n=None, seed=0):
    keys = iot(n)
    rng = np.random.default_rng(seed)
    queries = rng.choice(keys, min(100_000, len(keys)))
    rows = []
    sweeps = {
        "btree": [("page_size", p) for p in (64, 256, 1024, 4096)],
        "rmi": [("n_leaf", max(16, len(keys) // d))
                for d in (2000, 500, 100, 25)],
        "fiting": [("eps", e) for e in (16, 64, 256, 1024)],
        "pgm": [("eps", e) for e in (16, 64, 256, 1024)],
    }
    for method, knobs in sweeps.items():
        for pname, pval in knobs:
            idx = LearnedIndex.build(keys, method=method, **{pname: pval})
            m = btree_measure(idx, queries) if method == "btree" else \
                measure(idx, queries)
            rows.append({"name": f"{method}.{pname}{pval}", **m})
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "fig4")
