"""Single-pass query engine benchmark: engine (windowed search +
compacted fallback) vs the full-searchsorted oracle path, plus the
roofline-relevant bytes/query accounting.

The engine's CPU backend is the XLA windowed bisect (the Pallas kernel
is the TPU deploy target; ``interpret=True`` runs its body in Python and
is validated for correctness, not timed).  Before this PR the kernel
path ran the full-array oracle over EVERY query as an unconditional
fallback pass, so it was strictly slower than the oracle it wrapped;
the "before" column is therefore the oracle path itself (a lower bound
on the old cost).

Also writes ``BENCH_kernel.json`` at the repo root — the perf
trajectory file tracked across PRs.
"""

from __future__ import annotations

import json
import os
import pathlib
import time

# this container is 2-core: XLA's per-op thread handoff costs more than
# the parallelism returns on these op sizes (no effect if jax is already
# initialized, e.g. under the test suite)
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import numpy as np

from repro.core import LearnedIndex
from repro.kernels import QueryEngine, batched_lookup, from_learned_index

from .datasets import iot

_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _best_ns(fn, n_q, reps=9):
    fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        fn()
        best = min(best, time.perf_counter_ns() - t0)
    return best / n_q


def _best_ns_pair(fn_a, fn_b, n_q, reps=15):
    """Interleaved best-of timing: alternating the two arms cancels the
    container's load drift out of the comparison."""
    fn_a(), fn_b()
    best_a = best_b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        fn_a()
        best_a = min(best_a, time.perf_counter_ns() - t0)
        t0 = time.perf_counter_ns()
        fn_b()
        best_b = min(best_b, time.perf_counter_ns() - t0)
    return best_a / n_q, best_b / n_q


def run(n=None, seed=0):
    keys = iot(n)[:200_000]
    # f32-exact grid for the device path
    keys = np.unique(np.round(keys * 64.0))
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.15)
    engine = QueryEngine.from_index(idx)          # xla windowed on CPU
    oracle = QueryEngine.from_index(idx, backend="oracle")
    arrs = from_learned_index(idx)
    err_lo = idx.mech.plm.err_lo
    rng = np.random.default_rng(seed)
    rows = []
    w_tile = 2048
    for n_q in (4096, 32768):
        q = rng.choice(keys, n_q)
        escapes_before = engine.stats["oracle_escapes"]
        t_oracle, t_engine = _best_ns_pair(
            lambda: np.asarray(oracle.lookup(q)[0]),
            lambda: np.asarray(engine.lookup(q)[0]), n_q)
        out_o = np.asarray(oracle.lookup(q)[0])
        out_e, _, _, fb = engine.lookup(q)
        assert np.array_equal(np.asarray(out_e), out_o)
        # Pallas kernel (interpret): correctness + fallback-rate only
        out_k, _, _, fb_k = batched_lookup(arrs, err_lo, q, interpret=True)
        assert np.array_equal(np.asarray(out_k), out_o)
        # numpy reference
        t_numpy = _best_ns(lambda: idx.gapped.lookup_batch(q), n_q, reps=3)
        rows.append({
            "name": f"lookup.q{n_q}",
            "overall_ns": t_engine,
            "oracle_ns": t_oracle,
            "numpy_ns": t_numpy,
            "speedup_vs_oracle": t_oracle / t_engine,
            "fallback_rate": float(fb) / n_q,
            "kernel_fallback_rate": float(fb_k) / n_q,
            "oracle_escapes": engine.stats["oracle_escapes"]
            - escapes_before,
            "hbm_bytes_per_query": 2 * w_tile * 4 / 256.0,  # window/q_tile
            "match_oracle": 1.0,
        })
    _write_trajectory(rows)
    return rows


def _write_trajectory(rows):
    """BENCH_kernel.json at the repo root: before (oracle ns/query — a
    lower bound on the old always-double-resolve kernel path) vs after
    (single-pass compacted path) per batch size."""
    payload = {
        "benchmark": "kernel.single_pass_engine",
        "dataset": "iot",
        "rows": [
            {
                "batch": r["name"],
                "before_ns_per_query": r["oracle_ns"],
                "after_ns_per_query": r["overall_ns"],
                "speedup": r["speedup_vs_oracle"],
                "fallback_rate": r["fallback_rate"],
                "oracle_escapes": r["oracle_escapes"],
            }
            for r in rows
        ],
    }
    (_ROOT / "BENCH_kernel.json").write_text(json.dumps(payload, indent=2))


if __name__ == "__main__":
    from .common import emit
    emit(run(), "kernel")
