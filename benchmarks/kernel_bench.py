"""Pallas lookup kernel benchmark: kernel(interpret) vs jnp-oracle vs
numpy reference, plus the roofline-relevant bytes/query accounting.

interpret=True timing is NOT TPU wall-time (the body runs in Python);
the comparable numbers are (a) jnp-oracle XLA-CPU time and (b) the
per-query bytes/ops the kernel's tiling contracts to, reported as
derived columns.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import LearnedIndex
from repro.kernels import batched_lookup, from_learned_index

from .datasets import iot


def run(n=None, seed=0):
    keys = iot(n)[:200_000]
    # f32-exact grid for the kernel path
    keys = np.unique(np.round(keys * 64.0))
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.15)
    arrs = from_learned_index(idx)
    err_lo = idx.mech.plm.err_lo
    rng = np.random.default_rng(seed)
    rows = []
    for n_q in (4096, 32768):
        q = rng.choice(keys, n_q)
        # warm + time oracle path (XLA CPU)
        out_o, *_ = batched_lookup(arrs, err_lo, q, use_kernel=False)
        t0 = time.perf_counter_ns()
        out_o, *_ = batched_lookup(arrs, err_lo, q, use_kernel=False)
        t_oracle = (time.perf_counter_ns() - t0) / n_q
        # kernel (interpret) — correctness + fallback-rate measurement
        out_k, slot, found, fb = batched_lookup(arrs, err_lo, q,
                                                interpret=True)
        assert np.array_equal(np.asarray(out_k), np.asarray(out_o))
        # numpy reference
        t0 = time.perf_counter_ns()
        idx.gapped.lookup_batch(q)
        t_numpy = (time.perf_counter_ns() - t0) / n_q
        w_tile = 2048
        rows.append({
            "name": f"lookup.q{n_q}",
            "overall_ns": t_oracle,
            "numpy_ns": t_numpy,
            "fallback_rate": float(fb) / n_q,
            "hbm_bytes_per_query": 2 * w_tile * 4 / 256.0,  # window/q_tile
            "match_oracle": 1.0,
        })
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "kernel")
