"""Fused single-dispatch engine benchmark: the fused lookup path (rank-
routed bounded search + fused epilogue + O(#escapes) host patch; the
Pallas fused kernel on TPU, the lean XLA graph on CPU) vs the
full-searchsorted device oracle, across the small/medium/large batch
regime — plus the ``Index`` handle's ingest-to-queryable comparison
(delta-updated device buffers vs a full refreeze) written to
``BENCH_api.json``.

The sweep covers q512/q1024/q4096/q32768 and records the CROSSOVER
(smallest batch where the fused path is at least as fast as the
oracle): PR 2's multi-op windowed backend paid per-op dispatch overhead
and LOST to the oracle below ~8k queries (0.98x at q4096 in the
recorded trajectory) — the fused path exists to own exactly that
regime.  Both fused Pallas variants (legacy multi-op and fused
single-dispatch) are validated for bit-identity in interpret mode; the
timed CPU arm is the fused XLA graph.

``run_agg`` extends the small-batch story across CALLERS: at q<=1024
the residual cost is fixed per-dispatch host overhead, so N concurrent
small lookups through one ``MicroBatchQueue`` flush (one padded
dispatch + demux) are compared against N per-call dispatches — the
``lookup.agg.q*`` trajectory rows.

Also writes ``BENCH_kernel.json`` at the repo root — the perf
trajectory file tracked across PRs (benchmarks/run.py gates on it,
including the recorded crossover).
"""

from __future__ import annotations

import copy
import json
import os
import pathlib
import time

# this container is 2-core: XLA's per-op thread handoff costs more than
# the parallelism returns on these op sizes (no effect if jax is already
# initialized, e.g. under the test suite)
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import numpy as np

from repro.core import Index, LearnedIndex
from repro.kernels import QueryEngine, batched_lookup, from_learned_index

from .datasets import iot

_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _reps(reps):
    """--nightly triples the timing reps for lower-variance trajectories
    (benchmarks.run sets BENCH_NIGHTLY=1)."""
    return reps * 3 if os.environ.get("BENCH_NIGHTLY") == "1" else reps


def _best_ns(fn, n_q, reps=9):
    fn()
    best = float("inf")
    for _ in range(_reps(reps)):
        t0 = time.perf_counter_ns()
        fn()
        best = min(best, time.perf_counter_ns() - t0)
    return best / n_q


def _best_ns_pair(fn_a, fn_b, n_q, reps=15):
    """Interleaved best-of timing: alternating the two arms cancels the
    container's load drift out of the comparison."""
    fn_a(), fn_b()
    best_a = best_b = float("inf")
    for _ in range(_reps(reps)):
        t0 = time.perf_counter_ns()
        fn_a()
        best_a = min(best_a, time.perf_counter_ns() - t0)
        t0 = time.perf_counter_ns()
        fn_b()
        best_b = min(best_b, time.perf_counter_ns() - t0)
    return best_a / n_q, best_b / n_q


def run(n=None, seed=0):
    keys = iot(n)[:200_000]
    # f32-exact grid for the device path
    keys = np.unique(np.round(keys * 64.0))
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.15)
    engine = QueryEngine.from_index(idx)          # fused (XLA on CPU)
    oracle = QueryEngine.from_index(idx, backend="oracle")
    arrs = from_learned_index(idx)
    err_lo = idx.mech.plm.err_lo
    err_hi = idx.mech.plm.err_hi
    rng = np.random.default_rng(seed)
    rows = []
    w_tile = 2048
    for n_q in (512, 1024, 4096, 32768):
        q = rng.choice(keys, n_q)
        escapes_before = engine.stats["oracle_escapes"]
        t_oracle, t_engine = _best_ns_pair(
            lambda: np.asarray(oracle.lookup(q)[0]),
            lambda: np.asarray(engine.lookup(q)[0]), n_q)
        out_o = np.asarray(oracle.lookup(q)[0])
        out_e, _, _, fb = engine.lookup(q)
        assert np.array_equal(np.asarray(out_e), out_o)
        # Pallas kernels (interpret): correctness + fallback-rate only —
        # the legacy multi-op kernel and the fused single-dispatch one
        out_k, _, _, fb_k = batched_lookup(arrs, err_lo, q, interpret=True)
        assert np.array_equal(np.asarray(out_k), out_o)
        if n_q <= 4096:  # interpret mode runs the body in Python
            out_fk, _, _, _ = batched_lookup(
                arrs, err_lo, q, backend="fused-pallas",
                err_hi_by_seg=err_hi, interpret=True)
            assert np.array_equal(np.asarray(out_fk), out_o)
        # numpy reference
        t_numpy = _best_ns(lambda: idx.gapped.lookup_batch(q), n_q, reps=3)
        rows.append({
            "name": f"lookup.q{n_q}",
            "overall_ns": t_engine,
            "oracle_ns": t_oracle,
            "numpy_ns": t_numpy,
            "speedup_vs_oracle": t_oracle / t_engine,
            "fallback_rate": float(fb) / n_q,
            "kernel_fallback_rate": float(fb_k) / n_q,
            "oracle_escapes": engine.stats["oracle_escapes"]
            - escapes_before,
            "hbm_bytes_per_query": 2 * w_tile * 4 / 256.0,  # window/q_tile
            "match_oracle": 1.0,
        })
    # cross-caller aggregation at the small-batch sizes the per-dispatch
    # overhead dominates (rows join the BENCH_kernel trajectory)
    rows += run_agg(keys, seed=seed)
    # reduced sweeps (BENCH_FAST / n override) must NOT overwrite the
    # repo-root trajectory record the regression gate compares against —
    # toy-size numbers would read as phantom regressions on the next
    # full run
    full = n is None
    if full:
        _write_trajectory(rows)
    # full runs use the api benchmark's own serving-scale build; reduced
    # sweeps reuse the small key set to stay quick
    rows += run_api(None if full else keys, seed=seed, write=full)
    return rows


def run_agg(keys, seed=0, callers=8):
    """Cross-caller batch aggregation (serving/engine.MicroBatchQueue):
    ``callers`` concurrent callers each resolving a small sorted key
    batch, as ``callers`` per-call fused dispatches (before) vs ONE
    aggregated flush (after — submit + one padded shape-bucketed
    dispatch + typed demux).  At q<=1024 total the fixed per-dispatch
    host overhead (~0.5 ms/call on CPU) dominates the device search, so
    amortizing it across callers is the whole win — this is exactly the
    per-round page-resolution path ``ServingEngine`` runs.

    Rows enter ``BENCH_kernel.json`` as ``lookup.agg.q*`` with before =
    the per-call path, after = the aggregated flush."""
    from repro.serving.engine import MicroBatchQueue

    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.15)
    idx.sync_device()
    eng = idx._engine
    rng = np.random.default_rng(seed + 7)
    rows = []
    for n_q in (512, 1024):
        per = n_q // callers
        parts = [np.sort(rng.choice(keys, per)) for _ in range(callers)]
        agg = MicroBatchQueue(idx, min_bucket=n_q)

        def before():
            return [np.asarray(idx.lookup(p, backend="fused",
                                          queries_sorted=True).payloads)
                    for p in parts]

        def after():
            ts = [agg.submit_lookup(p) for p in parts]
            agg.flush()
            return [np.asarray(agg.result(t).payloads) for t in ts]

        t_before, t_after = _best_ns_pair(before, after, n_q)
        out_b, out_a = before(), after()
        assert all(np.array_equal(x, y) for x, y in zip(out_b, out_a))
        escapes0 = eng.stats["oracle_escapes"]
        res = idx.lookup(np.concatenate(parts), backend="fused")
        rows.append({
            "name": f"lookup.agg.q{n_q}",
            "overall_ns": t_after,
            "oracle_ns": t_before,
            "speedup_vs_oracle": t_before / max(t_after, 1e-9),
            "fallback_rate": float(res.fallbacks) / n_q,
            "oracle_escapes": eng.stats["oracle_escapes"] - escapes0,
        })
    return rows


def run_api(keys=None, seed=0, rounds=5, write=True):
    """Ingest-to-queryable latency over repeated mutation bursts (the
    serving shape: a decode loop allocates pages, then resolves them):
    per round, apply the same host mutations to both arms, then time how
    long until a probe batch is answered on the device —

    * delta arm: the ``Index`` handle's lazy device sync scatters only
      changed slot/payload elements and swaps the shifted CSR tables
      into the RESIDENT buffers (no window-bound recompute, no engine
      rebuild, no executable retrace);
    * refreeze arm: the legacy dance — full ``refreeze()`` per burst
      (window bounds + freeze + engine init; and whenever chain growth
      moves a jit static, an executable retrace).

    Lookups are asserted bit-identical between the arms every round.
    Writes ``BENCH_api.json`` (mean per-round latencies).
    """
    if keys is None:
        # serving-scale index: at toy sizes the host-side freeze is so
        # cheap the comparison degenerates
        keys = np.unique(np.round(iot(800_000) * 64.0))
    rng = np.random.default_rng(seed)
    base = Index.build(keys, method="pgm", eps=64, gap_rho=0.15)
    mids = np.setdiff1d(
        keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    rng.shuffle(mids)
    # one decode round's worth of page resolutions (sorted, device-sized)
    probe = np.sort(rng.choice(keys, 8_192))
    rows = []
    used = 0
    warm_n = max(512, len(keys) // 100)
    for frac in (0.01, 0.05):
        n_mut = int(frac * len(keys))
        warm = mids[used: used + warm_n]
        used += warm_n
        muts = []
        for r in range(rounds):
            muts.append((mids[used: used + n_mut],
                         (10 + r) * 1_000_000 + np.arange(n_mut)))
            used += n_mut

        def warmed_arm():
            a = copy.deepcopy(base)
            # warm rounds grow frozen capacities and compile the probe
            # bucket + every delta scatter/swap combination once, so the
            # timed rounds see steady-state behavior
            a.ingest(warm[: warm_n // 2], np.arange(warm_n // 2))
            a.refreeze()
            a.lookup(probe, backend="xla-windowed", queries_sorted=True)
            for s in range(2):  # two real delta rounds
                lo = warm_n // 2 + s * warm_n // 4
                wk = warm[lo: lo + warm_n // 4]
                a.insert_batch(wk, 777_000 + np.arange(len(wk)))
                a.lookup(probe, backend="xla-windowed",
                         queries_sorted=True)
            return a

        a = warmed_arm()
        a.refreeze_contested_frac = 1.1  # policy off: pure delta arm
        a.refreeze_link_growth = 10.0
        b = warmed_arm()
        t_delta = []
        t_refreeze = []
        bit_identical = True
        mode = "delta"
        elems0 = a.stats["delta_elems"]
        for mut, pays in muts:
            a.insert_batch(mut, pays)       # identical host mutation...
            b.insert_batch(mut, pays)       # ...applied to both arms
            t0 = time.perf_counter()        # mutations applied ->
            a.sync_device()                 # -> device queryable again
            t_delta.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            b.refreeze()                    # the legacy full rebuild
            t_refreeze.append(time.perf_counter() - t0)
            # untimed: both arms answer a probe batch bit-identically
            res_a = a.lookup(probe, backend="xla-windowed",
                             queries_sorted=True)
            res_b = b.lookup(probe, backend="xla-windowed",
                             queries_sorted=True)
            bit_identical &= bool(np.array_equal(res_a.payloads,
                                                 res_b.payloads))
            bit_identical &= bool(np.array_equal(res_a.found, res_b.found))
            if a.stats["refreezes"] > 1:
                mode = "refreeze"           # capacity outgrown mid-run
        elems = a.stats["delta_elems"] - elems0
        ok = bit_identical and bool(np.array_equal(
            np.asarray(a.lookup(muts[-1][0],
                                backend="xla-windowed").payloads),
            muts[-1][1]))
        # median over rounds: robust to container-load spikes while the
        # structural gap (resident-buffer patch vs full rebuild) remains
        d_ms = 1e3 * float(np.median(t_delta))
        r_ms = 1e3 * float(np.median(t_refreeze))
        rows.append({
            "name": f"api.ingest_mut{int(frac*100)}pct",
            "overall_ns": d_ms * 1e6 / max(n_mut, 1),
            "delta_ms": d_ms,
            "refreeze_ms": r_ms,
            "speedup_delta_vs_refreeze": r_ms / max(d_ms, 1e-9),
            "device_mode": mode,
            "device_elems": elems,
            "bit_identical": float(bit_identical),
            "resolves_mutations": float(ok),
        })
    payload = {
        "benchmark": "api.ingest_to_queryable",
        "dataset": "iot",
        "rounds": rounds,
        "rows": [
            {
                "batch": r["name"],
                "mutation_frac": float(r["name"].split("mut")[1][:-3]) / 100,
                "delta_ms": r["delta_ms"],
                "refreeze_ms": r["refreeze_ms"],
                "speedup": r["speedup_delta_vs_refreeze"],
                "bit_identical": bool(r["bit_identical"]),
            }
            for r in rows
        ],
    }
    if write:
        (_ROOT / "BENCH_api.json").write_text(json.dumps(payload, indent=2))
    return rows


def crossover_queries(rows):
    """Smallest benchmarked batch size where the engine is at least as
    fast as the device oracle (None if it never is)."""
    xs = sorted(
        (int(r["name"].split(".q")[1]), r["speedup_vs_oracle"])
        for r in rows if r["name"].startswith("lookup.q"))
    for n_q, sp in xs:
        if sp >= 1.0:
            return n_q
    return None


def _write_trajectory(rows):
    """BENCH_kernel.json at the repo root: before (device oracle
    ns/query — the searchsorted path the engine must beat at EVERY
    batch size) vs after (fused single-dispatch path) per batch size,
    plus the recorded small-batch crossover the run.py gate guards."""
    payload = {
        "benchmark": "kernel.single_pass_engine",
        "dataset": "iot",
        "crossover_vs_oracle_queries": crossover_queries(rows),
        "rows": [
            {
                "batch": r["name"],
                "before_ns_per_query": r["oracle_ns"],
                "after_ns_per_query": r["overall_ns"],
                "speedup": r["speedup_vs_oracle"],
                "fallback_rate": r["fallback_rate"],
                "oracle_escapes": r["oracle_escapes"],
            }
            for r in rows
        ],
    }
    (_ROOT / "BENCH_kernel.json").write_text(json.dumps(payload, indent=2))


if __name__ == "__main__":
    from .common import emit
    emit(run(), "kernel")
