"""Figure 11: dynamic workloads (read-heavy w=0.3, write-heavy w=0.7).

Split D into D_init + insert batches; after each batch, query the keys
seen so far and report MAE / times / remaining gap fraction, plus the
no-gap baseline that sees all data (the paper's 1.227x overall claim).

Ingest now goes through the vectorized ``insert_batch`` (batched §5.3
dynamic insert); each batch also replays sequential per-key ``insert()``
calls on a copy to report the batched-vs-sequential speedup (the two
paths are state-identical — asserted in tests/test_dynamic*), plus the
per-batch contested-replay fraction (keys the per-key commutativity
analysis could not clear — they visit the scalar arrival-order replay).

Writes ``BENCH_ingest.json`` at the repo root: the contested fraction +
batched-vs-sequential sweep, gated by ``benchmarks.run`` (schema always,
1.25x speedup regression against the recorded trajectory on full runs;
``--smoke`` validates the committed schema without timing).  The file
also carries the fused single-dispatch ingest sweep
(``run_fused_dispatch``): ONE fused device dispatch (placement + slot
scatter + CSR merge + rank/bound refresh) vs the two-dispatch
place-then-delta path, per batch size, on a device-resident handle.

Device staleness (``run_device_staleness``): clustered ingest bursts on
an epoch-versioned ``Index`` whose device state follows via DELTA
updates only (policy refreeze off), comparing the compacted-fallback
rate of the delta-synced engine — whose window bounds and fused rank
rows are incrementally refreshed for the touched segments — against a
fully refrozen copy.  The acceptance bar: the delta arm's fallback rate
stays within 2x of the post-refreeze rate instead of climbing until the
policy refreeze (ROADMAP "stale-window refresh").
"""

from __future__ import annotations

import copy
import json
import pathlib
import time

import numpy as np

from repro.core import Index, LearnedIndex

from .common import measure
from .datasets import iot

_ROOT = pathlib.Path(__file__).resolve().parents[1]


def run(n=None, seed=0, method="pgm", eps=128, rho=0.3, batches=5,
        write=True):
    keys = iot(n if n else None)
    keys = keys[: min(len(keys), 200_000)]  # dynamic path is host-side
    rng = np.random.default_rng(seed)
    rows = []
    for w, label in ((0.3, "read_heavy"), (0.7, "write_heavy")):
        perm = rng.permutation(len(keys))
        n_ins = int(w * len(keys))
        init_keys = np.sort(keys[perm[n_ins:]])
        ins_keys = keys[perm[:n_ins]]
        idx = LearnedIndex.build(init_keys, method=method, eps=eps,
                                 gap_rho=rho)
        # baseline without gaps that can access ALL the data
        full = LearnedIndex.build(np.sort(keys), method=method, eps=eps)
        qs = rng.choice(init_keys, 20_000)
        base = measure(full, qs)
        seen = [init_keys]
        for b in range(batches):
            batch = ins_keys[b * n_ins // batches:(b + 1) * n_ins // batches]
            pay = 10_000_000 + np.arange(len(batch)) + b
            # best-of-3 on both arms: single-shot timings are dominated
            # by container noise at these batch sizes
            t_seq = float("inf")
            for _ in range(3):  # sequential reference: per-key insert()
                seq_idx = copy.deepcopy(idx)
                t0 = time.perf_counter_ns()
                for k, p in zip(batch, pay):
                    seq_idx.insert(float(k), int(p))
                t_seq = min(t_seq,
                            (time.perf_counter_ns() - t0) / max(len(batch), 1))
            # batched dynamic ingest: warm reps on copies, then the real
            # apply (state moves forward exactly once)
            t_bat = float("inf")
            for _ in range(2):
                warm = copy.deepcopy(idx)
                t0 = time.perf_counter_ns()
                warm.insert_batch(batch, pay)
                t_bat = min(t_bat,
                            (time.perf_counter_ns() - t0) / max(len(batch), 1))
            t0 = time.perf_counter_ns()
            counts = idx.insert_batch(batch, pay)
            t_bat = min(t_bat,
                        (time.perf_counter_ns() - t0) / max(len(batch), 1))
            seen.append(batch)
            qpool = np.concatenate(seen)
            qs = rng.choice(qpool, 20_000)
            m = measure(idx, qs)
            m["gap_fraction"] = idx.gapped.gap_fraction
            m["overall_vs_nogap_baseline"] = base["overall_ns"] / m["overall_ns"]
            m["insert_seq_ns"] = t_seq
            m["insert_batch_ns"] = t_bat
            m["insert_speedup"] = t_seq / max(t_bat, 1e-9)
            m["contested_frac"] = counts["contested"] / max(len(batch), 1)
            rows.append({"name": f"{label}.batch{b+1}", **m})
    # aggregate: geometric-mean batched-vs-sequential insert speedup.
    # NOTE the sequential arm is the CSR-overlay scalar path the PR 2
    # refactor made ~3.5x faster (~25 us/key vs ~90 us/key before);
    # against the pre-CSR sequential baseline the batched path is
    # >100x.  The per-key demotion partition keeps the write-heavy tail
    # batches' contested-replay fraction in the ~1% range (the per-run
    # closure left 10-15% there, capping those batches near ~9x).
    sp = [r["insert_speedup"] for r in rows]
    rows.append({"name": "insert_speedup.geomean",
                 "us": 0.0,
                 "geomean": float(np.exp(np.mean(np.log(sp)))),
                 "min": float(min(sp)), "max": float(max(sp))})
    # fused single-dispatch ingest sweep: its rows join the ingest
    # trajectory file below (fresh batch names — the regression gate
    # starts guarding them from the first recorded full run onward);
    # the geomean above stays the host batched-vs-sequential aggregate
    rows += run_fused_dispatch(n=min(n, 120_000) if n else 120_000,
                               seed=seed)
    # reduced sweeps (BENCH_FAST / n override) must NOT overwrite the
    # repo-root trajectory record the regression gate compares against
    # (same rule as kernel_bench) — toy-size speedups would read as
    # phantom regressions on the next full run
    if write and n is None:
        probe = _abort_probe()
        payload = {
            "benchmark": "ingest.batched_vs_sequential",
            "dataset": "iot",
            "note": ("per-batch §5.3 batched insert vs sequential "
                     "insert() on a copy (state-identical arms); "
                     "contested_frac counts scalar-replay-visited keys "
                     "across all recursive partition rounds; "
                     "fused_dispatch rows compare ONE fused device "
                     "dispatch (insert_batch_ns) against the "
                     "two-dispatch place+delta path (insert_seq_ns)"),
            "rows": [
                {"batch": f"ingest.{r['name']}",
                 "contested_frac": r["contested_frac"],
                 "insert_seq_ns": r["insert_seq_ns"],
                 "insert_batch_ns": r["insert_batch_ns"],
                 "speedup": r["insert_speedup"]}
                for r in rows if "contested_frac" in r
            ],
            "speedup_geomean": float(np.exp(np.mean(np.log(sp)))),
            "contested_frac_max": float(max(
                r["contested_frac"] for r in rows
                if "contested_frac" in r)),
            # fused-abort telemetry (IngestReport.abort_reasons /
            # .fused_aborts): the crafted crowded-batch probe's veto,
            # answering "how often does the write graph refuse, and
            # why" from this file alone (the sweep rows above are
            # pre-screened committing batches, aborts there are 0)
            "fused_aborts_total": int(probe.fused_aborts),
            "fused_abort_reasons": sorted(probe.abort_reasons),
        }
        (_ROOT / "BENCH_ingest.json").write_text(
            json.dumps(payload, indent=2))
    rows += run_device_staleness(n=min(n, 120_000) if n else 120_000,
                                 seed=seed)
    return rows


def _abort_probe(n=40_000):
    """Craft a batch the fused write graph must VETO (a contiguous run
    crammed with new keys trips the in-graph closure check) and return
    its ``IngestReport`` — the per-batch ``abort_reasons`` and the
    engine's cumulative ``fused_aborts`` ride the trajectory file so
    the veto rate is answerable from ``BENCH_ingest.json`` alone."""
    keys = np.arange(0, 100 * n, 100, dtype=np.float64)
    idx = Index.build(keys, method="pgm", eps=32, gap_rho=0.2)
    idx.fused_ingest_enabled = True
    idx.sync_device()
    batch = np.setdiff1d(
        np.arange(5_001, 5_001 + 620, dtype=np.float64), keys)[:512]
    rep = idx.ingest(batch, np.arange(batch.size))
    assert rep.device != "fused" and rep.abort_reasons, (
        "abort probe no longer aborts — rebuild it around a shape the "
        "closure check refuses")
    return rep


def run_fused_dispatch(n=120_000, seed=0, batch_sizes=(512, 2048, 8192),
                       reps=3):
    """Fused single-dispatch ingest vs the two-dispatch path, per batch.

    Both arms start from the same device-resident ``Index`` and apply
    the same well-spread midpoint batch; both end device-queryable:

    * fused arm (``insert_batch_ns``): ONE device dispatch — placement,
      slot scatter, CSR merge, rank/bound refresh in one graph, device
      buffers adopted (``IngestReport.device == "fused"``);
    * two-dispatch arm (``insert_seq_ns``): ``fused_ingest_enabled =
      False`` — device placement dispatch, host partition, then the
      delta-update dispatch to re-sync the device buffers.

    Batches are strided midpoints, so the in-graph closure check accepts
    (contested_frac is the two-dispatch arm's measured fraction — the
    fused arm only ever commits contested-free batches); placement
    ESCAPE rows (the ~1e-4 rounding-band ambiguity, per-key and
    batch-independent) are pre-screened out, since one escape aborts
    the graph and this sweep measures the accepted-batch path.  Timing
    is interleaved best-of-``reps`` on fresh copies (arm state moves
    forward each rep, so copies are rebuilt outside the timer; the
    first rep absorbs graph compilation on each new shape bucket).
    """
    from repro.kernels.ops_gap import ingest_place

    keys = np.unique(np.round(iot(n) * 64.0))  # f32-pair-exact grid
    base = Index.build(keys, method="pgm", eps=64, gap_rho=0.15)
    base.sync_device()
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    rows = []
    for n_b in batch_sizes:
        if n_b > base.gapped.batch_chunk() or n_b > len(mids):
            continue
        batch = mids[:: max(1, len(mids) // n_b)][:n_b]
        _, esc = ingest_place(base._engine.arrays, batch)
        batch = batch[~np.asarray(esc, bool)]
        n_b = len(batch)
        pays = 20_000_000 + np.arange(n_b)

        def arm(fused: bool):
            a = copy.deepcopy(base)       # deepcopy drops the engine...
            a.fused_ingest_enabled = fused
            a.sync_device()               # ...refreeze outside the timer
            t0 = time.perf_counter_ns()
            rep = a.ingest(batch, pays)
            return (time.perf_counter_ns() - t0) / n_b, rep, a

        t_fused = t_two = float("inf")
        rep_f = rep_t = idx_f = idx_t = None
        for _ in range(reps):
            dt, rep_f, idx_f = arm(True)
            t_fused = min(t_fused, dt)
            dt, rep_t, idx_t = arm(False)
            t_two = min(t_two, dt)
        assert rep_f.device == "fused", rep_f.device
        # both arms end bit-identical and device-queryable
        assert np.array_equal(idx_f.gapped.slot_key, idx_t.gapped.slot_key)
        res = idx_f.lookup(batch, backend="fused", queries_sorted=True)
        assert np.array_equal(np.asarray(res.payloads), pays)
        rows.append({
            "name": f"fused_dispatch.batch{n_b}",
            "overall_ns": t_fused,
            "contested_frac": rep_t.contested / n_b,
            "insert_seq_ns": t_two,
            "insert_batch_ns": t_fused,
            "insert_speedup": t_two / max(t_fused, 1e-9),
        })
    return rows


def run_device_staleness(n=120_000, seed=0, rounds=4, probe_n=8_192):
    """Three arms, identical host mutations, compacted-fallback rate per
    ingest round on the FUSED device path (no overflow escape on that
    path, so the reported counts are the raw flag rates):

    * ``refresh``  — delta-synced device state WITH the incremental
      per-segment bound + rank-row refresh (the default);
    * ``stale``    — delta-synced with the refresh disabled
      (``refresh_segments_frac = 0``): what the fallback rate does when
      the frozen tables drift under the mutations;
    * ``refreeze`` — full rebuild per round (the expensive gold arm).

    Ingest bursts are CLUSTERED (contiguous key-range slices — the
    allocation pattern serving actually produces), so only a small
    fraction of segments is touched per round and the incremental
    refresh engages instead of being skipped as near-global churn.
    The acceptance bar: refresh-arm rate within 2x of the refreeze-arm
    rate on every round.
    """
    keys = np.unique(np.round(iot(n) * 64.0))  # f32-exact device grid
    rng = np.random.default_rng(seed)

    def build():
        idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.15)
        idx.refreeze_contested_frac = 1.1   # policy off: pure delta
        idx.refreeze_link_growth = 10.0
        # this experiment measures the DELTA-sync arm's staleness; the
        # fused single-dispatch path refreshes rank rows/bounds in-graph
        # and would never let the tables drift (run_fused_dispatch covers
        # that path)
        idx.fused_ingest_enabled = False
        idx.sync_device()
        return idx

    idx = build()
    stale = build()
    stale.refresh_segments_frac = 0.0       # refresh disabled
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    lo = len(mids) // 4  # clustered bursts from one key-range slice
    burst = max(1_000, len(mids) // 40)
    rows = []
    for r in range(rounds):
        batch = mids[lo + r * burst: lo + (r + 1) * burst]
        pays = 9_000_000 + r * burst + np.arange(len(batch))
        idx.ingest(batch, pays)
        stale.ingest(batch, pays)
        assert idx.stats["refreezes"] == 1  # still the delta arm
        probe = np.concatenate([
            rng.choice(keys, probe_n // 2),
            rng.choice(mids[lo: lo + (r + 1) * burst], probe_n // 2)])
        fresh = copy.deepcopy(idx)      # device dropped by deepcopy
        fresh.refreeze()
        t0 = time.perf_counter_ns()
        res_d = idx.lookup(probe, backend="fused")
        dt = (time.perf_counter_ns() - t0) / max(probe_n, 1)
        res_s = stale.lookup(probe, backend="fused")
        res_f = fresh.lookup(probe, backend="fused")
        assert np.array_equal(res_d.payloads, res_f.payloads)
        assert np.array_equal(res_s.payloads, res_f.payloads)
        rate = lambda res: res.fallbacks / max(probe_n, 1)  # noqa: E731
        floor = 1.0 / probe_n  # one fallback, for a stable ratio
        rows.append({
            "name": f"device_staleness.round{r + 1}",
            "overall_ns": dt,
            "fallback_rate_refresh": rate(res_d),
            "fallback_rate_stale": rate(res_s),
            "fallback_rate_refreeze": rate(res_f),
            "ratio_vs_refreeze": (rate(res_d) + floor)
            / (rate(res_f) + floor),
            "stale_ratio_vs_refreeze": (rate(res_s) + floor)
            / (rate(res_f) + floor),
            "bound_refreshes": idx.stats["bound_refreshes"],
        })
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "fig11")
