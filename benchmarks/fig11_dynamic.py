"""Figure 11: dynamic workloads (read-heavy w=0.3, write-heavy w=0.7).

Split D into D_init + insert batches; after each batch, query the keys
seen so far and report MAE / times / remaining gap fraction, plus the
no-gap baseline that sees all data (the paper's 1.227x overall claim).

Ingest now goes through the vectorized ``insert_batch`` (batched §5.3
dynamic insert); each batch also replays sequential per-key ``insert()``
calls on a copy to report the batched-vs-sequential speedup (the two
paths are state-identical — asserted in tests/test_dynamic*).
"""

from __future__ import annotations

import copy
import time

import numpy as np

from repro.core import LearnedIndex

from .common import measure
from .datasets import iot


def run(n=None, seed=0, method="pgm", eps=128, rho=0.3, batches=5):
    keys = iot(n if n else None)
    keys = keys[: min(len(keys), 200_000)]  # dynamic path is host-side
    rng = np.random.default_rng(seed)
    rows = []
    for w, label in ((0.3, "read_heavy"), (0.7, "write_heavy")):
        perm = rng.permutation(len(keys))
        n_ins = int(w * len(keys))
        init_keys = np.sort(keys[perm[n_ins:]])
        ins_keys = keys[perm[:n_ins]]
        idx = LearnedIndex.build(init_keys, method=method, eps=eps,
                                 gap_rho=rho)
        # baseline without gaps that can access ALL the data
        full = LearnedIndex.build(np.sort(keys), method=method, eps=eps)
        qs = rng.choice(init_keys, 20_000)
        base = measure(full, qs)
        seen = [init_keys]
        for b in range(batches):
            batch = ins_keys[b * n_ins // batches:(b + 1) * n_ins // batches]
            pay = 10_000_000 + np.arange(len(batch)) + b
            # best-of-3 on both arms: single-shot timings are dominated
            # by container noise at these batch sizes
            t_seq = float("inf")
            for _ in range(3):  # sequential reference: per-key insert()
                seq_idx = copy.deepcopy(idx)
                t0 = time.perf_counter_ns()
                for k, p in zip(batch, pay):
                    seq_idx.insert(float(k), int(p))
                t_seq = min(t_seq,
                            (time.perf_counter_ns() - t0) / max(len(batch), 1))
            # batched dynamic ingest: warm reps on copies, then the real
            # apply (state moves forward exactly once)
            t_bat = float("inf")
            for _ in range(2):
                warm = copy.deepcopy(idx)
                t0 = time.perf_counter_ns()
                warm.insert_batch(batch, pay)
                t_bat = min(t_bat,
                            (time.perf_counter_ns() - t0) / max(len(batch), 1))
            t0 = time.perf_counter_ns()
            idx.insert_batch(batch, pay)
            t_bat = min(t_bat,
                        (time.perf_counter_ns() - t0) / max(len(batch), 1))
            seen.append(batch)
            qpool = np.concatenate(seen)
            qs = rng.choice(qpool, 20_000)
            m = measure(idx, qs)
            m["gap_fraction"] = idx.gapped.gap_fraction
            m["overall_vs_nogap_baseline"] = base["overall_ns"] / m["overall_ns"]
            m["insert_seq_ns"] = t_seq
            m["insert_batch_ns"] = t_bat
            m["insert_speedup"] = t_seq / max(t_bat, 1e-9)
            rows.append({"name": f"{label}.batch{b+1}", **m})
    # aggregate: geometric-mean batched-vs-sequential insert speedup.
    # NOTE the sequential arm is the CSR-overlay scalar path this same
    # refactor made ~3.5x faster (~25 us/key vs ~90 us/key before);
    # against the pre-CSR sequential baseline the batched path is
    # ~30-40x.  Write-heavy tail batches sit near ~9x, bounded by the
    # contested-replay fraction (see ROADMAP).
    sp = [r["insert_speedup"] for r in rows]
    rows.append({"name": "insert_speedup.geomean",
                 "us": 0.0,
                 "geomean": float(np.exp(np.mean(np.log(sp)))),
                 "min": float(min(sp)), "max": float(max(sp))})
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "fig11")
