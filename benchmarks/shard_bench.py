"""Sharded fan-out sweep: the range-partitioned ``ShardedIndex``'s
single fused fan-out dispatch vs a single-device ``Index`` over the
same keys, across shard counts x query batch sizes.

Each row times the SAME query batch on both handles (answers asserted
bit-identical first — a sharded speedup bought with wrong payloads is
worthless) and reports the router mispredict fraction the fan-out
measured in-graph: routing is exact regardless (bisect backstop), the
fraction only prices how often the backstop pays log2(S) instead of a
gather.  The rebalance probe forces one median split and reports its
wall cost — the price of patching the topology, to weigh against the
occupancy watermark that triggers it.

Writes ``BENCH_shard.json`` at the repo root (full-size runs only, same
rule as the other trajectory files): per-row speedup = single_ns /
sharded_ns, gated lower-is-worse at 1.25x by ``benchmarks.run``; on this
2-core CPU container the ratio hovers near 1 — the sweep guards the
DISPATCH OVERHEAD of the route/exchange/unsort choreography, while the
win it buys (per-shard placement over a real mesh) shows up at device
counts this container cannot time.
"""

from __future__ import annotations

import json
import os
import pathlib
import time

import numpy as np

from repro.core import Index

_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _reps(reps):
    return reps * 3 if os.environ.get("BENCH_NIGHTLY") == "1" else reps


def _best_ns_per_q(fn, n_q, reps):
    fn()  # warm: compile + freeze outside the timer
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter_ns()
        fn()
        best = min(best, time.perf_counter_ns() - t0)
    return best / max(n_q, 1)


def run(n=None, seed=0, shard_counts=(2, 4, 8), q_sizes=(2_048, 16_384),
        write=True):
    n_keys = min(n, 200_000) if n else 200_000
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.choice(2 ** 22, n_keys, replace=False)
                     ).astype(np.float64)  # f32-exact int grid
    single = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    rows = []
    mis_fracs = []
    reps = _reps(3)
    for s in shard_counts:
        sharded = Index.build(keys, shards=s, method="pgm", eps=64,
                              gap_rho=0.2)
        for n_q in q_sizes:
            q = np.concatenate([rng.choice(keys, int(n_q * 0.8)),
                                rng.choice(keys, n_q - int(n_q * 0.8))
                                + 1.0])
            rng.shuffle(q)
            res_s = sharded.lookup(q, backend="fanout")
            res_1 = single.lookup(q)
            assert np.array_equal(np.asarray(res_s.payloads),
                                  np.asarray(res_1.payloads))
            assert np.array_equal(np.asarray(res_s.found),
                                  np.asarray(res_1.found))
            r0 = dict(sharded.router.stats)
            sharded.lookup(q, backend="fanout")
            r1 = sharded.router.stats
            mis = ((r1["mispredicted"] - r0["mispredicted"])
                   / max(r1["routed"] - r0["routed"], 1))
            mis_fracs.append(mis)
            t_shard = _best_ns_per_q(
                lambda: sharded.lookup(q, backend="fanout"), n_q, reps)
            t_single = _best_ns_per_q(
                lambda: single.lookup(q), n_q, reps)
            rows.append({
                "name": f"s{s}.q{n_q}",
                "overall_ns": t_shard,
                "shards": s,
                "queries": n_q,
                "sharded_ns_per_q": t_shard,
                "single_ns_per_q": t_single,
                "speedup": t_single / max(t_shard, 1e-9),
                "router_mispredict_frac": float(mis),
            })
    # rebalance probe: force one median split and price it
    sharded = Index.build(keys, shards=4, method="pgm", eps=64,
                          gap_rho=0.2)
    rec = sharded.maybe_rebalance(force_shard=1)
    rebalance_ms = rec["seconds"] * 1e3
    probe = rng.choice(keys, 4_096)
    assert np.array_equal(
        np.asarray(sharded.lookup(probe, backend="fanout").payloads),
        np.asarray(single.lookup(probe).payloads))
    rows.append({"name": "rebalance.split1", "us": rebalance_ms * 1e3,
                 "rebalance_ms": rebalance_ms,
                 "n_left": rec["n_left"], "n_right": rec["n_right"]})
    if write and n is None:  # reduced sweeps never overwrite the record
        payload = {
            "benchmark": "sharded.fanout_vs_single",
            "dataset": "uniform_int_2e22",
            "note": ("single fused shard_map fan-out dispatch vs one "
                     "single-device Index over the same keys, "
                     "bit-identity asserted before timing; "
                     "router_mispredict_frac is the in-graph learned-"
                     "route miss rate (routing stays exact via the "
                     "bisect backstop); rebalance_ms prices one forced "
                     "median split including both half rebuilds"),
            "rows": [
                {"batch": f"shard.{r['name']}", "shards": r["shards"],
                 "queries": r["queries"],
                 "sharded_ns_per_q": r["sharded_ns_per_q"],
                 "single_ns_per_q": r["single_ns_per_q"],
                 "speedup": r["speedup"],
                 "router_mispredict_frac": r["router_mispredict_frac"]}
                for r in rows if "speedup" in r
            ],
            "rebalance_ms": rebalance_ms,
            "router_mispredict_frac_max": float(max(mis_fracs)),
        }
        (_ROOT / "BENCH_shard.json").write_text(
            json.dumps(payload, indent=2))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(), "shard")
