"""Quickstart: the paper's pluggable learned index in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Covers: MDL comparison of four mechanisms (§3), sampling speedup (§4),
gap insertion precision + dynamic inserts (§5), and the device
(Pallas-validated) batched lookup path.
"""

import numpy as np

from repro.core import LearnedIndex
from repro.kernels import batched_lookup, from_learned_index


def main():
    rng = np.random.default_rng(0)
    # bursty timestamp-like keys (f32-exact grid for the device path)
    keys = np.unique(np.round(np.cumsum(
        rng.exponential(1.0, 300_000) * (1 + 8 * (rng.random(300_000) < .01)))
        * 16.0))
    print(f"dataset: {len(keys):,} unique keys\n")

    # --- §3: MDL framework compares mechanisms on one axis ------------
    print("== MDL comparison (alpha=1) ==")
    for method, kw in [("btree", dict(page_size=256)),
                       ("rmi", dict(n_leaf=2000)),
                       ("fiting", dict(eps=128)),
                       ("pgm", dict(eps=128))]:
        idx = LearnedIndex.build(keys, method=method, **kw)
        r = idx.mdl()
        print(f"  {method:7s} L(M)={r.l_model_params:7d} params "
              f"L(D|M)={r.l_data_given_model:6.3f} bits  MAE={r.mae:9.2f} "
              f"build={idx.build_seconds*1e3:8.1f} ms")

    # --- §4: sampling — build fast, stay precise -----------------------
    print("\n== sampling (PGM eps=128) ==")
    full = LearnedIndex.build(keys, method="pgm", eps=128)
    for s in (1.0, 0.1, 0.01):
        idx = LearnedIndex.build(keys, method="pgm", eps=128, sample_rate=s,
                                 rng=np.random.default_rng(1))
        print(f"  s={s:<5} build={idx.build_seconds*1e3:8.1f} ms "
              f"({full.build_seconds/max(idx.build_seconds,1e-9):5.1f}x) "
              f"MAE={idx.mdl().mae:8.2f} "
              f"segments={idx.mech.plm.n_segments}")

    # --- §5: gap insertion — precision + dynamics ----------------------
    print("\n== gap insertion (rho=0.2) ==")
    gapped = LearnedIndex.build(keys, method="pgm", eps=128, gap_rho=0.2,
                                sample_rate=0.1)
    print(f"  MAE {full.mdl().mae:.2f} -> {gapped.mdl().mae:.2f}; "
          f"gap fraction {gapped.gapped.gap_fraction:.2f}")
    new_keys = np.setdiff1d(keys[:-1] + np.diff(keys) * 0.5, keys)[:5000]
    paths = {"slot": 0, "chain": 0}
    for i, k in enumerate(new_keys):
        paths[gapped.insert(float(k), 1_000_000 + i)] += 1
    found = gapped.lookup(new_keys)
    print(f"  inserted {len(new_keys)} keys w/o retraining "
          f"(gap-slot={paths['slot']}, chained={paths['chain']}); "
          f"all found: {bool(np.all(found >= 1_000_000))}")

    # --- device path: fused batched lookup (Pallas, interpret on CPU) --
    arrays = from_learned_index(gapped)
    q = rng.choice(keys, 8192)
    out, slot, hit, fb = batched_lookup(arrays, gapped.mech.plm.err_lo, q,
                                        interpret=True)
    truth = gapped.gapped.lookup_batch(q)
    print(f"\n== device lookup == {len(q)} queries, "
          f"kernel==oracle: {np.array_equal(np.asarray(out), truth)}, "
          f"fallbacks: {int(fb)}")


if __name__ == "__main__":
    main()
