"""Quickstart: the paper's pluggable learned index in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Covers: MDL comparison of four mechanisms (§3), sampling speedup (§4),
gap insertion precision + dynamic inserts (§5) through the unified
epoch-versioned ``Index`` handle, and the device lookup path (typed
``LookupResult``s, delta-updated device buffers after ``ingest``).
"""

import numpy as np

from repro.core import Index


def main():
    rng = np.random.default_rng(0)
    # bursty timestamp-like keys (f32-exact grid for the device path)
    keys = np.unique(np.round(np.cumsum(
        rng.exponential(1.0, 300_000) * (1 + 8 * (rng.random(300_000) < .01)))
        * 16.0))
    print(f"dataset: {len(keys):,} unique keys\n")

    # --- §3: MDL framework compares mechanisms on one axis ------------
    print("== MDL comparison (alpha=1) ==")
    for method, kw in [("btree", dict(page_size=256)),
                       ("rmi", dict(n_leaf=2000)),
                       ("fiting", dict(eps=128)),
                       ("pgm", dict(eps=128))]:
        idx = Index.build(keys, method=method, **kw)
        r = idx.mdl()
        print(f"  {method:7s} L(M)={r.l_model_params:7d} params "
              f"L(D|M)={r.l_data_given_model:6.3f} bits  MAE={r.mae:9.2f} "
              f"build={idx.build_seconds*1e3:8.1f} ms")

    # --- §4: sampling — build fast, stay precise -----------------------
    print("\n== sampling (PGM eps=128) ==")
    full = Index.build(keys, method="pgm", eps=128)
    for s in (1.0, 0.1, 0.01):
        idx = Index.build(keys, method="pgm", eps=128, sample_rate=s,
                          rng=np.random.default_rng(1))
        print(f"  s={s:<5} build={idx.build_seconds*1e3:8.1f} ms "
              f"({full.build_seconds/max(idx.build_seconds,1e-9):5.1f}x) "
              f"MAE={idx.mdl().mae:8.2f} "
              f"segments={idx.mech.plm.n_segments}")

    # --- §5: gap insertion — precision + dynamics ----------------------
    print("\n== gap insertion (rho=0.2) ==")
    gapped = Index.build(keys, method="pgm", eps=128, gap_rho=0.2,
                         sample_rate=0.1)
    print(f"  MAE {full.mdl().mae:.2f} -> {gapped.mdl().mae:.2f}; "
          f"gap fraction {gapped.gapped.gap_fraction:.2f}")
    new_keys = np.setdiff1d(keys[:-1] + np.diff(keys) * 0.5, keys)[:10_000]
    report = gapped.ingest(new_keys[:5000],
                           1_000_000 + np.arange(5000))
    res = gapped.lookup(new_keys[:5000])
    print(f"  ingested {report.n} keys w/o retraining "
          f"(gap-slot={report.slot}, chained={report.chain}); "
          f"all found: {bool(res.found.all())} [epoch {gapped.epoch}]")

    # --- device path: typed lookups on the frozen engine ---------------
    # (backend resolves by batch size; the first big batch freezes the
    # engine, later ingests delta-update its buffers in place)
    q = rng.choice(keys, 8192)
    res = gapped.lookup(q)
    truth = gapped.gapped.lookup_batch(q)
    print(f"\n== device lookup == {len(q)} queries on '{res.backend}', "
          f"engine==host oracle: {np.array_equal(res.payloads, truth)}, "
          f"fallbacks: {res.fallbacks}")
    report = gapped.ingest(new_keys[5000:], 2_000_000
                           + np.arange(len(new_keys) - 5000))
    res = gapped.lookup(new_keys[5000:])
    print(f"== ingest-to-queryable == device sync '{report.device}' "
          f"({report.device_elems} elements scattered, "
          f"{report.seconds*1e3:.1f} ms incl. host insert); "
          f"all found: {bool(res.found.all())} — "
          f"{gapped.stats['delta_updates']} deltas / "
          f"{gapped.stats['refreezes']} refreezes total")


if __name__ == "__main__":
    main()
