"""Serve a small model with batched requests over the gapped paged-KV
block table (the paper's dynamic-insert path as a serving feature).

    PYTHONPATH=src python examples/serve_paged_kv.py
"""

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    cfg = reduced(ARCHS["yi-9b"])
    model = build_model(cfg)
    engine = ServingEngine(model, max_batch=4, max_len=128)
    engine.load(model.init_params(jax.random.PRNGKey(0)))

    rng = np.random.default_rng(0)
    for rid in range(1, 13):
        engine.submit(Request(
            request_id=rid,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(4, 32)),
                                dtype=np.int32),
            max_new_tokens=12))
    stats = engine.run_until_done()
    print(f"[serve] {stats['decoded_tokens']} tokens, "
          f"{stats['rounds']} rounds, {stats['wall_s']:.2f}s wall")
    print(f"[serve] block-table lookups: {stats['page_lookups']}; "
          f"index stats: {engine.kv_pages.insert_path_stats()}")


if __name__ == "__main__":
    main()
