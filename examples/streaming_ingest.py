"""Streaming ingestion: append documents to a live indexed dataset with
NO index rebuild (paper §5.3 dynamic inserts land in reserved gaps).

    PYTHONPATH=src python examples/streaming_ingest.py
"""

import time

import numpy as np

from repro.data import IndexedTokenDataset, PackedTokenStore


def main():
    store = PackedTokenStore.synthetic(20_000, mean_len=64, vocab=32_000)
    t0 = time.perf_counter()
    ds = IndexedTokenDataset.build(store, method="pgm", eps=64,
                                   sample_rate=0.1, gap_rho=0.25)
    print(f"[ingest] initial index over {store.n_docs:,} docs in "
          f"{time.perf_counter()-t0:.2f}s "
          f"(gap fraction {ds.index.gapped.gap_fraction:.2f})")

    rng = np.random.default_rng(1)
    existing = set(store.sample_keys.tolist())
    t0 = time.perf_counter()
    n_new, slots, chains = 2000, 0, 0
    added = []
    while len(added) < n_new:
        k = int(rng.integers(1, 2 ** 48))
        if k in existing:
            continue
        existing.add(k)
        doc = rng.integers(0, 32_000, 32, dtype=np.uint32)
        path = ds.ingest(doc, k)
        slots += path == "slot"
        chains += path == "chain"
        added.append(k)
    dt = time.perf_counter() - t0
    print(f"[ingest] streamed {n_new} docs in {dt:.2f}s "
          f"({1e6*dt/n_new:.0f} us/doc) — gap-slot={slots} chained={chains}, "
          f"zero retrains")
    ords = ds.ordinals(np.array(added[:500], np.float64))
    print(f"[ingest] spot-check lookups: all resolved = {bool((ords >= 0).all())}")


if __name__ == "__main__":
    main()
