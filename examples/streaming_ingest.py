"""Streaming ingestion: append documents to a live indexed dataset with
NO index rebuild (paper §5.3 dynamic inserts land in reserved gaps) —
per-document vs the batched ``ingest_batch`` path, plus the epoch story:
the frozen device engine is delta-updated in place per shipment instead
of refrozen.

    PYTHONPATH=src python examples/streaming_ingest.py
"""

import time

import numpy as np

from repro.data import IndexedTokenDataset, PackedTokenStore


def main():
    store = PackedTokenStore.synthetic(20_000, mean_len=64, vocab=32_000)
    t0 = time.perf_counter()
    ds = IndexedTokenDataset.build(store, method="pgm", eps=64,
                                   sample_rate=0.1, gap_rho=0.25)
    print(f"[ingest] initial index over {store.n_docs:,} docs in "
          f"{time.perf_counter()-t0:.2f}s "
          f"(gap fraction {ds.index.gapped.gap_fraction:.2f})")

    rng = np.random.default_rng(1)
    existing = set(store.sample_keys.tolist())

    def fresh_keys(n):
        out = []
        while len(out) < n:
            k = int(rng.integers(1, 2 ** 48))
            if k not in existing:
                existing.add(k)
                out.append(k)
        return out

    # --- per-document path (one predict + scan per insert) -------------
    n_new = 2000
    slots = chains = 0
    added = fresh_keys(n_new)
    seq_docs = [rng.integers(0, 32_000, 32, dtype=np.uint32)
                for _ in added]
    t0 = time.perf_counter()
    for k, doc in zip(added, seq_docs):
        path = ds.ingest(doc, k)
        slots += path == "slot"
        chains += path == "chain"
    dt_seq = time.perf_counter() - t0
    print(f"[ingest] streamed {n_new} docs one-by-one in {dt_seq:.2f}s "
          f"({1e6*dt_seq/n_new:.0f} us/doc) — gap-slot={slots} "
          f"chained={chains}, zero retrains")

    # --- batched path (vectorized predict + conflict partition) --------
    batch_keys = fresh_keys(n_new)
    docs = [rng.integers(0, 32_000, 32, dtype=np.uint32)
            for _ in batch_keys]
    t0 = time.perf_counter()
    report = ds.ingest_batch(docs, batch_keys)
    dt_bat = time.perf_counter() - t0
    print(f"[ingest] streamed {n_new} docs in ONE batch in {dt_bat:.2f}s "
          f"({1e6*dt_bat/n_new:.0f} us/doc, "
          f"{dt_seq/max(dt_bat, 1e-9):.1f}x) — "
          f"gap-slot={report.slot} chained={report.chain} "
          f"[epoch {report.epoch}]")

    # --- epoch story: device engine stays hot across shipments ---------
    # first big lookup freezes the device state; each later shipment is
    # delta-scattered into the resident buffers (48-bit content-hash
    # keys ride the f32 hi/lo pair representation on device)
    probe = np.array(added[:512] + batch_keys[:512], np.float64)
    res = ds.index.lookup(probe, backend="fused")
    print(f"[ingest] spot-check on '{res.backend}': all resolved = "
          f"{bool(res.found.all())}")
    ship_keys = fresh_keys(n_new)
    docs = [rng.integers(0, 32_000, 32, dtype=np.uint32)
            for _ in ship_keys]
    report = ds.ingest_batch(docs, ship_keys)
    res = ds.index.lookup(np.asarray(ship_keys, np.float64),
                          backend="fused")
    print(f"[ingest] next shipment: device sync '{report.device}' "
          f"({report.device_elems} elements, {report.seconds*1e3:.0f} ms "
          f"incl. host insert); all resolved = {bool(res.found.all())}; "
          f"{ds.index.stats['delta_updates']} deltas / "
          f"{ds.index.stats['refreezes']} refreezes")


if __name__ == "__main__":
    main()
