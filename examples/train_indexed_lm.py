"""End-to-end driver: train a (reduced) LM for a few hundred steps on a
learned-index-backed data pipeline with checkpoint/restart.

    PYTHONPATH=src python examples/train_indexed_lm.py

This is the e2e deliverable: real data path (packed store -> sampled
gapped PGM index -> sharded loader), real optimizer/schedule, crash at
step 120 + automatic resume, final loss reported.  Scale up with
--arch/--steps (the full configs need the TPU meshes in launch/mesh.py).
"""

import shutil
import sys

sys.argv = [sys.argv[0]]  # ignore notebook-style args

from repro.launch.train import main as train_main


def run():
    ckpt = "/tmp/repro_example_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)
    # phase 1: crash mid-run (injected) --------------------------------
    sys.argv = [
        "train", "--arch", "internlm2-1.8b", "--reduced",
        "--steps", "240", "--global-batch", "8", "--seq-len", "128",
        "--n-docs", "4096", "--ckpt-dir", ckpt, "--ckpt-every", "40",
        "--schedule", "wsd", "--index-sample-rate", "0.05",
        "--index-gap-rho", "0.2", "--inject-crash-at", "120",
    ]
    try:
        train_main()
        raise AssertionError("expected injected crash")
    except RuntimeError as e:
        print(f"[example] crashed as scheduled: {e}")
    # phase 2: restart resumes from the last checkpoint ----------------
    argv = sys.argv
    cut = argv.index("--inject-crash-at")
    sys.argv = argv[:cut] + argv[cut + 2:] + ["--inject-crash-at", "-1"]
    out = train_main()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] + 0.5, "training diverged"
    print(f"[example] resumed + finished: loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}")


if __name__ == "__main__":
    run()
