#!/usr/bin/env bash
# Tier-1 verify wrapper (see ROADMAP.md): run the full test suite from
# any cwd with the src tree on PYTHONPATH, then the benchmark smoke
# gate (schema + tiny-shape sanity + the deterministic fault-injection
# serving/recovery checks, no timing) so trajectory schema drift and
# crash-recovery regressions fail tier-1 cheaply.  Extra args pass
# through to pytest, e.g.  scripts/tier1.sh -k handle  or
# scripts/tier1.sh -x.
#
# The XLA flags are scoped to the pytest COMMAND only: 8 host devices
# so tests/test_sharded_index.py exercises the real shard_map
# all-to-all fan-out (every test must also pass at 1 device), while
# the smoke step keeps the real single CPU device that the committed
# benchmark baselines were measured on.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
scripts/lint.sh   # repro-lint static analysis: cheap, fails fast
XLA_FLAGS="--xla_force_host_platform_device_count=8 --xla_cpu_multi_thread_eigen=false" \
  python -m pytest -q "$@"
python -m benchmarks.run --smoke
