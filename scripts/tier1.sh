#!/usr/bin/env bash
# Tier-1 verify wrapper (see ROADMAP.md): run the full test suite from
# any cwd with the src tree on PYTHONPATH, then the benchmark smoke
# gate (schema + tiny-shape sanity, no timing) so trajectory schema
# drift fails tier-1 cheaply.  Extra args pass through to pytest,
# e.g.  scripts/tier1.sh -k handle  or  scripts/tier1.sh -x.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -q "$@"
python -m benchmarks.run --smoke
