#!/usr/bin/env bash
# Tier-1 verify wrapper (see ROADMAP.md): run the full test suite from
# any cwd with the src tree on PYTHONPATH.  Extra args pass through to
# pytest, e.g.  scripts/tier1.sh -k handle  or  scripts/tier1.sh -x.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -q "$@"
