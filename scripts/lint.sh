#!/usr/bin/env bash
# repro-lint: the repo-aware static-analysis suite (repro.analysis).
# Four passes over src/ and tests/: epoch-bump discipline on index
# mutators, trace-safety inside jit/loop bodies, guarded-by lock
# checking against `#: guarded-by:` annotations, and hi/lo pair
# exactness in the kernels.  Nonzero exit on any unsuppressed finding
# — wired into scripts/tier1.sh, so a violation fails tier-1.  Extra
# args pass through, e.g.  scripts/lint.sh --show-suppressed  or
# scripts/lint.sh --rules guarded-by src/repro/serving.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m repro.analysis "$@"
