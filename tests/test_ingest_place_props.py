"""Property tests (hypothesis, importorskip-guarded like the other
suites) for the per-key contested demotion and the device ingest-place
backend — the deterministic companions live in test_ingest_place.py."""

import copy

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Index, LearnedIndex


def _state_equal(g1, g2):
    return (np.array_equal(g1.slot_key, g2.slot_key)
            and np.array_equal(g1.occupied, g2.occupied)
            and np.array_equal(g1.payload, g2.payload)
            and g1.n_keys == g2.n_keys
            and dict(g1.links) == dict(g2.links))


def _mids(keys, rng, n):
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    return rng.permutation(mids)[:n]

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n0=st.integers(60, 1200),
       n_ins=st.integers(10, 700),
       dense=st.integers(2, 5),
       eps=st.sampled_from([4, 16, 64]),
       rho=st.sampled_from([0.02, 0.1, 0.4]))
def test_property_per_key_demotion_state_identical(seed, n0, n_ins, dense,
                                                   eps, rho):
    """Dense integer grids force shared runs, slot collisions, crowded
    collision groups, and global-min displacements — the shapes the
    per-key demotion rules (D1-D4 + chain-certain) must arbitrate."""
    rng = np.random.default_rng(seed)
    span = n0 * dense
    allk = rng.choice(span, size=min(span, n0 + n_ins),
                      replace=False).astype(np.float64)
    init = np.sort(allk[:n0])
    ins = allk[n0:]
    if ins.size == 0:
        return
    idx = LearnedIndex.build(init, method="pgm", eps=eps, gap_rho=rho)
    seq = copy.deepcopy(idx)
    pay = 10_000 + np.arange(ins.size)
    for i, k in enumerate(ins):
        seq.insert(float(k), int(pay[i]))
    counts = idx.insert_batch(ins, pay)
    assert counts["slot"] + counts["chain"] == ins.size
    assert 0 <= counts["contested"] <= ins.size
    assert _state_equal(seq.gapped, idx.gapped)



@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(600, 4000),
       wide=st.booleans(), rho=st.sampled_from([0.05, 0.2]))
def test_property_device_placements_match_host(seed, n, wide, rho):
    rng = np.random.default_rng(seed)
    span = 2 ** 40 if wide else 2 ** 22
    keys = np.unique(rng.choice(span, n, replace=False)).astype(np.float64)
    if keys.size < 16:
        return
    idx = Index.build(keys, method="pgm", eps=16, gap_rho=rho)
    idx.min_device_batch = 1
    idx.sync_device()
    batch = _mids(keys, rng, min(n, 1500))
    if batch.size == 0:
        return
    prims = idx._device_placements(batch)
    assert prims is not None
    host = idx.gapped.placement_primitives(batch)
    for f in prims:
        assert np.array_equal(prims[f], host[f]), f


