"""Serving: paged-KV learned-index block table + continuous batching."""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import PagedKVCache, Request, ServingEngine


def test_paged_kv_alloc_lookup_free():
    kv = PagedKVCache.create(n_pages=256, page_size=16,
                             expected_requests=16)
    phys = {}
    for rid in (3, 7, 11):
        for p in range(4):
            phys[(rid, p)] = kv.alloc(rid, p)
    rids = np.array([3, 7, 11, 3])
    pages = np.array([0, 2, 3, 1])
    got = kv.lookup_batch(rids, pages)
    want = [phys[(3, 0)], phys[(7, 2)], phys[(11, 3)], phys[(3, 1)]]
    assert list(got) == want
    kv.free_request(7, 4)
    got2 = kv.lookup_batch(np.array([7]), np.array([1]))
    assert got2[0] in (-1,)  # freed (or reverted to skeleton payload -1)
    # pages were returned to the free list
    assert kv.utilization < 12 / 256 + 1e-9


def test_paged_kv_composite_keys_above_2_24_on_device():
    """Regression (ROADMAP "f64 device keys"): composite keys beyond f32
    exactness (request_id >= 16 puts table_key past 2^24) must resolve on
    the DEVICE path bit-identically to the host oracle — they ride the
    f32 hi/lo pair representation instead of falling back to the host."""
    from repro.serving.kv_cache import table_key

    kv = PagedKVCache.create(n_pages=4096, page_size=16,
                             expected_requests=64)
    rng = np.random.default_rng(0)
    # request ids up to 2^21: table keys up to ~2^41 >> 2^24
    rids = np.unique(rng.integers(16, 2 ** 21, 300)).astype(np.int64)
    phys = {}
    pages = np.arange(4)
    for rid in rids.tolist():
        got = kv.alloc_batch(np.full(4, rid), pages)
        for p, ph in zip(pages, got):
            phys[(rid, int(p))] = int(ph)
    q_rids = np.repeat(rids, 4)
    q_pages = np.tile(pages, len(rids))
    assert float(table_key(int(q_rids.max()), 3)) > 2 ** 24
    want = np.array([phys[(r, p)] for r, p in zip(q_rids, q_pages)])
    # force the device engine (explicit) and compare with the host path
    got_dev = kv.lookup_batch(q_rids, q_pages, device=True)
    got_host = kv.lookup_batch(q_rids, q_pages, device=False)
    assert np.array_equal(got_host, want)
    assert np.array_equal(got_dev, want)
    assert kv.index._keys_wide()  # the pair representation was exercised
    # unmapped (request, page) pairs miss on both paths
    miss_dev = kv.lookup_batch(np.array([2 ** 21 + 7]), np.array([9]),
                               device=True)
    assert miss_dev[0] == -1


def test_paged_kv_exhaustion():
    kv = PagedKVCache.create(n_pages=4, page_size=16, expected_requests=2)
    for p in range(4):
        kv.alloc(1, p)
    with pytest.raises(MemoryError):
        kv.alloc(1, 4)


def test_engine_end_to_end():
    cfg = reduced(ARCHS["internlm2-1.8b"])
    model = build_model(cfg)
    engine = ServingEngine(model, max_batch=3, max_len=64)
    engine.load(model.init_params(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    for rid in range(1, 8):
        engine.submit(Request(request_id=rid,
                              prompt=rng.integers(0, cfg.vocab, 6,
                                                  dtype=np.int32),
                              max_new_tokens=5))
    stats = engine.run_until_done(max_rounds=100)
    assert stats["decoded_tokens"] == 7 * 5
    assert not engine.active and not engine.queue
    assert stats["page_lookups"] > 0


def test_engine_tokens_in_vocab():
    cfg = reduced(ARCHS["yi-9b"])
    model = build_model(cfg)
    engine = ServingEngine(model, max_batch=2, max_len=32)
    engine.load(model.init_params(jax.random.PRNGKey(1)))
    engine.submit(Request(request_id=1,
                          prompt=np.array([5, 6, 7], np.int32),
                          max_new_tokens=4))
    engine.run_until_done(max_rounds=50)
    done_tokens = []  # request was removed from active; re-run to capture
    engine2 = ServingEngine(model, max_batch=2, max_len=32)
    engine2.load(model.init_params(jax.random.PRNGKey(1)))
    req = Request(request_id=1, prompt=np.array([5, 6, 7], np.int32),
                  max_new_tokens=4)
    engine2.submit(req)
    engine2.run_until_done(max_rounds=50)
    assert len(req.generated) == 4
    assert all(0 <= t < cfg.vocab for t in req.generated)
