"""Serving: paged-KV learned-index block table + continuous batching."""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import PagedKVCache, Request, ServingEngine


def test_paged_kv_alloc_lookup_free():
    kv = PagedKVCache.create(n_pages=256, page_size=16,
                             expected_requests=16)
    phys = {}
    for rid in (3, 7, 11):
        for p in range(4):
            phys[(rid, p)] = kv.alloc(rid, p)
    rids = np.array([3, 7, 11, 3])
    pages = np.array([0, 2, 3, 1])
    got = kv.lookup_batch(rids, pages)
    want = [phys[(3, 0)], phys[(7, 2)], phys[(11, 3)], phys[(3, 1)]]
    assert list(got) == want
    kv.free_request(7, 4)
    got2 = kv.lookup_batch(np.array([7]), np.array([1]))
    assert got2[0] in (-1,)  # freed (or reverted to skeleton payload -1)
    # pages were returned to the free list
    assert kv.utilization < 12 / 256 + 1e-9


def test_paged_kv_composite_keys_above_2_24_on_device():
    """Regression (ROADMAP "f64 device keys"): composite keys beyond f32
    exactness (request_id >= 16 puts table_key past 2^24) must resolve on
    the DEVICE path bit-identically to the host oracle — they ride the
    f32 hi/lo pair representation instead of falling back to the host."""
    from repro.serving.kv_cache import table_key

    kv = PagedKVCache.create(n_pages=4096, page_size=16,
                             expected_requests=64)
    rng = np.random.default_rng(0)
    # request ids up to 2^21: table keys up to ~2^41 >> 2^24
    rids = np.unique(rng.integers(16, 2 ** 21, 300)).astype(np.int64)
    phys = {}
    pages = np.arange(4)
    for rid in rids.tolist():
        got = kv.alloc_batch(np.full(4, rid), pages)
        for p, ph in zip(pages, got):
            phys[(rid, int(p))] = int(ph)
    q_rids = np.repeat(rids, 4)
    q_pages = np.tile(pages, len(rids))
    assert float(table_key(int(q_rids.max()), 3)) > 2 ** 24
    want = np.array([phys[(r, p)] for r, p in zip(q_rids, q_pages)])
    # force the device engine (explicit) and compare with the host path
    got_dev = kv.lookup_batch(q_rids, q_pages, device=True)
    got_host = kv.lookup_batch(q_rids, q_pages, device=False)
    assert np.array_equal(got_host, want)
    assert np.array_equal(got_dev, want)
    assert kv.index._keys_wide()  # the pair representation was exercised
    # unmapped (request, page) pairs miss on both paths
    miss_dev = kv.lookup_batch(np.array([2 ** 21 + 7]), np.array([9]),
                               device=True)
    assert miss_dev[0] == -1


def test_paged_kv_exhaustion():
    kv = PagedKVCache.create(n_pages=4, page_size=16, expected_requests=2)
    for p in range(4):
        kv.alloc(1, p)
    with pytest.raises(MemoryError):
        kv.alloc(1, 4)


def test_engine_end_to_end():
    cfg = reduced(ARCHS["internlm2-1.8b"])
    model = build_model(cfg)
    engine = ServingEngine(model, max_batch=3, max_len=64)
    engine.load(model.init_params(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    for rid in range(1, 8):
        engine.submit(Request(request_id=rid,
                              prompt=rng.integers(0, cfg.vocab, 6,
                                                  dtype=np.int32),
                              max_new_tokens=5))
    stats = engine.run_until_done(max_rounds=100)
    assert stats["decoded_tokens"] == 7 * 5
    assert not engine.active and not engine.queue
    assert stats["page_lookups"] > 0


def test_engine_tokens_in_vocab():
    cfg = reduced(ARCHS["yi-9b"])
    model = build_model(cfg)
    engine = ServingEngine(model, max_batch=2, max_len=32)
    engine.load(model.init_params(jax.random.PRNGKey(1)))
    engine.submit(Request(request_id=1,
                          prompt=np.array([5, 6, 7], np.int32),
                          max_new_tokens=4))
    engine.run_until_done(max_rounds=50)
    done_tokens = []  # request was removed from active; re-run to capture
    engine2 = ServingEngine(model, max_batch=2, max_len=32)
    engine2.load(model.init_params(jax.random.PRNGKey(1)))
    req = Request(request_id=1, prompt=np.array([5, 6, 7], np.int32),
                  max_new_tokens=4)
    engine2.submit(req)
    engine2.run_until_done(max_rounds=50)
    assert len(req.generated) == 4
    assert all(0 <= t < cfg.vocab for t in req.generated)


def test_micro_batch_queue_error_paths():
    """Hardening regressions (ISSUE 7 satellite): an empty flush used to
    read the previous flush's stale staging buffer (``buf[off:] =
    buf[off-1]`` at off==0) and bump stats; a double result() used to
    trigger a spurious flush of OTHER callers' pending work."""
    from repro.serving.engine import MicroBatchQueue
    from repro.core import Index

    keys = np.arange(0, 4_000, 2, dtype=np.float64)
    idx = Index.build(keys, method="pgm", eps=32, gap_rho=0.2)
    q = MicroBatchQueue(idx, min_bucket=64)

    with pytest.raises(RuntimeError, match="nothing pending"):
        q.flush()
    assert q.stats["flushes"] == 0          # no spurious stats bump
    with pytest.raises(ValueError, match="empty"):
        q.submit_lookup(np.empty(0))
    with pytest.raises(ValueError, match="empty"):
        q.submit_ingest(np.empty(0), np.empty(0))
    with pytest.raises(ValueError, match="1:1"):
        q.submit_ingest(keys[:4], np.arange(3))

    t1 = q.submit_lookup(keys[:8])
    t2 = q.submit_lookup(keys[8:12] + 1.0)
    r1 = q.result(t1)                       # implicit flush of both
    assert np.array_equal(np.asarray(r1.payloads), np.arange(8))
    with pytest.raises(KeyError, match="exactly once"):
        q.result(t1)                        # duplicate read refused...
    r2 = q.result(t2)                       # ...without disturbing t2
    assert not np.any(np.asarray(r2.found))
    with pytest.raises(KeyError, match="never issued"):
        q.result(10_000)


def test_micro_batch_queue_over_sharded_index():
    """The queue is backend-agnostic (duck-typed lookup/ingest): one
    coalesced flush over a ShardedIndex demuxes per-ticket results
    identical to per-caller lookups on a single-device Index."""
    from repro.serving.engine import MicroBatchQueue
    from repro.core import Index

    rng = np.random.default_rng(6)
    keys = np.unique(rng.choice(2 ** 22, 24_000, replace=False)
                     ).astype(np.float64)
    single = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    sharded = Index.build(keys, shards=4, method="pgm", eps=64,
                          gap_rho=0.2)
    q = MicroBatchQueue(sharded, min_bucket=512)
    batches = [rng.choice(keys, 300), rng.choice(keys, 200) + 1.0,
               rng.choice(keys, 400)]
    tickets = [q.submit_lookup(b) for b in batches]
    ti = q.submit_ingest(np.array([keys[-1] + 10.0, keys[-1] + 12.0]),
                         np.array([7, 8]))
    q.flush()                               # ingest first, then ONE
    assert q.stats["lookup_dispatches"] == 1  # coalesced fan-out lookup
    for t, b in zip(tickets, batches):
        got = q.result(t)
        want = single.lookup(b)
        assert np.array_equal(np.asarray(got.payloads),
                              np.asarray(want.payloads))
        assert np.array_equal(np.asarray(got.found),
                              np.asarray(want.found))
    rep = q.result(ti)
    assert rep.device == "sharded" and rep.n == 2
    assert sharded.lookup(np.array([keys[-1] + 12.0])).payloads[0] == 8
