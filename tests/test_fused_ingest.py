"""Fused single-dispatch device-resident ingest (the tentpole contract).

* an accepted batch costs exactly ONE device dispatch — counted by
  monkeypatching the jitted graph entry (``ops_gap._fused_ingest_xla``)
  — with ZERO host-oracle placement calls and no delta/refreeze
  dispatches; the committed state is bit-identical to sequential
  ``insert()`` AND to the host ``insert_batch`` partition, chain-append
  (CSR-merge) arm included, and the adopted device buffers answer the
  new keys with no re-sync;
* crowded / headroom-overflow batches ABORT in-graph and fall back to
  the two-dispatch place+delta path REUSING the dispatch's placement
  primitives (no second placement dispatch, no wasted work) — state
  still bit-identical to sequential;
* ``MicroBatchQueue`` demultiplexes one aggregated flush back into
  per-ticket typed slices in submission order (ingests flushed first).

Hypothesis property versions are importorskip-guarded like the other
property suites.
"""

import copy

import numpy as np
import pytest

from repro.core import Index
from repro.kernels import ops_gap


def _state_equal(g1, g2):
    return (np.array_equal(g1.slot_key, g2.slot_key)
            and np.array_equal(g1.occupied, g2.occupied)
            and np.array_equal(g1.payload, g2.payload)
            and g1.n_keys == g2.n_keys
            and dict(g1.links) == dict(g2.links))


def _mids(keys):
    return np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)


def _spread(keys, n):
    mids = _mids(keys)
    return mids[:: max(1, len(mids) // n)][:n]


def _build(width=2 ** 22, n=25_000, seed=0, method="pgm"):
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.choice(width, n, replace=False)
                     ).astype(np.float64)
    idx = Index.build(keys, method=method, eps=64, gap_rho=0.2)
    idx.fused_ingest_enabled = True   # force the arm under test (the
    idx.sync_device()                 # CPU auto default is two-dispatch)
    return idx, keys, rng


def _count_dispatches(monkeypatch, gapped_cls):
    """Spy on the one-dispatch symbol and the host placement oracle."""
    calls = {"fused": 0, "oracle": 0}
    real_fused = ops_gap._fused_ingest_xla

    def counting_fused(*a, **kw):
        calls["fused"] += 1
        return real_fused(*a, **kw)

    real_pp = gapped_cls.placement_primitives

    def counting_pp(self, *a, **kw):
        calls["oracle"] += 1
        return real_pp(self, *a, **kw)

    monkeypatch.setattr(ops_gap, "_fused_ingest_xla", counting_fused)
    monkeypatch.setattr(gapped_cls, "placement_primitives", counting_pp)
    return calls


# ---------------------------------------------------------------------------
# accepted batch: one dispatch, state bit-identical, buffers adopted
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("width", [2 ** 22, 2 ** 40])
def test_fused_single_dispatch_state_identical(width, monkeypatch):
    idx, keys, _ = _build(width=width)
    batch = _spread(keys, 3_000)           # well-spread: closure-trivial
    pays = 1_000_000 + np.arange(batch.size)
    seq = copy.deepcopy(idx)
    hostp = copy.deepcopy(idx)

    calls = _count_dispatches(monkeypatch, type(idx.gapped))
    deltas0 = idx.stats["delta_updates"]
    refreezes0 = idx.stats["refreezes"]
    rep = idx.ingest(batch, pays)

    assert rep.device == "fused" and rep.placement == "device"
    assert rep.contested == 0 and rep.slot + rep.chain == rep.n
    assert rep.chain > 0                   # the CSR-merge arm really ran
    assert calls == {"fused": 1, "oracle": 0}
    assert idx.stats["delta_updates"] == deltas0   # nothing re-synced
    assert idx.stats["refreezes"] == refreezes0

    for i, k in enumerate(batch):
        seq.insert(float(k), int(pays[i]))
    hostp.gapped.insert_batch(batch, pays)
    assert _state_equal(idx.gapped, seq.gapped)
    assert _state_equal(idx.gapped, hostp.gapped)

    # the ADOPTED device buffers (no delta, no refreeze) answer slot and
    # chain keys exactly — batch is ascending, so pays align
    res = idx.lookup(batch, backend="fused", queries_sorted=True)
    assert np.array_equal(np.asarray(res.payloads), pays)
    assert bool(np.all(np.asarray(res.found)))
    assert idx.stats["delta_updates"] == deltas0
    assert idx.stats["refreezes"] == refreezes0


def test_fused_then_scalar_then_delta_roundtrip():
    """A fused commit leaves the mirror source-advanced/image-dirty; the
    next host-side mutation must still delta-sync correctly (the lazy
    image rebuild) and keep lookups exact."""
    idx, keys, rng = _build(n=20_000, seed=3)
    batch = _spread(keys, 1_000)
    rep = idx.ingest(batch, 2_000_000 + np.arange(batch.size))
    assert rep.device == "fused"
    deltas0 = idx.stats["delta_updates"]
    # scalar inserts -> stale device -> delta on the next device lookup
    extra = _mids(np.sort(np.concatenate([keys, batch])))[:40]
    for i, k in enumerate(extra):
        idx.insert(float(k), 9_000_000 + i)
    probe = np.sort(np.concatenate(
        [rng.choice(keys, 1_500), batch[:500], extra]))
    res = idx.lookup(probe, backend="fused", queries_sorted=True)
    assert idx.stats["delta_updates"] == deltas0 + 1
    assert np.array_equal(np.asarray(res.payloads),
                          idx.gapped.lookup_batch(probe))


# ---------------------------------------------------------------------------
# aborted batch: in-graph refusal, primitives reused, state identical
# ---------------------------------------------------------------------------


def test_fused_abort_falls_back_reusing_primitives(monkeypatch):
    """Contiguous runs crammed with new keys hit the in-graph closure
    check (collision groups / chain overflow) — the graph refuses,
    the handle replays the SAME primitives on the host-partition path,
    and the end state matches sequential insert()."""
    init = np.arange(0, 1_000_000, 100, dtype=np.float64)
    idx = Index.build(init, method="pgm", eps=32, gap_rho=0.2)
    idx.fused_ingest_enabled = True
    idx.sync_device()
    batch = np.setdiff1d(np.arange(50_001, 50_001 + 620,
                                   dtype=np.float64), init)[:512]  # crowded
    pays = 3_000_000 + np.arange(batch.size)
    seq = copy.deepcopy(idx)

    calls = _count_dispatches(monkeypatch, type(idx.gapped))
    rep = idx.ingest(batch, pays)
    assert calls["fused"] == 1             # the dispatch was not wasted:
    assert calls["oracle"] == 0            # ...its primitives were reused
    assert rep.device != "fused"
    assert idx.stats["fused_aborts"]       # the per-bit reasons recorded
    assert rep.slot + rep.chain == rep.n

    monkeypatch.undo()
    for i, k in enumerate(batch):
        seq.insert(float(k), int(pays[i]))
    assert _state_equal(idx.gapped, seq.gapped)


def test_fused_abort_on_link_headroom_overflow(monkeypatch):
    """A batch whose chain arm outgrows the frozen link capacity must
    abort in-graph (link_overflow), not scribble past the buffer."""
    keys = np.arange(0, 24_000, 2, dtype=np.float64)
    # linear keys + near-zero gap budget: no chains at freeze time, so
    # the link capacity freezes at its floor — and the odd midpoints are
    # chain-bound (no bracketed gap slot), one per run (no collisions,
    # no per-run overflow): the ONLY obstacle is total link capacity
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.01)
    idx.fused_ingest_enabled = True
    idx.sync_device()
    cap = int(idx._engine.arrays.link_keys.shape[0])
    assert cap <= 128
    batch = _spread(keys, 1_024)           # chain demand far beyond cap
    pays = 4_000_000 + np.arange(batch.size)
    seq = copy.deepcopy(idx)

    calls = _count_dispatches(monkeypatch, type(idx.gapped))
    rep = idx.ingest(batch, pays)
    assert rep.device != "fused"
    assert calls["fused"] == 1 and calls["oracle"] == 0
    assert any(b in idx.stats["fused_aborts"]
               for b in ("link_overflow", "chain_overflow"))
    monkeypatch.undo()
    for i, k in enumerate(batch):
        seq.insert(float(k), int(pays[i]))
    assert _state_equal(idx.gapped, seq.gapped)


# ---------------------------------------------------------------------------
# aggregation queue: typed demux in submission order
# ---------------------------------------------------------------------------


def test_microbatch_queue_demux_order():
    from repro.serving.engine import MicroBatchQueue

    idx, keys, rng = _build(n=20_000, seed=7)
    q = MicroBatchQueue(idx, min_bucket=64)
    parts = [rng.choice(keys, sz) for sz in (5, 17, 1, 33)]
    parts.append(np.array([keys[0] - 3.0, keys[5]]))  # one miss row
    tickets = [q.submit_lookup(p) for p in parts]
    ing = _spread(keys, 700)
    t_ing = q.submit_ingest(ing, 5_000_000 + np.arange(ing.size))
    q.flush()
    assert q.stats["lookup_dispatches"] == 1   # ONE coalesced dispatch
    assert q.stats["ingest_dispatches"] == 1
    assert q.stats["coalesced_lookups"] == len(parts)
    for t, p in zip(tickets, parts):
        res = q.result(t)
        assert res.payloads.shape[0] == p.shape[0]
        assert np.array_equal(np.asarray(res.payloads),
                              idx.gapped.lookup_batch(p))
    rep = q.result(t_ing)
    assert rep.n == ing.size
    # an unresolved ticket auto-flushes on result()
    t2 = q.submit_lookup(ing[:9])
    res2 = q.result(t2)
    assert np.array_equal(np.asarray(res2.payloads),
                          5_000_000 + np.arange(9))


# the hypothesis property versions (fused-or-abort vs sequential, queue
# demux under arbitrary submission patterns) live in
# tests/test_fused_ingest_props.py, importorskip-guarded so this
# deterministic module always runs
