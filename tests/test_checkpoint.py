"""Checkpointing: atomicity, async, restore, GC, crash-restart."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)),
                   "b": jnp.zeros((8,))},
        "opt": {"m": jnp.ones((8, 8)), "step": jnp.int32(5)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(10, s, extra={"step": 10})
    restored, extra = mgr.restore(template=s)
    assert extra["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    assert mgr.latest_step() == 10


def test_async_save_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save_async(step, _state(step), extra={"step": step})
    mgr.wait()
    mgr.save(5, _state(5), extra={"step": 5})
    dirs = sorted(os.listdir(tmp_path))
    assert len([d for d in dirs if d.startswith("step_")]) == 2
    assert mgr.latest_step() == 5


def test_atomic_no_tmp_shadow(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _state())
    # a stale tmp dir from a crashed writer must not shadow the real one
    os.makedirs(os.path.join(tmp_path, "step_00000009.tmp"))
    assert mgr.latest_step() == 7
    restored, _ = mgr.restore(template=_state())
    assert restored is not None


def test_restore_with_target_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as PS
    mesh = jax.make_mesh((1,), ("data",))
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(1, s)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, PS()), s)
    restored, _ = mgr.restore(template=s, shardings=shardings)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, PS())
