"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, plus decode-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeConfig, reduced
from repro.models import build_model
from repro.models import lm as _lm
from repro.models import ssm as _ssm
from repro.models import xlstm as _xl
from repro.models.base import init_params as _init

SMOKE_TRAIN = ShapeConfig("smoke_train", 32, 2, "train")
KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_train_step(name):
    cfg = reduced(ARCHS[name])
    m = build_model(cfg)
    params = m.init_params(KEY)
    batch = m.input_sample(SMOKE_TRAIN, KEY)
    batch["labels"] = batch["tokens"]
    loss, grads = jax.jit(jax.value_and_grad(lambda p: m.loss_fn(p, batch)))(
        params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_decode_step(name):
    cfg = reduced(ARCHS[name])
    m = build_model(cfg)
    if m.decode_fn is None:
        pytest.skip("no decode path")
    params = m.init_params(KEY)
    caches = m.init_caches(2, 16)
    tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab, dtype=jnp.int32)
    logits, caches = jax.jit(m.decode_fn)(params, {"tokens": tok}, caches,
                                          jnp.int32(0))
    assert logits.shape == (2, cfg.vocab)
    logits, _ = jax.jit(m.decode_fn)(params, {"tokens": tok}, caches,
                                     jnp.int32(1))
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_prefill_decode_matches_full_forward():
    cfg = reduced(ARCHS["yi-9b"])
    m = build_model(cfg)
    params = m.init_params(KEY)
    S = 12
    toks = jax.random.randint(KEY, (2, S), 0, cfg.vocab, dtype=jnp.int32)
    full = _lm.logits_fn(params, _lm.forward(params, toks, cfg), cfg)
    cache = m.init_caches(2, 32)
    plog, cache = m.prefill_fn(params, {"tokens": toks}, cache)
    assert bool(jnp.allclose(plog, full[:, -1], atol=2e-2))
    nxt = jax.random.randint(jax.random.PRNGKey(9), (2, 1), 0, cfg.vocab,
                             dtype=jnp.int32)
    dlog, _ = m.decode_fn(params, {"tokens": nxt}, cache, jnp.int32(S))
    full2 = _lm.logits_fn(
        params, _lm.forward(params, jnp.concatenate([toks, nxt], 1), cfg), cfg)
    assert bool(jnp.allclose(dlog, full2[:, -1], atol=2e-2))


def test_ssd_chunked_matches_sequential():
    spec = _ssm.mamba2_specs(32, 4, 16, 8)
    p = _init(spec, KEY)
    x = jax.random.normal(KEY, (2, 24, 32), jnp.float32).astype(jnp.bfloat16)
    y_par, _ = _ssm.mamba2_forward(p, x, n_heads=4, head_dim=16, d_state=8,
                                   chunk=8)
    cache = _ssm.init_ssm_cache(2, 4, 16, 8, dtype=jnp.float32)
    y_seq, _ = _ssm.mamba2_forward(p, x, n_heads=4, head_dim=16, d_state=8,
                                   cache=cache)
    np.testing.assert_allclose(
        np.asarray(y_par, np.float32), np.asarray(y_seq, np.float32),
        atol=8e-2, rtol=8e-2)


def test_mlstm_chunked_matches_sequential():
    spec = _xl.mlstm_specs(32, 4)
    p = _init(spec, KEY)
    x = jax.random.normal(KEY, (2, 16, 32), jnp.float32).astype(jnp.bfloat16)
    y_par, _ = _xl.mlstm_forward(p, x, n_heads=4, chunk=4)
    cache = _xl.init_mlstm_cache(2, 4, 16)
    y_seq, _ = _xl.mlstm_forward(p, x, n_heads=4, cache=cache)
    np.testing.assert_allclose(
        np.asarray(y_par, np.float32), np.asarray(y_seq, np.float32),
        atol=1e-1, rtol=1e-1)


def test_moe_gspmd_routes_all_tokens():
    """Generous capacity => combine output is a true top-k mixture (no drops):
    per-token output must be a convex combination of expert outputs."""
    from repro.models import moe as _moe
    spec = _moe.moe_specs(16, 32, 4)
    p = _init(spec, KEY)
    x = jax.random.normal(KEY, (2, 8, 16), jnp.float32).astype(jnp.bfloat16)
    out = _moe.moe_gspmd(p, x, top_k=2, capacity_factor=8.0)
    assert out.shape == x.shape
    # brute-force reference: every token through every expert, weight top-2
    x2 = x.reshape(16, 16).astype(jnp.float32)
    logits = x2 @ p["router"]
    w, e = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    w = w / w.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x2)
    for t in range(16):
        for j in range(2):
            ex = int(e[t, j])
            h = jax.nn.silu(x2[t] @ p["w_gate"][ex].astype(jnp.float32))
            h = h * (x2[t] @ p["w_up"][ex].astype(jnp.float32))
            ref = ref.at[t].add(w[t, j] * (h @ p["w_down"][ex].astype(jnp.float32)))
    np.testing.assert_allclose(
        np.asarray(out.reshape(16, 16), np.float32), np.asarray(ref),
        atol=1e-1, rtol=2e-1)


def test_param_counts_full_configs():
    """Full (unreduced) param counts are in the expected ballpark."""
    expected = {
        "yi-9b": (8.0e9, 10.5e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "qwen1.5-32b": (28e9, 36e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "granite-moe-1b-a400m": (1.0e9, 1.6e9),
        "minicpm-2b": (2.2e9, 3.2e9),
        "xlstm-125m": (0.10e9, 0.22e9),
    }
    for name, (lo, hi) in expected.items():
        m = build_model(ARCHS[name])
        n = m.param_count()
        assert lo <= n <= hi, f"{name}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"
