"""Hypothesis properties for the fused single-dispatch ingest path
(optional dep — the whole module skips when hypothesis is absent; the
deterministic companions in test_fused_ingest.py always run).

* fused-or-abort: for random spread/clustered/mixed batches, whichever
  arm the handle takes (one-dispatch fused commit, or in-graph abort +
  host-partition fallback reusing the dispatch's primitives), the final
  host state is bit-identical to sequential ``insert()`` and the device
  answers the committed batch exactly;
* queue demux: any submission pattern through ``MicroBatchQueue``
  resolves each ticket to exactly what that caller would have gotten
  alone.
"""

import copy

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
given = hypothesis.given
settings = hypothesis.settings
st = hypothesis.strategies

from test_fused_ingest import _build, _mids, _state_equal  # noqa: E402

_BASE = {}


def _base():
    if not _BASE:
        _BASE["idx"], _BASE["keys"], _ = _build(n=12_000, seed=11)
    return _BASE["idx"], _BASE["keys"]


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_prop_fused_or_abort_matches_sequential(data):
    base, keys = _base()
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    n_b = data.draw(st.integers(512, 1_500))
    mode = data.draw(st.sampled_from(["spread", "clustered", "mixed"]))
    mids = _mids(keys)
    if mode == "spread":
        batch = mids[:: max(1, len(mids) // n_b)][:n_b]
    elif mode == "clustered":
        lo = int(rng.integers(0, max(1, len(mids) - n_b)))
        batch = mids[lo: lo + n_b]
    else:
        half = n_b // 2
        lo = int(rng.integers(0, max(1, len(mids) - half)))
        batch = np.unique(np.concatenate(
            [mids[:: max(1, len(mids) // half)][:half],
             mids[lo: lo + half]]))
    pays = 6_000_000 + np.arange(batch.size)
    idx = copy.deepcopy(base)
    idx.sync_device()
    seq = copy.deepcopy(base)
    rep = idx.ingest(batch, pays)
    if rep.device == "fused":
        assert rep.contested == 0
    for i, k in enumerate(batch):
        seq.insert(float(k), int(pays[i]))
    assert _state_equal(idx.gapped, seq.gapped)
    # device answers the committed batch exactly on either arm
    res = idx.lookup(batch, backend="fused", queries_sorted=True)
    assert np.array_equal(np.asarray(res.payloads), pays)


@settings(max_examples=10, deadline=None)
@given(sizes=st.lists(st.integers(1, 40), min_size=1, max_size=12),
       seed=st.integers(0, 2 ** 16))
def test_prop_queue_demux_matches_per_caller(sizes, seed):
    from repro.serving.engine import MicroBatchQueue

    base, keys = _base()
    rng = np.random.default_rng(seed)
    q = MicroBatchQueue(base, min_bucket=32)
    parts = [rng.choice(keys, sz) for sz in sizes]
    tickets = [q.submit_lookup(p) for p in parts]
    q.flush()
    assert q.stats["lookup_dispatches"] == 1
    for t, p in zip(tickets, parts):
        res = q.result(t)
        assert np.array_equal(np.asarray(res.payloads),
                              base.gapped.lookup_batch(p))
