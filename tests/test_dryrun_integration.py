"""End-to-end dry-run integration: one real (arch × shape × mesh) cell
lowered + compiled in a subprocess (512 placeholder devices), record
validated.  Proves deliverable (e) machinery inside the test suite."""

import json
import os
import subprocess
import sys

import pytest


@pytest.mark.parametrize("arch,shape", [("whisper-base", "decode_32k")])
def test_dryrun_cell_compiles(tmp_path, arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=1200,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    cell = json.load(open(tmp_path / f"{arch}__{shape}__pod16x16.json"))
    assert cell["status"] == "ok"
    assert cell["n_devices"] == 256
    assert cell["flops_per_device"] > 0
    assert cell["bytes_per_device"] > 0
    assert cell["collective_ops"] >= 0
    assert "collectives" in cell


def test_na_cell_recorded(tmp_path):
    """long_500k for a full-attention arch is N/A-by-design, not an error."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "yi-9b",
         "--shape", "long_500k", "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=300,
        cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0
    cell = json.load(open(tmp_path / "yi-9b__long_500k__pod16x16.json"))
    assert cell["status"] == "n/a"
    assert "sub-quadratic" in cell["reason"]
