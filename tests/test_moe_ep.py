"""MoE expert-parallel (shard_map all_to_all) vs GSPMD dispatch:
numerical equivalence on a multi-device mesh.

Needs >1 device, so it runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (conftest must keep
the main process at 1 device)."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS
from repro.models import moe as _moe
from repro.models.base import init_params

mesh = jax.make_mesh((2, 4), ("data", "model"))
E, D, F, K = 8, 16, 32, 2
spec = _moe.moe_specs(D, F, E)
params = init_params(spec, jax.random.PRNGKey(0))
params = jax.tree.map(lambda t: t.astype(jnp.float32), params)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, D), jnp.float32)

ref = _moe.moe_gspmd(params, x, top_k=K, capacity_factor=8.0)

xs = jax.device_put(x, NamedSharding(mesh, PS("data", "model", None)))
ps = jax.tree.map(lambda t: jax.device_put(
    t, NamedSharding(mesh, PS("model", None, None)) if t.ndim == 3
    else NamedSharding(mesh, PS())), params)
out = jax.jit(lambda p, t: _moe.moe_ep_shardmap(
    p, t, top_k=K, mesh=mesh, capacity_factor=8.0))(ps, xs)

err = float(jnp.max(jnp.abs(out - ref)))
assert err < 2e-4, f"EP vs GSPMD mismatch: {err}"
print("EP==GSPMD ok, max err", err)
"""


def test_moe_ep_matches_gspmd_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"stdout:{r.stdout}\nstderr:{r.stderr[-2000:]}"
    assert "EP==GSPMD ok" in r.stdout
