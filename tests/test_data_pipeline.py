"""Data substrate: packed store, learned-index lookup, pipeline resume."""

import numpy as np
import pytest

from repro.data import IndexedTokenDataset, PackedTokenStore, ShardedLoader


@pytest.fixture(scope="module")
def dataset():
    store = PackedTokenStore.synthetic(600, mean_len=64, vocab=1000, seed=0)
    return IndexedTokenDataset.build(store, method="pgm", eps=16,
                                     sample_rate=0.5, gap_rho=0.2)


def test_store_roundtrip(tmp_path):
    store = PackedTokenStore.synthetic(50, mean_len=32, seed=1)
    store.save(str(tmp_path / "st"))
    loaded = PackedTokenStore.load(str(tmp_path / "st"))
    assert np.array_equal(loaded.sample_keys, store.sample_keys)
    assert np.array_equal(loaded.doc(7), store.doc(7))


def test_ordinal_resolution(dataset):
    keys = dataset.store.sample_keys[::7].astype(np.float64)
    ords = dataset.ordinals(keys)
    assert np.array_equal(ords, np.arange(dataset.store.n_docs)[::7])


def test_missing_key_raises(dataset):
    with pytest.raises(KeyError):
        dataset.ordinals(np.array([3.5]))


def test_batch_shapes(dataset):
    keys = dataset.store.sample_keys[:8].astype(np.float64)
    b = dataset.batch(keys, seq_len=32)
    assert b.shape == (8, 32)
    assert np.array_equal(b[0, :16], dataset.store.doc(0)[:16])


def test_streamed_ingest(dataset):
    new_key = int(dataset.store.sample_keys[10]) + 1  # interleaves
    doc = np.arange(20, dtype=np.uint32)
    dataset.ingest(doc, new_key)
    o = dataset.ordinals(np.array([float(new_key)]))
    assert np.array_equal(dataset.store.doc(int(o[0])), doc)


def test_loader_determinism_and_seek():
    store = PackedTokenStore.synthetic(256, mean_len=40, vocab=500, seed=2)
    ds = IndexedTokenDataset.build(store, method="fiting", eps=8)
    l1 = ShardedLoader(ds, global_batch=16, seq_len=32, seed=7)
    batches = [l1.next_batch() for _ in range(5)]
    # fresh loader seeked to step 3 reproduces batch 3 exactly
    l2 = ShardedLoader(ds, global_batch=16, seq_len=32, seed=7)
    l2.seek(3)
    b3 = l2.next_batch()
    assert np.array_equal(b3["tokens"], batches[3]["tokens"])


def test_loader_sharding_partitions_batch():
    store = PackedTokenStore.synthetic(128, mean_len=24, vocab=500, seed=3)
    ds = IndexedTokenDataset.build(store, method="rmi", n_leaf=32)
    shards = [
        ShardedLoader(ds, global_batch=16, seq_len=16, seed=1,
                      shard_id=i, n_shards=4).next_batch()["tokens"]
        for i in range(4)
    ]
    full = ShardedLoader(ds, global_batch=16, seq_len=16, seed=1).next_batch()
    stacked = np.stack(shards)  # (4, 4, 16) strided partitions
    for i in range(4):
        assert np.array_equal(stacked[i], full["tokens"][i::4])
