"""Hypothesis property for sampled construction + online retrain
(optional dep — the whole module skips when hypothesis is absent; the
deterministic companions in test_retrain.py always run, including a
fixed-seed sweep of the same bit-identity claim).

Property (§4 + §5 end-to-end): a sampled-then-refinalized build —
mechanism learning on O(n_s) pairs, ``connect_segments`` patch,
``refinalize_bounds`` backstop — ANSWERS bit-identically to the
full-data build, across mechanisms (pgm/fiting), both key widths
(below/above the 2**24 f32 integer-exactness edge), and THROUGH a
sampled ``retrain()`` of the live state under the epoch pipeline's
pinned snapshot."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_retrain import (  # noqa: E402
    check_sampled_build_identity_through_retrain,
)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2 ** 31 - 1),
    method=st.sampled_from(["pgm", "fiting"]),
    wide=st.booleans(),
    rate=st.sampled_from([0.05, 0.15]),
)
def test_sampled_build_bit_identical_through_retrain(seed, method, wide,
                                                     rate):
    check_sampled_build_identity_through_retrain(seed, method, wide, rate)
