"""Partitioning rules + elastic helpers (single-device mesh semantics
checked here; the 512-device meshes are proven by launch/dryrun.py in its
own process — conftest must NOT set device-count flags)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as PS

from repro.configs import ARCHS
from repro.dist.partitioning import (
    activation_constrainer,
    input_shardings,
    param_pspecs,
    pspec_for_axes,
)
from repro.launch.mesh import make_mesh_for
from repro.models import build_model


def _mesh2d(data=1, model=1):
    return jax.make_mesh((data, model), ("data", "model"))


def test_pspec_basic_rules():
    mesh = _mesh2d()
    assert pspec_for_axes(("embed", "heads", None), mesh) == PS(None, "model", None)
    assert pspec_for_axes(("vocab", "embed"), mesh) == PS("model", None)
    assert pspec_for_axes(("experts", "embed", "ffn"), mesh) == \
        PS("model", None, None)  # model axis claimed once


def test_pspec_fsdp_claims_data_axis():
    mesh = _mesh2d()
    assert pspec_for_axes(("embed", "ffn"), mesh, fsdp=True) == \
        PS("data", "model")


def test_pspec_divisibility_guard():
    mesh = _mesh2d(model=1)  # sizes 1 divide everything
    ps = pspec_for_axes(("heads",), mesh, shape=(36,))
    assert ps == PS("model")
    big = jax.make_mesh((1, 1), ("data", "model"))
    # emulate 16-way: use shape check directly via a fake mesh is not
    # possible on 1 device; assert the arithmetic path instead
    from repro.dist import partitioning as P_
    # 36 heads % 16 != 0 -> replicate
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    assert P_.pspec_for_axes(("heads",), FakeMesh, shape=(36,)) == PS(None)
    assert P_.pspec_for_axes(("heads",), FakeMesh, shape=(64,)) == PS("model")


def test_param_pspecs_whole_model():
    mesh = _mesh2d()
    model = build_model(ARCHS["internlm2-1.8b"])
    specs = param_pspecs(model.logical_axes(), mesh,
                         abstract_tree=model.abstract_params())
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, PS))
    assert all(isinstance(p, PS) for p in flat)
    # embed table is vocab-sharded
    assert specs["embed"] == PS("model", None)


def test_constrainer_runs_under_jit():
    mesh = _mesh2d()
    constrain = activation_constrainer(mesh)

    @jax.jit
    def f(x):
        return constrain(x, ("batch", None, "embed")) * 2

    out = f(jnp.ones((4, 8, 16)))
    assert out.shape == (4, 8, 16)


def test_make_mesh_for_elastic_shapes():
    m = make_mesh_for(1, model_parallel=1)
    assert m.devices.size == 1
    # model_parallel rounded down to a divisor of device count
    m2 = make_mesh_for(1, model_parallel=7)
    assert m2.devices.size == 1


def test_elastic_restore_roundtrip(tmp_path):
    from repro.configs import reduced
    from repro.train import CheckpointManager
    from repro.train.elastic import elastic_restore

    cfg = reduced(ARCHS["internlm2-1.8b"])
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    state = {"params": params}
    CheckpointManager(str(tmp_path)).save(3, state, extra={"step": 3})
    restored, mesh, extra = elastic_restore(
        model, str(tmp_path), model_parallel=1, template=state)
    assert extra["step"] == 3
    w0 = jax.tree.leaves(params)[0]
    w1 = jax.tree.leaves(restored["params"])[0]
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))
