"""repro.dist.sharded: range-partitioned ShardedIndex vs the
single-device Index — the bit-identity contract (ISSUE 7 acceptance):
sharded lookup AND ingest answers (payloads/found) must equal the
single-device handle's over the same key/payload sets, on both key
widths, on both the fused fan-out path and the grouped host path.

Run under ``scripts/tier1.sh`` these tests see 8 host devices
(XLA_FLAGS=--xla_force_host_platform_device_count=8) and exercise the
real shard_map all-to-all; under plain pytest they still pass with
D=1 (the fan-out degenerates to a vmapped single-device graph)."""

import numpy as np
import pytest

from repro.core import Index
from repro.dist.sharded import ShardedIndex, ShardedIngestReport, ShardRouter
from repro.core.results import IngestReport, LookupResult


def _int_keys(lo, hi, n, seed=0):
    rng = np.random.default_rng(seed)
    return np.unique(rng.integers(lo, hi, size=n)).astype(np.float64)


def _mixed_queries(keys, n_hit, n_miss, seed=1):
    rng = np.random.default_rng(seed)
    q = np.concatenate([rng.choice(keys, n_hit),
                        rng.choice(keys, n_miss) + 1.0])
    rng.shuffle(q)
    return q


NARROW = dict(lo=1 << 10, hi=1 << 22, n=25_000)   # f32-exact ints
WIDE = dict(lo=1 << 30, hi=1 << 45, n=20_000)     # f32 hi/lo pair ints


@pytest.fixture(scope="module", params=["narrow", "wide"])
def pair(request):
    cfg = NARROW if request.param == "narrow" else WIDE
    keys = _int_keys(cfg["lo"], cfg["hi"], cfg["n"], seed=3)
    single = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    sharded = Index.build(keys, shards=4, method="pgm", eps=64,
                          gap_rho=0.2)
    assert isinstance(sharded, ShardedIndex)
    return keys, single, sharded, request.param


def _assert_identical(a: LookupResult, b: LookupResult):
    assert np.array_equal(np.asarray(a.payloads), np.asarray(b.payloads))
    assert np.array_equal(np.asarray(a.found), np.asarray(b.found))


def test_lookup_bit_identity_both_paths(pair):
    keys, single, sharded, width = pair
    if width == "wide":
        assert single._key_caps() == (True, True)
    q = _mixed_queries(keys, 3000, 1500)
    want = single.lookup(q)
    got = sharded.lookup(q)                       # >= 512: fan-out
    assert got.backend == "sharded-fanout"
    _assert_identical(want, got)
    got_host = sharded.lookup(q[:200])            # < 512: grouped host
    assert got_host.backend == "sharded-host"
    _assert_identical(single.lookup(q[:200]), got_host)
    # sharded slots are globalized per shard: unique among found rows
    slots = np.asarray(got.slots)[np.asarray(got.found)]
    hits = np.asarray(q)[np.asarray(got.found)]
    first = {}
    for k, s in zip(hits, slots):
        first.setdefault(k, s)
        assert first[k] == s  # same key -> same physical slot


def test_boundary_queries_route_and_resolve_exactly(pair):
    keys, single, sharded, _ = pair
    b = sharded.router.bounds
    q = np.concatenate([b, b - 1.0, b + 1.0, keys[:1],
                        keys[-1:] + 17.0])
    q = np.tile(q, 64)  # over min_device_batch: exercises the fan-out
    want, got = single.lookup(q), sharded.lookup(q)
    assert got.backend == "sharded-fanout"
    _assert_identical(want, got)
    # boundary keys are shard firsts: route-right-open (key -> its own
    # shard), predecessors route left
    dst = sharded.router.route(b)
    assert np.array_equal(dst, np.arange(1, len(sharded.shards)))
    assert np.array_equal(sharded.router.route(b - 1.0),
                          np.arange(0, len(sharded.shards) - 1))


def test_ingest_bit_identity(pair):
    keys, single, sharded, width = pair
    rng = np.random.default_rng(7)
    lo, hi = float(keys[0]), float(keys[-1])
    new = np.unique(rng.integers(int(lo), int(hi), size=4000)
                    ).astype(np.float64) + 0.5  # interleaves everywhere
    pays = rng.integers(0, 1 << 30, size=new.shape[0])
    rep_s = single.ingest(new, pays)
    rep_d = sharded.ingest(new, pays)
    assert isinstance(rep_d, ShardedIngestReport)
    assert isinstance(rep_d, IngestReport)  # aggregate keeps the type
    assert rep_d.n == rep_s.n == new.shape[0]
    assert rep_d.slot + rep_d.chain == rep_d.n  # invariant survives sums
    assert rep_d.device == "sharded"
    assert len(rep_d.per_shard) >= 2  # writes spread over shards
    assert sum(r.n for _, r in rep_d.per_shard) == rep_d.n
    q = np.concatenate([rng.choice(keys, 2000), rng.choice(new, 2000),
                        rng.choice(keys, 500) + 2.0])
    rng.shuffle(q)
    _assert_identical(single.lookup(q), sharded.lookup(q))
    _assert_identical(single.lookup(q[:100]), sharded.lookup(q[:100]))


def test_forced_split_state_identity(pair):
    keys, single, sharded, _ = pair
    n_before = len(sharded.shards)
    rec = sharded.maybe_rebalance(force_shard=1)
    assert rec is not None and rec["shard"] == 1
    assert len(sharded.shards) == n_before + 1
    assert abs(rec["n_left"] - rec["n_right"]) <= 1  # median split
    assert len(sharded.router.bounds) == len(sharded.shards) - 1
    # the split is a pure re-layout: every answer identical after it
    q = _mixed_queries(keys, 2500, 1000, seed=11)
    _assert_identical(single.lookup(q), sharded.lookup(q))
    _assert_identical(single.lookup(q[:150]), sharded.lookup(q[:150]))


def test_skewed_writes_trigger_watermark_split():
    keys = _int_keys(1 << 10, 1 << 22, 20_000, seed=5)
    sharded = Index.build(keys, shards=4, method="pgm", eps=64,
                          gap_rho=0.2)
    sharded.min_split_keys = 2048
    sharded.split_occupancy_factor = 1.5
    # hammer shard 0 with interleaving writes
    skew = np.arange(keys[0] + 0.25, keys[0] + 2500.0, 0.5)
    sharded.ingest(skew, np.arange(skew.shape[0]) + (1 << 22))
    assert sharded.stats["splits"] >= 1
    assert len(sharded.shards) > 4
    assert sharded.stats["rebalance_seconds"] > 0.0
    r = sharded.lookup(skew[:600])
    assert bool(np.all(np.asarray(r.found)))
    # every pre-existing key still resolves
    r2 = sharded.lookup(keys[:: 37])
    assert bool(np.all(np.asarray(r2.found)))


def test_prime_shard_count_degenerate_mesh():
    """S=11 shards: on 8 (or 1) host devices the largest divisor is 1,
    so the fan-out runs single-device with S_local=11 — the mesh
    degenerates but the graph and answers do not."""
    keys = _int_keys(1 << 10, 1 << 22, 9_000, seed=9)
    single = Index.build(keys, method="pgm", eps=32, gap_rho=0.2)
    sharded = Index.build(keys, shards=11, method="pgm", eps=32,
                          gap_rho=0.2)
    q = _mixed_queries(keys, 1500, 500, seed=2)
    got = sharded.lookup(q)
    assert got.backend == "sharded-fanout"
    assert sharded._fan.D in (1, 11)
    _assert_identical(single.lookup(q), got)


def test_fanout_unavailable_falls_back_to_host_groups(monkeypatch):
    # when the stacked images cannot be built (non-PLM mechanism,
    # aliasing rounded boundaries, capacity blowup) lookup silently
    # takes the exact grouped-host route; only an EXPLICIT
    # backend="fanout" request raises
    import repro.kernels.shard_fanout as sf

    keys = _int_keys(1 << 10, 1 << 20, 6_000, seed=4)
    single = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    sharded = ShardedIndex.build(keys, shards=2, method="pgm", eps=64,
                                 gap_rho=0.2)

    def refuse(cls, *a, **k):
        raise sf.FanoutUnavailable("forced by test")

    monkeypatch.setattr(sf.ShardFanout, "build", classmethod(refuse))
    q = _mixed_queries(keys, 800, 200, seed=3)
    got = sharded.lookup(q)                       # >= 512, but no fan
    assert got.backend == "sharded-host"
    _assert_identical(single.lookup(q), got)
    with pytest.raises(RuntimeError):
        sharded.lookup(q, backend="fanout")
    # the failed build is negative-cached per epoch tag: unchanged
    # shards don't retry the build on every call
    assert sharded._fan_failed_tag is not None


def test_build_validation():
    keys = np.arange(100, dtype=np.float64)
    with pytest.raises(ValueError):  # gapless sharded build
        ShardedIndex.build(keys, shards=2, gap_rho=0.0)
    with pytest.raises(ValueError):  # too many shards for the keys
        ShardedIndex.build(keys, shards=64, gap_rho=0.2)
    with pytest.raises(ValueError):  # unsorted
        ShardedIndex.build(keys[::-1], shards=2, gap_rho=0.2)
    with pytest.raises(ValueError):  # payload shape mismatch
        ShardedIndex.build(keys, shards=2, gap_rho=0.2,
                           payloads=np.arange(3))


def test_router_boundary_exactness_property():
    """Hypothesis property: the DEVICE route (learned two-segment
    prediction + exact bisect backstop, kernels.shard_fanout
    ._route_block) equals searchsorted over the rounded boundaries for
    ARBITRARY integer key sets — including queries exactly on, just
    below, and just above every boundary."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    import jax.numpy as jnp
    from repro.kernels.shard_fanout import _round_key_repr, _route_block

    @hyp.settings(max_examples=40, deadline=None)
    @hyp.given(st.data())
    def run(data):
        key_wide = data.draw(st.booleans())
        hi = (1 << 47) if key_wide else (1 << 23)
        vals = data.draw(st.lists(st.integers(0, hi), min_size=4,
                                  max_size=40, unique=True))
        vals = np.sort(np.asarray(vals, np.float64))
        n_b = data.draw(st.integers(1, max(1, vals.size // 2)))
        idx = np.linspace(0, vals.size - 1, n_b + 2)[1:-1]
        bounds = np.unique(vals[np.round(idx).astype(int)])
        rb = _round_key_repr(bounds, key_wide)
        hyp.assume(np.all(np.diff(rb) > 0))
        q = np.unique(np.concatenate(
            [vals, bounds, bounds - 1.0, bounds + 1.0]))
        router = ShardRouter(bounds, lo_key=float(vals[0]))
        s = bounds.size + 1
        from repro.kernels import ops as _ops
        qh, ql = _ops.split_key_pair(q)
        bh, bl = _ops.split_key_pair(bounds)
        if not key_wide:
            ql, bl = np.zeros_like(ql), np.zeros_like(bl)
        r_trips = int(np.ceil(np.log2(max(s - 1, 2)))) + 1
        dst, _ = _route_block(
            jnp.asarray(qh), jnp.asarray(ql), jnp.asarray(bh),
            jnp.asarray(bl), jnp.asarray(router.device_params()),
            s, r_trips, key_wide)
        want = np.searchsorted(rb, _round_key_repr(q, key_wide),
                               side="right")
        assert np.array_equal(np.asarray(dst), want)

    run()


def test_abort_telemetry_on_ingest_report():
    """Satellite: the fused write graph's abort REASON (per batch) and
    the engine's cumulative abort counter ride the IngestReport — a
    report stream alone answers "how often does the write graph veto,
    and why"."""
    init = np.arange(0, 1_000_000, 100, dtype=np.float64)
    idx = Index.build(init, method="pgm", eps=32, gap_rho=0.2)
    idx.fused_ingest_enabled = True
    idx.sync_device()
    # contiguous run crammed with new keys: the in-graph closure check
    # refuses (collision groups / chain overflow), host partition lands
    batch = np.setdiff1d(np.arange(50_001, 50_001 + 620,
                                   dtype=np.float64), init)[:512]
    rep = idx.ingest(batch, 3_000_000 + np.arange(batch.size))
    assert rep.device != "fused"          # the graph vetoed the batch
    assert len(rep.abort_reasons) >= 1    # and the report says why
    assert rep.fused_aborts == 1
    assert idx.stats["fused_abort_total"] == 1
    for name in rep.abort_reasons:
        assert name in idx.stats["fused_aborts"]
    # a committable sparse follow-up batch reports NO per-batch reason;
    # the engine counter stays (it is cumulative)
    idx.sync_device()
    spread = (init + 50.0)[::19][:512]  # one midpoint per distant run
    rep2 = idx.ingest(spread, 4_000_000 + np.arange(spread.size))
    assert rep2.abort_reasons == ()
    assert rep2.fused_aborts == 1
