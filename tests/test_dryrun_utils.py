"""Dry-run utilities tested in-process (no 512-device flags here):
collective-bytes HLO parser, roofline math, extrapolation algebra."""

import json

import pytest


def _import_dryrun(monkeypatch):
    # importing dryrun sets XLA_FLAGS before jax init; jax is already
    # initialized in this process, so guard the env var side effect.
    import os
    prev = os.environ.get("XLA_FLAGS")
    import repro.launch.dryrun as dr
    if prev is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = prev
    return dr


HLO = """
HloModule jit_step
%x1 = bf16[2048,7168]{1,0} all-reduce(%a), replica_groups={{0,1}}
%x2 = (f32[128]{0}, f32[64]{0}) all-gather-start(%b, %c)
%x3 = f32[1024]{0} reduce-scatter(%d)
%y = bf16[8,16]{1,0} add(%e, %f)
%x4 = bf16[4,2,8]{2,1,0} all-to-all(%g)
%x5 = f32[32]{0} collective-permute-start(%h)
%x6 = f32[32]{0} collective-permute-done(%x5)
"""


def test_collective_bytes_parser(monkeypatch):
    dr = _import_dryrun(monkeypatch)
    total, per_kind, count = dr.collective_bytes(HLO)
    assert per_kind["all-reduce"] == 2048 * 7168 * 2
    assert per_kind["all-gather"] == 128 * 4 + 64 * 4
    assert per_kind["reduce-scatter"] == 1024 * 4
    assert per_kind["all-to-all"] == 4 * 2 * 8 * 2
    assert per_kind["collective-permute"] == 32 * 4  # start only, not done
    assert count == 5
    assert total == sum(per_kind.values())


def test_roofline_terms():
    from repro.launch.roofline import analyze_cell
    rec = {
        "arch": "yi-9b", "shape": "train_4k", "mesh": "pod16x16",
        "tag": "baseline", "status": "ok", "n_devices": 256,
        "flops_per_device": 1.97e14,       # exactly 1s of compute
        "bytes_per_device": 8.19e11,       # exactly 1s of HBM
        "collective_bytes_per_device": 5e10,  # 1s of ICI
        "params": 8.8e9, "active_params": 8.8e9,
    }
    c = analyze_cell(rec)
    assert c["t_compute_s"] == pytest.approx(1.0)
    assert c["t_memory_s"] == pytest.approx(1.0)
    assert c["t_collective_s"] == pytest.approx(1.0)
    assert c["dominant"] in ("compute", "memory", "collective")
    # useful flops: 6*N*D/devices over reported flops
    want = 6 * 8.8e9 * (4096 * 256) / 256 / 1.97e14
    assert c["useful_compute_ratio"] == pytest.approx(want, rel=1e-6)


def test_extrapolation_algebra(tmp_path):
    from repro.launch.extrapolate import LINEAR_FIELDS, extrapolate
    # synthetic probes: cost(L) = 100 + 10*L
    for tag, L in (("L4", 4), ("L8", 8)):
        rec = {"arch": "internlm2-1.8b", "shape": "train_4k",
               "mesh": "pod16x16", "tag": tag, "status": "ok",
               "layers_used": L, "n_devices": 256,
               "flops_per_device": 100 + 10 * L,
               "bytes_per_device": 7 + 3 * L,
               "collective_bytes_per_device": 5 * L,
               "collective_ops": 2 * L,
               "collectives": {"all-reduce": 5 * L},
               }
        with open(tmp_path / f"internlm2-1.8b__train_4k__pod16x16__{tag}.json",
                  "w") as f:
            json.dump(rec, f)
    out = extrapolate(str(tmp_path), "internlm2-1.8b", "train_4k",
                      "pod16x16", 4, 8)
    L = 24  # internlm2 layers
    assert out["flops_per_device"] == pytest.approx(100 + 10 * L)
    assert out["bytes_per_device"] == pytest.approx(7 + 3 * L)
    assert out["collective_bytes_per_device"] == pytest.approx(5 * L)
    assert out["collectives"]["all-reduce"] == pytest.approx(5 * L)
    assert out["extrapolated"] is True
