"""Unified Index handle: epoch protocol, delta-vs-rebuild state identity,
backend capability registry, typed results, deprecation shims."""

import copy
import warnings

import numpy as np
import pytest

from conftest import make_keys
from repro.core import BACKENDS, Index, IngestReport, LearnedIndex, LookupResult
from repro.kernels import from_learned_index


def _device_state_equal(engine_arrays, fresh_arrays):
    """Delta-updated device buffers == rebuild-from-scratch freeze, up to
    capacity padding (compare the live prefixes; CSR links reconstructed
    per slot through the offsets)."""
    ns = fresh_arrays.n_slots
    a, b = engine_arrays, fresh_arrays
    assert np.array_equal(np.asarray(a.slot_key)[:ns],
                          np.asarray(b.slot_key)[:ns])
    assert np.array_equal(np.asarray(a.payload)[:ns],
                          np.asarray(b.payload)[:ns])
    off_a = np.asarray(a.link_offsets)[: ns + 1]
    off_b = np.asarray(b.link_offsets)[: ns + 1]
    assert np.array_equal(off_a, off_b)
    L = int(off_b[-1])
    assert np.array_equal(np.asarray(a.link_keys)[:L],
                          np.asarray(b.link_keys)[:L])
    assert np.array_equal(np.asarray(a.link_payloads)[:L],
                          np.asarray(b.link_payloads)[:L])
    if a.key_wide:
        assert np.array_equal(np.asarray(a.slot_key_lo)[:ns],
                              np.asarray(b.slot_key_lo)[:ns])
        assert np.array_equal(np.asarray(a.link_keys_lo)[:L],
                              np.asarray(b.link_keys_lo)[:L])
    return True


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_epoch_delta_rounds_state_identical_to_rebuild(seed):
    """Property: N interleaved ingest/lookup rounds on the delta-updated
    device state leave buffers state-identical to a rebuild-from-scratch
    freeze, and every lookup is bit-identical to the host oracle."""
    rng = np.random.default_rng(seed)
    x = make_keys("uniform_int", 20_000, seed=seed)
    idx = Index.build(x, method="pgm", eps=64, gap_rho=0.25)
    idx.fused_ingest_enabled = False  # pin to the delta arm under test
    pool = np.setdiff1d(
        np.unique(rng.integers(1, 2 ** 22, 40_000)).astype(np.float64), x)
    rng.shuffle(pool)
    used = 0
    # materialize the device engine, then interleave
    idx.lookup(rng.choice(x, 4096), backend="xla-windowed")
    assert idx.device_epoch == idx.epoch == 0
    for rnd in range(4):
        batch = pool[used: used + 700]
        used += 700
        rep = idx.ingest(batch, 10_000_000 + np.arange(700) + rnd)
        assert isinstance(rep, IngestReport)
        assert rep.slot + rep.chain == 700
        assert rep.device in ("delta", "refreeze")
        assert idx.device_epoch == idx.epoch
        q = np.concatenate([batch, rng.choice(x, 2000),
                            pool[used: used + 300]])  # misses too
        res = idx.lookup(q, backend="xla-windowed")
        assert isinstance(res, LookupResult)
        truth_pay, _, truth_found = idx.gapped.lookup_batch(q, full=True)
        assert np.array_equal(res.payloads, truth_pay)
        assert np.array_equal(res.found, truth_found)
        assert res.epoch == idx.epoch
        _device_state_equal(idx._engine.arrays, from_learned_index(idx))
    assert idx.stats["delta_updates"] >= 1


def test_forced_refreeze_threshold_crossings():
    """Tiny thresholds force the refreeze arm; results stay identical and
    the refreeze counter moves instead of the delta counter."""
    x = make_keys("uniform_int", 15_000, seed=3)
    rng = np.random.default_rng(3)
    idx = Index.build(x, method="pgm", eps=64, gap_rho=0.2)
    idx.refreeze_contested_frac = 0.0  # any contested key -> refreeze
    idx.refreeze_link_growth = 0.0     # any chain growth -> refreeze
    idx.lookup(rng.choice(x, 4096), backend="xla-windowed")
    refreezes0 = idx.stats["refreezes"]
    mids = np.setdiff1d(x[:-1] + np.diff(x) * 0.5, x)[:1500]
    rep = idx.ingest(mids, np.arange(1500))
    if rep.chain or rep.contested:
        assert rep.device == "refreeze"
        assert idx.stats["refreezes"] > refreezes0
    res = idx.lookup(mids, backend="xla-windowed")
    assert np.array_equal(res.payloads, np.arange(1500))
    _device_state_equal(idx._engine.arrays, from_learned_index(idx))


def test_delta_and_refreeze_lookups_bit_identical():
    """The acceptance property: after the same mutations, a delta-updated
    engine and a freshly refrozen engine answer bit-identically."""
    x = make_keys("iot", 20_000, seed=4)
    rng = np.random.default_rng(4)
    idx_delta = Index.build(x, method="pgm", eps=64, gap_rho=0.25)
    # disable the policy thresholds so this run exercises the delta arm
    idx_delta.refreeze_contested_frac = 1.1
    idx_delta.refreeze_link_growth = 10.0
    idx_delta.fused_ingest_enabled = False
    mids = np.setdiff1d(x[:-1] + np.diff(x) * rng.random(len(x) - 1), x)
    # warm round: grows the frozen chain/link capacities (may refreeze)
    idx_delta.ingest(mids[800:1600], np.arange(800))
    idx_delta.lookup(rng.choice(x, 4096), backend="xla-windowed")
    idx_fresh = copy.deepcopy(idx_delta)  # device dropped by deepcopy
    mids = mids[:800]
    pay = 5_000_000 + np.arange(len(mids))
    rep = idx_delta.ingest(mids, pay)
    assert rep.device == "delta"
    idx_fresh.ingest(mids, pay)      # no engine yet -> device "none"
    idx_fresh.refreeze()
    q = np.concatenate([mids, rng.choice(x, 4000)])
    r_delta = idx_delta.lookup(q, backend="xla-windowed")
    r_fresh = idx_fresh.lookup(q, backend="xla-windowed")
    assert np.array_equal(r_delta.payloads, r_fresh.payloads)
    assert np.array_equal(r_delta.found, r_fresh.found)
    assert np.array_equal(r_delta.slots, r_fresh.slots)


def test_scalar_ops_bump_epoch_and_device_follows():
    """Scalar insert/delete/update through any path bump the epoch; the
    next device lookup syncs lazily."""
    x = make_keys("uniform_int", 10_000, seed=5)
    idx = Index.build(x, method="pgm", eps=64, gap_rho=0.2)
    rng = np.random.default_rng(5)
    idx.lookup(rng.choice(x, 4096), backend="xla-windowed")
    e0 = idx.epoch
    k = float(x[100]) + 0.5
    idx.insert(k, 777)
    assert idx.epoch > e0
    assert idx.device_epoch < idx.epoch  # stale until next device read
    res = idx.lookup(np.full(4096, k), backend="xla-windowed")
    assert idx.device_epoch == idx.epoch
    assert np.all(res.payloads == 777)
    idx.update(k, 778)
    assert np.all(idx.lookup(np.full(4096, k),
                             backend="xla-windowed").payloads == 778)
    assert idx.remove(np.array([k])) == 1
    res = idx.lookup(np.full(4096, k), backend="xla-windowed")
    assert not res.found.any() and np.all(res.payloads == -1)


def test_backend_registry_resolution_and_capabilities():
    x = make_keys("uniform_int", 9_000, seed=6)
    idx = Index.build(x, method="pgm", eps=64, gap_rho=0.1)
    assert set(BACKENDS) == {"fused", "pallas", "xla-windowed",
                             "numpy-oracle"}
    # size-aware default: small batches host, large device — and the
    # device default is the fused single-dispatch path
    assert not idx.resolve_backend(10).device
    assert idx.resolve_backend(10_000).device
    assert idx.resolve_backend(10_000).name == "fused"
    with pytest.raises(ValueError, match="unknown backend"):
        idx.lookup(x[:4], backend="cuda")
    # wide keys: explicit LEGACY pallas refused with the failed
    # capability (+2^30 offsets need >24 mantissa bits; *2^30 would
    # stay f32-exact)
    wide_keys = np.unique(x + 2.0 ** 30)
    widx = Index.build(wide_keys, method="pgm", eps=64, gap_rho=0.1)
    with pytest.raises(ValueError, match="hi/lo"):
        widx.lookup(wide_keys[:2048], backend="pallas")
    # ...but the default resolution serves them on device (fused)
    assert widx.resolve_backend(10_000).name == "fused"
    res = widx.lookup(wide_keys[:2048])
    assert res.backend == "fused"
    assert np.array_equal(res.payloads,
                          np.searchsorted(wide_keys, wide_keys[:2048]))
    # the legacy multi-op reference stage still serves explicitly
    res = widx.lookup(wide_keys[:2048], backend="xla-windowed")
    assert np.array_equal(res.payloads,
                          np.searchsorted(wide_keys, wide_keys[:2048]))


def test_static_build_typed_and_legacy_shim():
    """Static (no-gap) builds route through LookupResult too; the
    LearnedIndex shim preserves the old array returns under a
    DeprecationWarning."""
    x = make_keys("weblogs", 8_000, seed=7)
    rng = np.random.default_rng(7)
    q = np.concatenate([rng.choice(x, 500), x[:200] + 0.25])
    truth = np.where(np.isin(q, x), np.searchsorted(x, q), -1)
    idx = Index.build(x, method="pgm", eps=64)
    res = idx.lookup(q)
    assert np.array_equal(res.payloads, truth)
    assert np.array_equal(res.found, truth >= 0)
    legacy = LearnedIndex.build(x, method="pgm", eps=64)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out = legacy.lookup(q)
    assert any(issubclass(c.category, DeprecationWarning) for c in caught)
    assert isinstance(out, np.ndarray)
    assert np.array_equal(out, truth)
    # gapped legacy shim: payload array, same values as the typed result
    legacy_g = LearnedIndex.build(x, method="pgm", eps=64, gap_rho=0.2)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        out_g = legacy_g.lookup(q)
    assert np.array_equal(out_g, Index.lookup(legacy_g, q).payloads)


def test_keys_beyond_pair_exactness_stay_on_host():
    """Key sets whose distinct keys ALIAS in the f32 hi/lo pair
    representation (dense integers at ~2^52: pair resolution is 16)
    must never be served by a device backend — the pair compare would
    return false-positive hits."""
    from repro.kernels import keys_pair_exact, pair_alias_free

    rng = np.random.default_rng(10)
    # residuals near 2^27: f32 lo quantizes to multiples of 16, so keys
    # spaced 4 apart share their (hi, lo) pair
    keys = np.unique(2.0 ** 52 + 2.0 ** 27
                     + rng.integers(0, 2 ** 14, 6_000).astype(np.float64) * 4)
    assert not pair_alias_free(keys)  # genuinely aliasing
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.1)
    # auto-resolution: large batches still route to the exact host path
    assert idx.resolve_backend(10_000).name == "numpy-oracle"
    absent = np.setdiff1d(keys[:2048] + 1.0, keys)
    res = idx.lookup(absent)
    assert not res.found.any() and np.all(res.payloads == -1)
    for be in ("fused", "xla-windowed", "pallas"):
        with pytest.raises(ValueError, match="alias|hi/lo"):
            idx.lookup(keys[:1024], backend=be)
    # ingesting keys that alias EACH OTHER's pair into a device-backed
    # index drops the engine (the registry then serves host-side)
    x = make_keys("uniform_int", 9_000, seed=10)
    idx2 = Index.build(x, method="pgm", eps=64, gap_rho=0.2)
    idx2.lookup(np.sort(np.random.default_rng(0).choice(x, 4096)),
                backend="xla-windowed")
    assert idx2._engine is not None
    big1 = float(2 ** 52 + 2 ** 27)      # pair-exact
    big2 = big1 + 1.0                    # distinct key, SAME pair
    assert keys_pair_exact(np.array([big1]))
    assert not keys_pair_exact(np.array([big2]))
    rep = idx2.ingest(np.array([big1, big2]), np.array([123, 124]))
    assert rep.device == "none" and idx2._engine is None
    res = idx2.lookup(np.full(4096, big2))
    assert res.backend == "numpy-oracle"
    assert np.all(res.payloads == 124)


def test_no_plm_mechanism_serves_on_host():
    """btree exports no piecewise linear model; large batches must fall
    back to the host instead of crashing in the device freeze."""
    x = make_keys("uniform_int", 6_000, seed=11)
    idx = Index.build(x, method="btree", page_size=128)
    q = np.concatenate([x[:900], x[:124] + 0.5])
    res = idx.lookup(np.tile(q, 2))  # 2048 queries >= min_device_batch
    assert res.backend == "numpy-oracle"
    truth = np.where(np.isin(np.tile(q, 2), x),
                     np.searchsorted(x, np.tile(q, 2)), -1)
    assert np.array_equal(res.payloads, truth)
    with pytest.raises(ValueError, match="piecewise linear"):
        idx.lookup(q, backend="xla-windowed")


def test_capability_checks_track_ingested_keys():
    """_key_caps follows the LIVE key set: ingesting >2^24 keys into a
    narrow-key index flips the pallas capability check."""
    x = make_keys("uniform_int", 8_000, seed=12)  # < 2^22: narrow
    idx = Index.build(x, method="pgm", eps=64, gap_rho=0.2)
    idx.lookup(x[:1024], backend="pallas")  # narrow: accepted
    idx.ingest(np.array([2.0 ** 30 + 1]), np.array([5]))
    with pytest.raises(ValueError, match="hi/lo"):
        idx.lookup(x[:1024], backend="pallas")
    res = idx.lookup(np.full(4096, 2.0 ** 30 + 1),
                     backend="xla-windowed")
    assert np.all(res.payloads == 5)


def test_ingest_requires_gaps():
    x = make_keys("uniform_int", 5_000, seed=8)
    idx = Index.build(x, method="pgm", eps=64)
    with pytest.raises(NotImplementedError):
        idx.ingest(np.array([1.5]), np.array([1]))
