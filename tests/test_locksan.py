"""tsan-lite runtime lock sanitizer (analysis/locksan.py): lock-order
inversion detection, guarded-attribute runtime checking, and the
serving stack running sanitizer-clean under the fault harness."""

import threading
import time

import numpy as np
import pytest

from repro.analysis import (GuardedAccessViolation, LockOrderInversion,
                            LockSanitizer, sanitize_serving_stack)
from repro.core import Index
from repro.robustness import FaultInjector
from repro.serving import EpochPipeline, IngestWAL, MicroBatchQueue


def _mk_index(n=6_000, seed=0, **kw):
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.choice(2 ** 21, n, replace=False)).astype(
        np.float64)
    keys *= 2.0
    kw.setdefault("method", "pgm")
    kw.setdefault("eps", 64)
    kw.setdefault("gap_rho", 0.2)
    return Index.build(keys, **kw), keys


def _fresh(keys, n):
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    assert mids.size >= n
    return mids[:n]


# ---------------------------------------------------------------------------
# primitives


class _Guarded:
    def __init__(self):
        self._lock = threading.Lock()
        self.items = []   #: guarded-by: _lock


class TestSanLock:
    def test_wrap_and_reentrancy(self):
        san = LockSanitizer()
        lk = san.wrap_lock("L", threading.RLock())
        with lk:
            with lk:
                assert lk.held_by_me()
        assert not lk.held_by_me()
        san.assert_clean()

    def test_edges_recorded(self):
        san = LockSanitizer()
        a = san.wrap_lock("A", threading.Lock())
        b = san.wrap_lock("B", threading.Lock())
        with a:
            with b:
                pass
        assert san.edges.get(("A", "B"), 0) == 1
        assert not san.inversions()

    def test_inversion_detected(self):
        san = LockSanitizer()
        a = san.wrap_lock("A", threading.Lock())
        b = san.wrap_lock("B", threading.Lock())
        with a:
            with b:
                pass

        def inverted():
            with b:
                with a:
                    pass

        t = threading.Thread(target=inverted)
        t.start()
        t.join()
        inv = san.inversions()
        assert inv and set(inv[0]) == {"A", "B"}
        with pytest.raises(LockOrderInversion):
            san.assert_clean()


class TestInstrument:
    def test_single_thread_access_exempt(self):
        san = LockSanitizer()
        obj = san.instrument(_Guarded())
        obj.items.append(1)     # sole-owner: no race possible
        san.assert_clean()

    def test_cross_thread_unguarded_flagged(self):
        san = LockSanitizer()
        obj = san.instrument(_Guarded())
        obj.items.append(1)

        def other():
            obj.items.append(2)   # second thread, no lock held

        t = threading.Thread(target=other)
        t.start()
        t.join()
        assert san.violations
        with pytest.raises(GuardedAccessViolation):
            san.assert_clean()

    def test_cross_thread_guarded_clean(self):
        san = LockSanitizer()
        obj = san.instrument(_Guarded())
        with obj._lock:
            obj.items.append(1)

        def other():
            with obj._lock:
                obj.items.append(2)

        t = threading.Thread(target=other)
        t.start()
        t.join()
        san.assert_clean()

    def test_unannotated_class_rejected(self):
        class Bare:
            pass

        with pytest.raises(ValueError):
            LockSanitizer().instrument(Bare())

    def test_explicit_guarded_map(self):
        class Plain:
            def __init__(self):
                self.mu = threading.Lock()
                self.x = 0

        san = LockSanitizer()
        obj = san.instrument(Plain(), guarded={"x": "mu"})
        with obj.mu:
            obj.x = 1
        san.assert_clean()


# ---------------------------------------------------------------------------
# the serving stack


class TestServingStack:
    def test_real_workload_sanitizer_clean(self, tmp_path):
        """MicroBatchQueue + EpochPipeline + IngestWAL with the
        deadline timer firing and a second caller thread: zero
        lock-order inversions, zero guarded-access violations."""
        idx, keys = _mk_index()
        wal = IngestWAL(tmp_path / "w.wal", sync_every="adaptive")
        pipe = EpochPipeline(idx, wal=wal, publish_every=2)
        queue = MicroBatchQueue(pipe, max_wait_ms=2.0, min_bucket=64)
        san = sanitize_serving_stack(queue=queue, pipeline=pipe, wal=wal)

        fresh = _fresh(keys, 512)
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                t = queue.submit_lookup(keys[:32])
                queue.flush()
                queue.result(t)

        t = threading.Thread(target=reader)
        t.start()
        try:
            for i in range(8):
                bt = queue.submit_ingest(
                    fresh[i * 32: (i + 1) * 32],
                    (90_000 + np.arange(32) + i).astype(np.int64))
                time.sleep(0.004)  # let the deadline timer fire some
                queue.result(bt)
        finally:
            stop.set()
            t.join()
            queue.close()
            pipe.close()
        san.assert_clean()
        # the composition's canonical order was exercised
        assert any(a.startswith("MicroBatchQueue")
                   and b.startswith("EpochPipeline")
                   for (a, b) in san.edges)

    def test_constructed_inversion_caught(self):
        """A deliberate lock-order inversion in the MicroBatchQueue +
        EpochPipeline composition: one thread drives queue -> pipeline
        (flush under queue._lock ingests under pipeline._lock, the
        'slow' fault exercising the injected path), another submits
        INTO the queue while holding the pipeline lock — the reversed
        edge closes the cycle and locksan names it.

        The phases run sequentially: the lock-order graph is about
        ORDER, not overlap, so the potential deadlock is reported from
        a run that got lucky — exactly the point of the sanitizer."""
        idx, keys = _mk_index()
        faults = FaultInjector({("pipeline.ingest", 0): "slow"},
                               slow_s=0.02)
        pipe = EpochPipeline(idx, faults=faults)
        queue = MicroBatchQueue(pipe)
        san = sanitize_serving_stack(queue=queue, pipeline=pipe)

        fresh = _fresh(keys, 64)

        def forward():   # queue._lock -> pipeline._lock
            t = queue.submit_ingest(fresh,
                                    np.arange(64, dtype=np.int64))
            queue.flush()
            queue.result(t)

        def inverted():  # pipeline._lock -> queue._lock
            with pipe._lock:
                t = queue.submit_lookup(keys[:8])
                queue.flush()
                queue.result(t)

        for target in (forward, inverted):
            t = threading.Thread(target=target)
            t.start()
            t.join()

        inv = san.inversions()
        assert inv, san.report()
        names = set().union(*map(set, inv))
        assert any(n.startswith("MicroBatchQueue") for n in names)
        assert any(n.startswith("EpochPipeline") for n in names)
        with pytest.raises(LockOrderInversion):
            san.assert_clean()

    def test_lock_held_methods_verified_at_runtime(self):
        """The static checker trusts `lock-held:` docstrings; locksan
        verifies them — calling a lock-held helper WITHOUT the lock
        from a second thread is flagged."""
        idx, _ = _mk_index(n=2_000)
        queue = MicroBatchQueue(idx)
        san = LockSanitizer()
        san.instrument(queue)
        with queue._lock:
            queue._depth()

        def bad():
            queue._depth()   # documented lock-held, lock NOT held

        t = threading.Thread(target=bad)
        t.start()
        t.join()
        assert any("_lookups" in v or "_ingests" in v
                   for v in san.violations)
