"""Durability (ISSUE 8 tentpole): CRC-framed ingest WAL + snapshot
checkpoints + crash recovery.  Kill-and-restart property tests: a torn
WAL tail at EVERY byte boundary recovers to the exact acked prefix,
both key widths, single-device AND sharded."""

import os
import struct
import zlib

import numpy as np
import pytest

from repro.core import Index
from repro.robustness import FaultInjector, InjectedCrash, InvariantAuditor
from repro.serving import EpochPipeline, IngestWAL, recover_index, replay
from repro.serving.wal import truncate_torn_tail


def _mk_index(n=6_000, seed=0, wide=False, **kw):
    rng = np.random.default_rng(seed)
    hi = 2 ** 46 if wide else 2 ** 20  # wide: beyond f32, pair-exact
    keys = np.unique(rng.choice(hi, n, replace=False)).astype(np.float64)
    keys *= 2.0
    kw.setdefault("method", "pgm")
    kw.setdefault("eps", 64)
    kw.setdefault("gap_rho", 0.2)
    return Index.build(keys, **kw), keys


def _fresh(keys, n):
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    assert mids.size >= n
    return mids[:n]


def _state_equal(a, b):
    ga, gb = a.gapped, b.gapped
    if not (np.array_equal(ga.slot_key, gb.slot_key)
            and np.array_equal(ga.occupied, gb.occupied)
            and np.array_equal(ga.payload[ga.occupied],
                               gb.payload[gb.occupied])):
        return False
    oa, ka, pa = ga.export_csr_links()
    ob, kb, pb = gb.export_csr_links()
    return (np.array_equal(oa, ob) and np.array_equal(ka, kb)
            and np.array_equal(pa, pb))


# ---------------------------------------------------------------------------
# WAL framing


def test_wal_roundtrip_batches_and_fences(tmp_path):
    p = tmp_path / "a.wal"
    keys = np.array([3.0, 1.0, 7.5])
    pays = np.array([30, 10, 75])
    with IngestWAL(p, sync_every=2) as w:
        lsn1 = w.append(keys, pays)
        lsn2 = w.fence(5)
        lsn3 = w.append(keys + 100.0, pays + 100)
        assert lsn1 < lsn2 < lsn3 == w.lsn
        assert w.stats["fences"] == 1 and w.stats["records"] == 3
    recs, valid_end, torn = replay(p)
    assert not torn and valid_end == lsn3
    assert [r.kind for r in recs] == ["batch", "fence", "batch"]
    np.testing.assert_array_equal(recs[0].keys, keys)
    np.testing.assert_array_equal(recs[0].payloads, pays)
    assert recs[1].epoch == 5
    np.testing.assert_array_equal(recs[2].keys, keys + 100.0)
    assert recs[0].lsn == lsn1 and recs[2].lsn == lsn3


def test_wal_append_shape_mismatch_raises(tmp_path):
    with IngestWAL(tmp_path / "a.wal") as w:
        with pytest.raises(ValueError, match="1:1"):
            w.append(np.array([1.0, 2.0]), np.array([1]))


def test_wal_missing_file_is_empty_log(tmp_path):
    recs, valid_end, torn = replay(tmp_path / "nope.wal")
    assert recs == [] and valid_end == 0 and not torn


def test_wal_flipped_bit_is_caught_by_crc(tmp_path):
    p = tmp_path / "a.wal"
    with IngestWAL(p) as w:
        w.append(np.array([1.0, 2.0]), np.array([1, 2]))
        end1 = w.append(np.array([3.0]), np.array([3]))
    raw = bytearray(p.read_bytes())
    raw[end1 - 10] ^= 0x40  # flip one bit inside record 2's body
    p.write_bytes(bytes(raw))
    recs, valid_end, torn = replay(p)
    assert torn and len(recs) == 1 and valid_end < end1


def test_wal_truncate_torn_tail_then_append(tmp_path):
    p = tmp_path / "a.wal"
    with IngestWAL(p) as w:
        w.append(np.array([1.0]), np.array([1]))
        end1 = w.lsn
        w.append(np.array([2.0]), np.array([2]))
    with open(p, "r+b") as f:  # torn mid-record
        f.truncate(end1 + 9)
    assert truncate_torn_tail(p) == 9
    assert truncate_torn_tail(p) == 0  # idempotent on a clean log
    with IngestWAL(p) as w:
        w.append(np.array([5.0]), np.array([5]))
    recs, _, torn = replay(p)
    assert not torn and len(recs) == 2
    assert recs[1].keys[0] == 5.0


# ---------------------------------------------------------------------------
# snapshot + replay recovery, single-device


@pytest.mark.parametrize("wide", [False, True])
def test_recover_equals_uninterrupted_run(tmp_path, wide):
    idx, keys = _mk_index(wide=wide)
    wal = IngestWAL(tmp_path / "ingest.wal")
    pipe = EpochPipeline(idx, wal=wal)
    fresh = _fresh(keys, 600)
    b1, b2, b3 = fresh[:200], fresh[200:400], fresh[400:]
    pipe.ingest(b1, np.arange(200, dtype=np.int64))
    pipe.publish()
    pipe.checkpoint(tmp_path / "ckpt", step=0)  # snapshot at lsn(b1)
    pipe.ingest(b2, 200 + np.arange(200, dtype=np.int64))
    pipe.ingest(b3, 400 + np.arange(200, dtype=np.int64))
    pipe.publish()
    wal.sync()

    rec, info = recover_index(tmp_path / "ckpt", tmp_path / "ingest.wal")
    assert info["skipped"] == 1          # b1 folded into the snapshot
    assert info["replayed"] == 2 and not info["torn"]
    assert _state_equal(rec, idx)
    assert rec.epoch == idx.epoch
    res = rec.lookup(fresh)
    np.testing.assert_array_equal(res.payloads, np.arange(600))
    pipe.close()


@pytest.mark.parametrize("wide", [False, True])
@pytest.mark.parametrize("sharded", [False, True])
def test_kill_at_every_byte_boundary_recovers_acked_prefix(
        tmp_path, wide, sharded):
    """THE crash-safety property: tear the WAL at EVERY byte offset
    past the checkpoint; recovery must reproduce exactly the acked
    (fully logged) batches — never a partial batch, never a lost acked
    one — for narrow and wide keys, single-device and sharded."""
    kw = {"shards": 2} if sharded else {}
    idx, keys = _mk_index(n=3_000, wide=wide, **kw)
    wal_path = tmp_path / "ingest.wal"
    wal = IngestWAL(wal_path)
    pipe = EpochPipeline(idx, wal=wal)
    pipe.checkpoint(tmp_path / "ckpt", step=0)
    base_lsn = wal.lsn

    fresh = _fresh(keys, 24)
    batches = [(fresh[i * 8:(i + 1) * 8],
                (100 * (i + 1) + np.arange(8)).astype(np.int64))
               for i in range(3)]
    ends = []
    for bk, bp in batches:
        pipe.ingest(bk, bp)
        ends.append(wal.lsn)
    wal.sync()
    raw = wal_path.read_bytes()

    # reference states: acked prefix of 0, 1, 2, 3 batches
    refs = []
    for upto in range(4):
        r, _ = Index.restore(tmp_path / "ckpt") if not sharded else \
            __import__("repro.dist.sharded", fromlist=["ShardedIndex"]
                       ).ShardedIndex.restore(tmp_path / "ckpt")
        for bk, bp in batches[:upto]:
            r.ingest(bk, bp)
        refs.append(r)

    aud = InvariantAuditor()
    torn_path = tmp_path / "torn.wal"
    for cut in range(base_lsn, len(raw) + 1):
        torn_path.write_bytes(raw[:cut])
        rec, info = recover_index(tmp_path / "ckpt", torn_path)
        n_acked = sum(e <= cut for e in ends)
        assert info["replayed"] == n_acked, f"cut={cut}"
        assert info["torn"] == (cut not in ([base_lsn] + ends)), \
            f"cut={cut}"
        want = refs[n_acked]
        if sharded:
            for sa, sb in zip(rec.shards, want.shards):
                assert _state_equal(sa, sb), f"cut={cut}"
        else:
            assert _state_equal(rec, want), f"cut={cut}"
        aud.assert_ok(rec)
    pipe.close()


def test_recovery_is_idempotent_under_double_replay(tmp_path):
    """Records at or below the checkpoint's wal_lsn are skipped — a
    checkpoint taken mid-log never double-applies its own history."""
    idx, keys = _mk_index(n=3_000)
    wal = IngestWAL(tmp_path / "w.wal")
    pipe = EpochPipeline(idx, wal=wal)
    fresh = _fresh(keys, 30)
    pipe.ingest(fresh[:10], np.arange(10, dtype=np.int64))
    pipe.ingest(fresh[10:20], 10 + np.arange(10, dtype=np.int64))
    pipe.checkpoint(tmp_path / "ckpt", step=0)
    pipe.ingest(fresh[20:], 20 + np.arange(10, dtype=np.int64))
    wal.sync()
    rec, info = recover_index(tmp_path / "ckpt", tmp_path / "w.wal")
    assert info["skipped"] == 2 and info["replayed"] == 1
    assert _state_equal(rec, idx)
    pipe.close()


def test_sharded_checkpoint_restores_router_and_mutations(tmp_path):
    from repro.dist.sharded import ShardedIndex

    idx, keys = _mk_index(n=9_000, shards=3)
    fresh = _fresh(keys, 500)
    idx.ingest(fresh, np.arange(500, dtype=np.int64))
    idx.maybe_rebalance(force_shard=0)
    idx.save_snapshot(tmp_path / "ckpt", step=7, wal_lsn=123)
    rec, extra = ShardedIndex.restore(tmp_path / "ckpt")
    assert extra["wal_lsn"] == 123 and extra["step"] == 7
    assert rec.epoch == idx.epoch
    assert len(rec.shards) == len(idx.shards)
    np.testing.assert_array_equal(rec.router.bounds, idx.router.bounds)
    q = np.concatenate([keys[::17], fresh[::7]])
    a, b = rec.lookup(q), idx.lookup(q)
    np.testing.assert_array_equal(a.payloads, b.payloads)
    np.testing.assert_array_equal(a.slots, b.slots)
    np.testing.assert_array_equal(a.found, b.found)


def test_kill_and_restart_mid_pipeline_via_injected_crash(tmp_path):
    """End-to-end kill-and-restart: a scheduled crash fires mid-stream;
    the 'restarted process' recovers from snapshot + WAL and continues
    ingesting — final state equals a never-crashed run."""
    idx, keys = _mk_index(n=4_000)
    inj = FaultInjector({("pipeline.ingest", 2): "crash"})
    wal = IngestWAL(tmp_path / "w.wal")
    pipe = EpochPipeline(idx, wal=wal, faults=inj)
    pipe.checkpoint(tmp_path / "ckpt", step=0)
    fresh = _fresh(keys, 40)
    seqs = [(fresh[i * 10:(i + 1) * 10],
             (1000 * (i + 1) + np.arange(10)).astype(np.int64))
            for i in range(4)]
    done = []
    with pytest.raises(InjectedCrash):
        for bk, bp in seqs:
            pipe.ingest(bk, bp)
            done.append((bk, bp))
    assert len(done) == 2  # third ingest died BEFORE logging/applying
    wal.close()

    # "restart": recover, then run the remaining batches
    rec, info = recover_index(tmp_path / "ckpt", tmp_path / "w.wal")
    assert info["replayed"] == 2 and not info["torn"]
    wal2 = IngestWAL(tmp_path / "w.wal")  # safe append post-recovery
    pipe2 = EpochPipeline(rec, wal=wal2)
    for bk, bp in seqs[2:]:
        pipe2.ingest(bk, bp)
    pipe2.publish()

    ref, _ = _mk_index(n=4_000)
    for bk, bp in seqs:
        ref.ingest(bk, bp)
    assert _state_equal(rec, ref)
    res = pipe2.lookup(fresh)
    assert res.found.all()
    pipe2.close()


def test_save_restore_preserves_mechanism_and_lookups(tmp_path):
    idx, keys = _mk_index(n=5_000, method="fiting")
    fresh = _fresh(keys, 64)
    idx.ingest(fresh, np.arange(64, dtype=np.int64))
    idx.save_snapshot(tmp_path / "ckpt", step=3, wal_lsn=999,
                      extra={"note": "x"})
    rec, extra = Index.restore(tmp_path / "ckpt")
    assert extra["wal_lsn"] == 999 and extra["method"] == "fiting"
    assert rec.method == "fiting"
    assert rec.epoch == idx.epoch
    assert _state_equal(rec, idx)
    q = np.concatenate([keys[::11], fresh, fresh + 1.0])
    a, b = rec.lookup(q), idx.lookup(q)
    np.testing.assert_array_equal(a.payloads, b.payloads)
    np.testing.assert_array_equal(a.found, b.found)
    # the restored handle keeps ingesting (mechanism unpickled live)
    more = _fresh(keys, 128)[64:]
    rec.ingest(more, np.arange(more.size, dtype=np.int64))
    assert rec.lookup(more).found.all()


# ---------------------------------------------------------------------------
# ISSUE 10 satellite: load-adaptive group commit (sync_every="adaptive")


def test_wal_adaptive_idle_syncs_every_record(tmp_path):
    """Sparse writers get per-record durability: an inter-write gap
    above ``idle_s`` fsyncs on the spot (nothing to amortize into)."""
    import time

    wal = IngestWAL(tmp_path / "a.wal", sync_every="adaptive",
                    idle_s=0.0005)
    for i in range(5):
        wal.append([float(2 * i)], [i])
        time.sleep(0.003)              # gap >> idle_s: disk is idle
    assert wal.stats["idle_syncs"] == 5
    assert wal.stats["records"] == 5
    wal.close()


def test_wal_adaptive_burst_batches_syncs(tmp_path):
    """A write storm pays O(elapsed / burst_window) fsyncs, not one per
    record — and every record is still OS-flushed (replayable) before
    any sync happens."""
    wal = IngestWAL(tmp_path / "b.wal", sync_every="adaptive",
                    idle_s=10.0, burst_window_s=1.0)
    n = 200
    for i in range(n):
        wal.append([float(2 * i)], [i])
    assert wal.stats["records"] == n
    # first record sees the idle boot gap; the burst amortizes the rest
    assert wal.stats["syncs"] <= 2
    recs, _, torn = replay(wal.path)   # pre-close: flushed, parseable
    assert len(recs) == n and not torn
    wal.close()


def test_wal_adaptive_window_sync_under_sustained_burst(tmp_path):
    """A sustained burst longer than ``burst_window_s`` crosses the
    window and time-batched syncs fire."""
    import time

    wal = IngestWAL(tmp_path / "w.wal", sync_every="adaptive",
                    idle_s=10.0, burst_window_s=0.02)
    for i in range(20):
        wal.append([float(2 * i)], [i])
        time.sleep(0.005)              # < idle_s: still "a burst"
    assert wal.stats["window_syncs"] >= 1
    assert wal.stats["idle_syncs"] <= 1    # only the boot gap
    wal.close()


def test_wal_adaptive_framing_byte_identical_to_fixed(tmp_path):
    """Only fsync CADENCE changes under adaptive group commit: the same
    records produce byte-identical files, so every kill-at-any-byte
    recovery property proven for the fixed mode transfers verbatim."""
    rng = np.random.default_rng(5)
    batches = [(np.sort(rng.choice(2 ** 20, 16, replace=False)
                        ).astype(np.float64) * 2.0,
                (100 * i + np.arange(16)).astype(np.int64))
               for i in range(6)]
    wf = IngestWAL(tmp_path / "fixed.wal", sync_every=3)
    wa = IngestWAL(tmp_path / "adaptive.wal", sync_every="adaptive")
    for k, p in batches:
        wf.append(k, p)
        wa.append(k, p)
    wf.fence(1)
    wa.fence(1)
    wf.close()
    wa.close()
    fixed = (tmp_path / "fixed.wal").read_bytes()
    adaptive = (tmp_path / "adaptive.wal").read_bytes()
    assert fixed == adaptive
    # and a torn adaptive tail still recovers the acked prefix cleanly
    torn_path = tmp_path / "torn.wal"
    torn_path.write_bytes(adaptive[:-11])
    recs, _, torn = replay(torn_path)
    assert torn and len(recs) == 6     # fence torn off, batches intact
    assert all(r.kind == "batch" for r in recs)


def test_wal_concurrent_append_interleaves_whole_records(tmp_path):
    """Regression for the WAL lock: concurrent appenders (caller +
    deadline-timer threads in serving) must interleave whole framed
    records — replay sees every record, valid CRCs, no torn middle."""
    import threading

    wal = IngestWAL(tmp_path / "c.wal", sync_every="adaptive")

    def writer(tid):
        for i in range(50):
            wal.append([float(2 * (tid * 1_000 + i))], [tid * 1_000 + i])

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wal.close()
    recs, _, torn = replay(tmp_path / "c.wal")
    assert not torn and len(recs) == 200
    got = sorted(int(r.payloads[0]) for r in recs)
    assert got == sorted(t * 1_000 + i for t in range(4)
                         for i in range(50))
