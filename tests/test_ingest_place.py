"""Device-side §5.3 ingest placement + per-key contested demotion.

Three contracts (the hypothesis property versions live in
test_ingest_place_props.py, importorskip-guarded like the other
suites; these deterministic companions always run):

* the per-key demotion partition is STATE-identical to sequential
  ``insert()`` on adversarial shared-run batches;
* the device ingest-place backend (fused-XLA and the Pallas kernel in
  interpret mode) is bit-identical to the host oracle
  ``GappedArray.placement_primitives`` after the O(#escapes) patch;
* the ``IngestReport`` count invariant (slot + chain == n, contested ==
  replay-visited <= n) holds across recursive contested rounds.
"""

import copy

import numpy as np
import pytest

from conftest import make_keys
from repro.core import Index, LearnedIndex
from repro.kernels.ops_gap import ingest_place


def _state_equal(g1, g2):
    return (np.array_equal(g1.slot_key, g2.slot_key)
            and np.array_equal(g1.occupied, g2.occupied)
            and np.array_equal(g1.payload, g2.payload)
            and g1.n_keys == g2.n_keys
            and dict(g1.links) == dict(g2.links))


# ---------------------------------------------------------------------------
# per-key demotion == sequential insert() on adversarial shared-run batches
# ---------------------------------------------------------------------------


def test_count_invariant_across_recursive_rounds():
    """Force the recursive contested branch (1024 < contested < n) and
    check the invariant composes over rounds: one run crowded with
    collision groups (all contested) + a well-spread easy remainder."""
    rng = np.random.default_rng(7)
    init = np.arange(0, 4_000_000, 1000, dtype=np.float64)  # sparse
    idx = LearnedIndex.build(init, method="pgm", eps=32, gap_rho=0.3)
    # ~3000 keys crammed into a handful of runs -> contested via
    # crowding; plus ~3000 spread keys -> slot-easy
    crowded = np.unique(rng.choice(np.arange(1, 4000, dtype=np.float64),
                                   3000, replace=False)) + 0.5
    spread = np.setdiff1d(
        rng.choice(4_000_000, 4000, replace=False).astype(np.float64),
        np.concatenate([init, crowded]))[:3000]
    batch = np.concatenate([crowded, spread])
    batch = batch[rng.permutation(batch.size)]
    seq = copy.deepcopy(idx)
    pay = np.arange(batch.size)
    for i, k in enumerate(batch):
        seq.insert(float(k), int(pay[i]))
    counts = idx.insert_batch(batch, pay)
    assert counts["slot"] + counts["chain"] == batch.size
    assert 0 <= counts["contested"] <= batch.size
    assert counts["contested"] >= 1  # the crowded runs really contested
    assert _state_equal(seq.gapped, idx.gapped)
    # and the typed report enforces it
    from repro.core.results import IngestReport
    with pytest.raises(AssertionError):
        IngestReport(n=10, slot=5, chain=6, contested=0, epoch=0)
    with pytest.raises(AssertionError):
        IngestReport(n=10, slot=5, chain=5, contested=11, epoch=0)


def test_delete_batch_flushes_pending_overlay():
    """delete_batch owns its flush (same semantics as insert_batch) —
    buffered scalar chain inserts must not bill the next reader."""
    x = make_keys("iot", 6_000, seed=3)
    idx = LearnedIndex.build(x, method="pgm", eps=64, gap_rho=0.1)
    rng = np.random.default_rng(3)
    mids = np.setdiff1d(x[:-1] + np.diff(x) * 0.5, x)[:400]
    for i, k in enumerate(mids):  # scalar path: buffers in the overlay
        idx.insert(float(k), 1000 + i)
    ga = idx.gapped
    assert ga.links._pend_n > 0  # the overlay really is pending
    removed = ga.delete_batch(rng.choice(x, 200, replace=False))
    assert removed == 200
    assert ga.links._pend_n == 0  # flushed by THIS batch, not a reader


# ---------------------------------------------------------------------------
# device ingest placement: bit-identity with the host oracle
# ---------------------------------------------------------------------------


def _mids(keys, rng, n):
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    return rng.permutation(mids)[:n]


@pytest.mark.parametrize("width,method", [
    (2 ** 22, "pgm"), (2 ** 40, "pgm"), (2 ** 22, "fiting"),
])
def test_device_placements_bit_identical(width, method):
    rng = np.random.default_rng(0)
    keys = np.unique(rng.choice(width, 25_000, replace=False)
                     ).astype(np.float64)
    idx = Index.build(keys, method=method, eps=64, gap_rho=0.2)
    idx.sync_device()
    # one partition chunk (4096 floor) — bigger batches are split and
    # re-derived host-side past the first chunk, so the handle gates
    # the device path on batch_chunk()
    batch = _mids(keys, rng, 4_000)
    prims = idx._device_placements(batch)
    assert prims is not None  # pair-exact integer keys: device serves
    host = idx.gapped.placement_primitives(batch)
    for f in prims:
        assert np.array_equal(prims[f], host[f]), f
    # end state: device-placed ingest == host-partition insert_batch
    other = copy.deepcopy(idx)
    rep = idx.ingest(batch, 1_000_000 + np.arange(batch.size))
    assert rep.placement == "device"
    assert rep.slot + rep.chain == rep.n
    other.gapped.insert_batch(batch, 1_000_000 + np.arange(batch.size))
    assert _state_equal(idx.gapped, other.gapped)


def test_pallas_ingest_place_matches_fused_xla():
    """The Pallas kernel (interpret mode on CPU) and the fused-XLA
    variant run ONE shared body — bit-identical outputs, incl. the
    escape mask."""
    rng = np.random.default_rng(1)
    keys = np.unique(rng.choice(2 ** 40, 20_000, replace=False)
                     ).astype(np.float64)
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.25)
    idx.sync_device()
    batch = _mids(keys, rng, 4_000)
    px, ex = ingest_place(idx._engine.arrays, batch, impl="xla")
    pp, ep = ingest_place(idx._engine.arrays, batch, impl="pallas",
                          interpret=True, key_tile=256)
    for f in px:
        assert np.array_equal(px[f], pp[f]), f
    assert np.array_equal(ex, ep)


def test_device_placement_gates():
    """Stale device epoch / non-PLM predict / tiny batches fall back to
    the host oracle (placement == 'host'), never to wrong primitives."""
    rng = np.random.default_rng(2)
    keys = np.unique(rng.choice(2 ** 22, 20_000, replace=False)
                     ).astype(np.float64)
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    batch = _mids(keys, rng, 2_000)
    # no engine yet -> host
    assert idx._device_placements(batch) is None
    rep = idx.ingest(batch, np.arange(batch.size))
    assert rep.placement == "host" and rep.slot + rep.chain == rep.n
    # engine frozen at the current epoch -> device serves the next batch
    batch2 = _mids(np.sort(np.concatenate([keys, batch])), rng, 2_000)
    idx.sync_device()
    assert idx.device_epoch == idx.epoch
    rep2 = idx.ingest(batch2, np.arange(batch2.size))
    assert rep2.placement == "device"
    # scalar mutation leaves the device stale -> host again
    more = _mids(np.sort(np.concatenate(
        [keys, batch, batch2])), rng, 1_500)
    idx.insert(float(more[0]), 7)
    assert idx._device_placements(more[1:]) is None
    # rmi's predict is not its exported plm -> never device-placed
    idx_rmi = Index.build(keys, method="rmi", n_leaf=64, gap_rho=0.2)
    idx_rmi.sync_device()
    assert idx_rmi._device_placements(batch) is None
