"""repro-lint analyzer tests: per-rule fixtures (true positive +
suppressed + clean), suppression syntax, the CLI, and the dogfood
guarantee that the repo itself lints clean."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.core import (default_checkers, lint_paths,
                                 lint_source, parse_suppressions)

REPO = Path(__file__).resolve().parents[1]


def run_lint(source, path="src/repro/core/gaps_fixture.py", rules=None):
    src = textwrap.dedent(source)
    findings = lint_source(src, path, default_checkers(), rules=rules)
    return [f for f in findings if not f.suppressed]


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# epoch-bump
# ---------------------------------------------------------------------------

EPOCH_BAD = """
class GappedArray:
    def _invalidate(self):
        self.version += 1

    def clobber(self, i, key):
        self.slot_key[i] = key
"""

EPOCH_GOOD = """
class GappedArray:
    def _invalidate(self):
        self.version += 1

    def clobber(self, i, key):
        self._invalidate()
        self.slot_key[i] = key
"""

EPOCH_MARKED = """
class GappedArray:
    def _clobber_inner(self, i, key):
        \"\"\"caller-invalidates: clobber() bumps first.\"\"\"
        self.slot_key[i] = key
"""


class TestEpochBump:
    def test_true_positive(self):
        fs = run_lint(EPOCH_BAD)
        assert "epoch-bump" in rules_of(fs)
        assert any("clobber" in f.message for f in fs)

    def test_bump_evidence_is_clean(self):
        assert not run_lint(EPOCH_GOOD)

    def test_caller_invalidates_marker_is_clean(self):
        assert not run_lint(EPOCH_MARKED)

    def test_version_write_counts_as_evidence(self):
        # the retrain idiom: replace arrays, bump .version directly
        src = """
        class Index:
            def retrain(self):
                old = self.epoch
                new = build()
                new.gapped.version = old + 1
                self.gapped = new.gapped
        """
        assert not run_lint(src, path="src/repro/core/handle_fixture.py")

    def test_suppression(self):
        src = EPOCH_BAD.replace(
            "self.slot_key[i] = key",
            "self.slot_key[i] = key  "
            "# repro-lint: disable=epoch-bump -- test waiver")
        assert not run_lint(src)


# ---------------------------------------------------------------------------
# snapshot-mutate
# ---------------------------------------------------------------------------

SNAP_BAD = """
class GapSnapshot:
    def poke(self, x):
        self.n_keys = x
"""

PIN_BAD = """
def serve(index):
    snap = index.gapped.pin_snapshot()
    snap.epoch = 0
    return snap
"""


class TestSnapshotMutate:
    def test_method_mutation(self):
        fs = run_lint(SNAP_BAD)
        assert rules_of(fs) == {"snapshot-mutate"}

    def test_allowed_methods_clean(self):
        src = """
        class GapSnapshot:
            def release(self):
                self._cell = None
        """
        assert not run_lint(src)

    def test_pinned_name_mutation(self):
        fs = run_lint(PIN_BAD)
        assert rules_of(fs) == {"snapshot-mutate"}

    def test_suppressed(self):
        src = PIN_BAD.replace(
            "snap.epoch = 0",
            "snap.epoch = 0  # repro-lint: disable=snapshot-mutate -- x")
        assert not run_lint(src)


# ---------------------------------------------------------------------------
# trace-safety rules
# ---------------------------------------------------------------------------

TRACE_FIXTURE = "src/repro/kernels/lint_fixture.py"

HOST_SYNC_BAD = """
import jax, numpy as np

@jax.jit
def f(x):
    return np.asarray(x) + 1
"""

PY_BRANCH_BAD = """
import jax

@jax.jit
def f(x):
    if x > 0:
        return x
    return -x
"""

SELF_CAPTURE_BAD = """
import jax

class K:
    def build(self):
        def kern(x):
            return x + self.offset
        return jax.jit(kern)
"""

DYN_SHAPE_BAD = """
import jax, jax.numpy as jnp

@jax.jit
def f(n):
    return jnp.arange(n)
"""

STATIC_THREADING_OK = """
import functools, jax, jax.numpy as jnp

def helper(x, flag):
    if flag:
        return x * 2
    return x

@functools.partial(jax.jit, static_argnames=("flag",))
def f(x, flag):
    return helper(x, flag)
"""

CALLBACK_TAINTED = """
import jax

@jax.jit
def f(x, n):
    def body(i, c):
        if c > 0:
            return c
        return c + 1
    return jax.lax.fori_loop(0, 3, body, x)
"""


class TestTraceSafety:
    def test_host_sync(self):
        fs = run_lint(HOST_SYNC_BAD, path=TRACE_FIXTURE)
        assert "trace-host-sync" in rules_of(fs)

    def test_py_branch(self):
        fs = run_lint(PY_BRANCH_BAD, path=TRACE_FIXTURE)
        assert "trace-py-branch" in rules_of(fs)

    def test_self_capture(self):
        fs = run_lint(SELF_CAPTURE_BAD, path=TRACE_FIXTURE)
        assert "trace-self-capture" in rules_of(fs)

    def test_dynamic_shape(self):
        fs = run_lint(DYN_SHAPE_BAD, path=TRACE_FIXTURE)
        assert "trace-dynamic-shape" in rules_of(fs)

    def test_static_flag_threaded_through_helper_is_clean(self):
        # interprocedural: `flag` is static at the root, so branching
        # on it inside the helper is fine (the key_wide idiom)
        assert not run_lint(STATIC_THREADING_OK, path=TRACE_FIXTURE)

    def test_callback_params_are_tainted(self):
        # a fori_loop body's carry IS traced even though the body is
        # never called directly
        fs = run_lint(CALLBACK_TAINTED, path=TRACE_FIXTURE)
        assert "trace-py-branch" in rules_of(fs)

    def test_shape_access_cuts_taint(self):
        src = """
        import jax, numpy as np

        @jax.jit
        def f(x):
            trips = int(np.log2(max(x.shape[0], 2)))
            return x * trips
        """
        assert not run_lint(src, path=TRACE_FIXTURE)

    def test_is_none_test_is_static(self):
        src = """
        import jax

        @jax.jit
        def f(x, t=None):
            if t is not None:
                return x + t
            return x
        """
        assert not run_lint(src, path=TRACE_FIXTURE)

    def test_outside_kernels_not_checked(self):
        fs = run_lint(PY_BRANCH_BAD, path="src/repro/core/other.py")
        assert "trace-py-branch" not in rules_of(fs)

    def test_suppressed(self):
        src = PY_BRANCH_BAD.replace(
            "    if x > 0:",
            "    # repro-lint: disable=trace-py-branch -- test waiver\n"
            "    if x > 0:")
        assert not run_lint(src, path=TRACE_FIXTURE)


# ---------------------------------------------------------------------------
# guarded-by
# ---------------------------------------------------------------------------

GUARDED_BAD = """
import threading

class Q:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []   #: guarded-by: _lock

    def pop(self):
        return self._items.pop()
"""

GUARDED_WITH = """
import threading

class Q:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []   #: guarded-by: _lock

    def pop(self):
        with self._lock:
            return self._items.pop()
"""

GUARDED_DOC = """
import threading

class Q:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []   #: guarded-by: _lock

    def pop(self):
        \"\"\"lock-held: _lock\"\"\"
        return self._items.pop()
"""

GUARDED_NESTED_DEF = """
import threading

class Q:
    def __init__(self):
        self._lock = threading.Lock()
        self._items = []   #: guarded-by: _lock

    def pop(self):
        with self._lock:
            def later():
                return self._items.pop()
            return later
"""


class TestGuardedBy:
    def test_unguarded_access(self):
        fs = run_lint(GUARDED_BAD)
        assert rules_of(fs) == {"guarded-by"}

    def test_with_lock_clean(self):
        assert not run_lint(GUARDED_WITH)

    def test_lock_held_doc_clean(self):
        assert not run_lint(GUARDED_DOC)

    def test_nested_def_does_not_inherit_held(self):
        # the closure runs later, on an unknown thread
        fs = run_lint(GUARDED_NESTED_DEF)
        assert rules_of(fs) == {"guarded-by"}

    def test_annotation_line_above(self):
        src = GUARDED_BAD.replace(
            "        self._items = []   #: guarded-by: _lock",
            "        #: guarded-by: _lock\n        self._items = []")
        assert rules_of(run_lint(src)) == {"guarded-by"}

    def test_suppressed(self):
        src = GUARDED_BAD.replace(
            "        return self._items.pop()",
            "        return self._items.pop()  "
            "# repro-lint: disable=guarded-by -- single-threaded path")
        assert not run_lint(src)


# ---------------------------------------------------------------------------
# pair-exactness
# ---------------------------------------------------------------------------

PAIR_FIXTURE = "src/repro/kernels/gap_place_fixture.py"

PAIR_F64_BAD = """
import jax, jax.numpy as jnp

@jax.jit
def f(key_hi, key_lo):
    return key_hi.astype(jnp.float64) + key_lo
"""

PAIR_FMA_BAD = """
import jax

@jax.jit
def f(slope, dx, icept):
    return slope * dx + icept
"""

PAIR_EFT_OK = """
import jax

def _two_sum(a, b):
    s = a + b
    t = s - a
    return s, (a - (s - t)) + (b - t)

@jax.jit
def f(key_hi, key_lo):
    s, e = _two_sum(key_hi, key_lo)
    return s
"""


class TestPairExact:
    def test_float64(self):
        fs = run_lint(PAIR_F64_BAD, path=PAIR_FIXTURE)
        assert "pair-float64" in rules_of(fs)

    def test_raw_fma(self):
        fs = run_lint(PAIR_FMA_BAD, path=PAIR_FIXTURE)
        assert "pair-raw-fma" in rules_of(fs)

    def test_eft_primitives_exempt(self):
        assert not run_lint(PAIR_EFT_OK, path=PAIR_FIXTURE)

    def test_non_pairish_names_clean(self):
        src = PAIR_FMA_BAD.replace("slope", "a").replace(
            "dx", "b").replace("icept", "c")
        assert not run_lint(src, path=PAIR_FIXTURE)

    def test_only_kernel_pair_files_checked(self):
        fs = run_lint(PAIR_FMA_BAD, path="src/repro/core/handle2.py")
        assert "pair-raw-fma" not in rules_of(fs)

    def test_suppressed(self):
        src = PAIR_FMA_BAD.replace(
            "    return slope * dx + icept",
            "    # repro-lint: disable=pair-raw-fma -- test waiver\n"
            "    return slope * dx + icept")
        assert not run_lint(src, path=PAIR_FIXTURE)


# ---------------------------------------------------------------------------
# suppression machinery + framework
# ---------------------------------------------------------------------------

class TestSuppressions:
    def test_parse_same_line_and_above(self):
        comments, line, file_ = parse_suppressions(
            "x = 1  # repro-lint: disable=a,b -- why\n"
            "# repro-lint: disable-file=c\n")
        assert line[1] == {"a", "b"}
        assert file_ == {"c"}

    def test_disable_all(self):
        src = EPOCH_BAD + "\n# repro-lint: disable-file=all\n"
        assert not run_lint(src)

    def test_suppressed_findings_still_counted(self):
        src = EPOCH_BAD.replace(
            "self.slot_key[i] = key",
            "self.slot_key[i] = key  # repro-lint: disable=epoch-bump -- x")
        all_f = lint_source(textwrap.dedent(src),
                            "src/repro/core/gaps_fixture.py",
                            default_checkers())
        assert [f for f in all_f if f.suppressed]

    def test_rules_filter(self):
        fs = run_lint(EPOCH_BAD, rules=["guarded-by"])
        assert not fs

    def test_syntax_error_is_a_finding(self):
        fs = run_lint("def broken(:\n")
        assert rules_of(fs) == {"parse-error"}


class TestCLI:
    def _run(self, *args):
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            capture_output=True, text=True, cwd=str(REPO),
            env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})

    def test_repo_is_clean(self):
        # THE dogfood guarantee: the analyzer passes on its own repo
        p = self._run("src", "tests")
        assert p.returncode == 0, p.stdout + p.stderr

    def test_violation_fixture_fails(self, tmp_path):
        bad = tmp_path / "src" / "repro" / "core" / "gaps_fixture.py"
        bad.parent.mkdir(parents=True)
        bad.write_text(textwrap.dedent(EPOCH_BAD))
        p = self._run(str(bad))
        assert p.returncode == 1
        assert "epoch-bump" in p.stdout

    def test_json_output(self, tmp_path):
        bad = tmp_path / "guard_fixture.py"
        bad.write_text(textwrap.dedent(GUARDED_BAD))
        p = self._run("--json", str(bad))
        data = json.loads(p.stdout)
        assert data["findings"][0]["rule"] == "guarded-by"

    def test_list_rules(self):
        p = self._run("--list-rules")
        out = p.stdout
        for rule in ("epoch-bump", "snapshot-mutate", "trace-host-sync",
                     "guarded-by", "pair-raw-fma"):
            assert rule in out
        assert p.returncode == 0


class TestLintPaths:
    def test_walks_directories(self, tmp_path):
        d = tmp_path / "pkg"
        d.mkdir()
        (d / "ok.py").write_text("x = 1\n")
        (d / "bad_fixture.py").write_text(textwrap.dedent(GUARDED_BAD))
        findings = lint_paths([str(d)], default_checkers())
        assert any(f.rule == "guarded-by" for f in findings)

    def test_seeded_fixtures_per_rule_all_detected(self, tmp_path):
        seeds = {
            "epoch-bump": ("core/f1_fixture.py", EPOCH_BAD),
            "snapshot-mutate": ("core/f2_fixture.py", SNAP_BAD),
            "trace-py-branch": ("kernels/f3_fixture.py", PY_BRANCH_BAD),
            "guarded-by": ("core/f4_fixture.py", GUARDED_BAD),
            "pair-raw-fma": ("kernels/f5_fixture.py", PAIR_FMA_BAD),
        }
        for rule, (rel, src) in seeds.items():
            p = tmp_path / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(textwrap.dedent(src))
        findings = lint_paths([str(tmp_path)], default_checkers())
        assert set(seeds) <= {f.rule for f in findings}
