"""Online retrain (§4 applied live) + the sampled-build bit-identity
claim: a sampled-then-refinalized build must ANSWER like the full
build, across mechanisms, key widths, and through ``retrain()`` under
the epoch pipeline.  The randomized hypothesis property over the same
checker lives in test_retrain_props.py (optional dep)."""

import numpy as np
import pytest

from conftest import make_keys
from repro.core import Index
from repro.serving import EpochPipeline


def _int_keys(seed: int, n: int, wide: bool) -> np.ndarray:
    """Sorted unique integer keys; ``wide`` keys exceed 2**24 (the f32
    integer-exactness edge the kernels key-split on), narrow stay under."""
    rng = np.random.default_rng(seed)
    hi = 2 ** 40 if wide else 2 ** 22
    k = np.unique(rng.integers(0, hi, int(n * 1.3), dtype=np.int64))
    k = k[:n].astype(np.float64)
    assert (k.max() > 2 ** 24) == wide
    return k


def _queries(keys: np.ndarray, seed: int):
    """Present keys + guaranteed-absent midpoints + out-of-range probes."""
    rng = np.random.default_rng(seed)
    present = rng.choice(keys, min(2000, len(keys)))
    absent = keys[:-1] + np.diff(keys) / 2.0
    absent = np.setdiff1d(absent, keys)[:500]
    edges = np.array([keys[0] - 7.0, keys[-1] + 7.0])
    return np.concatenate([present, absent, edges])


def _assert_same_answers(a, b):
    assert np.array_equal(np.asarray(a.found), np.asarray(b.found))
    assert np.array_equal(np.asarray(a.payloads), np.asarray(b.payloads))


def check_sampled_build_identity_through_retrain(seed, method, wide, rate):
    """The shared checker (§4 + §5 end-to-end): sampled mechanism
    learning + connect_segments + refinalized bounds answers
    bit-identically to the full-data build — and stays exact through a
    sampled retrain of the live state under the epoch pipeline's pinned
    snapshot.  Driven deterministically below and by hypothesis in
    test_retrain_props.py."""
    keys = _int_keys(seed, 4000, wide)
    q = _queries(keys, seed + 1)
    truth = np.searchsorted(keys, q)
    truth_found = np.isin(q, keys)

    full = Index.build(keys, method=method, eps=32.0, gap_rho=0.2)
    samp = Index.build(keys, method=method, eps=32.0, gap_rho=0.2,
                       sample_rate=rate, rng=np.random.default_rng(seed))
    rf, rs = full.lookup(q), samp.lookup(q)
    _assert_same_answers(rf, rs)
    assert np.array_equal(np.asarray(rf.found), truth_found)
    assert np.array_equal(np.asarray(rf.payloads)[truth_found],
                          truth[truth_found])
    # learning really ran on the sample, not the full data
    assert samp.gapped.build_timings["n_fit"] < len(keys) // 2

    # retrain the LIVE state behind a pinned snapshot: fresh keys go in,
    # the held snapshot must not move, publish serves everything
    pipe = EpochPipeline(samp)
    pre = pipe.lookup(q)
    fresh = np.setdiff1d(keys[:-1] + np.diff(keys) / 4.0, keys)[-64:]
    pipe.ingest(fresh, 40_000_000 + np.arange(len(fresh)))
    pipe.retrain(sample_rate=rate, rng=np.random.default_rng(seed + 2))
    held = pipe.lookup(q)
    assert held.epoch == pre.epoch
    _assert_same_answers(pre, held)
    pipe.publish()
    post = pipe.lookup(q)
    _assert_same_answers(pre, post)
    got_fresh = pipe.lookup(fresh)
    assert np.asarray(got_fresh.found).all()
    assert np.array_equal(np.asarray(got_fresh.payloads),
                          40_000_000 + np.arange(len(fresh)))


@pytest.mark.parametrize("method", ["pgm", "fiting"])
@pytest.mark.parametrize("wide", [False, True])
def test_sampled_build_bit_identical_through_retrain(method, wide):
    check_sampled_build_identity_through_retrain(
        seed=17, method=method, wide=wide, rate=0.05)


def test_retrain_bumps_epoch_and_flattens_chains():
    """Tail-append ingest piles keys onto one chain; a sampled retrain
    relearns the layout and collapses it (the remedy mdl() drift asks
    for), with the epoch strictly monotone."""
    x = make_keys("iot", 20_000, seed=0)
    idx = Index.build(x, method="pgm", eps=64, gap_rho=0.15,
                      rng=np.random.default_rng(0))
    step = float(np.mean(np.diff(x)))
    tail = x[-1] + step * (1.0 + np.arange(600))
    idx.ingest(tail, 1_000_000 + np.arange(600))
    e0 = idx.epoch
    deep = idx.gapped.links.max_chain
    rec = idx.retrain(sample_rate=0.05, rng=np.random.default_rng(1))
    assert idx.epoch == e0 + 1 == rec["epoch"]
    assert rec["n"] == len(x) + 600
    assert idx.gapped.links.max_chain < deep
    assert idx.stats["retrains"] == 1
    # every live key (original + ingested) still answers exactly
    r = idx.lookup(np.concatenate([x, tail]))
    assert np.asarray(r.found).all()
    want = np.concatenate([np.arange(len(x)), 1_000_000 + np.arange(600)])
    assert np.array_equal(np.asarray(r.payloads), want)


def test_retrain_can_switch_mechanism():
    x = make_keys("weblogs", 10_000, seed=2)
    idx = Index.build(x, method="pgm", eps=64, gap_rho=0.15)
    idx.retrain(method="fiting", eps=128.0,
                rng=np.random.default_rng(3))
    assert idx.method == "fiting"
    r = idx.lookup(x[::7])
    assert np.asarray(r.found).all()
    assert np.array_equal(np.asarray(r.payloads),
                          np.searchsorted(x, x[::7]))


def test_retrain_rejects_static_index():
    x = make_keys("iot", 5_000, seed=4)
    idx = Index.build(x, method="pgm", eps=64)  # gap_rho=0: static
    with pytest.raises(NotImplementedError):
        idx.retrain()


def test_sharded_retrain_all_shards_preserves_answers():
    x = make_keys("iot", 24_000, seed=5)
    sharded = Index.build(x, shards=3, method="pgm", eps=64, gap_rho=0.15,
                          rng=np.random.default_rng(5))
    q = np.random.default_rng(6).choice(x, 4000)
    before = sharded.lookup(q)
    e0 = sharded.epoch
    rec = sharded.retrain(sample_rate=0.1, rng=np.random.default_rng(7))
    assert rec["kind"] == "retrain" and len(rec["per_shard"]) == 3
    assert sharded.epoch > e0
    after = sharded.lookup(q)
    _assert_same_answers(before, after)
    assert sharded.stats["retrains"] == 1


def test_sharded_watermark_retrains_unsplittable_shard():
    """A shard past the chain-depth watermark but below the split size
    floor gets a sampled retrain from ``maybe_rebalance`` — splitting
    is not an available remedy there."""
    x = make_keys("iot", 6_000, seed=8)
    sharded = Index.build(x, shards=2, method="pgm", eps=64, gap_rho=0.15,
                          rng=np.random.default_rng(8))
    sharded.min_split_keys = 10 ** 9       # nothing is ever splittable
    sharded.split_chain_depth = 4
    # chain a burst past shard 1's trained domain to exceed the watermark
    step = float(np.mean(np.diff(x)))
    tail = x[-1] + step * (1.0 + np.arange(300))
    sharded.ingest(tail, 2_000_000 + np.arange(300))
    assert any(sh.gapped.links.max_chain > 4 for sh in sharded.shards)
    rec = sharded.maybe_rebalance()
    assert rec is not None and rec["kind"] == "retrain"
    assert sharded.stats["splits"] == 0
    r = sharded.lookup(tail)
    assert np.asarray(r.found).all()
    assert np.array_equal(np.asarray(r.payloads),
                          2_000_000 + np.arange(300))
