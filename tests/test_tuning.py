"""MDL-guided auto-tuner (core/tuning.py): grid scoring, budgets,
``Index.build(method="auto")``, and the per-shard default."""

import numpy as np
import pytest

from conftest import make_keys
from repro.core import Index
from repro.core.tuning import TunedChoice, autotune, default_grid


def test_autotune_returns_grid_winner():
    x = make_keys("iot", 40_000, seed=0)
    choice = autotune(x, rng=np.random.default_rng(0))
    assert isinstance(choice, TunedChoice)
    assert choice.method in {m for m, _ in default_grid(len(x))}
    assert choice.budget_met
    assert 0.0 < choice.sample_rate <= 1.0
    assert choice.hoeffding_eps > 0.0
    # the winner IS the grid minimum among scored candidates
    assert choice.score == min(c["mdl"] for c in choice.candidates)


def test_autotune_dynamic_restricts_to_plm_serving_mechanisms():
    x = make_keys("weblogs", 30_000, seed=1)
    choice = autotune(x, dynamic=True, rng=np.random.default_rng(1))
    assert choice.method in ("pgm", "fiting")
    assert all(c["method"] in ("pgm", "fiting") for c in choice.candidates)


def test_autotune_size_budget_is_hard_filter():
    x = make_keys("iot", 40_000, seed=2)
    free = autotune(x, rng=np.random.default_rng(2))
    sizes = sorted(c["size_bytes"] for c in free.candidates)
    # a budget between the two smallest models: the pick must respect it
    budget = (sizes[0] + sizes[1]) // 2
    tight = autotune(x, size_budget_bytes=budget,
                     rng=np.random.default_rng(2))
    assert tight.budget_met
    assert tight.report.l_model_bytes <= budget
    # an unsatisfiable budget degrades to the smallest model, flagged
    impossible = autotune(x, size_budget_bytes=1,
                          rng=np.random.default_rng(2))
    assert not impossible.budget_met
    assert impossible.report.l_model_bytes == sizes[0]


def test_autotune_alpha_shifts_toward_precision():
    """Large alpha weights the correction term: the pick's correction
    cost must not be worse than the cheap-model pick's (paper §6.2)."""
    x = make_keys("longitude", 40_000, seed=3)
    cheap = autotune(x, alpha=0.01, rng=np.random.default_rng(3))
    precise = autotune(x, alpha=100.0, rng=np.random.default_rng(3))
    assert (precise.report.l_data_given_model
            <= cheap.report.l_data_given_model + 1e-9)


def test_build_auto_single_and_exact():
    x = make_keys("iot", 30_000, seed=4)
    idx = Index.build(x, method="auto", gap_rho=0.15,
                      rng=np.random.default_rng(4))
    assert idx.tuned is not None
    assert idx.method == idx.tuned.method
    assert idx.sample_rate == idx.tuned.sample_rate
    q = np.random.default_rng(5).choice(x, 4000)
    r = idx.lookup(q)
    assert r.found.all()
    assert np.array_equal(np.asarray(r.payloads), np.searchsorted(x, q))


def test_build_auto_static():
    x = make_keys("weblogs", 20_000, seed=6)
    idx = Index.build(x, method="auto")
    q = np.random.default_rng(6).choice(x, 2000)
    r = idx.lookup(q)
    assert r.found.all()


def test_build_auto_explicit_sample_rate_wins():
    x = make_keys("iot", 30_000, seed=7)
    idx = Index.build(x, method="auto", gap_rho=0.15, sample_rate=0.07,
                      rng=np.random.default_rng(7))
    assert idx.sample_rate == 0.07


def test_sharded_auto_per_shard():
    x = make_keys("iot", 30_000, seed=8)
    sharded = Index.build(x, shards=3, method="auto", gap_rho=0.15,
                          rng=np.random.default_rng(8))
    for sh in sharded.shards:
        assert sh.tuned is not None
        assert sh.method in ("pgm", "fiting")  # dynamic grid per shard
    q = np.random.default_rng(9).choice(x, 3000)
    r = sharded.lookup(q)
    assert np.array_equal(np.asarray(r.payloads), np.searchsorted(x, q))


def test_autotune_query_weighting_changes_score():
    """Scoring against a skewed query sample weights L(D|M) by what is
    actually queried, not the uniform key distribution."""
    x = make_keys("iot", 40_000, seed=10)
    hot = x[: len(x) // 50]  # hammer the head of the key space
    uni = autotune(x, rng=np.random.default_rng(10))
    skew = autotune(x, queries=np.random.default_rng(10).choice(hot, 8000),
                    rng=np.random.default_rng(10))
    assert skew.score != pytest.approx(uni.score, rel=1e-12)
