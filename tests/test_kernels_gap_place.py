"""Gap-place Pallas kernel vs the core numpy oracle (Eq. 3)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import make_keys
from repro.core.mechanisms import FITingMechanism, PGMMechanism
from repro.kernels.ops_gap import gap_positions_device, gap_positions_oracle


@pytest.mark.parametrize("mech_cls,kw", [
    (PGMMechanism, dict(eps=64, recursive=False)),
    (FITingMechanism, dict(eps=64)),
])
@pytest.mark.parametrize("rho", [0.05, 0.3])
def test_gap_place_matches_oracle(mech_cls, kw, rho):
    x = make_keys("uniform_int", 20_000, seed=1)
    y = np.arange(len(x), dtype=np.float64)
    plm = mech_cls(**kw).fit(x, y).plm
    dev = gap_positions_device(x, plm, rho, interpret=True)
    ora = gap_positions_oracle(x, plm, rho)
    # f32 kernel vs f64 oracle: relative tolerance on positions
    np.testing.assert_allclose(dev, ora, rtol=2e-5, atol=0.5)
    assert np.all(np.diff(dev) >= 0)


@pytest.mark.parametrize("key_tile,seg_chunk", [(256, 128), (2048, 1024)])
def test_gap_place_tile_sweep(key_tile, seg_chunk):
    x = make_keys("uniform_int", 9_000, seed=2)
    y = np.arange(len(x), dtype=np.float64)
    plm = PGMMechanism(eps=32, recursive=False).fit(x, y).plm
    dev = gap_positions_device(x, plm, 0.2, key_tile=key_tile,
                               seg_chunk=seg_chunk, interpret=True)
    ora = gap_positions_oracle(x, plm, 0.2)
    np.testing.assert_allclose(dev, ora, rtol=2e-5, atol=0.5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(300, 2000),
       rho=st.floats(0.01, 0.5))
def test_property_gap_place(seed, n, rho):
    rng = np.random.default_rng(seed)
    x = np.unique(rng.choice(2 ** 20, n, replace=False)).astype(np.float64)
    if len(x) < 16:
        return
    y = np.arange(len(x), dtype=np.float64)
    plm = FITingMechanism(eps=16).fit(x, y).plm
    dev = gap_positions_device(x, plm, rho, key_tile=256, seg_chunk=128,
                               interpret=True)
    ora = gap_positions_oracle(x, plm, rho)
    np.testing.assert_allclose(dev, ora, rtol=5e-5, atol=0.5)
    # budget: total inserted gaps <= rho*n (+rounding)
    assert dev[-1] - y[-1] <= rho * len(x) + 1.0
