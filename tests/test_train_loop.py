"""Trainer: loss goes down, crash-restart resumes, NaN guard, schedules,
gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data import IndexedTokenDataset, PackedTokenStore, ShardedLoader
from repro.models import build_model
from repro.optim import adafactor_init, adafactor_update, adamw_init, \
    adamw_update, cosine_schedule, wsd_schedule
from repro.optim.compress import compress_decompress, ef_compress_update
from repro.train import FailureInjector, TrainConfig, Trainer


def _setup(tmp_path, arch="internlm2-1.8b", steps=24, **tkw):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg)
    store = PackedTokenStore.synthetic(256, mean_len=33, vocab=cfg.vocab,
                                       seed=0)
    ds = IndexedTokenDataset.build(store, method="fiting", eps=8)
    loader = ShardedLoader(ds, global_batch=4, seq_len=32, seed=0)
    tcfg = TrainConfig(total_steps=steps, ckpt_every=8,
                       ckpt_dir=str(tmp_path), log_every=4,
                       warmup_steps=2, **tkw)
    return model, tcfg, loader


def test_loss_decreases(tmp_path):
    """Batch-matched eval: loss on the SAME held-out batch before and
    after training.  Comparing the first vs last LOGGED training loss
    (the old assertion) conflates the learning signal with per-batch
    variance (~±0.3 nats between batches of this size), which exceeds
    anything reachable in 30 steps — the trainer optimizes (interior
    losses dip), but the old test flipped on batch luck."""
    model, tcfg, loader = _setup(tmp_path, steps=30)
    trainer = Trainer(model, tcfg, loader)
    eval_batch = {k: jnp.asarray(v) for k, v in
                  loader.next_batch().items()}
    params0 = trainer.init_state(0)["params"]
    loss0 = float(model.loss_fn(params0, eval_batch, None))
    out = trainer.run(seed=0)
    loss1 = float(model.loss_fn(out["state"]["params"], eval_batch, None))
    assert np.isfinite(loss0) and np.isfinite(loss1)
    assert loss1 < loss0


def test_crash_restart_resumes(tmp_path):
    model, tcfg, loader = _setup(tmp_path, steps=20)
    injector = FailureInjector({13: "crash"})
    trainer = Trainer(model, tcfg, loader, failure_injector=injector)
    with pytest.raises(RuntimeError, match="injected crash"):
        trainer.run()
    # a new trainer (fresh process semantics) resumes from step 8 ckpt
    model2, tcfg2, loader2 = _setup(tmp_path, steps=20)
    out = Trainer(model2, tcfg2, loader2).run()
    assert out["metrics"][-1]["step"] == 20
    assert loader2.step >= 20  # pipeline seeked forward, no replay from 0


def test_grad_compression_trains(tmp_path):
    model, tcfg, loader = _setup(tmp_path, steps=16, grad_compress=True)
    out = Trainer(model, tcfg, loader).run()
    assert np.isfinite(out["metrics"][-1]["loss"])


def test_compress_decompress_error_feedback():
    g = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8)}
    e = {"w": jnp.zeros((8, 8))}
    deq, resid = ef_compress_update(g, e)
    err = np.abs(np.asarray(deq["w"] + resid["w"] - g["w"])).max()
    assert err < 1e-6  # feedback keeps the sum exact
    d, q, scale = compress_decompress(g["w"])
    assert q.dtype == jnp.int8
    assert np.abs(np.asarray(d - g["w"])).max() <= scale


def test_schedules():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup_steps=10,
                                 total_steps=100)) == 0.0
    assert float(cosine_schedule(10, peak_lr=1.0, warmup_steps=10,
                                 total_steps=100)) == pytest.approx(1.0)
    w = wsd_schedule(50, peak_lr=1.0, warmup_steps=10, stable_steps=60,
                     decay_steps=30)
    assert float(w) == pytest.approx(1.0)  # stable phase
    d = wsd_schedule(95, peak_lr=1.0, warmup_steps=10, stable_steps=60,
                     decay_steps=30)
    assert float(d) < 0.2  # decay phase


@pytest.mark.parametrize("init,update", [
    (adamw_init, adamw_update), (adafactor_init, adafactor_update)])
def test_optimizers_reduce_quadratic(init, update):
    """Both optimizers minimize a quadratic."""
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = update(grads, state, params, lr=5e-2)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adafactor_memory_is_factored():
    params = {"w": jnp.zeros((64, 32))}
    state = adafactor_init(params)
    slot = state["slots"]["w"]
    assert slot["vr"].shape == (64,) and slot["vc"].shape == (32,)
    assert slot["m"].dtype == jnp.bfloat16
