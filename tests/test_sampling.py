"""Sampling technique (§4): patches, theory bounds, end-to-end lookup."""

import numpy as np
import pytest

from conftest import make_keys
from repro.core import LearnedIndex
from repro.core.mechanisms import FITingMechanism, PGMMechanism, RMIMechanism
from repro.core.sampling import (
    exponential_search,
    fit_sampled,
    hoeffding_bound,
    sample_pairs,
    sample_size_bound,
)
from repro.core.mdl import correction_cost, mae


def test_sample_pairs_endpoints_and_size():
    x = make_keys("iot", 10_000, seed=0)
    y = np.arange(len(x), dtype=np.float64)
    xs, ys = sample_pairs(x, y, rate=0.01, rng=np.random.default_rng(0))
    assert xs[0] == x[0] and xs[-1] == x[-1]
    assert abs(len(xs) - 0.01 * len(x)) <= 3
    # positions are FULL-data positions
    assert np.all(ys == np.searchsorted(x, xs))


@pytest.mark.parametrize("factory", [
    lambda: PGMMechanism(eps=64, recursive=False),
    lambda: FITingMechanism(eps=64),
    lambda: RMIMechanism(n_leaf=200),
])
@pytest.mark.parametrize("rate", [0.1, 0.01])
def test_sampled_index_near_full_quality(factory, rate):
    """Sampling keeps MAE within a small multiple of the full build (§6.3)."""
    x = make_keys("weblogs", 40_000, seed=1)
    y = np.arange(len(x), dtype=np.float64)
    full = factory().fit(x, y)
    samp = fit_sampled(factory, x, y, rate=rate, rng=np.random.default_rng(1))
    mae_full = mae(y, full.predict(x))
    mae_samp = mae(y, samp.predict(x))
    # paper: non-degraded == same order of magnitude; generous factor here
    assert mae_samp <= max(8.0 * mae_full, 64.0 * 4)


@pytest.mark.parametrize("rate", [0.05, 0.01])
def test_sampled_lookup_exact(rate):
    """Every key still found after sampling + patch + refinalized bounds."""
    x = make_keys("iot", 30_000, seed=2)
    idx = LearnedIndex.build(x, method="pgm", eps=64, sample_rate=rate)
    q = np.random.default_rng(3).choice(x, 5000)
    pos = idx.lookup(q)
    assert np.all(x[pos] == q)


def test_exponential_search_matches_searchsorted():
    x = make_keys("longitude", 20_000, seed=4)
    rng = np.random.default_rng(5)
    q = rng.choice(x, 2000)
    # deliberately bad predictions to exercise the doubling phase
    y_hat = np.clip(np.searchsorted(x, q) + rng.integers(-5000, 5000, len(q)), 0, len(x) - 1)
    pos, probes = exponential_search(x, q, y_hat.astype(np.float64))
    assert np.all(x[pos] == q)
    assert probes > 0


def test_exponential_search_probe_count_tracks_error():
    """The (positions, probes) contract: probes grow with prediction
    error (that is the quantity gap insertion buys down)."""
    x = make_keys("iot", 30_000, seed=4)
    q = np.random.default_rng(5).choice(x, 3000)
    y_true = np.searchsorted(x, q).astype(np.float64)
    pos_good, probes_good = exponential_search(x, q, y_true)
    bad = np.clip(y_true + 4000, 0, len(x) - 1)
    pos_bad, probes_bad = exponential_search(x, q, bad)
    assert np.array_equal(pos_good, pos_bad)  # positions exact either way
    assert probes_bad > probes_good
    # perfect predictions still pay the bracket check + final bisects
    assert probes_good >= len(q)


def test_sample_pairs_default_rng_streams_independent():
    """rng=None must draw a FRESH stream per call — a fixed default
    seed made every per-shard build / retrain sample identically."""
    x = make_keys("iot", 20_000, seed=6)
    xs1, _ = sample_pairs(x, rate=0.05)
    xs2, _ = sample_pairs(x, rate=0.05)
    assert not np.array_equal(xs1, xs2)
    # explicit rng stays reproducible
    a, _ = sample_pairs(x, rate=0.05, rng=np.random.default_rng(3))
    b, _ = sample_pairs(x, rate=0.05, rng=np.random.default_rng(3))
    assert np.array_equal(a, b)


def test_spawn_rngs_independent_and_deterministic():
    from repro.core.sampling import spawn_rngs

    kids = spawn_rngs(np.random.default_rng(9), 4)
    draws = [k.integers(0, 2 ** 32, 8) for k in kids]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(draws[i], draws[j])
    again = spawn_rngs(np.random.default_rng(9), 4)
    assert np.array_equal(draws[0], again[0].integers(0, 2 ** 32, 8))
    # rng=None children are independent too
    k1, k2 = spawn_rngs(None, 2)
    assert not np.array_equal(k1.integers(0, 2 ** 32, 8),
                              k2.integers(0, 2 ** 32, 8))


def test_hoeffding_bound_monotone():
    assert hoeffding_bound(128, 100) > hoeffding_bound(128, 10_000)
    assert hoeffding_bound(1024, 100) > hoeffding_bound(16, 100)


def test_sample_size_bound_scaling():
    # O(alpha^2 log^2 E): quadratic in alpha, polylog in E
    assert sample_size_bound(2.0, 128) == pytest.approx(4 * sample_size_bound(1.0, 128))
    assert sample_size_bound(1.0, 2 ** 20) < 1000


def test_sampling_estimates_correction_cost():
    """Prop. 1 empirically: |L(D_s|M) - L(D|M)| within the bound."""
    x = make_keys("iot", 50_000, seed=6)
    y = np.arange(len(x), dtype=np.float64)
    m = PGMMechanism(eps=256, recursive=False).fit(x, y)
    full_cost = correction_cost(y, m.predict(x))
    rng = np.random.default_rng(7)
    fails = 0
    for _ in range(10):
        pick = rng.choice(len(x), 2000, replace=False)
        samp_cost = correction_cost(y[pick], m.predict(x[pick]))
        bound = hoeffding_bound(m.plm.max_abs_error(), 2000, delta=0.05)
        fails += abs(samp_cost - full_cost) > bound
    assert fails <= 2  # 5% failure prob per trial; allow slack


def test_fewer_segments_with_sampling():
    """Generalization improvement (§6.3 Fig. 7): fewer segments at lower s."""
    x = make_keys("iot", 60_000, seed=8)
    y = np.arange(len(x), dtype=np.float64)
    full = PGMMechanism(eps=64, recursive=False).fit(x, y)
    samp = fit_sampled(
        lambda: PGMMechanism(eps=64, recursive=False), x, y,
        rate=0.01, rng=np.random.default_rng(9), refinalize=False,
    )
    assert samp.plm.n_segments <= full.plm.n_segments
