"""Pallas lookup kernel vs pure-jnp oracle: shape/dtype/method sweeps
(interpret=True executes the kernel body on CPU; TPU is the target)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import make_keys
from repro.core import LearnedIndex
from repro.kernels import batched_lookup, from_learned_index, lookup_ref
import jax.numpy as jnp


def _truth(idx, q):
    if idx.gapped is not None:
        return idx.gapped.lookup_batch(q)
    return np.searchsorted(idx.keys, q)


@pytest.mark.parametrize("method,kw", [
    ("pgm", dict(eps=64)),
    ("fiting", dict(eps=64)),
    ("rmi", dict(n_leaf=512)),
])
@pytest.mark.parametrize("rho", [0.0, 0.2])
def test_kernel_matches_truth_methods(method, kw, rho):
    keys = make_keys("uniform_int", 30_000, seed=1)
    idx = LearnedIndex.build(keys, method=method, gap_rho=rho, **kw)
    arrs = from_learned_index(idx)
    q = np.random.default_rng(2).choice(keys, 2048)
    out, slot, found, fb = batched_lookup(arrs, idx.mech.plm.err_lo, q,
                                          interpret=True)
    assert np.array_equal(np.asarray(out), _truth(idx, q))


@pytest.mark.parametrize("q_tile,w_tile,win_chunk", [
    (128, 512, 128),
    (256, 2048, 512),
    (512, 4096, 1024),
])
def test_kernel_tile_shape_sweep(q_tile, w_tile, win_chunk):
    keys = make_keys("uniform_int", 20_000, seed=3)
    idx = LearnedIndex.build(keys, method="pgm", eps=32)
    arrs = from_learned_index(idx, w_tile=w_tile)
    q = np.random.default_rng(4).choice(keys, 1000)  # non-multiple of tile
    out, *_ = batched_lookup(arrs, idx.mech.plm.err_lo, q, q_tile=q_tile,
                             w_tile=w_tile, win_chunk=win_chunk,
                             interpret=True)
    assert np.array_equal(np.asarray(out), np.searchsorted(keys, q))


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_kernel_key_dtypes(dtype):
    """f32-exact integer keys, presented as float or int inputs."""
    keys = make_keys("uniform_int", 15_000, seed=5)
    idx = LearnedIndex.build(keys, method="fiting", eps=64, gap_rho=0.1)
    arrs = from_learned_index(idx)
    q_raw = np.random.default_rng(6).choice(keys, 1536).astype(dtype)
    out, *_ = batched_lookup(arrs, idx.mech.plm.err_lo, q_raw, interpret=True)
    assert np.array_equal(np.asarray(out), _truth(idx, q_raw.astype(np.float64)))


def test_kernel_misses_and_out_of_range():
    keys = make_keys("uniform_int", 10_000, seed=7)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.15)
    arrs = from_learned_index(idx)
    rng = np.random.default_rng(8)
    miss = np.setdiff1d(rng.choice(2 ** 22, 2000), keys.astype(np.int64))
    q = np.concatenate([
        miss[:500].astype(np.float64),
        [keys[0] - 10.0, keys[-1] + 10.0],          # out of range both sides
        rng.choice(keys, 500),                      # hits
    ])
    out, *_ = batched_lookup(arrs, idx.mech.plm.err_lo, q, interpret=True)
    truth = _truth(idx, q)
    assert np.array_equal(np.asarray(out), truth)
    assert np.all(np.asarray(out)[:502] == -1)


def test_oracle_only_path():
    """use_kernel=False exercises the jnp oracle end to end."""
    keys = make_keys("uniform_int", 8_000, seed=9)
    idx = LearnedIndex.build(keys, method="pgm", eps=64)
    arrs = from_learned_index(idx)
    q = np.random.default_rng(10).choice(keys, 1024)
    out_k, *_ = batched_lookup(arrs, idx.mech.plm.err_lo, q, interpret=True)
    out_o, *_ = batched_lookup(arrs, idx.mech.plm.err_lo, q, use_kernel=False)
    assert np.array_equal(np.asarray(out_k), np.asarray(out_o))


def test_lookup_ref_semantics():
    keys = jnp.asarray(np.array([1.0, 3.0, 3.0, 5.0, 9.0], np.float32))
    seg = jnp.zeros(1, jnp.float32)
    slot, found = lookup_ref(jnp.asarray([0.0, 3.0, 6.0, 9.0], jnp.float32),
                             seg, seg, seg, keys)
    assert list(np.asarray(slot)) == [-1, 2, 3, 4]
    assert list(np.asarray(found)) == [False, True, False, True]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(300, 3000),
       rho=st.sampled_from([0.0, 0.1, 0.3]))
def test_property_kernel_equals_oracle(seed, n, rho):
    """Property: kernel+fallback path == oracle for random key sets."""
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.choice(2 ** 20, n, replace=False)).astype(np.float64)
    if len(keys) < 16:
        return
    idx = LearnedIndex.build(keys, method="fiting", eps=16, gap_rho=rho)
    arrs = from_learned_index(idx)
    q = np.concatenate([
        rng.choice(keys, min(len(keys), 256)),
        rng.uniform(keys[0] - 5, keys[-1] + 5, 64),
    ])
    out_k, *_ = batched_lookup(arrs, idx.mech.plm.err_lo, q, q_tile=128,
                               w_tile=512, win_chunk=128, interpret=True)
    out_o, *_ = batched_lookup(arrs, idx.mech.plm.err_lo, q, use_kernel=False)
    assert np.array_equal(np.asarray(out_k), np.asarray(out_o))
