"""MDL framework (§3): objective terms and reports."""

import numpy as np
import pytest

from conftest import make_keys
from repro.core import LearnedIndex
from repro.core.mdl import correction_cost, mae, mdl_report
from repro.core.mechanisms import BTreeMechanism, PGMMechanism


def test_correction_cost_binary_search_form():
    y = np.array([0.0, 0.0, 0.0])
    assert correction_cost(y, y) == 1.0  # log2(1)+1 with max(err,1)
    y_hat = y + 16.0
    assert correction_cost(y, y_hat) == pytest.approx(np.log2(16) + 1)


def test_mdl_tradeoff_across_eps():
    """Smaller eps => larger L(M) (params), smaller L(D|M) (paper §6.2)."""
    x = make_keys("iot", 30_000, seed=0)
    y = np.arange(len(x), dtype=np.float64)
    reports = []
    for eps in (512.0, 64.0, 8.0):
        m = PGMMechanism(eps=eps, recursive=False).fit(x, y)
        reports.append(mdl_report(f"pgm{eps}", m, x, y))
    params = [r.l_model_params for r in reports]
    costs = [r.l_data_given_model for r in reports]
    assert params[0] < params[1] < params[2]
    assert costs[0] > costs[1] > costs[2]


def test_alpha_weighs_correction_term():
    x = make_keys("weblogs", 10_000, seed=1)
    y = np.arange(len(x), dtype=np.float64)
    m = PGMMechanism(eps=128, recursive=False).fit(x, y)
    r1 = mdl_report("a1", m, x, y, alpha=1.0)
    r10 = mdl_report("a10", m, x, y, alpha=10.0)
    assert r10.mdl > r1.mdl
    assert r10.mdl - r1.mdl == pytest.approx(9.0 * r1.l_data_given_model)


def test_btree_vs_learned_size(small_keys):
    y = np.arange(len(small_keys), dtype=np.float64)
    bt = mdl_report("btree", BTreeMechanism(page_size=256).fit(small_keys, y),
                    small_keys, y)
    pg = mdl_report("pgm", PGMMechanism(eps=128).fit(small_keys, y),
                    small_keys, y)
    # learned index stores far fewer parameters than dense-page B+Tree
    assert pg.l_model_bytes < bt.l_model_bytes


def test_learned_index_facade_mdl(small_keys):
    idx = LearnedIndex.build(small_keys, method="pgm", eps=128)
    rep = idx.mdl(alpha=2.0)
    assert rep.mae >= 0 and rep.l_data_given_model >= 1.0
    assert rep.max_abs_err <= 128 + 1e-6


def test_mdl_tracks_live_state_after_ingest():
    """Regression: ``Index.mdl()`` must score the LIVE key set (slots +
    chains), not the stale build-time snapshot — keys appended past the
    trained domain chain onto the tail with growing prediction error,
    and the report has to see that drift (it is the retrain trigger)."""
    from repro.core import Index

    x = make_keys("iot", 20_000, seed=3)
    idx = Index.build(x, method="pgm", eps=64, gap_rho=0.15)
    before = idx.mdl()
    step = float(np.mean(np.diff(x)))
    tail = x[-1] + step * 10.0 * (1.0 + np.arange(400))
    idx.ingest(tail, 1_000_000 + np.arange(400))
    after = idx.mdl()
    # the appended keys all chain onto the last slot while the model
    # extrapolates past it: correction cost and max error must grow
    assert after.max_abs_err > before.max_abs_err
    assert after.l_data_given_model > before.l_data_given_model
    assert after.mdl != before.mdl
