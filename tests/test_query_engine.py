"""Single-pass QueryEngine: compacted fallback re-resolution, sort-aware
scheduling, shape buckets, wide payloads (no hypothesis dependency —
this file carries the kernel-path coverage when hypothesis is absent)."""

import numpy as np
import pytest

from conftest import make_keys
from repro.core import LearnedIndex
from repro.kernels import (QueryEngine, batched_lookup, from_learned_index)
from repro.kernels import ops as ops_mod
from repro.kernels import ref as ref_mod


def _mixed_queries(keys, rng, n_hit=1500, n_miss=400):
    miss = np.setdiff1d(rng.choice(2 ** 22, 4 * n_miss + 16),
                        keys.astype(np.int64)).astype(np.float64)
    return np.concatenate([
        rng.choice(keys, n_hit),
        miss[:n_miss],
        [keys[0] - 10.0, keys[-1] + 10.0],
    ])


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_compaction_agrees_with_oracle_bit_exact(seed):
    """Property: the compacted-fallback path (non-overflow) and the
    overflow escape path both agree bit-exactly with the oracle."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5_000, 25_000))
    keys = make_keys("uniform_int", n, seed=seed)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.2)
    arrs = from_learned_index(idx)
    plm = idx.mech.plm
    q = _mixed_queries(keys, rng)
    out_o, slot_o, found_o, _ = batched_lookup(arrs, plm.err_lo, q,
                                               backend="oracle")
    # non-overflow: xla windowed + compacted fallback
    out_x, slot_x, found_x, fb = batched_lookup(
        arrs, plm.err_lo, q, backend="xla", err_hi_by_seg=plm.err_hi)
    assert np.array_equal(np.asarray(out_x), np.asarray(out_o))
    assert np.array_equal(np.asarray(slot_x), np.asarray(slot_o))
    assert np.array_equal(np.asarray(found_x), np.asarray(found_o))
    # forced overflow: broken bounds flag (almost) everything; the host
    # escape hatch must still return oracle-exact results
    bad = plm.err_lo + 1e6
    ops_mod._ESCAPES.count = 0
    out_esc, *_ = batched_lookup(arrs, bad, q, backend="xla",
                                 err_hi_by_seg=plm.err_hi + 1e6,
                                 fb_frac=0.001)
    assert ops_mod._ESCAPES.count == 1
    assert np.array_equal(np.asarray(out_esc), np.asarray(out_o))
    # pallas (interpret) with compaction agrees too
    out_k, *_ = batched_lookup(arrs, plm.err_lo, q, interpret=True)
    assert np.array_equal(np.asarray(out_k), np.asarray(out_o))


def test_oracle_not_evaluated_on_unflagged_queries(monkeypatch):
    """Regression: the single-pass path must never hand the FULL batch to
    the oracle — lookup_ref may only be traced over the (fb_cap,)-shaped
    compacted buffer, and the runtime escape hatch must not fire when
    the buffer does not overflow (counting shims on both)."""
    keys = make_keys("uniform_int", 20_000, seed=7)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.15)
    arrs = from_learned_index(idx)
    plm = idx.mech.plm
    rng = np.random.default_rng(8)
    # odd batch size => fresh jit trace (no cached executable to hide in)
    q = rng.choice(keys, 3001)

    traced_shapes = []
    real_lookup_ref = ref_mod.lookup_ref

    def spy_lookup_ref(queries, *args, **kw):
        traced_shapes.append(int(queries.shape[0]))
        return real_lookup_ref(queries, *args, **kw)

    monkeypatch.setattr(ref_mod, "lookup_ref", spy_lookup_ref)

    escapes = []
    real_escape = ops_mod._oracle_escape

    def spy_escape(*args, **kw):
        escapes.append(1)
        return real_escape(*args, **kw)

    monkeypatch.setattr(ops_mod, "_oracle_escape", spy_escape)

    for backend, kw in (("pallas", dict(interpret=True)),
                        ("xla", dict(err_hi_by_seg=plm.err_hi))):
        traced_shapes.clear()
        escapes.clear()
        out, _, _, fb = batched_lookup(arrs, plm.err_lo, q,
                                       backend=backend, **kw)
        # runtime: no full-oracle widening happened
        assert escapes == [], backend
        # trace-time: lookup_ref was never handed a full-batch array
        # (the xla/pallas search stages do not call it at all; only a
        # compacted (fb_cap,) buffer could)
        assert all(s < q.shape[0] for s in traced_shapes), (
            backend, traced_shapes)
        truth = idx.gapped.lookup_batch(q)
        assert np.array_equal(np.asarray(out), truth)


def test_engine_buckets_and_sorted_fast_path():
    keys = make_keys("uniform_int", 25_000, seed=3)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.2)
    eng = QueryEngine.from_index(idx, min_bucket=1024)
    rng = np.random.default_rng(4)
    truth_of = idx.gapped.lookup_batch
    # varying batch sizes collapse onto one shape bucket (no re-trace)
    for n_q in (700, 901, 1024):
        q = rng.choice(keys, n_q)
        out, *_ = eng.lookup(q)
        assert np.array_equal(np.asarray(out), truth_of(q))
    assert eng.stats["buckets"] == {1024}
    assert eng.stats["calls"] == 3
    # sorted fast path: identical results without the argsort round trip
    q = np.sort(rng.choice(keys, 2000))
    out_s, *_ = eng.lookup(q, queries_sorted=True)
    assert np.array_equal(np.asarray(out_s), truth_of(q))
    # oracle-backed engine agrees on a mixed batch
    eng_o = QueryEngine.from_index(idx, backend="oracle")
    q = _mixed_queries(keys, rng)
    out_a, *_ = eng.lookup(q)
    out_b, *_ = eng_o.lookup(q)
    assert np.array_equal(np.asarray(out_a), np.asarray(out_b))


def test_wide_int64_payloads_roundtrip():
    """from_learned_index must not truncate >32-bit payloads (hi/lo pair
    carried through slot and chain epilogues on every backend)."""
    keys = make_keys("uniform_int", 12_000, seed=5)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.2)
    ga = idx.gapped
    big = np.int64(3) << 40
    ga.payload[ga.occupied] = big + ga.payload[ga.occupied]
    # chains are CSR-native now: payloads are a live array view
    ga.links.chain_payloads[:] = big + ga.links.chain_payloads
    assert ga.links.total > 0  # the chain epilogue is exercised
    ga._invalidate()
    arrs = from_learned_index(idx)
    assert arrs.wide
    rng = np.random.default_rng(6)
    q = _mixed_queries(keys, rng, n_hit=1000, n_miss=200)
    truth = ga.lookup_batch(q)
    assert truth.max() > np.iinfo(np.int32).max  # test is meaningful
    plm = idx.mech.plm
    for backend, kw in (("oracle", {}), ("pallas", dict(interpret=True)),
                        ("xla", dict(err_hi_by_seg=plm.err_hi))):
        out, *_ = batched_lookup(arrs, plm.err_lo, q, backend=backend, **kw)
        assert np.asarray(out).dtype == np.int64
        assert np.array_equal(np.asarray(out), truth), backend
    eng = QueryEngine.from_index(idx)
    out, *_ = eng.lookup(q)
    assert np.array_equal(np.asarray(out), truth)


def test_narrow_payloads_not_flagged_wide():
    keys = make_keys("uniform_int", 8_000, seed=9)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.1)
    assert not from_learned_index(idx).wide
