"""Snapshot-isolated epoch pipelining (ISSUE 8 tentpole): pinned
snapshots bit-identical to the quiesced index under concurrent ingest,
COW correctness, epoch tagging, refcounts, admission control, and the
fused-ingest split commit."""

import threading
import time

import numpy as np
import pytest

from repro.core import Index, Overloaded
from repro.robustness import (FaultInjector, InjectedCrash, InjectedFault,
                              InvariantAuditor)
from repro.serving import EpochPipeline, MicroBatchQueue, pin_index
from repro.serving.engine import ServingEngine  # noqa: F401 (import path)


def _mk_index(n=20_000, seed=0, wide=False, **kw):
    rng = np.random.default_rng(seed)
    # wide: beyond f32 exactness (2^24) but inside the device pair-exact
    # range (integer keys < 2^48 after the *2 even-grid scaling)
    hi = 2 ** 46 if wide else 2 ** 21
    keys = np.unique(rng.choice(hi, n, replace=False)).astype(np.float64)
    keys *= 2.0  # even grid: every midpoint is a representable fresh key
    kw.setdefault("method", "pgm")
    kw.setdefault("eps", 64)
    kw.setdefault("gap_rho", 0.2)
    return Index.build(keys, **kw), keys


def _fresh(keys, n):
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    assert mids.size >= n
    return mids[:n]


# ---------------------------------------------------------------------------
# snapshot isolation: bit-identity to the quiesced index


@pytest.mark.parametrize("wide", [False, True])
def test_snapshot_bit_identical_under_ingest(wide):
    """A pinned snapshot's answers NEVER move while the live index
    ingests / deletes / updates — and equal the quiesced lookup at the
    pinned epoch bit-for-bit (payloads, slots, found)."""
    idx, keys = _mk_index(wide=wide)
    rng = np.random.default_rng(1)
    q = np.concatenate([rng.choice(keys, 1_500),
                        rng.choice(keys, 300) + 1.0,
                        [keys[0] - 4.0, keys[-1] + 4.0]])
    quiesced = idx.lookup(q)
    pipe = EpochPipeline(idx)
    epoch0 = pipe.epoch

    batches = np.array_split(_fresh(keys, 3_000), 4)
    for i, b in enumerate(batches):
        pipe.ingest(b, (50_000 + np.arange(b.size) + i).astype(np.int64))
        got = pipe.lookup(q)
        assert got.epoch == epoch0
        assert got.backend == "snapshot"
        np.testing.assert_array_equal(got.payloads, quiesced.payloads)
        np.testing.assert_array_equal(got.found, quiesced.found)
        # miss-row slots are backend-advisory (host oracle clamps to 0
        # where the device reports -1 — pre-existing convention); hit
        # rows must agree exactly
        hit = np.asarray(quiesced.found)
        np.testing.assert_array_equal(np.asarray(got.slots)[hit],
                                      np.asarray(quiesced.slots)[hit])
    # delete + update on the live side: still invisible at epoch 0
    idx.delete(float(keys[10]))
    idx.update(float(keys[11]), 999_999)
    got = pipe.lookup(q)
    np.testing.assert_array_equal(got.payloads, quiesced.payloads)

    # publish: the new epoch serves every applied write, quiesced path
    pipe.publish()
    assert pipe.epoch == pipe.live_epoch > epoch0
    allb = np.concatenate(batches)
    res = pipe.lookup(allb)
    assert res.found.all()
    assert not pipe.lookup(np.array([float(keys[10])])).found.any()
    pipe.close()


def test_snapshot_equals_quiesced_after_forced_refreeze():
    """Epoch-N pin survives a full device refreeze of the live index
    (the heaviest mutation path: arrays wholly rebuilt)."""
    idx, keys = _mk_index(n=8_000)
    pipe = EpochPipeline(idx)
    q = keys[::7]
    want = pipe.lookup(q)
    big = _fresh(keys, 4_000)
    pipe.ingest(big, np.arange(big.size, dtype=np.int64))
    idx._sync_device(prefer_delta=False)  # force refreeze under the pin
    got = pipe.lookup(q)
    np.testing.assert_array_equal(got.payloads, want.payloads)
    np.testing.assert_array_equal(got.found, want.found)
    pipe.close()


def test_sharded_snapshot_isolation_and_forced_split():
    """ShardedIndex snapshots pin the router topology too: answers stay
    bit-identical across concurrent ingest AND a forced shard split
    (which rewrites boundaries and slot bases live)."""
    idx, keys = _mk_index(n=24_000, shards=3)
    rng = np.random.default_rng(2)
    q = np.concatenate([rng.choice(keys, 2_000),
                        rng.choice(keys, 400) + 1.0])
    quiesced = idx.lookup(q)
    pipe = EpochPipeline(idx)
    epoch0 = pipe.epoch

    b = _fresh(keys, 2_000)
    pipe.ingest(b, (70_000 + np.arange(b.size)).astype(np.int64))
    idx.maybe_rebalance(force_shard=1)  # topology change under the pin
    got = pipe.lookup(q)
    assert got.epoch == epoch0 and got.backend == "snapshot"
    np.testing.assert_array_equal(got.payloads, quiesced.payloads)
    np.testing.assert_array_equal(got.found, quiesced.found)
    hit = np.asarray(quiesced.found)
    np.testing.assert_array_equal(np.asarray(got.slots)[hit],
                                  np.asarray(quiesced.slots)[hit])

    pipe.publish()
    res = pipe.lookup(b)
    assert res.found.all()
    np.testing.assert_array_equal(
        res.payloads, 70_000 + np.arange(b.size))
    pipe.close()


def test_concurrent_reader_thread_sees_one_epoch_per_call():
    """Hammer lookups from a reader thread while the main thread ingests
    and publishes: every result is internally consistent with the epoch
    it reports (fresh keys of epoch E are all-found iff served epoch >=
    E's publish)."""
    idx, keys = _mk_index(n=10_000)
    pipe = EpochPipeline(idx)
    b = _fresh(keys, 1_024)
    errors = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            res = pipe.lookup(b)
            nf = int(res.found.sum())
            # all-or-nothing: the batch publishes atomically, so a
            # partial found-count means a torn epoch was observed
            if nf not in (0, b.size):
                errors.append(f"torn epoch: {nf}/{b.size} found at "
                              f"epoch {res.epoch}")

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    time.sleep(0.02)
    pipe.ingest(b, np.arange(b.size, dtype=np.int64))
    time.sleep(0.02)
    pipe.publish()
    time.sleep(0.02)
    stop.set()
    t.join(timeout=5)
    assert not errors, errors
    pipe.close()


# ---------------------------------------------------------------------------
# pin refcounts + COW mechanics at the GappedArray level


def test_pin_refcount_and_cow_detach():
    idx, keys = _mk_index(n=4_000)
    ga = idx.gapped
    s1 = ga.pin_snapshot()
    s2 = ga.pin_snapshot()
    assert s1.pinned and s2.pinned
    base = s1.lookup_batch(keys[:64])
    # first post-pin mutation pays the COW once and detaches the cell
    idx.insert(float(keys[0] + 1.0), 1)
    assert ga._pins is None
    np.testing.assert_array_equal(s1.lookup_batch(keys[:64]), base)
    np.testing.assert_array_equal(s2.lookup_batch(keys[:64]), base)
    s1.release()
    assert not s1.pinned and s2.pinned  # shared cell: s2 still live
    s2.release()
    assert not s2.pinned
    # releasing twice is a no-op, not an underflow
    s2.release()
    aud = InvariantAuditor()
    aud.assert_ok(idx)


def test_pipeline_publish_releases_old_pin():
    idx, keys = _mk_index(n=4_000)
    pipe = EpochPipeline(idx)
    old = pipe._snapshot
    b = _fresh(keys, 128)
    pipe.ingest(b, np.arange(128, dtype=np.int64))
    pipe.publish()
    assert not old._snap.pinned  # old epoch's pin released on swap
    assert pipe._snapshot._snap.pinned
    pipe.close()
    assert not pipe._snapshot._snap.pinned


def test_static_index_refuses_snapshot():
    rng = np.random.default_rng(0)
    keys = np.unique(rng.choice(2 ** 20, 2_000, replace=False)
                     ).astype(np.float64)
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.0)
    with pytest.raises(ValueError, match="gapped"):
        pin_index(idx)


def test_auditor_catches_planted_corruption():
    idx, keys = _mk_index(n=4_000)
    aud = InvariantAuditor()
    aud.assert_ok(idx)
    idx.gapped.occupied[np.flatnonzero(idx.gapped.occupied)[0]] = False
    with pytest.raises(AssertionError, match="slot"):
        aud.assert_ok(idx)


# ---------------------------------------------------------------------------
# admission control (MicroBatchQueue, ISSUE 8 satellite)


def test_deadline_flush_fires_without_explicit_flush():
    idx, keys = _mk_index(n=4_000)
    q = MicroBatchQueue(idx, max_wait_ms=20)
    t = q.submit_lookup(keys[:4])
    deadline = time.monotonic() + 5.0
    while q.stats["deadline_flushes"] == 0:
        assert time.monotonic() < deadline, "deadline timer never fired"
        time.sleep(0.005)
    res = q.result(t)
    assert res.found.all()
    assert q.stats["deadline_flushes"] >= 1
    assert q.stats["flushes"] >= 1
    q.close()


def test_bounded_depth_sheds_with_typed_overloaded():
    idx, keys = _mk_index(n=4_000)
    q = MicroBatchQueue(idx, max_depth=2)
    t1 = q.submit_lookup(keys[:2])
    t2 = q.submit_ingest(_fresh(keys, 2), np.array([1, 2]))
    t3 = q.submit_lookup(keys[4:6])  # over the bound: shed
    shed = q.result(t3)
    assert isinstance(shed, Overloaded)
    assert not shed  # falsy: `if result:` skips shed tickets
    assert shed.kind == "lookup" and shed.depth == 2 == shed.max_depth
    assert q.stats["shed"] == 1
    # shed tickets resolve exactly once, like real ones
    with pytest.raises(KeyError, match="exactly once"):
        q.result(t3)
    q.flush()
    assert q.result(t1).found.all()
    assert q.result(t2).n == 2
    q.close()


def test_ingest_retry_absorbs_transient_abort():
    idx, keys = _mk_index(n=4_000)
    inj = FaultInjector({("ingest", 0): "abort"})
    q = MicroBatchQueue(idx, faults=inj, ingest_retries=2,
                        retry_backoff_ms=0.1)
    b = _fresh(keys, 8)
    t = q.submit_ingest(b, np.arange(8, dtype=np.int64))
    rep = q.result(t)
    assert rep.n == 8
    assert q.stats["ingest_retries"] == 1
    assert q.stats["host_fallbacks"] == 0
    assert inj.fired == [("ingest", 0, "abort")]
    assert idx.lookup(b).found.all()
    q.close()


def test_ingest_final_retry_falls_back_to_host_path():
    idx, keys = _mk_index(n=4_000)
    inj = FaultInjector({("ingest", 0): "abort", ("ingest", 1): "abort"})
    q = MicroBatchQueue(idx, faults=inj, ingest_retries=2,
                        retry_backoff_ms=0.1)
    prev = idx.fused_ingest_enabled
    b = _fresh(keys, 8)
    rep = q.result(q.submit_ingest(b, np.arange(8, dtype=np.int64)))
    assert rep.n == 8
    assert q.stats["ingest_retries"] == 2
    assert q.stats["host_fallbacks"] == 1
    assert idx.fused_ingest_enabled == prev  # restored after fallback
    q.close()


def test_injected_crash_propagates_through_retry():
    idx, keys = _mk_index(n=4_000)
    inj = FaultInjector({("ingest", 0): "crash"})
    q = MicroBatchQueue(idx, faults=inj, ingest_retries=5)
    t = q.submit_ingest(_fresh(keys, 4), np.arange(4, dtype=np.int64))
    with pytest.raises(InjectedCrash):
        q.result(t)
    assert q.stats["ingest_retries"] == 0  # crash is not retried
    q.close()


def test_exhausted_retries_raise_last_error():
    idx, keys = _mk_index(n=4_000)
    inj = FaultInjector({("ingest", i): "abort" for i in range(4)})
    q = MicroBatchQueue(idx, faults=inj, ingest_retries=2,
                        retry_backoff_ms=0.1)
    t = q.submit_ingest(_fresh(keys, 4), np.arange(4, dtype=np.int64))
    with pytest.raises(InjectedFault, match="injected abort"):
        q.result(t)
    q.close()


def test_deadline_timer_error_surfaces_on_next_call():
    """An exception inside the timer-thread flush must not vanish into
    the daemon thread — it re-raises on the next queue call."""
    idx, keys = _mk_index(n=4_000)
    inj = FaultInjector({("flush", 0): "abort"})
    q = MicroBatchQueue(idx, max_wait_ms=10, faults=inj)
    q.submit_lookup(keys[:4])
    deadline = time.monotonic() + 5.0
    while q._async_error is None:
        assert time.monotonic() < deadline, "timer error never captured"
        time.sleep(0.005)
    with pytest.raises(InjectedFault, match="injected abort"):
        q.submit_lookup(keys[4:8])
    q.close()


def test_queue_over_pipeline_composes():
    """MicroBatchQueue aggregates over an EpochPipeline unchanged —
    coalesced ingest goes through the WAL-less pipeline, coalesced
    lookups serve the pinned epoch."""
    idx, keys = _mk_index(n=6_000)
    pipe = EpochPipeline(idx, publish_every=1)
    q = MicroBatchQueue(pipe)
    b = _fresh(keys, 16)
    t1 = q.submit_ingest(b[:8], np.arange(8, dtype=np.int64))
    t2 = q.submit_ingest(b[8:], 8 + np.arange(8, dtype=np.int64))
    t3 = q.submit_lookup(b)
    assert q.result(t3).found.all()  # ingests flush first, then publish
    assert q.result(t1).n == 16 and q.result(t2).n == 16  # shared report
    assert pipe.stats["publishes"] == 1
    pipe.close()


# ---------------------------------------------------------------------------
# fused-ingest split commit (ISSUE 8 satellite)


def test_split_commit_prefix_on_device_bit_identical():
    """A localized abort (one in-batch collision pair late in the batch)
    commits the clean prefix through a second fused dispatch and routes
    only the remainder through the host — final state bit-identical to
    sequential insert()."""
    import copy

    rng = np.random.default_rng(7)
    keys = np.unique(rng.choice(2 ** 21, 30_000, replace=False)
                     ).astype(np.float64) * 2.0
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    idx.fused_ingest_enabled = True
    idx.sync_device()

    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    # spaced midpoints (one per gap region) so the batch carries NO
    # natural collision pair — the crafted late one below is the only
    # abort cause, keeping the clean prefix long
    batch = mids[:: max(1, mids.size // 1_024)][:1_024]
    prims = idx.gapped.placement_primitives(batch)
    free = np.asarray(prims["free"]) & np.asarray(prims["bracket"])
    late_free = np.flatnonzero(free)
    late_free = late_free[late_free >= 600]
    assert late_free.size, "need a late free placement to craft the abort"
    j = int(late_free[0])
    # a second key in slot j's gap run -> in-graph collision_group abort
    cand = batch[j] + 2.0
    assert cand < keys[np.searchsorted(keys, batch[j])]
    assert cand not in keys and cand not in batch
    batch = np.sort(np.append(batch, cand))
    pays = (90_000 + np.arange(batch.size)).astype(np.int64)

    ref = copy.deepcopy(idx)
    rep = idx.ingest(batch, pays)
    assert rep.placement == "device-split"
    assert rep.split_commits >= 1
    assert rep.device in ("fused+delta", "fused+refreeze", "fused+none")
    assert rep.n == batch.size

    for k, p in zip(batch, pays):
        ref.insert(float(k), int(p))
    ga, gb = idx.gapped, ref.gapped
    np.testing.assert_array_equal(ga.slot_key, gb.slot_key)
    np.testing.assert_array_equal(ga.occupied, gb.occupied)
    np.testing.assert_array_equal(ga.payload[ga.occupied],
                                  gb.payload[gb.occupied])
    np.testing.assert_array_equal(ga.lookup_batch(batch),
                                  gb.lookup_batch(batch))
    res = idx.lookup(batch)
    np.testing.assert_array_equal(res.payloads, pays)


def test_split_commit_disabled_falls_back_whole_batch():
    rng = np.random.default_rng(7)
    keys = np.unique(rng.choice(2 ** 21, 30_000, replace=False)
                     ).astype(np.float64) * 2.0
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.2)
    idx.fused_ingest_enabled = True
    idx.fused_split_commit = False
    idx.sync_device()
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    batch = mids[:: max(1, mids.size // 1_024)][:1_024]
    prims = idx.gapped.placement_primitives(batch)
    free = np.flatnonzero(np.asarray(prims["free"])
                          & np.asarray(prims["bracket"]))
    free = free[free >= 600]
    batch = np.sort(np.append(batch, batch[int(free[0])] + 2.0))
    rep = idx.ingest(batch, np.arange(batch.size, dtype=np.int64))
    assert rep.placement != "device-split"
    assert rep.split_commits == 0
    assert idx.lookup(batch).found.all()


# ---------------------------------------------------------------------------
# StepWatchdog close/join (ISSUE 8 satellite)


def test_step_watchdog_exception_exit_cancels_and_joins():
    from repro.train.fault import StepWatchdog

    fired = []
    with pytest.raises(RuntimeError, match="boom"):
        with StepWatchdog(0.05, on_timeout=lambda s, e: fired.append(s)) \
                as wd:
            wd.arm(3)
            raise RuntimeError("boom")
    assert wd._timer is None
    time.sleep(0.12)
    assert fired == []  # cancelled timer never fires after teardown

    wd2 = StepWatchdog(0.01, on_timeout=lambda s, e: fired.append(s))
    wd2.arm(5)
    time.sleep(0.05)
    wd2.close()
    assert fired == [5] and wd2.events[0]["step"] == 5
    # close() after the timer already fired joins cleanly (no hang)
    wd2.close()


# ---------------------------------------------------------------------------
# ISSUE 10 satellites: regressions for the concurrency fixes repro-lint /
# locksan surfaced, and the MDL-drift retrain daemon


def test_snapshot_refcount_survives_concurrent_publish():
    """Regression: ``publish()`` dropping the pipeline's reference to
    the old snapshot must NOT unpin it under a reader that retained it
    — the pin (and its copy-on-write protection) drops only when the
    last reference goes."""
    idx, keys = _mk_index(n=6_000)
    pipe = EpochPipeline(idx)
    snap = pipe._snapshot
    snap.retain()                      # in-flight reader
    pre = snap.lookup(keys[:64])
    try:
        pipe.ingest(_fresh(keys, 64), np.arange(64, dtype=np.int64))
        pipe.publish()                 # pipeline drops its old-pin ref
        assert snap._snap.pinned       # reader's retain keeps it alive
        mid = snap.lookup(keys[:64])   # still the frozen epoch, exact
        np.testing.assert_array_equal(np.asarray(pre.payloads),
                                      np.asarray(mid.payloads))
        assert mid.epoch == pre.epoch
    finally:
        snap.release()
    assert not snap._snap.pinned       # last ref gone -> unpinned
    with pytest.raises(RuntimeError):
        snap.retain()                  # a released snapshot stays dead
    pipe.close()


def test_pipeline_stats_consistent_under_concurrent_readers():
    """Regression: ``stats`` / ``lag`` reads raced ingest before the
    pipeline lock — counters now reconcile exactly against the calls
    issued, with reader threads hammering lookup()+lag the whole
    time."""
    idx, keys = _mk_index(n=6_000)
    pipe = EpochPipeline(idx, publish_every=3)
    fresh = _fresh(keys, 256)
    errors, counts = [], []
    stop = threading.Event()

    def reader():
        n = 0
        try:
            while not stop.is_set():
                res = pipe.lookup(keys[:16])
                assert res.epoch <= pipe.live_epoch
                assert pipe.lag >= 0
                n += 1
        except Exception as e:      # noqa: BLE001 - surfaced below
            errors.append(e)
        counts.append(n)

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        for i in range(8):
            pipe.ingest(fresh[i * 32: (i + 1) * 32],
                        (50_000 + np.arange(32) + i * 32).astype(np.int64))
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors, errors
    s = pipe.stats
    assert s["snapshot_lookups"] + s["live_lookups"] == sum(counts)
    assert s["ingests"] == 8 and s["publishes"] == 8 // 3
    assert pipe.lag == 8 % 3           # un-published tail, exact
    pipe.close()


def test_mdl_drift_retrain_trigger_fires_and_resets_baseline():
    """The PR-9-residual closer: out-of-domain tail appends grow
    ``Index.mdl()`` (keys chain past the trained domain); the pipeline
    daemon sees the growth at publish, retrains, and resets its
    baseline so a quiesced workload never re-fires."""
    rng = np.random.default_rng(3)
    keys = np.unique(rng.choice(2 ** 21, 20_000, replace=False)
                     ).astype(np.float64) * 2.0
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.15)
    pipe = EpochPipeline(idx, retrain_mdl_drift=0.02)
    base0 = pipe._mdl_baseline
    assert base0 is not None
    step = float(np.mean(np.diff(keys)))
    tail = keys[-1] + step * 10.0 * (1.0 + np.arange(800))
    tail = np.rint(tail) * 2.0         # stay on the even grid
    fired_at = None
    for i in range(4):
        pipe.ingest(tail[i * 200: (i + 1) * 200],
                    (1_000_000 + np.arange(200) + i * 200).astype(np.int64))
        pipe.publish()
        if pipe.stats["mdl_retrains"]:
            fired_at = i
            break
    assert fired_at is not None, "drift never crossed the threshold"
    assert pipe.stats["mdl_checks"] == fired_at + 1
    assert pipe.stats["retrains"] >= 1          # the real retrain ran
    # baseline reset to the post-retrain score: quiesced -> no re-fire
    assert pipe._mdl_baseline == pytest.approx(pipe._mdl_score())
    pipe.publish()                              # serve the retrained epoch
    got = pipe.lookup(np.concatenate([keys[:200],
                                      tail[:(fired_at + 1) * 200]]))
    assert got.found.all()
    n_fired = pipe.stats["mdl_retrains"]
    pipe.ingest(_fresh(keys, 32),
                (77_000 + np.arange(32)).astype(np.int64))
    pipe.publish()
    assert pipe.stats["mdl_retrains"] == n_fired
    pipe.close()


def test_mdl_drift_check_cadence():
    """``retrain_check_every=N`` scores every N-th publish only (the
    score walks the live set — the knob bounds that cost), and a slack
    threshold never fires."""
    idx, keys = _mk_index(n=6_000)
    pipe = EpochPipeline(idx, retrain_mdl_drift=10.0,
                         retrain_check_every=2)
    fresh = _fresh(keys, 128)
    for i in range(4):
        pipe.ingest(fresh[i * 32: (i + 1) * 32],
                    (np.arange(32) + i * 32).astype(np.int64))
        pipe.publish()
    assert pipe.stats["mdl_checks"] == 2
    assert pipe.stats["mdl_retrains"] == 0
    pipe.close()
