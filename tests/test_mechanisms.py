"""Mechanism-level guarantees: error bounds, optimality, PLM export."""

import numpy as np
import pytest

from conftest import make_keys
from repro.core.mechanisms import (
    BTreeMechanism,
    FITingMechanism,
    PGMMechanism,
    RMIMechanism,
    _optimal_pla,
    _shrinking_cone,
)

KINDS = ["weblogs", "iot", "longitude", "uniform_int"]


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("eps", [16.0, 128.0])
def test_pgm_error_bound(kind, eps):
    x = make_keys(kind, 8000, seed=3)
    y = np.arange(len(x), dtype=np.float64)
    m = PGMMechanism(eps=eps, recursive=False).fit(x, y)
    err = np.abs(m.predict(x) - y)
    assert err.max() <= eps + 1e-6
    assert m.plm.max_abs_error() <= eps + 1e-6


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("eps", [16.0, 128.0])
def test_fiting_error_bound(kind, eps):
    x = make_keys(kind, 8000, seed=4)
    y = np.arange(len(x), dtype=np.float64)
    m = FITingMechanism(eps=eps).fit(x, y)
    err = np.abs(m.predict(x) - y)
    assert err.max() <= eps + 1e-6


@pytest.mark.parametrize("kind", KINDS)
def test_pgm_no_more_segments_than_fiting(kind):
    """Optimal PLA (free intercept) <= greedy shrinking cone (Table 1)."""
    x = make_keys(kind, 8000, seed=5)
    y = np.arange(len(x), dtype=np.float64)
    pgm = PGMMechanism(eps=64, recursive=False).fit(x, y)
    fit = FITingMechanism(eps=64).fit(x, y)
    assert pgm.plm.n_segments <= fit.plm.n_segments


def _dp_min_segments(x, y, eps):
    """Quadratic DP: ground-truth minimum #segments covering all points."""
    n = len(x)
    # feas[i][j]: points i..j fit one line within eps (via optimal PLA on
    # the subrange returning a single segment)
    best = [0] * (n + 1)
    for i in range(n - 1, -1, -1):
        best[i] = 1 + best[i + 1]
        for j in range(n - 1, i, -1):
            segs = _optimal_pla(x[i : j + 1], y[i : j + 1], eps)
            if len(segs) == 1:
                best[i] = min(best[i], 1 + best[j + 1])
                break  # greedy-longest is optimal for interval covers
    return best[0]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pgm_optimality_small(seed):
    rng = np.random.default_rng(seed)
    x = np.unique(rng.integers(0, 4000, 60)).astype(np.float64)
    y = np.arange(len(x), dtype=np.float64)
    segs = _optimal_pla(x, y, 2.0)
    assert len(segs) == _dp_min_segments(x, y, 2.0)


def test_cone_anchor_midpoint_within_eps():
    x = make_keys("iot", 4000, seed=6)
    y = np.arange(len(x), dtype=np.float64)
    for i, j, slope, icept in _shrinking_cone(x, y, 32.0):
        seg_err = np.abs(slope * (x[i : j + 1] - x[i]) + icept - y[i : j + 1])
        assert seg_err.max() <= 32.0 + 1e-6


@pytest.mark.parametrize("kind", KINDS)
def test_rmi_predicts_and_exports_plm(kind):
    x = make_keys(kind, 9000, seed=7)
    y = np.arange(len(x), dtype=np.float64)
    m = RMIMechanism(n_leaf=256).fit(x, y)
    direct = m.predict(x)
    via_plm = m.plm.predict(x)
    # root-routing and searchsorted-routing agree (up to fp at boundaries)
    assert np.mean(np.abs(direct - via_plm) > 1e-6) < 0.01
    # exported error bounds are sound for the searchsorted routing
    y_hat, lo, hi = m.plm.predict_with_bounds(x)
    assert np.all(y >= lo - 1e-9) and np.all(y <= hi + 1e-9)


def test_btree_pages_and_height():
    x = make_keys("uniform_int", 10_000, seed=8)
    y = np.arange(len(x), dtype=np.float64)
    b = BTreeMechanism(page_size=128, fanout=16).fit(x, y)
    pred = b.predict(x)
    assert np.abs(pred - y).max() <= 128  # within one page
    assert b.height >= 2
    assert b.size_bytes() > 16 * len(x)  # dense leaves dominate


def test_recursive_pgm_levels():
    x = make_keys("iot", 30_000, seed=9)
    y = np.arange(len(x), dtype=np.float64)
    m = PGMMechanism(eps=4, recursive=True).fit(x, y)
    assert m.plm.levels >= 1
    assert m.param_count() >= m.plm.param_count()


def test_duplicate_keys_rejected():
    x = np.array([1.0, 2.0, 2.0, 3.0])
    y = np.arange(4, dtype=np.float64)
    with pytest.raises(ValueError):
        PGMMechanism(eps=1).fit(x, y)
    with pytest.raises(ValueError):
        FITingMechanism(eps=1).fit(x, y)
