"""Fused single-dispatch lookup path: bit-identity against the numpy
oracle (narrow + >2^24 hi/lo pair keys, CSR chain epilogue at max
chain), engine scheduling (the fused path owns the small/medium-batch
regime), and the incremental window-bound / rank-row refresh."""

import copy

import numpy as np
import pytest

from conftest import make_keys
from repro.core import BACKENDS, Index, LearnedIndex
from repro.kernels import QueryEngine, batched_lookup, from_learned_index
from repro.kernels import ops as ops_mod


def _mixed_queries(rng, keys, extra=(), n_hit=1500, n_miss=400):
    lo, hi = keys[0], keys[-1]
    miss = np.setdiff1d(
        np.round(rng.uniform(lo, hi, 4 * n_miss)), keys)[:n_miss]
    parts = [rng.choice(keys, n_hit), miss,
             [keys[0] - 10.0, keys[-1] + 10.0]]
    parts += [np.asarray(e, np.float64) for e in extra]
    return np.concatenate(parts)


@pytest.mark.parametrize("seed,wide", [(0, False), (1, False),
                                       (2, True), (3, True)])
def test_fused_backends_bit_identical_to_oracle(seed, wide):
    """Property: both fused implementations (XLA graph; Pallas kernel in
    interpret mode) agree bit-exactly with the device oracle AND the
    host oracle on payloads, slots, and found — including >2^24 keys
    riding the f32 hi/lo pair and chain hits at the frozen max chain."""
    rng = np.random.default_rng(seed)
    span = 2 ** 40 if wide else 2 ** 22
    keys = np.unique(rng.choice(span, 25_000, replace=False)
                     ).astype(np.float64)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.15)
    # force chains (and exercise the CSR epilogue at max_chain)
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5),
                        keys)[:3000]
    idx.gapped.insert_batch(mids, 7_000_000 + np.arange(len(mids)))
    arrs = from_learned_index(idx)
    assert arrs.key_wide == wide
    assert arrs.max_chain > 0
    plm = idx.mech.plm
    q = _mixed_queries(rng, keys, extra=[mids[:800], mids[:50] + 1.0])
    out_o, slot_o, found_o, _ = batched_lookup(arrs, plm.err_lo, q,
                                               backend="oracle")
    assert np.array_equal(np.asarray(out_o), idx.gapped.lookup_batch(q))
    for be in ("fused", "fused-pallas"):
        out, slot, found, fb = batched_lookup(
            arrs, plm.err_lo, q, backend=be, err_hi_by_seg=plm.err_hi,
            interpret=True)
        assert np.array_equal(np.asarray(out), np.asarray(out_o)), be
        assert np.array_equal(np.asarray(slot), np.asarray(slot_o)), be
        assert np.array_equal(np.asarray(found), np.asarray(found_o)), be
    # sorted fast path on the fused kernel (skips the lexsort/argsort)
    qs = np.sort(q)
    out_s, *_ = batched_lookup(arrs, plm.err_lo, qs,
                               backend="fused-pallas",
                               err_hi_by_seg=plm.err_hi, interpret=True,
                               queries_sorted=True)
    assert np.array_equal(np.asarray(out_s), idx.gapped.lookup_batch(qs))


def test_fused_wide_payloads_roundtrip():
    """int64 payloads ride the i32 hi/lo pair through both fused
    epilogues (in-kernel and XLA) and the host escape patch."""
    keys = make_keys("uniform_int", 12_000, seed=5)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.2)
    ga = idx.gapped
    big = np.int64(3) << 40
    ga.payload[ga.occupied] = big + ga.payload[ga.occupied]
    ga.links.chain_payloads[:] = big + ga.links.chain_payloads
    assert ga.links.total > 0
    ga._invalidate()
    arrs = from_learned_index(idx)
    assert arrs.wide
    rng = np.random.default_rng(6)
    q = _mixed_queries(rng, keys, n_hit=1000, n_miss=200)
    truth = ga.lookup_batch(q)
    assert truth.max() > np.iinfo(np.int32).max
    plm = idx.mech.plm
    for be in ("fused", "fused-pallas"):
        out, *_ = batched_lookup(arrs, plm.err_lo, q, backend=be,
                                 err_hi_by_seg=plm.err_hi, interpret=True)
        assert np.asarray(out).dtype == np.int64
        assert np.array_equal(np.asarray(out), truth), be


def test_fused_escape_patch_is_exact():
    """A poisoned rank table (every window 1 slot wide) flags nearly
    every query; the O(#escapes) host patch must still produce
    oracle-exact results — the fused path's stale-table soundness."""
    keys = make_keys("uniform_int", 10_000, seed=7)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.2)
    eng = QueryEngine.from_index(idx)
    rng = np.random.default_rng(7)
    q = _mixed_queries(rng, keys, n_hit=2000, n_miss=300)
    truth = idx.gapped.lookup_batch(q)
    import jax.numpy as jnp
    poisoned = np.minimum(eng._rank_np, eng._rank_np[len(eng._rank_np)//2])
    eng._rank_table = jnp.asarray(np.sort(poisoned))
    out, slot, found, fb = eng.lookup(q)
    assert fb > len(q) // 4          # the storm actually happened
    assert np.array_equal(np.asarray(out), truth)


def test_engine_schedules_fused_below_the_crossover():
    """The fused path owns the small/medium-batch regime: default
    engine resolution picks it at every bucket at and below the old
    ~8k crossover (the legacy xla stage used to be downgraded to the
    device oracle there)."""
    keys = make_keys("uniform_int", 20_000, seed=8)
    idx = LearnedIndex.build(keys, method="pgm", eps=64, gap_rho=0.15)
    eng = QueryEngine.from_index(idx)
    assert eng.backend == "fused"
    rng = np.random.default_rng(8)
    for n_q in (512, 1024, 4096):
        q = rng.choice(keys, n_q)
        out, *_ = eng.lookup(q)
        assert eng.last_stage == "fused", n_q
        assert np.array_equal(np.asarray(out), idx.gapped.lookup_batch(q))
    # legacy reference stages remain explicitly requestable
    eng.lookup(rng.choice(keys, 512), backend="xla", force_backend=True)
    assert eng.last_stage == "xla"
    # ...and the non-forced legacy xla request still downgrades
    eng.lookup(rng.choice(keys, 512), backend="xla")
    assert eng.last_stage == "oracle"


def test_handle_resolves_fused_and_serves_wide_keys():
    x = make_keys("uniform_int", 9_000, seed=9)
    wide_keys = np.unique(x + 2.0 ** 30)
    idx = Index.build(wide_keys, method="pgm", eps=64, gap_rho=0.1)
    assert idx.resolve_backend(4096).name == "fused"
    assert BACKENDS["fused"].wide_keys
    res = idx.lookup(wide_keys[:2048])
    assert res.backend == "fused"
    assert np.array_equal(res.payloads,
                          np.searchsorted(wide_keys, wide_keys[:2048]))


def test_incremental_bounds_match_full_recompute():
    """Property: the subset recompute (segments= + base=) reproduces the
    full query_window_bounds rows for the touched segments exactly."""
    rng = np.random.default_rng(11)
    keys = np.unique(rng.choice(2 ** 22, 15_000, replace=False)
                     ).astype(np.float64)
    idx = Index.build(keys, method="pgm", eps=32, gap_rho=0.2)
    lo0, hi0 = ops_mod.query_window_bounds(idx)
    # mutate a clustered slice, then recompute both ways
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    batch = mids[len(mids) // 3: len(mids) // 3 + 800]
    idx.gapped.insert_batch(batch, np.arange(800))
    full_lo, full_hi = ops_mod.query_window_bounds(idx)
    plm = idx.mech.plm
    segs = np.unique(plm.segment_of(batch))
    segs = np.unique(np.clip(np.concatenate([segs - 1, segs, segs + 1]),
                             0, plm.n_segments - 1))
    inc_lo, inc_hi = ops_mod.query_window_bounds(
        idx, segments=segs, base=(lo0, hi0))
    assert np.allclose(inc_lo[segs], full_lo[segs])
    assert np.allclose(inc_hi[segs], full_hi[segs])
    # untouched rows keep the base values
    other = np.setdiff1d(np.arange(plm.n_segments), segs)
    assert np.array_equal(inc_lo[other], np.asarray(lo0)[other])
    assert np.array_equal(inc_hi[other], np.asarray(hi0)[other])


def test_delta_refresh_tracks_refreeze_fallback_rate():
    """Acceptance: after clustered delta updates, the refreshed engine's
    fused fallback count equals the freshly refrozen engine's (ratio 1
    — well within the 2x bar), while results stay bit-identical."""
    rng = np.random.default_rng(12)
    keys = np.unique(rng.choice(2 ** 22, 20_000, replace=False)
                     ).astype(np.float64)
    idx = Index.build(keys, method="pgm", eps=64, gap_rho=0.15)
    idx.refreeze_contested_frac = 1.1
    idx.refreeze_link_growth = 10.0
    idx.fused_ingest_enabled = False  # this test measures the DELTA arm
    idx.sync_device()
    mids = np.setdiff1d(keys[:-1] + np.rint(np.diff(keys) * 0.5), keys)
    lo = len(mids) // 4
    for r in range(2):
        batch = mids[lo + r * 600: lo + (r + 1) * 600]
        rep = idx.ingest(batch, 5_000_000 + np.arange(600) + r)
        assert rep.device == "delta"
    assert idx.stats["bound_refreshes"] >= 1
    fresh = copy.deepcopy(idx)
    fresh.refreeze()
    probe = np.concatenate([rng.choice(keys, 3000),
                            mids[lo: lo + 1200],
                            mids[lo: lo + 200] + 1.0])
    res_d = idx.lookup(probe, backend="fused")
    res_f = fresh.lookup(probe, backend="fused")
    assert np.array_equal(res_d.payloads, res_f.payloads)
    assert np.array_equal(res_d.found, res_f.found)
    assert res_d.fallbacks <= 2 * max(res_f.fallbacks, 1)
