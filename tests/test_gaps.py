"""Gap insertion (§5): Eq.3 positions, placement invariants, lookup, MDL."""

import numpy as np
import pytest

from conftest import make_keys
from repro.core import LearnedIndex, build_gapped, gap_positions
from repro.core.mechanisms import PGMMechanism


def test_gap_positions_monotone_and_budget():
    x = make_keys("weblogs", 20_000, seed=0)
    y = np.arange(len(x), dtype=np.float64)
    plm = PGMMechanism(eps=128, recursive=False).fit(x, y).plm
    for rho in (0.05, 0.2, 0.5):
        yg = gap_positions(x, y, plm, rho)
        assert np.all(np.diff(yg) > 0)  # strict monotonicity preserved
        # budget: total inserted gaps <= rho * n (Eq. 2 constraint)
        assert yg[-1] - y[-1] <= rho * len(x) + 1


@pytest.mark.parametrize("kind", ["weblogs", "iot", "longitude"])
def test_gapped_improves_mae(kind):
    x = make_keys(kind, 30_000, seed=1)
    base = LearnedIndex.build(x, method="pgm", eps=128)
    gapped = LearnedIndex.build(x, method="pgm", eps=128, gap_rho=0.2)
    assert gapped.mdl().mae < base.mdl().mae  # §6.4: preciseness improves


def test_gapped_layout_invariants():
    x = make_keys("iot", 20_000, seed=2)
    g = LearnedIndex.build(x, method="pgm", eps=64, gap_rho=0.25).gapped
    # total order of the first-level array
    assert np.all(np.diff(g.slot_key[np.isfinite(g.slot_key)]) >= 0)
    # occupied slots carry exactly the stored minima; key count conserved
    chained, max_chain = g.link_stats()
    assert int(g.occupied.sum()) + chained == len(x)
    # every unoccupied slot's key equals the next occupied slot's key
    occ_idx = np.flatnonzero(g.occupied)
    for i in np.flatnonzero(~g.occupied)[:200]:
        nxt = occ_idx[np.searchsorted(occ_idx, i)] if i < occ_idx[-1] else None
        expect = g.slot_key[nxt] if nxt is not None else np.inf
        assert g.slot_key[i] == expect


def test_gapped_lookup_all_keys():
    x = make_keys("longitude", 15_000, seed=3)
    idx = LearnedIndex.build(x, method="fiting", eps=64, gap_rho=0.15)
    rng = np.random.default_rng(4)
    q = rng.choice(x, 4000)
    out = idx.lookup(q)
    truth = np.searchsorted(x, q)  # payloads were arange(n)
    assert np.array_equal(out, truth)
    # misses return -1
    miss = x[:-1] + np.diff(x) * 0.5
    miss = np.setdiff1d(miss, x)[:500]
    assert np.all(idx.lookup(miss) == -1)


def test_gapped_with_sampling_combo():
    """§5.4: sampling + gaps — still exact lookups, cheaper build."""
    x = make_keys("iot", 40_000, seed=5)
    idx = LearnedIndex.build(
        x, method="pgm", eps=64, gap_rho=0.2, sample_rate=0.02,
        rng=np.random.default_rng(5),
    )
    q = np.random.default_rng(6).choice(x, 3000)
    assert np.array_equal(idx.lookup(q), np.searchsorted(x, q))


def test_gap_fraction_tracks_rho():
    x = make_keys("weblogs", 20_000, seed=7)
    fracs = []
    for rho in (0.05, 0.2, 0.4):
        g = LearnedIndex.build(x, method="pgm", eps=128, gap_rho=rho).gapped
        fracs.append(g.gap_fraction)
    assert fracs[0] < fracs[1] < fracs[2]


def test_csr_link_export_roundtrip():
    x = make_keys("iot", 10_000, seed=8)
    g = LearnedIndex.build(x, method="pgm", eps=64, gap_rho=0.1).gapped
    offsets, keys, payloads = g.export_csr_links()
    assert offsets[-1] == g.link_stats()[0]
    for slot, chain in list(g.links.items())[:50]:
        o = offsets[slot]
        for t, (k, p) in enumerate(chain):
            assert keys[o + t] == k and payloads[o + t] == p
