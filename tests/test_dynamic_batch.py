"""Batched dynamic ops (§5.3): insert_batch / delete_batch must be
state-for-state identical to the sequential scalar paths (no hypothesis
dependency — runs even where tests/test_dynamic.py is skipped)."""

import copy

import numpy as np
import pytest

from conftest import make_keys
from repro.core import LearnedIndex


def _state_equal(g1, g2):
    return (np.array_equal(g1.slot_key, g2.slot_key)
            and np.array_equal(g1.occupied, g2.occupied)
            and np.array_equal(g1.payload, g2.payload)
            and g1.n_keys == g2.n_keys
            and dict(g1.links) == dict(g2.links))


@pytest.mark.parametrize("kind,seed", [
    ("iot", 0), ("iot", 1), ("weblogs", 2), ("uniform_int", 3),
])
def test_insert_batch_state_identical(kind, seed):
    rng = np.random.default_rng(seed)
    x = make_keys(kind, 16_000, seed=seed)
    perm = rng.permutation(len(x))
    n_ins = len(x) // 3
    init = np.sort(x[perm[n_ins:]])
    ins = x[perm[:n_ins]]
    pay = 1_000_000 + np.arange(n_ins)
    i_seq = LearnedIndex.build(init, method="pgm", eps=64, gap_rho=0.25)
    i_bat = copy.deepcopy(i_seq)
    for i, k in enumerate(ins):
        i_seq.insert(float(k), int(pay[i]))
    counts = i_bat.insert_batch(ins, pay)
    assert counts["slot"] + counts["chain"] == n_ins
    assert _state_equal(i_seq.gapped, i_bat.gapped)
    # every inserted + original key resolves identically afterwards
    q = np.concatenate([ins, rng.choice(init, 4_000)])
    assert np.array_equal(i_bat.lookup(q), i_seq.lookup(q))


def test_insert_batch_100k_state_identical_and_faster():
    """The acceptance-size run: 100k batched inserts == 100k sequential
    insert() calls (slot_key/occupied/payload/links), and faster."""
    import time

    x = make_keys("iot", 200_000, seed=11)
    rng = np.random.default_rng(11)
    perm = rng.permutation(len(x))
    n_ins = min(100_000, len(x) // 2)
    init = np.sort(x[perm[n_ins:]])
    ins = x[perm[:n_ins]]
    pay = 1_000_000 + np.arange(n_ins)
    i_seq = LearnedIndex.build(init, method="pgm", eps=128, gap_rho=0.3)
    i_bat = copy.deepcopy(i_seq)
    t0 = time.perf_counter()
    for i, k in enumerate(ins):
        i_seq.insert(float(k), int(pay[i]))
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    i_bat.insert_batch(ins, pay)
    t_bat = time.perf_counter() - t0
    assert _state_equal(i_seq.gapped, i_bat.gapped)
    assert t_bat < t_seq  # same result, strictly cheaper (typ. 5-9x here)


def test_insert_batch_duplicate_raises():
    x = make_keys("uniform_int", 4_000, seed=4)
    idx = LearnedIndex.build(x, method="pgm", eps=64, gap_rho=0.2)
    fresh = float(x[0]) + 0.5
    with pytest.raises(KeyError):
        idx.insert_batch(np.array([fresh, fresh]), np.array([1, 2]))
    with pytest.raises(KeyError):  # duplicate of an existing key
        idx2 = LearnedIndex.build(x, method="pgm", eps=64, gap_rho=0.2)
        idx2.insert_batch(np.array([float(x[17])]), np.array([3]))


def test_delete_batch_matches_sequential():
    x = make_keys("iot", 10_000, seed=6)
    rng = np.random.default_rng(6)
    i_seq = LearnedIndex.build(x, method="pgm", eps=64, gap_rho=0.25)
    i_bat = copy.deepcopy(i_seq)
    victims = rng.choice(x, 1_500, replace=False)
    for k in victims:
        assert i_seq.delete(float(k))
    removed = i_bat.delete_batch(victims)
    assert removed == len(victims)
    assert _state_equal(i_seq.gapped, i_bat.gapped)
    assert np.all(i_bat.lookup(victims) == -1)


def test_insert_batch_then_mixed_scalar_ops():
    """Batched and scalar dynamic ops interleave safely."""
    x = make_keys("iot", 8_000, seed=8)
    rng = np.random.default_rng(8)
    idx = LearnedIndex.build(x, method="pgm", eps=64, gap_rho=0.25)
    mids = x[:-1] + np.diff(x) * rng.random(len(x) - 1)
    new = np.setdiff1d(mids, x)[:3_000]
    idx.insert_batch(new, 500_000 + np.arange(len(new)))
    assert np.array_equal(idx.lookup(new), 500_000 + np.arange(len(new)))
    k = float(new[42])
    assert idx.update(k, 777) and idx.lookup(np.array([k]))[0] == 777
    assert idx.delete(k) and idx.lookup(np.array([k]))[0] == -1
    assert np.array_equal(idx.lookup(x), np.searchsorted(x, x))
