"""Shared fixtures.  NOTE: no XLA_FLAGS here — scripts/tier1.sh scopes
``--xla_force_host_platform_device_count=8`` to the pytest COMMAND only
(so tests/test_sharded_index.py exercises the real shard_map all-to-all
over 8 host devices), while the benchmark smoke step in the same script
still sees the real single CPU device; launch/dryrun.py forces its 512
placeholder devices in its own process.  Every test must also pass at
1 device (plain ``pytest``): the fan-out degenerates to D=1."""

import numpy as np
import pytest


def make_keys(kind: str, n: int, seed: int = 0) -> np.ndarray:
    """Synthetic key sets matching the paper's dataset families (small)."""
    rng = np.random.default_rng(seed)
    if kind == "weblogs":  # bursty periodic timestamps
        base = rng.exponential(1.0, n) * (1.0 + 8.0 * (rng.random(n) < 0.02))
        burst = 5.0 * np.sin(np.linspace(0, 40 * np.pi, n)) ** 2
        return np.unique(np.cumsum(base + burst))
    if kind == "iot":  # noisy multi-source timestamps
        srcs = [np.cumsum(rng.exponential(s, n // 4)) for s in (0.5, 1.0, 2.0, 5.0)]
        return np.unique(np.concatenate(srcs))
    if kind == "longitude":  # beta-mixture coordinates
        a = rng.beta(2, 5, n // 2) * 360 - 180
        b = rng.beta(8, 2, n - n // 2) * 360 - 180
        return np.unique(np.concatenate([a, b]))
    if kind == "uniform_int":  # f32-exact integer grid
        return np.unique(rng.choice(2 ** 22, n, replace=False)).astype(np.float64)
    raise KeyError(kind)


@pytest.fixture(scope="session")
def small_keys():
    return make_keys("weblogs", 20_000, seed=1)


@pytest.fixture(scope="session")
def int_keys():
    return make_keys("uniform_int", 30_000, seed=2)
