"""Dynamic scenario (§5.3): randomized + property-based oracle testing."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from conftest import make_keys
from repro.core import LearnedIndex


def _fresh(n=8000, rho=0.25, seed=0):
    x = make_keys("iot", n, seed=seed)
    return x, LearnedIndex.build(x, method="pgm", eps=64, gap_rho=rho)


def test_insert_then_lookup():
    x, idx = _fresh()
    rng = np.random.default_rng(1)
    mids = x[:-1] + np.diff(x) * rng.random(len(x) - 1)
    new = np.setdiff1d(mids, x)[:1500]
    for i, k in enumerate(new):
        idx.insert(float(k), 1_000_000 + i)
    got = idx.lookup(new)
    assert np.array_equal(got, 1_000_000 + np.arange(len(new)))
    # original keys unaffected
    q = rng.choice(x, 2000)
    assert np.array_equal(idx.lookup(q), np.searchsorted(x, q))


def test_insert_no_retrain_keeps_preciseness():
    """Inserted keys follow the learned distribution: MAE stays bounded."""
    x, idx = _fresh(n=12_000)
    before = idx.mdl().mae
    rng = np.random.default_rng(2)
    mids = x[:-1] + np.diff(x) * rng.random(len(x) - 1)
    new = np.setdiff1d(mids, x)[:3000]
    for i, k in enumerate(new):
        idx.insert(float(k), 2_000_000 + i)
    after = idx.mdl().mae
    assert after <= max(4.0 * before, 8.0)  # no blow-up without retraining


def test_delete_semantics():
    x, idx = _fresh()
    rng = np.random.default_rng(3)
    victims = rng.choice(x, 800, replace=False)
    for k in victims:
        assert idx.delete(float(k))
    assert np.all(idx.lookup(victims) == -1)
    survivors = np.setdiff1d(x, victims)
    q = rng.choice(survivors, 1500)
    assert np.array_equal(idx.lookup(q), np.searchsorted(x, q))
    # double delete fails
    assert not idx.delete(float(victims[0]))


def test_update_payload():
    x, idx = _fresh(n=4000)
    k = float(x[123])
    assert idx.update(k, 777)
    assert idx.lookup(np.array([k]))[0] == 777
    assert not idx.update(float(x[0] - 1.0), 1)  # absent key


def test_mixed_workload_against_dict_oracle():
    """Random interleaved insert/delete/update/lookup vs a dict oracle."""
    x, idx = _fresh(n=5000, seed=4)
    oracle = {float(k): int(p) for k, p in zip(x, np.searchsorted(x, x))}
    rng = np.random.default_rng(5)
    domain_lo, domain_hi = float(x[0]), float(x[-1])
    for step in range(3000):
        op = rng.random()
        if op < 0.4:  # insert fresh key
            k = float(rng.uniform(domain_lo, domain_hi))
            if k in oracle or k in (domain_lo, domain_hi):
                continue
            p = 5_000_000 + step
            idx.insert(k, p)
            oracle[k] = p
        elif op < 0.6 and oracle:  # delete existing
            k = float(rng.choice(list(oracle)))
            assert idx.delete(k)
            del oracle[k]
        elif op < 0.7 and oracle:  # update
            k = float(rng.choice(list(oracle)))
            oracle[k] = 9_000_000 + step
            assert idx.update(k, oracle[k])
        else:  # lookup a mix of present/absent keys
            keys = list(oracle)
            present = [float(rng.choice(keys)) for _ in range(3)]
            absent = [float(rng.uniform(domain_lo, domain_hi)) for _ in range(2)]
            absent = [a for a in absent if a not in oracle]
            got = idx.lookup(np.array(present + absent))
            want = [oracle[k] for k in present] + [-1] * len(absent)
            assert list(got) == want


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(64, 600),
    rho=st.floats(0.05, 0.5),
)
def test_property_insert_all_lookups_hold(seed, n, rho):
    """Property: after arbitrary inserts, every stored key is retrievable
    and key-position monotonicity of the first-level array holds."""
    rng = np.random.default_rng(seed)
    x = np.unique(rng.integers(0, 10 * n, n)).astype(np.float64)
    if len(x) < 8:
        return
    idx = LearnedIndex.build(x, method="fiting", eps=8, gap_rho=rho)
    extra = np.setdiff1d(
        np.unique(rng.integers(0, 10 * n, n // 2)).astype(np.float64) + 0.5, x
    )
    for i, k in enumerate(extra):
        idx.insert(float(k), 100_000 + i)
    g = idx.gapped
    finite = g.slot_key[np.isfinite(g.slot_key)]
    assert np.all(np.diff(finite) >= 0)
    assert np.array_equal(idx.lookup(x), np.searchsorted(x, x))
    assert np.array_equal(idx.lookup(extra), 100_000 + np.arange(len(extra)))
