"""Batched serving engine: continuous batching over prefill/decode rounds.

Scheduler: FIFO admission up to ``max_batch`` concurrent requests;
each round decodes one token for every active request (static batch
slots, padded), prefilling new admissions first.  The paged KV block
table is the gapped learned index (kv_cache.py) — every decode round
resolves the page of each (request, position) through the index.

Serving aggregation (``MicroBatchQueue``)
-----------------------------------------
Small index calls are dominated by fixed per-dispatch host overhead
(~0.5 ms on CPU: argument prep, executable launch, result fetch) — at
q<=1024 the fused lookup barely beats the numpy oracle even though the
device search itself is far faster.  The queue amortizes that overhead
across CALLERS instead of across keys:

* callers ``submit_lookup``/``submit_ingest`` and hold a ticket;
* ``flush()`` concatenates every pending lookup into ONE padded
  shape-bucketed batch (power-of-two buckets, so the engine reuses one
  compiled executable per bucket) and issues ONE fused dispatch; pending
  ingests are likewise coalesced into one ``Index.ingest`` — one handle
  call instead of one per caller (and a single fused device dispatch on
  engines with the fused write graph enabled);
* results demultiplex back per ticket, in submission order, as typed
  ``LookupResult``/``IngestReport`` slices.

The concat staging buffers are allocated once per shape bucket and
reused across flushes (the donated-buffer pattern: steady-state serving
stops re-allocating per call), and the padded tail repeats the last real
key, so every flush of a bucket replays the same executable on the same
buffer shapes.  ``ServingEngine`` routes its per-round page resolution
and admission-time prompt allocations through one queue — N concurrent
requests cost one dispatch per round, not N.

This engine is exercised end-to-end with reduced configs on CPU
(examples/serve_paged_kv.py, tests/test_serving.py); the same code lowers
for the production mesh in the decode dry-run cells.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.results import Overloaded
from ..models import Model
from .kv_cache import _PAGE_SHIFT, PagedKVCache


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int = -1


class MicroBatchQueue:
    """Cross-caller batch aggregation for index lookups/ingests (see
    module doc "Serving aggregation").  Single-threaded cooperative
    batching: callers submit, someone flushes, tickets resolve in
    submission order.

    ``index`` is any handle with ``lookup(queries) -> LookupResult``
    and ``ingest(keys, payloads) -> IngestReport`` — the single-device
    ``repro.core.Index``, the range-partitioned
    ``repro.dist.ShardedIndex`` (whose router then splits each
    coalesced flush across shards — one fan-out dispatch instead of one
    per caller), or a snapshot-isolated ``serving.EpochPipeline``.

    Admission control (ISSUE 8):

    * ``max_wait_ms`` — per-request deadline: the first pending submit
      arms a daemon timer that flushes a partially filled bucket when
      it fires, so a lone small caller never stalls waiting for
      bucket-full (``stats["deadline_flushes"]``).
    * ``max_depth`` — bounded queue: a submit past the bound resolves
      its ticket IMMEDIATELY to a typed ``core.Overloaded`` result
      (``stats["shed"]``) — explicit backpressure, never a silent hang
      and never an unbounded queue.
    * ingest retry — a raising ``index.ingest`` is retried
      ``ingest_retries`` times with exponential backoff, the final
      attempt forcing the host partition path
      (``fused_ingest_enabled=False``, restored after) so a
      misbehaving fused write graph degrades to the proven host path
      instead of failing the request.  ``InjectedCrash`` (process
      death) always propagates.
    """

    def __init__(self, index, min_bucket: int = 512,
                 max_wait_ms: Optional[float] = None,
                 max_depth: Optional[int] = None,
                 ingest_retries: int = 2,
                 retry_backoff_ms: float = 1.0,
                 faults=None, auditor=None, audit_every: int = 0):
        self.index = index
        self.min_bucket = max(1, int(min_bucket))
        self.max_wait_ms = max_wait_ms
        self.max_depth = max_depth
        self.ingest_retries = max(0, int(ingest_retries))
        self.retry_backoff_ms = float(retry_backoff_ms)
        self.faults = faults
        self.auditor = auditor
        self.audit_every = int(audit_every)
        self._lookups: list = []   #: guarded-by: _lock
        self._ingests: list = []   #: guarded-by: _lock
        self._results: dict = {}   #: guarded-by: _lock
        self._next_ticket = 0      #: guarded-by: _lock
        # reentrant: the deadline timer thread calls flush(); result()
        # nests flush() under the same lock on the caller thread
        self._lock = threading.RLock()
        #: guarded-by: _lock
        self._deadline_timer: Optional[threading.Timer] = None
        #: guarded-by: _lock
        self._async_error: Optional[BaseException] = None
        # per-bucket reused staging buffers (donated-buffer pattern):
        # one f64 concat target per padded shape, never re-allocated
        self._staging: dict = {}   #: guarded-by: _lock
        #: guarded-by: _lock
        self.stats = {"flushes": 0, "lookup_dispatches": 0,
                      "ingest_dispatches": 0, "coalesced_lookups": 0,
                      "coalesced_ingests": 0, "deadline_flushes": 0,
                      "shed": 0, "ingest_retries": 0,
                      "host_fallbacks": 0}

    def _ticket(self) -> int:
        """lock-held: _lock (every issuer is a locked public method)."""
        t = self._next_ticket
        self._next_ticket += 1
        return t

    def _raise_async_error(self) -> None:
        """lock-held: _lock"""
        err, self._async_error = self._async_error, None
        if err is not None:
            raise err

    def _depth(self) -> int:
        """lock-held: _lock"""
        return len(self._lookups) + len(self._ingests)

    def _shed(self, kind: str) -> int:
        """lock-held: _lock (called from the locked submit paths)."""
        t = self._ticket()
        self._results[t] = Overloaded(
            kind=kind, depth=self._depth(),
            max_depth=int(self.max_depth),
            epoch=int(getattr(self.index, "epoch", -1)))
        self.stats["shed"] += 1
        return t

    def _arm_deadline(self) -> None:
        """lock-held: _lock (called from the locked submit paths)."""
        if self.max_wait_ms is None or self._deadline_timer is not None:
            return
        t = threading.Timer(self.max_wait_ms / 1e3, self._deadline_fire)
        t.daemon = True
        self._deadline_timer = t
        t.start()

    def _cancel_deadline(self) -> None:
        """lock-held: _lock (flush()/close() call under their lock)."""
        t, self._deadline_timer = self._deadline_timer, None
        if t is not None:
            t.cancel()

    def _deadline_fire(self) -> None:
        with self._lock:
            self._deadline_timer = None
            if not (self._lookups or self._ingests):
                return
            self.stats["deadline_flushes"] += 1
            try:
                self.flush()
            except BaseException as e:  # surfaced on the next caller
                self._async_error = e   # touch — never lost silently

    def submit_lookup(self, keys) -> int:
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        if keys.shape[0] == 0:
            raise ValueError("submit_lookup: empty key batch")
        with self._lock:
            self._raise_async_error()
            if (self.max_depth is not None
                    and self._depth() >= self.max_depth):
                return self._shed("lookup")
            t = self._ticket()
            self._lookups.append((t, keys))
            self._arm_deadline()
            return t

    def submit_ingest(self, keys, payloads) -> int:
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        payloads = np.atleast_1d(np.asarray(payloads, np.int64))
        if keys.shape[0] == 0:
            raise ValueError("submit_ingest: empty key batch")
        if keys.shape != payloads.shape:
            raise ValueError("submit_ingest: payloads must match keys 1:1")
        with self._lock:
            self._raise_async_error()
            if (self.max_depth is not None
                    and self._depth() >= self.max_depth):
                return self._shed("ingest")
            t = self._ticket()
            self._ingests.append((t, keys, payloads))
            self._arm_deadline()
            return t

    def _bucket(self, n: int) -> int:
        b = self.min_bucket
        while b < n:
            b <<= 1
        return b

    def _stage(self, name: str, bucket: int, dtype) -> np.ndarray:
        """lock-held: _lock (only reached from flush())."""
        buf = self._staging.get((name, bucket))
        if buf is None:
            buf = np.empty(bucket, dtype)
            self._staging[(name, bucket)] = buf
        return buf

    def _ingest_with_retry(self, keys, pays):
        """Dispatch one coalesced ingest with retry-with-backoff and a
        final host-path fallback (see class doc).

        lock-held: _lock (only reached from flush()).

        Retries transient
        ``RuntimeError``s only — ``InjectedCrash`` (process death) and
        caller bugs (``KeyError``/``ValueError``: duplicate keys, shape
        mismatches) propagate immediately, since replaying them cannot
        succeed and may double-apply."""
        from ..robustness.faults import InjectedCrash
        last: Optional[BaseException] = None
        for attempt in range(self.ingest_retries + 1):
            force_host = attempt > 0 and attempt == self.ingest_retries
            target = self.index
            prev = getattr(target, "fused_ingest_enabled", None)
            try:
                if self.faults is not None:
                    self.faults.check("ingest")
                if force_host and hasattr(target, "fused_ingest_enabled"):
                    target.fused_ingest_enabled = False
                    self.stats["host_fallbacks"] += 1
                return target.ingest(keys, pays)
            except InjectedCrash:
                raise
            except RuntimeError as e:
                last = e
                self.stats["ingest_retries"] += 1
                time.sleep(self.retry_backoff_ms * (2 ** attempt) / 1e3)
            finally:
                if force_host and hasattr(target, "fused_ingest_enabled"):
                    target.fused_ingest_enabled = prev
        raise last

    def flush(self) -> None:
        """Coalesce everything pending into one dispatch per kind
        (ingests first, so lookups submitted after an ingest in the
        same flush window observe its writes) and demux the results.

        Raises ``RuntimeError`` when nothing is pending: a flush with
        zero submissions has no last real key to pad the staging buffer
        with, and silently reading the previous flush's stale staging
        contents is exactly the bug this guard closes."""
        with self._lock:
            self._cancel_deadline()
            if not self._ingests and not self._lookups:
                raise RuntimeError(
                    "MicroBatchQueue.flush() with nothing pending — "
                    "submit before flushing (stale staging buffers are "
                    "never read)")
            if self.faults is not None:
                self.faults.check("flush")
            if self._ingests:
                pend, self._ingests = self._ingests, []
                keys = np.concatenate([k for _, k, _ in pend])
                pays = np.concatenate([p for _, _, p in pend])
                rep = self._ingest_with_retry(keys, pays)
                for t, k, _ in pend:
                    self._results[t] = rep  # one report, shared per ticket
                self.stats["ingest_dispatches"] += 1
                self.stats["coalesced_ingests"] += len(pend)
                if (self.auditor is not None and self.audit_every
                        and self.stats["ingest_dispatches"]
                        % self.audit_every == 0):
                    self.auditor.assert_ok(self.index)
            if self._lookups:
                pend, self._lookups = self._lookups, []
                sizes = [k.shape[0] for _, k in pend]
                n = int(sum(sizes))
                bucket = self._bucket(n)
                buf = self._stage("lookup", bucket, np.float64)
                off = 0
                for _, k in pend:
                    buf[off: off + k.shape[0]] = k
                    off += k.shape[0]
                buf[off:] = buf[off - 1]  # pad: repeat the last real key
                res = self.index.lookup(buf)
                off = 0
                for (t, k), sz in zip(pend, sizes):
                    sl = slice(off, off + sz)
                    self._results[t] = dataclasses.replace(
                        res, payloads=res.payloads[sl],
                        slots=res.slots[sl], found=res.found[sl])
                    off += sz
                self.stats["lookup_dispatches"] += 1
                self.stats["coalesced_lookups"] += len(pend)
            self.stats["flushes"] += 1

    def result(self, ticket: int):
        """Pop a ticket's typed result (flushing pending work first if
        the ticket is still queued).  Each ticket resolves EXACTLY
        once — a duplicate read, or a ticket this queue never issued,
        raises ``KeyError`` instead of triggering a spurious flush.
        A shed ticket resolves to its ``Overloaded`` marker here."""
        with self._lock:
            self._raise_async_error()
            if ticket in self._results:
                return self._results.pop(ticket)
            pending = (any(t == ticket for t, _ in self._lookups)
                       or any(t == ticket for t, _, _ in self._ingests))
            if pending:
                self.flush()
                return self._results.pop(ticket)
            if 0 <= ticket < self._next_ticket:
                raise KeyError(
                    f"ticket {ticket} already consumed — results resolve "
                    "exactly once")
            raise KeyError(f"unknown ticket {ticket} (never issued by "
                           "this queue)")

    def close(self) -> None:
        """Cancel the deadline timer (join not needed: the timer body
        only takes the lock and returns when nothing is pending)."""
        with self._lock:
            self._cancel_deadline()


class ServingEngine:
    def __init__(self, model: Model, max_batch: int = 4,
                 max_len: int = 256, page_size: int = 16,
                 temperature: float = 0.0):
        self.model = model
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.params = None
        self.caches = None
        self.cache_index = 0
        self.kv_pages = PagedKVCache.create(
            n_pages=max_batch * (max_len // page_size + 1),
            page_size=page_size, expected_requests=max_batch * 4)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        # cross-caller aggregation over the block-table index: one
        # dispatch per round for all concurrent requests' page lookups
        self.aggregator = MicroBatchQueue(self.kv_pages.index)
        self.stats = {"decoded_tokens": 0, "rounds": 0, "page_lookups": 0}
        self._decode = jax.jit(model.decode_fn)

    def load(self, params):
        self.params = params
        self.caches = self.model.init_caches(self.max_batch, self.max_len)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        free_slots = [s for s in range(self.max_batch)
                      if s not in {r.slot for r in self.active.values()}]
        rids, pages = [], []
        while self.queue and free_slots:
            req = self.queue.pop(0)
            req.slot = free_slots.pop(0)
            self.active[req.request_id] = req
            n_pages = len(req.prompt) // self.kv_pages.page_size + 1
            rids.append(np.full(n_pages, req.request_id, np.int64))
            pages.append(np.arange(n_pages, dtype=np.int64))
        if rids:
            # ONE coalesced prompt allocation for every request admitted
            # this round — on a device-resident block table this is one
            # fused ingest dispatch, not one per request
            self.kv_pages.alloc_batch(np.concatenate(rids),
                                      np.concatenate(pages))

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return np.argmax(logits, axis=-1)
        probs = jax.nn.softmax(jnp.asarray(logits) / self.temperature, -1)
        return np.asarray(jax.random.categorical(
            jax.random.PRNGKey(self.stats["rounds"]), jnp.log(probs), axis=-1))

    def step(self):
        """One decode round for all active requests."""
        self._admit()
        if not self.active:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for req in self.active.values():
            last = (req.generated[-1] if req.generated
                    else int(req.prompt[-1]) % self.model.cfg.vocab)
            tokens[req.slot, 0] = last
        # resolve the current page of every active request via the index:
        # ONE batched lookup for the whole round, then one batched alloc
        # for the misses (instead of a per-request lookup+insert loop)
        rids = np.array([r.request_id for r in self.active.values()])
        pages = np.array([
            (len(r.prompt) + len(r.generated)) // self.kv_pages.page_size
            for r in self.active.values()])
        ticket = self.aggregator.submit_lookup(
            ((rids.astype(np.int64) << _PAGE_SHIFT)
             | pages.astype(np.int64)).astype(np.float64))
        self.aggregator.flush()
        known = np.asarray(
            self.aggregator.result(ticket).payloads).astype(np.int64)
        miss = known < 0
        if np.any(miss):
            self.kv_pages.alloc_batch(rids[miss], pages[miss])
        self.stats["page_lookups"] += len(rids)

        logits, self.caches = self._decode(
            self.params, {"tokens": jnp.asarray(tokens)}, self.caches,
            jnp.int32(self.cache_index))
        self.cache_index = min(self.cache_index + 1, self.max_len - 1)
        nxt = self._sample(np.asarray(logits, np.float32))
        for req in list(self.active.values()):
            tok = int(nxt[req.slot])
            req.generated.append(tok)
            self.stats["decoded_tokens"] += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.kv_pages.free_request(
                    req.request_id,
                    (len(req.prompt) + len(req.generated))
                    // self.kv_pages.page_size + 1)
                del self.active[req.request_id]
        self.stats["rounds"] += 1

    def run_until_done(self, max_rounds: int = 1000):
        t0 = time.perf_counter()
        while (self.queue or self.active) and self.stats["rounds"] < max_rounds:
            self.step()
        self.stats["wall_s"] = time.perf_counter() - t0
        # block-table health: epoch distance covered by cheap delta
        # updates vs full refreezes (the Index handle's device sync)
        idx = self.kv_pages.index
        self.stats["kv_epoch"] = idx.epoch
        self.stats["kv_delta_updates"] = idx.stats["delta_updates"]
        self.stats["kv_refreezes"] = idx.stats["refreezes"]
        return self.stats
