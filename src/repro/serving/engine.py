"""Batched serving engine: continuous batching over prefill/decode rounds.

Scheduler: FIFO admission up to ``max_batch`` concurrent requests;
each round decodes one token for every active request (static batch
slots, padded), prefilling new admissions first.  The paged KV block
table is the gapped learned index (kv_cache.py) — every decode round
resolves the page of each (request, position) through the index.

This engine is exercised end-to-end with reduced configs on CPU
(examples/serve_paged_kv.py, tests/test_serving.py); the same code lowers
for the production mesh in the decode dry-run cells.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model
from .kv_cache import PagedKVCache


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int = -1


class ServingEngine:
    def __init__(self, model: Model, max_batch: int = 4,
                 max_len: int = 256, page_size: int = 16,
                 temperature: float = 0.0):
        self.model = model
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.params = None
        self.caches = None
        self.cache_index = 0
        self.kv_pages = PagedKVCache.create(
            n_pages=max_batch * (max_len // page_size + 1),
            page_size=page_size, expected_requests=max_batch * 4)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.stats = {"decoded_tokens": 0, "rounds": 0, "page_lookups": 0}
        self._decode = jax.jit(model.decode_fn)

    def load(self, params):
        self.params = params
        self.caches = self.model.init_caches(self.max_batch, self.max_len)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        free_slots = [s for s in range(self.max_batch)
                      if s not in {r.slot for r in self.active.values()}]
        while self.queue and free_slots:
            req = self.queue.pop(0)
            req.slot = free_slots.pop(0)
            self.active[req.request_id] = req
            # allocate pages for the prompt through the learned index
            # (one batched §5.3 insert for the whole prompt)
            n_pages = len(req.prompt) // self.kv_pages.page_size + 1
            self.kv_pages.alloc_batch(
                np.full(n_pages, req.request_id, np.int64),
                np.arange(n_pages, dtype=np.int64))

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return np.argmax(logits, axis=-1)
        probs = jax.nn.softmax(jnp.asarray(logits) / self.temperature, -1)
        return np.asarray(jax.random.categorical(
            jax.random.PRNGKey(self.stats["rounds"]), jnp.log(probs), axis=-1))

    def step(self):
        """One decode round for all active requests."""
        self._admit()
        if not self.active:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for req in self.active.values():
            last = (req.generated[-1] if req.generated
                    else int(req.prompt[-1]) % self.model.cfg.vocab)
            tokens[req.slot, 0] = last
        # resolve the current page of every active request via the index:
        # ONE batched lookup for the whole round, then one batched alloc
        # for the misses (instead of a per-request lookup+insert loop)
        rids = np.array([r.request_id for r in self.active.values()])
        pages = np.array([
            (len(r.prompt) + len(r.generated)) // self.kv_pages.page_size
            for r in self.active.values()])
        known = self.kv_pages.lookup_batch(rids, pages)
        miss = known < 0
        if np.any(miss):
            self.kv_pages.alloc_batch(rids[miss], pages[miss])
        self.stats["page_lookups"] += len(rids)

        logits, self.caches = self._decode(
            self.params, {"tokens": jnp.asarray(tokens)}, self.caches,
            jnp.int32(self.cache_index))
        self.cache_index = min(self.cache_index + 1, self.max_len - 1)
        nxt = self._sample(np.asarray(logits, np.float32))
        for req in list(self.active.values()):
            tok = int(nxt[req.slot])
            req.generated.append(tok)
            self.stats["decoded_tokens"] += 1
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.kv_pages.free_request(
                    req.request_id,
                    (len(req.prompt) + len(req.generated))
                    // self.kv_pages.page_size + 1)
                del self.active[req.request_id]
        self.stats["rounds"] += 1

    def run_until_done(self, max_rounds: int = 1000):
        t0 = time.perf_counter()
        while (self.queue or self.active) and self.stats["rounds"] < max_rounds:
            self.step()
        self.stats["wall_s"] = time.perf_counter() - t0
        # block-table health: epoch distance covered by cheap delta
        # updates vs full refreezes (the Index handle's device sync)
        idx = self.kv_pages.index
        self.stats["kv_epoch"] = idx.epoch
        self.stats["kv_delta_updates"] = idx.stats["delta_updates"]
        self.stats["kv_refreezes"] = idx.stats["refreezes"]
        return self.stats
