from .kv_cache import PagedKVCache
from .engine import ServingEngine, Request

__all__ = ["PagedKVCache", "ServingEngine", "Request"]
