"""Serving layer: continuous batching, cross-caller aggregation, and
crash-safe concurrent serving.

Serving & durability contract
-----------------------------
The serving stack composes four layers, each independently usable:

* **Snapshot isolation** (``pipeline.EpochPipeline``): lookups serve a
  *pinned immutable snapshot* of epoch N while ingest builds epoch N+1
  on the live index; ``publish()`` pins the new epoch completely and
  swaps the served reference in one assignment — barrier-free, no
  observable half-built epoch.  Typed results carry the epoch they were
  served at (``LookupResult.epoch``).  **Bit-identity guarantee**: a
  concurrent snapshot lookup equals a quiesced lookup at the snapshot
  epoch bit-for-bit — the snapshot runs the proven host path over the
  frozen arrays, and the repo's backend contract (fused / pallas /
  oracle identical) extends that to every device backend.  Pinning is
  O(1); the live side pays one copy-on-write on its first post-pin
  mutation (``core.gaps.GappedArray.pin_snapshot``).

* **Durability** (``wal.IngestWAL`` + ``core.Index.save_snapshot`` /
  ``dist.ShardedIndex.save_snapshot``): ingests are CRC-framed to a
  write-ahead log *before* application; ``publish`` fences the epoch
  (fsync); ``EpochPipeline.checkpoint`` snapshots the live index with
  the current WAL offset.  **Recovery invariant**: after a crash at ANY
  byte boundary, ``wal.recover_index(snapshot_dir, wal_path)`` =
  latest snapshot + WAL-tail replay reproduces the pre-crash acked
  state bit-for-bit — a torn trailing record (bad CRC / short frame)
  is truncated, never partially applied, and records at or below the
  snapshot's ``wal_lsn`` are skipped, never double-applied.

* **Admission control** (``engine.MicroBatchQueue``): bounded queue
  depth with typed ``core.Overloaded`` shed (explicit backpressure,
  never a silent hang), ``max_wait_ms`` deadline flush for lone small
  callers, and ingest retry-with-backoff whose final attempt degrades
  to the proven host partition path (``fused_ingest_enabled=False``,
  restored after).  ``robustness.InjectedCrash`` always propagates —
  retry loops must not absorb process death.

* **Self-tuning retrain** (``EpochPipeline.retrain``): the live index
  can be REBUILT — a §4 sampled refit of the live key set
  (``Index.retrain`` / ``ShardedIndex.retrain``, mechanism learning
  O(n_s)) — behind the pinned snapshot.  **Trigger policy**: callers
  decide (watch ``Index.mdl()`` drift or chain growth); the sharded
  rebalance watermark also retrains automatically when a shard is past
  the chain-depth watermark but too small to split.  **Snapshot
  guarantee**: retrain replaces the live arrays, never mutates them,
  so the pinned snapshot serves its epoch bit-identically for the
  whole rebuild; the retrained epoch (strictly monotone) serves only
  after ``publish()``.

* **Fault discipline** (``repro.robustness``): every layer above
  accepts a deterministic ``FaultInjector`` (site-keyed crash / abort /
  slow / torn-tail schedules) and an ``InvariantAuditor`` (slot + chain
  == n, CSR well-formedness, epoch monotonicity, snapshot pin
  refcounts), so the crash/recovery/shed paths are *property-tested*,
  not best-effort (tests/test_wal_recovery.py,
  tests/test_serving_pipeline.py, ``benchmarks/run.py --smoke``).
"""

from ..core.results import Overloaded
from .engine import MicroBatchQueue, Request, ServingEngine
from .kv_cache import PagedKVCache
from .pipeline import (EpochPipeline, IndexSnapshot, ShardedSnapshot,
                       pin_index)
from .wal import IngestWAL, WALRecord, recover_index, replay

__all__ = [
    "EpochPipeline",
    "IndexSnapshot",
    "IngestWAL",
    "MicroBatchQueue",
    "Overloaded",
    "PagedKVCache",
    "Request",
    "ServingEngine",
    "ShardedSnapshot",
    "WALRecord",
    "pin_index",
    "recover_index",
    "replay",
]
