"""Append-only ingest write-ahead log + crash recovery.

Record framing (little-endian, self-delimiting, torn-tail tolerant)::

    [magic u32 "WAL1"] [type u8] [body_len u32] [body ...] [crc32 u32]

* ``type=1`` (BATCH): body = ``n u32`` + ``n`` f64 keys + ``n`` i64
  payloads — one ingest batch, logged BEFORE it is applied.
* ``type=2`` (FENCE): body = ``epoch i64`` — an epoch-publish marker
  (``EpochPipeline.publish``); fences force an fsync, so every record
  below the last fence is durable.

The CRC covers ``type + body_len + body``, so a record is valid iff its
frame is complete AND its checksum matches.  ``replay`` walks records
front-to-back and stops cleanly at the first incomplete or corrupt
frame — a crash mid-write (torn tail) loses at most the record being
written, never earlier history.  Writes are flushed to the OS per
record (so ``lsn`` byte offsets are exact) and ``fsync``-batched every
``sync_every`` records (durability/throughput knob; fences always
sync).

Recovery (``recover_index``) = ``Index.restore`` of the newest
checkpoint (written through ``train/checkpoint.py``'s array
serialization — same format as trainer checkpoints) + replay of every
BATCH record past the checkpoint's recorded ``wal_lsn``.  Replay calls
``Index.ingest`` with the original batches in original order, which is
bit-identical to the uninterrupted run by the repo's proven ingest
determinism contracts (see tests/test_wal_recovery.py).
"""

from __future__ import annotations

import os
import struct
import threading
import time
import zlib
from typing import List, NamedTuple, Optional, Tuple, Union

import numpy as np

__all__ = ["IngestWAL", "WALRecord", "replay", "truncate_torn_tail",
           "recover_index"]

_MAGIC = 0x314C4157  # "WAL1" little-endian
_HDR = struct.Struct("<IBI")  # magic, type, body_len
_CRC = struct.Struct("<I")
REC_BATCH = 1
REC_FENCE = 2


class WALRecord(NamedTuple):
    kind: str                      # "batch" | "fence"
    keys: Optional[np.ndarray]    # f64 (batch) or None
    payloads: Optional[np.ndarray]  # i64 (batch) or None
    epoch: int                     # fence epoch (-1 for batch)
    lsn: int                       # byte offset PAST this record


class IngestWAL:
    """Append-only CRC-framed ingest log (one writer, crash-tolerant).

    ``append``/``fence`` return the record's ``lsn`` — the byte offset
    just past it.  A checkpoint taken at ``wal_lsn = wal.lsn`` plus a
    replay of records with ``lsn > wal_lsn`` reconstructs the exact
    pre-crash state (write-ahead discipline: log first, apply second).

    ``sync_every`` is either a record count (fsync every N records,
    the fixed group-commit knob) or ``"adaptive"`` — load-adaptive
    group commit: when writes arrive sparsely (inter-write gap above
    ``idle_s``) every record is fsynced on the spot (durability is
    cheap when the disk is idle and there is no batch to amortize
    into); under a burst, fsyncs are TIME-batched — at most one per
    ``burst_window_s`` — so a write storm pays O(elapsed/window)
    fsyncs instead of O(records/N).  Only fsync *cadence* changes:
    framing and per-record flushes are identical, so the torn-tail
    recovery property ("kill at any byte") is unaffected.

    Thread-safe: the serving stack appends from the caller thread and
    the deadline-timer thread concurrently; every public method takes
    ``_lock`` (reentrant — ``fence`` nests ``sync``).
    """

    def __init__(self, path, sync_every: Union[int, str] = 8,
                 idle_s: float = 0.005, burst_window_s: float = 0.005):
        self.path = str(path)
        self.adaptive = sync_every == "adaptive"
        self.sync_every = (1 if self.adaptive
                           else max(1, int(sync_every)))
        self.idle_s = float(idle_s)
        self.burst_window_s = float(burst_window_s)
        self._lock = threading.RLock()
        self._f = open(self.path, "ab")   #: guarded-by: _lock
        self._since_sync = 0              #: guarded-by: _lock
        self._last_write_t = 0.0          #: guarded-by: _lock
        self._last_sync_t = 0.0           #: guarded-by: _lock
        #: guarded-by: _lock
        self.stats = {"records": 0, "fences": 0, "syncs": 0,
                      "idle_syncs": 0, "window_syncs": 0}

    @property
    def lsn(self) -> int:
        with self._lock:
            return self._f.tell()

    def _sync_now(self) -> None:
        """lock-held: _lock (internal half of ``sync``)."""
        self._f.flush()
        os.fsync(self._f.fileno())
        self._since_sync = 0
        self._last_sync_t = time.monotonic()
        self.stats["syncs"] += 1

    def _maybe_sync_adaptive(self, now: float) -> None:
        """lock-held: _lock.  The load-adaptive group-commit policy
        (see class doc): idle -> sync per record; burst -> one sync per
        ``burst_window_s`` of elapsed time."""
        gap = now - self._last_write_t
        if gap > self.idle_s:
            self.stats["idle_syncs"] += 1
            self._sync_now()
        elif now - self._last_sync_t >= self.burst_window_s:
            self.stats["window_syncs"] += 1
            self._sync_now()

    def _write(self, rtype: int, body: bytes) -> int:
        """lock-held: _lock (append/fence wrap this)."""
        hdr = _HDR.pack(_MAGIC, rtype, len(body))
        crc = zlib.crc32(hdr[4:] + body)  # covers type+len+body
        self._f.write(hdr + body + _CRC.pack(crc))
        self._f.flush()  # OS-visible immediately: lsn/tell stays exact
        self.stats["records"] += 1
        self._since_sync += 1
        if self.adaptive:
            now = time.monotonic()
            self._maybe_sync_adaptive(now)
            self._last_write_t = now
        elif self._since_sync >= self.sync_every:
            self._sync_now()
        return self._f.tell()

    def append(self, keys, payloads) -> int:
        keys = np.ascontiguousarray(np.atleast_1d(
            np.asarray(keys, np.float64)))
        pays = np.ascontiguousarray(np.atleast_1d(
            np.asarray(payloads, np.int64)))
        if keys.shape != pays.shape:
            raise ValueError("IngestWAL.append: payloads must match "
                             "keys 1:1")
        body = (struct.pack("<I", keys.shape[0])
                + keys.tobytes() + pays.tobytes())
        with self._lock:
            return self._write(REC_BATCH, body)

    def fence(self, epoch: int) -> int:
        with self._lock:
            lsn = self._write(REC_FENCE, struct.pack("<q", int(epoch)))
            self._sync_now()  # a published epoch is always durable
            self.stats["fences"] += 1
            return lsn

    def sync(self) -> None:
        with self._lock:
            self._sync_now()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._sync_now()
                self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def replay(path) -> Tuple[List[WALRecord], int, bool]:
    """Parse a WAL file -> ``(records, valid_end, torn)``.

    Walks frames front-to-back; stops at the first incomplete frame,
    bad magic, CRC mismatch, or malformed body.  ``valid_end`` is the
    byte offset of the last fully valid record (everything past it is
    the torn/corrupt tail, reported via ``torn``).  A missing file is
    an empty log, not an error.
    """
    if not os.path.exists(path):
        return [], 0, False
    data = open(path, "rb").read()
    records: List[WALRecord] = []
    pos, n = 0, len(data)
    while pos < n:
        if pos + _HDR.size > n:
            return records, pos, True
        magic, rtype, blen = _HDR.unpack_from(data, pos)
        body_end = pos + _HDR.size + blen
        if magic != _MAGIC or body_end + _CRC.size > n:
            return records, pos, True
        body = data[pos + _HDR.size: body_end]
        (crc,) = _CRC.unpack_from(data, body_end)
        if crc != zlib.crc32(data[pos + 4: body_end]):
            return records, pos, True
        end = body_end + _CRC.size
        if rtype == REC_BATCH:
            if blen < 4:
                return records, pos, True
            (cnt,) = struct.unpack_from("<I", body)
            if blen != 4 + 16 * cnt:
                return records, pos, True
            keys = np.frombuffer(body, np.float64, cnt, offset=4).copy()
            pays = np.frombuffer(body, np.int64, cnt,
                                 offset=4 + 8 * cnt).copy()
            records.append(WALRecord("batch", keys, pays, -1, end))
        elif rtype == REC_FENCE:
            if blen != 8:
                return records, pos, True
            (epoch,) = struct.unpack_from("<q", body)
            records.append(WALRecord("fence", None, None, int(epoch),
                                     end))
        else:
            return records, pos, True  # unknown type: treat as torn
        pos = end
    return records, pos, False


def truncate_torn_tail(path) -> int:
    """Trim a torn/corrupt tail in place -> bytes dropped (0 if clean).

    After this the file ends on a record boundary and a fresh
    ``IngestWAL`` can append to it safely."""
    _, valid_end, torn = replay(path)
    if not torn:
        return 0
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(valid_end)
    return size - valid_end


def recover_index(snapshot_dir, wal_path, *, step: Optional[int] = None):
    """Crash recovery: newest checkpoint + WAL-tail replay.

    Returns ``(index, report)`` where ``index`` is a single-device
    ``Index`` or a ``ShardedIndex`` (dispatched on what the checkpoint
    directory holds) restored to the exact pre-crash state, and
    ``report`` records ``{"replayed", "skipped", "torn", "valid_end",
    "restored_step"}``.  Records at or below the checkpoint's
    ``wal_lsn`` are already folded into the snapshot and skipped; the
    torn tail (if any) is ignored, exactly like ``replay``.
    """
    sharded_manifest = os.path.join(str(snapshot_dir),
                                    "sharded_manifest.json")
    if os.path.exists(sharded_manifest):
        from ..dist.sharded import ShardedIndex
        idx, extra = ShardedIndex.restore(snapshot_dir, step=step)
    else:
        from ..core.handle import Index
        idx, extra = Index.restore(snapshot_dir, step=step)
    lsn0 = int(extra.get("wal_lsn", 0))
    records, valid_end, torn = replay(wal_path)
    replayed = skipped = 0
    for rec in records:
        if rec.kind != "batch":
            continue
        if rec.lsn <= lsn0:
            skipped += 1
            continue
        idx.ingest(rec.keys, rec.payloads)
        replayed += 1
    return idx, {"replayed": replayed, "skipped": skipped, "torn": torn,
                 "valid_end": valid_end,
                 "restored_step": extra.get("step")}
