"""Snapshot-isolated epoch pipelining for concurrent serving.

The epoch-versioned handle (``core.handle.Index``) is one synchronous
object: a lookup issued while an ingest is mutating it observes
whatever intermediate state the mutation left.  ``EpochPipeline``
double-buffers instead:

* **lookups** run against a *pinned immutable snapshot* of epoch N —
  the frozen first-level arrays + CSR link image captured by
  ``GappedArray.pin_snapshot()`` (zero-copy: the live side pays one
  copy-on-write per pin on its first post-pin mutation, see
  ``core/gaps.py``);
* **ingest** applies to the live index, building epoch N+1 (delta
  application / refreeze proceed on the live buffers — the snapshot
  never sees them);
* ``publish()`` pins N+1 *completely* and then swaps the served
  reference in one assignment — barrier-free: there is no window in
  which a lookup can observe a half-built epoch, because the old
  snapshot stays valid until the swap and the new one is immutable
  before it.

Typed results carry the epoch they were served at (``LookupResult
.epoch``).  Bit-identity: a snapshot lookup runs the proven
``GappedArray.lookup_batch`` host path over the pinned arrays, and the
repo's backend contract (fused / pallas / oracle identical payloads,
slots, found — tests/test_kernel_lookup.py, tests/test_fused_ingest.py)
makes that bit-identical to ANY quiesced lookup at the snapshot epoch.
The same holds per shard for ``ShardedIndex`` (``ShardedSnapshot`` pins
every shard plus the router boundaries and slot bases, mirroring the
exact host route).

Durability hooks: give the pipeline an ``IngestWAL`` and every ingest
is logged *before* it is applied (write-ahead), ``publish`` fences the
epoch (fsync), and ``checkpoint()`` snapshots the live index through
``Index.save_snapshot`` with the current WAL offset — crash recovery
is ``serving.wal.recover_index``.
"""

from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from ..core.results import LookupResult

__all__ = ["EpochPipeline", "IndexSnapshot", "ShardedSnapshot",
           "pin_index"]


class IndexSnapshot:
    """Pinned immutable serving snapshot of a single-device ``Index``.

    Refcounted: the pipeline holds one reference for the published
    snapshot; in-flight readers ``retain()`` before serving and
    ``release()`` after, so a concurrent ``publish()`` swapping the
    snapshot out cannot unpin the ``GapSnapshot`` (and stop its
    copy-on-write protection) under a reader mid-``lookup_batch``.
    The underlying pin drops only when the last reference goes."""

    def __init__(self, index):
        if index.gapped is None:
            raise ValueError(
                "snapshot serving needs a gapped build (gap_rho > 0); "
                "a static index has no mutation to isolate against")
        self.epoch = int(index.epoch)
        self._snap = index.gapped.pin_snapshot()
        self._refs = 1
        self._refs_lock = threading.Lock()

    @property
    def n_keys(self) -> int:
        return self._snap.n_keys

    def lookup(self, queries) -> LookupResult:
        queries = np.atleast_1d(np.asarray(queries, np.float64))
        pay, slot, found = self._snap.lookup_batch(queries, full=True)
        return LookupResult(payloads=pay, slots=slot, found=found,
                            backend="snapshot", epoch=self.epoch)

    def retain(self) -> "IndexSnapshot":
        with self._refs_lock:
            if self._refs <= 0:
                raise RuntimeError("retain() on a released snapshot")
            self._refs += 1
        return self

    def release(self) -> None:
        with self._refs_lock:
            self._refs -= 1
            last = self._refs == 0
        if last:
            self._snap.release()


class ShardedSnapshot:
    """Pinned immutable serving snapshot of a ``ShardedIndex``: one
    ``GapSnapshot`` per shard plus the router boundaries and slot bases
    frozen at pin time, so routing and the per-shard slot offsets match
    the pinned topology even across a concurrent ``split_shard``."""

    def __init__(self, sharded):
        self.epoch = int(sharded.epoch)
        self._bounds = sharded.router.bounds.copy()
        self._bases = sharded._slot_bases().copy()
        self._snaps = [sh.gapped.pin_snapshot() for sh in sharded.shards]
        self._refs = 1
        self._refs_lock = threading.Lock()

    @property
    def n_keys(self) -> int:
        return int(sum(s.n_keys for s in self._snaps))

    def lookup(self, queries) -> LookupResult:
        queries = np.atleast_1d(np.asarray(queries, np.float64))
        n = queries.shape[0]
        # exact route against the PINNED boundaries (route-left, same
        # rule as ShardRouter.route)
        dst = (np.searchsorted(self._bounds, queries, side="right")
               if self._bounds.size else np.zeros(n, np.int64))
        pay = np.full(n, -1, np.int64)
        slot = np.full(n, -1, np.int64)
        found = np.zeros(n, bool)
        for s in np.unique(dst):
            rows = np.flatnonzero(dst == s)
            p, sl, f = self._snaps[s].lookup_batch(queries[rows],
                                                   full=True)
            pay[rows] = p
            slot[rows] = np.where(sl >= 0, sl + self._bases[s], -1)
            found[rows] = f
        return LookupResult(payloads=pay, slots=slot, found=found,
                            backend="snapshot", epoch=self.epoch)

    def retain(self) -> "ShardedSnapshot":
        with self._refs_lock:
            if self._refs <= 0:
                raise RuntimeError("retain() on a released snapshot")
            self._refs += 1
        return self

    def release(self) -> None:
        with self._refs_lock:
            self._refs -= 1
            last = self._refs == 0
        if last:
            for s in self._snaps:
                s.release()


def pin_index(index):
    """Pin the appropriate snapshot type for ``index`` (duck-typed on
    ``shards``, like ``MicroBatchQueue``)."""
    if hasattr(index, "shards"):
        return ShardedSnapshot(index)
    return IndexSnapshot(index)


class EpochPipeline:
    """Double-buffered serving front over an ``Index``/``ShardedIndex``
    (see module doc).  Duck-type compatible with the handles where it
    matters — ``lookup(queries)`` / ``ingest(keys, payloads)`` /
    ``epoch`` / ``stats`` — so ``MicroBatchQueue`` aggregates over a
    pipeline unchanged.

    * ``wal``: optional ``serving.wal.IngestWAL`` — ingests are logged
      before application, ``publish`` fences the epoch.
    * ``publish_every``: auto-publish after that many ingests (None =
      manual ``publish()`` only).
    * ``auditor`` + ``audit_every``: optional
      ``robustness.faults.InvariantAuditor`` sampled every N ingests
      (every ingest when 1 — the tests' setting).
    * ``faults``: optional ``robustness.faults.FaultInjector``; sites
      ``"pipeline.ingest"`` and ``"pipeline.publish"`` are checked on
      the way in (deterministic crash/slow/abort injection).
    * ``retrain_mdl_drift`` + ``retrain_check_every``: MDL-drift
      retrain daemon — every N ``publish()`` calls the live index is
      scored under the §3 MDL framework and a relative description-
      length growth past the threshold (vs the last retrain's baseline)
      triggers ``retrain()`` automatically, closing the PR-9 "retrain
      triggering is caller policy" loop.  The retrained epoch is served
      from the NEXT publish (same isolation as a manual retrain).

    Thread safety: ``MicroBatchQueue``'s deadline timer drives
    ``ingest``/``publish`` from a daemon thread concurrent with caller-
    thread lookups — all snapshot/stat state is guarded by ``_lock``,
    and readers serve a ``retain()``-ed snapshot so a concurrent
    publish can never unpin it mid-read.
    """

    def __init__(self, index, *, wal=None,
                 publish_every: Optional[int] = None,
                 auditor=None, audit_every: int = 0, faults=None,
                 retrain_mdl_drift: Optional[float] = None,
                 retrain_check_every: int = 1):
        self.index = index
        self.wal = wal
        self.publish_every = publish_every
        self.auditor = auditor
        self.audit_every = int(audit_every)
        self.faults = faults
        self.retrain_mdl_drift = retrain_mdl_drift
        self.retrain_check_every = max(1, int(retrain_check_every))
        # reentrant: ingest() auto-publishes, publish() may auto-retrain
        self._lock = threading.RLock()
        self._snapshot = pin_index(index)     #: guarded-by: _lock
        self._ingests_since_publish = 0       #: guarded-by: _lock
        #: guarded-by: _lock
        self._mdl_baseline = (self._mdl_score()
                              if retrain_mdl_drift is not None else None)
        #: guarded-by: _lock
        self.stats = {"publishes": 0, "snapshot_lookups": 0,
                      "live_lookups": 0, "ingests": 0, "wal_records": 0,
                      "max_lag": 0, "audits": 0, "retrains": 0,
                      "mdl_retrains": 0, "mdl_checks": 0}

    # ------------------------------------------------------------------
    def _mdl_score(self) -> Optional[float]:
        """lock-held: _lock (init runs single-owner).  Total description
        length of the live index, None when it cannot be scored (no
        ``mdl`` on the handle — e.g. a ShardedIndex)."""
        fn = getattr(self.index, "mdl", None)
        if fn is None:
            return None
        return float(fn().mdl)  # MDLReport.mdl is a property

    @property
    def epoch(self) -> int:
        """Epoch lookups are currently served at (the pinned snapshot)."""
        with self._lock:
            return self._snapshot.epoch

    @property
    def live_epoch(self) -> int:
        return int(self.index.epoch)

    @property
    def lag(self) -> int:
        """Mutations applied to the live index but not yet published.
        Live and snapshot epochs are read under the lock — one
        consistent pair, not two racing reads."""
        with self._lock:
            return int(self.index.epoch) - self._snapshot.epoch

    # ------------------------------------------------------------------
    def lookup(self, queries, *, backend: Optional[str] = None
               ) -> LookupResult:
        """Serve a lookup at the published snapshot epoch.

        When the live index is quiesced at the snapshot epoch the call
        delegates to ``index.lookup`` (device backends and their
        telemetry) — bit-identical to the snapshot by the backend
        contract; the lock is held across the delegated call so a
        concurrent ingest cannot mutate the live index mid-lookup.
        While ingest is in flight (live epoch ahead), the pinned
        snapshot serves — retained first, so a concurrent ``publish``
        releasing its reference cannot unpin it under the reader."""
        with self._lock:
            snap = self._snapshot
            if int(self.index.epoch) == snap.epoch:
                self.stats["live_lookups"] += 1
                return self.index.lookup(queries, backend=backend)
            self.stats["snapshot_lookups"] += 1
            snap.retain()
        try:
            return snap.lookup(queries)
        finally:
            snap.release()

    def ingest(self, keys, payloads):
        """Apply an ingest batch to the LIVE index (epoch N+1 under
        construction); logged to the WAL first when one is attached.
        Lookups keep serving the pinned snapshot until ``publish``.
        The lock spans log+apply, so WAL append order is apply order
        even with the deadline-timer thread ingesting concurrently."""
        if self.faults is not None:
            self.faults.check("pipeline.ingest")
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        payloads = np.atleast_1d(np.asarray(payloads, np.int64))
        with self._lock:
            if self.wal is not None:
                self.wal.append(keys, payloads)  # write-ahead: log, THEN apply
                self.stats["wal_records"] += 1
            rep = self.index.ingest(keys, payloads)
            self.stats["ingests"] += 1
            self.stats["max_lag"] = max(
                self.stats["max_lag"],
                int(self.index.epoch) - self._snapshot.epoch)
            self._ingests_since_publish += 1
            if (self.auditor is not None and self.audit_every
                    and self.stats["ingests"] % self.audit_every == 0):
                self.stats["audits"] += 1
                self.auditor.assert_ok(self.index, pipeline=self)
            if (self.publish_every is not None
                    and self._ingests_since_publish >= self.publish_every):
                self.publish()
            return rep

    def retrain(self, sample_rate: Optional[float] = None,
                **kwargs) -> dict:
        """Sampled refit of the LIVE index (``Index.retrain`` /
        ``ShardedIndex.retrain``) behind the snapshot: the retrain
        REPLACES the live arrays (never mutates them), so the pinned
        snapshot keeps serving its epoch bit-identically for the whole
        rebuild — epoch N+1 here is a fresh mechanism + layout instead
        of an ingest delta, the "refreeze is a dial" path.  Call
        ``publish()`` to start serving the retrained epoch."""
        with self._lock:
            rec = self.index.retrain(sample_rate=sample_rate, **kwargs)
            self.stats["retrains"] = self.stats.get("retrains", 0) + 1
            self.stats["max_lag"] = max(
                self.stats["max_lag"],
                int(self.index.epoch) - self._snapshot.epoch)
            if self._mdl_baseline is not None:
                self._mdl_baseline = self._mdl_score()
            return rec

    def _maybe_retrain_on_drift(self) -> None:
        """lock-held: _lock (publish() calls under its lock).  The MDL-
        drift daemon: score the live index every ``retrain_check_every``
        publishes; relative growth past ``retrain_mdl_drift`` triggers
        a retrain (which resets the baseline)."""
        if self.retrain_mdl_drift is None or self._mdl_baseline is None:
            return
        if self.stats["publishes"] % self.retrain_check_every != 0:
            return
        self.stats["mdl_checks"] += 1
        score = self._mdl_score()
        if score is None:
            return
        if score > self._mdl_baseline * (1.0 + self.retrain_mdl_drift):
            self.stats["mdl_retrains"] += 1
            self.retrain()

    def publish(self) -> int:
        """Pin epoch N+1 completely, then swap the served reference
        under the lock (no partially built epoch is ever observable)
        and drop the pipeline's reference to the old pin — readers that
        ``retain()``-ed it finish undisturbed; the unpin happens when
        the last reference goes.  Fences the WAL, then runs the MDL-
        drift check.  Returns the newly served epoch."""
        if self.faults is not None:
            self.faults.check("pipeline.publish")
        with self._lock:
            new = pin_index(self.index)  # fully pinned BEFORE the swap
            old, self._snapshot = self._snapshot, new
            old.release()
            self._ingests_since_publish = 0
            if self.wal is not None:
                self.wal.fence(new.epoch)
            self.stats["publishes"] += 1
            self._maybe_retrain_on_drift()
            return new.epoch

    # ------------------------------------------------------------------
    def checkpoint(self, directory, *, step: Optional[int] = None,
                   keep: int = 3) -> str:
        """Snapshot the live index to ``directory`` with the current
        WAL offset recorded — the recovery anchor for
        ``serving.wal.recover_index``.  Locked so the saved state and
        the recorded LSN are one consistent cut."""
        with self._lock:
            lsn = int(self.wal.lsn) if self.wal is not None else 0
            return self.index.save_snapshot(directory, step=step,
                                            keep=keep, wal_lsn=lsn)

    def close(self) -> None:
        with self._lock:
            self._snapshot.release()
            if self.wal is not None:
                self.wal.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
