"""Paged KV cache whose block table is the paper's gapped learned index.

vLLM-style paging keeps a per-request block table (logical page ->
physical page) in a hash map.  Here the table is a *gapped learned
index* over composite keys ``request_id * 2^20 + logical_page``:

 * allocation = the paper's §5.3 **dynamic insert**: the predicted slot
   is usually a reserved gap (requests allocate pages in key order, the
   exact pattern result-driven gaps anticipate), so inserts are O(1)
   without rehashing/retraining;
 * lookup     = batched predict+bounded-search — the Pallas kernel path
   resolves every (request, page) of a decode batch in one shot;
 * free       = §5.3 delete.

The physical pages themselves are a free-list over a preallocated
(n_pages, page_size, ...) tensor per layer — standard paged attention;
this module manages the mapping, not the attention math.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core import LearnedIndex

_PAGE_SHIFT = 20  # up to 2^20 pages per request


def table_key(request_id: int, logical_page: int) -> int:
    return (request_id << _PAGE_SHIFT) | logical_page


@dataclasses.dataclass
class PagedKVCache:
    n_pages: int
    page_size: int
    index: LearnedIndex
    free_pages: List[int]
    allocated: Dict[int, int]  # composite key -> physical page

    @staticmethod
    def create(n_pages: int, page_size: int = 16,
               expected_requests: int = 256,
               gap_rho: float = 0.3) -> "PagedKVCache":
        """Bootstrap the block-table index from a synthetic key skeleton
        matching the (request, page) key distribution, with gaps reserved
        for the real allocations to land in (result-driven §5.1)."""
        skeleton = []
        pages_per_req = max(4, n_pages // max(expected_requests, 1))
        for r in range(1, expected_requests + 1):
            for p in range(0, pages_per_req, 2):  # every other page: gaps
                skeleton.append(table_key(r, p))
        keys = np.array(sorted(set(skeleton)), np.float64)
        index = LearnedIndex.build(keys, method="pgm", eps=16,
                                   gap_rho=gap_rho)
        # skeleton keys carry payload -1 (not an allocation)
        for slot in range(index.gapped.n_slots):
            if index.gapped.occupied[slot]:
                index.gapped.payload[slot] = -1
        for chain in index.gapped.links.values():
            chain[:] = [(k, -1) for k, _ in chain]
        return PagedKVCache(
            n_pages=n_pages, page_size=page_size, index=index,
            free_pages=list(range(n_pages)), allocated={})

    # ------------------------------------------------------------------
    def alloc(self, request_id: int, logical_page: int) -> int:
        if not self.free_pages:
            raise MemoryError("KV cache out of pages")
        phys = self.free_pages.pop()
        key = table_key(request_id, logical_page)
        kf = float(key)
        if self.index.gapped.lookup(kf) is not None:
            self.index.update(kf, phys)       # skeleton slot: claim it
        else:
            self.index.insert(kf, phys)       # dynamic insert into a gap
        self.allocated[key] = phys
        return phys

    def lookup_batch(self, request_ids: np.ndarray,
                     logical_pages: np.ndarray) -> np.ndarray:
        keys = ((request_ids.astype(np.int64) << _PAGE_SHIFT)
                | logical_pages.astype(np.int64)).astype(np.float64)
        return self.index.lookup(keys)

    def free_request(self, request_id: int, n_pages: int) -> None:
        for p in range(n_pages):
            key = table_key(request_id, p)
            phys = self.allocated.pop(key, None)
            if phys is not None and phys >= 0:
                self.free_pages.append(phys)
                self.index.delete(float(key))

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free_pages) / self.n_pages

    def insert_path_stats(self) -> Dict[str, float]:
        """Fraction of allocations that landed in reserved gap slots
        (the paper's dynamic-insert claim, measurable)."""
        g = self.index.gapped
        chained, _ = g.link_stats()
        total = max(len(self.allocated), 1)
        return {"gap_fraction_remaining": g.gap_fraction,
                "chained_keys": chained}
