"""Paged KV cache whose block table is the paper's gapped learned index.

vLLM-style paging keeps a per-request block table (logical page ->
physical page) in a hash map.  Here the table is a *gapped learned
index* over composite keys ``request_id * 2^20 + logical_page``, held by
the epoch-versioned ``repro.core.Index`` handle:

 * allocation = the paper's §5.3 **dynamic insert**: the predicted slot
   is usually a reserved gap (requests allocate pages in key order, the
   exact pattern result-driven gaps anticipate), so inserts are O(1)
   without rehashing/retraining.  ``index.ingest`` delta-updates the
   frozen device buffers in place — no more "mark dirty + refreeze the
   whole engine on the next lookup" dance;
 * lookup     = ``index.lookup`` — the handle resolves small batches on
   the numpy oracle and large ones on the device engine.  Composite keys
   beyond f32 exactness (2^24) ride the f32 hi/lo pair representation,
   so the device path serves them exactly (no host fallback guard);
 * free       = §5.3 delete (device state follows via delta on the next
   device lookup).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core import Index

_PAGE_SHIFT = 20  # up to 2^20 pages per request


def table_key(request_id: int, logical_page: int) -> int:
    return (request_id << _PAGE_SHIFT) | logical_page


@dataclasses.dataclass
class PagedKVCache:
    n_pages: int
    page_size: int
    index: Index
    free_pages: List[int]
    allocated: Dict[int, int]  # composite key -> physical page

    @staticmethod
    def create(n_pages: int, page_size: int = 16,
               expected_requests: int = 256,
               gap_rho: float = 0.3) -> "PagedKVCache":
        """Bootstrap the block-table index from a synthetic key skeleton
        matching the (request, page) key distribution, with gaps reserved
        for the real allocations to land in (result-driven §5.1)."""
        skeleton = []
        pages_per_req = max(4, n_pages // max(expected_requests, 1))
        for r in range(1, expected_requests + 1):
            for p in range(0, pages_per_req, 2):  # every other page: gaps
                skeleton.append(table_key(r, p))
        keys = np.array(sorted(set(skeleton)), np.float64)
        index = Index.build(keys, method="pgm", eps=16, gap_rho=gap_rho)
        # skeleton keys carry payload -1 (not an allocation)
        ga = index.gapped
        ga.payload[ga.occupied] = -1
        ga.links.chain_payloads[:] = -1
        return PagedKVCache(
            n_pages=n_pages, page_size=page_size, index=index,
            free_pages=list(range(n_pages)), allocated={})

    # ------------------------------------------------------------------
    def alloc(self, request_id: int, logical_page: int) -> int:
        if not self.free_pages:
            raise MemoryError("KV cache out of pages")
        phys = self.free_pages.pop()
        key = table_key(request_id, logical_page)
        kf = float(key)
        if self.index.gapped.lookup(kf) is not None:
            self.index.update(kf, phys)       # skeleton slot: claim it
        else:
            self.index.insert(kf, phys)       # dynamic insert into a gap
        self.allocated[key] = phys
        return phys

    def alloc_batch(self, request_ids: np.ndarray,
                    logical_pages: np.ndarray) -> np.ndarray:
        """Allocate many (request, page) mappings in one shot.

        Skeleton keys are claimed through ONE vectorized
        ``index.update_batch`` (payload-only scatter, one epoch bump);
        fresh keys go through ONE ``index.ingest`` — on engines with
        the fused write graph enabled (``Index.fused_ingest_enabled``,
        auto-on for Pallas) that is a single fused dispatch (placement
        + slot scatter + CSR merge + rank/bound refresh in one graph;
        composite keys are integers < 2^48, so they are pair-exact and
        the device compares are exact); otherwise the two-dispatch
        place-then-delta path.  The physical-page claim
        is a vectorized tail slice of the free list (same pages, same
        order as the old one-pop-per-page loop — the last host-side
        per-element copy on this path).  Returns the physical pages.
        """
        request_ids = np.atleast_1d(np.asarray(request_ids, np.int64))
        logical_pages = np.atleast_1d(np.asarray(logical_pages, np.int64))
        n = request_ids.shape[0]
        if n == 0:
            return np.zeros(0, np.int64)
        if len(self.free_pages) < n:
            raise MemoryError("KV cache out of pages")
        keys = (request_ids << _PAGE_SHIFT) | logical_pages
        kf = keys.astype(np.float64)
        phys = np.array(self.free_pages[: -n - 1: -1], np.int64)
        del self.free_pages[-n:]
        existing = self.index.gapped.contains_batch(kf)  # skeleton: claim
        if np.any(existing):
            self.index.update_batch(kf[existing], phys[existing])
        fresh = ~existing
        if np.any(fresh):
            self.index.ingest(kf[fresh], phys[fresh])
        self.allocated.update(zip(keys.tolist(), phys.tolist()))
        return phys

    def lookup_batch(self, request_ids: np.ndarray,
                     logical_pages: np.ndarray,
                     device: Optional[bool] = None) -> np.ndarray:
        """Batched (request, page) -> physical page; -1 for unmapped.

        ``device=None`` lets the handle's capability registry pick
        (numpy oracle below ``index.min_device_batch``, the fused
        single-dispatch engine above — composite keys beyond 2^24 ride
        the f32 hi/lo pair through the fused kernel's pair compares, so
        wide-key decode batches stay on device with no host-only
        guard).
        """
        keys = ((request_ids.astype(np.int64) << _PAGE_SHIFT)
                | logical_pages.astype(np.int64)).astype(np.float64)
        backend = None
        if device is True:
            backend = "fused"
        elif device is False:
            backend = "numpy-oracle"
        qsorted = bool(np.all(np.diff(keys) >= 0))
        res = self.index.lookup(keys, backend=backend,
                                queries_sorted=qsorted)
        return np.asarray(res.payloads).astype(np.int64)

    def free_request(self, request_id: int, n_pages: int) -> None:
        doomed = []
        for p in range(n_pages):
            key = table_key(request_id, p)
            phys = self.allocated.pop(key, None)
            if phys is not None and phys >= 0:
                self.free_pages.append(phys)
                doomed.append(float(key))
        if doomed:
            self.index.remove(np.asarray(doomed, np.float64))

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free_pages) / self.n_pages

    def insert_path_stats(self) -> Dict[str, float]:
        """Fraction of allocations that landed in reserved gap slots
        (the paper's dynamic-insert claim, measurable)."""
        g = self.index.gapped
        chained, _ = g.link_stats()
        return {"gap_fraction_remaining": g.gap_fraction,
                "chained_keys": chained,
                "epoch": self.index.epoch,
                "refreezes": self.index.stats["refreezes"],
                "delta_updates": self.index.stats["delta_updates"]}
