"""Paged KV cache whose block table is the paper's gapped learned index.

vLLM-style paging keeps a per-request block table (logical page ->
physical page) in a hash map.  Here the table is a *gapped learned
index* over composite keys ``request_id * 2^20 + logical_page``:

 * allocation = the paper's §5.3 **dynamic insert**: the predicted slot
   is usually a reserved gap (requests allocate pages in key order, the
   exact pattern result-driven gaps anticipate), so inserts are O(1)
   without rehashing/retraining;
 * lookup     = batched predict+bounded-search — the Pallas kernel path
   resolves every (request, page) of a decode batch in one shot;
 * free       = §5.3 delete.

The physical pages themselves are a free-list over a preallocated
(n_pages, page_size, ...) tensor per layer — standard paged attention;
this module manages the mapping, not the attention math.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..core import LearnedIndex

_PAGE_SHIFT = 20  # up to 2^20 pages per request
_ENGINE_MIN_BATCH = 512  # below this the numpy host path wins


def table_key(request_id: int, logical_page: int) -> int:
    return (request_id << _PAGE_SHIFT) | logical_page


@dataclasses.dataclass
class PagedKVCache:
    n_pages: int
    page_size: int
    index: LearnedIndex
    free_pages: List[int]
    allocated: Dict[int, int]  # composite key -> physical page
    _engine: Optional[object] = None  # lazy QueryEngine over a frozen snapshot
    _engine_dirty: bool = True

    @staticmethod
    def create(n_pages: int, page_size: int = 16,
               expected_requests: int = 256,
               gap_rho: float = 0.3) -> "PagedKVCache":
        """Bootstrap the block-table index from a synthetic key skeleton
        matching the (request, page) key distribution, with gaps reserved
        for the real allocations to land in (result-driven §5.1)."""
        skeleton = []
        pages_per_req = max(4, n_pages // max(expected_requests, 1))
        for r in range(1, expected_requests + 1):
            for p in range(0, pages_per_req, 2):  # every other page: gaps
                skeleton.append(table_key(r, p))
        keys = np.array(sorted(set(skeleton)), np.float64)
        index = LearnedIndex.build(keys, method="pgm", eps=16,
                                   gap_rho=gap_rho)
        # skeleton keys carry payload -1 (not an allocation)
        for slot in range(index.gapped.n_slots):
            if index.gapped.occupied[slot]:
                index.gapped.payload[slot] = -1
        for chain in index.gapped.links.values():
            chain[:] = [(k, -1) for k, _ in chain]
        return PagedKVCache(
            n_pages=n_pages, page_size=page_size, index=index,
            free_pages=list(range(n_pages)), allocated={})

    # ------------------------------------------------------------------
    def alloc(self, request_id: int, logical_page: int) -> int:
        if not self.free_pages:
            raise MemoryError("KV cache out of pages")
        self._engine_dirty = True
        phys = self.free_pages.pop()
        key = table_key(request_id, logical_page)
        kf = float(key)
        if self.index.gapped.lookup(kf) is not None:
            self.index.update(kf, phys)       # skeleton slot: claim it
        else:
            self.index.insert(kf, phys)       # dynamic insert into a gap
        self.allocated[key] = phys
        return phys

    def alloc_batch(self, request_ids: np.ndarray,
                    logical_pages: np.ndarray) -> np.ndarray:
        """Allocate many (request, page) mappings in one shot.

        Skeleton keys are claimed via update; fresh keys go through the
        vectorized ``insert_batch`` (§5.3 batched dynamic insert) instead
        of one predict + scan per page.  Returns the physical pages.
        """
        request_ids = np.atleast_1d(np.asarray(request_ids, np.int64))
        logical_pages = np.atleast_1d(np.asarray(logical_pages, np.int64))
        n = request_ids.shape[0]
        if n == 0:
            return np.zeros(0, np.int64)
        if len(self.free_pages) < n:
            raise MemoryError("KV cache out of pages")
        self._engine_dirty = True
        keys = (request_ids << _PAGE_SHIFT) | logical_pages
        kf = keys.astype(np.float64)
        phys = np.array([self.free_pages.pop() for _ in range(n)],
                        np.int64)
        existing = self.index.gapped.contains_batch(kf)  # skeleton: claim
        for k, ph in zip(kf[existing], phys[existing]):
            self.index.update(float(k), int(ph))
        fresh = ~existing
        if np.any(fresh):
            self.index.insert_batch(kf[fresh], phys[fresh])
        for k, ph in zip(keys.tolist(), phys.tolist()):
            self.allocated[k] = ph
        return phys

    def query_engine(self):
        """Single-pass device ``QueryEngine`` over the current table,
        refrozen lazily after mutations (alloc/free are the rare path in
        a decode loop; lookups are per round)."""
        from ..kernels import QueryEngine

        if self._engine is None or self._engine_dirty:
            self._engine = QueryEngine.from_index(self.index)
            self._engine_dirty = False
        return self._engine

    def lookup_batch(self, request_ids: np.ndarray,
                     logical_pages: np.ndarray,
                     device: Optional[bool] = None) -> np.ndarray:
        """Batched (request, page) -> physical page; -1 for unmapped.

        ``device=None`` picks the single-pass engine for large batches
        (serving issues sorted page lookups — the engine skips the sort)
        and the numpy reference for small ones.
        """
        keys = ((request_ids.astype(np.int64) << _PAGE_SHIFT)
                | logical_pages.astype(np.int64)).astype(np.float64)
        if device is None:
            # engine only for large, f32-exact batches (the device path
            # stores keys as f32; huge composite keys stay on the host)
            device = (keys.shape[0] >= _ENGINE_MIN_BATCH
                      and bool(np.all(
                          keys.astype(np.float32).astype(np.float64)
                          == keys)))
        if device:
            qsorted = bool(np.all(np.diff(keys) >= 0))
            out, *_ = self.query_engine().lookup(keys,
                                                 queries_sorted=qsorted)
            return np.asarray(out).astype(np.int64)
        return self.index.lookup(keys)

    def free_request(self, request_id: int, n_pages: int) -> None:
        self._engine_dirty = True
        for p in range(n_pages):
            key = table_key(request_id, p)
            phys = self.allocated.pop(key, None)
            if phys is not None and phys >= 0:
                self.free_pages.append(phys)
                self.index.delete(float(key))

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free_pages) / self.n_pages

    def insert_path_stats(self) -> Dict[str, float]:
        """Fraction of allocations that landed in reserved gap slots
        (the paper's dynamic-insert claim, measurable)."""
        g = self.index.gapped
        chained, _ = g.link_stats()
        total = max(len(self.allocated), 1)
        return {"gap_fraction_remaining": g.gap_fraction,
                "chained_keys": chained}
