"""Learning index with sampling (paper §4).

Uniform random sample ``D_s`` of size ``n_s = s * n`` (always including the
first and last key so the key domain is covered), fit the mechanism on the
sampled (key, *full-data position*) pairs, then patch so every unsampled
key is covered:

* FITing-Tree / PGM: **connect adjacent segments** — each segment's line is
  re-anchored to pass through the next segment's first (key, position), so
  predictions interpolate instead of extrapolating across sample holes.
* RMI: **RMI-Nearest-Seg** — empty (untrained) leaves are re-assigned to
  the nearest trained leaf (built into ``RMIMechanism.fit``).

Because sampling can violate the fitted error bounds on unsampled keys, the
paper switches correction to exponential search; we provide both that
(`exponential_search`, paper-faithful) and exact re-finalized bounds
(`refinalize_bounds`, the production path that keeps the Pallas bounded-
window kernel correct).

Theory hooks: `hoeffding_bound` (Prop. 1) and `sample_size_bound` (Thm. 1's
``O(alpha^2 log^2 E)`` guideline), exercised in tests and Fig. 8.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .mechanisms import PiecewiseLinearModel, _finalize_errors

__all__ = [
    "sample_pairs",
    "spawn_rngs",
    "connect_segments",
    "refinalize_bounds",
    "exponential_search",
    "hoeffding_bound",
    "sample_size_bound",
    "fit_sampled",
]

# fallback entropy for rng=None callers: a module-level SeedSequence
# spawner, so every anonymous sample draws an INDEPENDENT stream
# (deterministic per process, but never the same stream twice — a fixed
# default_rng(0) here made every per-shard build and every retrain
# sample identically, hiding sampling variance entirely)
_FALLBACK_SEEDS = np.random.SeedSequence(0x5A3D1E)


def _default_rng(rng: Optional[np.random.Generator]) -> np.random.Generator:
    if rng is not None:
        return rng
    return np.random.default_rng(_FALLBACK_SEEDS.spawn(1)[0])


def spawn_rngs(rng: Optional[np.random.Generator],
               n: int) -> list:
    """``n`` independent child generators derived from ``rng``.

    With an explicit ``rng`` the children are seeded by draws from it
    (deterministic given the parent's state, distinct per child — the
    per-shard / per-split threading contract).  With ``rng=None`` the
    children come from the module fallback pool, each independent."""
    if rng is None:
        return [np.random.default_rng(s) for s in _FALLBACK_SEEDS.spawn(n)]
    seeds = rng.integers(0, 2 ** 63 - 1, size=(n, 4))
    return [np.random.default_rng(np.random.SeedSequence(list(map(int, s))))
            for s in seeds]


def sample_pairs(
    x: np.ndarray,
    y: Optional[np.ndarray] = None,
    rate: float = 0.01,
    rng: Optional[np.random.Generator] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Uniform sample of (key, full-data position) pairs, endpoints forced."""
    rng = _default_rng(rng)
    n = x.shape[0]
    if y is None:
        y = np.arange(n, dtype=np.float64)
    if not (0.0 < rate <= 1.0):
        raise ValueError(f"sample rate must be in (0, 1], got {rate}")
    if rate >= 1.0:
        return np.asarray(x, np.float64), np.asarray(y, np.float64)
    n_s = max(2, int(round(rate * n)))
    idx = rng.choice(n, size=n_s, replace=False)
    idx = np.union1d(idx, np.array([0, n - 1]))
    return np.asarray(x, np.float64)[idx], np.asarray(y, np.float64)[idx]


def connect_segments(plm: PiecewiseLinearModel) -> PiecewiseLinearModel:
    """The paper's FIT/PGM sampling patch: connect adjacent segments.

    Segment k's line is redefined to run from (first_key_k, icept_k) to
    (first_key_{k+1}, icept_{k+1}); the last segment keeps its slope.
    Guarantees continuity, so unsampled keys between segment anchors are
    interpolated rather than extrapolated.
    """
    K = plm.n_segments
    if K <= 1:
        return plm
    fk, ic = plm.seg_first_key, plm.icept
    dk = fk[1:] - fk[:-1]
    new_slope = plm.slope.copy()
    safe = dk > 0
    new_slope[:-1] = np.where(safe, (ic[1:] - ic[:-1]) / np.where(safe, dk, 1.0), plm.slope[:-1])
    plm.slope = new_slope
    return plm


def refinalize_bounds(
    plm: PiecewiseLinearModel, x_full: np.ndarray, y_full: np.ndarray
) -> PiecewiseLinearModel:
    """Recompute exact per-segment error bounds on the *full* dataset.

    O(n) vectorized; restores the bounded-window search contract after
    sampling (production path for the Pallas kernel).
    """
    return _finalize_errors(
        plm, np.asarray(x_full, np.float64), np.asarray(y_full, np.float64)
    )


def exponential_search(
    sorted_keys: np.ndarray, queries: np.ndarray, y_hat: np.ndarray
) -> Tuple[np.ndarray, int]:
    """Paper-faithful correction step: exponential search around y_hat.

    Doubles the radius around the (clipped) prediction until the query is
    bracketed, then binary-searches the bracket.  Vectorized over queries;
    returns ``(positions, probes)`` — positions are the index of the exact
    match (or of the predecessor), probes is the TOTAL key-comparison
    count across the batch (2 per doubling round per unbracketed query +
    1 per bisect round per unresolved query), the correction-cost figure
    the benchmarks surface.
    """
    n = sorted_keys.shape[0]
    q = np.asarray(queries)
    pos = np.clip(np.rint(y_hat), 0, n - 1).astype(np.int64)
    radius = np.ones_like(pos)
    probes = 0
    # bracket: grow radius until sorted_keys[pos-r] <= q <= sorted_keys[pos+r]
    pending = pos.shape[0]
    for _ in range(64):  # 2^64 covers any n
        probes += 2 * pending  # both bracket ends are probed per round
        lo = np.maximum(pos - radius, 0)
        hi = np.minimum(pos + radius, n - 1)
        ok = (sorted_keys[lo] <= q) & (q <= sorted_keys[hi])
        ok |= (lo == 0) & (q <= sorted_keys[hi])
        ok |= (hi == n - 1) & (sorted_keys[lo] <= q)
        pending = int(np.count_nonzero(~ok))
        if pending == 0:
            break
        radius = np.where(ok, radius, radius * 2)
    lo = np.maximum(pos - radius, 0)
    hi = np.minimum(pos + radius, n - 1)
    # binary search within [lo, hi] for predecessor position of q
    for _ in range(int(np.ceil(np.log2(max(n, 2)))) + 2):
        probes += int(np.count_nonzero(lo < hi))
        mid = (lo + hi + 1) // 2
        go_right = sorted_keys[mid] <= q
        lo = np.where(go_right, mid, lo)
        hi = np.where(go_right, hi, mid - 1)
        done = lo >= hi
        if bool(np.all(done)):
            break
    return lo, int(probes)


def hoeffding_bound(max_err: float, n_s: int, delta: float = 0.05) -> float:
    """Prop. 1: |L(D_s|M) - L(D|M)| <= log2(E)/sqrt(2 n_s) * sqrt(log(2/delta))."""
    return float(
        np.log2(max(max_err, 2.0)) / np.sqrt(2.0 * n_s) * np.sqrt(np.log(2.0 / delta))
    )


def sample_size_bound(alpha: float, max_err: float, c: float = 1.0) -> int:
    """Thm. 1 asymptotic guideline: n_s = O(alpha^2 log^2 E)."""
    return int(np.ceil(c * (alpha ** 2) * (np.log2(max(max_err, 2.0)) ** 2)))


def fit_sampled(
    mechanism_factory,
    x: np.ndarray,
    y: Optional[np.ndarray] = None,
    rate: float = 0.01,
    rng: Optional[np.random.Generator] = None,
    patch: str = "connect",
    refinalize: bool = True,
):
    """Fit a mechanism on a sample, apply the coverage patch, return it.

    ``mechanism_factory()`` -> unfitted mechanism.  ``patch`` in
    {"connect", "none"}; RMI's nearest-seg patch is internal to its fit.
    With ``refinalize`` the error bounds are recomputed exactly on the full
    data (production path); otherwise callers should correct with
    ``exponential_search`` (paper-faithful path).
    """
    n = x.shape[0]
    if y is None:
        y = np.arange(n, dtype=np.float64)
    xs, ys = sample_pairs(x, y, rate=rate, rng=rng)
    mech = mechanism_factory()
    mech.fit(xs, ys)
    plm = getattr(mech, "plm", None)
    if plm is not None and patch == "connect" and mech.name in ("pgm", "fiting"):
        connect_segments(plm)
    if plm is not None and refinalize:
        refinalize_bounds(plm, x, y)
    return mech
