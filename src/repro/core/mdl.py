"""MDL-based index learning objective (paper §3).

``MDL(M, D) = L(M) + alpha * L(D|M)`` where

* ``L(M)`` — prediction cost: model size in parameters/bytes, or the number
  of arithmetic ops to evaluate ``M(x)`` (mechanism-reported).
* ``L(D|M)`` — expected correction cost: ``E[log2|y - y_hat| + 1]`` for a
  binary/exponential search around the prediction.

These are the exact instantiations the paper uses (§3.1 "Two Example
Instantiations", §3.2 "Choice of L(M) and L(D|M)").
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

__all__ = ["correction_cost", "mae", "MDLReport", "mdl_report"]


def correction_cost(y: np.ndarray, y_hat: np.ndarray) -> float:
    """L(D|M) = E[log2(|y - y_hat|) + 1]  (binary-search correction cost)."""
    err = np.abs(np.asarray(y, dtype=np.float64) - np.asarray(y_hat, dtype=np.float64))
    return float(np.mean(np.log2(np.maximum(err, 1.0)) + 1.0))


def mae(y: np.ndarray, y_hat: np.ndarray) -> float:
    """Mean absolute error between true and predicted positions (§6.1)."""
    return float(np.mean(np.abs(np.asarray(y, np.float64) - np.asarray(y_hat, np.float64))))


@dataclasses.dataclass
class MDLReport:
    """One mechanism evaluated under the MDL framework."""

    name: str
    l_model_params: int        # L(M) as parameter count
    l_model_ops: int           # L(M) as prediction op count
    l_model_bytes: int         # L(M) as bytes (paper's index-size accounting)
    l_data_given_model: float  # L(D|M), log2 correction cost
    mae: float
    max_abs_err: float         # the paper's E (drives sample-size bound)
    alpha: float = 1.0

    @property
    def mdl(self) -> float:
        """Description length with L(M) in params (paper Eq. 1)."""
        return self.l_model_params + self.alpha * self.l_data_given_model


def mdl_report(
    name: str,
    mechanism,
    x: np.ndarray,
    y: np.ndarray,
    alpha: float = 1.0,
    payload_bytes: int = 0,
) -> MDLReport:
    """Evaluate a fitted mechanism on (x, y) under the MDL framework."""
    y_hat = mechanism.predict(x)
    err = np.abs(np.asarray(y, np.float64) - y_hat)
    size_fn: Optional[Callable[[int], int]] = getattr(mechanism, "size_bytes", None)
    if size_fn is None and getattr(mechanism, "plm", None) is not None:
        size_bytes = mechanism.plm.size_bytes(payload_bytes)
    elif size_fn is not None:
        size_bytes = mechanism.size_bytes(payload_bytes)
    else:
        size_bytes = 8 * mechanism.param_count()
    return MDLReport(
        name=name,
        l_model_params=int(mechanism.param_count()),
        l_model_ops=int(mechanism.prediction_ops()),
        l_model_bytes=int(size_bytes),
        l_data_given_model=correction_cost(y, y_hat),
        mae=mae(y, y_hat),
        max_abs_err=float(max(err.max(), 1.0)),
        alpha=alpha,
    )
