"""Unified ``Index`` handle: one epoch-versioned object owning both the
mutable host state and the frozen device state.

The paper's pitch is *pluggability* — sampling (§4) and gap insertion
(§5) as knobs over any base mechanism.  ``Index`` is the one public
surface those knobs hang off:

* ``Index.build(keys, method=..., sample_rate=..., gap_rho=...)``
* reads:  ``index.lookup(queries) -> LookupResult`` (typed: payloads,
  slots, found mask, fallback/escape stats) on a backend chosen from the
  capability registry below;
* writes: ``index.ingest(keys, payloads) -> IngestReport`` /
  ``index.remove(keys)`` — §5.3 dynamic ops, no retraining.

Epoch protocol
--------------
Every host mutation bumps ``index.epoch`` (delegated to the gapped
array's version counter, so scalar ``insert``/``delete``/``update``
through any path count too).  The frozen device state records the epoch
it was built against; when it is AT the host epoch, ``ingest`` computes
the batch's placement primitives on the device first (the kernels
ingest-place backend — see ``repro.kernels`` "Ingest backend contract";
host-oracle fallback whenever exactness cannot be guaranteed), then a
device-backend lookup first brings the device forward:

* **delta update** (the common case): scatter only the changed
  slot_key/payload entries and CSR-link tail regions into the resident
  device buffers — no re-jit, no window-bound recompute, no full
  transfer;
* **full refreeze**: taken only when the contested-remainder fraction of
  an ingest or the link-chain growth since the last freeze crosses a
  threshold (stale windows / long chains degrade the single-pass rate),
  or when a shape/dtype static changed (link capacity, max-chain
  headroom, payload or key width).

Backend capability registry
---------------------------
=============  ======  ==========  =========  =====================
name           device  wide keys   min batch  notes
=============  ======  ==========  =========  =====================
fused          yes     yes (hi/lo  512        single-dispatch path:
                       f32 pair)              fused Pallas kernel on
                                              TPU, minimal-op fused
                                              XLA graph elsewhere
pallas         yes     no          512        LEGACY multi-op TPU
                                              kernel (debug/ref;
                                              interpret=True on CPU)
xla-windowed   yes     yes (hi/lo  512        legacy multi-op
                       f32 pair)              windowed bisect/rank
                                              (debug/reference)
numpy-oracle   no      yes (f64)   0          host reference; exact
=============  ======  ==========  =========  =====================

``lookup(backend=None)`` resolves: small batches go to ``numpy-oracle``;
everything else to ``fused`` — the single-dispatch path serves narrow
AND wide (hi/lo pair) keys on every platform, so it owns the whole
device regime including the small/medium batches the legacy multi-op
paths used to lose to the oracle.  ``pallas`` / ``xla-windowed`` remain
explicitly requestable as debug/reference stages.  Explicitly
requesting a backend that cannot serve the index (e.g. the legacy
``pallas`` with >2^24 composite keys) raises with the capability that
failed; keys aliasing beyond pair exactness (~2^48) refuse every device
backend.
"""

from __future__ import annotations

import copy as _copy
import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from . import gaps as _gaps
from . import mdl as _mdl
from . import sampling as _sampling
from .mechanisms import MECHANISMS
from .results import IngestReport, LookupResult, host_lookup_result

__all__ = ["Index", "BackendSpec", "BACKENDS"]


def _mechanism_factory(method: str, **kwargs):
    cls = MECHANISMS[method]
    return lambda: cls(**kwargs)


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Capability record for one lookup backend."""

    name: str
    device: bool            # runs on the frozen device arrays
    wide_keys: bool         # exact beyond f32 (2^24) key magnitudes
    min_batch: int          # below this the backend loses to the host
    engine_backend: Optional[str]  # kernels.QueryEngine backend name

    def available(self) -> bool:
        if not self.device:
            return True
        import jax
        if self.name == "pallas":
            # auto-pick only on TPU; explicit requests run interpreted
            return jax.default_backend() == "tpu"
        return True


BACKENDS: Dict[str, BackendSpec] = {
    "fused": BackendSpec("fused", device=True, wide_keys=True,
                         min_batch=512, engine_backend="fused"),
    "pallas": BackendSpec("pallas", device=True, wide_keys=False,
                          min_batch=512, engine_backend="pallas"),
    "xla-windowed": BackendSpec("xla-windowed", device=True, wide_keys=True,
                                min_batch=512, engine_backend="xla"),
    "numpy-oracle": BackendSpec("numpy-oracle", device=False, wide_keys=True,
                                min_batch=0, engine_backend=None),
}


@dataclasses.dataclass
class Index:
    """A built learned index over sorted unique f64 keys (see module doc).

    Host state: ``keys`` / ``mech`` / ``gapped``; device state: a lazily
    frozen ``kernels.QueryEngine`` plus the host mirror its delta updates
    diff against.  ``epoch`` versions the pair.
    """

    keys: np.ndarray
    mech: object
    method: str
    gapped: Optional[_gaps.GappedArray] = None
    sample_rate: float = 1.0
    gap_rho: float = 0.0
    build_seconds: float = 0.0
    # mechanism-learning share of build_seconds (base fit + Eq.3 +
    # step-3 refit — O(n_s) under sampling; placement excluded)
    learn_seconds: float = 0.0
    # mechanism kwargs the build used — retrain() replays them
    mech_kwargs: dict = dataclasses.field(default_factory=dict)
    # the auto-tuner's TunedChoice when built with method="auto"
    tuned: object = dataclasses.field(default=None, repr=False,
                                      compare=False)
    # --- device-sync policy knobs -------------------------------------
    refreeze_contested_frac: float = 0.25
    refreeze_link_growth: float = 0.10
    min_device_batch: int = 512
    # single-dispatch device-resident ingest (fused place + slot scatter
    # + CSR merge + rank/bound refresh in ONE dispatch, device buffers
    # adopted from the graph's outputs).  None = AUTO: on for Pallas
    # (accelerator) engines, where one kernel beats two dispatches +
    # host round trips; off for the fused-XLA CPU engine, where the
    # graph's fixed O(state) cost (full-array carried-key repair scan,
    # functional whole-buffer updates) loses to the sparse host delta
    # at steady state (measured in BENCH_ingest fused_dispatch rows).
    # True/False force the arm either way; the staleness benchmarks pin
    # False to keep exercising the delta machinery in isolation.
    fused_ingest_enabled: Optional[bool] = None
    # split commit: when the fused abort gate vetoes a batch, retry the
    # longest locally-clean PREFIX in-graph and replay only the
    # contested remainder on the host path (ROADMAP residual closed in
    # PR 8) — False restores whole-batch abort-to-host
    fused_split_commit: bool = True
    # delta updates refresh window bounds for touched segments only;
    # past this fraction of all segments the refresh is skipped (stale
    # bounds are sound — the refreeze policy catches sustained growth)
    refresh_segments_frac: float = 0.25
    # --- device state (rebuilt lazily; dropped on deepcopy) -----------
    _engine: object = dataclasses.field(default=None, repr=False,
                                        compare=False)
    _mirror: object = dataclasses.field(default=None, repr=False,
                                        compare=False)
    _device_epoch: int = dataclasses.field(default=-1, repr=False,
                                           compare=False)
    _keycap_cache: object = dataclasses.field(default=None, repr=False,
                                              compare=False)
    # mutated key values since the last device sync — feeds the
    # incremental window-bound refresh (chain inserts never show up in
    # the device slot diff, so the handle logs them itself)
    _pending_touch: list = dataclasses.field(default_factory=list,
                                             repr=False, compare=False)
    stats: dict = dataclasses.field(default_factory=lambda: {
        "refreezes": 0, "delta_updates": 0, "delta_elems": 0,
        "lookups": 0, "ingests": 0, "bound_refreshes": 0,
        "retrains": 0, "search_probes": 0})

    # ------------------------------------------------------------------
    @classmethod
    def build(
        cls,
        keys: np.ndarray,
        method: str = "pgm",
        sample_rate: float = 1.0,
        gap_rho: float = 0.0,
        rng: Optional[np.random.Generator] = None,
        payloads: Optional[np.ndarray] = None,
        shards: Optional[int] = None,
        **mech_kwargs,
    ):
        """Build an index.  ``payloads`` overrides the stored payload
        per key (default: the key's position, ``arange(n)``) — gapped
        builds only.  ``shards=`` is the escape hatch into the
        range-partitioned ``repro.dist.ShardedIndex`` (same call
        surface, per-shard gap-inserted builds + learned router).

        ``method="auto"`` runs the §3 MDL auto-tuner
        (``core.tuning.autotune``) over a (mechanism, eps, sample-size)
        grid on a sample of the keys and builds the winner; the choice
        is recorded on ``index.tuned``.  The defaults ``sample_rate=1.0``
        mean "let the tuner pick" under auto; pass an explicit rate to
        pin it."""
        if shards is not None:
            from ..dist.sharded import ShardedIndex
            return ShardedIndex.build(
                keys, shards=int(shards), method=method,
                sample_rate=sample_rate, gap_rho=gap_rho, rng=rng,
                payloads=payloads, **mech_kwargs)
        keys = np.asarray(keys, np.float64)
        if keys.ndim != 1 or keys.shape[0] < 2:
            raise ValueError("need a 1-D array of at least two keys")
        if not bool(np.all(np.diff(keys) > 0)):
            raise ValueError("keys must be sorted, strictly increasing (unique)")
        if payloads is not None:
            payloads = np.asarray(payloads, np.int64)
            if payloads.shape != keys.shape:
                raise ValueError("payloads must match keys 1:1")
            if gap_rho <= 0.0:
                raise ValueError("explicit payloads need a gapped build "
                                 "(gap_rho > 0); static builds store "
                                 "positions")
        tuned = None
        if method == "auto":
            from . import tuning as _tuning
            tuned = _tuning.autotune(
                keys, queries=mech_kwargs.pop("queries", None),
                dynamic=gap_rho > 0.0, rng=rng,
                **{k: mech_kwargs.pop(k) for k in
                   ("alpha", "size_budget_bytes", "max_err_budget")
                   if k in mech_kwargs})
            method = tuned.method
            mech_kwargs = dict(tuned.mech_kwargs, **mech_kwargs)
            if sample_rate >= 1.0:  # default sentinel: tuner's pick
                sample_rate = tuned.sample_rate
        factory = _mechanism_factory(method, **mech_kwargs)
        t0 = time.perf_counter()
        if gap_rho > 0.0:
            refit_factory = None
            if method in ("pgm", "fiting") and "eps" in mech_kwargs:
                # D_g is near-linear: tighter refit eps => precise
                # placement, short linking arrays (beyond-paper knob)
                rkw = dict(mech_kwargs)
                rkw["eps"] = max(4.0, float(mech_kwargs["eps"]) / 16.0)
                refit_factory = _mechanism_factory(method, **rkw)
            ga = _gaps.build_gapped(
                factory, keys, payloads=payloads, rho=gap_rho,
                sample_rate=sample_rate, rng=rng,
                refit_factory=refit_factory,
            )
            mech = ga.mech
            gapped = ga
        else:
            gapped = None
            if sample_rate < 1.0:
                mech = _sampling.fit_sampled(factory, keys, rate=sample_rate,
                                             rng=rng)
            else:
                mech = factory()
                mech.fit(keys, np.arange(keys.shape[0], dtype=np.float64))
        dt = time.perf_counter() - t0
        timings = getattr(gapped, "build_timings", None) or {}
        return cls(
            keys=keys,
            mech=mech,
            method=method,
            gapped=gapped,
            sample_rate=sample_rate,
            gap_rho=gap_rho,
            build_seconds=dt,
            learn_seconds=float(timings.get("learn_seconds", dt)),
            mech_kwargs=dict(mech_kwargs),
            tuned=tuned,
        )

    # ------------------------------------------------------------------
    # epoch protocol
    # ------------------------------------------------------------------
    @property
    def epoch(self) -> int:
        """Monotone host-state version (0 for an untouched build)."""
        return self.gapped.version if self.gapped is not None else 0

    @property
    def device_epoch(self) -> int:
        """Epoch the frozen device state reflects (-1: not materialized)."""
        return self._device_epoch

    def __deepcopy__(self, memo):
        # device state is a cache keyed by epoch — rebuild it lazily in
        # the copy instead of deep-copying jax buffers
        new = Index(
            keys=_copy.deepcopy(self.keys, memo),
            mech=_copy.deepcopy(self.mech, memo),
            method=self.method,
            gapped=_copy.deepcopy(self.gapped, memo),
            sample_rate=self.sample_rate,
            gap_rho=self.gap_rho,
            build_seconds=self.build_seconds,
            learn_seconds=self.learn_seconds,
            mech_kwargs=dict(self.mech_kwargs),
            tuned=self.tuned,
            refreeze_contested_frac=self.refreeze_contested_frac,
            refreeze_link_growth=self.refreeze_link_growth,
            min_device_batch=self.min_device_batch,
            fused_ingest_enabled=self.fused_ingest_enabled,
            fused_split_commit=self.fused_split_commit,
            refresh_segments_frac=self.refresh_segments_frac,
            stats=dict(self.stats),
        )
        new.__class__ = self.__class__
        memo[id(self)] = new
        return new

    # ------------------------------------------------------------------
    # backend resolution
    # ------------------------------------------------------------------
    def _key_caps(self):
        """(wide, device_exact) of the LIVE key set, cached per epoch.

        ``wide``: keys exceed f32 exactness (2^24) and ride the hi/lo
        pair on device.  ``device_exact``: the device pair search cannot
        conflate stored keys — either every key is individually
        pair-exact (integers < 2^48; the common composite/hash case) or
        the pair mapping is alias-free over the stored set (continuous
        f64 keys whose spacing exceeds pair resolution).  ``ingest``
        maintains the cache incrementally for all-exact batches, so the
        hot path stays O(batch)."""
        cached = self._keycap_cache
        if cached is not None and cached[0] == self.epoch:
            return cached[1], cached[2]
        from ..kernels import ops as _ops
        if self.gapped is not None:
            arrs = (self.gapped.slot_key, self.gapped.links.chain_keys)
        else:
            arrs = (self.keys,)
        wide = any(_ops.keys_need_pair(a) for a in arrs)
        indiv = all(_ops.keys_pair_exact(a) for a in arrs)
        exact = indiv
        if wide and not indiv:
            merged = (np.sort(np.concatenate(arrs)) if len(arrs) > 1
                      and arrs[1].size else arrs[0])
            exact = _ops.pair_alias_free(merged)
        self._keycap_cache = (self.epoch, wide, exact, indiv)
        return wide, exact

    def _key_caps_after_batch(self, batch: np.ndarray) -> None:
        """Incremental cap maintenance after an ingest, O(batch log n):

        * all-exact set + per-key pair-exact batch: exact pairs
          reconstruct their key, so no aliasing can appear — roll the
          cache forward directly;
        * alias-free continuous set: a NEW alias must pair a new key
          with one of its key-order neighbors, so checking the batch
          against its bracketing stored keys (slot keys + the bracketing
          slots' chains) suffices — no O(n log n) global re-sort;
        * anything else leaves the cache stale for a full recompute.
        """
        cached = self._keycap_cache
        if cached is None or not cached[2]:
            return  # no cache, or already inexact (stays inexact)
        from ..kernels import ops as _ops
        batch = np.asarray(batch, np.float64)
        wide = cached[1] or _ops.keys_need_pair(batch)
        if cached[3] and _ops.keys_pair_exact(batch):
            self._keycap_cache = (self.epoch, wide, True, True)
            return
        ga = self.gapped
        if ga is None:
            return
        # continuous case: verify alias-freeness of the new keys against
        # their key-order neighbors in the (already updated) structure.
        # By the carried-key construction, a value's predecessor lives
        # on the PREV occupied slot (left-searchsorted - 1) or its
        # chain, and its bracketing chain hangs off the occupied upper
        # bound (right-searchsorted - 1); the successor value is that
        # slot's right neighbor's (carried) key.
        bs = np.unique(batch)
        m = ga.n_slots
        jr = np.searchsorted(ga.slot_key, bs, side="right") - 1
        jl = np.searchsorted(ga.slot_key, bs, side="left") - 1
        s_chain = np.unique(np.clip(np.concatenate([jl, jr]), 0, m - 1))
        s_vals = np.unique(np.clip(np.concatenate([jl, jr, jr + 1]),
                                   0, m - 1))
        nb = ga.slot_key[s_vals]
        off, ck, _ = ga.links.csr()
        starts, ends = off[s_chain], off[s_chain + 1]
        lens = ends - starts
        if int(lens.sum()):
            base = np.repeat(starts, lens)
            step = np.arange(int(lens.sum())) - np.repeat(
                np.cumsum(lens) - lens, lens)
            chain_nb = ck[base + step]
        else:
            chain_nb = np.zeros(0, np.float64)
        cand = np.concatenate([bs, nb[np.isfinite(nb)], chain_nb])
        exact = _ops.pair_alias_free(np.sort(np.unique(cand)))
        self._keycap_cache = (self.epoch, wide, bool(exact), False)

    def _keys_wide(self) -> bool:
        return self._key_caps()[0]

    def resolve_backend(self, n_queries: int,
                        requested: Optional[str] = None) -> BackendSpec:
        """Pick a backend from the capability registry (see module doc)."""
        has_plm = getattr(self.mech, "plm", None) is not None
        if requested is not None:
            try:
                spec = BACKENDS[requested]
            except KeyError:
                raise ValueError(
                    f"unknown backend {requested!r}; registered: "
                    f"{sorted(BACKENDS)}") from None
            if spec.device:
                if not has_plm:
                    raise ValueError(
                        f"backend {requested!r} cannot serve this index: "
                        f"mechanism {self.method!r} does not export a "
                        "piecewise linear model — use 'numpy-oracle'")
                wide, exact = self._key_caps()
                if wide and not spec.wide_keys:
                    raise ValueError(
                        f"backend {requested!r} cannot serve this index: "
                        "keys exceed f32 exactness (2^24) and the backend "
                        "lacks hi/lo wide-key support — use 'xla-windowed' "
                        "or 'numpy-oracle'")
                if wide and not exact:
                    raise ValueError(
                        f"backend {requested!r} cannot serve this index: "
                        "distinct keys alias in the f32 hi/lo pair "
                        "representation (exact only up to ~2^48) — only "
                        "'numpy-oracle' can distinguish them")
            return spec
        if n_queries < self.min_device_batch or not has_plm:
            return BACKENDS["numpy-oracle"]
        wide, exact = self._key_caps()
        if wide and not exact:  # beyond 2^48: only the host is exact
            return BACKENDS["numpy-oracle"]
        # the fused single-dispatch path serves narrow and wide (hi/lo
        # pair) keys on every platform; the engine picks the Pallas
        # kernel vs the fused XLA graph by platform (engine.fused_impl)
        return BACKENDS["fused"]

    # ------------------------------------------------------------------
    # device state lifecycle
    # ------------------------------------------------------------------
    def refreeze(self):
        """Full rebuild of the frozen device state (arrays + query-safe
        window bounds + host mirror) at the current epoch."""
        from ..kernels import ops as _ops
        self._engine, self._mirror = _ops.freeze_state(self)
        self._device_epoch = self.epoch
        self._pending_touch = []  # fresh bounds cover everything logged
        self.stats["refreezes"] += 1
        return self._engine

    def _log_touch(self, keys) -> None:
        """Record mutated key values for the next delta's incremental
        window-bound refresh (cleared by any device sync)."""
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        if keys.size:
            self._pending_touch.append(keys)
            if len(self._pending_touch) > 32:  # bound the log
                self._pending_touch = [
                    np.unique(np.concatenate(self._pending_touch))]

    def sync_device(self):
        """Bring the frozen device state to the current epoch NOW (delta
        scatter when possible, refreeze otherwise) instead of lazily on
        the next device lookup.  Returns the engine."""
        return self._sync_device()

    def _sync_device(self, prefer_delta: bool = True):
        """Bring the device state to the current epoch (delta if allowed
        and possible, else refreeze)."""
        if self._engine is None:
            return self.refreeze()
        if self._device_epoch == self.epoch:
            return self._engine
        from ..kernels import ops as _ops
        if prefer_delta:
            new_arrays, n_elems, touched_keys = _ops.delta_update(
                self._engine.arrays, self._mirror, self)
            if new_arrays is not None:
                self._engine.swap_arrays(new_arrays)
                self._device_epoch = self.epoch
                self.stats["delta_updates"] += 1
                self.stats["delta_elems"] += n_elems
                pending = ([np.asarray(touched_keys, np.float64)]
                           if touched_keys is not None else [])
                pending += [np.asarray(a, np.float64)
                            for a in self._pending_touch]
                self._pending_touch = []
                self._refresh_window_bounds(
                    np.concatenate(pending) if pending
                    else np.zeros(0, np.float64))
                return self._engine
        return self.refreeze()

    def _refresh_window_bounds(self, touched_keys) -> None:
        """Incremental per-segment window-bound refresh after a delta
        update: only segments whose keys moved (plus their key-order
        neighbors) recompute, so the compacted-fallback rate stays flat
        under chain growth instead of climbing until the policy
        refreeze.  Near-global churn (more than
        ``refresh_segments_frac`` of the segments touched) skips the
        refresh — stale bounds are SOUND (they only cost fallbacks) and
        the refreeze policy catches sustained growth.
        """
        eng = self._engine
        if (eng is None or touched_keys is None
                or np.asarray(touched_keys).size == 0
                or self.refresh_segments_frac <= 0):  # refresh disabled
            return
        plm = getattr(self.mech, "plm", None)
        if plm is None:
            return
        from ..kernels import ops as _ops
        # fused-path rank table: refresh only the touched buckets
        eng.refresh_rank_rows(touched_keys, self.gapped.slot_key)
        segs = np.unique(plm.segment_of(np.asarray(touched_keys,
                                                   np.float64)))
        # boundary terms reach into the neighboring segments' key spans
        segs = np.unique(np.clip(
            np.concatenate([segs - 1, segs, segs + 1]),
            0, plm.n_segments - 1))
        # the frac rule caps the refresh cost on big indexes; the floor
        # keeps small-K indexes (where a refresh is trivially cheap)
        # from reading every clustered burst as global churn
        if segs.size > max(8.0, self.refresh_segments_frac
                           * plm.n_segments):
            return
        err_hi_prev = (eng.err_hi if eng.err_hi is not None
                       else np.zeros_like(eng.err_lo))
        lo, hi = _ops.query_window_bounds(
            self, segments=segs, base=(eng.err_lo, err_hi_prev))
        eng.refresh_bounds(lo, hi)
        self.stats["bound_refreshes"] = (
            self.stats.get("bound_refreshes", 0) + 1)

    def _link_growth_fraction(self) -> float:
        """Chained keys added since the last freeze, relative to the
        index size AT that freeze (a stable denominator)."""
        if self.gapped is None or self._mirror is None:
            return 0.0
        grown = self.gapped.links.total - self._mirror.links_at_freeze
        return grown / max(self._mirror.n_keys_at_freeze, 1)

    # ------------------------------------------------------------------
    # reads
    # ------------------------------------------------------------------
    def predict(self, qs: np.ndarray) -> np.ndarray:
        return self.mech.predict(np.asarray(qs, np.float64))

    def lookup(self, queries, *, backend: Optional[str] = None,
               queries_sorted: bool = False) -> LookupResult:
        """Batched exact-match lookup -> ``LookupResult``.

        ``backend`` picks a registry entry explicitly; default resolves
        by batch size / platform / key width.  ``queries_sorted=True``
        skips the sort round trip on the Pallas path.
        """
        queries = np.asarray(queries, np.float64)
        spec = self.resolve_backend(queries.shape[0], backend)
        self.stats["lookups"] += 1
        if not spec.device:
            if self.gapped is not None:
                pay, slots, found = self.gapped.lookup_batch(queries,
                                                             full=True)
                return host_lookup_result(pay, slots, found, spec.name,
                                          self.epoch)
            pos, probes = _sampling.exponential_search(
                self.keys, queries, self.predict(queries))
            self.stats["search_probes"] += probes
            found = self.keys[pos] == queries
            pay = np.where(found, pos, -1)
            return host_lookup_result(pay, pos, found, spec.name, self.epoch)
        engine = self._sync_device()
        esc0 = engine.stats["oracle_escapes"]
        out, slot, found, fb = engine.lookup(
            queries, queries_sorted=queries_sorted,
            backend=spec.engine_backend, force_backend=backend is not None)
        # label the search stage that ACTUALLY ran: the engine's
        # size-aware scheduler may run the device oracle for small
        # default-resolved legacy-xla buckets (explicit requests are
        # forced), and overflow escapes land on the device oracle
        stage = {"fused": "fused", "pallas": "pallas",
                 "xla": "xla-windowed",
                 "oracle": "device-oracle"}[engine.last_stage]
        return LookupResult(
            payloads=np.asarray(out).astype(np.int64),
            slots=np.asarray(slot).astype(np.int64),
            found=np.asarray(found, bool),
            backend=stage,
            epoch=self.epoch,
            fallbacks=int(fb),
            oracle_escapes=engine.stats["oracle_escapes"] - esc0,
        )

    # ------------------------------------------------------------------
    # writes (§5.3 dynamic ops — need a gapped build)
    # ------------------------------------------------------------------
    def _need_gapped(self):
        if self.gapped is None:
            raise NotImplementedError(
                "dynamic ops need gap insertion (build with gap_rho > 0)"
            )

    def _device_placements(self, keys) -> Optional[dict]:
        """Compute the batch's placement primitives on the frozen device
        arrays (the kernels ingest-place backend), escape rows patched
        from the host oracle in O(#escapes).  Returns None whenever the
        device cannot serve the batch EXACTLY — device state behind the
        host epoch, non-PLM ``predict`` (rmi routes through its root
        model, btree has no slots), keys beyond per-key pair exactness,
        or slot counts past f32/i32 indexing — and the host partition
        runs as before.  Bit-identity with the host oracle is the
        contract (see kernels.__init__ "Ingest backend contract")."""
        if (self._engine is None or self._device_epoch != self.epoch
                or self.method not in ("pgm", "fiting")
                or self.gapped is None
                or keys.shape[0] < self.min_device_batch
                # past one partition chunk, insert_batch recomputes the
                # later chunks against mutated state anyway — computing
                # (and escape-patching) device primitives for rows that
                # would be discarded is pure waste, and the report's
                # placement label would lie
                or keys.shape[0] > self.gapped.batch_chunk()
                or self.gapped.n_slots >= (1 << 24)):
            return None
        from ..kernels import ops as _ops
        verify = False
        if self._engine.arrays.key_wide:
            # wide freeze: the stored set must be per-key pair-exact
            # (not merely alias-free — a pair-ROUNDED stored key could
            # land on the other side of a batch key) and so must the
            # batch, so device pair compares equal host f64 compares.
            # A merely ALIAS-FREE wide set no longer refuses outright:
            # its device primitives are certified row-by-row on the
            # host (exact f64 bracketing checks, see
            # GappedArray.verify_placements) with failing rows
            # recomputed per-key — reported as "device-verified"
            self._key_caps()  # refresh the cache to this epoch
            cached = self._keycap_cache
            if not (cached is not None and cached[0] == self.epoch
                    and cached[3] and _ops.keys_pair_exact(keys)):
                if not (cached is not None and cached[0] == self.epoch
                        and cached[2]):
                    return None  # aliasing set: only the host is exact
                verify = True
        elif _ops.keys_need_pair(keys):
            return None  # wide batch against a narrow (plain-f32) freeze
        prims, esc = self._engine.ingest_place(keys)
        n_esc = int(np.count_nonzero(esc))
        if n_esc:
            sub = self.gapped.placement_primitives(keys[esc])
            for f, v in prims.items():
                v[esc] = sub[f]
        self.stats["ingest_place_escapes"] = (
            self.stats.get("ingest_place_escapes", 0) + n_esc)
        if verify:
            bad = self.gapped.verify_placements(keys, prims)
            n_bad = int(np.count_nonzero(bad))
            if n_bad:
                sub = self.gapped.placement_primitives(keys[bad])
                for f, v in prims.items():
                    v[bad] = sub[f]
            self.stats["ingest_place_verify_patched"] = (
                self.stats.get("ingest_place_verify_patched", 0) + n_bad)
        self._placement_mode = "device-verified" if verify else "device"
        return prims

    def _fused_eligible(self, keys, payloads) -> bool:
        """Gates for the single-dispatch fused ingest: the device-
        placement gates (epoch, PLM mechanism, one-chunk batch, per-key
        pair exactness — verified mode is NOT eligible: its host
        certification would defeat the zero-host-intermediate point)
        PLUS the fused graph's own statics: i32 sort/index range, a
        nonzero frozen link image for the CSR merge, and payloads
        within the frozen narrow width."""
        ga = self.gapped
        if (self._engine is None or self._device_epoch != self.epoch
                or self.method not in ("pgm", "fiting") or ga is None
                or keys.shape[0] < self.min_device_batch
                or keys.shape[0] > ga.batch_chunk()
                or ga.n_slots >= (1 << 22)
                or ga.n_keys == 0):
            return False
        arrays = self._engine.arrays
        if int(arrays.link_keys.shape[0]) == 0:
            return False
        from ..kernels import ops as _ops
        if not arrays.wide and payloads.size and (
                int(payloads.min()) < _ops._I32_MIN
                or int(payloads.max()) > _ops._I32_MAX):
            return False
        if arrays.key_wide:
            self._key_caps()
            cached = self._keycap_cache
            if not (cached is not None and cached[0] == self.epoch
                    and cached[3] and _ops.keys_pair_exact(keys)):
                return False
        elif _ops.keys_need_pair(keys):
            return False
        return True

    def _fused_dispatch(self, keys, payloads):
        """Issue the ONE fused device dispatch; returns ``(prims, ok,
        state)``.  On an in-graph abort (``ok`` False) the primitives
        are escape-patched and handed to the host partition — exactly
        the two-dispatch path's inputs, from the dispatch already paid
        for, so an abort never wastes the round trip."""
        prims, esc, ok, reasons, state = self._engine.fused_ingest(
            keys, payloads)
        self._placement_mode = "device"
        if ok:
            return prims, True, state
        from ..kernels.ops_gap import FUSED_ABORT_BITS
        ab = self.stats.setdefault("fused_aborts", {})
        names = [name for i, name in enumerate(FUSED_ABORT_BITS)
                 if reasons >> i & 1]
        for name in names:
            ab[name] = ab.get(name, 0) + 1
        # per-batch reason + engine-lifetime counter ride the
        # IngestReport (the abort telemetry the split-commit question
        # in ROADMAP needs answered from BENCH_ingest.json)
        self._last_abort_reasons = tuple(names)
        self.stats["fused_abort_total"] = (
            self.stats.get("fused_abort_total", 0) + 1)
        # the split-commit arm needs the raw escape rows to pick a prefix
        self._last_escape_mask = np.asarray(esc, bool)
        n_esc = int(np.count_nonzero(esc))
        if n_esc:
            sub = self.gapped.placement_primitives(keys[esc])
            for f, v in prims.items():
                v[esc] = sub[f]
        self.stats["ingest_place_escapes"] = (
            self.stats.get("ingest_place_escapes", 0) + n_esc)
        return prims, False, None

    def _commit_fused(self, keys, payloads, prims, state, t0):
        """Commit an accepted fused dispatch.  Host state advances
        through the normal partition fed the SAME dispatch's primitives
        (the host stays authoritative and bit-identical to sequential
        ``insert()``); device state advances by ADOPTING the dispatch's
        output buffers — nothing is diffed, rebuilt, or re-uploaded.
        The mirror is marked source-advanced/image-dirty, so a later
        HOST-side delta lazily rebuilds its padded images first."""
        from ..kernels import ops as _ops
        eng = self._engine
        cand = np.asarray(prims["free"], bool) & np.asarray(
            prims["bracket"], bool)
        counts = self.gapped.insert_batch(keys, payloads, placements=prims)
        self._key_caps_after_batch(keys)
        self.stats["ingests"] += 1
        if (counts["contested"] != 0 or counts["slot"] != state["n_slot"]
                or counts["chain"] != state["n_chain"]):
            # unreachable by the closure-trivial acceptance argument
            # (the graph aborts on every shape the partition could
            # demote) — if it ever fires, distrust the graph image and
            # refreeze instead of adopting it
            self._log_touch(keys)
            self.refreeze()
            return IngestReport(
                n=int(keys.shape[0]), slot=counts["slot"],
                chain=counts["chain"], contested=counts["contested"],
                epoch=self.epoch, device="refreeze",
                seconds=time.perf_counter() - t0, placement="device")
        # adopt the in-graph refreshed state + catch the host mirrors up
        err_lo = eng.err_lo
        err_hi = (eng.err_hi if eng.err_hi is not None
                  else np.zeros_like(err_lo))
        seg = state["seg"][cand]
        dlt = state["dlt"][cand].astype(np.float32)
        np.minimum.at(err_lo, seg, dlt - np.float32(1.0))
        np.maximum.at(err_hi, seg, dlt + np.float32(1.0))
        eng.adopt_fused_state(state, err_lo, err_hi)
        eng.refresh_rank_rows(keys, self.gapped.slot_key, upload=False)
        self._device_epoch = self.epoch
        self._pending_touch = []
        self._mirror.sources = _ops._snapshot_sources(self)
        self._mirror.images = None  # lazily rebuilt by the next delta
        self.stats["fused_ingests"] = (
            self.stats.get("fused_ingests", 0) + 1)
        device = "fused"
        if self._link_growth_fraction() > self.refreeze_link_growth:
            self.refreeze()  # capacity-growth policy still applies
            device = "refreeze"
        return IngestReport(
            n=int(keys.shape[0]), slot=counts["slot"],
            chain=counts["chain"], contested=0, epoch=self.epoch,
            device=device, device_elems=0,
            seconds=time.perf_counter() - t0, placement="device",
            fused_aborts=self.stats.get("fused_abort_total", 0),
            split_commits=self.stats.get("split_commits", 0))

    def _split_prefix(self, keys, prims) -> int:
        """Longest batch prefix with no locally-suspect row — the
        split-commit candidate.  A row is suspect when it carries the
        escape bit, duplicates another batch key, is a free candidate
        without a bracket, or shares a gap run (``pv``/``ub``) with any
        other batch row (collision groups, d1/d4 demotions, and chain
        duplicates all require two rows in one run).  Heuristic, not a
        proof: the second fused dispatch re-runs the full abort gate on
        the prefix, so a miss costs one dispatch, never correctness."""
        n = int(keys.shape[0])
        free = np.asarray(prims["free"], bool)
        bracket = np.asarray(prims["bracket"], bool)
        suspect = free & ~bracket
        esc = getattr(self, "_last_escape_mask", None)
        if esc is not None and esc.shape == suspect.shape:
            suspect |= esc
        order = np.argsort(keys, kind="stable")
        ks = keys[order]
        dup = np.r_[False, ks[1:] == ks[:-1]]
        dup |= np.r_[dup[1:], False]
        suspect[order[dup]] = True
        rid = np.where(free, np.asarray(prims["pv"], np.int64),
                       np.asarray(prims["ub"], np.int64))
        uniq, inv, cnt = np.unique(rid, return_inverse=True,
                                   return_counts=True)
        # shared runs are only collision-suspect when a FREE placement
        # is involved (two free rows fighting for one slot run, or a
        # free row racing a chain attach on the same slot); several
        # chain rows merging into one chain is the graph's normal case
        free_in_run = np.zeros(uniq.size, bool)
        np.logical_or.at(free_in_run, inv, free)
        suspect |= (cnt[inv] > 1) & free_in_run[inv]
        bad = np.flatnonzero(suspect)
        # row-level veto but no locally-attributable suspect (heuristic
        # miss): halve and hope the offending rows sit in the back half
        return int(bad[0]) if bad.size else n // 2

    def _try_split_commit(self, keys, payloads, prims, t0):
        """Split commit (ROADMAP residual): the abort gate vetoed the
        whole batch, but the veto is typically caused by a handful of
        rows.  Salvage the longest locally-clean prefix with a second
        fused dispatch (committed in-graph, device buffers adopted) and
        replay only the remainder through the host partition + delta
        sync.  Returns the merged ``IngestReport`` for the FULL batch,
        or None when the prefix is too small to be worth a dispatch or
        its dispatch also aborts — the caller then falls back to the
        single host partition on the primitives already paid for."""
        n = int(keys.shape[0])
        k = self._split_prefix(keys, prims)
        if k < max(self.min_device_batch, n // 8) or k >= n:
            return None
        pk, pp = keys[:k], payloads[:k]
        if not self._fused_eligible(pk, pp):
            return None
        prims2, esc2, ok2, reasons2, state2 = self._engine.fused_ingest(
            pk, pp)
        if not ok2:
            self.stats["split_commit_misses"] = (
                self.stats.get("split_commit_misses", 0) + 1)
            return None
        rep1 = self._commit_fused(pk, pp, prims2, state2, t0)
        self.stats["split_commits"] = (
            self.stats.get("split_commits", 0) + 1)
        # remainder replays against the post-commit state (fresh
        # placements — the prefix moved slots under it)
        rk, rp = keys[k:], payloads[k:]
        rprims = self._device_placements(rk)
        counts = self.gapped.insert_batch(rk, rp, placements=rprims)
        self._key_caps_after_batch(rk)
        self._log_touch(rk)
        device = rep1.device
        elems = rep1.device_elems
        if self._engine is not None:
            wide, exact = self._key_caps()
            if wide and not exact:
                self._engine = None
                self._mirror = None
                self._device_epoch = -1
                device = "none"
            else:
                contested_frac = counts["contested"] / max(rk.shape[0], 1)
                want_refreeze = (
                    contested_frac > self.refreeze_contested_frac
                    or self._link_growth_fraction()
                    > self.refreeze_link_growth)
                before = (self.stats["delta_updates"],
                          self.stats["refreezes"],
                          self.stats["delta_elems"])
                self._sync_device(prefer_delta=not want_refreeze)
                if self.stats["delta_updates"] > before[0]:
                    device = "fused+delta"
                    elems += self.stats["delta_elems"] - before[2]
                elif self.stats["refreezes"] > before[1]:
                    device = "refreeze"
        return IngestReport(
            n=n, slot=rep1.slot + counts["slot"],
            chain=rep1.chain + counts["chain"],
            contested=counts["contested"], epoch=self.epoch,
            device=device, device_elems=elems,
            seconds=time.perf_counter() - t0, placement="device-split",
            abort_reasons=getattr(self, "_last_abort_reasons", ()),
            fused_aborts=self.stats.get("fused_abort_total", 0),
            split_commits=self.stats.get("split_commits", 0))

    def ingest(self, keys, payloads) -> IngestReport:
        """Batched insert; placements computed on the frozen device
        arrays when the engine is at the host epoch (the ingest-place
        backend; host-oracle fallback otherwise), then the device state
        is delta-updated in place (full refreeze only past the policy
        thresholds — see module doc).

        On an eligible device-resident engine the ENTIRE ingest is one
        fused dispatch: placement, slot scatter + carried repair, the
        chain arm's CSR merge, and the rank-row/window-bound refresh
        run in a single graph whose outputs the engine adopts directly
        (``device == "fused"``).  The graph self-vetoes on any shape
        the host partition could demote (collision groups, contested
        rows, capacity overflows, duplicates) — those batches fall back
        to the host partition REUSING the same dispatch's primitives.
        """
        self._need_gapped()
        t0 = time.perf_counter()
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        payloads = np.atleast_1d(np.asarray(payloads, np.int64))
        prims = None
        placement = "host"
        self._last_abort_reasons = ()
        enabled = self.fused_ingest_enabled
        if enabled is None:  # auto: the fused write graph pays off on
            enabled = (      # accelerator engines (see the field doc)
                getattr(self._engine, "fused_impl", "xla") == "pallas")
        if enabled and self._fused_eligible(keys, payloads):
            prims, ok, state = self._fused_dispatch(keys, payloads)
            placement = "device"
            if ok:
                return self._commit_fused(keys, payloads, prims, state, t0)
            # split commit only helps when the veto is attributable to
            # specific rows; a purely capacity-based veto (static chain/
            # link headroom) vetoes any same-shaped prefix too, so those
            # keep the one-dispatch abort contract
            cap_only = set(self._last_abort_reasons) <= {
                "chain_overflow", "link_overflow"}
            if self.fused_split_commit and not cap_only:
                rep = self._try_split_commit(keys, payloads, prims, t0)
                if rep is not None:
                    return rep
        if prims is None:
            prims = self._device_placements(keys)
            placement = ("host" if prims is None
                         else getattr(self, "_placement_mode", "device"))
        counts = self.gapped.insert_batch(keys, payloads, placements=prims)
        self._key_caps_after_batch(keys)
        self._log_touch(keys)
        self.stats["ingests"] += 1
        device = "none"
        elems = 0
        if self._engine is not None:
            wide, exact = self._key_caps()
            if wide and not exact:
                # ingested keys outgrew the hi/lo pair's exactness: the
                # device can no longer answer exactly — drop the frozen
                # state; the registry now routes every lookup host-side
                self._engine = None
                self._mirror = None
                self._device_epoch = -1
            else:
                contested_frac = counts["contested"] / max(keys.shape[0], 1)
                want_refreeze = (
                    contested_frac > self.refreeze_contested_frac
                    or self._link_growth_fraction()
                    > self.refreeze_link_growth)
                before = (self.stats["delta_updates"],
                          self.stats["refreezes"],
                          self.stats["delta_elems"])
                self._sync_device(prefer_delta=not want_refreeze)
                if self.stats["delta_updates"] > before[0]:
                    device = "delta"
                    elems = self.stats["delta_elems"] - before[2]
                elif self.stats["refreezes"] > before[1]:
                    device = "refreeze"
        return IngestReport(
            n=int(keys.shape[0]), slot=counts["slot"], chain=counts["chain"],
            contested=counts["contested"], epoch=self.epoch, device=device,
            device_elems=elems, seconds=time.perf_counter() - t0,
            placement=placement,
            abort_reasons=getattr(self, "_last_abort_reasons", ()),
            fused_aborts=self.stats.get("fused_abort_total", 0),
            split_commits=self.stats.get("split_commits", 0))

    def _roll_caps(self) -> None:
        """Advance the keycap cache to the current epoch UNCHANGED —
        for mutations that cannot worsen key capabilities (payload
        updates; deletes, which can only remove aliasing: stale wide
        or inexact flags err conservative)."""
        cached = self._keycap_cache
        if cached is not None:
            self._keycap_cache = (self.epoch,) + cached[1:]

    def remove(self, keys) -> int:
        """Batched delete; device state follows lazily (next device
        lookup delta-updates or refreezes as needed)."""
        self._need_gapped()
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        n = self.gapped.delete_batch(keys)
        self._roll_caps()
        self._log_touch(keys)
        return n

    # scalar host ops (thin delegates; epoch bumps via gapped.version)
    def insert(self, key: float, payload: int) -> str:
        self._need_gapped()
        path = self.gapped.insert(key, payload)
        self._key_caps_after_batch(np.array([key], np.float64))
        self._log_touch(np.array([key], np.float64))
        return path

    def insert_batch(self, keys: np.ndarray, payloads: np.ndarray) -> dict:
        """Raw batched insert returning §5.3 path counts (host only; use
        ``ingest`` for the typed report + eager device sync)."""
        self._need_gapped()
        counts = self.gapped.insert_batch(keys, payloads)
        self._log_touch(keys)
        return counts

    def delete(self, key: float) -> bool:
        self._need_gapped()
        out = self.gapped.delete(key)
        self._roll_caps()
        self._log_touch(np.array([key], np.float64))
        return out

    def delete_batch(self, keys: np.ndarray) -> int:
        self._need_gapped()
        out = self.gapped.delete_batch(keys)
        self._roll_caps()
        self._log_touch(np.asarray(keys, np.float64))
        return out

    def update(self, key: float, payload: int) -> bool:
        self._need_gapped()
        out = self.gapped.update(key, payload)
        self._roll_caps()  # payload-only: key capabilities unchanged
        return out

    def update_batch(self, keys: np.ndarray, payloads: np.ndarray) -> int:
        """Batched payload update (ONE epoch bump; payload-only, so the
        next device sync is a pure payload-scatter delta)."""
        self._need_gapped()
        out = self.gapped.update_batch(np.asarray(keys, np.float64),
                                       np.asarray(payloads, np.int64))
        self._roll_caps()
        return out

    # ------------------------------------------------------------------
    # durability (serving/wal.py crash recovery rides on these)
    # ------------------------------------------------------------------
    def save_snapshot(self, directory, *, step: Optional[int] = None,
                      keep: int = 3, wal_lsn: int = 0,
                      extra: Optional[dict] = None) -> str:
        """Write a restorable checkpoint of the full HOST state through
        ``train.checkpoint.CheckpointManager`` — the same array format
        as trainer checkpoints (one fsynced ``.npy`` per array +
        manifest, atomic tmp→rename publish), not a second serializer.
        Device state is never serialized: it is an epoch-keyed cache
        that refreezes lazily after ``restore``.  ``wal_lsn`` records
        the ingest-WAL byte offset this snapshot is consistent with
        (crash recovery replays only records past it — serving/wal.py).
        Returns the published checkpoint directory."""
        import pickle
        from ..train.checkpoint import CheckpointManager
        state = {
            "keys": np.asarray(self.keys, np.float64),
            "mech_pickle": np.frombuffer(
                pickle.dumps(self.mech), np.uint8).copy(),
        }
        meta = {
            "kind": "index",
            "method": self.method,
            "sample_rate": float(self.sample_rate),
            "gap_rho": float(self.gap_rho),
            "gapped": self.gapped is not None,
            "epoch": int(self.epoch),
            "wal_lsn": int(wal_lsn),
        }
        ga = self.gapped
        if ga is not None:
            offsets, lkeys, lpays = ga.export_csr_links()
            state.update(
                slot_key=np.asarray(ga.slot_key, np.float64),
                occupied=np.asarray(ga.occupied, bool),
                payload=np.asarray(ga.payload, np.int64),
                offsets=np.asarray(offsets, np.int64),
                chain_keys=np.asarray(lkeys, np.float64),
                chain_payloads=np.asarray(lpays, np.int64),
            )
            meta["n_keys"] = int(ga.n_keys)
            meta["rho"] = float(ga.rho)
        if extra:
            meta.update(extra)
        s = int(step if step is not None else self.epoch)
        meta["step"] = s
        return CheckpointManager(directory, keep=keep).save(
            s, state, extra=meta)

    @classmethod
    def restore(cls, directory, step: Optional[int] = None):
        """Load a ``save_snapshot`` checkpoint -> ``(index, extra)``.

        Host state is restored bit-identically (arrays verbatim, the
        mechanism via its pickle); ``extra`` is the manifest's metadata
        dict (includes ``wal_lsn``).  Newest step when ``step`` is
        None."""
        import json as _json
        import os as _os
        import pickle
        from ..train.checkpoint import CheckpointManager
        from .links import CSRLinks
        mgr = CheckpointManager(str(directory))
        s = int(step) if step is not None else mgr.latest_step()
        if s is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        with open(_os.path.join(str(directory), f"step_{s:08d}",
                                "manifest.json")) as f:
            names = _json.load(f)["leaves"]
        # flat dict of arrays: a same-keyed template sidesteps the
        # treedef-proto deserialization path entirely
        state, meta = mgr.restore(step=s,
                                  template={n: 0 for n in names})
        mech = pickle.loads(
            np.asarray(state["mech_pickle"], np.uint8).tobytes())
        gapped = None
        if meta.get("gapped"):
            slot_key = np.asarray(state["slot_key"], np.float64)
            gapped = _gaps.GappedArray(
                slot_key=slot_key,
                occupied=np.asarray(state["occupied"], bool),
                payload=np.asarray(state["payload"], np.int64),
                links=CSRLinks(
                    int(slot_key.shape[0]),
                    np.asarray(state["offsets"], np.int64),
                    np.asarray(state["chain_keys"], np.float64),
                    np.asarray(state["chain_payloads"], np.int64)),
                mech=mech,
                n_keys=int(meta["n_keys"]),
                rho=float(meta["rho"]),
                version=int(meta["epoch"]))
        idx = cls(keys=np.asarray(state["keys"], np.float64), mech=mech,
                  method=meta["method"], gapped=gapped,
                  sample_rate=float(meta["sample_rate"]),
                  gap_rho=float(meta["gap_rho"]))
        return idx, meta

    # ------------------------------------------------------------------
    # self-tuning: online retrain (the ROADMAP-4 dial)
    # ------------------------------------------------------------------
    def retrain(self, sample_rate: Optional[float] = None, *,
                gap_rho: Optional[float] = None, rng=None,
                method: Optional[str] = None, **mech_kwargs) -> dict:
        """Sampled refit of the LIVE gapped state — the paper's §4
        construction cost applied online.

        Extracts the live (key, payload) set (occupied slots + CSR
        chain keys via ``GappedArray.live_items``), rebuilds the gapped
        array through ``build_gapped`` with mechanism learning on a
        sample (O(n_s)), and swaps it in with the epoch bumped past the
        old one.  The OLD arrays are replaced, never mutated, so any
        outstanding ``GapSnapshot`` pin (``serving.EpochPipeline``)
        keeps serving its epoch bit-identically throughout; the device
        cache is dropped and refreezes lazily at the new epoch.
        Defaults replay the build's settings (``method`` / mech kwargs /
        ``gap_rho``); ``sample_rate`` defaults to the build's rate.
        Returns a record dict (n / seconds / learn_seconds / epoch /
        chains before-after)."""
        self._need_gapped()
        t0 = time.perf_counter()
        old_epoch = self.epoch
        chains_before = self.gapped.link_stats()
        keys, payloads = self.gapped.live_items()
        method = method or self.method
        rate = self.sample_rate if sample_rate is None else float(sample_rate)
        rho = self.gap_rho if gap_rho is None else float(gap_rho)
        kwargs = dict(self.mech_kwargs, **mech_kwargs) if method == \
            self.method else dict(mech_kwargs)
        new = Index.build(keys, method=method, sample_rate=rate,
                          gap_rho=rho, rng=rng, payloads=payloads,
                          **kwargs)
        # swap host state wholesale; epoch stays strictly monotone
        new.gapped.version = old_epoch + 1
        self.keys = new.keys
        self.mech = new.mech
        self.method = new.method
        self.gapped = new.gapped
        self.sample_rate = rate
        self.gap_rho = rho
        self.mech_kwargs = new.mech_kwargs
        self.tuned = new.tuned if new.tuned is not None else self.tuned
        # device state is an epoch-keyed cache of the REPLACED arrays
        self._engine = None
        self._mirror = None
        self._device_epoch = -1
        self._keycap_cache = None
        self._pending_touch = []
        self.stats["retrains"] += 1
        return {
            "n": int(keys.shape[0]),
            "seconds": time.perf_counter() - t0,
            "learn_seconds": float(new.learn_seconds),
            "sample_rate": rate,
            "epoch": int(self.epoch),
            "chains_before": chains_before,
            "chains_after": self.gapped.link_stats(),
        }

    # ------------------------------------------------------------------
    def mdl(self, alpha: float = 1.0) -> _mdl.MDLReport:
        """Evaluate under the §3 MDL framework (positions = logical y).

        Gapped builds are scored on the LIVE key set — occupied slot
        keys plus CSR chain keys from ``GappedArray.live_items()`` —
        against their physical slots, so keys added by ``ingest`` enter
        ``L(D|M)`` / ``max_abs_err`` and the report tracks drift (the
        retrain trigger's input).  A chained key's position is its chain
        owner's slot: exactly where the search lands before the chain
        bisect, i.e. the true correction distance."""
        if self.gapped is not None:
            keys, _ = self.gapped.live_items()
            y = (np.searchsorted(self.gapped.slot_key, keys,
                                 side="right") - 1).astype(np.float64)
        else:
            keys = self.keys
            y = np.arange(keys.shape[0], dtype=np.float64)
        return _mdl.mdl_report(self.method, self.mech, keys, y,
                               alpha=alpha)
