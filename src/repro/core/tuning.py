"""MDL-guided auto-tuner (paper §3 as a decision procedure).

The paper frames index learning as minimizing ``MDL = L(M) + alpha *
L(D|M)`` and argues the objective "helps design suitable indexes for
different scenarios"; fig4 plots that tradeoff offline.  ``autotune``
evaluates it ONLINE: fit every candidate (mechanism, budget) on a
*sample* of the keys — §4 makes candidate evaluation O(n_s), which is
what makes a grid affordable — and score each with a query-weighted
``mdl_report``, so the correction term reflects the keys queries
actually hit, not the uniform key distribution.

Constraint set ("Lower Bounds for the Algorithmic Complexity of
Learned Indexes", PAPERS.md): the space/error budget is a hard filter,
not a soft penalty — candidates over ``size_budget_bytes`` or
``max_err_budget`` are dropped before scoring (if ALL candidates bust
the budget the smallest model wins, flagged ``budget_met=False``).

Sample sizing uses the paper's theory hooks: ``sample_size_bound``
(Thm. 1, ``O(alpha^2 log^2 E)``) floors the sample so the sampled
correction-cost estimate is trustworthy, and the returned choice
carries ``hoeffding_eps`` (Prop. 1) — the confidence radius of the
winning score at that sample size.

Consumers: ``Index.build(method="auto")`` (and therefore per-shard
``ShardedIndex.build(method="auto")``) and ``Index.retrain``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from . import mdl as _mdl
from . import sampling as _sampling
from .mechanisms import MECHANISMS

__all__ = ["TunedChoice", "autotune", "default_grid"]

# sample floor: Thm. 1's constant is asymptotic; in practice a few
# thousand pairs make the per-candidate correction estimate stable at
# negligible fit cost (PGM on 4k pairs is ~ms)
_MIN_SAMPLE = 4096


@dataclasses.dataclass(frozen=True)
class TunedChoice:
    """The auto-tuner's winning configuration + its evidence."""

    method: str
    mech_kwargs: dict
    sample_rate: float          # rate that makes n_s >= the Thm.1 floor
    score: float                # winning query-weighted MDL
    report: _mdl.MDLReport      # full report of the winner (on sample)
    hoeffding_eps: float        # Prop.1 confidence radius of the score
    budget_met: bool            # False: every candidate busts the budget
    candidates: Tuple[dict, ...]  # (name, kwargs, mdl, bytes, max_err)


def default_grid(n: int) -> Sequence[Tuple[str, dict]]:
    """The scored (mechanism, kwargs) grid: PGM/FITing across an eps
    ladder plus one RMI sized to the key count.  B+Tree is excluded —
    it exists as the paper's baseline, never a serving choice."""
    grid = []
    for eps in (32.0, 128.0, 512.0):
        grid.append(("pgm", {"eps": eps, "recursive": False}))
        grid.append(("fiting", {"eps": eps}))
    grid.append(("rmi", {"n_leaf": max(64, n // 1024)}))
    return grid


def _query_positions(keys: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """True full-data position of each query key (predecessor rank)."""
    return (np.searchsorted(keys, queries, side="right") - 1).clip(0)


def autotune(
    keys: np.ndarray,
    queries: Optional[np.ndarray] = None,
    *,
    alpha: float = 1.0,
    dynamic: bool = False,
    size_budget_bytes: Optional[int] = None,
    max_err_budget: Optional[float] = None,
    grid: Optional[Sequence[Tuple[str, dict]]] = None,
    rng: Optional[np.random.Generator] = None,
) -> TunedChoice:
    """Pick (mechanism, kwargs, sample_rate) minimizing query-weighted
    MDL on a sample of ``keys``.

    ``queries`` weights ``L(D|M)`` by the observed query distribution
    (defaults to the key sample itself — uniform).  ``dynamic=True``
    restricts the grid to PLM-exporting mechanisms the gapped dynamic
    path serves device-side (pgm/fiting) — the per-shard default.
    ``alpha`` is the paper's Eq.1 weight; the budget kwargs are the
    lower-bounds constraint set (hard filter, see module doc).
    """
    keys = np.asarray(keys, np.float64)
    n = keys.shape[0]
    rngs = _sampling.spawn_rngs(rng, 2)
    # Thm.1-floored sample: E is unknown before fitting, so bound it by
    # the worst case (a single line => E <= n) — log2^2(n) * alpha^2,
    # floored at _MIN_SAMPLE for small-n stability
    n_bound = _sampling.sample_size_bound(max(alpha, 1.0), float(n), c=8.0)
    n_s = int(min(n, max(_MIN_SAMPLE, n_bound)))
    sample_rate = min(1.0, n_s / max(n, 1))
    xs, ys = _sampling.sample_pairs(keys, rate=sample_rate, rng=rngs[0])

    if queries is None:
        qx, qy = xs, ys
    else:
        queries = np.asarray(queries, np.float64)
        if queries.shape[0] > n_s:  # cap the scoring cost at O(n_s)
            queries = rngs[1].choice(queries, n_s, replace=False)
        qx = np.sort(queries)
        qy = _query_positions(keys, qx).astype(np.float64)

    cand_grid = list(grid) if grid is not None else list(default_grid(n))
    if dynamic:
        cand_grid = [(m, kw) for m, kw in cand_grid if m in ("pgm", "fiting")]

    scored = []
    for name, kwargs in cand_grid:
        mech = MECHANISMS[name](**kwargs)
        mech.fit(xs, ys)
        plm = getattr(mech, "plm", None)
        if plm is not None and name in ("pgm", "fiting") and sample_rate < 1.0:
            _sampling.connect_segments(plm)
        rep = _mdl.mdl_report(name, mech, qx, qy, alpha=alpha)
        scored.append((name, dict(kwargs), rep))
    if not scored:
        raise ValueError("autotune: empty candidate grid")

    def within_budget(rep: _mdl.MDLReport) -> bool:
        if size_budget_bytes is not None and \
                rep.l_model_bytes > size_budget_bytes:
            return False
        if max_err_budget is not None and rep.max_abs_err > max_err_budget:
            return False
        return True

    eligible = [c for c in scored if within_budget(c[2])]
    budget_met = bool(eligible)
    if not eligible:  # every candidate busts the budget: smallest model
        eligible = [min(scored, key=lambda c: c[2].l_model_bytes)]
    name, kwargs, rep = min(eligible, key=lambda c: c[2].mdl)

    return TunedChoice(
        method=name,
        mech_kwargs=kwargs,
        sample_rate=sample_rate,
        score=float(rep.mdl),
        report=rep,
        hoeffding_eps=_sampling.hoeffding_bound(rep.max_abs_err,
                                                int(xs.shape[0])),
        budget_met=budget_met,
        candidates=tuple(
            {"method": m, "mech_kwargs": kw, "mdl": float(r.mdl),
             "size_bytes": int(r.l_model_bytes),
             "max_abs_err": float(r.max_abs_err)}
            for m, kw, r in scored),
    )
