"""Core learned-index library: the paper's contribution behind ONE
epoch-versioned handle.

``Index`` (handle.py) is the public surface: it owns the mutable host
state (mechanism + gapped array) and the frozen device state (a
``kernels.QueryEngine``), versioned by an epoch counter.  Reads go
through ``index.lookup(queries) -> LookupResult`` on a backend resolved
from the capability registry (``pallas`` / ``xla-windowed`` /
``numpy-oracle``); writes go through ``index.ingest(keys, payloads) ->
IngestReport``, which delta-updates the resident device buffers and only
refreezes past the contested-remainder / link-growth thresholds.  See
``handle.py`` for the full epoch-protocol and backend-capability docs.

Self-tuning & retrain contract
------------------------------
Construction cost is a dial, not a constant:

* **Sampled end-to-end builds** (§4): with ``sample_rate < 1.0`` every
  learning stage of ``build_gapped`` — base fit, Eq.3 gap targets, the
  step-3 refit — runs on the sampled (key, full-position) pairs, so
  mechanism learning is O(n_s); only physical placement and the
  ``_finalize_errors`` refinalize backstop stay O(n).  Answers are
  BIT-IDENTICAL to a full-data build: ``connect_segments`` keeps
  unsampled keys interpolated and the refinalized bounds restore the
  bounded-window kernel contract exactly.  ``GappedArray
  .build_timings`` / ``Index.learn_seconds`` record the split.
* **MDL auto-tuning** (§3): ``Index.build(method="auto")`` runs
  ``tuning.autotune`` — a (mechanism, eps, sample-size) grid fit on a
  Thm.1-sized sample, scored by query-weighted ``mdl_report`` under the
  lower-bounds space/error budget — and builds the winner (recorded on
  ``index.tuned``).  Sharded builds tune PER SHARD.
* **Online retrain**: ``Index.retrain(sample_rate=...)`` refits the
  LIVE key set (occupied slots + chains, ``GappedArray.live_items``)
  through the same sampled pipeline and swaps the state in with the
  epoch bumped — old arrays are replaced, never mutated, so pinned
  serving snapshots stay bit-identical throughout (see
  ``repro.serving``).  ``Index.mdl()`` scores the live set, so the
  report tracks post-ingest drift — the retrain trigger's input.

Layout:
  mechanisms.py — RMI / FITing-Tree / PGM / B+Tree in one PLM framework
  mdl.py        — §3 MDL objective (L(M), L(D|M), reports)
  sampling.py   — §4 sampling + coverage patches + theory bounds
  tuning.py     — §3-guided auto-tuner (grid scored by sampled MDL)
  gaps.py       — §5 result-driven gap insertion, gapped array, dynamics
  links.py      — CSR-native linking arrays (canonical chain storage)
  results.py    — typed LookupResult / IngestReport
  handle.py     — the unified Index handle (epochs, backends, deltas)
  index.py      — legacy LearnedIndex facade (deprecation shim)
"""

from .handle import BACKENDS, BackendSpec, Index
from .index import LearnedIndex
from .links import CSRLinks
from .mechanisms import (
    BTreeMechanism,
    FITingMechanism,
    MECHANISMS,
    PGMMechanism,
    PiecewiseLinearModel,
    RMIMechanism,
    build_mechanism,
)
from .mdl import MDLReport, correction_cost, mae, mdl_report
from .results import IngestReport, LookupResult, Overloaded
from .sampling import (
    exponential_search,
    fit_sampled,
    hoeffding_bound,
    refinalize_bounds,
    sample_pairs,
    sample_size_bound,
    spawn_rngs,
)
from .tuning import TunedChoice, autotune
from .gaps import GappedArray, GapSnapshot, build_gapped, gap_positions

__all__ = [
    "Index",
    "BackendSpec",
    "BACKENDS",
    "LearnedIndex",
    "LookupResult",
    "IngestReport",
    "Overloaded",
    "CSRLinks",
    "BTreeMechanism",
    "FITingMechanism",
    "MECHANISMS",
    "PGMMechanism",
    "PiecewiseLinearModel",
    "RMIMechanism",
    "build_mechanism",
    "MDLReport",
    "correction_cost",
    "mae",
    "mdl_report",
    "exponential_search",
    "fit_sampled",
    "hoeffding_bound",
    "refinalize_bounds",
    "sample_pairs",
    "sample_size_bound",
    "spawn_rngs",
    "TunedChoice",
    "autotune",
    "GappedArray",
    "GapSnapshot",
    "build_gapped",
    "gap_positions",
]
