"""Core learned-index library: the paper's contribution behind ONE
epoch-versioned handle.

``Index`` (handle.py) is the public surface: it owns the mutable host
state (mechanism + gapped array) and the frozen device state (a
``kernels.QueryEngine``), versioned by an epoch counter.  Reads go
through ``index.lookup(queries) -> LookupResult`` on a backend resolved
from the capability registry (``pallas`` / ``xla-windowed`` /
``numpy-oracle``); writes go through ``index.ingest(keys, payloads) ->
IngestReport``, which delta-updates the resident device buffers and only
refreezes past the contested-remainder / link-growth thresholds.  See
``handle.py`` for the full epoch-protocol and backend-capability docs.

Layout:
  mechanisms.py — RMI / FITing-Tree / PGM / B+Tree in one PLM framework
  mdl.py        — §3 MDL objective (L(M), L(D|M), reports)
  sampling.py   — §4 sampling + coverage patches + theory bounds
  gaps.py       — §5 result-driven gap insertion, gapped array, dynamics
  links.py      — CSR-native linking arrays (canonical chain storage)
  results.py    — typed LookupResult / IngestReport
  handle.py     — the unified Index handle (epochs, backends, deltas)
  index.py      — legacy LearnedIndex facade (deprecation shim)
"""

from .handle import BACKENDS, BackendSpec, Index
from .index import LearnedIndex
from .links import CSRLinks
from .mechanisms import (
    BTreeMechanism,
    FITingMechanism,
    MECHANISMS,
    PGMMechanism,
    PiecewiseLinearModel,
    RMIMechanism,
    build_mechanism,
)
from .mdl import MDLReport, correction_cost, mae, mdl_report
from .results import IngestReport, LookupResult, Overloaded
from .sampling import (
    exponential_search,
    fit_sampled,
    hoeffding_bound,
    refinalize_bounds,
    sample_pairs,
    sample_size_bound,
)
from .gaps import GappedArray, GapSnapshot, build_gapped, gap_positions

__all__ = [
    "Index",
    "BackendSpec",
    "BACKENDS",
    "LearnedIndex",
    "LookupResult",
    "IngestReport",
    "Overloaded",
    "CSRLinks",
    "BTreeMechanism",
    "FITingMechanism",
    "MECHANISMS",
    "PGMMechanism",
    "PiecewiseLinearModel",
    "RMIMechanism",
    "build_mechanism",
    "MDLReport",
    "correction_cost",
    "mae",
    "mdl_report",
    "exponential_search",
    "fit_sampled",
    "hoeffding_bound",
    "refinalize_bounds",
    "sample_pairs",
    "sample_size_bound",
    "GappedArray",
    "GapSnapshot",
    "build_gapped",
    "gap_positions",
]
