"""Core learned-index library: the paper's contribution.

Layout:
  mechanisms.py — RMI / FITing-Tree / PGM / B+Tree in one PLM framework
  mdl.py        — §3 MDL objective (L(M), L(D|M), reports)
  sampling.py   — §4 sampling + coverage patches + theory bounds
  gaps.py       — §5 result-driven gap insertion, gapped array, dynamics
  index.py      — pluggable facade combining all of the above
"""

from .index import LearnedIndex
from .mechanisms import (
    BTreeMechanism,
    FITingMechanism,
    MECHANISMS,
    PGMMechanism,
    PiecewiseLinearModel,
    RMIMechanism,
    build_mechanism,
)
from .mdl import MDLReport, correction_cost, mae, mdl_report
from .sampling import (
    exponential_search,
    fit_sampled,
    hoeffding_bound,
    refinalize_bounds,
    sample_pairs,
    sample_size_bound,
)
from .gaps import GappedArray, build_gapped, gap_positions

__all__ = [
    "LearnedIndex",
    "BTreeMechanism",
    "FITingMechanism",
    "MECHANISMS",
    "PGMMechanism",
    "PiecewiseLinearModel",
    "RMIMechanism",
    "build_mechanism",
    "MDLReport",
    "correction_cost",
    "mae",
    "mdl_report",
    "exponential_search",
    "fit_sampled",
    "hoeffding_bound",
    "refinalize_bounds",
    "sample_pairs",
    "sample_size_bound",
    "GappedArray",
    "build_gapped",
    "gap_positions",
]
