"""Pluggable LearnedIndex facade — the paper's techniques as composable knobs.

``LearnedIndex.build(keys, method=..., sample_rate=..., gap_rho=...)``
combines any base mechanism (rmi / fiting / pgm / btree) with the two
pluggable techniques:

* ``sample_rate < 1``  -> §4 sampling (+ coverage patches)
* ``gap_rho > 0``      -> §5 result-driven gap insertion (gapped layout,
                          linking arrays, dynamic ops)

Static layout (no gaps) supports batched exact lookup via bounded search;
gapped layout additionally supports insert/delete/update without
retraining.  ``mdl()`` evaluates the instance under the §3 framework.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from . import gaps as _gaps
from . import mdl as _mdl
from . import sampling as _sampling
from .mechanisms import MECHANISMS

__all__ = ["LearnedIndex"]


def _mechanism_factory(method: str, **kwargs):
    cls = MECHANISMS[method]
    return lambda: cls(**kwargs)


@dataclasses.dataclass
class LearnedIndex:
    """A built index over a sorted unique key array."""

    keys: np.ndarray
    mech: object
    method: str
    gapped: Optional[_gaps.GappedArray] = None
    sample_rate: float = 1.0
    gap_rho: float = 0.0
    build_seconds: float = 0.0

    # ------------------------------------------------------------------
    @staticmethod
    def build(
        keys: np.ndarray,
        method: str = "pgm",
        sample_rate: float = 1.0,
        gap_rho: float = 0.0,
        rng: Optional[np.random.Generator] = None,
        **mech_kwargs,
    ) -> "LearnedIndex":
        keys = np.asarray(keys, np.float64)
        if keys.ndim != 1 or keys.shape[0] < 2:
            raise ValueError("need a 1-D array of at least two keys")
        if not bool(np.all(np.diff(keys) > 0)):
            raise ValueError("keys must be sorted, strictly increasing (unique)")
        factory = _mechanism_factory(method, **mech_kwargs)
        t0 = time.perf_counter()
        if gap_rho > 0.0:
            refit_factory = None
            if method in ("pgm", "fiting") and "eps" in mech_kwargs:
                # D_g is near-linear: tighter refit eps => precise
                # placement, short linking arrays (beyond-paper knob)
                rkw = dict(mech_kwargs)
                rkw["eps"] = max(4.0, float(mech_kwargs["eps"]) / 16.0)
                refit_factory = _mechanism_factory(method, **rkw)
            ga = _gaps.build_gapped(
                factory, keys, rho=gap_rho, sample_rate=sample_rate, rng=rng,
                refit_factory=refit_factory,
            )
            mech = ga.mech
            gapped = ga
        else:
            gapped = None
            if sample_rate < 1.0:
                mech = _sampling.fit_sampled(factory, keys, rate=sample_rate, rng=rng)
            else:
                mech = factory()
                mech.fit(keys, np.arange(keys.shape[0], dtype=np.float64))
        dt = time.perf_counter() - t0
        return LearnedIndex(
            keys=keys,
            mech=mech,
            method=method,
            gapped=gapped,
            sample_rate=sample_rate,
            gap_rho=gap_rho,
            build_seconds=dt,
        )

    # ------------------------------------------------------------------
    def predict(self, qs: np.ndarray) -> np.ndarray:
        return self.mech.predict(np.asarray(qs, np.float64))

    def lookup(self, qs: np.ndarray) -> np.ndarray:
        """Exact positions (static) or payloads (gapped); -1 for misses."""
        qs = np.asarray(qs, np.float64)
        if self.gapped is not None:
            return self.gapped.lookup_batch(qs)
        pos = _sampling.exponential_search(self.keys, qs, self.predict(qs))
        found = self.keys[pos] == qs
        return np.where(found, pos, -1)

    def insert(self, key: float, payload: int) -> str:
        if self.gapped is None:
            raise NotImplementedError(
                "dynamic ops need gap insertion (build with gap_rho > 0)"
            )
        return self.gapped.insert(key, payload)

    def insert_batch(self, keys: np.ndarray, payloads: np.ndarray) -> dict:
        """Vectorized bulk insert; state-identical to sequential insert()."""
        if self.gapped is None:
            raise NotImplementedError(
                "dynamic ops need gap insertion (build with gap_rho > 0)"
            )
        return self.gapped.insert_batch(keys, payloads)

    def delete(self, key: float) -> bool:
        if self.gapped is None:
            raise NotImplementedError(
                "dynamic ops need gap insertion (build with gap_rho > 0)"
            )
        return self.gapped.delete(key)

    def delete_batch(self, keys: np.ndarray) -> int:
        """Bulk delete; returns the number of keys removed."""
        if self.gapped is None:
            raise NotImplementedError(
                "dynamic ops need gap insertion (build with gap_rho > 0)"
            )
        return self.gapped.delete_batch(keys)

    def update(self, key: float, payload: int) -> bool:
        if self.gapped is None:
            raise NotImplementedError(
                "dynamic ops need gap insertion (build with gap_rho > 0)"
            )
        return self.gapped.update(key, payload)

    # ------------------------------------------------------------------
    def mdl(self, alpha: float = 1.0) -> _mdl.MDLReport:
        """Evaluate under the §3 MDL framework (positions = logical y)."""
        y = np.arange(self.keys.shape[0], dtype=np.float64)
        if self.gapped is not None:
            # positions are physical slots in the gapped layout
            y = np.searchsorted(self.gapped.slot_key, self.keys, side="right") - 1
        return _mdl.mdl_report(self.method, self.mech, self.keys, y, alpha=alpha)
