"""Legacy ``LearnedIndex`` facade — a thin deprecation shim over the
unified ``repro.core.Index`` handle.

``LearnedIndex`` predates the epoch-versioned handle; it returned bare
arrays from ``lookup`` (positions for static builds, payloads for gapped
ones, -1 sentinels for both) and ad-hoc dicts/strings from dynamic ops.
The handle replaces all of that with typed results (``LookupResult`` /
``IngestReport``) and owns the frozen device state.

Migration:

====================================  =================================
old                                   new
====================================  =================================
``LearnedIndex.build(...)``           ``Index.build(...)``
``idx.lookup(q) -> ndarray``          ``idx.lookup(q).payloads``
``idx.insert_batch(k, p) -> dict``    ``idx.ingest(k, p) -> IngestReport``
``QueryEngine.from_index(idx)``       ``idx.lookup(q, backend=...)`` (the
                                      handle freezes lazily and keeps the
                                      engine fresh via delta updates)
====================================  =================================

``LearnedIndex.lookup`` keeps the old array returns for one release and
emits a ``DeprecationWarning``; everything else inherits the handle's
behavior unchanged (same build knobs, same §5.3 dynamic ops).
"""

from __future__ import annotations

import warnings

import numpy as np

from .handle import Index

__all__ = ["LearnedIndex"]


class LearnedIndex(Index):
    """Deprecated facade — use ``repro.core.Index`` (see module doc)."""

    def lookup(self, qs: np.ndarray, **kwargs) -> np.ndarray:
        """Legacy lookup: positions (static) / payloads (gapped); -1 for
        misses.  One-release shim: routes through the unified
        ``LookupResult`` and returns its payload array (identical values
        — static payloads ARE positions), warning once per call site.
        """
        warnings.warn(
            "LearnedIndex.lookup returning a bare array is deprecated; "
            "use repro.core.Index.lookup -> LookupResult (payloads/slots/"
            "found/stats)", DeprecationWarning, stacklevel=2)
        return np.asarray(Index.lookup(self, qs, **kwargs).payloads)
