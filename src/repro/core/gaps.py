"""Result-driven gap insertion and the gapped physical layout (paper §5).

Pipeline (``build_gapped``):

1. Learn a base mechanism with K segments on (x, y) — optionally on a
   sample (§5.4 "Combining Sampling and Gap Insertion").
2. **Result-driven position manipulation** (Eq. 3): per segment k, propose
   the hypothetical line through the gap-shifted endpoints; every key's
   target position is
   ``y^g = y_k1 + S_k + (x - x_k1) * (y_km - y_k1) (1 + rho) / (x_km - x_k1)``
   with ``S_k = sum of gaps inserted in earlier segments`` and gap budget
   ``rho * n`` overall.
3. Re-learn the mechanism on the gap-inserted pairs (x, y^g) — the data is
   now near-linear per segment, so the re-learned index is much more
   precise (this is the paper's information-bottleneck argument, §5.1).
4. **Physical key placement** (§5.2): place each key at its re-learned
   predicted slot ``round(M(x))``; prediction conflicts and monotonicity
   violations go to per-slot **linking arrays**; slot-key total order is
   maintained by giving unoccupied slots the key of the first occupied slot
   to their right ("empty payload sorts before non-empty").

Dynamic scenario (§5.3): inserts land on their predicted slot when it is
free and order-compatible (the gaps were *reserved in a data-dependent
way*, so this is the common case), otherwise they chain onto the upper-
bound slot's linking array.  Deletes/updates are local.  No retraining.

The frozen arrays (`slot_key`, `occupied`, CSR links) are exactly what the
jnp reference and the Pallas lookup kernel consume (``repro.kernels``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .mechanisms import PiecewiseLinearModel, _finalize_errors
from . import sampling as _sampling

__all__ = ["gap_positions", "GappedArray", "build_gapped"]

_EMPTY = np.iinfo(np.int64).min  # payload marker for unoccupied slots


def gap_positions(
    x: np.ndarray,
    y: np.ndarray,
    plm: PiecewiseLinearModel,
    rho: float,
) -> np.ndarray:
    """Eq. 3 — target positions y^g for every key, fully vectorized.

    Segment boundaries come from ``plm`` (learned on (x, y) or a sample);
    anchoring points are each segment's first/last *present* key.
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    seg = plm.segment_of(x)
    K = plm.n_segments
    # first/last data index per segment (segments may be empty under sampling)
    first = np.full(K, -1, np.int64)
    last = np.full(K, -1, np.int64)
    idx = np.arange(x.shape[0], dtype=np.int64)
    first = np.full(K, x.shape[0], np.int64)
    np.minimum.at(first, seg, idx)
    np.maximum.at(last, seg, idx)
    n = x.shape[0]
    present = first < n
    f_idx = np.minimum(first, n - 1)
    l_idx = np.clip(last, 0, n - 1)
    y_first = np.where(present, y[f_idx], 0.0)
    y_last = np.where(present, y[l_idx], 0.0)
    x_first = np.where(present, x[f_idx], 0.0)
    x_last = np.where(present, x[l_idx], 1.0)
    # gaps inserted inside segment j:  U_j = rho * (y_jm - y_j1)
    U = np.where(present, rho * (y_last - y_first), 0.0)
    S = np.concatenate([[0.0], np.cumsum(U)[:-1]])  # sum over j < k
    dx = np.where(x_last > x_first, x_last - x_first, 1.0)
    scale = (y_last - y_first) * (1.0 + rho) / dx
    yg = y_first[seg] + S[seg] + (x - x_first[seg]) * scale[seg]
    # monotonicity guard: numerical ties across segment boundaries
    return np.maximum.accumulate(yg)


@dataclasses.dataclass
class GappedArray:
    """First-level gapped array G + linking arrays (paper §5.2).

    * ``slot_key[i]``: the total-order key of slot i.  Occupied slots hold
      ``min(A_i)``; unoccupied slots carry the key of the first occupied
      slot to their right (+inf past the last occupied slot).
    * ``payload[i]``: payload of the occupied slot's min key, or _EMPTY.
    * ``links``: slot -> list of (key, payload), keys > slot min, sorted.
    """

    slot_key: np.ndarray           # (m,) float64
    occupied: np.ndarray           # (m,) bool
    payload: np.ndarray            # (m,) int64
    links: Dict[int, List[Tuple[float, int]]]
    mech: object                   # re-learned mechanism (predicts slots)
    n_keys: int
    rho: float

    # ------------------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return int(self.slot_key.shape[0])

    @property
    def gap_fraction(self) -> float:
        return float(1.0 - self.occupied.mean())

    def link_stats(self) -> Tuple[int, int]:
        """(#chained keys, max chain length)."""
        if not self.links:
            return 0, 0
        lens = [len(v) for v in self.links.values()]
        return int(sum(lens)), int(max(lens))

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def _upper_bound_slot(self, q: float) -> int:
        """Rightmost slot whose (total-order) key is <= q and occupied.

        Relies on the carried-key construction: the last slot with
        slot_key < q is always occupied; for slot_key == q the occupied
        slot is the last one of the equal run.
        """
        j = int(np.searchsorted(self.slot_key, q, side="right")) - 1
        while j >= 0 and not self.occupied[j]:
            j -= 1  # only possible at the very front (all-carried prefix)
        return j

    def lookup(self, q: float) -> Optional[int]:
        """Exact-match lookup -> payload or None (paper's read path)."""
        j = self._upper_bound_slot(q)
        if j < 0:
            return None
        if self.slot_key[j] == q:
            return int(self.payload[j])
        for k, p in self.links.get(j, ()):  # bounded linear chain scan
            if k == q:
                return int(p)
        return None

    def _csr(self):
        """Cached CSR link tables (invalidated by dynamic ops)."""
        if getattr(self, "_csr_cache", None) is None:
            self._csr_cache = self.export_csr_links()
        return self._csr_cache

    def _invalidate(self):
        self._csr_cache = None

    def lookup_batch(self, qs: np.ndarray, bounded: bool = True) -> np.ndarray:
        """Vectorized batch lookup; -1 for misses (numpy kernel reference).

        ``bounded`` uses the mechanism's prediction + exponential search
        (the paper's correction step — cost scales with log|err|, which
        is where gap insertion's precision pays off); otherwise a plain
        full-array binary search.
        """
        from . import sampling as _s

        qs = np.asarray(qs, np.float64)
        if bounded and getattr(self.mech, "plm", None) is not None:
            y_hat = self.mech.predict(qs)
            j = _s.exponential_search(self.slot_key, qs, y_hat)
        else:
            j = np.searchsorted(self.slot_key, qs, side="right") - 1
        out = np.full(qs.shape[0], -1, np.int64)
        ok = j >= 0
        hit = ok & (np.where(ok, self.slot_key[np.maximum(j, 0)], np.nan) == qs)
        out[hit] = self.payload[j[hit]]
        # vectorized chain scan over the CSR link tables for the misses
        miss = np.flatnonzero(ok & ~hit)
        if miss.size:
            offsets, lkeys, lpays = self._csr()
            start = offsets[j[miss]]
            end = offsets[j[miss] + 1]
            live = np.flatnonzero(end > start)
            start, end = start[live], end[live]
            midx = miss[live]
            t = 0
            max_t = int(np.max(end - start)) if live.size else 0
            while t < max_t and midx.size:
                idx = start + t
                in_chain = idx < end
                found = in_chain & (lkeys[np.minimum(idx, len(lkeys) - 1)]
                                    == qs[midx])
                out[midx[found]] = lpays[idx[found]]
                keep = in_chain & ~found
                start, end, midx = start[keep], end[keep], midx[keep]
                t += 1
        return out

    # ------------------------------------------------------------------
    # dynamic path (paper §5.3) — host-side mutation, no retraining
    # ------------------------------------------------------------------
    def _prev_occupied(self, i: int) -> int:
        j = i
        while j >= 0 and not self.occupied[j]:
            j -= 1
        return j

    def _next_occupied(self, i: int) -> int:
        m = self.n_slots
        j = i
        while j < m and not self.occupied[j]:
            j += 1
        return j  # == m when none

    def insert(self, key: float, payload: int) -> str:
        """Insert via predicted position.  Returns 'slot'|'chain' (path taken)."""
        self._invalidate()
        m = self.n_slots
        p = int(np.clip(np.rint(self.mech.predict(np.array([key]))[0]), 0, m - 1))
        if not self.occupied[p]:
            prev = self._prev_occupied(p)
            nxt = self._next_occupied(p)
            # order check must include the previous slot's chain maximum
            # (total-order invariant: max(A_{i-1}) < G(i), paper §5.3)
            prev_max = -np.inf
            if prev >= 0:
                prev_max = float(self.slot_key[prev])
                chain = self.links.get(prev)
                if chain:
                    prev_max = max(prev_max, chain[-1][0])
            prev_ok = prev < 0 or prev_max < key
            next_ok = nxt >= m or self.slot_key[nxt] > key
            if prev_ok and next_ok:
                self.occupied[p] = True
                self.payload[p] = payload
                # carried keys: slots (prev, p] now see `key` as next occupied
                self.slot_key[prev + 1 : p + 1] = key
                self.n_keys += 1
                return "slot"
        # chain onto the upper-bound slot (or become the new global min)
        ub = self._upper_bound_slot(key)
        if ub < 0:
            nxt = self._next_occupied(0)
            if nxt >= m:  # empty structure: take slot p
                self.occupied[p] = True
                self.payload[p] = payload
                self.slot_key[: p + 1] = key
                self.n_keys += 1
                return "slot"
            # new global minimum: displace the current min into the chain
            old_key = float(self.slot_key[nxt])
            old_payload = int(self.payload[nxt])
            chain = self.links.setdefault(nxt, [])
            chain.append((old_key, old_payload))
            chain.sort()
            self.payload[nxt] = payload
            self.slot_key[: nxt + 1] = key
            self.n_keys += 1
            return "chain"
        if self.slot_key[ub] == key:
            raise KeyError(f"duplicate key {key!r}")
        chain = self.links.setdefault(ub, [])
        if any(k == key for k, _ in chain):
            raise KeyError(f"duplicate key {key!r}")
        chain.append((key, payload))
        chain.sort()
        self.n_keys += 1
        return "chain"

    def delete(self, key: float) -> bool:
        """Delete a key (paper §5.3).  Returns True if present."""
        self._invalidate()
        ub = self._upper_bound_slot(key)
        if ub < 0:
            return False
        chain = self.links.get(ub)
        if self.slot_key[ub] == key:
            if chain:  # promote chain min into the slot
                k2, p2 = chain.pop(0)
                if not chain:
                    del self.links[ub]
                prev = self._prev_occupied(ub - 1)
                self.slot_key[prev + 1 : ub + 1] = k2
                self.payload[ub] = p2
            else:  # unoccupy; carried keys point at next occupied
                self.occupied[ub] = False
                self.payload[ub] = _EMPTY
                nxt = self._next_occupied(ub)
                nk = self.slot_key[nxt] if nxt < self.n_slots else np.inf
                prev = self._prev_occupied(ub)
                self.slot_key[prev + 1 : nxt] = nk
            self.n_keys -= 1
            return True
        if chain:
            for t, (k, _) in enumerate(chain):
                if k == key:
                    chain.pop(t)
                    if not chain:
                        del self.links[ub]
                    self.n_keys -= 1
                    return True
        return False

    def update(self, key: float, payload: int) -> bool:
        """Reset the payload of an existing key (paper §5.3)."""
        self._invalidate()
        ub = self._upper_bound_slot(key)
        if ub < 0:
            return False
        if self.slot_key[ub] == key:
            self.payload[ub] = payload
            return True
        chain = self.links.get(ub, [])
        for t, (k, _) in enumerate(chain):
            if k == key:
                chain[t] = (key, payload)
                return True
        return False

    # ------------------------------------------------------------------
    # frozen export for the jnp/Pallas query path
    # ------------------------------------------------------------------
    def export_csr_links(self, max_chain: Optional[int] = None):
        """CSR link tables: (offsets (m+1,), keys (L,), payloads (L,)).

        ``max_chain`` bounds per-slot chains for the fixed-trip-count
        kernel; overflow raises (asserted rare — paper §5.2 observes
        chains are short).
        """
        m = self.n_slots
        counts = np.zeros(m + 1, np.int64)
        for i, chain in self.links.items():
            counts[i + 1] = len(chain)
            if max_chain is not None and len(chain) > max_chain:
                raise ValueError(
                    f"chain at slot {i} has {len(chain)} > max_chain={max_chain}"
                )
        offsets = np.cumsum(counts)
        total = int(offsets[-1])
        keys = np.empty(total, np.float64)
        payloads = np.empty(total, np.int64)
        for i, chain in self.links.items():
            o = offsets[i]
            for t, (k, p) in enumerate(chain):
                keys[o + t] = k
                payloads[o + t] = p
        return offsets, keys, payloads


def _place_keys(
    x: np.ndarray,
    payloads: np.ndarray,
    pred_slot: np.ndarray,
    m: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Dict[int, List[Tuple[float, int]]]]:
    """Linking-array placement (§5.2): slot = prediction; conflicts chain.

    Keys arrive sorted; we keep a cursor at the last occupied slot.  A key
    predicted at/behind the cursor chains onto the cursor slot; otherwise
    it occupies its predicted slot.
    """
    slot_key = np.full(m, np.inf, np.float64)
    occupied = np.zeros(m, bool)
    payload = np.full(m, _EMPTY, np.int64)
    links: Dict[int, List[Tuple[float, int]]] = {}
    cur = -1
    for t in range(x.shape[0]):
        p = int(pred_slot[t])
        if p > cur:
            slot_key[p] = x[t]
            occupied[p] = True
            payload[p] = payloads[t]
            cur = p
        else:
            links.setdefault(cur, []).append((float(x[t]), int(payloads[t])))
    # carried keys for unoccupied slots: next occupied key to the right
    carried = slot_key.copy()
    nxt = np.inf
    for i in range(m - 1, -1, -1):
        if occupied[i]:
            nxt = carried[i]
        else:
            carried[i] = nxt
    return carried, occupied, payload, links


def build_gapped(
    mechanism_factory,
    x: np.ndarray,
    payloads: Optional[np.ndarray] = None,
    rho: float = 0.1,
    sample_rate: float = 1.0,
    rng: Optional[np.random.Generator] = None,
    refinalize: bool = True,
    refit_factory=None,
) -> GappedArray:
    """Full §5 pipeline: base fit (+sampling §5.4) -> Eq.3 -> re-learn -> place.

    ``refit_factory`` builds the step-3 mechanism re-learned on the
    gap-inserted data; default is the base factory.  Because D_g is
    near-linear per segment, a *tighter* eps here costs few segments but
    sharply reduces placement collisions (shorter linking arrays) — see
    LearnedIndex.build's adaptive default.
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    y = np.arange(n, dtype=np.float64)
    if payloads is None:
        payloads = np.arange(n, dtype=np.int64)

    # 1) base mechanism (optionally on a sample)
    if sample_rate < 1.0:
        base = _sampling.fit_sampled(
            mechanism_factory, x, y, rate=sample_rate, rng=rng, refinalize=False
        )
    else:
        base = mechanism_factory()
        base.fit(x, y)
    base_plm = getattr(base, "plm", None)
    if base_plm is None:
        raise ValueError("gap insertion needs a PLM-exporting mechanism")

    # 2) result-driven target positions (Eq. 3)
    yg = gap_positions(x, y, base_plm, rho)

    # 3) re-learn on the gap-inserted data
    mech = (refit_factory or mechanism_factory)()
    mech.fit(x, yg)

    # 4) physical placement at re-learned predictions
    m = int(np.ceil(yg[-1])) + 2
    pred = np.clip(np.rint(mech.predict(x)), 0, m - 1).astype(np.int64)
    slot_key, occupied, payload, links = _place_keys(x, payloads, pred, m)

    ga = GappedArray(
        slot_key=slot_key,
        occupied=occupied,
        payload=payload,
        links=links,
        mech=mech,
        n_keys=n,
        rho=rho,
    )
    # error bounds against *physical* slots so bounded search is exact
    if refinalize and getattr(mech, "plm", None) is not None:
        slot_of_key = np.searchsorted(ga.slot_key, x, side="right") - 1
        _finalize_errors(mech.plm, x, slot_of_key.astype(np.float64))
    return ga
