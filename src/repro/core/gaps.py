"""Result-driven gap insertion and the gapped physical layout (paper §5).

Pipeline (``build_gapped``):

1. Learn a base mechanism with K segments on (x, y) — optionally on a
   sample (§5.4 "Combining Sampling and Gap Insertion").
2. **Result-driven position manipulation** (Eq. 3): per segment k, propose
   the hypothetical line through the gap-shifted endpoints; every key's
   target position is
   ``y^g = y_k1 + S_k + (x - x_k1) * (y_km - y_k1) (1 + rho) / (x_km - x_k1)``
   with ``S_k = sum of gaps inserted in earlier segments`` and gap budget
   ``rho * n`` overall.
3. Re-learn the mechanism on the gap-inserted pairs (x, y^g) — the data is
   now near-linear per segment, so the re-learned index is much more
   precise (this is the paper's information-bottleneck argument, §5.1).
4. **Physical key placement** (§5.2): place each key at its re-learned
   predicted slot ``round(M(x))``; prediction conflicts and monotonicity
   violations go to per-slot **linking arrays**; slot-key total order is
   maintained by giving unoccupied slots the key of the first occupied slot
   to their right ("empty payload sorts before non-empty").

Dynamic scenario (§5.3): inserts land on their predicted slot when it is
free and order-compatible (the gaps were *reserved in a data-dependent
way*, so this is the common case), otherwise they chain onto the upper-
bound slot's linking array.  Deletes/updates are local.  No retraining.

The frozen arrays (`slot_key`, `occupied`, CSR links) are exactly what the
jnp reference and the Pallas lookup kernel consume (``repro.kernels``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import numpy as np

from .links import CSRLinks
from .mechanisms import PiecewiseLinearModel, _finalize_errors
from . import sampling as _sampling

__all__ = ["gap_positions", "GappedArray", "GapSnapshot", "build_gapped"]

_EMPTY = np.iinfo(np.int64).min  # payload marker for unoccupied slots


class _PinCell:
    """Shared refcount between a live ``GappedArray`` and the snapshots
    pinning its current arrays.  The live side checks ``count`` inside
    ``_invalidate`` (copy-on-write trigger); snapshots decrement on
    ``release`` so the auditor can prove no snapshot leaks."""

    __slots__ = ("count",)

    def __init__(self):
        self.count = 0


def _group_extreme(rids, vals, n_runs, fill, reducer):
    """Per-run extreme of ``vals`` grouped by run id (``fill`` for runs
    with no entries) — one argsort + reduceat over batch-sized arrays."""
    out = np.full(n_runs, fill)
    if rids.size:
        o = np.argsort(rids, kind="stable")
        r, v = rids[o], vals[o]
        starts = np.flatnonzero(np.r_[True, r[1:] != r[:-1]])
        out[r[starts]] = reducer.reduceat(v, starts)
    return out


def _seg_suffix_min(vals, segs):
    """Per-position min over the value suffix of its segment (positions
    ascending, segment ids non-decreasing and contiguous).

    Vectorized segmented reverse scan: dense value ranks plus an offset
    of n per segment make every later-segment entry unbeatable, so ONE
    global reverse ``minimum.accumulate`` realizes the per-segment
    reset, and the rank decodes back to the value."""
    n = vals.shape[0]
    if n == 0:
        return vals
    o = np.argsort(vals, kind="stable")
    rk = np.empty(n, np.int64)
    rk[o] = np.arange(n, dtype=np.int64)
    seg_d = np.cumsum(np.r_[True, segs[1:] != segs[:-1]]) - 1
    w = rk + seg_d * np.int64(n)
    wm = np.minimum.accumulate(w[::-1])[::-1]
    return vals[o[wm - seg_d * np.int64(n)]]


def gap_positions(
    x: np.ndarray,
    y: np.ndarray,
    plm: PiecewiseLinearModel,
    rho: float,
) -> np.ndarray:
    """Eq. 3 — target positions y^g for every key, fully vectorized.

    Segment boundaries come from ``plm`` (learned on (x, y) or a sample);
    anchoring points are each segment's first/last *present* key.
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    seg = plm.segment_of(x)
    K = plm.n_segments
    # first/last data index per segment (segments may be empty under sampling)
    first = np.full(K, -1, np.int64)
    last = np.full(K, -1, np.int64)
    idx = np.arange(x.shape[0], dtype=np.int64)
    first = np.full(K, x.shape[0], np.int64)
    np.minimum.at(first, seg, idx)
    np.maximum.at(last, seg, idx)
    n = x.shape[0]
    present = first < n
    f_idx = np.minimum(first, n - 1)
    l_idx = np.clip(last, 0, n - 1)
    y_first = np.where(present, y[f_idx], 0.0)
    y_last = np.where(present, y[l_idx], 0.0)
    x_first = np.where(present, x[f_idx], 0.0)
    x_last = np.where(present, x[l_idx], 1.0)
    # gaps inserted inside segment j:  U_j = rho * (y_jm - y_j1)
    U = np.where(present, rho * (y_last - y_first), 0.0)
    S = np.concatenate([[0.0], np.cumsum(U)[:-1]])  # sum over j < k
    dx = np.where(x_last > x_first, x_last - x_first, 1.0)
    scale = (y_last - y_first) * (1.0 + rho) / dx
    yg = y_first[seg] + S[seg] + (x - x_first[seg]) * scale[seg]
    # monotonicity guard: numerical ties across segment boundaries
    return np.maximum.accumulate(yg)


@dataclasses.dataclass
class GappedArray:
    """First-level gapped array G + CSR linking arrays (paper §5.2).

    * ``slot_key[i]``: the total-order key of slot i.  Occupied slots hold
      ``min(A_i)``; unoccupied slots carry the key of the first occupied
      slot to their right (+inf past the last occupied slot).
    * ``payload[i]``: payload of the occupied slot's min key, or _EMPTY.
    * ``links``: ``CSRLinks`` — per-slot key-sorted chains stored natively
      as CSR (offsets / chain_keys / chain_payloads) arrays; the frozen
      device export is these arrays verbatim.
    * ``version``: monotone mutation counter — every dynamic op bumps it;
      the epoch-versioned ``repro.core.Index`` handle uses it to detect
      host/device divergence.
    """

    slot_key: np.ndarray           # (m,) float64
    occupied: np.ndarray           # (m,) bool
    payload: np.ndarray            # (m,) int64
    links: CSRLinks
    mech: object                   # re-learned mechanism (predicts slots)
    n_keys: int
    rho: float
    version: int = 0
    # live pin cell shared with outstanding ``GapSnapshot``s (refcount);
    # None when no snapshot pins the current arrays
    _pins: object = dataclasses.field(default=None, repr=False,
                                      compare=False)
    # build_gapped's cost breakdown {"learn_seconds", "place_seconds",
    # "n_fit"}: learn = base fit + Eq.3 targets + step-3 refit (O(n_s)
    # under sampling), place = physical placement + refinalize (O(n)
    # always).  None on restored / hand-built arrays.
    build_timings: object = dataclasses.field(default=None, repr=False,
                                              compare=False)

    # ------------------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return int(self.slot_key.shape[0])

    @property
    def gap_fraction(self) -> float:
        return float(1.0 - self.occupied.mean())

    def link_stats(self) -> Tuple[int, int]:
        """(#chained keys, max chain length)."""
        return self.links.total, self.links.max_chain

    # ------------------------------------------------------------------
    # read path
    # ------------------------------------------------------------------
    def _upper_bound_slot(self, q: float) -> int:
        """Rightmost slot whose (total-order) key is <= q and occupied.

        Relies on the carried-key construction: the last slot with
        slot_key < q is always occupied; for slot_key == q the occupied
        slot is the last one of the equal run.
        """
        j = int(np.searchsorted(self.slot_key, q, side="right")) - 1
        while j >= 0 and not self.occupied[j]:
            j -= 1  # only possible at the very front (all-carried prefix)
        return j

    def lookup(self, q: float) -> Optional[int]:
        """Exact-match lookup -> payload or None (paper's read path)."""
        j = self._upper_bound_slot(q)
        if j < 0:
            return None
        if self.slot_key[j] == q:
            return int(self.payload[j])
        return self.links.find_payload(j, q)  # bounded chain bisect

    def _csr(self):
        """CSR link tables — free: they ARE the canonical storage."""
        return self.links.csr()

    def _invalidate(self):
        self.version += 1
        pins = self._pins
        if pins is not None and pins.count > 0:
            # copy-on-write: every mutator calls _invalidate() BEFORE
            # touching storage, so pinned snapshots keep the exact
            # pre-mutation arrays while the live side writes into fresh
            # private copies.  Paid once per pin, not once per mutation
            # (the cell detaches here; a new pin installs a new cell).
            self.slot_key = self.slot_key.copy()
            self.occupied = self.occupied.copy()
            self.payload = self.payload.copy()
            self.links.unshare()
            self._pins = None
        elif pins is not None:
            self._pins = None  # every snapshot released: nothing to copy

    # ------------------------------------------------------------------
    # snapshot pinning (serving-side isolation)
    # ------------------------------------------------------------------
    def pin_snapshot(self) -> "GapSnapshot":
        """Pin the current arrays into an immutable ``GapSnapshot``.

        O(1): no copies are made here — the snapshot references the live
        arrays by identity, and the first mutation after the pin pays a
        single copy-on-write inside ``_invalidate``.  Lookups through
        the snapshot are bit-identical to a quiesced lookup at this
        version forever, regardless of concurrent mutation of the live
        array.  Call ``release()`` when done serving from it."""
        self.links.flush()  # pending overlay empties before sharing CSR
        if self._pins is None:
            self._pins = _PinCell()
        self._pins.count += 1
        self.links.mark_shared()
        offsets, lkeys, lpays = self.links.csr()
        return GapSnapshot(self, offsets, lkeys, lpays, self._pins)

    def lookup_batch(self, qs: np.ndarray, bounded: bool = True,
                     full: bool = False) -> np.ndarray:
        """Vectorized batch lookup; -1 for misses (numpy kernel reference).

        ``bounded`` uses the mechanism's prediction + exponential search
        (the paper's correction step — cost scales with log|err|, which
        is where gap insertion's precision pays off); otherwise a plain
        full-array binary search.  ``full=True`` returns the triple
        ``(payloads, slots, found)`` — slots are first-level upper
        bounds, found covers slot AND chain hits (the typed-result
        contract of ``repro.core.Index.lookup``).
        """
        from . import sampling as _s

        qs = np.asarray(qs, np.float64)
        if bounded and getattr(self.mech, "plm", None) is not None:
            y_hat = self.mech.predict(qs)
            j, _probes = _s.exponential_search(self.slot_key, qs, y_hat)
        else:
            j = np.searchsorted(self.slot_key, qs, side="right") - 1
        out = np.full(qs.shape[0], -1, np.int64)
        ok = j >= 0
        hit = ok & (np.where(ok, self.slot_key[np.maximum(j, 0)], np.nan) == qs)
        out[hit] = self.payload[j[hit]]
        resolved = hit.copy()
        # vectorized chain scan over the CSR link tables for the misses
        miss = np.flatnonzero(ok & ~hit)
        if miss.size:
            offsets, lkeys, lpays = self._csr()
            start = offsets[j[miss]]
            end = offsets[j[miss] + 1]
            live = np.flatnonzero(end > start)
            start, end = start[live], end[live]
            midx = miss[live]
            t = 0
            max_t = int(np.max(end - start)) if live.size else 0
            while t < max_t and midx.size:
                idx = start + t
                in_chain = idx < end
                found = in_chain & (lkeys[np.minimum(idx, len(lkeys) - 1)]
                                    == qs[midx])
                out[midx[found]] = lpays[idx[found]]
                resolved[midx[found]] = True
                keep = in_chain & ~found
                start, end, midx = start[keep], end[keep], midx[keep]
                t += 1
        if full:
            return out, j.astype(np.int64), resolved
        return out

    def contains_batch(self, qs: np.ndarray) -> np.ndarray:
        """Vectorized membership test (present even when the stored
        payload is a sentinel like -1, which ``lookup_batch`` conflates
        with a miss)."""
        qs = np.asarray(qs, np.float64)
        j = np.searchsorted(self.slot_key, qs, side="right") - 1
        ok = j >= 0
        found = ok & (self.slot_key[np.maximum(j, 0)] == qs)
        miss = np.flatnonzero(ok & ~found)
        if miss.size:
            offsets, lkeys, _ = self._csr()
            start = offsets[j[miss]]
            end = offsets[j[miss] + 1]
            for t in range(int(np.max(end - start))):
                idx = np.minimum(start + t, max(len(lkeys) - 1, 0))
                hit = (start + t < end) & (lkeys[idx] == qs[miss])
                found[miss[hit]] = True
            # (bounded by the longest chain; chains are short by §5.2)
        return found

    # ------------------------------------------------------------------
    # dynamic path (paper §5.3) — host-side mutation, no retraining
    # ------------------------------------------------------------------
    def _prev_occupied(self, i: int) -> int:
        j = i
        while j >= 0 and not self.occupied[j]:
            j -= 1
        return j

    def _next_occupied(self, i: int) -> int:
        m = self.n_slots
        j = i
        while j < m and not self.occupied[j]:
            j += 1
        return j  # == m when none

    def insert(self, key: float, payload: int) -> str:
        """Insert via predicted position.  Returns 'slot'|'chain' (path taken)."""
        self._invalidate()
        m = self.n_slots
        p = int(np.clip(np.rint(self.mech.predict(np.array([key]))[0]), 0, m - 1))
        return self._insert_at(key, payload, p)

    def _insert_at(self, key: float, payload: int, p: int) -> str:
        """insert() body with the predicted slot already computed.

        caller-invalidates: both call sites (``insert``,
        ``insert_batch``) bump the epoch via ``_invalidate()`` before
        dispatching here.

        Chain writes land in the CSRLinks pending overlay (O(chain)),
        merged into the flat tables lazily — scalar insert loops and
        insert_batch's contested replay never pay a per-insert O(m)
        offsets shift.
        """
        links = self.links
        m = self.n_slots
        if not self.occupied[p]:
            prev = self._prev_occupied(p)
            nxt = self._next_occupied(p)
            # order check must include the previous slot's chain maximum
            # (total-order invariant: max(A_{i-1}) < G(i), paper §5.3)
            prev_max = -np.inf
            if prev >= 0:
                prev_max = max(float(self.slot_key[prev]),
                               links.chain_max_key(prev))
            prev_ok = prev < 0 or prev_max < key
            next_ok = nxt >= m or self.slot_key[nxt] > key
            if prev_ok and next_ok:
                self.occupied[p] = True
                self.payload[p] = payload
                # carried keys: slots (prev, p] now see `key` as next occupied
                self.slot_key[prev + 1 : p + 1] = key
                self.n_keys += 1
                return "slot"
        # chain onto the upper-bound slot (or become the new global min)
        ub = self._upper_bound_slot(key)
        if ub < 0:
            nxt = self._next_occupied(0)
            if nxt >= m:  # empty structure: take slot p
                self.occupied[p] = True
                self.payload[p] = payload
                self.slot_key[: p + 1] = key
                self.n_keys += 1
                return "slot"
            # new global minimum: displace the current min into the chain
            old_key = float(self.slot_key[nxt])
            old_payload = int(self.payload[nxt])
            links.insert_one(nxt, old_key, old_payload)
            self.payload[nxt] = payload
            self.slot_key[: nxt + 1] = key
            self.n_keys += 1
            return "chain"
        if self.slot_key[ub] == key:
            raise KeyError(f"duplicate key {key!r}")
        links.insert_one(ub, key, payload)  # raises on duplicates
        self.n_keys += 1
        return "chain"

    def delete(self, key: float) -> bool:
        """Delete a key (paper §5.3).  Returns True if present."""
        self._invalidate()
        ub = self._upper_bound_slot(key)
        if ub < 0:
            return False
        if self.slot_key[ub] == key:
            if self.links.chain_len(ub):  # promote chain min into the slot
                k2, p2 = self.links.pop_front(ub)
                prev = self._prev_occupied(ub - 1)
                self.slot_key[prev + 1 : ub + 1] = k2
                self.payload[ub] = p2
            else:  # unoccupy; carried keys point at next occupied
                self.occupied[ub] = False
                self.payload[ub] = _EMPTY
                nxt = self._next_occupied(ub)
                nk = self.slot_key[nxt] if nxt < self.n_slots else np.inf
                prev = self._prev_occupied(ub)
                self.slot_key[prev + 1 : nxt] = nk
            self.n_keys -= 1
            return True
        if self.links.remove(ub, key):
            self.n_keys -= 1
            return True
        return False

    def update(self, key: float, payload: int) -> bool:
        """Reset the payload of an existing key (paper §5.3)."""
        self._invalidate()
        ub = self._upper_bound_slot(key)
        if ub < 0:
            return False
        if self.slot_key[ub] == key:
            self.payload[ub] = payload
            return True
        return self.links.set_payload(ub, key, payload)

    def update_batch(self, keys: np.ndarray, payloads: np.ndarray) -> int:
        """Batched payload update: slot hits land in ONE vectorized
        scatter (duplicate keys: last write wins, as sequentially);
        chain hits fall back to per-key ``set_payload``.  One epoch
        bump for the whole batch.  Returns the number of keys updated.
        """
        keys = np.asarray(keys, np.float64)
        payloads = np.asarray(payloads, np.int64)
        if keys.shape[0] == 0:
            return 0
        self._invalidate()
        ub = np.searchsorted(self.slot_key, keys,
                             side="right").astype(np.int64) - 1
        ok = ub >= 0
        hit = ok & (self.slot_key[np.maximum(ub, 0)] == keys)
        self.payload[ub[hit]] = payloads[hit]
        n = int(np.count_nonzero(hit))
        for i in np.flatnonzero(ok & ~hit):
            n += bool(self.links.set_payload(int(ub[i]), float(keys[i]),
                                             int(payloads[i])))
        return n

    # ------------------------------------------------------------------
    # batched dynamic path — state-identical to sequential insert()
    # ------------------------------------------------------------------
    def _repair_carried(self):
        """One-shot carried-key repair: every unoccupied slot gets the key
        of the first occupied slot to its right (+inf past the last).
        Occupied keys are ascending, so the suffix minimum IS the nearest
        occupied key to the right — one O(m) reverse cummin.

        caller-invalidates: only reached from ``insert_batch``, after
        its leading ``_invalidate()``."""
        x = np.where(self.occupied, self.slot_key, np.inf)
        self.slot_key = np.minimum.accumulate(x[::-1])[::-1]

    def batch_chunk(self) -> int:
        """``insert_batch``'s chunking threshold at the current
        occupancy.  Precomputed placements only serve batches up to ONE
        chunk (later chunks repartition against mutated state), so the
        device ingest-place path gates on this too."""
        return max(4096, min(16384,
                             int(np.count_nonzero(self.occupied)) // 8))

    def placement_primitives(self, keys: np.ndarray,
                             p: Optional[np.ndarray] = None) -> dict:
        """Per-key placement primitives against the CURRENT state — the
        inputs of ``insert_batch``'s order-equivalence partition:

        * ``p``       — predicted slot, ``clip(rint(M(x)), 0, m-1)``;
        * ``free``    — predicted slot unoccupied;
        * ``ub``      — rightmost occupied slot whose key is <= the
          batch key (-1 below all occupied keys).  Runs are named by
          their left-boundary slot index, so this is the key-run id AND
          the §5.3 chain target in one;
        * ``pv``      — the predicted slot's run id: the previous
          occupied slot (-1 for the leading run), recovered from the
          carried-key construction with one searchsorted (a free slot's
          carried key marks exactly where its run starts);
        * ``bracket`` — free AND strictly inside the run's key interval
          (left-boundary key incl. its chain max < key < carried next
          key): the key could take its predicted slot.

        The device ingest-placement backend (``repro.kernels.ops_gap``)
        computes the same dict against the frozen device arrays; this
        host path is the oracle the device variants must match
        bit-for-bit (asserted in tests/test_ingest_place.py).
        """
        keys = np.asarray(keys, np.float64)
        m = self.n_slots
        if p is None:
            p = np.clip(np.rint(self.mech.predict(keys)), 0, m - 1).astype(
                np.int64)
        free = ~self.occupied[p]
        ub = np.searchsorted(self.slot_key, keys,
                             side="right").astype(np.int64) - 1
        # carried key of a free slot == its run's next occupied key; the
        # run's slots (pv, next_occ] all carry it, so 'left' lands at
        # pv + 1 (for occupied p this degenerates to its own prev slot,
        # harmless: pv is only consumed for free keys)
        nx_key = self.slot_key[p]
        pv = np.searchsorted(self.slot_key, nx_key,
                             side="left").astype(np.int64) - 1
        prev_max = np.where(pv >= 0, self.slot_key[np.maximum(pv, 0)],
                            -np.inf)
        if self.links:
            # CSR chains: the per-slot max is chain_keys[offsets[i+1]-1]
            # — one vectorized gather instead of a per-key python scan
            sel = np.flatnonzero(free & (pv >= 0))
            if sel.size:
                cm = self.links.chain_max_keys(pv[sel])
                np.maximum.at(prev_max, sel, cm)
        bracket = free & (prev_max < keys) & (keys < nx_key)
        return {"p": p, "free": free, "pv": pv, "ub": ub,
                "bracket": bracket}

    def verify_placements(self, keys: np.ndarray, prims: dict) -> np.ndarray:
        """Host-side f64 certification of device-computed placement
        primitives, for wide key sets the per-key pair-exactness gate
        refuses but whose pair mapping is ALIAS-FREE over the stored
        set: returns the mask of rows whose ``p``/``ub``/``pv`` could
        not be certified (the caller recomputes those per-key), and
        overwrites ``free``/``bracket`` in place with exact host
        recomputations (cheap gathers once ``p`` is certified — cheaper
        than certifying the device's pair-rounded interval tests).

        The checks are sound, not heuristic: ``p`` is compared against
        the exact host prediction, and ``ub``/``pv`` are accepted only
        when the f64 slot keys bracket them exactly the way their
        defining ``searchsorted`` would — a bracketing check uniquely
        identifies the searchsorted answer, duplicate (carried) slot
        key values included.
        """
        keys = np.asarray(keys, np.float64)
        m = self.n_slots
        sk = self.slot_key
        p = np.asarray(prims["p"], np.int64)
        ub = np.asarray(prims["ub"], np.int64)
        pv = np.asarray(prims["pv"], np.int64)
        p_true = np.clip(np.rint(self.mech.predict(keys)), 0,
                         m - 1).astype(np.int64)
        bad = p != p_true
        # ub: rightmost slot with key <= k  <=>  sk[ub] <= k < sk[ub+1]
        # (sentinels: ub == -1 iff k < sk[0]; +inf above the top slot)
        lo_ok = np.where(ub >= 0, sk[np.clip(ub, 0, m - 1)] <= keys,
                         keys < sk[0])
        hi = np.where(ub + 1 < m, sk[np.clip(ub + 1, 0, m - 1)], np.inf)
        bad |= ~((ub >= -1) & (ub < m) & lo_ok & (keys < hi))
        # pv: searchsorted(sk, nx, 'left') - 1  <=>  sk[pv] < nx <= sk[pv+1]
        nx = sk[p_true]
        pl_ok = np.where(pv >= 0, sk[np.clip(pv, 0, m - 1)] < nx, True)
        ph = np.where(pv + 1 < m, sk[np.clip(pv + 1, 0, m - 1)], np.inf)
        bad |= ~((pv >= -1) & (pv < m) & pl_ok & (nx <= ph))
        # free/bracket: exact recomputation (same as placement_primitives)
        free = ~self.occupied[p_true]
        prev_max = np.where(pv >= 0, sk[np.clip(pv, 0, m - 1)], -np.inf)
        if self.links:
            sel = np.flatnonzero(free & (pv >= 0) & ~bad)
            if sel.size:
                cm = self.links.chain_max_keys(pv[sel])
                np.maximum.at(prev_max, sel, cm)
        prims["free"] = free
        prims["bracket"] = free & (prev_max < keys) & (keys < nx)
        return bad

    def insert_batch(self, keys: np.ndarray, payloads: np.ndarray,
                     placements: Optional[dict] = None) -> dict:
        """Batched §5.3 inserts; final state is bit-identical to calling
        ``insert()`` per key in order (slot_key/occupied/payload/links).

        Three classes, partitioned by an order-equivalence argument on
        pre-batch *gap runs* (the free-slot run between two occupied
        slots — every check and write of ``insert()`` touches only the
        runs of a key's predicted slot and of its key value):

        A. **slot-easy** — predicted slot free and unique, key bracketed
           by the run's pre-batch boundary keys, and no hard key can
           flap its order checks (see the per-key demotion rules below):
           every arrival order occupies the same slots, so they are
           applied vectorized, with ONE carried-key repair at the end
           (replacing the per-insert slice writes and ``while`` scans).
           A *collision group* (several keys predicting the same free
           slot) joins this class through its first arrival — the
           winner, which takes the slot under every interleaving; the
           later arrivals always find the slot occupied and become
           order-commuting chain appends (onto the winner's slot above
           the winner's key, onto the run's left boundary below it),
           provided the group has the run to itself and every member is
           bracketed by the run's boundary keys.
        B. **chain-certain** — predicted slot occupied pre-batch (it can
           only stay occupied), so the chain target is the key-run's
           left boundary, and chains are sorted sets, so appends
           commute; applied as ONE vectorized CSR merge.
        C. **contested** — everything else: re-run through the same
           partition against the updated state (the argument applies
           recursively), with a scalar arrival-order replay for small
           or non-shrinking remainders.

        Per-key demotion (closure to a fixed point): a class-A candidate
        ``a`` (run R, slot p_a, key k_a) is demoted exactly when a hard
        key can observe or perturb its checks under SOME interleaving —

        * **D1 chain capture**: a hard key h chaining into R by key
          order (class B, or any contested key with key-run R) with
          k_h > k_a would chain onto a's slot once a occupies, but onto
          the run boundary before — demote a when k_a < max hard key of
          R.  (Candidates above every hard key are safe: a chain append
          below k_a can never break a's order checks.)
        * **D2 occupier shadow**: a hard FREE key h that could occupy in
          R (bracketed) at a slot p_h >= p_a with k_h < k_a makes a's
          slot checks order-dependent — demote a when the slot-suffix
          min of such keys undercuts k_a.  (The mirrored corner,
          p_h <= p_a with k_h > k_a, is already D1.)
        * **D3 leading-run displacement**: any hard key that can reach
          the global-min displacement path (key below all occupied
          keys) rewrites the leading run's boundary slot in BOTH key
          directions — demote every candidate of run -1.
        * **D4 candidate co-monotonicity**: two candidates sharing a run
          whose slot order disagrees with their key order flap each
          other — demote both (recomputed over the LIVE candidate set
          each round, so pairs separated by demoted keys still meet).

        Class-B keys are refined per-key too: a predicted-occupied key
        k_b stays class B unless a hard free occupier with key < k_b
        shares its run (only an occupation below k_b can move its chain
        target; chain-only contested keys and appends commute).  The
        demotion closure iterates until no rule fires, so classes A/B
        and the alive collision groups provably cannot observe the
        contested replay's intermediate states.  Duplicate keys raise
        ``KeyError`` just like ``insert()`` (state of the current batch
        is unspecified on raise, as with a partial sequential loop).

        ``placements`` optionally injects precomputed
        ``placement_primitives`` (the device ingest-placement path);
        they must describe the CURRENT pre-batch state, so they are
        consumed by the first chunk only and never by recursive rounds.

        Returns ``{"slot": n, "chain": n, "contested": n}`` with the
        invariant ``slot + chain == len(keys)`` (every key lands on
        exactly one §5.3 path) and ``contested`` counting the keys that
        visited the scalar arrival-order replay, across ALL recursive
        rounds (the epoch-versioned ``Index`` handle uses its fraction
        as a refreeze signal).
        """
        keys = np.asarray(keys, np.float64)
        payloads = np.asarray(payloads, np.int64)
        n_b = keys.shape[0]
        if n_b == 0:
            return {"slot": 0, "chain": 0, "contested": 0}
        if n_b == 1:
            path = self.insert(float(keys[0]), int(payloads[0]))
            return {"slot": int(path == "slot"),
                    "chain": int(path == "chain"), "contested": 0}
        # chunk large batches: cross-key run contention grows
        # ~quadratically with batch size while the per-chunk vectorized
        # cost is only ~O(m); sequential equality composes over chunks
        chunk = self.batch_chunk()
        if n_b > chunk:
            counts = {"slot": 0, "chain": 0, "contested": 0}
            for s in range(0, n_b, chunk):
                sub_pl = None
                if placements is not None and s == 0:
                    sub_pl = {k: v[:chunk] for k, v in placements.items()}
                c = self.insert_batch(keys[s:s + chunk],
                                      payloads[s:s + chunk],
                                      placements=sub_pl)
                counts["slot"] += c["slot"]
                counts["chain"] += c["chain"]
                counts["contested"] += c["contested"]
            return counts
        self._invalidate()
        if not np.any(self.occupied):  # degenerate: empty structure
            m = self.n_slots
            p0 = np.clip(np.rint(self.mech.predict(keys)), 0,
                         m - 1).astype(np.int64)
            counts = {"slot": 0, "chain": 0, "contested": 0}
            for i in range(n_b):
                counts[self._insert_at(float(keys[i]), int(payloads[i]),
                                       int(p0[i]))] += 1
            return counts
        pr = (placements if placements is not None
              else self.placement_primitives(keys))
        p = np.asarray(pr["p"], np.int64)
        free = np.asarray(pr["free"], bool)
        pv = np.asarray(pr["pv"], np.int64)
        ub = np.asarray(pr["ub"], np.int64)
        bracket = np.asarray(pr["bracket"], bool)

        # compressed run ids over the (<= 2B) runs the batch touches;
        # rid_p is only meaningful for free keys (clip keeps the masked
        # gathers in range for occupied ones)
        uniq_runs = np.unique(np.concatenate([pv[free], ub]))
        n_runs = int(uniq_runs.size)
        rid_p = np.minimum(np.searchsorted(uniq_runs, pv), n_runs - 1)
        rid_k = np.searchsorted(uniq_runs, ub)

        # --- collision groups ------------------------------------------
        order = np.argsort(p, kind="stable")  # stable: arrival order
        po = p[order]
        dup_adj = np.r_[False, po[1:] == po[:-1]]
        is_dup = np.zeros(n_b, bool)
        is_dup[order] = dup_adj | np.r_[dup_adj[1:], False]
        # collision groups: free keys sharing a predicted slot; the first
        # arrival (stable sort order) is the slot winner
        is_winner = np.zeros(n_b, bool)
        is_loser = np.zeros(n_b, bool)
        w_of = np.arange(n_b)
        gsel_o = is_dup[order] & free[order]
        if np.any(gsel_o):
            gpos = np.flatnonzero(gsel_o)
            gstart = np.r_[True, po[gpos][1:] != po[gpos][:-1]]
            winners = order[gpos[gstart]]
            is_winner[winners] = True
            w_of[order[gpos]] = np.repeat(winners,
                                          np.diff(np.r_[
                                              np.flatnonzero(gstart),
                                              gpos.size]))
            is_loser[order[gpos]] = ~is_winner[order[gpos]]
        cand = free & (~is_dup | is_winner) & bracket

        # group validity: every member bracketed in the winner's run,
        # no duplicate keys inside the group, no members below the
        # winner in the leftmost run (that is the global-min path), and
        # the run exclusively theirs (no singleton candidates, no other
        # groups) — under those conditions the winner takes the slot and
        # every loser's chain target is fixed under all interleavings
        group_ok = np.ones(n_b, bool)  # indexed by winner
        if np.any(is_winner):
            member = is_winner | is_loser
            bad_w = np.unique(w_of[member & (
                ~bracket | (pv != pv[w_of])
                | ((pv == -1) & (keys < keys[w_of]))
            )])
            group_ok[bad_w] = False
            mo = np.lexsort((keys, p))
            msel = member[mo]
            mp, mk = p[mo][msel], keys[mo][msel]
            kdup = np.r_[False, (mp[1:] == mp[:-1]) & (mk[1:] == mk[:-1])]
            group_ok[w_of[mo[msel][kdup]]] = False
            groups_per_run = np.bincount(rid_p[is_winner],
                                         minlength=n_runs)
            singles_per_run = np.bincount(
                rid_p[cand & ~is_winner], minlength=n_runs)
            crowded = (groups_per_run[rid_p] > 1) | \
                (singles_per_run[rid_p] > 0)
            group_ok &= ~(is_winner & crowded)
            cand &= ~(is_winner & ~group_ok)

        # duplicate of an occupied slot's own key -> KeyError, as
        # insert() (sequentially EVERY such key raises at its arrival:
        # occupied-slot keys only leave by deletion; state of the
        # partial batch is unspecified on raise).  Checked for all keys
        # because the vectorized chain merge only dedups against CHAIN
        # keys, not the first-level array.
        b_dup = (ub >= 0) & (self.slot_key[np.maximum(ub, 0)] == keys)
        if np.any(b_dup):
            raise KeyError(f"duplicate key {keys[np.flatnonzero(b_dup)[0]]!r}")

        # --- per-key demotion closure (rules D1-D4, see docstring) -----
        while True:
            loser_alive = is_loser & group_ok[w_of] & cand[w_of]
            hard = ~cand & ~loser_alive  # class B/C-bound keys
            # D1: max hard key chaining into each run (by key-run)
            max_h = _group_extreme(rid_k[hard], keys[hard], n_runs,
                                   -np.inf, np.maximum)
            demote = cand & (keys < max_h[rid_p])
            # D3: a hard key below all occupied keys can displace the
            # leading run's boundary slot
            if np.any(hard & (ub == -1)):
                demote |= cand & (pv == -1)
            # D2: hard occupier at a slot >= the candidate's with a
            # smaller key (slot-suffix min per run, slot-sorted)
            occh = hard & free & bracket
            if np.any(occh):
                usel = cand | occh
                ui = order[usel[order]]
                hk = np.where(occh[ui], keys[ui], np.inf)
                sm = _seg_suffix_min(hk, rid_p[ui])
                d2u = cand[ui] & (sm < keys[ui])
                demote[ui[d2u]] = True
            # D4: candidate pairs in one run whose slot order disagrees
            # with their key order (recomputed on the live set — pairs
            # separated by demoted keys become adjacent)
            ai = order[cand[order]]
            if ai.size > 1:
                same = rid_p[ai][1:] == rid_p[ai][:-1]
                badp = same & (keys[ai][1:] <= keys[ai][:-1])
                demote[ai[1:][badp]] = True
                demote[ai[:-1][badp]] = True
            if not np.any(demote):
                break
            cand &= ~demote

        # --- class B / C partition (per-key, see docstring) ------------
        # Chain-certain covers BOTH hard shapes that provably always
        # chain at their pre-batch upper bound: predicted-slot-occupied
        # keys (classic class B) AND free-but-bracket-failing keys —
        # their order checks can only tighten as inserts land (new
        # occupants carry keys above the failing boundary, displacement
        # keeps the boundary max), so they can never occupy.  The only
        # hazard left for either shape is a hard occupier BELOW them in
        # their key-run (an occupation that could capture the chain
        # target mid-replay) — the min_o guard.
        loser_alive = is_loser & group_ok[w_of] & cand[w_of]
        hard = ~cand & ~loser_alive
        occh = hard & free & bracket
        min_o = _group_extreme(rid_p[occh], keys[occh], n_runs, np.inf,
                               np.minimum)
        b_mask = hard & ~(free & bracket) & (ub >= 0) & \
            ~(min_o[rid_k] < keys)
        c_mask = hard & ~b_mask

        # --- apply A: vectorized occupation + one carried repair -------
        ai = np.flatnonzero(cand)
        n_slot = int(ai.size)
        if n_slot:
            pe = p[ai]
            self.occupied[pe] = True
            self.payload[pe] = payloads[ai]
            self.slot_key[pe] = keys[ai]
            self._repair_carried()

        # --- apply B (+ alive-group losers): grouped chain appends -----
        n_chain = 0
        bi = np.flatnonzero(b_mask)
        li = np.flatnonzero(loser_alive)
        targets = ub[bi]
        if li.size:  # losers chain on the winner's slot or the boundary
            l_t = np.where(keys[li] > keys[w_of[li]], p[li], pv[li])
            bi = np.concatenate([bi, li])
            targets = np.concatenate([targets, l_t])
        if bi.size:
            # ONE vectorized CSR merge for every chain append in the
            # batch (raises KeyError on duplicates, like insert())
            self.links.append_batch(targets, keys[bi], payloads[bi])
            n_chain += int(bi.size)
        self.n_keys += n_slot + n_chain

        # --- apply C -----------------------------------------------------
        # Re-partition the contested keys against the updated state: the
        # equivalence argument applies recursively, and contention shrinks
        # geometrically per round.  Sequential replay only when a round
        # makes no progress (pathological all-contested batches).
        # Count invariant: slot + chain == n_b over all rounds;
        # "contested" counts exactly the replay-visited keys.
        ci = np.flatnonzero(c_mask)
        counts = {"slot": n_slot, "chain": n_chain, "contested": 0}
        if ci.size == n_b or ci.size <= 1024:
            # no progress (pathological all-contested batch) or a small
            # tail: scalar replay in arrival order beats another O(m)
            # round; chain appends buffer in the CSRLinks pending
            # overlay and merge as one flush
            counts["contested"] = int(ci.size)
            ins_at = self._insert_at
            for k, pl, pp in zip(keys[ci].tolist(), payloads[ci].tolist(),
                                 p[ci].tolist()):
                counts[ins_at(k, pl, pp)] += 1
        elif ci.size:
            sub = self.insert_batch(keys[ci], payloads[ci])
            counts["slot"] += sub["slot"]
            counts["chain"] += sub["chain"]
            counts["contested"] += sub["contested"]
        # merge the replay tail's buffered chain appends now: the flush
        # belongs to this batch, not to the next reader (e.g. the epoch
        # handle's timed device sync)
        self.links.flush()
        return counts

    def delete_batch(self, keys: np.ndarray) -> int:
        """Batched §5.3 deletes — a host-side sweep over ``delete()``
        (deletes are the rare arm of dynamic workloads; each chain
        removal is one CSR memmove).  Returns the number of keys
        actually removed.

        Like ``insert_batch``, the CSRLinks pending overlay is flushed
        before returning: deletes of unoccupied-path keys never touch
        the flush-first link mutators, so without this a batch running
        after buffered scalar inserts would leave the merge bill to the
        next reader (e.g. the epoch handle's timed device sync)."""
        removed = 0
        for k in np.asarray(keys, np.float64):
            removed += bool(self.delete(float(k)))
        self.links.flush()
        return removed

    # ------------------------------------------------------------------
    def live_items(self) -> Tuple[np.ndarray, np.ndarray]:
        """The LIVE (key, payload) set, key-sorted: occupied slot keys
        merged with every CSR chain key.  This is the authoritative
        key set after any sequence of dynamic ops — retrain, shard
        splits, and the live ``Index.mdl`` report all rebuild from it
        (total-order invariant: keys are unique across slots+chains)."""
        occ = np.asarray(self.occupied, bool)
        k = np.asarray(self.slot_key, np.float64)[occ]
        p = np.asarray(self.payload, np.int64)[occ]
        _off, lk, lp = self.links.csr()
        if lk.size:
            k = np.concatenate([k, np.asarray(lk, np.float64)])
            p = np.concatenate([p, np.asarray(lp, np.int64)])
            order = np.argsort(k, kind="stable")
            k, p = k[order], p[order]
        return k, p

    # ------------------------------------------------------------------
    # frozen export for the jnp/Pallas query path
    # ------------------------------------------------------------------
    def export_csr_links(self, max_chain: Optional[int] = None):
        """CSR link tables: (offsets (m+1,), keys (L,), payloads (L,)).

        Free — the chains are stored natively as CSR arrays; the return
        values are views of the canonical storage (copy before mutating
        this structure).  ``max_chain`` bounds per-slot chains for the
        fixed-trip-count kernel; overflow raises (asserted rare — paper
        §5.2 observes chains are short).
        """
        if max_chain is not None and self.links.max_chain > max_chain:
            lens = np.diff(self.links.offsets)
            i = int(np.argmax(lens))
            raise ValueError(
                f"chain at slot {i} has {int(lens[i])} > max_chain={max_chain}"
            )
        return self.links.csr()


class GapSnapshot:
    """Immutable pinned view of a ``GappedArray`` at one version.

    Created by ``GappedArray.pin_snapshot()``; holds the slot/payload/CSR
    arrays by identity (zero-copy) and relies on the live side's
    copy-on-write to never see a post-pin mutation.  Serves lookups
    through the proven ``GappedArray.lookup_batch`` host path over a
    read-only view, so results are bit-identical to a quiesced lookup at
    ``epoch`` by construction.  ``release()`` drops the pin (refcounted
    — releasing twice is a no-op)."""

    __slots__ = ("epoch", "n_keys", "_view", "_cell")

    def __init__(self, live: "GappedArray", offsets, lkeys, lpays, cell):
        self.epoch = int(live.version)
        self.n_keys = int(live.n_keys)
        links = CSRLinks(live.n_slots, offsets, lkeys, lpays)
        self._view = GappedArray(
            slot_key=live.slot_key, occupied=live.occupied,
            payload=live.payload, links=links, mech=live.mech,
            n_keys=live.n_keys, rho=live.rho, version=live.version)
        self._cell = cell

    @property
    def pinned(self) -> bool:
        return self._cell is not None

    @property
    def n_slots(self) -> int:
        return self._view.n_slots

    def lookup_batch(self, qs: np.ndarray, full: bool = False):
        return self._view.lookup_batch(qs, full=full)

    def release(self) -> None:
        cell, self._cell = self._cell, None
        if cell is not None:
            cell.count -= 1


def _place_keys(
    x: np.ndarray,
    payloads: np.ndarray,
    pred_slot: np.ndarray,
    m: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, CSRLinks]:
    """Linking-array placement (§5.2): slot = prediction; conflicts chain.

    Keys arrive sorted; the cursor (last occupied slot) is the running
    max of predicted slots, so the whole placement vectorizes: a key
    occupies iff its prediction strictly exceeds every earlier
    prediction; otherwise it chains onto the cursor.  Chain targets are
    non-decreasing and keys arrive key-sorted, so the chained triples
    are already in CSR order — built with one bincount + cumsum.
    """
    slot_key = np.full(m, np.inf, np.float64)
    occupied = np.zeros(m, bool)
    payload = np.full(m, _EMPTY, np.int64)
    pred_slot = np.asarray(pred_slot, np.int64)
    n = x.shape[0]
    links = CSRLinks(m)
    if n:
        cm = np.maximum.accumulate(pred_slot)
        occ = np.r_[True, pred_slot[1:] > cm[:-1]]
        po = pred_slot[occ]
        slot_key[po] = x[occ]
        occupied[po] = True
        payload[po] = payloads[occ]
        chained = ~occ
        if np.any(chained):
            targets = cm[chained]  # cursor at each chained arrival
            counts = np.bincount(targets, minlength=m)
            links = CSRLinks(m, np.concatenate([[0], np.cumsum(counts)]),
                             np.asarray(x[chained], np.float64),
                             np.asarray(payloads[chained], np.int64))
    # carried keys for unoccupied slots: next occupied key to the right
    # (occupied keys ascend, so one reverse cummin repairs everything)
    carried = np.minimum.accumulate(
        np.where(occupied, slot_key, np.inf)[::-1])[::-1]
    return carried, occupied, payload, links


def build_gapped(
    mechanism_factory,
    x: np.ndarray,
    payloads: Optional[np.ndarray] = None,
    rho: float = 0.1,
    sample_rate: float = 1.0,
    rng: Optional[np.random.Generator] = None,
    refinalize: bool = True,
    refit_factory=None,
) -> GappedArray:
    """Full §5 pipeline: base fit (+sampling §5.4) -> Eq.3 -> re-learn -> place.

    ``refit_factory`` builds the step-3 mechanism re-learned on the
    gap-inserted data; default is the base factory.  Because D_g is
    near-linear per segment, a *tighter* eps here costs few segments but
    sharply reduces placement collisions (shorter linking arrays) — see
    LearnedIndex.build's adaptive default.

    With ``sample_rate < 1.0`` the ENTIRE learning pipeline runs on the
    sampled (key, full-data position) pairs — base fit, Eq.3 targets,
    and the step-3 refit are all O(n_s); only physical placement and the
    refinalize backstop stay O(n).  Exactness is preserved anyway: the
    step-3 mechanism gets ``connect_segments`` (unsampled keys
    interpolate, never extrapolate) and the final ``_finalize_errors``
    recomputes exact per-segment bounds against the PHYSICAL slots of
    the full key set, so the bounded-window kernel contract is identical
    to a full-data build.  ``build_timings`` on the returned array
    records the learn/place split.
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    y = np.arange(n, dtype=np.float64)
    if payloads is None:
        payloads = np.arange(n, dtype=np.int64)

    t0 = time.perf_counter()
    if sample_rate < 1.0:
        # ONE sample drives the whole learning pipeline (base fit, Eq.3
        # targets, step-3 refit): ys are FULL-data positions, endpoints
        # forced, so the gapped domain [0, yg_s[-1]] covers every key
        xs, ys = _sampling.sample_pairs(x, y, rate=sample_rate, rng=rng)
    else:
        xs, ys = x, y

    # 1) base mechanism on the (possibly sampled) pairs
    base = mechanism_factory()
    base.fit(xs, ys)
    base_plm = getattr(base, "plm", None)
    if base_plm is None:
        raise ValueError("gap insertion needs a PLM-exporting mechanism")
    if sample_rate < 1.0 and base.name in ("pgm", "fiting"):
        _sampling.connect_segments(base_plm)

    # 2) result-driven target positions (Eq. 3) — O(n_s) under sampling
    yg = gap_positions(xs, ys, base_plm, rho)

    # 3) re-learn on the gap-inserted data — O(n_s) under sampling
    mech = (refit_factory or mechanism_factory)()
    mech.fit(xs, yg)
    if sample_rate < 1.0 and mech.name in ("pgm", "fiting") \
            and getattr(mech, "plm", None) is not None:
        _sampling.connect_segments(mech.plm)
    learn_seconds = time.perf_counter() - t0

    # 4) physical placement at re-learned predictions — O(n) always
    t1 = time.perf_counter()
    m = int(np.ceil(yg[-1])) + 2
    pred = np.clip(np.rint(mech.predict(x)), 0, m - 1).astype(np.int64)
    slot_key, occupied, payload, links = _place_keys(x, payloads, pred, m)

    ga = GappedArray(
        slot_key=slot_key,
        occupied=occupied,
        payload=payload,
        links=links,
        mech=mech,
        n_keys=n,
        rho=rho,
    )
    # error bounds against *physical* slots so bounded search is exact
    if refinalize and getattr(mech, "plm", None) is not None:
        slot_of_key = np.searchsorted(ga.slot_key, x, side="right") - 1
        _finalize_errors(mech.plm, x, slot_of_key.astype(np.float64))
    ga.build_timings = {
        "learn_seconds": learn_seconds,
        "place_seconds": time.perf_counter() - t1,
        "n_fit": int(xs.shape[0]),
    }
    return ga
