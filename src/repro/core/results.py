"""Typed results for the unified ``repro.core.Index`` handle.

Every read returns a ``LookupResult`` and every write returns an
``IngestReport`` — one contract across host and device backends, static
and gapped builds (before this, static builds returned position arrays,
gapped builds payload arrays, and dynamic ops ad-hoc dicts/strings).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["LookupResult", "IngestReport", "Overloaded"]


@dataclasses.dataclass(frozen=True)
class LookupResult:
    """Result of ``Index.lookup`` (one batch).

    * ``payloads`` — (n,) int64; -1 for absent keys.  For static (no-gap)
      builds the payload of key i is its position i, so this doubles as
      the classic position array.
    * ``slots``    — (n,) int64 physical slot of each query's upper bound
      in the first-level array (-1 below all keys).
    * ``found``    — (n,) bool: key present (first-level slot hit OR
      linking-chain hit).  Distinguishes "absent" from "stored payload
      happens to be -1".
    * ``backend``  — the search stage that actually ran: ``pallas`` /
      ``xla-windowed`` / ``numpy-oracle``, or ``device-oracle`` when the
      engine's size-aware scheduler ran the full-array device search for
      a small default-resolved batch (explicit backend requests are
      forced and never relabeled).
    * ``epoch``    — index epoch the answer was computed against.
    * ``fallbacks`` — device-path queries re-resolved through the
      compacted fallback buffer (0 on the host backend).
    * ``oracle_escapes`` — whole-batch oracle escapes taken (compaction
      buffer overflow; rare by construction).
    """

    payloads: np.ndarray
    slots: np.ndarray
    found: np.ndarray
    backend: str
    epoch: int
    fallbacks: int = 0
    oracle_escapes: int = 0

    def __len__(self) -> int:
        return int(self.payloads.shape[0])

    def __array__(self, dtype=None):
        # legacy interop: np.asarray(result) is the old payload array
        a = self.payloads
        return a if dtype is None else a.astype(dtype)


@dataclasses.dataclass(frozen=True)
class IngestReport:
    """Result of ``Index.ingest`` (one batch of (key, payload) pairs).

    * ``n`` — batch size; ``slot`` / ``chain`` — §5.3 placement path
      counts (gap slot vs linking chain).  Invariant (asserted):
      ``slot + chain == n`` — every ingested key lands on exactly one
      path.
    * ``contested`` — how many keys visited the scalar arrival-order
      replay, summed over ALL recursive partition rounds (the contested
      remainder driving the refreeze policy); always ``<= n``.
    * ``placement`` — where the placement primitives were computed:
      ``"host"`` (numpy partition), ``"device"`` (the ingest-place
      kernel/fused-XLA backend against the frozen device arrays, exact
      by the per-key pair-exactness gate), or ``"device-verified"``
      (device primitives against a merely alias-free wide key set,
      validated row-by-row on the host in f64 with failing rows
      recomputed per-key — the widened-gate mode).
    * ``epoch`` — host epoch after the ingest.
    * ``device`` — how the frozen device state was brought forward:
      ``"none"`` (no device state materialized yet — it will freeze
      lazily on the next device lookup), ``"fused"`` (the single-
      dispatch ingest wrote the device buffers in-graph — placement,
      slot scatter, CSR merge, and rank/bound refresh in ONE dispatch;
      nothing was re-uploaded), ``"delta"`` (in-place scatter of
      changed slot/payload entries + CSR link tail appends), or
      ``"refreeze"`` (full rebuild: a threshold crossed or a capacity /
      dtype static changed).
    * ``device_elems`` — elements scattered on the delta path.
    * ``seconds`` — wall time of the whole ingest (host + device sync).
    * ``abort_reasons`` — names of the in-graph abort bits the fused
      single-dispatch write tripped on for THIS batch (empty when no
      fused dispatch ran or it committed): ``contested`` / ``d1_demote``
      / ``chain_overflow`` / ... (``kernels.ops_gap.FUSED_ABORT_BITS``).
      An aborted batch still lands (host partition path), so a non-empty
      tuple plus ``device != "fused"`` reads as "fused tried, vetoed".
    * ``fused_aborts`` — the ENGINE's cumulative fused-abort counter
      after this ingest (``Index.stats["fused_abort_total"]``), so a
      benchmark row answers "how often does the write graph veto" from
      the report stream alone.
    * ``split_commits`` — cumulative split-commit counter
      (``Index.stats["split_commits"]``): fused dispatches that aborted
      but salvaged the closure-trivial prefix in-graph, replaying only
      the contested remainder on the host path (``placement ==
      "device-split"`` when THIS batch took that arm).
    """

    n: int
    slot: int
    chain: int
    contested: int
    epoch: int
    device: str = "none"
    device_elems: int = 0
    seconds: float = 0.0
    placement: str = "host"
    abort_reasons: tuple = ()
    fused_aborts: int = 0
    split_commits: int = 0

    def __post_init__(self):
        if self.slot + self.chain != self.n:
            raise AssertionError(
                f"IngestReport count invariant violated: slot={self.slot} "
                f"+ chain={self.chain} != n={self.n}")
        if not 0 <= self.contested <= self.n:
            raise AssertionError(
                f"IngestReport contested={self.contested} outside "
                f"[0, n={self.n}]")

    @property
    def contested_fraction(self) -> float:
        return self.contested / max(self.n, 1)


@dataclasses.dataclass(frozen=True)
class Overloaded:
    """Typed backpressure shed from the serving queue.

    Returned (never raised) by ``MicroBatchQueue.result`` for a ticket
    the queue refused at admission because the pending depth was at
    ``max_depth`` — the explicit alternative to a silent hang or an
    unbounded queue.  Falsy (``bool(Overloaded(...)) is False``) so
    callers can branch ``if not res: retry_later()`` uniformly against
    ``LookupResult``/``IngestReport``.

    * ``kind``   — ``"lookup"`` or ``"ingest"`` (which submission shed).
    * ``depth``  — pending submissions at shed time.
    * ``max_depth`` — the configured bound the submission hit.
    * ``epoch``  — index epoch at shed time (for client-side retry
      bookkeeping; -1 if the backend exposes none).
    """

    kind: str
    depth: int
    max_depth: int
    epoch: int = -1

    def __bool__(self) -> bool:
        return False


def host_lookup_result(payloads: np.ndarray, slots: Optional[np.ndarray],
                       found: Optional[np.ndarray], backend: str,
                       epoch: int) -> LookupResult:
    """Assemble a LookupResult, defaulting slots/found from payloads."""
    payloads = np.asarray(payloads)
    if found is None:
        found = payloads >= 0
    if slots is None:
        slots = np.full(payloads.shape[0], -1, np.int64)
    return LookupResult(payloads=payloads.astype(np.int64),
                        slots=np.asarray(slots, np.int64),
                        found=np.asarray(found, bool),
                        backend=backend, epoch=epoch)
