"""Growable CSR linking arrays — canonical storage for §5.2 chains.

``CSRLinks`` stores every per-slot linking array (the keys that chained
onto an occupied slot instead of taking their predicted slot) in three
flat arrays:

* ``offsets``  — (n_slots + 1,) int64; slot i's chain is
  ``chain_keys[offsets[i]:offsets[i+1]]`` (key-sorted, like the old
  per-slot sorted lists);
* ``chain_keys``     — (L,) float64;
* ``chain_payloads`` — (L,) int64.

This replaces the previous dict-of-lists: batched chain appends become
ONE vectorized merge (``append_batch``) instead of ~1.2 us/append of
interpreter overhead, ``GappedArray.export_csr_links`` is free (the CSR
tables ARE the storage), and the device delta-update path can diff the
tables directly.

Scalar mutators stay O(chain) despite the flat layout: ``insert_one``
lands in a small per-slot PENDING overlay (sorted python lists) that is
merged into the CSR arrays lazily — read surfaces that need the flat
tables (``csr()``, ``offsets``, the dict-style views) flush first, while
the scalar hot-path reads (``chain_len`` / ``chain_max_key`` /
``find_payload`` / ``set_payload``) consult CSR + overlay directly, so
scalar insert/lookup loops never pay an O(L) rebuild per write.
Removals (`pop_front`/`remove`) are flush-first — deletes are the rare
arm of dynamic workloads.
"""

from __future__ import annotations

from bisect import bisect_left
from typing import Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["CSRLinks"]


class CSRLinks:
    """CSR linking arrays over ``n_slots`` slots (see module docstring)."""

    __slots__ = ("_offsets", "_keys", "_pays", "_maxlen", "_pend",
                 "_pend_n", "_shared")

    def __init__(self, n_slots: int,
                 offsets: Optional[np.ndarray] = None,
                 chain_keys: Optional[np.ndarray] = None,
                 chain_payloads: Optional[np.ndarray] = None):
        if offsets is None:
            offsets = np.zeros(n_slots + 1, np.int64)
        self._offsets = np.asarray(offsets, np.int64)
        self._keys = (np.zeros(0, np.float64) if chain_keys is None
                      else np.asarray(chain_keys, np.float64))
        self._pays = (np.zeros(0, np.int64) if chain_payloads is None
                      else np.asarray(chain_payloads, np.int64))
        self._maxlen = (int(np.max(np.diff(self._offsets)))
                        if self._offsets[-1] else 0)
        self._pend = {}
        self._pend_n = 0
        self._shared = False

    # ------------------------------------------------------------------
    # snapshot sharing (copy-on-write backing for GappedArray pins)
    # ------------------------------------------------------------------
    def mark_shared(self) -> None:
        """A pinned snapshot now references the CSR arrays by identity;
        every in-place mutation must ``unshare`` first.  Wholesale
        rebuilds (``_merge``) are COW-safe by construction — they
        replace all three arrays — so only the in-place mutators
        (``_remove_at``, ``set_payload``) and the write-capable
        ``chain_payloads`` view pay the copy, once per pin."""
        self._shared = True

    def unshare(self) -> None:
        if self._shared:
            self._offsets = self._offsets.copy()
            self._keys = self._keys.copy()
            self._pays = self._pays.copy()
            self._shared = False

    # ------------------------------------------------------------------
    # pending overlay
    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Merge the pending per-slot overlay into the CSR arrays now
        (ONE vectorized merge).  Reads that need the flat tables call
        this implicitly; batch writers call it eagerly so the merge is
        accounted to the write, not to a later reader."""
        self._flush()

    def _flush(self) -> None:
        if not self._pend_n:
            return
        pend, self._pend, self._pend_n = self._pend, {}, 0
        slots, keys, pays = [], [], []
        for s, lst in pend.items():
            for k, p in lst:
                slots.append(s)
                keys.append(k)
                pays.append(p)
        self._merge(np.asarray(slots, np.int64),
                    np.asarray(keys, np.float64),
                    np.asarray(pays, np.int64))

    def _csr_len(self, slot: int) -> int:
        return int(self._offsets[slot + 1] - self._offsets[slot])

    def _find_csr(self, slot: int, key: float) -> int:
        s, e = int(self._offsets[slot]), int(self._offsets[slot + 1])
        if e == s:
            return -1
        # bounded bisect straight on the flat array: chains are short
        # (§5.2), so a few python probes beat a numpy slice + dispatch
        j = bisect_left(self._keys, key, s, e)
        if j < e and self._keys[j] == key:
            return j
        return -1

    # ------------------------------------------------------------------
    # shape / stats (overlay-aware, no flush)
    # ------------------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return int(self._offsets.shape[0]) - 1

    @property
    def total(self) -> int:
        """Total number of chained keys (incl. pending)."""
        return int(self._offsets[-1]) + self._pend_n

    @property
    def offsets(self) -> np.ndarray:
        """(n_slots+1,) int64 CSR offsets — flushes pending appends."""
        self._flush()
        return self._offsets

    @property
    def chain_keys(self) -> np.ndarray:
        """(L,) float64 chain keys in CSR order — flushes pending."""
        self._flush()
        return self._keys

    @property
    def chain_payloads(self) -> np.ndarray:
        """(L,) int64 — flushes pending; in-place writes are allowed."""
        self._flush()
        self.unshare()  # callers may write through the returned view
        return self._pays

    @property
    def max_chain(self) -> int:
        """Longest per-slot chain — tracked incrementally (O(1) read)."""
        return self._maxlen

    def chain_len(self, slot: int) -> int:
        b = self._pend.get(slot)
        return self._csr_len(slot) + (len(b) if b else 0)

    def chain_max_key(self, slot: int) -> float:
        """Largest chained key at ``slot`` (-inf when the chain is empty);
        max over the CSR run AND the pending overlay."""
        s, e = self._offsets[slot], self._offsets[slot + 1]
        mx = float(self._keys[e - 1]) if e > s else -np.inf
        b = self._pend.get(slot)
        if b and b[-1][0] > mx:
            mx = float(b[-1][0])
        return mx

    def chain_max_keys(self, slots: np.ndarray) -> np.ndarray:
        """Vectorized ``chain_max_key`` over an int array of slots
        (flushes pending first)."""
        self._flush()
        slots = np.asarray(slots, np.int64)
        s = self._offsets[slots]
        e = self._offsets[slots + 1]
        out = np.full(slots.shape[0], -np.inf, np.float64)
        live = e > s
        out[live] = self._keys[e[live] - 1]
        return out

    # ------------------------------------------------------------------
    # dict-compatible read surface (chains are key-sorted snapshots)
    # ------------------------------------------------------------------
    def _nonempty(self) -> np.ndarray:
        self._flush()
        return np.flatnonzero(np.diff(self._offsets) > 0)

    def keys(self) -> List[int]:
        return [int(i) for i in self._nonempty()]

    def __iter__(self) -> Iterator[int]:
        return iter(self.keys())

    def __len__(self) -> int:
        return int(self._nonempty().shape[0])

    def __bool__(self) -> bool:
        return self.total > 0

    def __contains__(self, slot: int) -> bool:
        return 0 <= slot < self.n_slots and self.chain_len(slot) > 0

    def __getitem__(self, slot: int) -> List[Tuple[float, int]]:
        self._flush()
        s, e = int(self._offsets[slot]), int(self._offsets[slot + 1])
        if e == s:
            raise KeyError(slot)
        return list(zip(self._keys[s:e].tolist(), self._pays[s:e].tolist()))

    def get(self, slot: int, default=None):
        if self.chain_len(slot) == 0:
            return default
        return self[slot]

    def items(self):
        return [(i, self[i]) for i in self.keys()]

    def values(self):
        return [self[i] for i in self.keys()]

    def __eq__(self, other) -> bool:
        if isinstance(other, CSRLinks):
            return (np.array_equal(self.offsets, other.offsets)
                    and np.array_equal(self.chain_keys, other.chain_keys)
                    and np.array_equal(self.chain_payloads,
                                       other.chain_payloads))
        if isinstance(other, dict):
            return dict(self) == other
        return NotImplemented

    def __hash__(self):  # mutable container
        raise TypeError("CSRLinks is unhashable")

    def __repr__(self) -> str:
        return (f"CSRLinks(n_slots={self.n_slots}, total={self.total}, "
                f"max_chain={self.max_chain})")

    # ------------------------------------------------------------------
    # point lookups
    # ------------------------------------------------------------------
    def find(self, slot: int, key: float) -> int:
        """Global CSR index of (slot, key), or -1 (flushes pending)."""
        self._flush()
        return self._find_csr(slot, key)

    def find_payload(self, slot: int, key: float) -> Optional[int]:
        """Payload stored for (slot, key), or None — overlay-aware, no
        flush (the scalar read path)."""
        j = self._find_csr(slot, key)
        if j >= 0:
            return int(self._pays[j])
        b = self._pend.get(slot)
        if b:
            t = bisect_left(b, (key,))
            if t < len(b) and b[t][0] == key:
                return int(b[t][1])
        return None

    # ------------------------------------------------------------------
    # scalar mutators (O(chain): pending overlay, lazily merged)
    # ------------------------------------------------------------------
    def insert_one(self, slot: int, key: float, payload: int) -> None:
        """Sorted-position insert; raises KeyError on a duplicate key."""
        if self._find_csr(slot, key) >= 0:
            raise KeyError(f"duplicate key {key!r}")
        b = self._pend.setdefault(slot, [])
        j = bisect_left(b, (key,))
        if j < len(b) and b[j][0] == key:
            raise KeyError(f"duplicate key {key!r}")
        b.insert(j, (key, payload))
        self._pend_n += 1
        self._maxlen = max(self._maxlen, self._csr_len(slot) + len(b))

    def pop_front(self, slot: int) -> Tuple[float, int]:
        """Remove and return the chain's minimum (key, payload)."""
        self._flush()
        s, e = int(self._offsets[slot]), int(self._offsets[slot + 1])
        if e == s:
            raise KeyError(slot)
        k, p = float(self._keys[s]), int(self._pays[s])
        self._remove_at(slot, s)
        return k, p

    def remove(self, slot: int, key: float) -> bool:
        self._flush()
        j = self._find_csr(slot, key)
        if j < 0:
            return False
        self._remove_at(slot, j)
        return True

    def _remove_at(self, slot: int, j: int) -> None:
        self.unshare()  # in-place offset shift below
        was = self._csr_len(slot)
        self._keys = np.delete(self._keys, j)
        self._pays = np.delete(self._pays, j)
        self._offsets[slot + 1 :] -= 1
        if was == self._maxlen:  # rare: the argmax shrank — recompute
            self._maxlen = (int(np.max(np.diff(self._offsets)))
                            if self._offsets[-1] else 0)

    def set_payload(self, slot: int, key: float, payload: int) -> bool:
        j = self._find_csr(slot, key)
        if j >= 0:
            self.unshare()
            self._pays[j] = payload
            return True
        b = self._pend.get(slot)
        if b:
            t = bisect_left(b, (key,))
            if t < len(b) and b[t][0] == key:
                b[t] = (key, payload)
                return True
        return False

    # ------------------------------------------------------------------
    # the vectorized batch path
    # ------------------------------------------------------------------
    def append_batch(self, slots: np.ndarray, keys: np.ndarray,
                     payloads: np.ndarray) -> None:
        """Merge a batch of (slot, key, payload) chain entries in ONE
        vectorized pass (lexsort + merge), preserving per-slot key order.
        Raises KeyError on any duplicate (slot, key) — within the batch
        or against an existing entry — matching sequential semantics.
        """
        self._flush()
        slots = np.asarray(slots, np.int64)
        if slots.shape[0] == 0:
            return
        self._merge(slots, np.asarray(keys, np.float64),
                    np.asarray(payloads, np.int64))

    def _merge(self, slots: np.ndarray, keys: np.ndarray,
               payloads: np.ndarray) -> None:
        """O(L + B log B) merge: the flat CSR arrays are globally
        key-sorted (per-slot chains are key-sorted and per-slot key
        ranges ascend with the slot — §5.3's total-order invariant), so
        the batch's insert positions come from ONE searchsorted and the
        rebuild is a single gather instead of an O((L+B) log(L+B))
        lexsort over everything already stored."""
        order = np.lexsort((keys, slots))
        bs = slots[order]
        bk = keys[order]
        bp = payloads[order]
        dup = (bs[1:] == bs[:-1]) & (bk[1:] == bk[:-1])
        if np.any(dup):
            raise KeyError(f"duplicate key {bk[1:][dup][0]!r}")
        pos = np.searchsorted(self._keys, bk, side="left")
        L = self._keys.shape[0]
        if L:
            exists = (pos < L) & (self._keys[np.minimum(pos, L - 1)] == bk)
            if np.any(exists):
                raise KeyError(
                    f"duplicate key {bk[np.flatnonzero(exists)[0]]!r}")
        # single-allocation merge (this is also the host oracle for the
        # device CSR-merge scatter in kernels.gap_place): old entry i
        # shifts right by the number of batch positions <= i, batch
        # entry j lands at pos[j] + j (pos is nondecreasing after the
        # lexsort, so the destinations are strictly increasing) — one
        # scatter each instead of np.insert's two full rebuilds
        B = bk.shape[0]
        dst_old = np.arange(L) + np.searchsorted(pos, np.arange(L),
                                                 side="right")
        dst_new = pos + np.arange(B)
        new_keys = np.empty(L + B, self._keys.dtype)
        new_pays = np.empty(L + B, self._pays.dtype)
        new_keys[dst_old] = self._keys
        new_keys[dst_new] = bk
        new_pays[dst_old] = self._pays
        new_pays[dst_new] = bp
        self._keys = new_keys
        self._pays = new_pays
        counts = np.bincount(bs, minlength=self.n_slots)
        old_len = np.diff(self._offsets)
        self._offsets = self._offsets + np.concatenate(
            [[0], np.cumsum(counts)])
        upd = np.flatnonzero(counts)
        if upd.size:
            self._maxlen = max(self._maxlen,
                               int(np.max(old_len[upd] + counts[upd])))
        # all three arrays were rebuilt above: any pinned snapshot keeps
        # the pre-merge arrays, so the new storage is privately owned
        self._shared = False

    # ------------------------------------------------------------------
    # export / copy
    # ------------------------------------------------------------------
    def csr(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(offsets, keys, payloads) — views of the canonical storage
        after flushing pending appends (free when nothing is pending;
        copy before mutating the structure)."""
        self._flush()
        return self._offsets, self._keys, self._pays

    def copy(self) -> "CSRLinks":
        self._flush()
        return CSRLinks(self.n_slots, self._offsets.copy(),
                        self._keys.copy(), self._pays.copy())

    @staticmethod
    def from_dict(n_slots: int, d) -> "CSRLinks":
        """Build from the legacy dict-of-sorted-lists representation."""
        out = CSRLinks(n_slots)
        if d:
            slots = np.concatenate(
                [np.full(len(v), int(i), np.int64) for i, v in d.items()])
            keys = np.concatenate(
                [np.array([k for k, _ in v], np.float64) for v in d.values()])
            pays = np.concatenate(
                [np.array([p for _, p in v], np.int64) for v in d.values()])
            out.append_batch(slots, keys, pays)
        return out
