"""Index mechanisms M(y|x) — the model families the paper plugs into.

All mechanisms share one prediction representation, a sorted piecewise
linear model (PLM):

    seg_first_key[k]  first key covered by segment k   (sorted, (K,))
    slope[k], icept[k] linear map  y_hat = slope*(x - seg_first_key) + icept
    err_lo[k], err_hi[k] per-segment signed error bounds over training keys

Prediction is branchless and batched: route each query to its segment with
``searchsorted`` (binary probe over a small table — VMEM-resident on TPU),
then one fused multiply-add.  This is the TPU adaptation of the paper's
pointer-based variants (stx::btree over segments for FITing-Tree, recursive
levels for PGM): identical semantics, vector-friendly layout.

Mechanisms:
  * :class:`PGMMechanism` — optimal piecewise linear approximation under an
    error bound eps (O'Rourke streaming convex hull, as used by the
    PGM-index).  Guarantees ``|y_hat - y| <= eps`` on trained keys.
    Recursive variant stacks PLMs over the segment keys.
  * :class:`FITingMechanism` — greedy shrinking-cone segmentation
    (FITing-Tree).  Same guarantee, more segments than optimal.
  * :class:`RMIMechanism` — two-layer recursive model index with linear
    models; leaf assignment by the root model, leaves fit with a
    closed-form least squares via ``segment_sum`` (fully parallel in JAX —
    a deliberate better-than-paper TPU adaptation of RMI training).
  * :class:`BTreeMechanism` — the classic baseline expressed in the same
    framework: "prediction" walks fence keys (cost ~ height), "correction"
    scans a page.  Used for the MDL comparison (paper §6.2/Table 1).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "PiecewiseLinearModel",
    "PGMMechanism",
    "FITingMechanism",
    "RMIMechanism",
    "BTreeMechanism",
    "build_mechanism",
    "MECHANISMS",
]


# ---------------------------------------------------------------------------
# Shared piecewise-linear prediction representation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PiecewiseLinearModel:
    """Frozen, array-backed piecewise linear model (host-side numpy).

    The jnp/Pallas query path consumes these arrays directly
    (see ``repro.kernels``).
    """

    seg_first_key: np.ndarray  # (K,) float64, sorted
    slope: np.ndarray          # (K,) float64
    icept: np.ndarray          # (K,) float64 — y_hat at seg_first_key
    err_lo: np.ndarray         # (K,) float64 — min(y - y_hat) per segment
    err_hi: np.ndarray         # (K,) float64 — max(y - y_hat) per segment
    n_keys: int                # number of keys the model was fit on
    levels: int = 1            # recursive levels (PGM recursive variant)
    level_sizes: Tuple[int, ...] = ()

    @property
    def n_segments(self) -> int:
        return int(self.seg_first_key.shape[0])

    def segment_of(self, x: np.ndarray) -> np.ndarray:
        """Index of the segment covering each query key."""
        x = np.asarray(x)
        seg = np.searchsorted(self.seg_first_key, x, side="right") - 1
        return np.clip(seg, 0, self.n_segments - 1)

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Batched position prediction y_hat (float; callers round/clip)."""
        x = np.asarray(x, dtype=np.float64)
        seg = self.segment_of(x)
        return self.slope[seg] * (x - self.seg_first_key[seg]) + self.icept[seg]

    def predict_with_bounds(self, x) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(y_hat, lo, hi): search window [y_hat+err_lo, y_hat+err_hi]."""
        x = np.asarray(x, dtype=np.float64)
        seg = self.segment_of(x)
        y_hat = self.slope[seg] * (x - self.seg_first_key[seg]) + self.icept[seg]
        return y_hat, y_hat + self.err_lo[seg], y_hat + self.err_hi[seg]

    def max_abs_error(self) -> float:
        """E — the paper's maximum absolute prediction error bound."""
        if self.n_segments == 0:
            return 1.0
        return float(max(np.max(np.abs(self.err_lo)), np.max(np.abs(self.err_hi)), 1.0))

    def param_count(self) -> int:
        # slope + intercept + first_key (+2 error bounds) per segment
        return 5 * self.n_segments

    def size_bytes(self, payload_bytes: int = 0) -> int:
        """Index size following the paper's accounting (doubles per field)."""
        return 8 * self.param_count() + payload_bytes


def _finalize_errors(
    plm: PiecewiseLinearModel, x: np.ndarray, y: np.ndarray
) -> PiecewiseLinearModel:
    """Recompute exact per-segment signed error bounds on (x, y)."""
    seg = plm.segment_of(x)
    err = y - plm.predict(x)
    K = plm.n_segments
    lo = np.full(K, 0.0)
    hi = np.full(K, 0.0)
    np.minimum.at(lo, seg, err)
    np.maximum.at(hi, seg, err)
    plm.err_lo, plm.err_hi = lo, hi
    return plm


# ---------------------------------------------------------------------------
# PGM — optimal PLA under an error bound (streaming convex hull)
# ---------------------------------------------------------------------------


_POLY_MAX = 32  # cap on feasible-polygon complexity (see _thin_poly)


def _clip_halfplane(poly, cx, cc, keep_le):
    """Clip convex polygon (list of (a, b)) with cx*a + b {<=,>=} cc."""
    out = []
    m = len(poly)
    for idx in range(m):
        a1, b1 = poly[idx]
        a2, b2 = poly[(idx + 1) % m]
        f1 = cx * a1 + b1 - cc
        f2 = cx * a2 + b2 - cc
        in1 = (f1 <= 0.0) if keep_le else (f1 >= 0.0)
        in2 = (f2 <= 0.0) if keep_le else (f2 >= 0.0)
        if in1:
            out.append((a1, b1))
        if in1 != in2:
            t = f1 / (f1 - f2)
            out.append((a1 + t * (a2 - a1), b1 + t * (b2 - b1)))
    return out


def _thin_poly(poly):
    """Bound polygon complexity (keeps the eps guarantee conservative).

    On (near-)exactly-linear data every new constraint grazes the feasible
    polygon, netting +1 vertex per point — O(n) vertices and quadratic
    total work.  We (a) drop near-duplicate vertices and (b) if still over
    ``_POLY_MAX``, keep an evenly spaced subset.  The kept subset spans a
    convex *inner* approximation, so every accepted point still satisfies
    |err| <= eps; segments can only end marginally earlier than optimal.
    """
    if len(poly) <= _POLY_MAX:
        return poly
    # drop consecutive near-duplicates (relative tolerance)
    out = []
    for v in poly:
        if out:
            pa, pb = out[-1]
            da = abs(v[0] - pa)
            db = abs(v[1] - pb)
            if da <= 1e-12 * (1.0 + abs(pa)) and db <= 1e-12 * (1.0 + abs(pb)):
                continue
        out.append(v)
    if len(out) > _POLY_MAX:
        step = (len(out) + _POLY_MAX - 1) // _POLY_MAX
        out = out[::step]
    if len(out) >= 3:
        return out
    return poly[:3]


def _optimal_pla(x: np.ndarray, y: np.ndarray, eps: float):
    """Optimal PLA under error bound eps (the PGM-index algorithm).

    Greedy maximal extension with a *free intercept*: per segment we
    maintain the feasible region of (slope a, intercept b) — a convex
    polygon, the intersection of the strips
    ``y_t - eps <= a*(x_t - x0) + b + y0 <= y_t + eps`` —
    and end the segment when the polygon empties.  Greedy-maximal pieces
    are provably minimal in count (O'Rourke '81).  Coordinates are
    anchored at the segment's first point for conditioning.
    Sequential by nature (documented in DESIGN.md §2); host-side.

    Returns list of (first_idx, last_idx, slope, icept_at_first_key).
    """
    n = int(x.shape[0])
    eps = float(eps)
    segments = []
    i = 0
    while i < n:
        if i == n - 1:
            segments.append((i, i, 0.0, float(y[i])))
            break
        x0 = float(x[i])
        y0 = float(y[i])
        dx1 = float(x[i + 1]) - x0
        if dx1 <= 0:
            raise ValueError("keys must be strictly increasing (deduplicate first)")
        dy1 = float(y[i + 1]) - y0
        # Feasible (a, b) after the first two points: a parallelogram.
        poly = [
            ((dy1 - eps + eps) / dx1, -eps),   # b=-eps, lower constraint
            ((dy1 + eps + eps) / dx1, -eps),   # b=-eps, upper constraint
            ((dy1 + eps - eps) / dx1, eps),    # b=+eps, upper constraint
            ((dy1 - eps - eps) / dx1, eps),    # b=+eps, lower constraint
        ]
        j = i + 2
        while j < n:
            # cheap per-point cut test (pure python over <=POLY_MAX verts):
            # a point whose two halfplanes contain every vertex cannot
            # change the feasible region — skipping it is EXACT.
            dx = float(x[j]) - x0
            dy = float(y[j]) - y0
            hi = -np.inf
            lo = np.inf
            for va, vb in poly:
                v = va * dx + vb
                if v > hi:
                    hi = v
                if v < lo:
                    lo = v
            if hi <= dy + eps and lo >= dy - eps:
                # no cut here: vectorized scan-ahead for the next cutter
                pa = np.fromiter((v[0] for v in poly), np.float64, len(poly))
                pb = np.fromiter((v[1] for v in poly), np.float64, len(poly))
                chunk = 256
                j += 1
                while j < n:
                    j_end = min(n, j + chunk)
                    dxs = x[j:j_end] - x0
                    dys = y[j:j_end] - y0
                    vals = dxs[:, None] * pa[None, :] + pb[None, :]
                    cuts = ((vals.max(axis=1) > dys + eps)
                            | (vals.min(axis=1) < dys - eps))
                    idx = np.flatnonzero(cuts)
                    if idx.size:
                        j = j + int(idx[0])
                        break
                    j = j_end
                    chunk = min(chunk * 2, 1 << 16)
                continue
            p1 = _clip_halfplane(poly, dx, dy + eps, keep_le=True)
            if not p1:
                break
            p2 = _clip_halfplane(p1, dx, dy - eps, keep_le=False)
            if not p2:
                break
            poly = _thin_poly(p2)
            j += 1
        a = sum(v[0] for v in poly) / len(poly)
        b = sum(v[1] for v in poly) / len(poly)
        segments.append((i, j - 1, float(a), float(y0 + b)))
        i = j
    return segments


@dataclasses.dataclass
class PGMMechanism:
    """PGM-index: optimal PLA segments (+optional recursive levels)."""

    eps: float = 128.0
    recursive: bool = True
    plm: Optional[PiecewiseLinearModel] = None
    upper_plms: Tuple[PiecewiseLinearModel, ...] = ()

    name = "pgm"

    def fit(self, x: np.ndarray, y: np.ndarray) -> "PGMMechanism":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if not bool(np.all(np.diff(x) > 0)):
            raise ValueError("keys must be strictly increasing (deduplicate first)")
        segs = _optimal_pla(x, y, self.eps)
        K = len(segs)
        plm = PiecewiseLinearModel(
            seg_first_key=np.array([x[s[0]] for s in segs]),
            slope=np.array([s[2] for s in segs]),
            icept=np.array([s[3] for s in segs]),
            err_lo=np.zeros(K),
            err_hi=np.zeros(K),
            n_keys=x.shape[0],
        )
        self.plm = _finalize_errors(plm, x, y)
        # Recursive variant: index the segment-first-keys with further PLMs
        # until one segment remains (paper evaluates the recursive PGM).
        self.upper_plms = ()
        if self.recursive:
            uppers = []
            keys = plm.seg_first_key
            while keys.shape[0] > 64:
                pos = np.arange(keys.shape[0], dtype=np.float64)
                usegs = _optimal_pla(keys, pos, max(self.eps / 2, 4.0))
                uk = len(usegs)
                uplm = PiecewiseLinearModel(
                    seg_first_key=np.array([keys[s[0]] for s in usegs]),
                    slope=np.array([s[2] for s in usegs]),
                    icept=np.array([s[3] for s in usegs]),
                    err_lo=np.zeros(uk),
                    err_hi=np.zeros(uk),
                    n_keys=keys.shape[0],
                )
                uplm = _finalize_errors(uplm, keys, pos)
                uppers.append(uplm)
                keys = uplm.seg_first_key
            self.upper_plms = tuple(uppers)
            self.plm.levels = 1 + len(uppers)
            self.plm.level_sizes = (K,) + tuple(u.n_segments for u in uppers)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.plm.predict(x)

    def param_count(self) -> int:
        return self.plm.param_count() + sum(u.param_count() for u in self.upper_plms)

    def prediction_ops(self) -> int:
        # one fma per level + binary probe of the final level table
        levels = 1 + len(self.upper_plms)
        return 2 * levels + int(np.ceil(np.log2(max(self.plm.n_segments, 2))))


# ---------------------------------------------------------------------------
# FITing-Tree — greedy shrinking cone
# ---------------------------------------------------------------------------


def _shrinking_cone(x: np.ndarray, y: np.ndarray, eps: float, chunk: int = 8192):
    """Greedy shrinking-cone segmentation (FITing-Tree).

    The cone is anchored at the segment's first point (fixed intercept),
    which is what makes it greedy/suboptimal vs. the PGM polygon method.
    Vectorized in chunks: running cone bounds are prefix max/min, so each
    chunk is one ``maximum.accumulate`` — O(n) numpy work total.
    """
    n = int(x.shape[0])
    segments = []
    i = 0
    while i < n:
        if i == n - 1:
            segments.append((i, i, 0.0, float(y[i])))
            break
        x0, y0 = x[i], y[i]
        lo, hi = -np.inf, np.inf
        j = i + 1
        while j < n:
            j_end = min(n, j + chunk)
            dx = x[j:j_end] - x0
            if dx[0] <= 0:
                raise ValueError("keys must be strictly increasing (deduplicate first)")
            s_lo = np.maximum(np.maximum.accumulate((y[j:j_end] - eps - y0) / dx), lo)
            s_hi = np.minimum(np.minimum.accumulate((y[j:j_end] + eps - y0) / dx), hi)
            bad = s_lo > s_hi
            if bad.any():
                k = int(np.argmax(bad))  # first violating offset in chunk
                if k > 0:
                    lo, hi = float(s_lo[k - 1]), float(s_hi[k - 1])
                j = j + k
                break
            lo, hi = float(s_lo[-1]), float(s_hi[-1])
            j = j_end
        if not np.isfinite(lo) or not np.isfinite(hi):
            slope = 0.0
        else:
            slope = (lo + hi) / 2.0
        segments.append((i, j - 1, float(slope), float(y0)))
        i = j
    return segments


@dataclasses.dataclass
class FITingMechanism:
    """FITing-Tree: greedy eps-bounded segments, routed by sorted table."""

    eps: float = 128.0
    plm: Optional[PiecewiseLinearModel] = None

    name = "fiting"

    def fit(self, x: np.ndarray, y: np.ndarray) -> "FITingMechanism":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if not bool(np.all(np.diff(x) > 0)):
            raise ValueError("keys must be strictly increasing (deduplicate first)")
        segs = _shrinking_cone(x, y, self.eps)
        K = len(segs)
        plm = PiecewiseLinearModel(
            seg_first_key=np.array([x[s[0]] for s in segs]),
            slope=np.array([s[2] for s in segs]),
            icept=np.array([s[3] for s in segs]),
            err_lo=np.zeros(K),
            err_hi=np.zeros(K),
            n_keys=x.shape[0],
        )
        self.plm = _finalize_errors(plm, x, y)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.plm.predict(x)

    def param_count(self) -> int:
        return self.plm.param_count()

    def prediction_ops(self) -> int:
        return 2 + int(np.ceil(np.log2(max(self.plm.n_segments, 2))))


# ---------------------------------------------------------------------------
# RMI — two-layer linear recursive model index
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RMIMechanism:
    """Two-layer RMI with linear models (paper's configuration).

    Root: one linear model mapping key -> leaf bucket in [0, n_leaf).
    Leaves: per-bucket least-squares linear fits, computed closed-form and
    in parallel over buckets (segment sums) — the TPU-native adaptation.
    Empty leaves are patched to their nearest trained leaf
    (the paper's RMI-Nearest-Seg patch; see sampling.py).
    """

    n_leaf: int = 1000
    plm: Optional[PiecewiseLinearModel] = None
    root_slope: float = 0.0
    root_icept: float = 0.0
    leaf_first_key: Optional[np.ndarray] = None  # for PLM-style export

    name = "rmi"

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RMIMechanism":
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n = x.shape[0]
        # Root linear model fit on (x, y), scaled to leaf ids.
        xm, ym = x.mean(), y.mean()
        xv = ((x - xm) ** 2).mean()
        slope = 0.0 if xv == 0 else (((x - xm) * (y - ym)).mean()) / xv
        icept = ym - slope * xm
        y_max = max(float(y.max()), 1.0)
        self.root_slope = slope * self.n_leaf / (y_max + 1.0)
        self.root_icept = icept * self.n_leaf / (y_max + 1.0)
        leaf = np.clip(
            (self.root_slope * x + self.root_icept).astype(np.int64),
            0,
            self.n_leaf - 1,
        )
        # Root is monotone (slope>=0) => leaf ids are sorted; closed-form
        # per-leaf least squares via segment sums (vectorized).
        L = self.n_leaf
        cnt = np.bincount(leaf, minlength=L).astype(np.float64)
        sx = np.bincount(leaf, weights=x, minlength=L)
        sy = np.bincount(leaf, weights=y, minlength=L)
        sxx = np.bincount(leaf, weights=x * x, minlength=L)
        sxy = np.bincount(leaf, weights=x * y, minlength=L)
        denom = cnt * sxx - sx * sx
        safe = np.abs(denom) > 1e-12
        slopes = np.where(safe, (cnt * sxy - sx * sy) / np.where(safe, denom, 1.0), 0.0)
        iceptc = np.where(cnt > 0, (sy - slopes * sx) / np.maximum(cnt, 1.0), 0.0)
        # Leaf boundaries in key space: first key mapped into each leaf.
        # leaf id l covers keys with root(x) in [l, l+1) =>
        # first_key(l) = (l - root_icept)/root_slope  (root_slope>0).
        if self.root_slope <= 0:
            bounds = np.full(L, x[0])
        else:
            bounds = (np.arange(L, dtype=np.float64) - self.root_icept) / self.root_slope
        bounds[0] = min(bounds[0], x[0])
        # Patch empty leaves -> nearest trained leaf (RMI-Nearest-Seg).
        trained = np.flatnonzero(cnt > 0)
        if trained.size == 0:
            raise ValueError("RMI: no trained leaves")
        all_ids = np.arange(L)
        nearest = trained[
            np.clip(np.searchsorted(trained, all_ids), 0, trained.size - 1)
        ]
        # choose the closer of the neighbors on each side
        left = trained[np.clip(np.searchsorted(trained, all_ids) - 1, 0, trained.size - 1)]
        use_left = np.abs(all_ids - left) < np.abs(nearest - all_ids)
        nearest = np.where(use_left, left, nearest)
        slopes = slopes[nearest]
        iceptc = iceptc[nearest]
        # Export in the shared PLM layout: per-leaf y = slope*x + icept
        #   = slope*(x - first_key) + (slope*first_key + icept).
        plm = PiecewiseLinearModel(
            seg_first_key=bounds,
            slope=slopes,
            icept=slopes * bounds + iceptc,
            err_lo=np.zeros(L),
            err_hi=np.zeros(L),
            n_keys=n,
        )
        self.plm = _finalize_errors(plm, x, y)
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        """RMI inference: root linear -> leaf linear (no search)."""
        x = np.asarray(x, dtype=np.float64)
        leaf = np.clip(
            (self.root_slope * x + self.root_icept).astype(np.int64),
            0,
            self.n_leaf - 1,
        )
        # icept in PLM layout is at seg_first_key; reconstruct absolute form
        sl = self.plm.slope[leaf]
        return sl * (x - self.plm.seg_first_key[leaf]) + self.plm.icept[leaf]

    def param_count(self) -> int:
        return 2 + 4 * self.n_leaf  # root + (slope,icept,err+,err-) per leaf

    def prediction_ops(self) -> int:
        return 4  # two fmas, no search


# ---------------------------------------------------------------------------
# B+Tree baseline (array-backed, same evaluation framework)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BTreeMechanism:
    """Dense-page B+Tree expressed as a mechanism for the MDL comparison.

    Prediction = root-to-leaf fence-key walk (cost ~ height * log2(fanout)
    comparisons); correction = binary scan within a page (cost ~ log2(page)).
    Arrays: fence keys per level; fully vectorizable lookup.
    """

    page_size: int = 256
    fanout: int = 16
    levels_keys: Tuple[np.ndarray, ...] = ()
    n_keys: int = 0

    name = "btree"

    def fit(self, x: np.ndarray, y: np.ndarray) -> "BTreeMechanism":
        x = np.asarray(x, dtype=np.float64)
        self.n_keys = x.shape[0]
        levels = []
        # leaf fence keys: first key of each page
        fences = x[:: self.page_size]
        levels.append(fences)
        while fences.shape[0] > self.fanout:
            fences = fences[:: self.fanout]
            levels.append(fences)
        self.levels_keys = tuple(reversed(levels))  # root first
        return self

    @property
    def height(self) -> int:
        return len(self.levels_keys)

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Returns the page-start position for each query key."""
        x = np.asarray(x, dtype=np.float64)
        leaf_fences = self.levels_keys[-1]
        page = np.clip(
            np.searchsorted(leaf_fences, x, side="right") - 1, 0, leaf_fences.shape[0] - 1
        )
        return page.astype(np.float64) * self.page_size + self.page_size / 2.0

    def param_count(self) -> int:
        return int(sum(lvl.shape[0] for lvl in self.levels_keys))

    def prediction_ops(self) -> int:
        return int(self.height * np.ceil(np.log2(self.fanout)))

    def size_bytes(self, payload_bytes: int = 0) -> int:
        # inner nodes (fence keys + child pointers) + leaves incl. payload
        inner = int(sum(lvl.shape[0] for lvl in self.levels_keys)) * 16
        leaves = self.n_keys * 16  # key + payload per entry, dense pages
        return inner + leaves + payload_bytes


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

MECHANISMS = {
    "pgm": PGMMechanism,
    "fiting": FITingMechanism,
    "rmi": RMIMechanism,
    "btree": BTreeMechanism,
}


def build_mechanism(name: str, **kwargs):
    """Build and fit nothing — returns the configured mechanism object."""
    if name not in MECHANISMS:
        raise KeyError(f"unknown mechanism {name!r}; have {sorted(MECHANISMS)}")
    return MECHANISMS[name](**kwargs)
