import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
compiles, and fits — without hardware.

For each cell this script:
  1. builds the mesh ((16,16) and/or (2,16,16)) of host placeholder devices,
  2. builds abstract params/opt-state/caches (ShapeDtypeStruct — nothing
     is allocated),
  3. jits the train/prefill/serve step with in/out shardings,
     ``.lower()``s and ``.compile()``s it,
  4. records memory_analysis / cost_analysis / per-collective bytes
     (parsed from the optimized HLO) into a JSON cell file consumed by
     launch/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all --mesh single [--out results/dryrun]
"""

import argparse
import functools
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs import ARCHS, SHAPES
from repro.dist import (
    activation_constrainer,
    input_shardings,
    param_pspecs,
    param_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import OPTIMIZERS
from repro.optim.compress import residual_init

# cells that are N/A by design (documented in DESIGN.md §4):
# long_500k needs sub-quadratic attention.
def applicable(cfg, shape) -> bool:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False
    return True


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum result bytes of every collective op in the optimized HLO.

    Returns (total_bytes, per_kind dict, op_count).  HLO line form:
      %x = bf16[2048,7168]{1,0} all-reduce(...), replica_groups=...
    """
    per_kind = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(\(?.*?\)?)\s+(" + "|".join(_COLLECTIVES)
                      + r")(-start|-done)?\(", stripped)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        shape_txt, kind = m.group(1), m.group(2)
        b = _bytes_of_shapes(shape_txt)
        per_kind[kind] += b
        count += 1
    return sum(per_kind.values()), per_kind, count


def _constrain_factory(mesh, cfg, seq_axis=None):
    return activation_constrainer(mesh, fsdp=cfg.fsdp, seq_axis=seq_axis)


def build_step(model, shape, mesh, seq_axis=None, kv_shard="heads"):
    """Returns (step_fn, abstract_args, in_shardings)."""
    cfg = model.cfg
    constrain = _constrain_factory(mesh, cfg, seq_axis)
    laxes = model.logical_axes()
    aparams = model.abstract_params()
    pshard = param_shardings(laxes, mesh, fsdp=cfg.fsdp,
                             abstract_tree=aparams)
    repl = NamedSharding(mesh, PS())

    if shape.kind == "train":
        opt_init, opt_update = OPTIMIZERS[cfg.optimizer]
        aopt = opt_init(aparams, abstract=True)
        # opt-state sharding mirrors the param sharding (ZeRO falls out of
        # FSDP param sharding); factored slots drop the reduced dim
        pshard_flat = param_pspecs(laxes, mesh, fsdp=cfg.fsdp,
                                   abstract_tree=aparams)
        def mirror(tree):
            return jax.tree.map(
                lambda ps: NamedSharding(mesh, ps), tree,
                is_leaf=lambda x: isinstance(x, PS))
        if cfg.optimizer == "adamw":
            oshard = {"m": mirror(pshard_flat), "v": mirror(pshard_flat),
                      "step": repl}
        else:
            def slot_shard(ps, sds):
                # factored slots (>=2-D params): vr drops the last dim,
                # vc drops the second-to-last; 1-D/scalars keep full v
                if len(sds.shape) >= 2:
                    t = tuple(ps) + (None,) * (len(sds.shape) - len(tuple(ps)))
                    return {
                        "vr": NamedSharding(mesh, PS(*t[:-1])),
                        "vc": NamedSharding(mesh, PS(*t[:-2], t[-1])),
                        "m": NamedSharding(mesh, ps),
                    }
                return {"v": NamedSharding(mesh, ps),
                        "m": NamedSharding(mesh, ps)}
            oshard = {
                "slots": jax.tree.map(slot_shard, pshard_flat, aparams,
                                      is_leaf=lambda x: isinstance(x, PS)),
                "step": repl,
            }
        binput = model.input_specs(shape)
        bshard = input_shardings(binput, mesh)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch, constrain))(params)
            new_params, new_opt, gnorm = opt_update(
                grads, opt_state, params, lr=3e-4)
            return new_params, new_opt, {"loss": loss, "gnorm": gnorm}

        jitted = jax.jit(
            train_step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, repl),
            donate_argnums=(0, 1),
        )
        return jitted, (aparams, aopt, binput)

    if shape.kind == "prefill":
        binput = model.input_specs(shape)
        bshard = input_shardings(binput, mesh)
        acache = model.cache_specs(shape.global_batch, _cache_len(cfg, shape))
        cshard = _cache_shardings(acache, mesh, cfg, shape, kv_shard)

        if model.prefill_fn is not None:
            def prefill_step(params, batch, cache):
                return model.prefill_fn(params, batch, cache, constrain)
        else:  # enc-dec / recurrent: prefill == loss-less forward; reuse loss
            def prefill_step(params, batch, cache):
                batch = dict(batch)
                batch["labels"] = batch["tokens"]
                return model.loss_fn(params, batch, constrain), cache

        jitted = jax.jit(
            prefill_step,
            in_shardings=(pshard, bshard, cshard),
            out_shardings=(None, cshard),
            donate_argnums=(2,),
        )
        return jitted, (aparams, binput, acache)

    # decode: one new token against a seq_len KV cache
    binput = model.input_specs(shape)
    bshard = input_shardings(binput, mesh)
    acache = model.cache_specs(shape.global_batch, _cache_len(cfg, shape))
    cshard = _cache_shardings(acache, mesh, cfg, shape, kv_shard)

    def serve_step(params, batch, cache, idx):
        return model.decode_fn(params, batch, cache, idx, constrain)

    jitted = jax.jit(
        serve_step,
        in_shardings=(pshard, bshard, cshard, repl),
        out_shardings=(NamedSharding(mesh, PS()), cshard),
        donate_argnums=(2,),
    )
    aidx = jax.ShapeDtypeStruct((), jnp.int32)
    return jitted, (aparams, binput, acache, aidx)


def _cache_len(cfg, shape) -> int:
    """KV capacity: +frontend tokens for multimodal prefill (vlm)."""
    extra = cfg.n_frontend_tokens if cfg.arch == "vlm" else 0
    return shape.seq_len + extra


def _cache_shardings(acache, mesh, cfg, shape, kv_shard: str = "heads"):
    """KV/state caches: batch -> data axes, heads -> model.

    long_500k (batch=1) shards the KV sequence over 'data' instead.
    ``kv_shard='seq'`` (§Perf hillclimb) shards the cache's sequence dim
    over the model axis instead of heads — context-parallel decode; fixes
    the kv_heads<16 replication blow-up (GQA archs).
    """
    axes_avail = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = tuple(a for a in ("pod", "data") if a in axes_avail)
    data_size = int(np.prod([sizes[a] for a in data_axes]))
    model_size = sizes.get("model", 1)
    long_ctx = shape.global_batch < len(jax.devices()) // 16

    def one(leaf):
        nd = len(leaf.shape)
        spec = [None] * nd
        # batch dim: first dim equal to global_batch, if shardable
        bdim = None
        if shape.global_batch % data_size == 0:
            try:
                bdim = leaf.shape.index(shape.global_batch)
                spec[bdim] = data_axes
            except ValueError:
                bdim = None
        cache_len = _cache_len(cfg, shape)
        if kv_shard == "seq" and cache_len in leaf.shape \
                and "model" in axes_avail and cache_len % model_size == 0:
            tdim = leaf.shape.index(cache_len)
            spec[tdim] = "model"
        else:
            # heads dim: shard over model when divisible
            for d in range(nd):
                if spec[d] is None and d != bdim and leaf.shape[d] in (
                        cfg.n_kv, cfg.n_heads) and "model" in axes_avail \
                        and leaf.shape[d] % model_size == 0:
                    spec[d] = "model"
                    break
        if long_ctx and cache_len in leaf.shape:
            tdim = leaf.shape.index(cache_len)
            if spec[tdim] is None and cache_len % data_size == 0:
                spec[tdim] = data_axes
        return NamedSharding(mesh, PS(*spec))

    return jax.tree.map(one, acache)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             seq_axis=None, tag: str = "baseline", skip_existing: bool = False,
             scan_layers: bool = False, layers: int = 0,
             kv_shard: str = "heads", moe_impl: str = "gspmd",
             no_fsdp: bool = False):
    import dataclasses as _dc
    cfg = ARCHS[arch]
    # default: UNROLLED layer stacks — XLA cost analysis counts while-loop
    # (scan) bodies only once, which silently undercounts flops/bytes/
    # collectives by ~n_layers; the scan variant (tag "scan") proves the
    # production compile path separately.
    cfg = _dc.replace(cfg, scan_layers=scan_layers)
    if layers:  # reduced-depth probe for per-layer cost extrapolation
        cfg = _dc.replace(cfg, n_layers=layers)
    cfg = _dc.replace(cfg, moe_impl=moe_impl)
    if no_fsdp:
        cfg = _dc.replace(cfg, fsdp=False)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (
        f"__{tag}" if tag != "baseline" else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    os.makedirs(out_dir, exist_ok=True)
    if skip_existing and os.path.exists(out_path):
        with open(out_path) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "n/a"):
            print(f"[dryrun] {cell_id}: cached ({rec['status']})")
            return rec

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "status": "n/a", "layers_used": layers or ARCHS[arch].n_layers,
           "scan_layers": scan_layers}
    if not applicable(cfg, shape):
        rec["reason"] = "long_500k requires sub-quadratic attention (DESIGN.md §4)"
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] {cell_id}: N/A by design")
        return rec

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        # jax.set_mesh landed after 0.4.x; entering the Mesh context is
        # the portable equivalent (build_step shards via NamedSharding)
        with mesh:
            jitted, aargs = build_step(model, shape, mesh, seq_axis=seq_axis,
                                       kv_shard=kv_shard)
            lowered = jitted.lower(*aargs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict
                cost = cost[0] if cost else None  # per computation
            hlo = compiled.as_text()
            cbytes, per_kind, n_coll = collective_bytes(hlo)

            rec.update(
                status="ok",
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                n_devices=int(mesh.devices.size),
                params=model.param_count(),
                active_params=model.active_param_count(),
                flops_per_device=float(cost.get("flops", -1.0)) if cost else -1.0,
                bytes_per_device=float(cost.get("bytes accessed", -1.0))
                if cost else -1.0,
                collective_bytes_per_device=int(cbytes),
                collective_ops=n_coll,
                collectives=per_kind,
            )
            if mem is not None:
                for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                    v = getattr(mem, k, None)
                    if v is not None:
                        rec[k] = int(v)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] {cell_id}: FAILED {type(e).__name__}: {e}")

    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] == "ok":
        print(f"[dryrun] {cell_id}: ok  flops/dev={rec['flops_per_device']:.3e}"
              f" bytes/dev={rec['bytes_per_device']:.3e}"
              f" coll/dev={rec['collective_bytes_per_device']:.3e}"
              f" (lower {rec['lower_s']}s compile {rec['compile_s']}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--seq-axis", default=None,
                    help="mesh axis to shard activations' seq dim over")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--scan-layers", action="store_true",
                    help="use lax.scan over layers (production compile "
                         "path; undercounts cost analysis)")
    ap.add_argument("--layers", type=int, default=0,
                    help="override layer count (reduced-depth cost probe)")
    ap.add_argument("--kv-shard", choices=["heads", "seq"], default="heads",
                    help="decode cache sharding: heads (baseline) or seq "
                         "(context-parallel; §Perf)")
    ap.add_argument("--moe-impl", choices=["gspmd", "ep"], default="gspmd",
                    help="MoE dispatch: GSPMD-derived (baseline) or "
                         "explicit shard_map all_to_all EP (§Perf)")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="disable FSDP param sharding (§Perf: trades "
                         "memory for the weight-regather collectives)")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if args.all or not args.arch else [args.arch]
    shapes = sorted(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failed = 0
    for a in archs:
        for s in shapes:
            for mp in meshes:
                rec = run_cell(a, s, mp, args.out, seq_axis=args.seq_axis,
                               tag=args.tag,
                               skip_existing=args.skip_existing,
                               scan_layers=args.scan_layers,
                               layers=args.layers, kv_shard=args.kv_shard,
                               moe_impl=args.moe_impl, no_fsdp=args.no_fsdp)
                cells.append(rec)
                failed += rec["status"] == "error"
    print(f"[dryrun] {len(cells)} cells, {failed} failures")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
