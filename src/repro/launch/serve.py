"""Serving launcher: batched requests through the paged-KV engine whose
block table is the gapped learned index.

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \\
      --reduced --requests 12 --max-new 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.models import build_model
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    if model.decode_fn is None:
        raise SystemExit(f"{cfg.name} has no decode path")

    engine = ServingEngine(model, max_batch=args.max_batch,
                           max_len=args.max_len)
    engine.load(model.init_params(jax.random.PRNGKey(args.seed)))

    rng = np.random.default_rng(args.seed)
    for rid in range(1, args.requests + 1):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 24),
                              dtype=np.int32)
        engine.submit(Request(request_id=rid, prompt=prompt,
                              max_new_tokens=args.max_new))
    stats = engine.run_until_done()
    stats.update(engine.kv_pages.insert_path_stats())
    print(f"[serve] decoded={stats['decoded_tokens']} tokens in "
          f"{stats['rounds']} rounds ({stats['wall_s']:.2f}s); "
          f"page_lookups={stats['page_lookups']} "
          f"kv_util={engine.kv_pages.utilization:.2f}")
    return stats


if __name__ == "__main__":
    main()
