"""Roofline analysis over dry-run cell records.

Three terms per (arch × shape × mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16)
    memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective = collective_bytes_per_device / link_bw      (~50 GB/s ICI)

cost_analysis on the SPMD-partitioned module reports per-shard shapes, so
"per device" falls straight out; collective bytes come from the HLO parse
in dryrun.py.  MODEL_FLOPS uses 6·N·D (train) / 2·N·D (inference) with
N = active params (MoE-aware), giving the useful-compute ratio that
catches remat/redundancy waste.

Usage: python -m repro.launch.roofline [--dir results/dryrun] [--md out.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12     # TPU v5e bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

SHAPE_TOKENS = {  # global tokens processed per step
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 1 * 128,
    "long_500k": 1 * 1,
}


def analyze_cell(rec: Dict) -> Dict:
    n_dev = rec.get("n_devices", 256)
    flops = rec.get("flops_per_device", 0.0)
    bytes_ = rec.get("bytes_per_device", 0.0)
    cbytes = rec.get("collective_bytes_per_device", 0)

    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    t_coll = cbytes / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())

    tokens = SHAPE_TOKENS.get(rec["shape"], 0)
    n_active = rec.get("active_params", rec.get("params", 0))
    mult = 6 if rec["shape"].startswith("train") else 2
    model_flops_per_dev = mult * n_active * tokens / max(n_dev, 1)
    useful = model_flops_per_dev / flops if flops > 0 else 0.0
    # roofline fraction: useful work / time if running at the binding roof
    frac = (model_flops_per_dev / PEAK_FLOPS) / bound if bound > 0 else 0.0

    hints = {
        "compute": "compute-bound: raise MFU via larger per-device tiles "
                   "or reduced remat recompute",
        "memory": "memory-bound: cut bytes via fusion/remat policy, bf16 "
                  "intermediates, or KV/page layout",
        "collective": "collective-bound: reshard to cut all-gathers, "
                      "overlap comm/compute, or shard_map the MoE "
                      "dispatch into pure all-to-all",
    }
    return {
        **{k: rec.get(k) for k in ("arch", "shape", "mesh", "tag", "status")},
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": model_flops_per_dev,
        "useful_compute_ratio": useful,
        "roofline_fraction": frac,
        "hint": hints[dominant],
        "collectives": rec.get("collectives", {}),
        "compile_s": rec.get("compile_s"),
    }


def load_cells(d: str, tag: str = None) -> List[Dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("status") == "ok" and (tag is None or rec.get("tag") == tag):
            cells.append(analyze_cell(rec))
        elif rec.get("status") == "n/a":
            cells.append({**{k: rec.get(k) for k in
                             ("arch", "shape", "mesh", "tag", "status")},
                          "reason": rec.get("reason", "")})
    return cells


def to_markdown(cells: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful % | roofline % |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        if c.get("status") == "n/a":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                        f"— | — | — | N/A by design | — | — |")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['t_compute_s']:.3e} | {c['t_memory_s']:.3e} "
            f"| {c['t_collective_s']:.3e} | **{c['dominant']}** "
            f"| {100*c['useful_compute_ratio']:.1f} "
            f"| {100*c['roofline_fraction']:.1f} |")
    return hdr + "\n".join(rows) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    cells = load_cells(args.dir, tag=args.tag)
    md = to_markdown(cells)
    print(md)
    # summary: most interesting hillclimb candidates
    ok = [c for c in cells if c.get("status") != "n/a"
          and c.get("mesh") == "pod16x16"]
    if ok:
        worst = min(ok, key=lambda c: c["roofline_fraction"])
        coll = max(ok, key=lambda c: c["t_collective_s"])
        print(f"worst roofline fraction : {worst['arch']} x {worst['shape']}"
              f" ({100*worst['roofline_fraction']:.1f}%)")
        print(f"most collective-bound   : {coll['arch']} x {coll['shape']}"
              f" ({coll['t_collective_s']:.3e}s)")
    if args.md:
        with open(args.md, "w") as f:
            f.write(md)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(cells, f, indent=1)


if __name__ == "__main__":
    main()
