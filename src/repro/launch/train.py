"""Training launcher.

CPU-scale end-to-end run (reduced config, real data pipeline + learned
index + checkpoints + watchdog):

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduced \\
      --steps 200 --global-batch 8 --seq-len 128

Production launch (TPU pod; same code path, full config, mesh from
launch/mesh.py) adds --mesh single|multi and per-host data sharding via
JAX distributed initialization (jax.distributed.initialize on real
clusters).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, reduced as reduce_cfg
from repro.data import IndexedTokenDataset, PackedTokenStore, ShardedLoader
from repro.dist import activation_constrainer
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.train import FailureInjector, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-9b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU end-to-end)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--n-docs", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="cosine")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--index-method", default="pgm")
    ap.add_argument("--index-sample-rate", type=float, default=0.1)
    ap.add_argument("--index-gap-rho", type=float, default=0.15)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--inject-crash-at", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    print(f"[train] arch={cfg.name} params={model.param_count():,}")

    # data: packed store + learned-index lookup (sampling + gaps per paper)
    store = PackedTokenStore.synthetic(
        args.n_docs, mean_len=args.seq_len + 1, vocab=cfg.vocab,
        seed=args.seed)
    dataset = IndexedTokenDataset.build(
        store, method=args.index_method,
        sample_rate=args.index_sample_rate, gap_rho=args.index_gap_rho)
    print(f"[train] index: {args.index_method} "
          f"segments={dataset.index.mech.plm.n_segments} "
          f"build={dataset.index.build_seconds*1e3:.1f}ms")
    loader = ShardedLoader(dataset, global_batch=args.global_batch,
                           seq_len=args.seq_len, seed=args.seed)

    constrain = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        constrain = activation_constrainer(mesh, fsdp=cfg.fsdp)

    injector = FailureInjector(
        {args.inject_crash_at: "crash"} if args.inject_crash_at >= 0 else {})
    tcfg = TrainConfig(
        total_steps=args.steps, peak_lr=args.lr, schedule=args.schedule,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        grad_compress=args.grad_compress,
        warmup_steps=max(2, args.steps // 20))
    trainer = Trainer(model, tcfg, loader, constrain=constrain,
                      failure_injector=injector)
    out = trainer.run(seed=args.seed, resume=not args.no_resume)
    losses = [m["loss"] for m in out["metrics"]]
    print(f"[train] done: first_loss={losses[0]:.4f} "
          f"last_loss={losses[-1]:.4f} stragglers={len(out['straggler_events'])}")
    return out


if __name__ == "__main__":
    main()
