"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Shapes per the assignment:
  single pod : (16, 16)      axes ("data", "model")   — 256 chips
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") — 512 chips
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 1):
    """Elastic helper: best (data, model) mesh for whatever is alive.

    Used by the elastic-restart path (repro.train.elastic) when a pod
    comes back with fewer healthy hosts.
    """
    model_parallel = max(1, min(model_parallel, n_devices))
    while n_devices % model_parallel:
        model_parallel -= 1
    return jax.make_mesh(
        (n_devices // model_parallel, model_parallel), ("data", "model")
    )
