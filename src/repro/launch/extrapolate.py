"""Per-layer cost extrapolation for deep models whose fully-unrolled
compile is impractical on this single-core container.

Costs of a homogeneous layer stack are affine in depth:
    cost(L) = outside + L * per_layer
Two reduced-depth unrolled compiles (tags ``L<a>``/``L<b>``) pin the
line; the full-depth record is synthesized exactly (``extrapolated``
flag set, both probe points kept for audit).

Usage:
  python -m repro.launch.extrapolate --arch qwen1.5-32b --shape train_4k \\
      --mesh pod16x16 --a 4 --b 8 [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os

LINEAR_FIELDS = (
    "flops_per_device", "bytes_per_device", "collective_bytes_per_device",
    "collective_ops", "temp_size_in_bytes", "argument_size_in_bytes",
    "output_size_in_bytes", "alias_size_in_bytes",
)


def extrapolate(d: str, arch: str, shape: str, mesh: str, a: int, b: int,
                prefix: str = ""):
    def load(tag):
        path = os.path.join(d, f"{arch}__{shape}__{mesh}__{tag}.json")
        with open(path) as f:
            rec = json.load(f)
        assert rec["status"] == "ok", (path, rec.get("error"))
        return rec

    ra, rb = load(f"{prefix}L{a}"), load(f"{prefix}L{b}")
    import os as _os
    _os.environ.setdefault("XLA_FLAGS",
                           "--xla_force_host_platform_device_count=1")
    from repro.configs import ARCHS
    L = ARCHS[arch].n_layers

    out = dict(rb)
    out["tag"] = prefix.rstrip("_") if prefix else "baseline"
    out["layers_used"] = L
    out["extrapolated"] = True
    out["probe_layers"] = [a, b]
    for f in LINEAR_FIELDS:
        if f not in ra or f not in rb:
            continue
        per_layer = (rb[f] - ra[f]) / (b - a)
        outside = ra[f] - a * per_layer
        out[f] = outside + L * per_layer
    cd = {}
    for k in set(ra.get("collectives", {})) | set(rb.get("collectives", {})):
        va, vb = ra["collectives"].get(k, 0), rb["collectives"].get(k, 0)
        per_layer = (vb - va) / (b - a)
        cd[k] = va - a * per_layer + L * per_layer
    out["collectives"] = cd
    # param counts from the full model
    from repro.models import build_model
    m = build_model(ARCHS[arch])
    out["params"] = m.param_count()
    out["active_params"] = m.active_param_count()

    suffix = f"__{prefix.rstrip('_')}" if prefix else ""
    path = os.path.join(d, f"{arch}__{shape}__{mesh}{suffix}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[extrapolate] {arch} x {shape} x {mesh}: "
          f"flops/dev={out['flops_per_device']:.3e} "
          f"coll/dev={out['collective_bytes_per_device']:.3e} "
          f"(from L={a},{b} -> L={L})")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--a", type=int, default=4)
    ap.add_argument("--b", type=int, default=8)
    ap.add_argument("--prefix", default="",
                    help="probe-tag prefix, e.g. 'ep_' for ep_L4/ep_L8")
    args = ap.parse_args()
    extrapolate(args.dir, args.arch, args.shape, args.mesh, args.a, args.b,
                prefix=args.prefix)


if __name__ == "__main__":
    main()
