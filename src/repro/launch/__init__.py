# Launchers: mesh construction, multi-pod dry-run, roofline analysis,
# training and serving entry points.
