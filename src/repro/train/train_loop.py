"""Fault-tolerant training loop.

Composition per step:
  data (seekable learned-index pipeline) -> [optional EF-int8 grad
  compression] -> jitted train_step (loss+grad+optimizer) -> metrics
  -> watchdog disarm -> periodic async atomic checkpoint.

Restart semantics: ``Trainer.run`` restores the latest checkpoint (if
any), seeks the loader to the restored step, and continues — crash at
any point loses at most ``ckpt_every`` steps and zero data order.
NaN steps are skipped (grads dropped, step counted) and surfaced in
metrics — the standard large-scale "bad step" mitigation.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model
from ..optim import OPTIMIZERS
from ..optim.compress import ef_compress_update, residual_init
from ..optim.schedules import cosine_schedule, wsd_schedule
from .checkpoint import CheckpointManager
from .fault import FailureInjector, StepWatchdog


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "cosine"           # cosine | wsd
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    watchdog_timeout_s: float = 300.0
    grad_compress: bool = False        # EF-int8 on the DP gradient path
    log_every: int = 10


class Trainer:
    def __init__(self, model: Model, train_cfg: TrainConfig,
                 loader, constrain=None,
                 failure_injector: Optional[FailureInjector] = None):
        self.model = model
        self.cfg = train_cfg
        self.loader = loader
        self.constrain = constrain
        self.injector = failure_injector or FailureInjector()
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir,
                                      keep=train_cfg.keep_ckpts)
        self.watchdog = StepWatchdog(train_cfg.watchdog_timeout_s)
        self.metrics: List[Dict] = []

        opt_init, opt_update = OPTIMIZERS[model.cfg.optimizer]
        self._opt_init = opt_init
        sched = cosine_schedule if train_cfg.schedule == "cosine" else \
            wsd_schedule
        mcfg = model.cfg
        compress = train_cfg.grad_compress

        def lr_at(step):
            if train_cfg.schedule == "wsd":
                return wsd_schedule(
                    step, peak_lr=train_cfg.peak_lr,
                    warmup_steps=train_cfg.warmup_steps,
                    stable_steps=int(0.8 * train_cfg.total_steps),
                    decay_steps=max(1, int(0.1 * train_cfg.total_steps)))
            return cosine_schedule(
                step, peak_lr=train_cfg.peak_lr,
                warmup_steps=train_cfg.warmup_steps,
                total_steps=train_cfg.total_steps)

        def train_step(params, opt_state, residual, batch):
            loss, grads = jax.value_and_grad(
                lambda p: self.model.loss_fn(p, batch, self.constrain))(params)
            if compress:
                grads, residual = ef_compress_update(grads, residual)
            lr = lr_at(opt_state["step"])
            bad = ~jnp.isfinite(loss)
            new_params, new_opt, gnorm = opt_update(
                grads, opt_state, params, lr=lr)
            # NaN guard: drop the update, keep counting steps
            new_params = jax.tree.map(
                lambda n, o: jnp.where(bad, o, n), new_params, params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(bad, o, n) if n.ndim else n,
                new_opt, opt_state)
            return new_params, new_opt, residual, {
                "loss": loss, "gnorm": gnorm, "lr": lr,
                "bad_step": bad.astype(jnp.float32)}

        self._train_step = jax.jit(train_step, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init_params(jax.random.PRNGKey(seed))
        opt_state = self._opt_init(params)
        residual = (residual_init(params) if self.cfg.grad_compress
                    else jax.tree.map(lambda p: jnp.zeros((), jnp.float32),
                                      params))
        return {"params": params, "opt": opt_state, "residual": residual}

    def run(self, seed: int = 0, resume: bool = True) -> Dict[str, Any]:
        state = None
        start_step = 0
        if resume and self.ckpt.latest_step() is not None:
            template = self.init_state(seed)
            state, extra = self.ckpt.restore(template=template)
            start_step = int(extra.get("step", 0))
            self.loader.seek(start_step)
            print(f"[train] resumed from step {start_step}")
        if state is None:
            state = self.init_state(seed)

        step = start_step
        t_start = time.time()
        while step < self.cfg.total_steps:
            self.watchdog.arm(step)
            self.injector.maybe_fail(step)
            batch = self.loader.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state["params"], state["opt"], state["residual"], m = \
                self._train_step(state["params"], state["opt"],
                                 state["residual"], batch)
            self.watchdog.cancel()
            step += 1
            if step % self.cfg.log_every == 0 or step == self.cfg.total_steps:
                m = {k: float(v) for k, v in m.items()}
                m.update(step=step,
                         stragglers=len(self.watchdog.events),
                         elapsed_s=round(time.time() - t_start, 2))
                self.metrics.append(m)
                print(f"[train] step={step} loss={m['loss']:.4f} "
                      f"lr={m['lr']:.2e} gnorm={m['gnorm']:.3f}")
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save_async(step, state,
                                     extra={"step": step,
                                            "loader_step": self.loader.step})
        self.ckpt.wait()
        self.ckpt.save(step, state, extra={"step": step,
                                           "loader_step": self.loader.step})
        return {"state": state, "metrics": self.metrics,
                "straggler_events": self.watchdog.events}
