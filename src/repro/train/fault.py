"""Fault tolerance primitives: straggler watchdog + failure injection.

At thousand-node scale the dominant events are (a) slow hosts
(stragglers), (b) dead hosts (restart), (c) flaky steps (NaN/timeout).
The Trainer composes:

  * :class:`StepWatchdog` — wall-clock alarm around each step; on
    expiry it records a straggler event and invokes a callback
    (production: mark host suspect, pre-empt its shard; here: logged and
    surfaced in metrics so tests can assert on it).
  * :class:`FailureInjector` — deterministic fault schedule for tests/
    examples (raise at step k, NaN at step m), proving the
    checkpoint-restart path end to end.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional


class StepWatchdog:
    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[int, float], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self.events: List[Dict] = []
        self._timer: Optional[threading.Timer] = None
        self._t0 = 0.0
        self._step = -1

    def _fire(self):
        elapsed = time.monotonic() - self._t0
        self.events.append({"step": self._step, "elapsed_s": elapsed})
        if self.on_timeout is not None:
            self.on_timeout(self._step, elapsed)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        # cancel even on exception exit — an armed timer surviving a
        # crashed step would fire a bogus straggler event for a step
        # that never completed, and keep a thread alive past teardown
        self.close()
        return False

    def arm(self, step: int):
        self.cancel()
        self._step = step
        self._t0 = time.monotonic()
        self._timer = threading.Timer(self.timeout_s, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def cancel(self) -> Optional[threading.Timer]:
        t, self._timer = self._timer, None
        if t is not None:
            t.cancel()
        return t

    def close(self, timeout_s: float = 1.0):
        """Cancel and JOIN the timer thread so no ``_fire`` callback can
        run after the owner is torn down (cancel() alone races a timer
        that already started firing)."""
        t = self.cancel()
        if (t is not None and t.is_alive()
                and t is not threading.current_thread()):
            t.join(timeout=timeout_s)


class FailureInjector:
    """Deterministic fault schedule: {step: kind} with kind in
    {"crash", "nan", "slow"}."""

    def __init__(self, schedule: Optional[Dict[int, str]] = None):
        self.schedule = dict(schedule or {})
        self.fired: List[int] = []

    def maybe_fail(self, step: int):
        kind = self.schedule.get(step)
        if kind is None or step in self.fired:
            return None
        self.fired.append(step)
        if kind == "crash":
            raise RuntimeError(f"injected crash at step {step}")
        if kind == "slow":
            time.sleep(0.2)
        return kind
