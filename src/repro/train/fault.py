"""Fault tolerance primitives: straggler watchdog + failure injection.

At thousand-node scale the dominant events are (a) slow hosts
(stragglers), (b) dead hosts (restart), (c) flaky steps (NaN/timeout).
The Trainer composes:

  * :class:`StepWatchdog` — wall-clock alarm around each step; on
    expiry it records a straggler event and invokes a callback
    (production: mark host suspect, pre-empt its shard; here: logged and
    surfaced in metrics so tests can assert on it).
  * :class:`FailureInjector` — deterministic fault schedule for tests/
    examples (raise at step k, NaN at step m), proving the
    checkpoint-restart path end to end.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional


class StepWatchdog:
    def __init__(self, timeout_s: float,
                 on_timeout: Optional[Callable[[int, float], None]] = None):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self.events: List[Dict] = []
        self._timer: Optional[threading.Timer] = None
        self._t0 = 0.0
        self._step = -1

    def _fire(self):
        elapsed = time.monotonic() - self._t0
        self.events.append({"step": self._step, "elapsed_s": elapsed})
        if self.on_timeout is not None:
            self.on_timeout(self._step, elapsed)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.cancel()
        return False

    def arm(self, step: int):
        self.cancel()
        self._step = step
        self._t0 = time.monotonic()
        self._timer = threading.Timer(self.timeout_s, self._fire)
        self._timer.daemon = True
        self._timer.start()

    def cancel(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None


class FailureInjector:
    """Deterministic fault schedule: {step: kind} with kind in
    {"crash", "nan", "slow"}."""

    def __init__(self, schedule: Optional[Dict[int, str]] = None):
        self.schedule = dict(schedule or {})
        self.fired: List[int] = []

    def maybe_fail(self, step: int):
        kind = self.schedule.get(step)
        if kind is None or step in self.fired:
            return None
        self.fired.append(step)
        if kind == "crash":
            raise RuntimeError(f"injected crash at step {step}")
        if kind == "slow":
            time.sleep(0.2)
        return kind
