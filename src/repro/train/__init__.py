from .checkpoint import CheckpointManager
from .fault import StepWatchdog, FailureInjector
from .train_loop import Trainer, TrainConfig

__all__ = ["CheckpointManager", "StepWatchdog", "FailureInjector",
           "Trainer", "TrainConfig"]
