"""Sharded, atomic, async checkpointing (no orbax dependency).

Layout: ``<dir>/step_<k>/`` with one ``.npy`` per pytree leaf (path-
encoded filename) + ``manifest.json`` (tree structure, shapes, dtypes,
step, data-pipeline cursor).  Writes go to ``step_<k>.tmp`` and are
``os.rename``d only after fsync — a torn write can never shadow the
latest good checkpoint.  ``save_async`` runs in a daemon thread
(double-buffered: at most one in flight — backpressure instead of
unbounded queueing).

Restore is mesh-agnostic: leaves are loaded on host then ``device_put``
against the *target* shardings, so a checkpoint taken on (16,16) resumes
on (2,16,16) or any elastic mesh (see elastic.py).  On a real multi-host
cluster each host writes only the shards it owns (addressable_shards);
on this single-host container that degenerates to full arrays.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _leafname(path) -> str:
    out = []
    for p in path:
        key = getattr(p, "key", None)
        if key is None:
            key = getattr(p, "idx", None)
        if key is None:
            key = getattr(p, "name", str(p))
        out.append(str(key))
    return "__".join(out) or "root"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None):
        leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        names = []
        dtypes = []
        for path, leaf in leaves:
            name = _leafname(path)
            names.append(name)
            arr = np.asarray(jax.device_get(leaf))
            dtypes.append(str(arr.dtype))
            with open(os.path.join(tmp, name + ".npy"), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
        manifest = {
            "step": step,
            "leaves": names,
            "dtypes": dtypes,
            "treedef": jax.tree_util.tree_structure(state).serialize_using_proto().hex(),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, state: Any, extra: Optional[Dict] = None):
        """Backpressured async save: waits for any in-flight save first."""
        self.wait()
        state = jax.tree.map(jax.device_get, state)  # snapshot now
        self._thread = threading.Thread(
            target=self.save, args=(step, state, extra), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    steps.append(int(d.split("_")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    def restore(self, step: Optional[int] = None, shardings: Any = None,
                template: Any = None):
        """Returns (state, extra).  ``shardings``: target tree (elastic
        re-mesh supported); ``template``: tree to unflatten against when
        the serialized treedef is unavailable."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = []
        dtypes = manifest.get("dtypes", [None] * len(manifest["leaves"]))
        for n, dt in zip(manifest["leaves"], dtypes):
            arr = np.load(os.path.join(d, n + ".npy"))
            if arr.dtype.kind == "V" and dt is not None:
                # bf16/f8 round-trip: npy stores raw void bytes
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, dt)))
            arrays.append(arr)
        if template is not None:
            treedef = jax.tree_util.tree_structure(template)
        else:
            treedef = jax.tree_util.tree_structure_from_proto_bytes(
                bytes.fromhex(manifest["treedef"]))  # pragma: no cover
        state = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        return state, manifest["extra"]

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d))
