"""Elastic re-meshing: resume a checkpoint on a different device count.

At scale, a failed pod returns with fewer healthy hosts; training must
continue on the survivors.  Because checkpoints are host-format arrays
and shardings are derived (not stored), elasticity is just:

    mesh' = make_mesh_for(len(jax.devices()), model_parallel)
    shardings' = param_shardings(logical_axes, mesh', fsdp)
    state = ckpt.restore(shardings=shardings')

This module packages that and re-validates divisibility (batch may need
to shrink; the caller owns the batch policy).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from ..dist import param_shardings
from ..launch.mesh import make_mesh_for
from .checkpoint import CheckpointManager


def elastic_restore(model, ckpt_dir: str, *, model_parallel: int = 1,
                    n_devices: Optional[int] = None,
                    template: Any = None) -> Tuple[Any, Any, Any]:
    """Returns (state, mesh, extra) resharded onto the surviving devices."""
    n = n_devices or len(jax.devices())
    mesh = make_mesh_for(n, model_parallel)
    pshard = param_shardings(model.logical_axes(), mesh,
                             fsdp=model.cfg.fsdp,
                             abstract_tree=model.abstract_params())
    mgr = CheckpointManager(ckpt_dir)
    if template is None:
        raise ValueError("elastic_restore needs a state template")
    # reshard only the params subtree; opt state follows its own tree
    state, extra = mgr.restore(template=template)
    state["params"] = jax.tree.map(
        lambda a, s: jax.device_put(a, s), state["params"], pshard)
    return state, mesh, extra
