"""Fault injection + online invariant auditing for the serving stack.

``faults.py`` generalizes the trainer's ``train/fault.py``
(``FailureInjector``/``StepWatchdog``, step-keyed) to SITE-keyed
deterministic schedules usable anywhere in the serving path — ingest
aborts, slow flushes, torn WAL tails, mid-publish crashes — plus the
``InvariantAuditor`` that proves ``slot + chain == n``, epoch
monotonicity, and snapshot pin refcounts after every ingest in tests
(sampled in serving via ``EpochPipeline(audit_every=...)``).
"""

from .faults import (
    FaultInjector,
    InjectedCrash,
    InjectedFault,
    InvariantAuditor,
    tear_tail,
)

__all__ = ["FaultInjector", "InjectedCrash", "InjectedFault",
           "InvariantAuditor", "tear_tail"]
