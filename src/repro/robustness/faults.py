"""Deterministic fault-injection harness + online invariant auditor.

``FaultInjector`` generalizes ``train.fault.FailureInjector`` from
step-keyed trainer schedules to (site, occurrence)-keyed schedules over
the whole serving stack.  Components expose named *sites* — the queue
checks ``"ingest"``/``"flush"``, the pipeline ``"pipeline.ingest"`` /
``"pipeline.publish"`` — and the schedule decides deterministically
which occurrence of which site fails, and how:

* ``"crash"`` — raise ``InjectedCrash`` (the tests' stand-in for
  process death: kill-and-restart recovery tests catch it, then
  recover from snapshot + WAL and prove bit-identity);
* ``"abort"`` — raise ``InjectedFault`` (a transient failure the
  admission-control retry loop is expected to absorb);
* ``"slow"``  — sleep ``slow_s`` (deadline/watchdog exercise);
* ``"torn_tail"`` — truncate the registered WAL file by
  ``torn_bytes`` (torn-write simulation at an arbitrary byte cut).

Schedules are exact and replayable: ``{(site, i): kind}`` fires on the
i-th check of ``site`` (0-based) and ``fired`` records what actually
triggered, so a test can assert the exact fault sequence.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["InjectedFault", "InjectedCrash", "FaultInjector",
           "tear_tail", "InvariantAuditor"]


class InjectedFault(RuntimeError):
    """A schedule-injected transient failure (retryable)."""


class InjectedCrash(InjectedFault):
    """A schedule-injected crash — the in-process stand-in for process
    death.  Retry loops must NOT absorb it (propagated through
    ``MicroBatchQueue``'s retry machinery), so a test catches it at the
    top, drops the live object, and exercises recovery."""


def tear_tail(path, nbytes: int) -> int:
    """Truncate ``nbytes`` off the end of ``path`` (torn-write
    simulation at an arbitrary, not record-aligned, cut).  Returns the
    resulting file size."""
    size = os.path.getsize(path)
    new = max(0, size - int(nbytes))
    with open(path, "r+b") as f:
        f.truncate(new)
    return new


class FaultInjector:
    """Deterministic (site, occurrence)-keyed fault schedule.

    >>> inj = FaultInjector({("ingest", 0): "abort",
    ...                      ("pipeline.publish", 2): "crash"})

    ``check(site)`` counts the call as one occurrence of ``site`` and
    fires the scheduled kind, if any (see module doc for kinds).  For
    ``"torn_tail"`` a WAL path must be registered (``wal_path=`` or
    ``register_wal``).

    Thread-safe: sites are checked from the caller thread and the
    queue's deadline-timer thread concurrently; occurrence counting
    stays exact under ``_lock`` (the slow-sleep itself runs unlocked —
    a fault must not serialize the stack it is perturbing)."""

    def __init__(self, schedule: Dict[Tuple[str, int], str], *,
                 slow_s: float = 0.05, torn_bytes: int = 1,
                 wal_path: Optional[str] = None):
        self.schedule = dict(schedule)
        self.slow_s = float(slow_s)
        self.torn_bytes = int(torn_bytes)
        self.wal_path = wal_path
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, int, str]] = []  #: guarded-by: _lock
        self._counts: Dict[str, int] = {}            #: guarded-by: _lock

    def register_wal(self, path) -> None:
        self.wal_path = str(path)

    def check(self, site: str) -> Optional[str]:
        with self._lock:
            i = self._counts.get(site, 0)
            self._counts[site] = i + 1
            kind = self.schedule.get((site, i))
            if kind is not None:
                self.fired.append((site, i, kind))
        if kind is None:
            return None
        if kind == "crash":
            raise InjectedCrash(f"injected crash at {site}#{i}")
        if kind == "abort":
            raise InjectedFault(f"injected abort at {site}#{i}")
        if kind == "slow":
            time.sleep(self.slow_s)
            return "slow"
        if kind == "torn_tail":
            if self.wal_path is None:
                raise ValueError("torn_tail fault needs a registered "
                                 "WAL path")
            tear_tail(self.wal_path, self.torn_bytes)
            return "torn_tail"
        raise ValueError(f"unknown fault kind {kind!r} at {site}#{i}")


class InvariantAuditor:
    """Online structural-invariant checks over ``Index`` /
    ``ShardedIndex`` (and optionally the serving pipeline's snapshot
    refcounts).  ``audit`` returns the violations found (and
    accumulates them); ``assert_ok`` raises on any.

    Checks per gapped array:
    * **slot + chain == n**: occupied first-level slots plus CSR chain
      entries must equal ``n_keys`` exactly;
    * CSR offsets monotone nondecreasing, final offset == chain total;
    * carried-key total order: ``slot_key`` nondecreasing;
    * pin refcount nonnegative.

    Plus epoch monotonicity per audited object (keyed by identity) and,
    when a pipeline is passed, served-epoch <= live-epoch and a live
    pin backing the served snapshot."""

    def __init__(self):
        self._lock = threading.Lock()
        self.checks = 0                        #: guarded-by: _lock
        self.violations: List[str] = []        #: guarded-by: _lock
        self._last_epoch: Dict[int, int] = {}  #: guarded-by: _lock

    # ------------------------------------------------------------------
    def _audit_gapped(self, label: str, ga) -> List[str]:
        v = []
        n_slot = int(np.count_nonzero(np.asarray(ga.occupied, bool)))
        n_chain = int(ga.links.total)
        if n_slot + n_chain != int(ga.n_keys):
            v.append(f"{label}: slot({n_slot}) + chain({n_chain}) != "
                     f"n_keys({ga.n_keys})")
        offsets, lkeys, _ = ga.export_csr_links()
        if np.any(np.diff(offsets) < 0):
            v.append(f"{label}: CSR offsets not monotone")
        if int(offsets[-1]) != n_chain:
            v.append(f"{label}: CSR offsets[-1]={int(offsets[-1])} != "
                     f"chain total {n_chain}")
        sk = np.asarray(ga.slot_key, np.float64)
        finite = sk[np.isfinite(sk)]
        if finite.size and np.any(np.diff(finite) < 0):
            v.append(f"{label}: slot_key total order violated")
        pins = getattr(ga, "_pins", None)
        if pins is not None and pins.count < 0:
            v.append(f"{label}: negative snapshot pin count "
                     f"({pins.count})")
        return v

    def audit(self, index, pipeline=None) -> List[str]:
        v: List[str] = []
        if hasattr(index, "shards"):
            for i, sh in enumerate(index.shards):
                v += self._audit_gapped(f"shard[{i}]", sh.gapped)
        elif getattr(index, "gapped", None) is not None:
            v += self._audit_gapped("index", index.gapped)
        epoch = int(index.epoch)
        with self._lock:
            last = self._last_epoch.get(id(index))
            if last is not None and epoch < last:
                v.append(f"epoch went backwards: {last} -> {epoch}")
            self._last_epoch[id(index)] = epoch
        if pipeline is not None:
            if pipeline.epoch > epoch:
                v.append(f"served epoch {pipeline.epoch} ahead of live "
                         f"epoch {epoch}")
            snap = pipeline._snapshot
            snaps = getattr(snap, "_snaps", None)
            for g in (snaps if snaps is not None else [snap._snap]):
                if not g.pinned:
                    v.append("served snapshot lost its pin while "
                             "installed")
        with self._lock:
            self.checks += 1
            self.violations += v
        return v

    def assert_ok(self, index, pipeline=None) -> None:
        v = self.audit(index, pipeline=pipeline)
        if v:
            raise AssertionError("invariant violations: " + "; ".join(v))
