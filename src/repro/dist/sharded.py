"""Range-partitioned ``ShardedIndex``: a learned router over learned
indexes (the two-stage decomposition of "A Scalable Learned Index
Scheme in Storage Systems", at shard granularity).

Architecture
------------
* **Shards** are full ``repro.core.Index`` handles over disjoint key
  ranges; shard ``s`` owns ``[first_key[s], first_key[s+1])`` (the last
  shard is right-open to +inf, the first left-open to -inf).  Keys that
  arrive BETWEEN shards route LEFT, to the predecessor's shard, for
  both lookups and ingest — so the routing boundaries never drift and a
  lookup always lands where the matching ingest landed.
* **Router** (``ShardRouter``): the paper's RMI idea at shard
  granularity — a two-segment linear model fit on the shard boundary
  keys predicts the shard id in one multiply-add per query, and an
  exact ``searchsorted`` backstop certifies it.  Routing is therefore
  EXACT by construction; the model only determines how often the
  backstop is a gather (hit) vs a bisect (mispredict, counted).
* **Fused fan-out** (``kernels.shard_fanout.ShardFanout``): the
  per-shard frozen images are stacked, placed over the device mesh via
  ``repro.dist.partitioning`` + ``launch.mesh``, and a single
  ``shard_map`` graph serves a whole batch: route -> bucket-count ->
  all-to-all exchange -> per-shard fused search -> inverse-permutation
  gather.  Built lazily and tagged with the shard epochs; any shard
  mutation makes it stale and the next large lookup rebuilds it.
* **Ingest** is shard-local: the exact host route groups the batch, and
  every shard runs its OWN ``Index.ingest`` — on engines with the fused
  write graph enabled that is the PR-6 single-dispatch path, and an
  in-graph abort falls back to that shard's host partition only.  The
  per-shard ``IngestReport``s aggregate into a ``ShardedIngestReport``
  (sums preserve the ``slot + chain == n`` invariant).
* **Rebalance**: when skewed writes pile onto one shard past the
  occupancy watermark (``split_occupancy_factor`` x mean keys, floored
  by ``min_split_keys``) or its chains exceed ``split_chain_depth``,
  ``split_shard`` extracts the live (key, payload) set from the gapped
  array + CSR chains, rebuilds two gap-inserted halves around the
  median occupied key, splices them into the shard list, and patches
  the router with the new boundary.

Result contract: ``lookup`` returns the same typed ``LookupResult``
with payloads/found BIT-IDENTICAL to a single-device ``Index`` built
over the same key/payload set (both key widths; proved in
tests/test_sharded_index.py).  Slots are physical and the sharded
physical layout legitimately differs; they come back offset by the
per-shard slot base so they remain unique and monotone per shard.

``ShardedIndex`` is duck-type compatible with the single ``Index``
handle where it matters: ``lookup(queries)`` / ``ingest(keys,
payloads)`` / ``epoch`` / ``stats`` — so ``serving.MicroBatchQueue``
aggregates over a sharded backend unchanged.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from ..core import sampling as _sampling
from ..core.handle import Index
from ..core.results import IngestReport, LookupResult

__all__ = ["ShardRouter", "ShardedIndex", "ShardedIngestReport"]


@dataclasses.dataclass(frozen=True)
class ShardedIngestReport(IngestReport):
    """Aggregate of the per-shard reports (device="sharded").  The
    scalar counters are sums — ``slot + chain == n`` and the contested
    bound survive summation — and ``per_shard`` keeps the individual
    ``(shard_id, IngestReport)`` pairs for telemetry."""

    per_shard: tuple = ()


class ShardRouter:
    """Two-segment linear-on-boundaries learned router with an exact
    backstop.  ``bounds`` are the S-1 internal boundaries (first key of
    shards 1..S-1); ``route`` is EXACT (searchsorted authority), the
    learned prediction is raced against it only to count mispredicts —
    the device graph uses the same model with an in-graph exact bisect
    backstop (``kernels.shard_fanout._route_block``)."""

    def __init__(self, bounds: np.ndarray,
                 lo_key: Optional[float] = None):
        self.bounds = np.asarray(bounds, np.float64).copy()
        if self.bounds.size and not np.all(np.diff(self.bounds) > 0):
            raise ValueError("shard boundaries must be strictly increasing")
        # global min key anchors (lo_key -> shard 0) so queries inside
        # shard 0 interpolate instead of rounding up to the first
        # boundary's anchor (without it the fit has no point below y=1)
        self.lo_key = None if lo_key is None else float(lo_key)
        self.stats = {"routed": 0, "mispredicted": 0}
        self._fit()

    @property
    def n_shards(self) -> int:
        return int(self.bounds.shape[0]) + 1

    # ------------------------------------------------------------------
    def _fit(self) -> None:
        b = self.bounds
        if b.size == 0:
            self._params = (0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
            return
        # anchor boundary b_i at y = (i+1) - 0.5: shard j's key range
        # then maps to (j - 0.5, j + 0.5) and rint() recovers j across
        # the WHOLE range, not just its left half (the lo_key anchor is
        # shard 0's left edge, y = -0.5)
        anchors = b
        ys = np.arange(1, b.shape[0] + 1, dtype=np.float64) - 0.5
        if self.lo_key is not None and self.lo_key < b[0]:
            anchors = np.concatenate([[self.lo_key], b])
            ys = np.concatenate([[-0.5], ys])
        x0 = float(anchors[0])
        split = float(anchors[anchors.shape[0] // 2])
        xs = anchors - x0
        hi = anchors >= split

        def seg(x: np.ndarray, y: np.ndarray, empty_icept: float):
            if x.size == 0:
                return 0.0, empty_icept
            if x.size == 1:
                # an anchor sits on a shard's LEFT edge (y = j - 0.5);
                # nudge into the shard interior, else rint's round-half-
                # to-even sends every key at/above it one shard low
                return 0.0, float(y[0]) + 0.25
            a = np.vstack([x, np.ones_like(x)]).T
            slope, icept = np.linalg.lstsq(a, y, rcond=None)[0]
            if not (slope >= 0.0) or not np.isfinite(icept):
                return 0.0, float(np.mean(y))
            return float(slope), float(icept)

        s0, i0 = seg(xs[~hi], ys[~hi], 0.0)
        s1, i1 = seg(xs[hi], ys[hi], float(ys[-1]))
        self._params = (x0, s0, i0, s1, i1, split)

    def predict(self, q: np.ndarray) -> np.ndarray:
        """Learned shard-id prediction (clipped round) — NOT exact; use
        ``route`` for answers."""
        x0, s0, i0, s1, i1, split = self._params
        q = np.asarray(q, np.float64)
        x = q - x0
        pred = np.where(q >= split, x * s1 + i1, x * s0 + i0)
        return np.clip(np.rint(pred), 0, self.n_shards - 1).astype(np.int64)

    def route(self, q: np.ndarray) -> np.ndarray:
        """Exact f64 shard id per query (route-left semantics: a key
        between shards belongs to its predecessor's shard)."""
        q = np.asarray(q, np.float64)
        self.stats["routed"] += int(q.shape[0])
        if self.bounds.size == 0:
            return np.zeros(q.shape[0], np.int64)
        exact = np.searchsorted(self.bounds, q, side="right").astype(np.int64)
        self.stats["mispredicted"] += int(
            np.count_nonzero(self.predict(q) != exact))
        return exact

    def insert_boundary(self, pos: int, key: float) -> None:
        """Patch in the boundary of a split: shard ``pos`` became
        ``pos`` (left half) and ``pos + 1`` (right half, first key
        ``key``).  Refits the model on the new boundary set."""
        self.bounds = np.insert(self.bounds, pos, float(key))
        if not np.all(np.diff(self.bounds) > 0):  # pragma: no cover
            raise ValueError("split boundary breaks the shard ordering")
        self._fit()

    def device_params(self) -> np.ndarray:
        """The f32 octet the in-graph router consumes: [x0_hi, x0_lo,
        slope0, icept0, slope1, icept1, split_hi, split_lo]."""
        from ..kernels import ops as _ops
        x0, s0, i0, s1, i1, split = self._params
        hi, lo = _ops.split_key_pair(np.array([x0, split], np.float64))
        return np.array([hi[0], lo[0], s0, i0, s1, i1, hi[1], lo[1]],
                        np.float32)


class ShardedIndex:
    """Range-partitioned learned index (see module doc)."""

    def __init__(self, shards: List[Index], router: ShardRouter, *,
                 method: str = "pgm", sample_rate: float = 1.0,
                 gap_rho: float = 0.1, mech_kwargs: Optional[dict] = None,
                 split_occupancy_factor: float = 4.0,
                 min_split_keys: int = 4096, split_chain_depth: int = 24,
                 min_device_batch: int = 512):
        if len(shards) != router.n_shards:
            raise ValueError(
                f"{len(shards)} shards vs router for {router.n_shards}")
        self.shards = list(shards)
        self.router = router
        self.method = method
        self.sample_rate = sample_rate
        self.gap_rho = gap_rho
        self.mech_kwargs = dict(mech_kwargs or {})
        self.split_occupancy_factor = float(split_occupancy_factor)
        self.min_split_keys = int(min_split_keys)
        self.split_chain_depth = int(split_chain_depth)
        self.min_device_batch = int(min_device_batch)
        self._mutations = 0
        self._fan = None
        self._fan_failed_tag: Optional[tuple] = None
        self.stats = {"lookups": 0, "ingests": 0, "splits": 0,
                      "retrains": 0, "fanout_lookups": 0,
                      "grouped_lookups": 0, "rebalance_seconds": 0.0}

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, keys: np.ndarray, *, shards: int, method: str = "pgm",
              sample_rate: float = 1.0, gap_rho: float = 0.1,
              rng: Optional[np.random.Generator] = None,
              payloads: Optional[np.ndarray] = None,
              min_device_batch: int = 512,
              fused_ingest_enabled: Optional[bool] = None,
              **mech_kwargs) -> "ShardedIndex":
        """Equal-count range partition + per-shard gap-inserted builds.

        Payloads default to the GLOBAL key position (``arange(n)``
        sliced per shard), exactly what a single-device ``Index.build``
        stores — this is what makes the bit-identity contract hold.
        ``gap_rho`` must be positive: shards serve the dynamic gapped
        path (a static sharded build has nothing to rebalance).

        Each shard builds with its OWN child generator spawned from
        ``rng`` (``core.sampling.spawn_rngs``), so sampled per-shard
        builds draw independent streams — one shared generator would
        sample every shard identically.  ``method="auto"`` runs the
        MDL auto-tuner PER SHARD (each shard's key distribution picks
        its own mechanism/budget — ``core.tuning``).
        """
        keys = np.asarray(keys, np.float64)
        s = int(shards)
        if keys.ndim != 1:
            raise ValueError("need a 1-D key array")
        if s < 1:
            raise ValueError("shards must be >= 1")
        if gap_rho <= 0.0:
            raise ValueError("ShardedIndex requires gap insertion "
                             "(gap_rho > 0)")
        n = keys.shape[0]
        if n < 2 * s:
            raise ValueError(f"{n} keys cannot fill {s} shards "
                             "(need >= 2 per shard)")
        if not bool(np.all(np.diff(keys) > 0)):
            raise ValueError("keys must be sorted, strictly increasing")
        if payloads is None:
            payloads = np.arange(n, dtype=np.int64)
        else:
            payloads = np.asarray(payloads, np.int64)
            if payloads.shape != keys.shape:
                raise ValueError("payloads must match keys 1:1")
        cuts = np.round(np.linspace(0, n, s + 1)).astype(np.int64)
        handles = []
        shard_rngs = _sampling.spawn_rngs(rng, s)
        for (a, b), srng in zip(zip(cuts[:-1], cuts[1:]), shard_rngs):
            sh = Index.build(keys[a:b], method=method,
                             sample_rate=sample_rate, gap_rho=gap_rho,
                             rng=srng, payloads=payloads[a:b],
                             **mech_kwargs)
            sh.min_device_batch = min_device_batch
            sh.fused_ingest_enabled = fused_ingest_enabled
            handles.append(sh)
        router = ShardRouter(keys[cuts[1:-1]], lo_key=keys[0])
        return cls(handles, router, method=method, sample_rate=sample_rate,
                   gap_rho=gap_rho, mech_kwargs=mech_kwargs,
                   min_device_batch=min_device_batch)

    # ------------------------------------------------------------------
    @property
    def epoch(self) -> int:
        """Monotone sharded-state version: total shard mutations plus
        topology changes (splits count through ``_mutations``)."""
        return int(sum(sh.epoch for sh in self.shards)) + self._mutations

    @property
    def n_keys(self) -> int:
        return int(sum(sh.gapped.n_keys for sh in self.shards))

    def _slot_bases(self) -> np.ndarray:
        sizes = np.array([sh.gapped.n_slots for sh in self.shards],
                         np.int64)
        return np.concatenate([[0], np.cumsum(sizes)[:-1]])

    # ------------------------------------------------------------------
    # fused fan-out (lazy, epoch-tagged)
    # ------------------------------------------------------------------
    def _fanout(self):
        tag = tuple(sh.epoch for sh in self.shards)
        if self._fan is not None and self._fan.epochs == tag:
            return self._fan
        if self._fan_failed_tag == tag:
            return None
        from ..kernels.shard_fanout import FanoutUnavailable, ShardFanout
        boosts = dict(self._fan._cap_boost) if self._fan is not None else {}
        try:
            fan = ShardFanout.build(self.shards, self.router.bounds,
                                    self.router.device_params(),
                                    min_bucket=self.min_device_batch)
        except FanoutUnavailable:
            self._fan = None
            self._fan_failed_tag = tag
            return None
        fan._cap_boost.update(boosts)  # keep the exchange sizing learned
        self._fan = fan                # under previous epochs
        self._fan_failed_tag = None
        return fan

    # ------------------------------------------------------------------
    def lookup(self, queries, *, backend: Optional[str] = None,
               queries_sorted: bool = False) -> LookupResult:
        """Batched lookup.  Large batches (>= ``min_device_batch``) run
        the single fused fan-out dispatch; small batches and explicit
        per-shard backends take the exact host route + grouped per-shard
        lookups.  ``backend="fanout"`` forces the fan-out."""
        queries = np.atleast_1d(np.asarray(queries, np.float64))
        self.stats["lookups"] += 1
        n = queries.shape[0]
        if backend == "fanout" or (
                backend is None and n >= self.min_device_batch):
            fan = self._fanout()
            if fan is not None:
                pay, slot, found, _shard, esc, mis = fan.lookup(queries)
                self.stats["fanout_lookups"] += 1
                self.router.stats["routed"] += n
                self.router.stats["mispredicted"] += mis
                return LookupResult(
                    payloads=pay, slots=slot, found=found,
                    backend="sharded-fanout", epoch=self.epoch,
                    fallbacks=esc)
            if backend == "fanout":
                raise RuntimeError(
                    "shard fan-out unavailable for this shard set "
                    "(non-PLM mechanism or aliasing keys)")
        dst = self.router.route(queries)
        pay = np.full(n, -1, np.int64)
        slot = np.full(n, -1, np.int64)
        found = np.zeros(n, bool)
        fallbacks = 0
        bases = self._slot_bases()
        for s in np.unique(dst):
            rows = np.flatnonzero(dst == s)
            r = self.shards[s].lookup(queries[rows], backend=backend)
            pay[rows] = np.asarray(r.payloads, np.int64)
            sl = np.asarray(r.slots, np.int64)
            slot[rows] = np.where(sl >= 0, sl + bases[s], -1)
            found[rows] = np.asarray(r.found, bool)
            fallbacks += int(r.fallbacks)
        self.stats["grouped_lookups"] += 1
        return LookupResult(payloads=pay, slots=slot, found=found,
                            backend="sharded-host", epoch=self.epoch,
                            fallbacks=fallbacks)

    # ------------------------------------------------------------------
    def ingest(self, keys, payloads) -> ShardedIngestReport:
        """Shard-local batched insert: the exact route groups the batch
        (stable — per-shard relative order is the caller's), every
        touched shard runs its own ``Index.ingest`` (fused single
        dispatch where that shard's engine allows; an abort falls back
        to THAT shard's host partition only), and the reports
        aggregate.  Finishes with the rebalance watermark check."""
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        payloads = np.atleast_1d(np.asarray(payloads, np.int64))
        if keys.shape != payloads.shape:
            raise ValueError("payloads must match keys 1:1")
        t0 = time.perf_counter()
        dst = self.router.route(keys)
        reports = []
        for s in np.unique(dst):
            rows = np.flatnonzero(dst == s)
            reports.append(
                (int(s), self.shards[s].ingest(keys[rows], payloads[rows])))
        self.stats["ingests"] += 1
        self._mutations += 1
        self.maybe_rebalance()
        reps = [r for _, r in reports]
        return ShardedIngestReport(
            n=sum(r.n for r in reps), slot=sum(r.slot for r in reps),
            chain=sum(r.chain for r in reps),
            contested=sum(r.contested for r in reps),
            epoch=self.epoch, device="sharded",
            device_elems=sum(r.device_elems for r in reps),
            seconds=time.perf_counter() - t0, placement="sharded",
            abort_reasons=tuple(
                rr for r in reps for rr in r.abort_reasons),
            fused_aborts=sum(r.fused_aborts for r in reps),
            split_commits=sum(r.split_commits for r in reps),
            per_shard=tuple(reports))

    # ------------------------------------------------------------------
    # durability (serving/wal.py crash recovery rides on these)
    # ------------------------------------------------------------------
    def save_snapshot(self, directory, *, step: Optional[int] = None,
                      keep: int = 3, wal_lsn: int = 0) -> str:
        """Checkpoint every shard through ``Index.save_snapshot`` (one
        ``train/checkpoint.py``-format subdirectory per shard) plus a
        ``sharded_manifest.json`` capturing the router boundaries and
        topology knobs.  The manifest is published last (tmp→rename),
        so a crash mid-save can never shadow a complete checkpoint with
        a partial one."""
        import json
        import os
        s = int(step if step is not None else self.epoch)
        d = str(directory)
        os.makedirs(d, exist_ok=True)
        for i, sh in enumerate(self.shards):
            sh.save_snapshot(os.path.join(d, f"shard_{i:03d}"), step=s,
                             keep=keep)
        manifest = {
            "kind": "sharded",
            "n_shards": len(self.shards),
            "bounds": [float(b) for b in self.router.bounds],
            "lo_key": self.router.lo_key,
            "method": self.method,
            "sample_rate": float(self.sample_rate),
            "gap_rho": float(self.gap_rho),
            "mech_kwargs": self.mech_kwargs,
            "split_occupancy_factor": self.split_occupancy_factor,
            "min_split_keys": self.min_split_keys,
            "split_chain_depth": self.split_chain_depth,
            "min_device_batch": self.min_device_batch,
            "mutations": self._mutations,
            "epoch": int(self.epoch),
            "step": s,
            "wal_lsn": int(wal_lsn),
        }
        tmp = os.path.join(d, "sharded_manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(d, "sharded_manifest.json"))
        return d

    @classmethod
    def restore(cls, directory, step: Optional[int] = None):
        """Load a ``save_snapshot`` checkpoint -> ``(sharded, extra)``.
        Shards restore bit-identically; the router refits on the saved
        boundaries (deterministic), so routing matches the saved
        instance exactly."""
        import json
        import os
        d = str(directory)
        with open(os.path.join(d, "sharded_manifest.json")) as f:
            m = json.load(f)
        s = int(step) if step is not None else int(m["step"])
        shards = []
        for i in range(int(m["n_shards"])):
            sh, _ = Index.restore(os.path.join(d, f"shard_{i:03d}"),
                                  step=s)
            sh.min_device_batch = int(m["min_device_batch"])
            shards.append(sh)
        router = ShardRouter(np.asarray(m["bounds"], np.float64),
                             lo_key=m["lo_key"])
        out = cls(shards, router, method=m["method"],
                  sample_rate=float(m["sample_rate"]),
                  gap_rho=float(m["gap_rho"]),
                  mech_kwargs=m["mech_kwargs"],
                  split_occupancy_factor=float(m["split_occupancy_factor"]),
                  min_split_keys=int(m["min_split_keys"]),
                  split_chain_depth=int(m["split_chain_depth"]),
                  min_device_batch=int(m["min_device_batch"]))
        out._mutations = int(m["mutations"])
        return out, m

    # ------------------------------------------------------------------
    # split / rebalance
    # ------------------------------------------------------------------
    def _split_candidate(self) -> Optional[int]:
        sizes = np.array([sh.gapped.n_keys for sh in self.shards],
                         np.float64)
        mean = float(sizes.mean())
        cand, cand_size = None, -1.0
        for s, sh in enumerate(self.shards):
            ga = sh.gapped
            if ga.n_keys < max(self.min_split_keys, 4):
                continue
            if (ga.n_keys > self.split_occupancy_factor * mean
                    or ga.links.max_chain > self.split_chain_depth):
                if sizes[s] > cand_size:
                    cand, cand_size = s, float(sizes[s])
        return cand

    def _retrain_candidate(self) -> Optional[int]:
        """A shard past the chain-depth watermark that is too SMALL to
        split (below ``min_split_keys``): splitting can't help it, but
        a sampled retrain flattens its chains in O(n_s) learning +
        O(n_shard) placement.  Deepest chain wins."""
        cand, cand_depth = None, -1
        for s, sh in enumerate(self.shards):
            ga = sh.gapped
            if ga.n_keys >= max(self.min_split_keys, 4):
                continue  # big enough to split — the split path owns it
            depth = ga.links.max_chain
            if depth > self.split_chain_depth and depth > cand_depth:
                cand, cand_depth = s, depth
        return cand

    def maybe_rebalance(self,
                        force_shard: Optional[int] = None) -> Optional[dict]:
        """Split the most-overloaded shard if any is past the
        occupancy/chain-depth watermark (or split ``force_shard``
        unconditionally).  When nothing is splittable, a shard past the
        chain-depth watermark but below the split size floor gets a
        sampled RETRAIN instead (same trigger machinery, cheaper
        remedy).  Returns the split/retrain record or None."""
        s = force_shard if force_shard is not None else self._split_candidate()
        if s is not None:
            return self.split_shard(int(s))
        if force_shard is None:
            r = self._retrain_candidate()
            if r is not None:
                return self.retrain(shard=int(r))
        return None

    def retrain(self, shard: Optional[int] = None,
                sample_rate: Optional[float] = None,
                rng: Optional[np.random.Generator] = None) -> dict:
        """Sampled refit of one shard (or every shard when ``shard`` is
        None) via ``Index.retrain`` — independent child generators per
        shard, epoch bumped through ``_mutations`` so pinned
        ``ShardedSnapshot``s stay isolated (shard arrays are replaced,
        never mutated).  Returns an aggregate record."""
        t0 = time.perf_counter()
        ids = list(range(len(self.shards))) if shard is None else [int(shard)]
        rngs = _sampling.spawn_rngs(rng, len(ids))
        recs = []
        for s, srng in zip(ids, rngs):
            recs.append((s, self.shards[s].retrain(
                sample_rate=sample_rate, rng=srng)))
        self._mutations += 1
        dt = time.perf_counter() - t0
        self.stats["retrains"] += 1
        self.stats["rebalance_seconds"] += dt
        return {"kind": "retrain", "shards": [s for s, _ in recs],
                "seconds": dt, "per_shard": recs}

    def split_shard(self, s: int,
                    rng: Optional[np.random.Generator] = None) -> dict:
        """Split shard ``s`` at its median live key: extract the live
        (key, payload) set (``GappedArray.live_items``), rebuild two
        gap-inserted halves with the same mechanism settings (each with
        its own spawned generator), splice them in, and patch the
        router boundary."""
        sh = self.shards[s]
        ga = sh.gapped
        t0 = time.perf_counter()
        k, p = ga.live_items()
        n = k.shape[0]
        if n < 4:
            raise ValueError(f"shard {s} too small to split ({n} keys)")
        mid = n // 2
        halves = []
        half_rngs = _sampling.spawn_rngs(rng, 2)
        for (a, b), hrng in zip(((0, mid), (mid, n)), half_rngs):
            h = Index.build(k[a:b], method=self.method,
                            sample_rate=self.sample_rate,
                            gap_rho=self.gap_rho, payloads=p[a:b],
                            rng=hrng, **self.mech_kwargs)
            h.min_device_batch = sh.min_device_batch
            h.fused_ingest_enabled = sh.fused_ingest_enabled
            halves.append(h)
        self.shards[s: s + 1] = halves
        self.router.insert_boundary(s, float(k[mid]))
        self._mutations += 1
        dt = time.perf_counter() - t0
        self.stats["splits"] += 1
        self.stats["rebalance_seconds"] += dt
        return {"shard": int(s), "boundary": float(k[mid]),
                "n_left": int(mid), "n_right": int(n - mid),
                "seconds": dt}
