"""Rule-based logical-axis -> mesh-axis partitioning (the single place
sharding policy lives; see models/base.py for the logical vocabulary).

Every parameter / activation carries a tuple of logical axis names;
``pspec_for_axes`` maps that tuple onto whatever mesh is alive by three
rules, applied left to right:

1. **Vocabulary**: "vocab"/"heads"/"kv"/"ffn"/"experts" want the "model"
   axis; "batch" wants ("pod", "data"); "embed" wants nothing (or "data"
   under FSDP — ZeRO-3 falls out of the param sharding); everything else
   (including None) is replicated.
2. **Claim once**: each mesh axis is assigned to at most one tensor dim
   (first claimant wins), so e.g. ("experts", "embed", "ffn") shards only
   the expert dim over "model".
3. **Divisibility guard**: a dim is only sharded if its size divides by
   the product of the claimed mesh axis sizes; otherwise it is
   replicated (elastic meshes never produce invalid shardings).

``param_pspecs`` / ``param_shardings`` lift the rule over a whole
logical-axes tree; ``input_shardings`` shard batch dims of input specs;
``activation_constrainer`` closes over a mesh and returns the
``constrain(x, axes)`` function threaded through every model forward.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as PS

__all__ = [
    "pspec_for_axes",
    "param_pspecs",
    "param_shardings",
    "input_shardings",
    "activation_constrainer",
]

# logical axis -> preferred mesh axes, in priority order
_RULES = {
    "vocab": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "batch": ("pod", "data"),
}
_FSDP_RULES = {"embed": ("data",)}


def _mesh_sizes(mesh) -> dict:
    """axis name -> size; works for jax.sharding.Mesh and duck-typed
    stand-ins exposing ``axis_names`` + ``devices.shape`` (tests)."""
    return dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))


def pspec_for_axes(
    axes: Tuple[Optional[str], ...],
    mesh,
    *,
    fsdp: bool = False,
    shape: Optional[Tuple[int, ...]] = None,
    seq_axis: Optional[str] = None,
) -> PS:
    """Map one logical-axes tuple to a PartitionSpec under ``mesh``.

    ``shape`` (optional) enables the divisibility guard per dim.
    ``seq_axis`` names a mesh axis for sequence parallelism: a None
    logical entry directly after "batch" is sharded over it.
    """
    sizes = _mesh_sizes(mesh)
    claimed = set()
    out = []
    for d, name in enumerate(axes):
        want = _RULES.get(name, ())
        if fsdp and not want:
            want = _FSDP_RULES.get(name, ())
        if (name is None and seq_axis is not None and d > 0
                and axes[d - 1] == "batch"):
            want = (seq_axis,)
        picked = tuple(
            a for a in want if a in sizes and a not in claimed
        )
        if picked and shape is not None:
            total = 1
            for a in picked:
                total *= sizes[a]
            if total > 1 and shape[d] % total:
                picked = ()
        claimed.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(picked)
    return PS(*out)


def _map_axes_tree(laxes_tree, fn):
    """tree-map over a logical-axes tree whose leaves are tuples."""
    return jax.tree.map(
        fn, laxes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def param_pspecs(laxes_tree, mesh, *, fsdp: bool = False,
                 abstract_tree=None):
    """Tree of PartitionSpecs mirroring a logical-axes tree.

    ``abstract_tree`` (ShapeDtypeStructs, same structure) turns on the
    divisibility guard.
    """
    if abstract_tree is None:
        return _map_axes_tree(
            laxes_tree, lambda ax: pspec_for_axes(ax, mesh, fsdp=fsdp)
        )
    return jax.tree.map(
        lambda ax, sds: pspec_for_axes(ax, mesh, fsdp=fsdp,
                                       shape=tuple(sds.shape)),
        laxes_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def param_shardings(laxes_tree, mesh, *, fsdp: bool = False,
                    abstract_tree=None):
    """Like param_pspecs but wrapped into device-placeable NamedShardings."""
    specs = param_pspecs(laxes_tree, mesh, fsdp=fsdp,
                         abstract_tree=abstract_tree)
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), specs,
        is_leaf=lambda x: isinstance(x, PS),
    )


def input_shardings(abstract_inputs, mesh):
    """Batch-shard input specs: dim 0 over ("pod","data") when divisible,
    everything else replicated."""
    def one(sds):
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        ps = pspec_for_axes(axes, mesh, shape=tuple(sds.shape))
        return NamedSharding(mesh, ps)

    return jax.tree.map(one, abstract_inputs)


def activation_constrainer(mesh, *, fsdp: bool = False,
                           seq_axis: Optional[str] = None):
    """Returns ``constrain(x, logical_axes)`` for use inside jit.

    The constraint is derived per call from the *static* activation shape,
    so the divisibility guard composes with elastic meshes for free.
    """
    def constrain(x, axes):
        ps = pspec_for_axes(tuple(axes), mesh, fsdp=fsdp,
                            shape=tuple(x.shape), seq_axis=seq_axis)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, ps)
        )

    return constrain
