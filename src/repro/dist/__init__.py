# Sharding policy: logical axis names -> mesh PartitionSpecs.
# partitioning.py is the only module that spells a mesh axis name.
#
# sharded.py (range-partitioned ShardedIndex + learned ShardRouter) is
# re-exported LAZILY: it pulls in core/kernels, and eager import here
# would cycle through repro.core -> repro.kernels -> repro.dist.

from .partitioning import (
    activation_constrainer,
    input_shardings,
    param_pspecs,
    param_shardings,
    pspec_for_axes,
)

__all__ = [
    "activation_constrainer",
    "input_shardings",
    "param_pspecs",
    "param_shardings",
    "pspec_for_axes",
    "ShardRouter",
    "ShardedIndex",
    "ShardedIngestReport",
]

_LAZY = ("ShardRouter", "ShardedIndex", "ShardedIngestReport")


def __getattr__(name):
    if name in _LAZY:
        from . import sharded as _sharded
        return getattr(_sharded, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
