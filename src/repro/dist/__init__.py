# Sharding policy: logical axis names -> mesh PartitionSpecs.
# partitioning.py is the only module that spells a mesh axis name.

from .partitioning import (
    activation_constrainer,
    input_shardings,
    param_pspecs,
    param_shardings,
    pspec_for_axes,
)

__all__ = [
    "activation_constrainer",
    "input_shardings",
    "param_pspecs",
    "param_shardings",
    "pspec_for_axes",
]
