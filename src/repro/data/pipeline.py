"""Deterministic, seekable, host-sharded batch pipeline.

Determinism + seekability are the fault-tolerance substrate: a restart at
step k replays the exact key schedule (seeded permutation of sample
keys, re-seeded per epoch) and O(1)-seeks to k — no data loss or dup.
Each data-parallel host takes a strided shard of every global batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from .indexed_dataset import IndexedTokenDataset


@dataclasses.dataclass
class ShardedLoader:
    dataset: IndexedTokenDataset
    global_batch: int
    seq_len: int
    seed: int = 0
    shard_id: int = 0
    n_shards: int = 1
    step: int = 0

    def __post_init__(self):
        if self.global_batch % self.n_shards:
            raise ValueError("global_batch must divide evenly across shards")
        self._epoch = -1
        self._perm = None

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.n_shards

    @property
    def steps_per_epoch(self) -> int:
        return max(1, self.dataset.store.n_docs // self.global_batch)

    def _ensure_epoch(self, epoch: int):
        if epoch != self._epoch:
            rng = np.random.default_rng((self.seed, epoch))
            self._perm = rng.permutation(self.dataset.store.n_docs)
            self._epoch = epoch

    def seek(self, step: int) -> None:
        """O(1) restart-resume: jump the schedule to ``step``."""
        self.step = step

    def next_batch(self) -> Dict[str, np.ndarray]:
        epoch = self.step // self.steps_per_epoch
        self._ensure_epoch(epoch)
        pos = (self.step % self.steps_per_epoch) * self.global_batch
        sel = self._perm[pos : pos + self.global_batch]
        if len(sel) < self.global_batch:  # wrap the tail deterministically
            sel = np.concatenate([sel, self._perm[: self.global_batch - len(sel)]])
        sel = sel[self.shard_id :: self.n_shards]
        keys = self.dataset.store.sample_keys[sel].astype(np.float64)
        toks = self.dataset.batch(keys, self.seq_len + 1)
        self.step += 1
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": (toks[:, 1:] != 0).astype(np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
