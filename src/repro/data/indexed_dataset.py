"""Learned-index-backed sample lookup — the paper as a data-plane feature.

A training job addresses samples by *key* (content hash / global shuffle
id), not ordinal: restarts, online mixing, and streamed ingestion all
need key -> storage-position resolution.  Classically that's a B-tree or
a hash map per worker; here it is the paper's pluggable learned index
behind the epoch-versioned ``repro.core.Index`` handle:

 * build: PGM/FITing/RMI over the store's sorted sample keys —
   optionally **sampled** (§4) for fast worker startup on huge stores;
 * serve: ``index.lookup`` — the handle routes big batches through the
   jnp/Pallas device path and small ones through the numpy reference
   (``prefer_device`` pins the device backend instead);
 * stream: new documents appended out-of-key-order land in **gap slots**
   (§5.3 dynamic insert via ``index.ingest``) — no index rebuild, and
   the frozen device buffers are delta-updated in place (the old code
   refroze the whole engine after every append).

Misses raise KeyError (a miss means a corrupt manifest — fail loudly).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import Index, IngestReport
from .token_store import PackedTokenStore


@dataclasses.dataclass
class IndexedTokenDataset:
    store: PackedTokenStore
    index: Index
    prefer_device: bool = False

    @staticmethod
    def build(store: PackedTokenStore, method: str = "pgm",
              sample_rate: float = 1.0, gap_rho: float = 0.15,
              use_device: bool = False, **mech_kwargs) -> "IndexedTokenDataset":
        keys = store.sample_keys.astype(np.float64)
        index = Index.build(
            keys, method=method, sample_rate=sample_rate, gap_rho=gap_rho,
            **mech_kwargs)
        ds = IndexedTokenDataset(store=store, index=index,
                                 prefer_device=use_device)
        if use_device:
            index.refreeze()  # materialize the engine up front
        return ds

    # ------------------------------------------------------------------
    def ordinals(self, sample_keys: np.ndarray) -> np.ndarray:
        """Batched key -> document ordinal (payload) resolution."""
        q = np.asarray(sample_keys, np.float64)
        backend = "xla-windowed" if self.prefer_device else None
        res = self.index.lookup(q, backend=backend)
        if not bool(res.found.all()):
            missing = q[~res.found][:5]
            raise KeyError(f"sample keys not in index (first 5): {missing}")
        return np.asarray(res.payloads, np.int64)

    def batch(self, sample_keys: np.ndarray, seq_len: int) -> np.ndarray:
        """Fetch + pad/trim documents into an (n, seq_len) token matrix."""
        ords = self.ordinals(sample_keys)
        out = np.zeros((len(ords), seq_len), np.int32)
        for i, o in enumerate(ords):
            doc = self.store.doc(int(o))[:seq_len]
            out[i, : len(doc)] = doc
        return out

    # ------------------------------------------------------------------
    def ingest(self, doc: np.ndarray, sample_key: int) -> str:
        """Streamed append: O(1) gap-slot insert, no retrain (paper §5.3).

        Returns the placement path ('slot'|'chain'); the device state —
        if materialized — follows lazily via delta update on the next
        device lookup.
        """
        ordinal = self.store.append(doc, sample_key)
        return self.index.insert(float(sample_key), int(ordinal))

    def ingest_batch(self, docs, sample_keys) -> IngestReport:
        """Batched streamed append: one vectorized §5.3 ingest (and at
        most ONE device delta-update/refreeze) for a whole shipment of
        documents.  Returns the typed ``IngestReport``."""
        ordinals = self.store.append_batch(docs, sample_keys)
        return self.index.ingest(
            np.asarray(sample_keys, np.float64), ordinals)
