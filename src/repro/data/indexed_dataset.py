"""Learned-index-backed sample lookup — the paper as a data-plane feature.

A training job addresses samples by *key* (content hash / global shuffle
id), not ordinal: restarts, online mixing, and streamed ingestion all
need key -> storage-position resolution.  Classically that's a B-tree or
a hash map per worker; here it is the paper's pluggable learned index:

 * build: PGM/FITing/RMI over the store's sorted sample keys —
   optionally **sampled** (§4) for fast worker startup on huge stores;
 * serve: batched lookups through the jnp/Pallas path (`use_device=True`)
   or the numpy reference;
 * stream: new documents appended out-of-key-order land in **gap slots**
   (§5.3 dynamic insert) — no index rebuild on ingestion.

Misses raise KeyError (a miss means a corrupt manifest — fail loudly).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import LearnedIndex
from .token_store import PackedTokenStore


@dataclasses.dataclass
class IndexedTokenDataset:
    store: PackedTokenStore
    index: LearnedIndex
    use_device: bool = False
    _device_state: Optional[tuple] = None

    @staticmethod
    def build(store: PackedTokenStore, method: str = "pgm",
              sample_rate: float = 1.0, gap_rho: float = 0.15,
              use_device: bool = False, **mech_kwargs) -> "IndexedTokenDataset":
        keys = store.sample_keys.astype(np.float64)
        index = LearnedIndex.build(
            keys, method=method, sample_rate=sample_rate, gap_rho=gap_rho,
            **mech_kwargs)
        ds = IndexedTokenDataset(store=store, index=index,
                                 use_device=use_device)
        if use_device:
            ds._refresh_device()
        return ds

    def _refresh_device(self):
        from ..kernels import QueryEngine
        self._device_state = QueryEngine.from_index(self.index)

    # ------------------------------------------------------------------
    def ordinals(self, sample_keys: np.ndarray) -> np.ndarray:
        """Batched key -> document ordinal (payload) resolution."""
        q = np.asarray(sample_keys, np.float64)
        if self.use_device and self._device_state is not None:
            out, *_ = self._device_state.lookup(q)
            out = np.asarray(out)
        else:
            out = self.index.lookup(q)
        if np.any(out < 0):
            missing = q[out < 0][:5]
            raise KeyError(f"sample keys not in index (first 5): {missing}")
        return out.astype(np.int64)

    def batch(self, sample_keys: np.ndarray, seq_len: int) -> np.ndarray:
        """Fetch + pad/trim documents into an (n, seq_len) token matrix."""
        ords = self.ordinals(sample_keys)
        out = np.zeros((len(ords), seq_len), np.int32)
        for i, o in enumerate(ords):
            doc = self.store.doc(int(o))[:seq_len]
            out[i, : len(doc)] = doc
        return out

    # ------------------------------------------------------------------
    def ingest(self, doc: np.ndarray, sample_key: int) -> str:
        """Streamed append: O(1) gap-slot insert, no retrain (paper §5.3)."""
        ordinal = self.store.append(doc, sample_key)
        path = self.index.insert(float(sample_key), int(ordinal))
        if self.use_device:
            self._refresh_device()  # device arrays are immutable snapshots
        return path

    def ingest_batch(self, docs, sample_keys) -> dict:
        """Batched streamed append: one vectorized §5.3 ``insert_batch``
        (and at most ONE device refreeze) for a whole shipment of
        documents.  Returns the {'slot': n, 'chain': n} path counts."""
        ordinals = self.store.append_batch(docs, sample_keys)
        counts = self.index.insert_batch(
            np.asarray(sample_keys, np.float64), ordinals)
        if self.use_device:
            self._refresh_device()
        return counts
