"""Packed token storage: one flat token array + document boundaries.

On disk: ``<name>.tokens.npy`` (uint32) and ``<name>.meta.json`` with the
document offsets and the *sample keys* (sorted uint64 ids — e.g. content
hashes or global shuffle ids).  The learned index in
``indexed_dataset.py`` maps sample key -> document ordinal.

Streaming appends write into amortized-doubling capacity buffers (the
public ``tokens`` / ``doc_offsets`` / ``sample_keys`` are trimmed
views), so per-document ``append`` is O(len(doc)) amortized instead of
one whole-buffer copy per call; ``version`` counts appends — the
mutation counter the indexed dataset's epoch story keys off.
"""

from __future__ import annotations

import json
import os
from typing import Optional, Sequence

import numpy as np


def _with_capacity(a: np.ndarray, cap: int) -> np.ndarray:
    out = np.empty(cap, a.dtype)
    out[: a.shape[0]] = a
    return out


class PackedTokenStore:
    def __init__(self, tokens: np.ndarray, doc_offsets: np.ndarray,
                 sample_keys: np.ndarray):
        self._tokens = np.asarray(tokens, np.uint32)
        self._offsets = np.asarray(doc_offsets, np.int64)
        self._keys = np.asarray(sample_keys, np.uint64)
        self._n_tokens = int(self._tokens.shape[0])
        self._n_docs = int(self._keys.shape[0])
        self.version = 0

    # ------------------------------------------------------------------
    @property
    def tokens(self) -> np.ndarray:
        return self._tokens[: self._n_tokens]

    @property
    def doc_offsets(self) -> np.ndarray:
        return self._offsets[: self._n_docs + 1]

    @property
    def sample_keys(self) -> np.ndarray:
        return self._keys[: self._n_docs]

    @property
    def n_docs(self) -> int:
        return self._n_docs

    def doc(self, ordinal: int) -> np.ndarray:
        a, b = self._offsets[ordinal], self._offsets[ordinal + 1]
        return self._tokens[a:b]

    # ------------------------------------------------------------------
    @staticmethod
    def build(docs: Sequence[np.ndarray],
              sample_keys: Optional[np.ndarray] = None) -> "PackedTokenStore":
        """Pack token documents; keys default to spaced ids (gap-friendly)."""
        lens = np.array([len(d) for d in docs], np.int64)
        offsets = np.concatenate([[0], np.cumsum(lens)])
        tokens = (np.concatenate(docs).astype(np.uint32)
                  if len(docs) else np.zeros(0, np.uint32))
        if sample_keys is None:
            # spaced keys leave headroom for streamed appends (paper §5.3)
            sample_keys = (np.arange(len(docs), dtype=np.uint64) + 1) * 16
        sample_keys = np.asarray(sample_keys, np.uint64)
        if not np.all(np.diff(sample_keys.astype(np.float64)) > 0):
            raise ValueError("sample keys must be strictly increasing")
        return PackedTokenStore(tokens, offsets, sample_keys)

    @staticmethod
    def synthetic(n_docs: int, mean_len: int = 512, vocab: int = 32_000,
                  seed: int = 0) -> "PackedTokenStore":
        rng = np.random.default_rng(seed)
        lens = np.maximum(8, rng.poisson(mean_len, n_docs))
        # Zipfian token frequencies (realistic, and gives training a
        # learnable unigram signal in tests/examples)
        docs = [(rng.zipf(1.4, l) - 1).clip(0, vocab - 1).astype(np.uint32)
                for l in lens]
        # realistic keys: sorted 48-bit content hashes
        keys = np.sort(rng.choice(2 ** 48, n_docs, replace=False)).astype(
            np.uint64)
        return PackedTokenStore.build(docs, keys)

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.save(path + ".tokens.npy", self.tokens)
        np.save(path + ".offsets.npy", self.doc_offsets)
        np.save(path + ".keys.npy", self.sample_keys)
        with open(path + ".meta.json", "w") as f:
            json.dump({"n_docs": self.n_docs,
                       "total_tokens": int(self._n_tokens)}, f)

    @staticmethod
    def load(path: str) -> "PackedTokenStore":
        return PackedTokenStore(
            tokens=np.load(path + ".tokens.npy", mmap_mode="r"),
            doc_offsets=np.load(path + ".offsets.npy"),
            sample_keys=np.load(path + ".keys.npy"),
        )

    # ------------------------------------------------------------------
    def _reserve(self, extra_tokens: int, extra_docs: int) -> None:
        need_t = self._n_tokens + extra_tokens
        if need_t > self._tokens.shape[0]:
            self._tokens = _with_capacity(self.tokens, max(need_t * 2, 1024))
        need_d = self._n_docs + extra_docs
        if need_d + 1 > self._offsets.shape[0]:
            self._offsets = _with_capacity(self.doc_offsets,
                                           max((need_d + 1) * 2, 64))
        if need_d > self._keys.shape[0]:
            self._keys = _with_capacity(self.sample_keys,
                                        max(need_d * 2, 64))

    def append(self, doc: np.ndarray, sample_key: int) -> int:
        """Streamed ingestion: append one document (key may interleave).

        Returns the new document ordinal.  The learned index layer
        handles out-of-order keys through gap insertion (paper §5.3) —
        physical token storage is append-only (amortized O(len(doc))).
        """
        doc = np.asarray(doc, np.uint32)
        self._reserve(doc.shape[0], 1)
        t0 = self._n_tokens
        self._tokens[t0 : t0 + doc.shape[0]] = doc
        self._n_tokens += int(doc.shape[0])
        self._offsets[self._n_docs + 1] = self._n_tokens
        self._keys[self._n_docs] = np.uint64(sample_key)
        self._n_docs += 1
        self.version += 1
        return self._n_docs - 1

    def append_batch(self, docs, sample_keys) -> np.ndarray:
        """Append many documents with ONE capacity reservation.
        Returns the new document ordinals."""
        lens = np.array([len(d) for d in docs], np.int64)
        self._reserve(int(lens.sum()), len(docs))
        first = self._n_docs
        for d, k in zip(docs, np.asarray(sample_keys, np.uint64)):
            d = np.asarray(d, np.uint32)
            t0 = self._n_tokens
            self._tokens[t0 : t0 + d.shape[0]] = d
            self._n_tokens += int(d.shape[0])
            self._offsets[self._n_docs + 1] = self._n_tokens
            self._keys[self._n_docs] = k
            self._n_docs += 1
        self.version += 1
        return np.arange(first, first + len(lens), dtype=np.int64)
