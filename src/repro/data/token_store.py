"""Packed token storage: one flat token array + document boundaries.

On disk: ``<name>.tokens.npy`` (uint32) and ``<name>.meta.json`` with the
document offsets and the *sample keys* (sorted uint64 ids — e.g. content
hashes or global shuffle ids).  The learned index in
``indexed_dataset.py`` maps sample key -> document ordinal.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Iterable, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PackedTokenStore:
    tokens: np.ndarray        # (total_tokens,) uint32
    doc_offsets: np.ndarray   # (n_docs + 1,) int64
    sample_keys: np.ndarray   # (n_docs,) uint64, strictly increasing

    @property
    def n_docs(self) -> int:
        return int(self.sample_keys.shape[0])

    def doc(self, ordinal: int) -> np.ndarray:
        a, b = self.doc_offsets[ordinal], self.doc_offsets[ordinal + 1]
        return self.tokens[a:b]

    # ------------------------------------------------------------------
    @staticmethod
    def build(docs: Sequence[np.ndarray],
              sample_keys: Optional[np.ndarray] = None) -> "PackedTokenStore":
        """Pack token documents; keys default to spaced ids (gap-friendly)."""
        lens = np.array([len(d) for d in docs], np.int64)
        offsets = np.concatenate([[0], np.cumsum(lens)])
        tokens = (np.concatenate(docs).astype(np.uint32)
                  if docs else np.zeros(0, np.uint32))
        if sample_keys is None:
            # spaced keys leave headroom for streamed appends (paper §5.3)
            sample_keys = (np.arange(len(docs), dtype=np.uint64) + 1) * 16
        sample_keys = np.asarray(sample_keys, np.uint64)
        if not np.all(np.diff(sample_keys.astype(np.float64)) > 0):
            raise ValueError("sample keys must be strictly increasing")
        return PackedTokenStore(tokens, offsets, sample_keys)

    @staticmethod
    def synthetic(n_docs: int, mean_len: int = 512, vocab: int = 32_000,
                  seed: int = 0) -> "PackedTokenStore":
        rng = np.random.default_rng(seed)
        lens = np.maximum(8, rng.poisson(mean_len, n_docs))
        # Zipfian token frequencies (realistic, and gives training a
        # learnable unigram signal in tests/examples)
        docs = [(rng.zipf(1.4, l) - 1).clip(0, vocab - 1).astype(np.uint32)
                for l in lens]
        # realistic keys: sorted 48-bit content hashes
        keys = np.sort(rng.choice(2 ** 48, n_docs, replace=False)).astype(
            np.uint64)
        return PackedTokenStore.build(docs, keys)

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        np.save(path + ".tokens.npy", self.tokens)
        np.save(path + ".offsets.npy", self.doc_offsets)
        np.save(path + ".keys.npy", self.sample_keys)
        with open(path + ".meta.json", "w") as f:
            json.dump({"n_docs": self.n_docs,
                       "total_tokens": int(self.tokens.shape[0])}, f)

    @staticmethod
    def load(path: str) -> "PackedTokenStore":
        return PackedTokenStore(
            tokens=np.load(path + ".tokens.npy", mmap_mode="r"),
            doc_offsets=np.load(path + ".offsets.npy"),
            sample_keys=np.load(path + ".keys.npy"),
        )

    def append(self, doc: np.ndarray, sample_key: int) -> int:
        """Streamed ingestion: append one document (key may interleave).

        Returns the new document ordinal.  The learned index layer
        handles out-of-order keys through gap insertion (paper §5.3) —
        physical token storage is append-only.
        """
        self.tokens = np.concatenate([self.tokens, doc.astype(np.uint32)])
        self.doc_offsets = np.concatenate(
            [self.doc_offsets, [self.doc_offsets[-1] + len(doc)]])
        self.sample_keys = np.concatenate(
            [self.sample_keys, [np.uint64(sample_key)]])
        return self.n_docs - 1

    def append_batch(self, docs, sample_keys) -> np.ndarray:
        """Append many documents with ONE buffer reallocation (the
        per-doc ``append`` copies the whole token buffer every call).
        Returns the new document ordinals."""
        first = self.n_docs
        lens = np.array([len(d) for d in docs], np.int64)
        self.tokens = np.concatenate(
            [self.tokens] + [np.asarray(d, np.uint32) for d in docs])
        self.doc_offsets = np.concatenate(
            [self.doc_offsets, self.doc_offsets[-1] + np.cumsum(lens)])
        self.sample_keys = np.concatenate(
            [self.sample_keys, np.asarray(sample_keys, np.uint64)])
        return np.arange(first, first + len(lens), dtype=np.int64)
