from .token_store import PackedTokenStore
from .indexed_dataset import IndexedTokenDataset
from .pipeline import ShardedLoader

__all__ = ["PackedTokenStore", "IndexedTokenDataset", "ShardedLoader"]
