"""Config dataclasses + the assigned input-shape sets."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str                  # lm | encdec | zamba | xlstm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    moe: Optional[MoEConfig] = None
    ssm_state: int = 0
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    remat: str = "full"        # none | full | dots
    scan_layers: bool = True
    # modality frontends (STUBS per assignment: precomputed embeddings)
    frontend: Optional[str] = None       # "vit" | "audio"
    n_frontend_tokens: int = 0
    d_frontend: int = 0
    # encoder (enc-dec archs)
    n_enc_layers: int = 0
    # hybrid (zamba)
    attn_every: int = 6
    # distribution hints
    fsdp: bool = False         # shard params/opt-state over the data axis
    optimizer: str = "adamw"   # adamw | adafactor
    moe_impl: str = "gspmd"    # gspmd | ep (shard_map all_to_all; §Perf)
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=max(1, min(cfg.n_kv, 4) * 4 // max(cfg.n_heads, 1)) or 2,
        d_ff=128,
        vocab=256,
        head_dim=16,
        scan_layers=cfg.scan_layers,
        remat="none",
    )
    kw["n_kv"] = 2 if cfg.n_kv < cfg.n_heads else 4
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32)
    if cfg.arch == "encdec":
        kw["n_enc_layers"] = 2
    if cfg.frontend:
        kw["n_frontend_tokens"] = 8
        kw["d_frontend"] = 32
    if cfg.ssm_state:
        kw["ssm_state"] = 16
    if cfg.arch == "zamba":
        kw["attn_every"] = 1  # exercise the shared block even at 2 layers
    return dataclasses.replace(cfg, **kw)
