"""qwen1.5-32b — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", arch="lm",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27_392, vocab=152_064,
    qkv_bias=True, fsdp=True,
)
