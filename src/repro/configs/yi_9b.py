"""yi-9b — llama-arch GQA dense. [arXiv:2403.04652; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", arch="lm",
    n_layers=48, d_model=4096, n_heads=32, n_kv=4, d_ff=11_008, vocab=64_000,
    fsdp=True,
)
