"""xlstm-125m — sLSTM + mLSTM blocks (7:1 ratio). [arXiv:2405.04517]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", arch="xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0, vocab=50_304,
    subquadratic=True,
)
