"""zamba2-1.2b — Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", arch="zamba",
    n_layers=38, d_model=2048, n_heads=32, n_kv=32, d_ff=8192, vocab=32_000,
    ssm_state=64, attn_every=6, subquadratic=True,
)
