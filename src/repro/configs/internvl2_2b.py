"""internvl2-2b — InternViT (STUB frontend: precomputed patch embeddings)
+ InternLM2-2B backbone. [arXiv:2404.16821; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", arch="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, d_ff=8192, vocab=92_553,
    frontend="vit", n_frontend_tokens=256, d_frontend=1024,
)
