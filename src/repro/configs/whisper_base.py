"""whisper-base — enc-dec; conv/audio frontend is a STUB per assignment
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", arch="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048,
    vocab=51_865, frontend="audio", n_frontend_tokens=1500, d_frontend=512,
)
