"""kimi-k2-1t-a32b — trillion-param MoE (paper-table config).
[arXiv:2501.kimi2; unverified]
1T params do not fit one 256-chip v5e pod with fp32 Adam; config selects
Adafactor + FSDP (see DESIGN.md §4) and targets the 512-chip 2-pod mesh."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", arch="lm",
    n_layers=61, d_model=7168, n_heads=64, n_kv=8, d_ff=2048, vocab=163_840,
    head_dim=112,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048),
    fsdp=True, optimizer="adafactor",
)
