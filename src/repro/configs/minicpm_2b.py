"""minicpm-2b — WSD schedule, llama-like arch, tied embeddings.
[arXiv:2404.06395; hf].  36 heads (not divisible by the 16-way model
axis — GSPMD pads; see EXPERIMENTS.md roofline note)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", arch="lm",
    n_layers=40, d_model=2304, n_heads=36, n_kv=36, d_ff=5760, vocab=122_753,
    tie_embeddings=True,
)
