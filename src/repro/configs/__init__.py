"""Assigned architecture configs (+ shape sets).

Every config is selectable via ``--arch <id>`` in the launchers."""

from .base import SHAPES, ModelConfig, MoEConfig, ShapeConfig, reduced
from . import (
    granite_moe_1b_a400m, kimi_k2_1t_a32b, yi_9b, internlm2_1_8b,
    minicpm_2b, qwen1_5_32b, whisper_base, zamba2_1_2b, xlstm_125m,
    internvl2_2b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        granite_moe_1b_a400m, kimi_k2_1t_a32b, yi_9b, internlm2_1_8b,
        minicpm_2b, qwen1_5_32b, whisper_base, zamba2_1_2b, xlstm_125m,
        internvl2_2b,
    )
}

__all__ = ["ARCHS", "SHAPES", "ModelConfig", "MoEConfig", "ShapeConfig", "reduced"]
