"""repro — production-grade JAX framework reproducing and extending
"A Pluggable Learned Index Method via Sampling and Gap Insertion"
(Li & Chen et al., 2021) for multi-pod TPU deployments."""

__version__ = "1.0.0"
