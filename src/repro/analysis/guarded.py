"""Guarded-by lock checking.

Annotation convention (content-activated: any file using it is
checked)::

    class MicroBatchQueue:
        def __init__(self):
            self._lock = threading.RLock()
            self._lookups = []      #: guarded-by: _lock
            #: guarded-by: _lock
            self._results = {}

``#: guarded-by: <lockname>`` on the attribute's assignment line (or
the line directly above it) declares that every read/write of
``self.<attr>`` inside the declaring class must happen

* lexically inside a ``with self.<lockname>:`` block, or
* in a method documented *lock-held*: its docstring contains
  ``lock-held: <lockname>`` (audited convention — every call site must
  hold the lock; the runtime sanitizer ``analysis.locksan`` checks it
  dynamically), or
* in ``__init__``/``__del__`` (construction/teardown is single-owner).

The check is lexical, deliberately: a guarded access in a method
without a visible ``with`` and without the lock-held marker is exactly
the pattern that rots into a data race when a refactor adds a second
thread (the deadline-timer lesson of PR 8).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .core import Checker, Finding, LintContext

__all__ = ["GuardedByChecker", "collect_guarded", "ANNOTATION_RE",
           "LOCK_HELD_RE"]

ANNOTATION_RE = re.compile(r"#:\s*guarded-by:\s*([A-Za-z_][\w]*)")
LOCK_HELD_RE = re.compile(r"lock-held:\s*([A-Za-z_][\w,\s]*)")

_EXEMPT_METHODS = {"__init__", "__post_init__", "__del__"}


def _guard_comment(comments: Dict[int, str], line: int) -> Optional[str]:
    for ln in (line, line - 1):
        c = comments.get(ln)
        if c:
            m = ANNOTATION_RE.search(c)
            if m:
                return m.group(1)
    return None


def collect_guarded(tree: ast.AST, comments: Dict[int, str]
                    ) -> Dict[str, Dict[str, str]]:
    """{class name: {attr: lockname}} from ``#: guarded-by:``
    annotations on ``self.<attr> = ...`` statements."""
    out: Dict[str, Dict[str, str]] = {}
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        attrs: Dict[str, str] = {}
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign):
                targets = [node.target]
            else:
                continue
            lock = _guard_comment(comments, node.lineno)
            if lock is None:
                continue
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    attrs[t.attr] = lock
        if attrs:
            out[cls.name] = attrs
    return out


def collect_guarded_source(source: str) -> Dict[str, Dict[str, str]]:
    """Source-string front end (used by ``locksan`` to instrument live
    objects from their class source)."""
    from .core import parse_suppressions
    comments, _, _ = parse_suppressions(source)
    return collect_guarded(ast.parse(source), comments)


def _lock_held_names(fn: ast.FunctionDef) -> Set[str]:
    doc = ast.get_docstring(fn) or ""
    m = LOCK_HELD_RE.search(doc)
    if not m:
        return set()
    return {n.strip() for n in m.group(1).split(",") if n.strip()}


def _with_locks(item: ast.withitem) -> Optional[str]:
    """``with self.<lock>:`` -> lock name."""
    expr = item.context_expr
    if (isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name)
            and expr.value.id == "self"):
        return expr.attr
    return None


class GuardedByChecker(Checker):
    rules = ("guarded-by",)
    # content-activated: cheap sniff, then full parse
    path_patterns = ()

    def applies(self, path: str, source: str) -> bool:
        return "guarded-by:" in source

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        guarded = collect_guarded(ctx.tree, ctx.comments)
        if not guarded:
            return
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            attrs = guarded.get(cls.name)
            if not attrs:
                continue
            for fn in cls.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if fn.name in _EXEMPT_METHODS:
                    continue
                held_doc = _lock_held_names(fn)
                yield from self._walk(ctx, cls.name, fn, fn.body, attrs,
                                      held_doc)

    def _walk(self, ctx: LintContext, clsname: str,
              fn: ast.FunctionDef, body: List[ast.stmt],
              attrs: Dict[str, str], held: Set[str]
              ) -> Iterable[Finding]:
        for stmt in body:
            yield from self._visit(ctx, clsname, fn, stmt, attrs, held)

    def _visit(self, ctx: LintContext, clsname: str,
               fn: ast.FunctionDef, node: ast.AST,
               attrs: Dict[str, str], held: Set[str]
               ) -> Iterable[Finding]:
        if isinstance(node, ast.With):
            inner = set(held)
            for item in node.items:
                lk = _with_locks(item)
                if lk is not None:
                    inner.add(lk)
            for stmt in node.body:
                yield from self._visit(ctx, clsname, fn, stmt, attrs,
                                       inner)
            for item in node.items:
                yield from self._visit(ctx, clsname, fn,
                                       item.context_expr, attrs, held)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested def: runs later, on an unknown thread — the held
            # set does not carry over (its own lock-held doc may)
            nested_held = _lock_held_names(node)
            for stmt in node.body:
                yield from self._visit(ctx, clsname, node, stmt, attrs,
                                       nested_held)
            return
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
                and node.attr in attrs):
            lock = attrs[node.attr]
            if lock not in held:
                kind = ("write" if isinstance(node.ctx, (ast.Store,
                                                         ast.Del))
                        else "read")
                yield Finding(
                    "guarded-by", ctx.path, node.lineno,
                    f"{clsname}.{fn.name}: unguarded {kind} of "
                    f"'self.{node.attr}' (guarded-by: {lock}) — wrap in "
                    f"'with self.{lock}:' or document the method "
                    f"'lock-held: {lock}'")
            return
        for child in ast.iter_child_nodes(node):
            yield from self._visit(ctx, clsname, fn, child, attrs, held)
