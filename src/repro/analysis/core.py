"""repro-lint framework: file walker, checker registry, suppressions,
reporters.

A *checker* is a class with

* ``rules``: tuple of rule names it can emit (``Finding.rule`` must be
  one of them);
* ``applies(path, source) -> bool``: cheap scope gate (path pattern
  and/or content sniff) so e.g. trace-safety never parses host-only
  modules;
* ``check(ctx) -> iterable[Finding]``: the AST pass over one file.

``lint_source``/``lint_paths`` drive the registry; ``main`` is the CLI
behind ``scripts/lint.sh`` (JSON + human reporters, nonzero exit on any
unsuppressed finding).

Suppression syntax (see ``repro.analysis`` package doc):

* ``# repro-lint: disable=rule1,rule2 -- justification`` on the flagged
  line, or on the line directly above it;
* ``# repro-lint: disable-file=rule -- justification`` anywhere in the
  file (whole-file scope);
* ``disable=all`` matches every rule.

A suppressed finding is still collected (``suppressed=True``) so
``--show-suppressed`` can audit the waiver inventory, but it never
fails the lint.
"""

from __future__ import annotations

import ast
import dataclasses
import fnmatch
import io
import json
import sys
import time
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

__all__ = ["Finding", "LintContext", "Checker", "default_checkers",
           "lint_source", "lint_paths", "parse_suppressions", "main"]


@dataclasses.dataclass
class Finding:
    rule: str
    path: str
    line: int
    message: str
    suppressed: bool = False

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{tag}"


@dataclasses.dataclass
class LintContext:
    """Everything a checker pass needs for one file."""

    path: str                      # repo-relative (or caller-given) path
    source: str
    tree: ast.AST
    comments: Dict[int, str]       # line -> comment text (incl. '#')
    line_disables: Dict[int, Set[str]]   # line -> rules disabled there
    file_disables: Set[str]

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self.file_disables or "all" in self.file_disables:
            return True
        for ln in (line, line - 1):
            rules = self.line_disables.get(ln)
            if rules and (rule in rules or "all" in rules):
                return True
        return False


_DISABLE = "repro-lint:"


def parse_suppressions(source: str) -> Tuple[Dict[int, str],
                                             Dict[int, Set[str]],
                                             Set[str]]:
    """Tokenize ``source`` -> (comments, per-line disables, file
    disables).  Tolerates files that tokenize rejects (returns empty
    maps — the AST parse will raise its own error upstream)."""
    comments: Dict[int, str] = {}
    line_disables: Dict[int, Set[str]] = {}
    file_disables: Set[str] = set()
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            comments[tok.start[0]] = tok.string
            body = tok.string.lstrip("#").strip()
            if not body.startswith(_DISABLE):
                continue
            body = body[len(_DISABLE):].strip()
            # strip trailing justification:  disable=x -- why
            body = body.split("--", 1)[0].strip()
            if body.startswith("disable-file="):
                rules = body[len("disable-file="):]
                file_disables.update(
                    r.strip() for r in rules.split(",") if r.strip())
            elif body.startswith("disable="):
                rules = body[len("disable="):]
                line_disables.setdefault(tok.start[0], set()).update(
                    r.strip() for r in rules.split(",") if r.strip())
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass
    return comments, line_disables, file_disables


class Checker:
    """Base checker.  Subclasses set ``rules`` and ``path_patterns``
    (fnmatch globs matched against the posix path; empty = every file)
    and implement ``check``."""

    rules: Tuple[str, ...] = ()
    path_patterns: Tuple[str, ...] = ()

    def applies(self, path: str, source: str) -> bool:
        if not self.path_patterns:
            return True
        p = Path(path).as_posix()
        return any(fnmatch.fnmatch(p, pat) or p.endswith(pat.lstrip("*"))
                   for pat in self.path_patterns)

    def check(self, ctx: LintContext) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


def default_checkers() -> List[Checker]:
    from .epoch import EpochDisciplineChecker, SnapshotImmutabilityChecker
    from .guarded import GuardedByChecker
    from .pairexact import PairExactChecker
    from .tracesafe import TraceSafetyChecker
    return [EpochDisciplineChecker(), SnapshotImmutabilityChecker(),
            TraceSafetyChecker(), GuardedByChecker(), PairExactChecker()]


def lint_source(source: str, path: str = "<string>",
                checkers: Optional[List[Checker]] = None,
                rules: Optional[Set[str]] = None) -> List[Finding]:
    """Run the checkers over one source string (the fixture-test entry
    point).  ``rules`` filters which rule names may be emitted."""
    checkers = default_checkers() if checkers is None else checkers
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("parse-error", path, e.lineno or 0,
                        f"syntax error: {e.msg}")]
    comments, line_dis, file_dis = parse_suppressions(source)
    ctx = LintContext(path=path, source=source, tree=tree,
                      comments=comments, line_disables=line_dis,
                      file_disables=file_dis)
    out: List[Finding] = []
    for ch in checkers:
        if not ch.applies(path, source):
            continue
        for f in ch.check(ctx):
            if rules is not None and f.rule not in rules:
                continue
            f.suppressed = ctx.suppressed(f.rule, f.line)
            out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def _iter_py_files(paths: Iterable[str]) -> Iterable[Path]:
    for p in paths:
        pth = Path(p)
        if pth.is_dir():
            yield from sorted(pth.rglob("*.py"))
        elif pth.suffix == ".py":
            yield pth


def lint_paths(paths: Iterable[str],
               checkers: Optional[List[Checker]] = None,
               rules: Optional[Set[str]] = None) -> List[Finding]:
    checkers = default_checkers() if checkers is None else checkers
    out: List[Finding] = []
    for f in _iter_py_files(paths):
        src = f.read_text()
        out.extend(lint_source(src, path=f.as_posix(), checkers=checkers,
                               rules=rules))
    return out


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="repo-aware static analysis (epoch/snapshot "
                    "discipline, trace-safety, guarded-by locks, "
                    "pair-exactness)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"])
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset to enable")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="print suppressed findings too")
    args = ap.parse_args(argv)

    checkers = default_checkers()
    if args.list_rules:
        for ch in checkers:
            for r in ch.rules:
                print(f"{r:24s} ({type(ch).__name__})")
        return 0
    rules = (set(r.strip() for r in args.rules.split(","))
             if args.rules else None)
    t0 = time.perf_counter()
    findings = lint_paths(args.paths, checkers=checkers, rules=rules)
    dt = time.perf_counter() - t0
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    if args.json:
        print(json.dumps({
            "findings": [f.as_dict() for f in findings],
            "active": len(active), "suppressed": len(suppressed),
            "seconds": round(dt, 3)}, indent=2))
    else:
        shown = findings if args.show_suppressed else active
        for f in shown:
            print(f.render())
        print(f"repro-lint: {len(active)} finding(s), "
              f"{len(suppressed)} suppressed, {dt:.2f}s",
              file=sys.stderr)
    return 1 if active else 0
