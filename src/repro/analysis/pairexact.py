"""Pair-exactness checker for the hi/lo double-f32 kernel files.

The device kernels carry keys, slopes and intercepts as f32 (hi, lo)
pairs whose arithmetic must go through the fma-free error-free
transforms (``_two_sum``/``_two_prod``/``_dd_*`` in
``kernels/gap_place.py``) — that is what makes integer keys < 2^48
exact on hardware without f64.  Two ways code silently breaks that
contract:

``pair-float64``
    A float64 dtype inside a traced kernel function (``jnp.float64``,
    ``astype('float64')``, ``np.float64``): accelerators demote or
    refuse f64, so a device build silently loses the bits the pair
    representation was carrying.
``pair-raw-fma``
    A raw ``a*b + c`` / ``a*b - c`` on pair-component operands (names
    ending ``_h``/``_l``/``_hi``/``_lo`` or containing ``slope``/
    ``icept``/``key``/``pair``) outside the designated error-free-
    transform primitives: compilers may contract it to an fma (or
    round the product) and the hi/lo invariant ``hi + lo == exact`` is
    gone.  Use ``_dd_mul``/``_dd_add2`` or route through
    ``_two_sum``/``_two_prod``.

Approximate-by-design arithmetic (e.g. a window *base* whose error
only costs an escape, never a wrong answer) is exempted with an inline
suppression carrying the justification.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, Set

from .core import Checker, Finding, LintContext
from .tracesafe import _fn_index, discover_traced

__all__ = ["PairExactChecker"]

_EFT_PRIMITIVE_RE = re.compile(r"(two_sum|two_prod|_dd_)")
_PAIRISH_RE = re.compile(r"(_h|_l|_hi|_lo)$|slope|icept|key|pair")


def _leaf_names(expr: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(expr):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
    return out


def _pairish(expr: ast.AST) -> bool:
    return any(_PAIRISH_RE.search(n) for n in _leaf_names(expr))


def _mentions_float64(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "float64":
        return True
    if isinstance(node, ast.Constant) and node.value == "float64":
        return True
    return False


class PairExactChecker(Checker):
    rules = ("pair-float64", "pair-raw-fma")
    path_patterns = ("*/kernels/gap_place.py", "*/kernels/lookup.py",
                     "*/kernels/ops_gap.py", "*fixture*")

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        traced = discover_traced(ctx.tree)
        fns = _fn_index(ctx.tree)
        for name in traced:
            fn = fns.get(name)
            if fn is None:
                continue
            if _EFT_PRIMITIVE_RE.search(name):
                continue  # the error-free transforms themselves
            yield from self._check_fn(ctx, fn)

    def _check_fn(self, ctx: LintContext,
                  fn: ast.FunctionDef) -> Iterable[Finding]:
        where = f"traced function '{fn.name}'"
        for node in ast.walk(fn):
            if _mentions_float64(node):
                yield Finding(
                    "pair-float64", ctx.path, node.lineno,
                    f"float64 intermediate in {where} — device pair "
                    f"code must stay f32 hi/lo (accelerators demote "
                    f"f64; the 2^48 contract silently breaks)")
            if isinstance(node, ast.BinOp) and isinstance(
                    node.op, (ast.Add, ast.Sub)):
                for side in (node.left, node.right):
                    if (isinstance(side, ast.BinOp)
                            and isinstance(side.op, ast.Mult)
                            and _pairish(side)):
                        yield Finding(
                            "pair-raw-fma", ctx.path, node.lineno,
                            f"raw 'a*b {'+' if isinstance(node.op, ast.Add) else '-'} c' "
                            f"on pair operands in {where} — fma "
                            f"contraction / product rounding breaks the "
                            f"hi/lo exactness contract; use _dd_mul/"
                            f"_two_prod + _two_sum")
                        break
