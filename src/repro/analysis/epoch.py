"""Epoch/snapshot discipline checkers.

Rule ``epoch-bump``
-------------------
Methods on the registered stateful classes (``GappedArray``, ``Index``,
``ShardedIndex``) that write *mutable index state* attributes must carry
epoch-bump evidence in the same method body:

* a call to ``*._invalidate()`` (the GappedArray version bump + COW
  trigger), or
* an assignment/augassign to a ``.version`` attribute (the replace-not-
  mutate retrain idiom: the new arrays get ``version = old + 1`` before
  installation), or
* an assignment/augassign to ``self._mutations`` (the ShardedIndex
  topology counter folded into its epoch).

Private helpers that mutate on behalf of an already-invalidated caller
declare it: the docstring must contain the marker ``caller-invalidates``
(audited convention — every caller must have bumped first).
``__init__``/``__post_init__``/dunder constructors are exempt.

Rule ``snapshot-mutate``
------------------------
Pinned snapshot objects are immutable after construction.  Inside the
registered snapshot classes (``GapSnapshot``, ``IndexSnapshot``,
``ShardedSnapshot``) any ``self.<attr> = ...`` (or element store)
outside ``__init__``/``release``/``retain`` is flagged.  Additionally,
in ANY scanned function, a name bound from ``*.pin_snapshot()`` must
never have attributes assigned — that is a mutation path bypassing
copy-on-write isolation.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from .core import Checker, Finding, LintContext

__all__ = ["EpochDisciplineChecker", "SnapshotImmutabilityChecker",
           "STATEFUL_CLASSES", "SNAPSHOT_CLASSES"]

# class -> attributes that constitute mutable index state (writes to
# anything else — caches, stats, config — are epoch-neutral)
STATEFUL_CLASSES: Dict[str, Set[str]] = {
    "GappedArray": {"slot_key", "occupied", "payload", "links", "mech",
                    "n_keys", "rho"},
    "Index": {"gapped", "mechanism"},
    "ShardedIndex": {"shards", "router"},
}

SNAPSHOT_CLASSES: Dict[str, Set[str]] = {
    # class -> methods allowed to assign self attributes
    "GapSnapshot": {"__init__", "release", "retain"},
    "IndexSnapshot": {"__init__", "release", "retain"},
    "ShardedSnapshot": {"__init__", "release", "retain"},
}

CALLER_MARKER = "caller-invalidates"


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.X`` -> ``X`` (through one subscript/slice level:
    ``self.X[...]`` also targets X)."""
    if isinstance(node, ast.Subscript):
        node = node.value
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _assign_targets(stmt: ast.stmt) -> List[ast.AST]:
    if isinstance(stmt, ast.Assign):
        out = []
        for t in stmt.targets:
            out.extend(t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t])
        return out
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        return [stmt.target]
    return []


def _has_bump_evidence(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "_invalidate"):
            return True
        for tgt in _assign_targets(node) if isinstance(node, ast.stmt) \
                else []:
            base = tgt.value if isinstance(tgt, ast.Subscript) else tgt
            if isinstance(base, ast.Attribute) and base.attr == "version":
                return True
            if (isinstance(base, ast.Attribute)
                    and isinstance(base.value, ast.Name)
                    and base.value.id == "self"
                    and base.attr == "_mutations"):
                return True
    return False


def _docstring_marker(fn: ast.FunctionDef, marker: str) -> bool:
    doc = ast.get_docstring(fn) or ""
    return marker in doc


class EpochDisciplineChecker(Checker):
    rules = ("epoch-bump",)
    path_patterns = ("*core/gaps.py", "*core/handle.py",
                     "*dist/sharded.py", "*serving/pipeline.py",
                     "*fixture*")

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            watched = STATEFUL_CLASSES.get(cls.name)
            if not watched:
                continue
            for fn in cls.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if fn.name.startswith("__"):
                    continue
                writes = []
                for node in ast.walk(fn):
                    if not isinstance(node, ast.stmt):
                        continue
                    for tgt in _assign_targets(node):
                        attr = _self_attr(tgt)
                        if attr in watched:
                            writes.append((node.lineno, attr))
                if not writes:
                    continue
                if _has_bump_evidence(fn):
                    continue
                if _docstring_marker(fn, CALLER_MARKER):
                    continue
                line, attr = writes[0]
                yield Finding(
                    "epoch-bump", ctx.path, line,
                    f"{cls.name}.{fn.name} writes index state "
                    f"'self.{attr}' without epoch-bump evidence "
                    f"(_invalidate()/.version write/self._mutations) and "
                    f"no '{CALLER_MARKER}' docstring marker")


class SnapshotImmutabilityChecker(Checker):
    rules = ("snapshot-mutate",)
    path_patterns = ("*core/gaps.py", "*core/handle.py",
                     "*serving/pipeline.py", "*serving/engine.py",
                     "*dist/sharded.py", "*fixture*")

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        yield from self._class_rule(ctx)
        yield from self._pin_binding_rule(ctx)

    def _class_rule(self, ctx: LintContext) -> Iterable[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            allowed = SNAPSHOT_CLASSES.get(cls.name)
            if allowed is None:
                continue
            for fn in cls.body:
                if not isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    continue
                if fn.name in allowed:
                    continue
                for node in ast.walk(fn):
                    if not isinstance(node, ast.stmt):
                        continue
                    for tgt in _assign_targets(node):
                        attr = _self_attr(tgt)
                        if attr is not None:
                            yield Finding(
                                "snapshot-mutate", ctx.path, node.lineno,
                                f"{cls.name}.{fn.name} assigns "
                                f"'self.{attr}' — pinned snapshots are "
                                f"immutable outside {sorted(allowed)}")

    def _pin_binding_rule(self, ctx: LintContext) -> Iterable[Finding]:
        """Names bound from ``*.pin_snapshot()`` must never be assigned
        attributes in the same function."""
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            pinned: Set[str] = set()
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Call)
                        and isinstance(node.value.func, ast.Attribute)
                        and node.value.func.attr in ("pin_snapshot",
                                                     "pin_index")):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            pinned.add(t.id)
            if not pinned:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.stmt):
                    continue
                for tgt in _assign_targets(node):
                    base = (tgt.value if isinstance(tgt, ast.Subscript)
                            else tgt)
                    if (isinstance(base, ast.Attribute)
                            and isinstance(base.value, ast.Name)
                            and base.value.id in pinned):
                        yield Finding(
                            "snapshot-mutate", ctx.path, node.lineno,
                            f"assignment to attribute "
                            f"'{base.value.id}.{base.attr}' of a pinned "
                            f"snapshot — snapshots are immutable; mutate "
                            f"the live side (COW protects the pin)")
