"""Trace-safety checker for device kernel modules.

Scope: files under ``kernels/`` (plus lint fixtures).  The pass first
discovers the *traced set* — functions that run under a JAX trace:

* functions decorated ``@jax.jit`` / ``@functools.partial(jax.jit,
  ...)``;
* the function argument of ``pallas_call`` / ``shard_map`` /
  ``jax.jit`` / ``jax.vmap`` call sites (through one level of
  ``name = functools.partial(fn, ...)`` indirection);
* transitively, every module function *referenced by name* inside a
  traced body (covers ``fori_loop``/``vmap``/``scan`` bodies and plain
  helper calls).

Static (host) parameters are excluded from taint: keyword-only
parameters, parameters annotated ``int``/``float``/``bool``/``str``,
and names listed in ``static_argnames=``.  A local becomes traced-
tainted when assigned from an expression referencing a tainted name —
except through ``.shape``/``.dtype``/``.ndim``/``len()``, which
produce host values under a trace.

Rules
-----
``trace-host-sync``
    Inside a traced function: ``np.*`` calls (host numpy forces a
    device sync — or a trace error — mid-graph), ``.item()``, and
    ``float()``/``int()``/``bool()`` applied to a traced-tainted
    expression.
``trace-py-branch``
    Python ``if``/``while``/ternary on a traced-tainted test:
    control flow must go through ``jnp.where``/``lax.cond``/
    ``lax.fori_loop`` or the value must be a static.
``trace-self-capture``
    A traced function body referencing ``self``: closure capture of
    mutable object state bakes the *current* attribute values into the
    compiled executable (stale after any mutation) — hoist them into
    locals before defining the traced function.
``trace-dynamic-shape``
    Array-constructor/reshape calls whose shape argument is traced-
    tainted: data-dependent shapes retrace per batch (or fail to
    trace); shapes must come from statics or shape buckets.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .core import Checker, Finding, LintContext

__all__ = ["TraceSafetyChecker", "discover_traced"]

_NP_ALIASES = {"np", "numpy", "onp"}
_SHAPE_ATTRS = {"shape", "dtype", "ndim", "size"}
_STATIC_ANNOTATIONS = {"int", "float", "bool", "str"}
_SHAPE_FNS = {"zeros", "ones", "full", "empty", "arange", "reshape",
              "broadcast_to", "iota", "broadcasted_iota"}
_TRACE_WRAPPERS = {"pallas_call", "shard_map", "jit", "vmap", "pmap",
                   "checkpoint", "remat", "grad", "value_and_grad"}


def _call_name(func: ast.AST) -> Optional[str]:
    """Terminal name of a call target: ``pl.pallas_call`` ->
    ``pallas_call``, ``jit`` -> ``jit``."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _static_argnames(call: ast.Call) -> Set[str]:
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            for node in ast.walk(kw.value):
                if isinstance(node, ast.Constant) and isinstance(
                        node.value, str):
                    out.add(node.value)
    return out


def _is_jit_decorator(dec: ast.AST) -> Tuple[bool, Set[str]]:
    """(is-jit, static names) for one decorator node."""
    if isinstance(dec, (ast.Name, ast.Attribute)):
        return _call_name(dec) == "jit", set()
    if isinstance(dec, ast.Call):
        name = _call_name(dec.func)
        if name == "jit":
            return True, _static_argnames(dec)
        if name == "partial":
            inner = [a for a in dec.args
                     if _call_name(a) == "jit"
                     or (isinstance(a, ast.Call)
                         and _call_name(a.func) == "jit")]
            if inner:
                return True, _static_argnames(dec)
    return False, set()


def _fn_index(tree: ast.AST) -> Dict[str, ast.FunctionDef]:
    """name -> FunctionDef for every def in the file (incl. nested;
    last definition wins on name collision — fine for lint)."""
    out: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[node.name] = node
    return out


def _partial_bindings(tree: ast.AST) -> Dict[str, Tuple[str, Set[str]]]:
    """``name = functools.partial(F, kw=...)`` / ``name = F`` ->
    {name: (F, bound-kwarg-names)}."""
    out: Dict[str, Tuple[str, Set[str]]] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        tgt = node.targets[0].id
        val = node.value
        if isinstance(val, ast.Name):
            out[tgt] = (val.id, set())
        elif (isinstance(val, ast.Call)
              and _call_name(val.func) == "partial" and val.args
              and isinstance(val.args[0], ast.Name)):
            out[tgt] = (val.args[0].id,
                        {kw.arg for kw in val.keywords if kw.arg})
    return out


def _discover(tree: ast.AST
              ) -> Tuple[Dict[str, Set[str]], Set[str], Set[str]]:
    """Traced-set discovery: ``(traced, roots, callbacks)``.

    ``traced`` maps fn-name -> extra static param names (from jit
    ``static_argnames`` / partial kwargs).  ``roots`` are functions
    entered with tracer arguments directly (jit decoration or wrapper
    call sites); ``callbacks`` are functions *referenced by name
    without being called* inside a traced body (``fori_loop``/``cond``/
    ``scan`` bodies — invoked by lax with tracers).  Everything else in
    ``traced`` is a helper whose parameter taint comes from its call
    sites (interprocedural, see the checker)."""
    fns = _fn_index(tree)
    partials = _partial_bindings(tree)
    traced: Dict[str, Set[str]] = {}
    roots: Set[str] = set()

    def mark(name: str, statics: Set[str]):
        if name in partials:
            target, bound = partials[name]
            mark(target, statics | bound)
            return
        if name in fns:
            traced.setdefault(name, set()).update(statics)
            roots.add(name)

    # decorator roots
    for name, fn in fns.items():
        for dec in fn.decorator_list:
            is_jit, statics = _is_jit_decorator(dec)
            if is_jit:
                mark(name, statics)
    # call-site roots: pallas_call/shard_map/jit/vmap(first_arg)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        cname = _call_name(node.func)
        if cname not in _TRACE_WRAPPERS or not node.args:
            continue
        first = node.args[0]
        statics = _static_argnames(node)
        if isinstance(first, ast.Name):
            mark(first.id, statics)
        elif (isinstance(first, ast.Call)
              and _call_name(first.func) == "partial" and first.args
              and isinstance(first.args[0], ast.Name)):
            mark(first.args[0].id,
                 statics | {kw.arg for kw in first.keywords if kw.arg})
    # transitive closure: a known fn name referenced inside a traced
    # body is traced too.  Split by how it is reached: the target of a
    # direct ``Call`` is a helper (call-site taint); a bare reference
    # (function passed as a value — fori_loop/scan/cond bodies) is a
    # callback, entered by lax with tracer arguments.
    callbacks: Set[str] = set()
    locals_cache: Dict[str, Set[str]] = {}

    def local_binds(name: str) -> Set[str]:
        """Names bound as plain variables inside ``fns[name]`` (params
        + store-context names).  A reference to such a name is the
        local, not the module function that happens to share it —
        without this, ``upd = lo < hi`` in a bisect body drags an
        unrelated host helper ``def upd(...)`` into the traced set."""
        if name in locals_cache:
            return locals_cache[name]
        fn = fns[name]
        out = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                               + fn.args.kwonlyargs)}
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and isinstance(
                    node.ctx, (ast.Store, ast.Del)):
                out.add(node.id)
        locals_cache[name] = out
        return out

    changed = True
    while changed:
        changed = False
        for name in list(traced):
            fn = fns.get(name)
            if fn is None:
                continue
            call_targets = set()
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and isinstance(
                        node.func, ast.Name):
                    call_targets.add(id(node.func))
            binds = local_binds(name)
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Name) and node.id in fns
                        and node.id != name
                        and isinstance(node.ctx, ast.Load)
                        and node.id not in binds):
                    continue
                if node.id not in traced:
                    traced[node.id] = set()
                    changed = True
                if (id(node) not in call_targets
                        and node.id not in callbacks):
                    callbacks.add(node.id)
                    changed = True
    return traced, roots, callbacks


def discover_traced(tree: ast.AST) -> Dict[str, Set[str]]:
    """Traced functions in one module -> {fn-name: extra static param
    names} (kwonly and annotated params are added per-function at
    check time)."""
    return _discover(tree)[0]


def _fn_static_params(fn: ast.FunctionDef, extra: Set[str]) -> Set[str]:
    statics = set(extra)
    for arg in fn.args.kwonlyargs:
        statics.add(arg.arg)
    for arg in (fn.args.args + fn.args.posonlyargs):
        ann = arg.annotation
        if (isinstance(ann, ast.Name)
                and ann.id in _STATIC_ANNOTATIONS):
            statics.add(arg.arg)
        elif (isinstance(ann, ast.Constant)
              and str(ann.value) in _STATIC_ANNOTATIONS):
            statics.add(arg.arg)
    return statics


def _expr_tainted(expr: ast.AST, tainted: Set[str]) -> bool:
    """Does ``expr`` reference a tainted name OUTSIDE a shape context
    (``x.shape``/``x.dtype``/``x.ndim``/``len(x)`` are host values)."""
    if isinstance(expr, ast.Attribute) and expr.attr in _SHAPE_ATTRS:
        return False
    if (isinstance(expr, ast.Call)
            and isinstance(expr.func, ast.Name)
            and expr.func.id == "len"):
        return False
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    for child in ast.iter_child_nodes(expr):
        if _expr_tainted(child, tainted):
            return True
    return False


def _test_tainted(test: ast.AST, tainted: Set[str]) -> bool:
    """Taint of a *branch test*: ``x is None`` / ``x is not None`` are
    trace-time-static (identity never concretizes a tracer), so
    identity comparisons are exempt even on tainted operands."""
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return False
    if isinstance(test, ast.BoolOp):
        return any(_test_tainted(v, tainted) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _test_tainted(test.operand, tainted)
    return _expr_tainted(test, tainted)


def _pos_params(fn: ast.FunctionDef) -> List[str]:
    return [a.arg for a in (fn.args.posonlyargs + fn.args.args)]


def _local_taint(fn: ast.FunctionDef, seed: Set[str]) -> Set[str]:
    """Seed params + assignment propagation to a local fixpoint."""
    tainted = set(seed)
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            if not _expr_tainted(node.value, tainted):
                continue
            for t in node.targets:
                els = (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t])
                for e in els:
                    if isinstance(e, ast.Name) and e.id not in tainted:
                        tainted.add(e.id)
                        changed = True
    return tainted


class TraceSafetyChecker(Checker):
    rules = ("trace-host-sync", "trace-py-branch", "trace-self-capture",
             "trace-dynamic-shape")
    path_patterns = ("*/kernels/*.py", "*fixture*")

    def check(self, ctx: LintContext) -> Iterable[Finding]:
        traced, roots, callbacks = _discover(ctx.tree)
        fns = _fn_index(ctx.tree)
        seeds = self._param_taint(traced, roots, callbacks, fns)
        for name in traced:
            fn = fns.get(name)
            if fn is None:
                continue
            yield from self._check_traced_fn(ctx, fn, seeds[name])

    # ------------------------------------------------------------------
    def _param_taint(self, traced: Dict[str, Set[str]], roots: Set[str],
                     callbacks: Set[str],
                     fns: Dict[str, ast.FunctionDef]
                     ) -> Dict[str, Set[str]]:
        """Interprocedural fixpoint: which params of each traced
        function actually receive tracers.

        Roots and callbacks: every non-static parameter (they are
        entered by jit/lax with tracer arguments).  Helpers: a param is
        tainted only if some traced call site passes it a tainted
        expression — branching on a trace-time-constant flag threaded
        from a root's ``static_argnames`` is fine and common (the
        ``key_wide``/``flat_w`` idiom)."""
        statics = {n: _fn_static_params(fns[n], traced[n])
                   for n in traced if n in fns}
        seeds: Dict[str, Set[str]] = {}
        for name in traced:
            if name not in fns:
                continue
            if name in roots or name in callbacks:
                params = set(_pos_params(fns[name])) | {
                    a.arg for a in fns[name].args.kwonlyargs}
                seeds[name] = params - statics[name]
            else:
                seeds[name] = set()
        changed = True
        while changed:
            changed = False
            for caller, seed in seeds.items():
                fn = fns[caller]
                tainted = _local_taint(fn, seed)
                for node in ast.walk(fn):
                    if not (isinstance(node, ast.Call)
                            and isinstance(node.func, ast.Name)
                            and node.func.id in seeds
                            and node.func.id != caller):
                        continue
                    callee = node.func.id
                    pos = _pos_params(fns[callee])
                    pairs = list(zip(pos, node.args))
                    pairs += [(kw.arg, kw.value) for kw in node.keywords
                              if kw.arg]
                    for pname, arg in pairs:
                        if (pname in statics[callee]
                                or pname in seeds[callee]):
                            continue
                        if _expr_tainted(arg, tainted):
                            seeds[callee].add(pname)
                            changed = True
        return seeds

    def _check_traced_fn(self, ctx: LintContext, fn: ast.FunctionDef,
                         seed: Set[str]) -> Iterable[Finding]:
        params = {a.arg for a in (fn.args.args + fn.args.posonlyargs
                                  + fn.args.kwonlyargs)}
        tainted = _local_taint(fn, seed)
        where = f"traced function '{fn.name}'"

        for node in ast.walk(fn):
            # ---- trace-self-capture ------------------------------------
            if isinstance(node, ast.Name) and node.id == "self":
                if "self" not in params:
                    yield Finding(
                        "trace-self-capture", ctx.path, node.lineno,
                        f"{where} closes over 'self' — mutable object "
                        f"state is baked into the compiled executable; "
                        f"hoist the needed attributes into locals first")

            # ---- trace-py-branch ---------------------------------------
            if isinstance(node, (ast.If, ast.While)):
                if _test_tainted(node.test, tainted):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield Finding(
                        "trace-py-branch", ctx.path, node.lineno,
                        f"Python '{kind}' on a traced value in {where} — "
                        f"use jnp.where/lax.cond/lax.fori_loop (a traced "
                        f"bool forces a host sync or a tracer error)")
            if isinstance(node, ast.IfExp) and _test_tainted(node.test,
                                                             tainted):
                yield Finding(
                    "trace-py-branch", ctx.path, node.lineno,
                    f"Python ternary on a traced value in {where} — "
                    f"use jnp.where")

            # ---- trace-host-sync ---------------------------------------
            if isinstance(node, ast.Call):
                func = node.func
                # np.* calls
                if (isinstance(func, ast.Attribute)
                        and isinstance(func.value, ast.Name)
                        and func.value.id in _NP_ALIASES):
                    if any(_expr_tainted(a, tainted)
                           for a in list(node.args)
                           + [kw.value for kw in node.keywords]):
                        yield Finding(
                            "trace-host-sync", ctx.path, node.lineno,
                            f"host numpy call 'np.{func.attr}(...)' in "
                            f"{where} — forces a device sync (or trace "
                            f"error); use jnp or hoist to the host "
                            f"wrapper")
                # .item()
                if (isinstance(func, ast.Attribute)
                        and func.attr == "item"):
                    yield Finding(
                        "trace-host-sync", ctx.path, node.lineno,
                        f"'.item()' in {where} — synchronous "
                        f"device->host transfer inside a trace")
                # float()/int()/bool() on tainted expressions
                if (isinstance(func, ast.Name)
                        and func.id in ("float", "int", "bool")
                        and node.args
                        and _expr_tainted(node.args[0], tainted)):
                    yield Finding(
                        "trace-host-sync", ctx.path, node.lineno,
                        f"'{func.id}()' on a traced value in {where} — "
                        f"concretizes the tracer (host sync / trace "
                        f"error); keep it a jnp array or make the input "
                        f"static")

            # ---- trace-dynamic-shape -----------------------------------
            if isinstance(node, ast.Call):
                cname = _call_name(node.func)
                if cname in _SHAPE_FNS and node.args:
                    shape_arg = node.args[0]
                    if _expr_tainted(shape_arg, tainted):
                        yield Finding(
                            "trace-dynamic-shape", ctx.path, node.lineno,
                            f"'{cname}' with a traced-value shape in "
                            f"{where} — data-dependent shapes retrace "
                            f"per batch; derive shapes from statics / "
                            f"shape buckets")
