"""tsan-lite runtime lock sanitizer.

Opt-in instrumentation that complements the static ``guarded-by``
checker (``analysis/guarded.py``) at runtime:

* **lock-order graph** — every sanitized lock acquisition records an
  edge ``held -> acquired`` per thread; a cycle in that graph is a
  lock-order inversion (a potential deadlock even if this run got
  lucky).  ``inversions()`` returns the cycles, ``assert_clean()``
  raises on any.
* **guarded-attribute access** — ``instrument(obj)`` reads the
  ``#: guarded-by:`` annotations straight from the object's class
  source (same parser as the static checker), wraps the named lock
  attributes in sanitized locks, and swaps the instance onto a proxy
  class whose ``__getattribute__``/``__setattr__`` verify the mapped
  lock is held by the accessing thread.  Accesses from the sole thread
  that has ever touched the object are exempt (single-owner warm-up /
  test setup — no race is possible until a second thread appears).

Usage with the fault harness (tests/test_locksan.py)::

    san = LockSanitizer()
    san.instrument(queue)      # MicroBatchQueue
    san.instrument(pipeline)   # EpochPipeline
    san.instrument(wal)        # IngestWAL
    ... run the workload (FaultInjector "slow" sites widen windows) ...
    san.assert_clean()

Scope: this is a test/debug harness — proxy classes add per-access
overhead and are never installed on the serving path by default.
"""

from __future__ import annotations

import inspect
import textwrap
import threading
from typing import Dict, Iterable, List, Optional, Set, Tuple

__all__ = ["LockSanitizer", "LockOrderInversion", "GuardedAccessViolation",
           "sanitize_serving_stack"]


class LockOrderInversion(AssertionError):
    pass


class GuardedAccessViolation(AssertionError):
    pass


class _SanLock:
    """Sanitized lock wrapper: context-manager compatible, records
    ownership and acquisition-order edges."""

    def __init__(self, san: "LockSanitizer", name: str, lock,
                 reentrant: Optional[bool] = None):
        self._san = san
        self.name = name
        self._lock = lock
        if reentrant is None:
            reentrant = "RLock" in type(lock).__name__
        self._reentrant = reentrant
        self._owner: Optional[int] = None
        self._count = 0

    def held_by_me(self) -> bool:
        return self._owner == threading.get_ident() and self._count > 0

    def acquire(self, *a, **kw) -> bool:
        me = threading.get_ident()
        if self._reentrant and self._owner == me:
            ok = self._lock.acquire(*a, **kw)
            if ok:
                self._count += 1
            return ok
        self._san._pre_acquire(self)
        ok = self._lock.acquire(*a, **kw)
        if ok:
            self._owner = me
            self._count += 1
            self._san._post_acquire(self)
        return ok

    def release(self) -> None:
        me = threading.get_ident()
        if self._owner != me:
            self._san._violation(
                f"lock '{self.name}' released by thread {me} which does "
                f"not own it")
        self._count -= 1
        if self._count <= 0:
            self._owner = None
            self._count = 0
            self._san._post_release(self)
        self._lock.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class LockSanitizer:
    def __init__(self):
        self._tls = threading.local()
        # (held_name, acquired_name) -> occurrences
        self.edges: Dict[Tuple[str, str], int] = {}
        self.violations: List[str] = []
        self._shared_threads: Dict[int, Set[int]] = {}
        self._meta = threading.Lock()

    # -- lock bookkeeping ------------------------------------------------
    def _held(self) -> List[_SanLock]:
        h = getattr(self._tls, "held", None)
        if h is None:
            h = self._tls.held = []
        return h

    def _pre_acquire(self, lock: _SanLock) -> None:
        with self._meta:
            for h in self._held():
                if h is not lock:
                    key = (h.name, lock.name)
                    self.edges[key] = self.edges.get(key, 0) + 1

    def _post_acquire(self, lock: _SanLock) -> None:
        self._held().append(lock)

    def _post_release(self, lock: _SanLock) -> None:
        held = self._held()
        if lock in held:
            held.remove(lock)

    def _violation(self, msg: str) -> None:
        with self._meta:
            self.violations.append(msg)

    # -- lock wrapping / object instrumentation --------------------------
    def wrap_lock(self, name: str, lock) -> _SanLock:
        if isinstance(lock, _SanLock):
            return lock
        return _SanLock(self, name, lock)

    def instrument(self, obj, guarded: Optional[Dict[str, str]] = None):
        """Instrument ``obj``: wrap its guard locks and install a proxy
        class verifying guarded-attribute discipline.  ``guarded`` maps
        attr -> lock-attr; by default it is parsed from the class
        source's ``#: guarded-by:`` annotations.  Returns ``obj``."""
        cls = type(obj)
        if getattr(cls, "_lsan_base", None) is not None:
            return obj  # already instrumented
        if guarded is None:
            from .guarded import collect_guarded_source
            src = textwrap.dedent(inspect.getsource(cls))
            guarded = collect_guarded_source(src).get(cls.__name__, {})
        if not guarded:
            raise ValueError(
                f"{cls.__name__} has no '#: guarded-by:' annotations "
                f"and no explicit guarded= map")
        for lockattr in sorted(set(guarded.values())):
            raw = getattr(obj, lockattr)
            object.__setattr__(obj, lockattr, self.wrap_lock(
                f"{cls.__name__}.{lockattr}", raw))
        with self._meta:
            self._shared_threads[id(obj)] = {threading.get_ident()}
        san = self

        class _Proxy(cls):
            _lsan_base = cls

            def __getattribute__(self, name):
                if name in guarded:
                    san._record_access(self, guarded, name, "read")
                return object.__getattribute__(self, name)

            def __setattr__(self, name, value):
                if name in guarded:
                    san._record_access(self, guarded, name, "write")
                object.__setattr__(self, name, value)

        _Proxy.__name__ = cls.__name__ + "+locksan"
        object.__setattr__(obj, "__class__", _Proxy)
        return obj

    def _record_access(self, obj, guarded: Dict[str, str], attr: str,
                       kind: str) -> None:
        lock = object.__getattribute__(obj, guarded[attr])
        if isinstance(lock, _SanLock) and lock.held_by_me():
            return
        me = threading.get_ident()
        with self._meta:
            seen = self._shared_threads.setdefault(id(obj), set())
            seen.add(me)
            shared = len(seen) > 1
        if shared:
            base = getattr(type(obj), "_lsan_base", type(obj))
            self._violation(
                f"unguarded {kind} of {base.__name__}.{attr} "
                f"(guarded-by: {guarded[attr]}) from thread {me}")

    # -- reporting -------------------------------------------------------
    def inversions(self) -> List[List[str]]:
        """Cycles in the lock-order graph (each as the list of lock
        names along the cycle)."""
        graph: Dict[str, Set[str]] = {}
        for (a, b) in self.edges:
            graph.setdefault(a, set()).add(b)
            graph.setdefault(b, set())
        cycles: List[List[str]] = []
        seen_cycles: Set[frozenset] = set()

        def dfs(node: str, path: List[str], on_path: Set[str],
                done: Set[str]):
            on_path.add(node)
            path.append(node)
            for nxt in graph.get(node, ()):
                if nxt in on_path:
                    cyc = path[path.index(nxt):]
                    key = frozenset(cyc)
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        cycles.append(list(cyc))
                elif nxt not in done:
                    dfs(nxt, path, on_path, done)
            on_path.discard(node)
            path.pop()
            done.add(node)

        done: Set[str] = set()
        for node in sorted(graph):
            if node not in done:
                dfs(node, [], set(), done)
        return cycles

    def report(self) -> dict:
        return {"edges": {f"{a} -> {b}": n
                          for (a, b), n in sorted(self.edges.items())},
                "inversions": self.inversions(),
                "violations": list(self.violations)}

    def assert_clean(self) -> None:
        inv = self.inversions()
        if inv:
            raise LockOrderInversion(
                "lock-order inversion(s): "
                + "; ".join(" -> ".join(c + [c[0]]) for c in inv))
        if self.violations:
            raise GuardedAccessViolation(
                "guarded-attribute violations: "
                + "; ".join(self.violations[:10]))


def sanitize_serving_stack(queue=None, pipeline=None, wal=None,
                           san: Optional[LockSanitizer] = None
                           ) -> LockSanitizer:
    """Instrument the standard serving trio (``MicroBatchQueue``,
    ``EpochPipeline``, ``IngestWAL``) in one call — the shape the
    fault-injection tests use."""
    san = san or LockSanitizer()
    for obj in (queue, pipeline, wal):
        if obj is not None:
            san.instrument(obj)
    return san
