"""repro-lint: repo-aware static analysis + the tsan-lite lock sanitizer.

The pluggable framework only pays off if every plug preserves the core
contracts.  After PRs 6-9 those contracts lived in docstrings and
whatever tests happened to exercise them; this package makes them
machine-enforced before tier-1 even runs (``scripts/lint.sh``, wired
into ``scripts/tier1.sh``).

Machine-checked invariants
==========================

``epoch-bump`` (analysis/epoch.py)
    Every method of ``GappedArray``/``Index``/``ShardedIndex`` that
    writes mutable index state (slot arrays, links, mechanism, shard
    list, router) must carry epoch-bump evidence in its body: a
    ``*._invalidate()`` call, a ``.version`` write (the replace-not-
    mutate retrain idiom), or a ``self._mutations`` write (the sharded
    topology counter).  Private helpers mutating on behalf of an
    already-bumped caller declare ``caller-invalidates`` in their
    docstring — an audited convention, not a free pass.

``snapshot-mutate`` (analysis/epoch.py)
    Pinned snapshots (``GapSnapshot``/``IndexSnapshot``/
    ``ShardedSnapshot``) are immutable outside ``__init__``/
    ``release``/``retain``; and any name bound from
    ``*.pin_snapshot()`` must never have attributes assigned — both
    are mutation paths that bypass the ``_invalidate`` copy-on-write
    isolation the serving pipeline's bit-identity proof rests on.

``trace-host-sync`` / ``trace-py-branch`` / ``trace-self-capture`` /
``trace-dynamic-shape`` (analysis/tracesafe.py)
    Inside functions reachable from ``jax.jit``/``pallas_call``/
    ``shard_map`` call sites in ``kernels/*``: no host numpy calls,
    ``.item()``, or ``float()``/``int()`` on traced values (device
    syncs mid-graph); no Python ``if``/``while`` on traced values (use
    ``jnp.where``/``lax.cond``); no closure capture of ``self``
    (mutable state baked into the executable goes stale after any
    mutation — hoist attributes into locals, the ``_build_fn`` idiom);
    no data-dependent shapes (shape buckets exist for a reason).

``guarded-by`` (analysis/guarded.py)
    Attributes declared ``#: guarded-by: <lockname>`` (annotated
    across ``serving/engine.py``, ``serving/pipeline.py``,
    ``serving/wal.py``, ``robustness/faults.py``) may only be accessed
    inside a lexical ``with self.<lockname>:`` block or in a method
    whose docstring declares ``lock-held: <lockname>`` (meaning every
    call site holds the lock — verified at runtime by ``locksan``).

``pair-float64`` / ``pair-raw-fma`` (analysis/pairexact.py)
    In the traced functions of ``kernels/gap_place.py``/``lookup.py``/
    ``ops_gap.py``: no float64 intermediates, and no raw ``a*b + c``
    on pair-component operands outside the fma-free error-free
    transforms (``_two_sum``/``_two_prod``/``_dd_*``) — the 2^48
    hi/lo exactness contract.

Suppression syntax
==================
``# repro-lint: disable=<rule>[,<rule>] -- <justification>`` on the
flagged line or the line above; ``# repro-lint: disable-file=<rule>``
for file scope; ``disable=all`` matches every rule.  Suppressions are
waivers, not deletions: ``python -m repro.analysis --show-suppressed``
audits the inventory, and every suppression in this repo carries its
justification inline.

Runtime sanitizer
=================
``analysis/locksan.py`` is the dynamic half of ``guarded-by``: a
tsan-lite harness that wraps the annotated locks, records the
lock-acquisition graph across ``MicroBatchQueue``/``EpochPipeline``/
``IngestWAL`` threads (cycles = lock-order inversions), and verifies
at runtime that ``lock-held:`` methods really do run under their lock.
Opt-in (tests/fault harness only), composes with
``robustness.FaultInjector`` — see tests/test_locksan.py.

CLI
===
``python -m repro.analysis [paths] [--json] [--rules r1,r2]
[--list-rules] [--show-suppressed]`` — exit 1 on any unsuppressed
finding.  ``scripts/lint.sh`` runs it over ``src/`` + ``tests/``.
"""

from .core import (Checker, Finding, LintContext, default_checkers,
                   lint_paths, lint_source, main)
from .locksan import (GuardedAccessViolation, LockOrderInversion,
                      LockSanitizer, sanitize_serving_stack)

__all__ = [
    "Checker",
    "Finding",
    "GuardedAccessViolation",
    "LintContext",
    "LockOrderInversion",
    "LockSanitizer",
    "default_checkers",
    "lint_paths",
    "lint_source",
    "main",
    "sanitize_serving_stack",
]
