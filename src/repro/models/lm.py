"""Decoder-only LM: dense GQA or MoE FFN, scan-over-layers, KV-cache serving.

Covers granite-moe-1b-a400m, kimi-k2-1t-a32b, yi-9b, internlm2-1.8b,
minicpm-2b, qwen1.5-32b, and serves as the text backbone for
internvl2-2b (vlm.py) and the decoder of whisper-base (encdec.py).

Layer stack is a ``lax.scan`` over stacked layer params (hallmark of
compile-time-sane big-model JAX) with a configurable remat policy.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import moe as _moe
from .base import (
    P,
    attention_specs,
    causal_additive_mask,
    padded_vocab,
    gqa_attention,
    mlp,
    mlp_specs,
    rms_norm,
    softmax_xent,
)

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def _stack_specs(layer_specs: Dict[str, Any], n_layers: int):
    """Prefix every per-layer spec with a scan ('layers') axis."""
    return jax.tree.map(
        lambda p: P((n_layers, *p.shape), ("layers", *p.axes), p.dtype, p.scale),
        layer_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def layer_specs(cfg):
    head_dim = cfg.head_dim or cfg.d_model // cfg.n_heads
    s = {
        "ln_attn": P((cfg.d_model,), ("embed",)),
        "ln_mlp": P((cfg.d_model,), ("embed",)),
        "attn": attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv, head_dim,
                                cfg.qkv_bias),
    }
    if cfg.moe is not None:
        s["moe"] = _moe.moe_specs(cfg.d_model, cfg.moe.d_ff_expert,
                                  cfg.moe.n_experts)
    else:
        s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff)
    return s


def param_specs(cfg):
    vp = padded_vocab(cfg.vocab)
    specs = {
        "embed": P((vp, cfg.d_model), ("vocab", "embed"), scale=1.0),
        "ln_f": P((cfg.d_model,), ("embed",)),
        "layers": _stack_specs(layer_specs(cfg), cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P((cfg.d_model, vp), ("embed", "vocab"))
    return specs


def _layer_fwd(cfg, constrain, lp, x, positions, kv_cache=None,
               cache_index=None, attn_mask=None):
    """One transformer layer.  Returns (x, new_kv)."""
    h, new_kv = gqa_attention(
        lp["attn"], rms_norm(x, lp["ln_attn"]), positions,
        causal=True, rope_theta=cfg.rope_theta,
        kv_cache=kv_cache, cache_index=cache_index, attn_mask=attn_mask,
    )
    x = constrain(x + h, ("batch", None, "embed"))
    h2 = rms_norm(x, lp["ln_mlp"])
    if cfg.moe is not None:
        mesh = getattr(constrain, "mesh", None)
        if cfg.moe_impl == "ep" and mesh is not None:
            h2 = _moe.moe_ep_shardmap(
                lp["moe"], h2, top_k=cfg.moe.top_k, mesh=mesh,
                capacity_factor=cfg.moe.capacity_factor)
        else:
            h2 = _moe.moe_gspmd(lp["moe"], h2, top_k=cfg.moe.top_k,
                                capacity_factor=cfg.moe.capacity_factor,
                                constrain=constrain)
    else:
        h2 = mlp(lp["mlp"], h2)
    return constrain(x + h2, ("batch", None, "embed")), new_kv


def forward(params, tokens, cfg, constrain=None, *, embedded=None):
    """Training/prefill-style forward (no cache).  Returns hidden (B,S,D)."""
    if constrain is None:
        constrain = lambda t, axes: t
    if embedded is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embedded
    x = constrain(x, ("batch", None, "embed"))
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    attn_mask = causal_additive_mask(positions)  # hoisted out of layers

    body = functools.partial(_layer_fwd, cfg, constrain)
    policy = REMAT_POLICIES[cfg.remat]
    if policy is not None or cfg.remat == "none":
        def scan_body(carry, lp):
            fn = body if policy is None else jax.checkpoint(body, policy=policy)
            y, _ = fn(lp, carry, positions, attn_mask=attn_mask)
            return y, ()
    else:  # pragma: no cover
        raise KeyError(cfg.remat)

    if cfg.scan_layers:
        x, _ = jax.lax.scan(scan_body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            x, _ = scan_body(x, lp)
    return rms_norm(x, params["ln_f"])


def logits_fn(params, hidden, cfg, constrain=None):
    if constrain is None:
        constrain = lambda t, axes: t
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", hidden, head)
    vp = head.shape[-1]
    if vp != cfg.vocab:  # mask padded vocab columns out of the softmax
        pad_mask = jnp.where(jnp.arange(vp) < cfg.vocab, 0.0, -1e30)
        logits = logits + pad_mask.astype(logits.dtype)
    return constrain(logits, ("batch", None, "vocab"))


def loss_fn(params, batch, cfg, constrain=None):
    """Next-token CE.  batch: {tokens (B,S) i32, labels (B,S) i32, mask}."""
    hidden = forward(params, batch["tokens"], cfg, constrain)
    logits = logits_fn(params, hidden, cfg, constrain)
    return softmax_xent(logits, batch["labels"], batch.get("mask"))


# ---------------------------------------------------------------------------
# serving: prefill builds the cache, decode appends one token
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Per-layer cache tuple: no (L, ...) stacking — avoids the giant
    slice/stack ops a stacked layout costs in unrolled serving graphs."""
    head_dim = cfg.head_dim or cfg.d_model // cfg.n_heads
    shape = (batch, cfg.n_kv, max_len, head_dim)
    return tuple({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
                 for _ in range(cfg.n_layers))


def kv_cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    head_dim = cfg.head_dim or cfg.d_model // cfg.n_heads
    shape = (batch, cfg.n_kv, max_len, head_dim)
    sds = jax.ShapeDtypeStruct(shape, dtype)
    return tuple({"k": sds, "v": sds} for _ in range(cfg.n_layers))


def _cached_stack(params, cfg, constrain, x, positions, cache, cache_index):
    """Layer stack threading per-layer KV caches (tuple of dicts).

    Scan path stacks the per-layer caches (production compile path on
    TPU); the unrolled path consumes them directly — zero slice/stack
    traffic, which is what the dry-run accounting sees."""
    body = functools.partial(_layer_fwd, cfg, constrain)

    def scan_body(carry, inp):
        lp, ck, cv = inp
        y, new_kv = body(lp, carry, positions, kv_cache=(ck, cv),
                         cache_index=cache_index)
        return y, (new_kv[0].astype(ck.dtype), new_kv[1].astype(cv.dtype))

    if cfg.scan_layers:
        ks = jnp.stack([c["k"] for c in cache])
        vs = jnp.stack([c["v"] for c in cache])
        x, (nk, nv) = jax.lax.scan(scan_body, x, (params["layers"], ks, vs))
        return x, tuple({"k": nk[i], "v": nv[i]}
                        for i in range(cfg.n_layers))
    out = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda t: t[i], params["layers"])
        x, (nk, nv) = scan_body(x, (lp, cache[i]["k"], cache[i]["v"]))
        out.append({"k": nk, "v": nv})
    return x, tuple(out)


def prefill(params, tokens, cache, cfg, constrain=None, *, embedded=None):
    """Prefill: runs the full prompt, fills cache.  Returns (logits_last,
    cache).  tokens: (B, S)."""
    if constrain is None:
        constrain = lambda t, axes: t
    x = jnp.take(params["embed"], tokens, axis=0) if embedded is None else embedded
    x = constrain(x, ("batch", None, "embed"))
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x, cache = _cached_stack(params, cfg, constrain, x, positions, cache,
                             jnp.int32(0))
    hidden = rms_norm(x[:, -1:], params["ln_f"])
    return logits_fn(params, hidden, cfg, constrain)[:, 0], cache


def decode_step(params, tokens, cache, cache_index, cfg, constrain=None):
    """One decode step.  tokens: (B, 1); cache_index: scalar i32 (#valid).
    Returns (logits (B, V), cache)."""
    if constrain is None:
        constrain = lambda t, axes: t
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, ("batch", None, "embed"))
    B = x.shape[0]
    positions = jnp.broadcast_to(cache_index[None, None], (B, 1))
    x, cache = _cached_stack(params, cfg, constrain, x, positions, cache,
                             cache_index)
    hidden = rms_norm(x, params["ln_f"])
    return logits_fn(params, hidden, cfg, constrain)[:, 0], cache
