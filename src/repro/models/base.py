"""Functional model substrate: param specs, logical axes, common layers.

No flax — params are plain pytrees of jnp arrays.  Every parameter is
declared as a :class:`P` spec carrying shape, dtype, init scale and
*logical axis names*; ``repro.dist.partitioning`` maps logical names to
mesh axes (the single place sharding policy lives).

Logical axis vocabulary:
  "batch"   tokens/batch dim            -> ("pod", "data")
  "vocab"   vocabulary                  -> "model"
  "embed"   d_model                     -> None (or "data" under FSDP)
  "heads"   attention heads             -> "model"
  "kv"      kv heads                    -> "model"
  "ffn"     mlp hidden                  -> "model"
  "experts" MoE experts                 -> "model"
  "layers"  scan-stacked layer dim      -> None
  everything else                       -> None
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class P:
    """Parameter spec: shape + logical axes (+dtype, init scale)."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    scale: float = 1.0  # stddev multiplier over 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def abstract_params(spec_tree) -> Params:
    """ShapeDtypeStruct tree (no allocation) from a spec tree."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def logical_axes(spec_tree):
    """Tree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(
        lambda p: p.axes, spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def init_params(spec_tree, key) -> Params:
    """Real initialization (smoke tests / examples; dry-run never calls)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        if len(p.shape) == 0:
            out.append(jnp.zeros(p.shape, p.dtype))
            continue
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = p.scale / np.sqrt(max(fan_in, 1))
        out.append((jax.random.normal(k, p.shape, jnp.float32) * std).astype(p.dtype))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# layers (pure functions over param dicts)
# ---------------------------------------------------------------------------


VOCAB_PAD = 256


def padded_vocab(vocab: int) -> int:
    """Vocab rounded up so embedding/logits shard over the model axis;
    padded logit columns are masked to -inf in logits_fn."""
    return -(-vocab // VOCAB_PAD) * VOCAB_PAD


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding.  x: (..., S, H, Dh), positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def attention_specs(d_model, n_heads, n_kv, head_dim, qkv_bias=False):
    s = {
        "wq": P((d_model, n_heads, head_dim), ("embed", "heads", None)),
        "wk": P((d_model, n_kv, head_dim), ("embed", "kv", None)),
        "wv": P((d_model, n_kv, head_dim), ("embed", "kv", None)),
        "wo": P((n_heads, head_dim, d_model), ("heads", None, "embed")),
    }
    if qkv_bias:
        s["bq"] = P((n_heads, head_dim), ("heads", None))
        s["bk"] = P((n_kv, head_dim), ("kv", None))
        s["bv"] = P((n_kv, head_dim), ("kv", None))
    return s


def gqa_attention(
    params,
    x,                      # (B, S, D)
    positions,              # (B, S)
    *,
    causal: bool = True,
    rope_theta: float = 10_000.0,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B,KV,T,Dh) x2
    cache_index: Optional[jax.Array] = None,  # scalar: #valid cache entries
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    attn_mask: Optional[jax.Array] = None,  # precomputed additive (B,S,T)
):
    """Grouped-query attention with optional KV cache / cross-attention.

    Returns (out (B,S,D), new_kv or None).  Cache layout (B, KV, T, Dh).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    if kv_override is not None:
        k, v = kv_override
        new_kv = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        if "bk" in params:
            k = k + params["bk"]
            v = v + params["bv"]
        k = rope(k, positions, rope_theta)
        k = jnp.swapaxes(k, 1, 2)  # (B, KV, S, Dh)
        v = jnp.swapaxes(v, 1, 2)
        if kv_cache is not None:
            ck, cv = kv_cache
            k = jax.lax.dynamic_update_slice(
                ck, k.astype(ck.dtype), (0, 0, cache_index, 0)
            )
            v = jax.lax.dynamic_update_slice(
                cv, v.astype(cv.dtype), (0, 0, cache_index, 0)
            )
        new_kv = (k, v)
    q = rope(q, positions, rope_theta)

    n_heads = q.shape[2]
    n_kv = k.shape[1]
    group = n_heads // n_kv
    T = k.shape[2]
    # fold the softmax scale into q: saves one full pass over the S x T
    # score tensor per layer (bytes-visible in the roofline)
    qh = (q * (1.0 / np.sqrt(q.shape[-1]))).astype(q.dtype)
    qh = qh.reshape(B, S, n_kv, group, -1)
    scores = jnp.einsum("bsngk,bntk->bngst", qh, k).astype(jnp.float32)

    if attn_mask is not None:
        # hoisted additive mask: built ONCE per forward, reused by every
        # layer (the per-layer bool mask + where costs n_layers * S*T)
        scores = scores + attn_mask[:, None, None, :, :]
    else:
        # mask[b, s_query, t_key]; positions are ABSOLUTE (shared w/ RoPE)
        key_pos = jnp.arange(T)
        if kv_cache is not None:
            valid = key_pos[None, None, :] < (cache_index + S)
            if causal:
                mask = valid & (key_pos[None, None, :]
                                <= positions[:, :, None])
            else:
                mask = jnp.broadcast_to(valid, (B, S, T))
        elif causal:
            mask = positions[:, None, :] <= positions[:, :, None]
        else:
            mask = None
        if mask is not None:
            scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngst,bntk->bsngk", probs, v)
    out = out.reshape(B, S, n_heads, -1)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, new_kv


def mlp_specs(d_model, d_ff, gated=True):
    if gated:
        return {
            "w_gate": P((d_model, d_ff), ("embed", "ffn")),
            "w_up": P((d_model, d_ff), ("embed", "ffn")),
            "w_down": P((d_ff, d_model), ("ffn", "embed")),
        }
    return {
        "w_up": P((d_model, d_ff), ("embed", "ffn")),
        "w_down": P((d_ff, d_model), ("ffn", "embed")),
    }


def mlp(params, x):
    if "w_gate" in params:
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


def softmax_xent(logits, labels, mask=None):
    """Mean CE over valid tokens; logits (..., V) f32-upcast."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def causal_additive_mask(positions, T: Optional[int] = None,
                         cache_index=None, S: Optional[int] = None):
    """Additive f32 mask built once per forward (hoisted out of layers)."""
    if T is None:
        mask = positions[:, None, :] <= positions[:, :, None]
    else:
        key_pos = jnp.arange(T)
        valid = key_pos[None, None, :] < (cache_index + S)
        mask = valid & (key_pos[None, None, :] <= positions[:, :, None])
    return jnp.where(mask, 0.0, -1e30).astype(jnp.float32)
