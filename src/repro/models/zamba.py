"""Zamba2-style hybrid: Mamba2 backbone + one *shared* GQA attention block
applied every ``attn_every`` layers (arXiv:2411.15242).

The shared block attends over [hidden ; original embedding] concatenated
(Zamba's trick to refresh the residual stream) and is the only quadratic
component — at decode it keeps a single KV cache, so long_500k decodes
with O(seq) attention reads once per ``attn_every`` mamba layers.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from .base import (
    P,
    attention_specs,
    padded_vocab,
    gqa_attention,
    mlp,
    mlp_specs,
    rms_norm,
    softmax_xent,
)
from .lm import REMAT_POLICIES, _stack_specs, logits_fn
from .ssm import SSMCache, init_ssm_cache, mamba2_forward, mamba2_specs


def _ssm_geometry(cfg):
    n_heads = cfg.n_heads
    head_dim = (2 * cfg.d_model) // n_heads  # expand=2
    return n_heads, head_dim, cfg.ssm_state


def param_specs(cfg):
    n_heads, head_dim, d_state = _ssm_geometry(cfg)
    mamba = {
        "ln": P((cfg.d_model,), ("embed",)),
        "ssm": mamba2_specs(cfg.d_model, n_heads, head_dim, d_state),
    }
    shared = {
        "ln_attn": P((2 * cfg.d_model,), ("embed",)),
        "attn": attention_specs(2 * cfg.d_model, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim_),
        "w_proj": P((2 * cfg.d_model, cfg.d_model), (None, "embed")),
        "ln_mlp": P((cfg.d_model,), ("embed",)),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff),
    }
    vp = padded_vocab(cfg.vocab)
    return {
        "embed": P((vp, cfg.d_model), ("vocab", "embed")),
        "ln_f": P((cfg.d_model,), ("embed",)),
        "mamba_layers": _stack_specs(mamba, cfg.n_layers),
        "shared_attn": shared,
        "lm_head": P((cfg.d_model, vp), ("embed", "vocab")),
    }


def _shared_block(params, x, x0, positions, cfg, constrain,
                  kv_cache=None, cache_index=None):
    """Shared attention over [x ; x0] -> project back to d_model."""
    sp = params["shared_attn"]
    cat = jnp.concatenate([x, x0], axis=-1)
    a, new_kv = gqa_attention(sp["attn"], rms_norm(cat, sp["ln_attn"]),
                              positions, causal=True,
                              rope_theta=cfg.rope_theta,
                              kv_cache=kv_cache, cache_index=cache_index)
    x = constrain(x + a @ sp["w_proj"], ("batch", None, "embed"))
    h = mlp(sp["mlp"], rms_norm(x, sp["ln_mlp"]))
    return constrain(x + h, ("batch", None, "embed")), new_kv


def _groups(cfg):
    """Layer indices after which the shared block runs."""
    return [i for i in range(cfg.n_layers) if (i + 1) % cfg.attn_every == 0]


def forward(params, tokens, cfg, constrain=None, *, caches=None,
            cache_index=None):
    """Training forward (caches=None) or cached decode.

    caches: {"ssm": SSMCache stacked (L, ...), "k"/"v": shared attn KV}.
    """
    if constrain is None:
        constrain = lambda t, axes: t
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, ("batch", None, "embed"))
    x0 = x
    B, S = x.shape[0], x.shape[1]
    if cache_index is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    else:
        positions = cache_index[None, None] + jnp.broadcast_to(
            jnp.arange(S)[None, :], (B, S))
    n_heads, head_dim, d_state = _ssm_geometry(cfg)
    policy = REMAT_POLICIES[cfg.remat]
    attn_after = set(_groups(cfg))

    def mamba_body(lp, h, cache: Optional[SSMCache]):
        o, new_cache = mamba2_forward(
            lp["ssm"], rms_norm(h, lp["ln"]), n_heads=n_heads,
            head_dim=head_dim, d_state=d_state, cache=cache)
        return constrain(h + o, ("batch", None, "embed")), new_cache

    new_ssm = []
    new_attn_kv = []  # one KV history per shared-block application
    app = 0
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda t: t[i], params["mamba_layers"])
        cache_i = (None if caches is None
                   else jax.tree.map(lambda t: t[i], caches["ssm"]))
        fn = mamba_body if policy is None else jax.checkpoint(
            mamba_body, policy=policy, static_argnums=())
        x, nc = fn(lp, x, cache_i)
        if nc is not None:
            new_ssm.append(nc)
        if i in attn_after:
            kvc = (None if caches is None
                   else (caches["k"][app], caches["v"][app]))
            x, kv = _shared_block(
                params, x, x0, positions, cfg, constrain,
                kv_cache=kvc, cache_index=cache_index)
            new_attn_kv.append(kv)
            app += 1
    hidden = rms_norm(x, params["ln_f"])
    out_caches = None
    if caches is not None:
        out_caches = {
            "ssm": jax.tree.map(lambda *ts: jnp.stack(ts), *new_ssm),
            "k": (jnp.stack([kv[0] for kv in new_attn_kv]).astype(
                caches["k"].dtype) if new_attn_kv else caches["k"]),
            "v": (jnp.stack([kv[1] for kv in new_attn_kv]).astype(
                caches["v"].dtype) if new_attn_kv else caches["v"]),
        }
    return hidden, out_caches


def loss_fn(params, batch, cfg, constrain=None):
    hidden, _ = forward(params, batch["tokens"], cfg, constrain)
    logits = logits_fn(params, hidden, cfg, constrain)
    return softmax_xent(logits, batch["labels"], batch.get("mask"))


def decode_step(params, tokens, caches, cache_index, cfg, constrain=None):
    hidden, caches = forward(params, tokens, cfg, constrain, caches=caches,
                             cache_index=cache_index)
    logits = logits_fn(params, hidden, cfg, constrain)[:, 0]
    return logits, caches


def cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_heads, head_dim, d_state = _ssm_geometry(cfg)
    conv_ch = n_heads * head_dim + 2 * d_state
    L = cfg.n_layers
    A = len(_groups(cfg))
    return {
        "ssm": SSMCache(
            conv=jax.ShapeDtypeStruct((L, batch, 3, conv_ch), dtype),
            state=jax.ShapeDtypeStruct((L, batch, n_heads, d_state, head_dim),
                                       dtype),
        ),
        "k": jax.ShapeDtypeStruct(
            (A, batch, cfg.n_kv, max_len, cfg.head_dim_), dtype),
        "v": jax.ShapeDtypeStruct(
            (A, batch, cfg.n_kv, max_len, cfg.head_dim_), dtype),
    }


def init_caches(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_heads, head_dim, d_state = _ssm_geometry(cfg)
    per_layer = init_ssm_cache(batch, n_heads, head_dim, d_state, dtype=dtype)
    L = cfg.n_layers
    A = len(_groups(cfg))
    return {
        "ssm": SSMCache(
            conv=jnp.broadcast_to(per_layer.conv[None],
                                  (L, *per_layer.conv.shape)).copy(),
            state=jnp.broadcast_to(per_layer.state[None],
                                   (L, *per_layer.state.shape)).copy(),
        ),
        "k": jnp.zeros((A, batch, cfg.n_kv, max_len, cfg.head_dim_), dtype),
        "v": jnp.zeros((A, batch, cfg.n_kv, max_len, cfg.head_dim_), dtype),
    }
