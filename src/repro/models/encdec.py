"""Whisper-style encoder-decoder (audio frontend stubbed per assignment).

Encoder: bidirectional self-attention over precomputed frame embeddings
(the conv stem is a stub — ``input_specs`` supplies (B, F, D) frames).
Decoder: causal self-attention + cross-attention to encoder output.
Serving: decoder decode step with self-KV cache + precomputed cross-KV.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from .base import (
    P,
    attention_specs,
    padded_vocab,
    gqa_attention,
    mlp,
    mlp_specs,
    rms_norm,
    softmax_xent,
)
from .lm import REMAT_POLICIES, _stack_specs, logits_fn


def _enc_layer_specs(cfg):
    return {
        "ln_attn": P((cfg.d_model,), ("embed",)),
        "ln_mlp": P((cfg.d_model,), ("embed",)),
        "attn": attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                cfg.head_dim_),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, gated=False),
    }


def _dec_layer_specs(cfg):
    s = _enc_layer_specs(cfg)
    s["ln_cross"] = P((cfg.d_model,), ("embed",))
    s["cross"] = attention_specs(cfg.d_model, cfg.n_heads, cfg.n_kv,
                                 cfg.head_dim_)
    return s


def param_specs(cfg):
    vp = padded_vocab(cfg.vocab)
    return {
        "embed": P((vp, cfg.d_model), ("vocab", "embed")),
        "pos_enc": P((cfg.n_frontend_tokens, cfg.d_model), (None, "embed")),
        "ln_enc": P((cfg.d_model,), ("embed",)),
        "ln_f": P((cfg.d_model,), ("embed",)),
        "enc_layers": _stack_specs(_enc_layer_specs(cfg), cfg.n_enc_layers),
        "dec_layers": _stack_specs(_dec_layer_specs(cfg), cfg.n_layers),
        "lm_head": P((cfg.d_model, vp), ("embed", "vocab")),
    }


def encode(params, frames, cfg, constrain):
    """frames: (B, F, D) stub frame embeddings -> encoder states."""
    x = frames + params["pos_enc"][None, : frames.shape[1]]
    x = constrain(x, ("batch", None, "embed"))
    B, F = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(F)[None, :], (B, F))
    policy = REMAT_POLICIES[cfg.remat]

    def body(lp, h):
        a, _ = gqa_attention(lp["attn"], rms_norm(h, lp["ln_attn"]),
                             positions, causal=False,
                             rope_theta=cfg.rope_theta)
        h = constrain(h + a, ("batch", None, "embed"))
        return h + mlp(lp["mlp"], rms_norm(h, lp["ln_mlp"]))

    def scan_body(carry, lp):
        fn = body if policy is None else jax.checkpoint(body, policy=policy)
        return fn(lp, carry), ()

    if cfg.scan_layers:
        x, _ = jax.lax.scan(scan_body, x, params["enc_layers"])
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = scan_body(x, jax.tree.map(lambda t: t[i],
                                             params["enc_layers"]))
    return rms_norm(x, params["ln_enc"])


def _cross_kv(lp, enc_out):
    k = jnp.einsum("bfd,dhk->bhfk", enc_out, lp["cross"]["wk"])
    v = jnp.einsum("bfd,dhk->bhfk", enc_out, lp["cross"]["wv"])
    return k, v


def _dec_layer(cfg, constrain, lp, x, positions, enc_out=None,
               kv_cache=None, cache_index=None, cross_kv=None):
    a, new_kv = gqa_attention(lp["attn"], rms_norm(x, lp["ln_attn"]),
                              positions, causal=True,
                              rope_theta=cfg.rope_theta,
                              kv_cache=kv_cache, cache_index=cache_index)
    x = constrain(x + a, ("batch", None, "embed"))
    if cross_kv is None:
        cross_kv = _cross_kv(lp, enc_out)
    c, _ = gqa_attention(lp["cross"], rms_norm(x, lp["ln_cross"]), positions,
                         causal=False, rope_theta=cfg.rope_theta,
                         kv_override=cross_kv)
    x = constrain(x + c, ("batch", None, "embed"))
    h = mlp(lp["mlp"], rms_norm(x, lp["ln_mlp"]))
    return constrain(x + h, ("batch", None, "embed")), new_kv


def loss_fn(params, batch, cfg, constrain=None):
    """batch: frames (B,F,D), tokens (B,S), labels (B,S) [, mask]."""
    if constrain is None:
        constrain = lambda t, axes: t
    enc_out = encode(params, batch["frames"], cfg, constrain)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = constrain(x, ("batch", None, "embed"))
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    policy = REMAT_POLICIES[cfg.remat]
    body = functools.partial(_dec_layer, cfg, constrain)

    def scan_body(carry, lp):
        fn = body if policy is None else jax.checkpoint(body, policy=policy)
        y, _ = fn(lp, carry, positions, enc_out=enc_out)
        return y, ()

    if cfg.scan_layers:
        x, _ = jax.lax.scan(scan_body, x, params["dec_layers"])
    else:
        for i in range(cfg.n_layers):
            x, _ = scan_body(x, jax.tree.map(lambda t: t[i],
                                             params["dec_layers"]))
    hidden = rms_norm(x, params["ln_f"])
    logits = logits_fn(params, hidden, cfg, constrain)
    return softmax_xent(logits, batch["labels"], batch.get("mask"))


def decode_step(params, tokens, caches, cache_index, cfg, constrain=None):
    """One decoder step.  caches: {"k","v" (L,B,KV,T,Dh), "ck","cv"
    (L,B,KV,F,Dh) precomputed cross-KV}."""
    if constrain is None:
        constrain = lambda t, axes: t
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, ("batch", None, "embed"))
    B = x.shape[0]
    positions = jnp.broadcast_to(cache_index[None, None], (B, 1))
    body = functools.partial(_dec_layer, cfg, constrain)

    def scan_body(carry, inp):
        lp, ck, cv, xk, xv = inp
        y, new_kv = body(lp, carry, positions, kv_cache=(ck, cv),
                         cache_index=cache_index, cross_kv=(xk, xv))
        return y, (new_kv[0].astype(ck.dtype), new_kv[1].astype(cv.dtype))

    ins = (params["dec_layers"], caches["k"], caches["v"],
           caches["ck"], caches["cv"])
    if cfg.scan_layers:
        x, (nk, nv) = jax.lax.scan(scan_body, x, ins)
    else:
        nks, nvs = [], []
        for i in range(cfg.n_layers):
            x, (nk1, nv1) = scan_body(
                x, jax.tree.map(lambda t: t[i], ins))
            nks.append(nk1)
            nvs.append(nv1)
        nk, nv = jnp.stack(nks), jnp.stack(nvs)
    hidden = rms_norm(x, params["ln_f"])
    logits = logits_fn(params, hidden, cfg, constrain)[:, 0]
    return logits, {**caches, "k": nk, "v": nv}
