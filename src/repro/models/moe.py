"""Mixture-of-Experts FFN: top-k router + capacity-based dispatch.

Two interchangeable implementations (same math, same params):

* ``moe_gspmd`` — index-scatter dispatch expressed in plain einsum/scatter;
  GSPMD derives the collectives from sharding constraints (experts on
  "model").  This is the *baseline* the roofline measures.
* ``moe_ep_shardmap`` — explicit expert parallelism: shard_map over the
  model axis with hand-placed ``all_to_all`` dispatch/combine (the
  beyond-paper optimization exercised in §Perf hillclimbing).

Capacity: each expert accepts at most C = ceil(T_local*k/E * cf) tokens;
overflow tokens are dropped (contribute zero) like Switch/GShard.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import P


def moe_specs(d_model: int, d_ff: int, n_experts: int):
    return {
        "router": P((d_model, n_experts), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": P((n_experts, d_model, d_ff), ("experts", "embed", "ffn")),
        "w_up": P((n_experts, d_model, d_ff), ("experts", "embed", "ffn")),
        "w_down": P((n_experts, d_ff, d_model), ("experts", "ffn", "embed")),
    }


def _route(params, x2d, top_k: int):
    """Router: top-k expert ids + renormalized weights.  x2d: (T, D)."""
    logits = (x2d.astype(jnp.float32) @ params["router"])  # (T, E)
    weights, experts = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9
    )
    return experts, weights.astype(x2d.dtype), logits


def _capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    c = int(np.ceil(n_tokens * top_k / n_experts * cf))
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for TPU-friendly shapes


def moe_gspmd(params, x, *, top_k: int, capacity_factor: float = 1.25,
              constrain=None):
    """Capacity MoE via scatter dispatch; sharding left to GSPMD.

    x: (B, S, D) -> (B, S, D).  ``constrain(tensor, logical_axes)`` applies
    sharding constraints (injected by the distribution layer; identity in
    tests).
    """
    if constrain is None:
        constrain = lambda t, axes: t
    B, S, D = x.shape
    E = params["router"].shape[1]
    T = B * S
    x2d = x.reshape(T, D)
    experts, weights, _ = _route(params, x2d, top_k)  # (T, k)

    C = _capacity(T, top_k, E, capacity_factor)
    # position of each (token, k) within its expert, by arrival order
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.int32)      # (T, k, E)
    flat = onehot.reshape(T * top_k, E)
    pos_in_e = jnp.cumsum(flat, axis=0) * flat - 1            # (T*k, E)
    pos = jnp.max(pos_in_e, axis=-1)                          # (T*k,)
    e_flat = experts.reshape(T * top_k)
    keep = pos < C

    # scatter tokens into (E, C, D) buffers; dropped tokens -> row C (waste row)
    buf = jnp.zeros((E, C + 1, D), x.dtype)
    slot = jnp.where(keep, pos, C)
    src = jnp.repeat(x2d, top_k, axis=0)                      # (T*k, D)
    buf = buf.at[e_flat, slot].add(src)
    buf = constrain(buf, ("experts", None, "embed"))[:, :C, :]

    # expert FFN (swiglu), experts sharded on "model"
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = constrain(h, ("experts", None, "ffn"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = constrain(out_buf, ("experts", None, "embed"))

    # combine: gather each (token, k) result, weight, sum over k
    gathered = out_buf[e_flat, jnp.minimum(slot, C - 1)]      # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    gathered = gathered.reshape(T, top_k, D) * weights[..., None]
    return gathered.sum(axis=1).reshape(B, S, D)


def moe_ep_shardmap(params, x, *, top_k: int, mesh, model_axis: str = "model",
                    capacity_factor: float = 1.25):
    """Explicit expert parallelism (hillclimb variant).

    Tokens sharded over all mesh axes; experts sharded over the model
    axis.  Dispatch/combine are single ``all_to_all`` pairs instead of the
    GSPMD-derived gather/scatter collectives.
    """
    from jax.sharding import PartitionSpec as PS
    from jax.experimental.shard_map import shard_map

    E = params["router"].shape[1]
    ep = mesh.shape[model_axis]
    assert E % ep == 0, (E, ep)
    e_local = E // ep
    B, S, D = x.shape
    batch_axes = tuple(a for a in mesh.axis_names if a != model_axis)

    def local_fn(router, w_gate, w_up, w_down, xl):
        # xl: (b_l, s_l, D) — batch sharded over data axes, seq over model
        b_l, s_l = xl.shape[0], xl.shape[1]
        t_l = b_l * s_l
        x2d = xl.reshape(t_l, D)
        prm = {"router": router}
        experts, weights, _ = _route(prm, x2d, top_k)
        C = _capacity(t_l, top_k, E, capacity_factor)

        onehot = jax.nn.one_hot(experts, E, dtype=jnp.int32)
        flat = onehot.reshape(t_l * top_k, E)
        pos = jnp.max(jnp.cumsum(flat, axis=0) * flat - 1, axis=-1)
        e_flat = experts.reshape(t_l * top_k)
        keep = pos < C
        slot = jnp.where(keep, pos, C)

        buf = jnp.zeros((E, C + 1, D), xl.dtype)
        buf = buf.at[e_flat, slot].add(jnp.repeat(x2d, top_k, axis=0))
        buf = buf[:, :C, :].reshape(ep, e_local, C, D)
        # dispatch: tokens routed to the device owning their expert
        buf = jax.lax.all_to_all(buf, model_axis, 0, 0, tiled=False)
        # buf now (ep, e_local, C, D): rows from every source device
        h = jax.nn.silu(jnp.einsum("pecd,edf->pecf", buf, w_gate))
        h = h * jnp.einsum("pecd,edf->pecf", buf, w_up)
        out = jnp.einsum("pecf,efd->pecd", h, w_down)
        # combine: send results back to token owners
        out = jax.lax.all_to_all(out, model_axis, 0, 0, tiled=False)
        out = out.reshape(E, C, D)
        pad = jnp.zeros((E, 1, D), out.dtype)
        out = jnp.concatenate([out, pad], axis=1)
        gathered = out[e_flat, slot]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        gathered = gathered.reshape(t_l, top_k, D) * weights[..., None]
        return gathered.sum(axis=1).reshape(b_l, s_l, D)

    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            PS(),                      # router replicated
            PS(model_axis, None, None),
            PS(model_axis, None, None),
            PS(model_axis, None, None),
            # batch over data axes, sequence over the model axis: every
            # device owns a token shard => all_to_all is the only collective
            PS(batch_axes, model_axis, None),
        ),
        out_specs=PS(batch_axes, model_axis, None),
        check_rep=False,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)
