"""InternVL2-2B: stub InternViT frontend + InternLM2-2B text backbone.

Per the assignment, the vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_patches, d_frontend); only the MLP
projector (2-layer, as in InternVL) and the LM backbone are real.
Patch tokens are prepended to the text sequence; loss is computed on the
text positions only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import lm as _lm
from .base import P, rms_norm, softmax_xent


def param_specs(cfg):
    specs = _lm.param_specs(cfg)
    specs["projector"] = {
        "ln": P((cfg.d_frontend,), (None,)),
        "w1": P((cfg.d_frontend, cfg.d_model), (None, "embed")),
        "w2": P((cfg.d_model, cfg.d_model), ("embed", "embed")),
    }
    return specs


def _project(params, patches):
    p = params["projector"]
    h = rms_norm(patches, p["ln"])
    return jax.nn.gelu(h @ p["w1"]) @ p["w2"]


def loss_fn(params, batch, cfg, constrain=None):
    """batch: patches (B,P,Dv), tokens (B,S), labels (B,S)."""
    if constrain is None:
        constrain = lambda t, axes: t
    vis = _project(params, batch["patches"]).astype(jnp.bfloat16)
    txt = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = jnp.concatenate([vis, txt], axis=1)
    hidden = _lm.forward(params, None, cfg, constrain, embedded=x)
    n_p = vis.shape[1]
    logits = _lm.logits_fn(params, hidden[:, n_p:], cfg, constrain)
    return softmax_xent(logits, batch["labels"], batch.get("mask"))


def prefill(params, batch, cache, cfg, constrain=None):
    """Multimodal prefill: patches + prompt tokens fill the cache."""
    if constrain is None:
        constrain = lambda t, axes: t
    vis = _project(params, batch["patches"]).astype(jnp.bfloat16)
    txt = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = jnp.concatenate([vis, txt], axis=1)
    return _lm.prefill(params, None, cache, cfg, constrain, embedded=x)


decode_step = _lm.decode_step          # text-only decode after prefill
init_kv_cache = _lm.init_kv_cache
kv_cache_specs = _lm.kv_cache_specs
