"""Unified model API: one object per architecture family.

``build_model(cfg)`` returns a :class:`Model` exposing:

  param_specs / abstract_params / logical_axes / init_params
  loss_fn(params, batch, constrain)           -> scalar
  prefill_fn(params, batch, cache, constrain) -> (logits, cache)   [if any]
  decode_fn(params, batch, cache, idx, constrain) -> (logits, cache)
  cache_specs(batch, max_len) / init_caches(batch, max_len)
  input_specs(shape)  -> ShapeDtypeStruct batch for the dry-run
  input_sample(shape, key) -> real batch for smoke tests
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from . import base as _base
from . import encdec as _encdec
from . import lm as _lm
from . import vlm as _vlm
from . import xlstm_lm as _xlstm
from . import zamba as _zamba


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    _specs: Any
    loss_fn: Callable
    decode_fn: Optional[Callable] = None
    prefill_fn: Optional[Callable] = None
    cache_specs: Optional[Callable] = None
    init_caches: Optional[Callable] = None

    def param_specs(self):
        return self._specs

    def abstract_params(self):
        return _base.abstract_params(self._specs)

    def logical_axes(self):
        return _base.logical_axes(self._specs)

    def init_params(self, key):
        return _base.init_params(self._specs, key)

    def param_count(self) -> int:
        return sum(
            int(np.prod(s.shape))
            for s in jax.tree.leaves(self.abstract_params())
        )

    def active_param_count(self) -> int:
        """6*N*D accounting for MoE: routed-expert share scaled by top_k/E."""
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
            self.abstract_params()
        )[0]:
            n = int(np.prod(leaf.shape))
            keys = "/".join(str(p) for p in path)
            if self.cfg.moe and ("w_gate" in keys or "w_up" in keys
                                 or "w_down" in keys) and "moe" in keys:
                n = n * self.cfg.moe.top_k // self.cfg.moe.n_experts
            total += n
        return total

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """Dry-run stand-ins: weak-type-correct, shardable, no allocation."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.kind == "train":
            batch = {"tokens": tok(B, S), "labels": tok(B, S)}
            if cfg.arch == "encdec":
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
            if cfg.arch == "vlm":
                batch["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.bfloat16)
            return batch
        if shape.kind == "prefill":
            batch = {"tokens": tok(B, S)}
            if cfg.arch == "encdec":
                batch["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
            if cfg.arch == "vlm":
                batch["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_frontend_tokens, cfg.d_frontend), jnp.bfloat16)
            return batch
        if shape.kind == "decode":
            return {"tokens": tok(B, 1)}
        raise KeyError(shape.kind)

    def input_sample(self, shape: ShapeConfig, key) -> Dict[str, Any]:
        specs = self.input_specs(shape)
        out = {}
        for name, s in specs.items():
            key, k = jax.random.split(key)
            if jnp.issubdtype(s.dtype, jnp.integer):
                out[name] = jax.random.randint(k, s.shape, 0, self.cfg.vocab,
                                               dtype=s.dtype)
            else:
                out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(
                    s.dtype)
        return out


def build_model(cfg: ModelConfig) -> Model:
    if cfg.arch == "lm":
        return Model(
            cfg=cfg,
            _specs=_lm.param_specs(cfg),
            loss_fn=lambda p, b, c=None: _lm.loss_fn(p, b, cfg, c),
            prefill_fn=lambda p, b, cache, c=None: _lm.prefill(
                p, b["tokens"], cache, cfg, c),
            decode_fn=lambda p, b, cache, idx, c=None: _lm.decode_step(
                p, b["tokens"], cache, idx, cfg, c),
            cache_specs=lambda batch, max_len: _lm.kv_cache_specs(
                cfg, batch, max_len),
            init_caches=lambda batch, max_len: _lm.init_kv_cache(
                cfg, batch, max_len),
        )
    if cfg.arch == "vlm":
        return Model(
            cfg=cfg,
            _specs=_vlm.param_specs(cfg),
            loss_fn=lambda p, b, c=None: _vlm.loss_fn(p, b, cfg, c),
            prefill_fn=lambda p, b, cache, c=None: _vlm.prefill(
                p, b, cache, cfg, c),
            decode_fn=lambda p, b, cache, idx, c=None: _vlm.decode_step(
                p, b["tokens"], cache, idx, cfg, c),
            cache_specs=lambda batch, max_len: _vlm.kv_cache_specs(
                cfg, batch, max_len),
            init_caches=lambda batch, max_len: _vlm.init_kv_cache(
                cfg, batch, max_len),
        )
    if cfg.arch == "encdec":
        def enc_cache_specs(batch, max_len):
            shape = (cfg.n_layers, batch, cfg.n_kv, max_len, cfg.head_dim_)
            sds = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
            F = cfg.n_frontend_tokens
            cross = jax.ShapeDtypeStruct(
                (cfg.n_layers, batch, cfg.n_kv, F, cfg.head_dim_),
                jnp.bfloat16)
            return {"k": sds, "v": sds, "ck": cross, "cv": cross}

        def enc_init_caches(batch, max_len):
            shape = (cfg.n_layers, batch, cfg.n_kv, max_len, cfg.head_dim_)
            F = cfg.n_frontend_tokens
            z = jnp.zeros((cfg.n_layers, batch, cfg.n_kv, F, cfg.head_dim_),
                          jnp.bfloat16)
            return {"k": jnp.zeros(shape, jnp.bfloat16),
                    "v": jnp.zeros(shape, jnp.bfloat16), "ck": z, "cv": z}

        return Model(
            cfg=cfg,
            _specs=_encdec.param_specs(cfg),
            loss_fn=lambda p, b, c=None: _encdec.loss_fn(p, b, cfg, c),
            decode_fn=lambda p, b, cache, idx, c=None: _encdec.decode_step(
                p, b["tokens"], cache, idx, cfg, c),
            cache_specs=enc_cache_specs,
            init_caches=enc_init_caches,
        )
    if cfg.arch == "zamba":
        return Model(
            cfg=cfg,
            _specs=_zamba.param_specs(cfg),
            loss_fn=lambda p, b, c=None: _zamba.loss_fn(p, b, cfg, c),
            decode_fn=lambda p, b, cache, idx, c=None: _zamba.decode_step(
                p, b["tokens"], cache, idx, cfg, c),
            cache_specs=lambda batch, max_len: _zamba.cache_specs(
                cfg, batch, max_len),
            init_caches=lambda batch, max_len: _zamba.init_caches(
                cfg, batch, max_len),
        )
    if cfg.arch == "xlstm":
        return Model(
            cfg=cfg,
            _specs=_xlstm.param_specs(cfg),
            loss_fn=lambda p, b, c=None: _xlstm.loss_fn(p, b, cfg, c),
            decode_fn=lambda p, b, cache, idx, c=None: _xlstm.decode_step(
                p, b["tokens"], cache, idx, cfg, c),
            cache_specs=lambda batch, max_len: _xlstm.cache_specs(cfg, batch),
            init_caches=lambda batch, max_len: _xlstm.init_caches(cfg, batch),
        )
    raise KeyError(cfg.arch)
