"""xLSTM-125M language model: 12 residual blocks, mLSTM:sLSTM = 7:1
(sLSTM at block 6; rest mLSTM), d_ff=0 per assignment (blocks carry their
own projections)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .base import P, padded_vocab, rms_norm, softmax_xent
from .lm import logits_fn
from .xlstm import (
    MLSTMCache,
    SLSTMCache,
    init_mlstm_cache,
    init_slstm_cache,
    mlstm_forward,
    mlstm_specs,
    slstm_forward,
    slstm_specs,
)

SLSTM_EVERY = 8  # one sLSTM block per 8 (≈7:1 per the paper's 125M recipe)


def block_kinds(cfg):
    return ["slstm" if (i % SLSTM_EVERY) == SLSTM_EVERY - 1 else "mlstm"
            for i in range(cfg.n_layers)]


def param_specs(cfg):
    blocks = {}
    for i, kind in enumerate(block_kinds(cfg)):
        if kind == "mlstm":
            blocks[f"b{i}"] = {
                "ln": P((cfg.d_model,), ("embed",)),
                "cell": mlstm_specs(cfg.d_model, cfg.n_heads),
            }
        else:
            blocks[f"b{i}"] = {
                "ln": P((cfg.d_model,), ("embed",)),
                "cell": slstm_specs(cfg.d_model, cfg.n_heads),
            }
    vp = padded_vocab(cfg.vocab)
    return {
        "embed": P((vp, cfg.d_model), ("vocab", "embed")),
        "ln_f": P((cfg.d_model,), ("embed",)),
        "blocks": blocks,
        "lm_head": P((cfg.d_model, vp), ("embed", "vocab")),
    }


def forward(params, tokens, cfg, constrain=None, *, caches=None):
    if constrain is None:
        constrain = lambda t, axes: t
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, ("batch", None, "embed"))
    new_caches = {}
    for i, kind in enumerate(block_kinds(cfg)):
        bp = params["blocks"][f"b{i}"]
        h = rms_norm(x, bp["ln"])
        cache = None if caches is None else caches[f"b{i}"]
        if kind == "mlstm":
            o, nc = mlstm_forward(bp["cell"], h, n_heads=cfg.n_heads,
                                  cache=cache)
        else:
            o, nc = slstm_forward(bp["cell"], h, n_heads=cfg.n_heads,
                                  cache=cache)
        x = constrain(x + o, ("batch", None, "embed"))
        if nc is not None:
            new_caches[f"b{i}"] = nc
    hidden = rms_norm(x, params["ln_f"])
    return hidden, (new_caches if caches is not None else None)


def loss_fn(params, batch, cfg, constrain=None):
    hidden, _ = forward(params, batch["tokens"], cfg, constrain)
    logits = logits_fn(params, hidden, cfg, constrain)
    return softmax_xent(logits, batch["labels"], batch.get("mask"))


def decode_step(params, tokens, caches, cache_index, cfg, constrain=None):
    del cache_index  # recurrent state carries position implicitly
    hidden, caches = forward(params, tokens, cfg, constrain, caches=caches)
    logits = logits_fn(params, hidden, cfg, constrain)[:, 0]
    return logits, caches


def _cache_template(cfg, batch: int, abstract: bool):
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (
        lambda s, d: jnp.zeros(s, d))
    caches = {}
    d_inner = cfg.d_model * 2
    n_m = d_inner // cfg.n_heads  # mLSTM head dim (post up-projection)
    n_s = cfg.d_model // cfg.n_heads
    for i, kind in enumerate(block_kinds(cfg)):
        if kind == "mlstm":
            caches[f"b{i}"] = MLSTMCache(
                c=mk((batch, cfg.n_heads, n_m, n_m), jnp.float32),
                n=mk((batch, cfg.n_heads, n_m), jnp.float32),
                m=mk((batch, cfg.n_heads), jnp.float32),
            )
        else:
            z = (batch, cfg.n_heads, n_s)
            caches[f"b{i}"] = SLSTMCache(
                c=mk(z, jnp.float32), n=mk(z, jnp.float32),
                h=mk(z, jnp.float32), m=mk(z, jnp.float32),
            )
    return caches


def cache_specs(cfg, batch: int, max_len: int = 0, dtype=None):
    """Recurrent caches are O(1) in sequence length (max_len unused)."""
    return _cache_template(cfg, batch, abstract=True)


def init_caches(cfg, batch: int, max_len: int = 0, dtype=None):
    caches = _cache_template(cfg, batch, abstract=False)
    # sLSTM normalizer starts at 1
    for i, kind in enumerate(block_kinds(cfg)):
        if kind == "slstm":
            c = caches[f"b{i}"]
            caches[f"b{i}"] = SLSTMCache(c=c.c, n=jnp.ones_like(c.n), h=c.h,
                                         m=c.m)
    return caches
