"""Mamba-2 (SSD) block — chunked state-space duality formulation.

TPU-native: the sequence is split into chunks; within a chunk the SSD
computation is a masked matmul (MXU-friendly), across chunks a short
``lax.scan`` carries the (B, H, P, N) state.  Decode is the O(1)
single-step recurrence over cached (conv window, SSM state).

Scalar-identity A per head (Mamba-2), SiLU-gated output, RMSNorm on the
gate branch, short causal conv on x/B/C as in the reference architecture.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .base import P, rms_norm


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_width_channels)
    state: jax.Array  # (B, H, N, P)


def mamba2_specs(d_model: int, n_heads: int, head_dim: int, d_state: int,
                 d_conv: int = 4, expand: int = 2):
    d_inner = n_heads * head_dim
    conv_ch = d_inner + 2 * d_state * 1  # x + B + C (single group)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": P((d_model, 2 * d_inner + 2 * d_state + n_heads),
                  ("embed", "heads_x")),
        "conv_w": P((d_conv, conv_ch), (None, "heads_x")),
        "A_log": P((n_heads,), ("heads",), dtype=jnp.float32),
        "dt_bias": P((n_heads,), ("heads",), dtype=jnp.float32),
        "D": P((n_heads,), ("heads",), dtype=jnp.float32),
        "norm_w": P((d_inner,), ("heads_x",)),
        "w_out": P((d_inner, d_model), ("heads_x", "embed")),
    }


def _split_proj(params, x, n_heads, head_dim, d_state):
    d_inner = n_heads * head_dim
    proj = x @ params["w_in"]
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_inner + 2 * d_state], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_state: Optional[jax.Array] = None):
    """Depthwise short causal conv over time.  xbc: (B, S, C_ch)."""
    d_conv = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], d_conv - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :]
        for i in range(d_conv)
    )
    new_state = xp[:, -(d_conv - 1):, :] if d_conv > 1 else pad
    return jax.nn.silu(out), new_state


def ssd_chunked(xh, b, c, dt_a, chunk: int):
    """Chunked SSD scan.

    xh: (B, S, H, P) inputs, b/c: (B, S, N), dt_a: (B, S, H) in (0,1] decay
    per step (a_t = exp(-dt*A)); dt premultiplied into xh by the caller.
    Returns (B, S, H, P) outputs.
    """
    B, S, H, Pd = xh.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    G = S // chunk
    xh = xh.reshape(B, G, chunk, H, Pd)
    b = b.reshape(B, G, chunk, N)
    c = c.reshape(B, G, chunk, N)
    la = jnp.log(dt_a.reshape(B, G, chunk, H).astype(jnp.float32))
    cum = jnp.cumsum(la, axis=2)                      # log prod_{r<=t} a_r

    # intra-chunk: y_t = sum_{s<=t} (prod_{s<r<=t} a_r) (c_t.b_s) x_s
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,G,t,s,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    gbc = jnp.einsum("bgtn,bgsn->bgts", c, b).astype(jnp.float32)
    y_intra = jnp.einsum("bgts,bgtsh,bgshp->bgthp", gbc, L,
                         xh.astype(jnp.float32))

    # chunk summaries: state_g = sum_t (prod_{r>t} a_r) b_t x_t^T
    rem = cum[:, :, -1:, :] - cum                      # log prod_{r>t} a_r
    w = jnp.exp(rem)                                   # (B,G,t,H)
    chunk_state = jnp.einsum("bgtn,bgth,bgthp->bghnp", b, w,
                             xh.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B,G,H)

    # inter-chunk scan over G carrying (B,H,N,P) state
    def step(h, inputs):
        st, dec = inputs  # (B,H,N,P), (B,H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    init = jnp.zeros((B, H, N, Pd), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                # (B,G,H,N,P) state at chunk start

    # inter contribution: y_t += (prod_{r<=t} a_r) c_t . h_start
    y_inter = jnp.einsum("bgtn,bgth,bghnp->bgthp", c, jnp.exp(cum), h_prev)
    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y, h_last


def mamba2_forward(params, x, *, n_heads, head_dim, d_state, chunk=128,
                   cache: Optional[SSMCache] = None):
    """Full block.  x: (B, S, D).  With ``cache`` performs decode (S small,
    sequential recurrence); returns (out, new_cache or None)."""
    B, S, D = x.shape
    d_inner = n_heads * head_dim
    z, xbc, dt = _split_proj(params, x, n_heads, head_dim, d_state)
    conv_state = cache.conv if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], conv_state)
    xi, b, c = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    xh = xi.reshape(B, S, n_heads, head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(params["A_log"]))        # per-step decay
    xh_dt = xh.astype(jnp.float32) * dt[..., None]

    if cache is None:
        ch = min(chunk, S)
        if S % ch != 0:  # pad sequence to a chunk multiple
            padlen = ch - S % ch
            pad = lambda t: jnp.pad(t, [(0, 0), (0, padlen)] + [(0, 0)] * (t.ndim - 2))
            y, _ = ssd_chunked(pad(xh_dt), pad(b), pad(c),
                               jnp.pad(a, [(0, 0), (0, padlen), (0, 0)],
                                       constant_values=1.0), chunk=ch)
            y = y[:, :S]
        else:
            y, _ = ssd_chunked(xh_dt, b, c, a, chunk=ch)
        new_state = None  # training path does not emit state
    else:
        # sequential decode recurrence (S typically 1)
        def step(h, inp):
            xt, bt, ct, at = inp  # (B,H,P), (B,N), (B,N), (B,H)
            h = h * at[:, :, None, None] + jnp.einsum("bn,bhp->bhnp", bt, xt)
            yt = jnp.einsum("bn,bhnp->bhp", ct, h)
            return h, yt

        h0 = cache.state.astype(jnp.float32)
        h_fin, ys = jax.lax.scan(
            step, h0,
            (jnp.moveaxis(xh_dt, 1, 0), jnp.moveaxis(b, 1, 0).astype(jnp.float32),
             jnp.moveaxis(c, 1, 0).astype(jnp.float32), jnp.moveaxis(a, 1, 0)),
        )
        y = jnp.moveaxis(ys, 0, 1)                     # (B,S,H,P)
        new_state = h_fin

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ params["w_out"]
    if cache is None:
        return out, None
    return out, SSMCache(conv=new_conv, state=new_state.astype(cache.state.dtype))


def init_ssm_cache(batch: int, n_heads: int, head_dim: int, d_state: int,
                   d_conv: int = 4, dtype=jnp.bfloat16) -> SSMCache:
    conv_ch = n_heads * head_dim + 2 * d_state
    return SSMCache(
        conv=jnp.zeros((batch, d_conv - 1, conv_ch), dtype),
        state=jnp.zeros((batch, n_heads, d_state, head_dim), dtype),
    )
