"""Model substrate: the 10 assigned architectures in functional JAX.

base.py      param specs + logical axes + shared layers (GQA, RoPE, MLP)
moe.py       top-k capacity MoE (GSPMD baseline + shard_map EP variant)
ssm.py       Mamba-2 / SSD chunked scan + O(1) decode
xlstm.py     mLSTM (chunked) + sLSTM (sequential scan) cells
lm.py        decoder-only LM (dense/MoE) with scan-over-layers + KV cache
encdec.py    whisper-style encoder-decoder (stub audio frontend)
zamba.py     Mamba2 backbone + shared attention block (hybrid)
xlstm_lm.py  xLSTM block stack
vlm.py       InternVL2 (stub ViT frontend) over the LM backbone
api.py       unified Model facade used by launch/train/serve/dryrun
"""

from .api import Model, build_model

__all__ = ["Model", "build_model"]
