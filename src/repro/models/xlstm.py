"""xLSTM blocks (sLSTM + mLSTM) — arXiv:2405.04517.

* mLSTM: matrix-memory cell with exponential input gate and stabilizer;
  parallelizable — implemented in the same chunked form as SSD (the decay
  is the cumulative forget gate), matching the paper's parallel training
  formulation.
* sLSTM: scalar-memory cell with *recurrent* weights — inherently
  sequential; implemented as a ``lax.scan`` over time (the paper states
  sLSTM is not parallelizable).  Decode is O(1) for both.

Block layout follows the paper: mLSTM blocks use pre-up-projection
(factor 2) with SiLU gating; sLSTM blocks post-project with a gated FFN
(factor 4/3).  xLSTM-125M uses ratio 7:1 (mLSTM:sLSTM).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import P, rms_norm


class MLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, N, N) matrix memory (keys N = values N = head dim)
    n: jax.Array  # (B, H, N) normalizer
    m: jax.Array  # (B, H) stabilizer


class SLSTMCache(NamedTuple):
    c: jax.Array  # (B, H, N) cell
    n: jax.Array  # (B, H, N) normalizer
    h: jax.Array  # (B, H, N) hidden (recurrent input)
    m: jax.Array  # (B, H, N) stabilizer


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_specs(d_model: int, n_heads: int, expand: int = 2):
    d_inner = d_model * expand
    return {
        "w_up": P((d_model, 2 * d_inner), ("embed", "ffn")),  # [x, gate]
        "w_qkv": P((d_inner, 3 * d_inner), (None, "heads_x")),
        "w_if": P((d_inner, 2 * n_heads), (None, None), dtype=jnp.float32),
        "norm_w": P((d_inner,), (None,)),
        "w_down": P((d_inner, d_model), ("ffn", "embed")),
    }


def _mlstm_cell_chunked(q, k, v, i_gate, f_gate, chunk: int):
    """Chunked stabilized mLSTM.  q/k/v: (B,S,H,N); gates (B,S,H) raw.

    Uses log-space cumulative forget gates; within-chunk quadratic form,
    cross-chunk sequential scan (same skeleton as ssd_chunked).
    """
    B, S, H, N = q.shape
    assert S % chunk == 0
    G = S // chunk
    rs = lambda t: t.reshape(B, G, chunk, *t.shape[2:])
    q, k, v = rs(q), rs(k), rs(v)
    logf = jax.nn.log_sigmoid(f_gate).reshape(B, G, chunk, H)
    logi = i_gate.reshape(B, G, chunk, H).astype(jnp.float32)
    cumf = jnp.cumsum(logf, axis=2)

    # within-chunk unnormalized weights: D_ts = exp(cumf_t - cumf_s + i_s)
    seg = cumf[:, :, :, None, :] - cumf[:, :, None, :, :] + logi[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    seg = jnp.where(tri, seg, -jnp.inf)
    # stabilizer per (b,g,t,h): max over s and the carried chunk state
    m_intra = jnp.max(seg, axis=3)                    # (B,G,t,H)

    scores = jnp.einsum("bgthn,bgshn->bgtsh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(N)

    # chunk summaries for the inter-chunk recurrence
    rem = cumf[:, :, -1:, :] - cumf + logi            # weight of step t in carry
    chunk_c = jnp.einsum("bgthn,bgth,bgthm->bghnm", k.astype(jnp.float32),
                         jnp.exp(rem), v.astype(jnp.float32))
    chunk_n = jnp.einsum("bgthn,bgth->bghn", k.astype(jnp.float32), jnp.exp(rem))
    chunk_f = jnp.exp(cumf[:, :, -1, :])              # (B,G,H)

    def step(carry, inp):
        c, n = carry
        cc, cn, cf = inp
        c_new = c * cf[:, :, None, None] + cc
        n_new = n * cf[:, :, None] + cn
        return (c_new, n_new), (c, n)

    c0 = jnp.zeros((B, H, N, N), jnp.float32)
    n0 = jnp.zeros((B, H, N), jnp.float32)
    (_, _), (c_prev, n_prev) = jax.lax.scan(
        step, (c0, n0),
        (jnp.moveaxis(chunk_c, 1, 0), jnp.moveaxis(chunk_n, 1, 0),
         jnp.moveaxis(chunk_f, 1, 0)),
    )
    c_prev = jnp.moveaxis(c_prev, 0, 1)               # (B,G,H,N,N)
    n_prev = jnp.moveaxis(n_prev, 0, 1)               # (B,G,H,N)

    # combine intra + inter with joint stabilization
    m_tot = jnp.maximum(m_intra, cumf)                # inter weight is exp(cumf)
    w_intra = jnp.exp(seg - m_tot[:, :, :, None, :])
    num_intra = jnp.einsum("bgtsh,bgtsh,bgshn->bgthn", scores, w_intra,
                           v.astype(jnp.float32))
    den_intra = jnp.einsum("bgtsh,bgtsh->bgth", w_intra, scores)

    w_inter = jnp.exp(cumf - m_tot)                   # (B,G,t,H)
    num_inter = jnp.einsum("bgthn,bgth,bghnm->bgthm", q.astype(jnp.float32),
                           w_inter, c_prev) / np.sqrt(N)
    den_inter = jnp.einsum("bgthn,bgth,bghn->bgth", q.astype(jnp.float32),
                           w_inter, n_prev) / np.sqrt(N)

    num = num_intra + num_inter
    den = den_intra + den_inter
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))  # paper's max(|n|, e^-m)
    return (num / den[..., None]).reshape(B, S, H, N)


def mlstm_forward(params, x, *, n_heads, cache: Optional[MLSTMCache] = None,
                  chunk: int = 64):
    B, S, D = x.shape
    up = x @ params["w_up"]
    xi, gate = jnp.split(up, 2, axis=-1)
    d_inner = xi.shape[-1]
    N = d_inner // n_heads
    qkv = xi @ params["w_qkv"]
    q, k, v = [t.reshape(B, S, n_heads, N) for t in jnp.split(qkv, 3, axis=-1)]
    gates = (xi @ params["w_if"]).astype(jnp.float32)
    i_gate, f_gate = jnp.split(gates.reshape(B, S, n_heads, 2), 2, axis=-1)
    i_gate, f_gate = i_gate[..., 0], f_gate[..., 0]

    if cache is None:
        ch = min(chunk, S)
        if S % ch:
            padlen = ch - S % ch
            p3 = lambda t: jnp.pad(t, [(0, 0), (0, padlen), (0, 0), (0, 0)])
            p2 = lambda t: jnp.pad(t, [(0, 0), (0, padlen), (0, 0)])
            h = _mlstm_cell_chunked(p3(q), p3(k), p3(v), p2(i_gate),
                                    p2(f_gate), ch)[:, :S]
        else:
            h = _mlstm_cell_chunked(q, k, v, i_gate, f_gate, ch)
        new_cache = None
    else:
        def step(carry, inp):
            c, n, m = carry
            qt, kt, vt, it, ft = inp
            logf = jax.nn.log_sigmoid(ft)
            m_new = jnp.maximum(logf + m, it)
            fi = jnp.exp(logf + m - m_new)
            ii = jnp.exp(it - m_new)
            c = c * fi[:, :, None, None] + ii[:, :, None, None] * jnp.einsum(
                "bhn,bhm->bhnm", kt, vt) / np.sqrt(N)
            n = n * fi[:, :, None] + ii[:, :, None] * kt / np.sqrt(N)
            num = jnp.einsum("bhn,bhnm->bhm", qt, c)
            den = jnp.maximum(
                jnp.abs(jnp.einsum("bhn,bhn->bh", qt, n)), jnp.exp(-m_new)
            )
            return (c, n, m_new), num / den[..., None]

        f32 = lambda t: jnp.moveaxis(t, 1, 0).astype(jnp.float32)
        carry, hs = jax.lax.scan(
            step,
            (cache.c.astype(jnp.float32), cache.n.astype(jnp.float32),
             cache.m.astype(jnp.float32)),
            (f32(q), f32(k), f32(v), f32(i_gate), f32(f_gate)),
        )
        h = jnp.moveaxis(hs, 0, 1)
        new_cache = MLSTMCache(
            c=carry[0].astype(cache.c.dtype),
            n=carry[1].astype(cache.n.dtype),
            m=carry[2].astype(cache.m.dtype),
        )

    h = h.reshape(B, S, d_inner).astype(x.dtype)
    h = rms_norm(h, params["norm_w"]) * jax.nn.silu(gate)
    return h @ params["w_down"], new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_specs(d_model: int, n_heads: int):
    N = d_model // n_heads
    return {
        "w_in": P((d_model, 4 * d_model), ("embed", "heads_x")),  # z i f o
        "r_in": P((n_heads, N, 4 * N), ("heads", None, None)),    # recurrent
        "norm_w": P((d_model,), (None,)),
        # gated FFN (factor 4/3, GeGLU) per the paper's sLSTM block
        "w_ff_gate": P((d_model, 4 * d_model // 3), ("embed", "ffn")),
        "w_ff_up": P((d_model, 4 * d_model // 3), ("embed", "ffn")),
        "w_ff_down": P((4 * d_model // 3, d_model), ("ffn", "embed")),
    }


def slstm_forward(params, x, *, n_heads, cache: Optional[SLSTMCache] = None):
    """Sequential sLSTM with recurrent weights + post FFN.  x: (B,S,D)."""
    B, S, D = x.shape
    N = D // n_heads
    zifo = (x @ params["w_in"]).reshape(B, S, n_heads, 4 * N)

    if cache is None:
        c0 = jnp.zeros((B, n_heads, N), jnp.float32)
        h0 = jnp.zeros((B, n_heads, N), jnp.float32)
        n0 = jnp.ones((B, n_heads, N), jnp.float32)
        m0 = jnp.zeros((B, n_heads, N), jnp.float32)
    else:
        c0, n0, h0, m0 = (cache.c.astype(jnp.float32),
                          cache.n.astype(jnp.float32),
                          cache.h.astype(jnp.float32),
                          cache.m.astype(jnp.float32))

    r_w = params["r_in"].astype(jnp.float32)

    def step(carry, inp):
        c, n, h, m = carry
        zifo_t = inp.astype(jnp.float32)  # (B, H, 4N)
        rec = jnp.einsum("bhn,hnm->bhm", h, r_w)
        z_r, i_r, f_r, o_r = jnp.split(zifo_t + rec, 4, axis=-1)
        z = jnp.tanh(z_r)
        o = jax.nn.sigmoid(o_r)
        logf = jax.nn.log_sigmoid(f_r)
        m_new = jnp.maximum(logf + m, i_r)
        i = jnp.exp(i_r - m_new)
        f = jnp.exp(logf + m - m_new)
        c_new = f * c + i * z
        n_new = f * n + i
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    carry, hs = jax.lax.scan(step, (c0, n0, h0, m0),
                             jnp.moveaxis(zifo, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
    h = rms_norm(h, params["norm_w"])
    ff = jax.nn.gelu(h @ params["w_ff_gate"]) * (h @ params["w_ff_up"])
    out = ff @ params["w_ff_down"]
    new_cache = None
    if cache is not None:
        new_cache = SLSTMCache(
            c=carry[0].astype(cache.c.dtype), n=carry[1].astype(cache.n.dtype),
            h=carry[2].astype(cache.h.dtype), m=carry[3].astype(cache.m.dtype),
        )
    return out, new_cache


def init_mlstm_cache(batch, n_heads, head_dim, dtype=jnp.float32):
    return MLSTMCache(
        c=jnp.zeros((batch, n_heads, head_dim, head_dim), dtype),
        n=jnp.zeros((batch, n_heads, head_dim), dtype),
        m=jnp.zeros((batch, n_heads), dtype),
    )


def init_slstm_cache(batch, n_heads, head_dim, dtype=jnp.float32):
    z = jnp.zeros((batch, n_heads, head_dim), dtype)
    return SLSTMCache(c=z, n=jnp.ones_like(z), h=z, m=z)
