"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM 2404.06395)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr, warmup_steps, total_steps,
                    final_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5
                     * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup_steps, warm, cos)


def wsd_schedule(step, *, peak_lr, warmup_steps, stable_steps, decay_steps,
                 final_frac=0.01):
    """Warmup -> Stable (constant) -> Decay (exponential-ish cosine tail)."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    in_decay = step > warmup_steps + stable_steps
    prog = jnp.clip((step - warmup_steps - stable_steps)
                    / jnp.maximum(decay_steps, 1), 0.0, 1.0)
    decay = peak_lr * (final_frac ** prog)
    out = jnp.where(step < warmup_steps, warm, peak_lr)
    return jnp.where(in_decay, decay, out)
