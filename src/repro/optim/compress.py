"""Error-feedback int8 gradient compression for cross-pod data parallelism.

At 1000+ nodes the DP all-reduce over the pod axis rides the slowest
links; int8 quantization with per-tensor scale cuts those bytes 4x
(vs f32) while error feedback keeps the optimizer trajectory unbiased:

    e_new = g + e_carry - dequant(quant(g + e_carry))

``ef_compress_update`` is applied to grads *before* the optimizer; the
residual state lives alongside the optimizer state and shards like the
params.  This compresses what crosses the wire when the grad reduction
is done explicitly per-axis (see train.py --grad-compress).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_decompress(x):
    """Symmetric per-tensor int8 quantize->dequantize (round-to-nearest)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale, q, scale


def ef_compress_update(grads, residual):
    """Returns (compressed grads to reduce, new residual).  Tree-mapped."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        deq, _, _ = compress_decompress(corrected)
        return deq, corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(residual)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def residual_init(params, abstract: bool = False):
    def mk(p):
        if abstract:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)
    return jax.tree.map(mk, params)
