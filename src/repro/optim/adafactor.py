"""Adafactor-style optimizer: factored second moment + bf16 momentum.

For >=2-D params the second moment is stored as row/col means (O(n+m)
instead of O(nm)); momentum is bf16.  This is what makes the
kimi-k2-1t-a32b training state fit the 2-pod mesh (DESIGN.md §4):
  fp32 Adam  : 16 B/param -> 16 TB        (impossible)
  this       : 2 (bf16 param) + 2 (bf16 m) + ~0 (factored v) ≈ 4 B/param.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params, abstract: bool = False):
    def mk(p):
        def a(shape, dtype):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dtype)
            return jnp.zeros(shape, dtype)

        if _factored(p.shape):
            return {
                "vr": a(p.shape[:-1], jnp.float32),   # row second moment
                "vc": a(p.shape[:-2] + p.shape[-1:], jnp.float32),
                "m": a(p.shape, jnp.bfloat16),
            }
        return {"v": a(p.shape, jnp.float32), "m": a(p.shape, jnp.bfloat16)}

    return {
        "slots": jax.tree.map(mk, params),
        "step": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                 else jnp.zeros((), jnp.int32)),
    }


def adafactor_update(grads, state, params, *, lr, b1=0.9, decay=0.99,
                     eps=1e-30, weight_decay=0.0, clip_norm=1.0):
    from .adamw import global_norm

    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(g, slot, p):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + eps
        if "vr" in slot:
            vr = decay * slot["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
            vc = decay * slot["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., :, None] * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                              eps)
            )
            u = g / jnp.maximum(denom, eps)
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = decay * slot["v"] + (1 - decay) * g2
            u = g / (jnp.sqrt(v) + 1e-8)
            new_slot = {"v": v}
        m = b1 * slot["m"].astype(jnp.float32) + (1 - b1) * u
        new_slot["m"] = m.astype(jnp.bfloat16)
        delta = m + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, new_slot

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["slots"])
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_s = treedef.unflatten([o[1] for o in out])
    return new_p, {"slots": new_s, "step": step}, gnorm
