"""AdamW with decoupled weight decay + global-norm clipping.

State layout mirrors params (m, v fp32) so the partitioner can reuse the
param PartitionSpecs verbatim (ZeRO-style sharding falls out of FSDP
param sharding).  ``abstract=True`` init returns ShapeDtypeStructs for
the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, abstract: bool = False):
    def mk(p):
        if abstract:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(mk, params),
        "v": jax.tree.map(mk, params),
        "step": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                 else jnp.zeros((), jnp.int32)),
    }


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ))


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1, clip_norm=1.0):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mh = m_new / (1 - b1 ** step)
        vh = v_new / (1 - b2 ** step)
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
