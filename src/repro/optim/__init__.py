"""Optimizers + schedules (self-contained, no optax dependency).

adamw.py      AdamW with decoupled weight decay and global-norm clipping
adafactor.py  factored second moment + bf16 momentum — the 1T-param path
schedules.py  cosine and WSD (warmup-stable-decay, MiniCPM) schedules
compress.py   error-feedback int8 gradient compression for DP all-reduce
"""

from .adamw import adamw_init, adamw_update
from .adafactor import adafactor_init, adafactor_update
from .schedules import cosine_schedule, wsd_schedule
from .compress import compress_decompress, ef_compress_update, residual_init

OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adafactor": (adafactor_init, adafactor_update),
}

__all__ = [
    "OPTIMIZERS",
    "adamw_init",
    "adamw_update",
    "adafactor_init",
    "adafactor_update",
    "cosine_schedule",
    "wsd_schedule",
    "compress_decompress",
    "ef_compress_update",
    "residual_init",
]
