"""Host prep + jit wrappers + jnp oracles for the gap-insertion device
kernels (Eq. 3 gap placement AND the §5.3 dynamic-ingest placement
stage — see ``ingest_place`` for the latter's contract)."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .gap_place import (fused_ingest_body, gap_place_call,
                        ingest_place_body, ingest_place_call)
from .ops import _pad_pow


def prepare_gap_tables(x: np.ndarray, y: np.ndarray, plm, rho: float,
                       seg_chunk: int = 512):
    """Fold Eq. 3 into per-segment (first_key, base, x0, scale) tables.

    Mirrors core.gaps.gap_positions' segment anchoring (first/last present
    key per segment), done once host-side in O(n).
    """
    seg = plm.segment_of(x)
    K = plm.n_segments
    n = x.shape[0]
    idx = np.arange(n, dtype=np.int64)
    first = np.full(K, n, np.int64)
    last = np.full(K, -1, np.int64)
    np.minimum.at(first, seg, idx)
    np.maximum.at(last, seg, idx)
    present = first < n
    f = np.minimum(first, n - 1)
    l = np.clip(last, 0, n - 1)
    y_first = np.where(present, y[f], 0.0)
    y_last = np.where(present, y[l], 0.0)
    x_first = np.where(present, x[f], 0.0)
    x_last = np.where(present, x[l], 1.0)
    U = np.where(present, rho * (y_last - y_first), 0.0)
    S = np.concatenate([[0.0], np.cumsum(U)[:-1]])
    dx = np.where(x_last > x_first, x_last - x_first, 1.0)
    scale = (y_last - y_first) * (1.0 + rho) / dx
    base = y_first + S

    pad = lambda a, fill: _pad_pow(np.asarray(a, np.float32), seg_chunk,
                                   np.float32(fill))
    return (pad(plm.seg_first_key, np.inf), pad(base, 0.0),
            pad(x_first, 0.0), pad(scale, 0.0))


def gap_positions_device(x: np.ndarray, plm, rho: float, *,
                         key_tile: int = 1024, seg_chunk: int = 512,
                         interpret: bool = True) -> np.ndarray:
    """Device Eq. 3: returns monotone target positions for all keys."""
    x = np.asarray(x, np.float64)
    y = np.arange(x.shape[0], dtype=np.float64)
    segk, base, x0, scale = prepare_gap_tables(x, y, plm, rho, seg_chunk)
    xp = _pad_pow(x.astype(np.float32), key_tile, np.float32(np.inf))
    out = gap_place_call(
        jnp.asarray(xp), jnp.asarray(segk), jnp.asarray(base),
        jnp.asarray(x0), jnp.asarray(scale),
        key_tile=key_tile, seg_chunk=seg_chunk, interpret=interpret,
    )
    yg = np.asarray(out)[: x.shape[0]].astype(np.float64)
    return np.maximum.accumulate(yg)  # same boundary-tie guard as core


def gap_positions_oracle(x: np.ndarray, plm, rho: float) -> np.ndarray:
    """Pure-jnp/numpy oracle — delegates to the core implementation."""
    from ..core.gaps import gap_positions

    x = np.asarray(x, np.float64)
    return gap_positions(x, np.arange(x.shape[0], dtype=np.float64), plm,
                         rho)


# ---------------------------------------------------------------------------
# §5.3 dynamic-ingest placement backend (device primitives for
# GappedArray.insert_batch — registered in the kernels backend table)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_slots",))
def _ingest_place_xla(x_hi, x_lo, segk_hi, segk_lo, slope_hi, slope_lo,
                      icept_hi, icept_lo, slot_hi, slot_lo, link_offsets,
                      link_hi, link_lo, *, n_slots):
    """Fused-XLA variant: the SAME per-key body the Pallas kernel runs,
    over the whole batch in one lean dispatch (the CPU/GPU half of the
    ingest-place backend, mirroring the fused lookup's split)."""
    return ingest_place_body(
        x_hi, x_lo, segk_hi, segk_lo, slope_hi, slope_lo, icept_hi,
        icept_lo, slot_hi, slot_lo, link_offsets, link_hi, link_lo,
        n_slots=n_slots)


def ingest_place(arrays, keys, *, impl: str = "xla",
                 interpret: bool = True, key_tile: int = 512):
    """Device §5.3 ingest placement: per-key placement primitives for an
    insert batch, computed against the FROZEN device arrays.

    Returns ``(primitives, escape)`` where ``primitives`` is the numpy
    dict ``GappedArray.insert_batch`` consumes (``p``/``free``/``pv``/
    ``ub``/``bracket`` — the same contract as the host oracle
    ``GappedArray.placement_primitives``) and ``escape`` flags keys
    whose double-f32 prediction landed inside the rounding-band guard;
    the caller (``Index.ingest``) re-derives THOSE rows host-side in
    O(#escapes) and the patched primitives are bit-identical to the
    host oracle.

    Exactness contract (gated by the Index handle): every stored and
    batch key must be pair-exact (reconstructed exactly by its f32
    hi/lo split — all integer keys < 2^48), so every pair compare below
    equals the host's f64 compare; narrow (f32-exact) indexes run with
    zero lo arrays.  ``impl`` picks the Pallas kernel ("pallas", the
    TPU half) or the fused-XLA graph ("xla" — CPU/GPU); both run ONE
    shared per-key body, so they are bit-identical by construction.
    """
    from .ops import split_key_pair

    keys = np.asarray(keys, np.float64)
    x_hi, x_lo = split_key_pair(keys)
    key_wide = bool(arrays.key_wide)
    segk_hi = arrays.seg_first_key
    segk_lo = (arrays.seg_first_key_lo if key_wide
               else jnp.zeros_like(segk_hi))
    slot_hi = arrays.slot_key
    slot_lo = (arrays.slot_key_lo if key_wide
               else jnp.zeros_like(slot_hi))
    link_hi = arrays.link_keys
    link_lo = (arrays.link_keys_lo if key_wide
               else jnp.zeros_like(link_hi))
    if int(link_hi.shape[0]) == 0:  # tileable non-empty chain tables
        link_hi = jnp.full((1,), jnp.inf, jnp.float32)
        link_lo = jnp.zeros((1,), jnp.float32)
    n_b = keys.shape[0]
    if impl == "pallas":
        pad = (-n_b) % key_tile
        xh = jnp.asarray(np.concatenate(
            [x_hi, np.full(pad, np.inf, np.float32)]))
        xl = jnp.asarray(np.concatenate([x_lo, np.zeros(pad, np.float32)]))
        p, pv, ub, flags = ingest_place_call(
            xh, xl, segk_hi, segk_lo, arrays.seg_slope,
            arrays.seg_slope_lo, arrays.seg_icept, arrays.seg_icept_lo,
            slot_hi, slot_lo, arrays.link_offsets, link_hi, link_lo,
            key_tile=key_tile, n_slots=arrays.n_slots,
            interpret=interpret)
        flags = np.asarray(flags)[:n_b]
        free = (flags & 1).astype(bool)
        bracket = (flags & 2).astype(bool)
        escape = (flags & 4).astype(bool)
    else:
        p, pv, ub, free, bracket, escape = _ingest_place_xla(
            jnp.asarray(x_hi), jnp.asarray(x_lo), segk_hi, segk_lo,
            arrays.seg_slope, arrays.seg_slope_lo, arrays.seg_icept,
            arrays.seg_icept_lo, slot_hi, slot_lo, arrays.link_offsets,
            link_hi, link_lo, n_slots=arrays.n_slots)
        free = np.asarray(free)[:n_b]
        bracket = np.asarray(bracket)[:n_b]
        escape = np.asarray(escape)[:n_b]
    prims = {  # writable copies: the caller patches escape rows in place
        "p": np.asarray(p)[:n_b].astype(np.int64),
        "free": np.array(free, dtype=bool),
        "pv": np.asarray(pv)[:n_b].astype(np.int64),
        "ub": np.asarray(ub)[:n_b].astype(np.int64),
        "bracket": np.array(bracket, dtype=bool),
    }
    return prims, np.array(escape, dtype=bool)


# ---------------------------------------------------------------------------
# single-dispatch fused ingest (placement + partition + slot scatter +
# device CSR merge + rank/bound refresh — ONE graph, see
# gap_place.fused_ingest_body for the correctness contract)
# ---------------------------------------------------------------------------

# abort-reason bit names (the graph's ``reasons`` bitmask), for stats
FUSED_ABORT_BITS = (
    "escape", "dup_batch", "collision_group", "slot_dup", "contested",
    "d1_demote", "d4_demote", "chain_overflow", "link_overflow",
    "chain_dup",
)


@functools.partial(jax.jit, static_argnames=(
    "n_slots", "max_chain", "key_wide", "use_pallas", "interpret",
    "key_tile"))
def _fused_ingest_xla(
        x_hi, x_lo, pay_lo, pay_hi, segk_hi, segk_lo, slope_hi, slope_lo,
        icept_hi, icept_lo, slot_hi, slot_lo, spay_lo, spay_hi,
        link_offsets, link_hi, link_lo, lpay_lo, lpay_hi, rank_table,
        rank_bounds_hi, rank_bounds_lo, rank_scale, elo, ehi, *,
        n_slots, max_chain, key_wide, use_pallas, interpret, key_tile):
    """The one device dispatch ``Index.ingest`` issues on the fused
    path (the dispatch-counting shim in tests/test_fused_ingest.py
    monkeypatches exactly this symbol)."""
    return fused_ingest_body(
        x_hi, x_lo, pay_lo, pay_hi, segk_hi, segk_lo, slope_hi, slope_lo,
        icept_hi, icept_lo, slot_hi, slot_lo, spay_lo, spay_hi,
        link_offsets, link_hi, link_lo, lpay_lo, lpay_hi, rank_table,
        rank_bounds_hi, rank_bounds_lo, rank_scale, elo, ehi,
        n_slots=n_slots, max_chain=max_chain, key_wide=key_wide,
        use_pallas=use_pallas, interpret=interpret, key_tile=key_tile)


def fused_ingest(arrays, keys, payloads, *, rank_table, rank_bounds_hi,
                 rank_bounds_lo, rank_scale, elo, ehi, max_chain,
                 impl: str = "xla", interpret: bool = True,
                 min_bucket: int = 256, key_tile: int = 512):
    """Single-dispatch device-resident ingest.

    Pads the batch to a power-of-two bucket (+inf keys / -1 payloads —
    each bucket compiles once, like the fused lookup), runs the fused
    graph, and returns ``(prims, escape, ok, reasons, state)``:

    * ``prims``/``escape`` — the usual ``ingest_place`` contract (valid
      whether or not the graph committed, so an aborted batch reuses
      them on the host partition path at no extra dispatch);
    * ``ok`` — True iff the graph produced the post-batch device
      images; ``reasons`` is the abort bitmask (``FUSED_ABORT_BITS``);
    * ``state`` — dict of NEW device arrays (slot/payload/link images,
      rank table, window bounds) plus the downloaded ``seg``/``dlt``
      residuals the caller mirrors into its host bound copies.  All
      entries are live device buffers — nothing round-trips through
      host numpy on the ok path.
    """
    from .ops import _split_i64, split_key_pair

    keys = np.asarray(keys, np.float64)
    payloads = np.asarray(payloads, np.int64)
    n_b = keys.shape[0]
    bucket = max(min_bucket, 1 << max(n_b - 1, 1).bit_length())
    pad = bucket - n_b
    x_hi, x_lo = split_key_pair(keys)
    x_hi = np.concatenate([x_hi, np.full(pad, np.inf, np.float32)])
    x_lo = np.concatenate([x_lo, np.zeros(pad, np.float32)])
    p_lo, p_hi = _split_i64(payloads)
    p_lo = np.concatenate([p_lo, np.full(pad, -1, np.int32)])
    p_hi = np.concatenate([p_hi, np.full(pad, -1, np.int32)])

    key_wide = bool(arrays.key_wide)
    wide = bool(arrays.wide)
    zeros_f = lambda a: jnp.zeros_like(a)  # noqa: E731
    segk_lo = arrays.seg_first_key_lo if key_wide \
        else zeros_f(arrays.seg_first_key)
    slot_lo = arrays.slot_key_lo if key_wide else zeros_f(arrays.slot_key)
    link_lo = arrays.link_keys_lo if key_wide \
        else zeros_f(arrays.link_keys)
    spay_hi = arrays.payload_hi if wide else zeros_f(arrays.payload)
    lpay_hi = arrays.link_payload_hi if wide \
        else zeros_f(arrays.link_payloads)

    outs = _fused_ingest_xla(
        jnp.asarray(x_hi), jnp.asarray(x_lo), jnp.asarray(p_lo),
        jnp.asarray(p_hi), arrays.seg_first_key, segk_lo,
        arrays.seg_slope, arrays.seg_slope_lo, arrays.seg_icept,
        arrays.seg_icept_lo, arrays.slot_key, slot_lo, arrays.payload,
        spay_hi, arrays.link_offsets, arrays.link_keys, link_lo,
        arrays.link_payloads, lpay_hi, rank_table, rank_bounds_hi,
        rank_bounds_lo, rank_scale, elo, ehi,
        n_slots=arrays.n_slots, max_chain=int(max_chain),
        key_wide=key_wide, use_pallas=(impl == "pallas"),
        interpret=interpret, key_tile=key_tile)
    (p, pv, ub, free, bracket, escape, ok, reasons, n_slot, n_chain,
     seg, dlt) = outs[:12]
    (slot_key, slot_key_lo, payload, payload_hi, link_offsets, link_keys,
     link_keys_lo, link_payloads, link_payload_hi, new_rank, new_elo,
     new_ehi) = outs[12:]
    prims = {  # writable copies: escape rows are patched in place
        "p": np.asarray(p)[:n_b].astype(np.int64),
        "free": np.array(np.asarray(free)[:n_b], dtype=bool),
        "pv": np.asarray(pv)[:n_b].astype(np.int64),
        "ub": np.asarray(ub)[:n_b].astype(np.int64),
        "bracket": np.array(np.asarray(bracket)[:n_b], dtype=bool),
    }
    state = {
        "slot_key": slot_key, "slot_key_lo": slot_key_lo,
        "payload": payload, "payload_hi": payload_hi,
        "link_offsets": link_offsets, "link_keys": link_keys,
        "link_keys_lo": link_keys_lo, "link_payloads": link_payloads,
        "link_payload_hi": link_payload_hi, "rank_table": new_rank,
        "elo": new_elo, "ehi": new_ehi,
        "n_slot": int(n_slot), "n_chain": int(n_chain),
        "seg": np.asarray(seg)[:n_b], "dlt": np.asarray(dlt)[:n_b],
    }
    return (prims, np.array(np.asarray(escape)[:n_b], dtype=bool),
            bool(ok), int(reasons), state)
