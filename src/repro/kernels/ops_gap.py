"""Host prep + jit wrapper + jnp oracle for the gap-place kernel."""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .gap_place import gap_place_call
from .ops import _pad_pow


def prepare_gap_tables(x: np.ndarray, y: np.ndarray, plm, rho: float,
                       seg_chunk: int = 512):
    """Fold Eq. 3 into per-segment (first_key, base, x0, scale) tables.

    Mirrors core.gaps.gap_positions' segment anchoring (first/last present
    key per segment), done once host-side in O(n).
    """
    seg = plm.segment_of(x)
    K = plm.n_segments
    n = x.shape[0]
    idx = np.arange(n, dtype=np.int64)
    first = np.full(K, n, np.int64)
    last = np.full(K, -1, np.int64)
    np.minimum.at(first, seg, idx)
    np.maximum.at(last, seg, idx)
    present = first < n
    f = np.minimum(first, n - 1)
    l = np.clip(last, 0, n - 1)
    y_first = np.where(present, y[f], 0.0)
    y_last = np.where(present, y[l], 0.0)
    x_first = np.where(present, x[f], 0.0)
    x_last = np.where(present, x[l], 1.0)
    U = np.where(present, rho * (y_last - y_first), 0.0)
    S = np.concatenate([[0.0], np.cumsum(U)[:-1]])
    dx = np.where(x_last > x_first, x_last - x_first, 1.0)
    scale = (y_last - y_first) * (1.0 + rho) / dx
    base = y_first + S

    pad = lambda a, fill: _pad_pow(np.asarray(a, np.float32), seg_chunk,
                                   np.float32(fill))
    return (pad(plm.seg_first_key, np.inf), pad(base, 0.0),
            pad(x_first, 0.0), pad(scale, 0.0))


def gap_positions_device(x: np.ndarray, plm, rho: float, *,
                         key_tile: int = 1024, seg_chunk: int = 512,
                         interpret: bool = True) -> np.ndarray:
    """Device Eq. 3: returns monotone target positions for all keys."""
    x = np.asarray(x, np.float64)
    y = np.arange(x.shape[0], dtype=np.float64)
    segk, base, x0, scale = prepare_gap_tables(x, y, plm, rho, seg_chunk)
    xp = _pad_pow(x.astype(np.float32), key_tile, np.float32(np.inf))
    out = gap_place_call(
        jnp.asarray(xp), jnp.asarray(segk), jnp.asarray(base),
        jnp.asarray(x0), jnp.asarray(scale),
        key_tile=key_tile, seg_chunk=seg_chunk, interpret=interpret,
    )
    yg = np.asarray(out)[: x.shape[0]].astype(np.float64)
    return np.maximum.accumulate(yg)  # same boundary-tie guard as core


def gap_positions_oracle(x: np.ndarray, plm, rho: float) -> np.ndarray:
    """Pure-jnp/numpy oracle — delegates to the core implementation."""
    from ..core.gaps import gap_positions

    x = np.asarray(x, np.float64)
    return gap_positions(x, np.arange(x.shape[0], dtype=np.float64), plm,
                         rho)
