"""Gap-insertion device kernels — Pallas TPU.

Two kernels live here:

1. ``gap_place_call`` — Eq. 3 gap-position manipulation: the
   result-driven target position for every key,

    y^g_i = base[seg(x_i)] + (x_i - x0[seg(x_i)]) * scale[seg(x_i)]

   where per-segment constants fold the paper's Eq. 3 terms
   (``base = y_k1 + S_k``, ``scale = (y_km - y_k1)(1+rho)/(x_km-x_k1)``,
   ``x0 = x_k1``; host-side prep in ``ops_gap.prepare_gap_tables``).
   Structure mirrors the lookup kernel's routing stage: keys tiled over
   the grid, segment tables VMEM-resident, branchless rank-routing via
   chunked masked counts, one fused multiply-add — O(n) with n/key_tile
   grid steps.  This makes the §5.4 combined pipeline (sample -> fit ->
   *place all n keys*) device-resident for billion-key stores.

2. ``ingest_place_call`` — the §5.3 dynamic-ingest placement stage:
   for a batch of insert keys, compute the per-key placement primitives
   (predicted slot, slot occupancy, run boundaries, order-check
   bracket) directly against the FROZEN device arrays, so
   ``Index.ingest`` ships placements back for the CSR merge instead of
   re-deriving everything in host numpy.  The per-key body
   (``ingest_place_body``) is shared verbatim with the fused-XLA
   variant in ``ops_gap`` — one numerics contract, two dispatch
   strategies (see ``ops_gap.ingest_place`` for the exactness story:
   f32 hi/lo pair compares end to end, double-f32 prediction with a
   rounding-band escape patched on host in O(#escapes)).

Double-f32 ("pair") arithmetic: slopes/intercepts and wide keys are
carried as f32 (hi, lo) pairs; ``_dd_mul``/``_dd_add2`` below implement
the classic Dekker/Knuth error-free transforms WITHOUT an fma (XLA-CPU
has no guaranteed fused multiply-add), giving ~2^-45-relative products
— far inside the host-patch escape band.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# double-f32 (pair) arithmetic — error-free transforms, no fma needed
# ---------------------------------------------------------------------------

_SPLITTER = 4097.0  # 2^12 + 1 (Veltkamp split for f32; python scalar so
#                     Pallas kernels don't capture a traced constant)


def _two_sum(a, b):
    """Knuth two-sum: s + e == a + b exactly."""
    s = a + b
    bb = s - a
    e = (a - (s - bb)) + (b - bb)
    return s, e


def _two_prod(a, b):
    """Dekker two-product via Veltkamp splitting: p + e == a * b."""
    p = a * b
    ca = _SPLITTER * a
    ah = ca - (ca - a)
    al = a - ah
    cb = _SPLITTER * b
    bh = cb - (cb - b)
    bl = b - bh
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def _dd_add2(ah, al, bh, bl):
    """(ah, al) + (bh, bl), renormalized."""
    s, e = _two_sum(ah, bh)
    e = e + (al + bl)
    return _two_sum(s, e)


def _dd_sub2(ah, al, bh, bl):
    return _dd_add2(ah, al, -bh, -bl)


def _dd_mul(ah, al, bh, bl):
    """(ah, al) * (bh, bl), renormalized (drops the al*bl term)."""
    p, e = _two_prod(ah, bh)
    e = e + (ah * bl + al * bh)
    return _two_sum(p, e)


# ---------------------------------------------------------------------------
# pair compares + fixed-trip bisects (lexicographic (hi, lo) order ==
# numeric f64 order for pair-split keys — kernels.ops.split_key_pair)
# ---------------------------------------------------------------------------


def _p_le(kh, kl, qh, ql):
    return (kh < qh) | ((kh == qh) & (kl <= ql))


def _p_lt(kh, kl, qh, ql):
    return (kh < qh) | ((kh == qh) & (kl < ql))


def _p_eq(kh, kl, qh, ql):
    return (kh == qh) & (kl == ql)


def _bisect_pair(kh, kl, qh, ql, trips, strict):
    """Rightmost index with key {<,<=} query over the whole array
    (-1 when none) — branchless fixed-trip bisect, pair-aware."""
    n = kh.shape[0]
    cmp = _p_lt if strict else _p_le
    lo0 = jnp.full(qh.shape, -1, jnp.int32)
    hi0 = jnp.full(qh.shape, n - 1, jnp.int32)

    def body(_, carry):
        lo, hi = carry
        upd = lo < hi
        mid = (lo + hi + 1) >> 1
        midc = jnp.clip(mid, 0, n - 1)
        go = cmp(jnp.take(kh, midc), jnp.take(kl, midc), qh, ql)
        lo = jnp.where(upd & go, mid, lo)
        hi = jnp.where(upd, jnp.where(go, hi, mid - 1), hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, trips, body, (lo0, hi0))
    return lo


def ingest_place_body(
    x_hi, x_lo,                       # (B,) f32 pair of batch keys
    segk_hi, segk_lo,                 # (Kpad,) f32 segment first keys
    slope_hi, slope_lo,               # (Kpad,) f32 pair of slopes
    icept_hi, icept_lo,               # (Kpad,) f32 pair of intercepts
    slot_hi, slot_lo,                 # (Mpad,) f32 pair, +inf padded
    link_offsets,                     # (>= Mpad+1,) i32 CSR offsets
    link_hi, link_lo,                 # (Lpad,) f32 pair of chain keys
    *,
    n_slots: int,
):
    """Per-key §5.3 placement primitives against frozen device arrays.

    Returns ``(p, pv, ub, free, bracket, escape)`` — the device image of
    ``GappedArray.placement_primitives`` (the host oracle):

    * predicted slot ``p = clip(rint(slope*(x - seg_key) + icept))`` in
      double-f32, with ``escape`` flagging keys whose prediction lands
      within the pair-arithmetic error band of a rounding boundary (the
      host re-derives those few exactly);
    * ``free`` from the carried-key construction: a slot is occupied iff
      its key strictly precedes its right neighbor's;
    * ``ub``/``pv`` — key-run and slot-run left boundaries via pair
      bisects (exact: the Index handle gates this path on pair-exact
      key sets);
    * ``bracket`` — boundary-key order checks incl. the left boundary's
      chain max, gathered from the CSR link tables.

    Pure jnp on purpose: the Pallas kernel calls it per key tile over
    VMEM-resident tables, the fused-XLA variant over the whole batch —
    bit-identical by construction.
    """
    k_pad = segk_hi.shape[0]
    m_pad = slot_hi.shape[0]
    seg_trips = int(max(k_pad, 2) - 1).bit_length() + 1
    slot_trips = int(max(m_pad, 2) - 1).bit_length() + 1

    # --- segment routing (searchsorted-right - 1, clipped like host) ---
    seg = _bisect_pair(segk_hi, segk_lo, x_hi, x_lo, seg_trips,
                       strict=False)
    seg = jnp.clip(seg, 0, k_pad - 1)

    # --- double-f32 prediction + rint with escape band -----------------
    fk_h = jnp.take(segk_hi, seg)
    fk_l = jnp.take(segk_lo, seg)
    dx_h, dx_l = _dd_sub2(x_hi, x_lo, fk_h, fk_l)
    sl_h = jnp.take(slope_hi, seg)
    sl_l = jnp.take(slope_lo, seg)
    ic_h = jnp.take(icept_hi, seg)
    ic_l = jnp.take(icept_lo, seg)
    m_h, m_l = _dd_mul(sl_h, sl_l, dx_h, dx_l)
    y_h, y_l = _dd_add2(m_h, m_l, ic_h, ic_l)
    rh = jnp.round(y_h)
    d = (y_h - rh) + y_l  # |y_h - rh| <= 0.5 -> Sterbenz-exact
    step = jnp.where(d > 0.5, 1, jnp.where(d < -0.5, -1, 0)).astype(
        jnp.int32)
    rh_c = jnp.clip(rh, -1.0, float(n_slots))  # i32-safe (host clips too)
    p = jnp.clip(rh_c.astype(jnp.int32) + step, 0, n_slots - 1)
    # escape band: double-f32 carries ~2^-45 relative error; flag any
    # prediction within a (hugely padded) 2^-30-relative band of the
    # .5 rounding boundary and let the host recompute it in f64
    tol = (jnp.abs(sl_h * dx_h) + jnp.abs(ic_h) + 4.0) * jnp.float32(2e-9)
    escape = jnp.abs(jnp.abs(d) - 0.5) < tol
    escape |= ~jnp.isfinite(y_h)  # f32 range overflow: host re-derives
    # clip edges: rint(f64) could land on the far side of the clip
    escape |= (rh <= 0.0) & (jnp.abs(d) > 0.4)
    escape |= (rh >= n_slots - 1) & (jnp.abs(d) > 0.4)

    # --- occupancy from the carried-key construction -------------------
    nx_h = jnp.take(slot_hi, p)
    nx_l = jnp.take(slot_lo, p)
    # right neighbor; a table frozen by _freeze_numpy always has an
    # +inf tail block past n_slots, but do not RELY on it — an exactly
    # m-sized table would otherwise self-compare the last slot and
    # misread an occupied last slot as free
    r_valid = p + 1 < m_pad
    r_i = jnp.minimum(p + 1, m_pad - 1)
    r_h = jnp.where(r_valid, jnp.take(slot_hi, r_i), jnp.inf)
    r_l = jnp.where(r_valid, jnp.take(slot_lo, r_i), 0.0)
    free = _p_eq(nx_h, nx_l, r_h, r_l)

    # --- run boundaries: key-run ub, slot-run pv -----------------------
    ub = _bisect_pair(slot_hi, slot_lo, x_hi, x_lo, slot_trips,
                      strict=False)
    pv = _bisect_pair(slot_hi, slot_lo, nx_h, nx_l, slot_trips,
                      strict=True)

    # --- bracket: prev boundary key (incl. chain max) < key < next -----
    pv_safe = jnp.maximum(pv, 0)
    pm_h = jnp.take(slot_hi, pv_safe)
    pm_l = jnp.take(slot_lo, pv_safe)
    s0 = jnp.take(link_offsets, pv_safe)
    e0 = jnp.take(link_offsets, pv_safe + 1)
    has_chain = e0 > s0
    if link_hi.shape[0]:
        ci = jnp.clip(e0 - 1, 0, link_hi.shape[0] - 1)
        cm_h = jnp.take(link_hi, ci)
        cm_l = jnp.take(link_lo, ci)
        bigger = has_chain & _p_lt(pm_h, pm_l, cm_h, cm_l)
        pm_h = jnp.where(bigger, cm_h, pm_h)
        pm_l = jnp.where(bigger, cm_l, pm_l)
    prev_ok = (pv < 0) | _p_lt(pm_h, pm_l, x_hi, x_lo)
    bracket = free & prev_ok & _p_lt(x_hi, x_lo, nx_h, nx_l)
    return p, pv, ub, free, bracket, escape


def _gap_place_kernel(
    x_ref,       # (key_tile,) f32 keys (sorted, padded +inf)
    segk_ref,    # (Kpad,) f32 segment first keys (+inf padded)
    base_ref,    # (Kpad,) f32
    x0_ref,      # (Kpad,) f32
    scale_ref,   # (Kpad,) f32
    out_ref,     # (key_tile,) f32 target positions
    *,
    seg_chunk: int,
):
    x = x_ref[:]
    kt = x.shape[0]
    k_pad = segk_ref.shape[0]

    def seg_count(c, acc):
        ks = segk_ref[pl.ds(c * seg_chunk, seg_chunk)]
        return acc + jnp.sum((ks[None, :] <= x[:, None]).astype(jnp.int32),
                             axis=1)

    n_chunks = k_pad // seg_chunk
    cnt = jax.lax.fori_loop(0, n_chunks, seg_count,
                            jnp.zeros((kt,), jnp.int32))
    seg = jnp.clip(cnt - 1, 0, k_pad - 1)
    base = jnp.take(base_ref[:], seg)
    x0 = jnp.take(x0_ref[:], seg)
    scale = jnp.take(scale_ref[:], seg)
    out_ref[:] = base + (x - x0) * scale


@functools.partial(
    jax.jit, static_argnames=("key_tile", "seg_chunk", "interpret"))
def gap_place_call(
    keys_padded,   # (Npad,) f32, padded with +inf
    seg_first_key, # (Kpad,) f32
    base,          # (Kpad,) f32
    x0,            # (Kpad,) f32
    scale,         # (Kpad,) f32
    *,
    key_tile: int = 1024,
    seg_chunk: int = 512,
    interpret: bool = False,
):
    n = keys_padded.shape[0]
    assert n % key_tile == 0 and seg_first_key.shape[0] % seg_chunk == 0
    grid = (n // key_tile,)
    kernel = functools.partial(_gap_place_kernel, seg_chunk=seg_chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((key_tile,), lambda i: (i,)),
            pl.BlockSpec(seg_first_key.shape, lambda i: (0,)),
            pl.BlockSpec(base.shape, lambda i: (0,)),
            pl.BlockSpec(x0.shape, lambda i: (0,)),
            pl.BlockSpec(scale.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((key_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(keys_padded, seg_first_key, base, x0, scale)


# ---------------------------------------------------------------------------
# §5.3 dynamic-ingest placement kernel
# ---------------------------------------------------------------------------


def _ingest_place_kernel(
    x_hi_ref, x_lo_ref,               # (key_tile,) f32 batch-key pair
    segk_hi_ref, segk_lo_ref,         # (Kpad,) segment tables
    slope_hi_ref, slope_lo_ref,
    icept_hi_ref, icept_lo_ref,
    slot_hi_ref, slot_lo_ref,         # (Mpad,) frozen slot keys
    off_ref,                          # (Opad,) i32 CSR offsets
    link_hi_ref, link_lo_ref,         # (Lpad,) chain keys
    p_ref, pv_ref, ub_ref,            # out (key_tile,) i32
    flags_ref,                        # out (key_tile,) i32 bitmask
    *,
    n_slots: int,
):
    """One key tile of ``ingest_place_body`` over VMEM-resident tables.

    The frozen tables ride whole-array BlockSpecs (slot keys at f32 are
    4 B/slot — ~4 MiB/M slots, VMEM-resident like the lookup kernel's
    segment tables; beyond that the fused-XLA variant serves).  Flags
    pack free(1) | bracket(2) | escape(4).
    """
    p, pv, ub, free, bracket, escape = ingest_place_body(
        x_hi_ref[:], x_lo_ref[:],
        segk_hi_ref[:], segk_lo_ref[:],
        slope_hi_ref[:], slope_lo_ref[:],
        icept_hi_ref[:], icept_lo_ref[:],
        slot_hi_ref[:], slot_lo_ref[:],
        off_ref[:], link_hi_ref[:], link_lo_ref[:],
        n_slots=n_slots,
    )
    p_ref[:] = p
    pv_ref[:] = pv.astype(jnp.int32)
    ub_ref[:] = ub.astype(jnp.int32)
    flags_ref[:] = (free.astype(jnp.int32)
                    + 2 * bracket.astype(jnp.int32)
                    + 4 * escape.astype(jnp.int32))


_I32MAX = 2 ** 31 - 1  # sort sentinel: above any slot/link index


def _p_min(a, b):
    """Pair lexicographic min (associative_scan combine fn)."""
    ah, al = a
    bh, bl = b
    take = _p_le(ah, al, bh, bl)
    return jnp.where(take, ah, bh), jnp.where(take, al, bl)


def fused_ingest_body(
    x_hi, x_lo,                       # (B,) f32 pair, +inf padded
    pay_lo, pay_hi,                   # (B,) i32 payload pair (-1 padded)
    segk_hi, segk_lo,                 # (Kpad,) segment tables
    slope_hi, slope_lo,
    icept_hi, icept_lo,
    slot_hi, slot_lo,                 # (Mpad,) frozen slot keys
    spay_lo, spay_hi,                 # (Mpad,) i32 slot payload pair
    link_offsets,                     # (O,) i32 CSR offsets (tail=total)
    link_hi, link_lo,                 # (Lpad,) chain keys (+inf padded)
    lpay_lo, lpay_hi,                 # (Lpad,) i32 chain payload pair
    rank_table,                       # (R+1,) i32 fused-lookup rank rows
    rank_bounds_hi, rank_bounds_lo,   # (R+1,) f32 pair of bucket bounds
    rank_scale,                       # (3,) f32 (kmin_hi, kmin_lo, scale)
    elo, ehi,                         # (k_pad,) f32 per-seg window bounds
    *,
    n_slots: int,
    max_chain: int,
    key_wide: bool,
    use_pallas: bool = False,
    interpret: bool = True,
    key_tile: int = 512,
):
    """The single-dispatch §5.3 ingest graph: placement -> partition ->
    slot scatter + carried repair -> CSR-merge scatter -> rank-row /
    window-bound refresh, all in ONE jitted XLA graph (the Pallas
    placement kernel composes inside it on TPU — still one dispatch).

    The graph serves exactly the batches whose host demotion closure is
    TRIVIAL — no collision groups (no two batch keys predict the same
    slot when either is free), no demotion rule fires on the first
    round, no contested remainder — which it detects in-graph and
    reports via ``reasons``; everything else returns the placement
    primitives untouched with ``ok=False`` so ``Index.ingest`` replays
    the batch through the host partition + delta path (the primitives
    are NOT wasted: they are the same ``ingest_place`` output the
    two-dispatch path would have computed).  On the accepted batches the
    split is provably the host's fixed point (``cand = free & bracket``,
    every other key chains at its pre-batch ``ub``), so the produced
    device images are bit-identical to freezing the post-batch host
    state:

    * slot arm — masked scatter of the key pair + payload pair at
      ``p[cand]``, then the carried-key repair as a reverse pair-min
      ``associative_scan`` (== ``_repair_carried``: pair lex order is
      numeric order for pair-exact splits);
    * chain arm — device CSR merge: chain entries are key-sorted
      (target order == key order by the global CSR key invariant), a
      strict pair bisect gives each its ``np.insert`` position, old
      elements shift by ``searchsorted(pos, i, 'right')``, offsets gain
      a prefix-sum of per-slot counts — single-allocation, no host
      ``np.insert``;
    * refresh arm — touched bucket rows of the fused lookup's rank
      table are re-bisected against the NEW slot keys in-graph, and the
      per-segment window bounds are widened by a scatter-min/max of the
      inserted keys' (slot - predict) residuals.  Both tables are
      stale-SOUND, so the f32 bound rounding here only moves the
      fallback rate, never correctness.

    Every state output is gated on ``ok`` (aborted graphs return the
    old arrays untouched).  Duplicate keys — in-batch, vs a slot key,
    or vs a chain key — abort, and the host replay raises the same
    ``KeyError`` the sequential path would.
    """
    B = x_hi.shape[0]
    m_pad = slot_hi.shape[0]
    O = link_offsets.shape[0]
    l_pad = link_hi.shape[0]
    k_pad = segk_hi.shape[0]
    iota = jnp.arange(B, dtype=jnp.int32)

    # ---- stage 1: placement primitives (shared per-key body) ----------
    if use_pallas:
        p, pv, ub, flags = ingest_place_call(
            x_hi, x_lo, segk_hi, segk_lo, slope_hi, slope_lo,
            icept_hi, icept_lo, slot_hi, slot_lo, link_offsets,
            link_hi, link_lo, key_tile=min(key_tile, B),
            n_slots=n_slots, interpret=interpret)
        free = (flags & 1) != 0
        bracket = (flags & 2) != 0
        escape = (flags & 4) != 0
    else:
        p, pv, ub, free, bracket, escape = ingest_place_body(
            x_hi, x_lo, segk_hi, segk_lo, slope_hi, slope_lo,
            icept_hi, icept_lo, slot_hi, slot_lo, link_offsets,
            link_hi, link_lo, n_slots=n_slots)
    p = p.astype(jnp.int32)
    pv = pv.astype(jnp.int32)
    ub = ub.astype(jnp.int32)
    valid = jnp.isfinite(x_hi)
    free &= valid
    bracket &= valid
    escape &= valid

    # ---- stage 2: batch key ranks + in-batch duplicate detection ------
    # all later key compares among batch keys become i32 rank compares
    # (exact for the distinct keys dup detection guarantees)
    xs_hi, xs_lo, perm = jax.lax.sort((x_hi, x_lo, iota), num_keys=2,
                                      is_stable=True)
    both_fin = jnp.isfinite(xs_hi[1:]) & jnp.isfinite(xs_hi[:-1])
    dup_batch = jnp.any(both_fin & _p_eq(xs_hi[:-1], xs_lo[:-1],
                                         xs_hi[1:], xs_lo[1:]))
    rank = jnp.zeros(B, jnp.int32).at[perm].set(iota)

    # ---- stage 3: closure-trivial partition + abort detection ---------
    # collision groups: any free key sharing a predicted slot with any
    # other batch key aborts (the host winner/loser machinery owns it)
    pa = jnp.where(valid, p, _I32MAX)
    ps_a, free_a = jax.lax.sort((pa, free.astype(jnp.int32)),
                                num_keys=1, is_stable=True)
    eq = (ps_a[1:] == ps_a[:-1]) & (ps_a[1:] != _I32MAX)
    isdup_s = jnp.concatenate([eq, jnp.zeros(1, bool)]) \
        | jnp.concatenate([jnp.zeros(1, bool), eq])
    grp_abort = jnp.any(isdup_s & (free_a > 0))

    cand = free & bracket
    hard = valid & ~cand

    # batch key == stored slot key -> the host raises KeyError
    ubc = jnp.clip(ub, 0, m_pad - 1)
    bdup_any = jnp.any(valid & (ub >= 0) & _p_eq(
        jnp.take(slot_hi, ubc), jnp.take(slot_lo, ubc), x_hi, x_lo))

    # leading-run displacement / contested (host rule D3 + class C)
    c_abort = jnp.any(hard & (ub < 0))

    # D1 (chain capture): a hard key chaining into a candidate's run
    # with a LARGER key would demote the candidate on the host
    runmax = jnp.full(n_slots + 1, -1, jnp.int32)
    runmax = runmax.at[jnp.where(hard, ub + 1, 0)].max(
        jnp.where(hard, rank, -1))
    d1_any = jnp.any(cand & (rank < jnp.take(
        runmax, jnp.clip(pv + 1, 0, n_slots))))

    # D4 (co-monotonicity): adjacent candidates of one run whose slot
    # order disagrees with key order demote on the host
    pc = jnp.where(cand, p, _I32MAX)
    ps_c, rk_c, pv_c = jax.lax.sort(
        (pc, rank, jnp.where(cand, pv, -2)), num_keys=1, is_stable=True)
    d4_any = jnp.any((ps_c[1:] != _I32MAX) & (ps_c[:-1] != _I32MAX)
                     & (pv_c[1:] == pv_c[:-1]) & (rk_c[1:] <= rk_c[:-1]))
    # (D2 cannot fire here: its occupier set is hard & free & bracket,
    # empty once collision groups are excluded — cand == free & bracket)

    # ---- stage 4: chain-arm counts + capacity checks ------------------
    cnt = jnp.zeros(O, jnp.int32).at[jnp.where(hard, ub + 1, 0)].add(
        jnp.where(hard, 1, 0))
    n_chain = jnp.sum(hard.astype(jnp.int32))
    n_slot = jnp.sum(cand.astype(jnp.int32))
    L_old = link_offsets[n_slots]
    ub1 = jnp.clip(ub + 1, 0, O - 1)
    old_len = jnp.take(link_offsets, ub1) \
        - jnp.take(link_offsets, jnp.clip(ub, 0, O - 1))
    chain_over = jnp.any(hard & (old_len + jnp.take(cnt, ub1) > max_chain))
    link_over = L_old + n_chain > l_pad

    # ---- stage 5: device CSR merge (the np.insert replacement) --------
    # chain entries sorted by key == sorted by (target, key): per-slot
    # chain key ranges ascend with the slot (global CSR invariant)
    ch_hi = jnp.where(hard, x_hi, jnp.inf)
    ch_lo = jnp.where(hard, x_lo, 0.0)
    sh, sl_, spl, sph, jflag = jax.lax.sort(
        (ch_hi, ch_lo, pay_lo, pay_hi, hard.astype(jnp.int32)),
        num_keys=2, is_stable=True)
    jmask = jflag > 0
    link_trips = int(max(l_pad, 2) - 1).bit_length() + 1
    pos = _bisect_pair(link_hi, link_lo, sh, sl_, link_trips,
                       strict=True) + 1
    posc = jnp.clip(pos, 0, l_pad - 1)
    edup_any = jnp.any(jmask & (pos < L_old) & _p_eq(
        jnp.take(link_hi, posc), jnp.take(link_lo, posc), sh, sl_))
    cj = jnp.cumsum(jmask.astype(jnp.int32)) - 1
    dst_new = jnp.where(jmask, pos + cj, l_pad)
    pos_eff = jnp.where(jmask, pos, l_pad + 1)  # sorted: jmask is a prefix
    old_i = jnp.arange(l_pad, dtype=jnp.int32)
    dst_old = old_i + jnp.searchsorted(pos_eff, old_i,
                                       side="right").astype(jnp.int32)
    new_lhi = jnp.full(l_pad, jnp.inf, jnp.float32) \
        .at[dst_old].set(link_hi, mode="drop") \
        .at[dst_new].set(sh, mode="drop")
    new_llo = jnp.zeros(l_pad, jnp.float32) \
        .at[dst_old].set(link_lo, mode="drop") \
        .at[dst_new].set(sl_, mode="drop")
    new_lpl = jnp.full(l_pad, -1, jnp.int32) \
        .at[dst_old].set(lpay_lo, mode="drop") \
        .at[dst_new].set(spl, mode="drop")
    new_lph = jnp.full(l_pad, -1, jnp.int32) \
        .at[dst_old].set(lpay_hi, mode="drop") \
        .at[dst_new].set(sph, mode="drop")
    new_off = link_offsets + jnp.cumsum(cnt)

    # ---- stage 6: slot arm — scatter + carried-key repair -------------
    nb_hi = jnp.concatenate([slot_hi[1:], jnp.full(1, jnp.inf,
                                                   jnp.float32)])
    nb_lo = jnp.concatenate([slot_lo[1:], jnp.zeros(1, jnp.float32)])
    occ_old = _p_lt(slot_hi, slot_lo, nb_hi, nb_lo)
    idx_c = jnp.where(cand, p, m_pad)
    occ_new = occ_old.at[idx_c].set(True, mode="drop")
    sc_hi = slot_hi.at[idx_c].set(x_hi, mode="drop")
    sc_lo = slot_lo.at[idx_c].set(x_lo, mode="drop")
    new_shi, new_slo = jax.lax.associative_scan(
        _p_min,
        (jnp.where(occ_new, sc_hi, jnp.inf),
         jnp.where(occ_new, sc_lo, 0.0)),
        reverse=True)
    new_pl = spay_lo.at[idx_c].set(pay_lo, mode="drop")
    new_ph = spay_hi.at[idx_c].set(pay_hi, mode="drop")

    # ---- stage 7: rank-row refresh against the NEW slot keys ----------
    r_size = rank_table.shape[0] - 1
    if key_wide:
        xb = (x_hi - rank_scale[0]) + (x_lo - rank_scale[1])
    else:
        xb = x_hi - rank_scale[0]
    b = jnp.clip(xb * rank_scale[2], 0.0,
                 float(r_size - 1)).astype(jnp.int32)
    rows = jnp.clip(jnp.concatenate([b - 1, b, b + 1]), 0, r_size)
    rows_ok = jnp.concatenate([valid] * 3) & (rows < r_size)
    slot_trips = int(max(m_pad, 2) - 1).bit_length() + 1
    vals = _bisect_pair(new_shi, new_slo,
                        jnp.take(rank_bounds_hi, rows),
                        jnp.take(rank_bounds_lo, rows),
                        slot_trips, strict=True) + 1
    new_rank = rank_table.at[jnp.where(rows_ok, rows, r_size + 1)].set(
        vals, mode="drop")

    # ---- stage 8: window-bound widening for the inserted keys ---------
    seg_trips = int(max(k_pad, 2) - 1).bit_length() + 1
    seg = jnp.clip(_bisect_pair(segk_hi, segk_lo, x_hi, x_lo, seg_trips,
                                strict=False), 0, k_pad - 1)
    y1 = jnp.take(slope_hi, seg) * (x_hi - jnp.take(segk_hi, seg)) \
        + jnp.take(icept_hi, seg)
    dlt = p.astype(jnp.float32) - y1
    segc = jnp.where(cand, seg, k_pad)
    new_elo = elo.at[segc].min(dlt - 1.0, mode="drop")
    new_ehi = ehi.at[segc].max(dlt + 1.0, mode="drop")

    # ---- abort gating -------------------------------------------------
    reasons = (jnp.any(escape).astype(jnp.int32)
               + 2 * dup_batch.astype(jnp.int32)
               + 4 * grp_abort.astype(jnp.int32)
               + 8 * bdup_any.astype(jnp.int32)
               + 16 * c_abort.astype(jnp.int32)
               + 32 * d1_any.astype(jnp.int32)
               + 64 * d4_any.astype(jnp.int32)
               + 128 * chain_over.astype(jnp.int32)
               + 256 * link_over.astype(jnp.int32)
               + 512 * edup_any.astype(jnp.int32))
    ok = reasons == 0
    gate = lambda new, old: jnp.where(ok, new, old)  # noqa: E731
    return (p, pv, ub, free, bracket, escape, ok, reasons,
            n_slot, n_chain, seg, dlt,
            gate(new_shi, slot_hi), gate(new_slo, slot_lo),
            gate(new_pl, spay_lo), gate(new_ph, spay_hi),
            gate(new_off, link_offsets),
            gate(new_lhi, link_hi), gate(new_llo, link_lo),
            gate(new_lpl, lpay_lo), gate(new_lph, lpay_hi),
            gate(new_rank, rank_table),
            gate(new_elo, elo), gate(new_ehi, ehi))


@functools.partial(
    jax.jit, static_argnames=("key_tile", "n_slots", "interpret"))
def ingest_place_call(
    x_hi, x_lo,            # (Bpad,) f32 pair, Bpad % key_tile == 0
    segk_hi, segk_lo,
    slope_hi, slope_lo,
    icept_hi, icept_lo,
    slot_hi, slot_lo,
    link_offsets,          # i32
    link_hi, link_lo,
    *,
    key_tile: int = 512,
    n_slots: int,
    interpret: bool = False,
):
    n = x_hi.shape[0]
    assert n % key_tile == 0
    grid = (n // key_tile,)
    kernel = functools.partial(_ingest_place_kernel, n_slots=n_slots)
    whole = lambda a: pl.BlockSpec(a.shape, lambda i: (0,))  # noqa: E731
    out32 = jax.ShapeDtypeStruct((n,), jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((key_tile,), lambda i: (i,)),
            pl.BlockSpec((key_tile,), lambda i: (i,)),
            whole(segk_hi), whole(segk_lo),
            whole(slope_hi), whole(slope_lo),
            whole(icept_hi), whole(icept_lo),
            whole(slot_hi), whole(slot_lo),
            whole(link_offsets), whole(link_hi), whole(link_lo),
        ],
        out_specs=[pl.BlockSpec((key_tile,), lambda i: (i,))] * 4,
        out_shape=[out32, out32, out32, out32],
        interpret=interpret,
    )(x_hi, x_lo, segk_hi, segk_lo, slope_hi, slope_lo, icept_hi,
      icept_lo, slot_hi, slot_lo, link_offsets, link_hi, link_lo)
