"""Eq. 3 gap-position manipulation — Pallas TPU kernel.

Computes the result-driven target position for every key,

    y^g_i = base[seg(x_i)] + (x_i - x0[seg(x_i)]) * scale[seg(x_i)]

where per-segment constants fold the paper's Eq. 3 terms
(``base = y_k1 + S_k``, ``scale = (y_km - y_k1)(1+rho)/(x_km - x_k1)``,
``x0 = x_k1``; host-side prep in ``ops_gap.prepare_gap_tables``).
Structure mirrors the lookup kernel's routing stage: keys tiled over the
grid, segment tables VMEM-resident, branchless rank-routing via chunked
masked counts, one fused multiply-add — O(n) with n/key_tile grid steps,
each reading key_tile*4 B of keys and writing the same in positions.

This makes the §5.4 combined pipeline (sample -> fit -> *place all n
keys*) device-resident for billion-key stores: the only O(n) stage runs
at HBM bandwidth instead of host memory bandwidth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gap_place_kernel(
    x_ref,       # (key_tile,) f32 keys (sorted, padded +inf)
    segk_ref,    # (Kpad,) f32 segment first keys (+inf padded)
    base_ref,    # (Kpad,) f32
    x0_ref,      # (Kpad,) f32
    scale_ref,   # (Kpad,) f32
    out_ref,     # (key_tile,) f32 target positions
    *,
    seg_chunk: int,
):
    x = x_ref[:]
    kt = x.shape[0]
    k_pad = segk_ref.shape[0]

    def seg_count(c, acc):
        ks = segk_ref[pl.ds(c * seg_chunk, seg_chunk)]
        return acc + jnp.sum((ks[None, :] <= x[:, None]).astype(jnp.int32),
                             axis=1)

    n_chunks = k_pad // seg_chunk
    cnt = jax.lax.fori_loop(0, n_chunks, seg_count,
                            jnp.zeros((kt,), jnp.int32))
    seg = jnp.clip(cnt - 1, 0, k_pad - 1)
    base = jnp.take(base_ref[:], seg)
    x0 = jnp.take(x0_ref[:], seg)
    scale = jnp.take(scale_ref[:], seg)
    out_ref[:] = base + (x - x0) * scale


@functools.partial(
    jax.jit, static_argnames=("key_tile", "seg_chunk", "interpret"))
def gap_place_call(
    keys_padded,   # (Npad,) f32, padded with +inf
    seg_first_key, # (Kpad,) f32
    base,          # (Kpad,) f32
    x0,            # (Kpad,) f32
    scale,         # (Kpad,) f32
    *,
    key_tile: int = 1024,
    seg_chunk: int = 512,
    interpret: bool = False,
):
    n = keys_padded.shape[0]
    assert n % key_tile == 0 and seg_first_key.shape[0] % seg_chunk == 0
    grid = (n // key_tile,)
    kernel = functools.partial(_gap_place_kernel, seg_chunk=seg_chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((key_tile,), lambda i: (i,)),
            pl.BlockSpec(seg_first_key.shape, lambda i: (0,)),
            pl.BlockSpec(base.shape, lambda i: (0,)),
            pl.BlockSpec(x0.shape, lambda i: (0,)),
            pl.BlockSpec(scale.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((key_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(keys_padded, seg_first_key, base, x0, scale)
