"""Per-shard fan-out of the fused lookup graph (``shard_map`` + all-to-all).

This is the kernels half of ``repro.dist.sharded``: the per-shard frozen
images are STACKED into ``(S, ...)`` arrays, placed across a device mesh
via the existing partitioning machinery (``repro.dist.partitioning``
derives the PartitionSpecs, ``launch.mesh.make_mesh_for`` builds the
mesh), and ONE ``shard_map``-dispatched graph serves a whole query batch:

1. **route** — every device routes its local query block with the
   learned two-segment router (one multiply-add per query) backed by an
   EXACT boundary check: mispredicted rows fall back, in-graph, to a
   fixed-trip bisect over the shard boundaries, so routing is exact by
   construction and the prediction only buys the common-case gathers
   (mispredict count rides home as telemetry);
2. **bucket-count + exchange** — a stable counting sort groups the local
   queries by destination shard into an ``(S, cap)`` send buffer and one
   ``lax.all_to_all`` delivers every query to the device owning its
   shard (capacity overflows are flagged, never dropped silently — the
   rows resolve through the host escape patch and the per-bucket cap
   sticky-doubles like the engine's fallback buffer);
3. **per-shard fused search** — each device runs the SAME
   ``_fused_search`` + ``_epilogue`` stages as the single-index fused
   backend, vmapped over its local shards against the stacked slot/chain
   images and per-shard rank tables;
4. **return + inverse permutation** — a second all-to-all returns
   payload/slot/found/escape per query and the counting sort's inverse
   permutation restores caller order.

Exactness contract: per-shard results are exact by the fused search's
bracket validation (escapes are flagged and host-patched, as on the
single-engine path); ROUTING is exact because the boundary backstop
compares in the same rounded key representation (f32, or f32 hi/lo
pair) the per-shard search uses, and stacking refuses key sets whose
rounded shard boundaries are not strictly ordered — so the sharded
answer is bit-identical to the single-device fused answer over the
same keys.  Slots come back shard-local; the caller offsets them by
the per-shard slot base.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from . import ops as _ops

__all__ = ["ShardFanout", "FanoutUnavailable", "stack_shard_images",
           "largest_divisor_leq"]


class FanoutUnavailable(Exception):
    """The shard set cannot be served by the fused fan-out graph
    (non-PLM mechanism, aliasing keys, unordered rounded boundaries);
    the caller keeps the host route + per-shard path."""


def largest_divisor_leq(s: int, n: int) -> int:
    """Largest divisor of ``s`` that is ``<= n`` (>= 1)."""
    for d in range(min(s, max(n, 1)), 0, -1):
        if s % d == 0:
            return d
    return 1


def _pad_to(a: np.ndarray, n: int, fill) -> np.ndarray:
    if a.shape[0] == n:
        return a
    pad = np.full(n - a.shape[0], fill, a.dtype)
    return np.concatenate([a, pad])


def stack_shard_images(shards, *, w_tile: int = 2048):
    """Freeze every shard (``_freeze_numpy``) and stack the padded
    images into ``(S, ...)`` numpy arrays with shared statics.

    Shards are frozen with ``force_wide``/``force_key_wide`` set to the
    OR across shards, so one set of jit statics serves all of them —
    narrow shards in a wide stack carry zero lo-residuals, which is
    exact.  Per-shard rank-router tables are built on the padded slot
    keys and stacked alongside.  Returns ``(stacked, statics)``.
    """
    imgs = [_ops._freeze_numpy(sh, w_tile=w_tile) for sh in shards]
    wide = any(st["wide"] for _, st in imgs)
    key_wide = any(st["key_wide"] for _, st in imgs)
    imgs = [
        (arr, st) if (st["wide"] == wide and st["key_wide"] == key_wide)
        else _ops._freeze_numpy(sh, w_tile=w_tile, force_wide=wide,
                                force_key_wide=key_wide)
        for sh, (arr, st) in zip(shards, imgs)
    ]
    m_pad = max(a["slot_key"].shape[0] for a, _ in imgs)
    o_pad = max(a["link_offsets"].shape[0] for a, _ in imgs)
    l_pad = max(max(a["link_keys"].shape[0] for a, _ in imgs), 1)

    def col(field, n, fill, dtype):
        return np.stack([
            _pad_to(np.asarray(a[field], dtype), n, fill) for a, _ in imgs])

    stacked = {
        "slot_key": col("slot_key", m_pad, np.inf, np.float32),
        "payload": col("payload", m_pad, -1, np.int32),
        "link_keys": col("link_keys", l_pad, np.inf, np.float32),
        "link_payloads": col("link_payloads", l_pad, -1, np.int32),
        # offset tails repeat the per-shard total so padded slots read
        # empty chains
        "link_offsets": np.stack([
            _pad_to(np.asarray(a["link_offsets"], np.int32), o_pad,
                    a["link_offsets"][-1]) for a, _ in imgs]),
        "slot_key_lo": (col("slot_key_lo", m_pad, 0.0, np.float32)
                        if key_wide else np.zeros((len(imgs), 0),
                                                  np.float32)),
        "link_keys_lo": (col("link_keys_lo", l_pad, 0.0, np.float32)
                         if key_wide else np.zeros((len(imgs), 0),
                                                   np.float32)),
        "payload_hi": (col("payload_hi", m_pad, -1, np.int32)
                       if wide else np.zeros((len(imgs), 0), np.int32)),
        "link_payload_hi": (col("link_payload_hi", l_pad, -1, np.int32)
                            if wide else np.zeros((len(imgs), 0),
                                                  np.int32)),
    }
    tables, scales, trips = [], [], 1
    for a, st in imgs:
        tbl, scl, tr, _meta = _ops.build_rank_router(
            a["slot_key"], a["slot_key_lo"] if st["key_wide"] else None)
        tables.append(tbl)
        scales.append(scl)
        trips = max(trips, tr)
    stacked["rank_table"] = np.stack(tables)
    stacked["rank_scale"] = np.stack(scales)
    statics = {
        "n_shards": len(imgs),
        "trips": trips,
        "max_chain": max(st["max_chain"] for _, st in imgs),
        "wide": wide,
        "key_wide": key_wide,
        "n_slots": np.array([st["n_slots"] for _, st in imgs], np.int64),
    }
    return stacked, statics


def _live_extent(ga):
    """(min, max) live key of a gapped array, chains included."""
    sk = np.asarray(ga.slot_key, np.float64)[np.asarray(ga.occupied, bool)]
    lo, hi = float(sk[0]), float(sk[-1])
    ck = np.asarray(ga.links.chain_keys, np.float64)
    if ck.size:
        lo = min(lo, float(np.min(ck)))
        hi = max(hi, float(np.max(ck)))
    return lo, hi


def _round_key_repr(q64: np.ndarray, key_wide: bool) -> np.ndarray:
    """f64 value of a query's frozen-representation rounding (pair sum
    when wide, f32 round trip when narrow) — the order the device
    compares in."""
    q64 = np.asarray(q64, np.float64)
    if key_wide:
        hi, lo = _ops.split_key_pair(q64)
        return hi.astype(np.float64) + lo.astype(np.float64)
    with np.errstate(over="ignore"):
        return q64.astype(np.float32).astype(np.float64)


def _route_block(qh, ql, bnd_hi, bnd_lo, rparams, s, r_trips, key_wide):
    """Learned two-segment route + exact boundary backstop, in-graph.

    ``rparams`` is the f32 octet [x0_hi, x0_lo, slope0, icept0, slope1,
    icept1, split_hi, split_lo].  The prediction picks the shard; ONE
    boundary-pair gather certifies it (``bnd[s-1] <= q < bnd[s]``), and
    certified-wrong rows take a fixed-trip bisect over the (S-1,)
    boundary array — exact in the same rounded representation the
    per-shard search compares in.  Returns ``(dst, mispredicts)``.
    """
    if s == 1:
        return (jnp.zeros(qh.shape, jnp.int32),
                jnp.zeros((), jnp.int32))
    if key_wide:
        x = (qh - rparams[0]) + (ql - rparams[1])
        seg1 = _ops._ple(rparams[6], rparams[7], qh, ql)
    else:
        x = qh - rparams[0]
        seg1 = qh >= rparams[6]
    pred = jnp.where(seg1, x * rparams[4] + rparams[5],
                     x * rparams[2] + rparams[3])
    s_hat = jnp.clip(jnp.rint(pred), 0.0, float(s - 1)).astype(jnp.int32)
    lo_i = jnp.clip(s_hat - 1, 0, s - 2)
    hi_i = jnp.clip(s_hat, 0, s - 2)
    if key_wide:
        lo_ok = (s_hat == 0) | _ops._ple(
            jnp.take(bnd_hi, lo_i), jnp.take(bnd_lo, lo_i), qh, ql)
        hi_ok = (s_hat == s - 1) | ~_ops._ple(
            jnp.take(bnd_hi, hi_i), jnp.take(bnd_lo, hi_i), qh, ql)
    else:
        lo_ok = (s_hat == 0) | (jnp.take(bnd_hi, lo_i) <= qh)
        hi_ok = (s_hat == s - 1) | (jnp.take(bnd_hi, hi_i) > qh)
    ok = lo_ok & hi_ok
    # exact backstop: rightmost boundary <= q (pair compare degenerates
    # to the plain f32 compare when the lo planes are zero)
    zl = jnp.zeros_like(qh) if not key_wide else ql
    bl = jnp.zeros_like(bnd_hi) if not key_wide else bnd_lo
    i = _ops._pair_bisect(
        bnd_hi, bl, qh, zl,
        jnp.full(qh.shape, -1, jnp.int32),
        jnp.full(qh.shape, s - 2, jnp.int32), r_trips)
    dst = jnp.where(ok, s_hat, (i + 1).astype(jnp.int32))
    mis = jnp.sum((~ok & jnp.isfinite(qh)).astype(jnp.int32))
    return dst, mis


class ShardFanout:
    """Device-resident stacked shard state + the compiled fan-out graph.

    Built by ``repro.dist.sharded.ShardedIndex`` from its per-shard
    handles; tagged with the shard epochs it froze at (the owner
    rebuilds on staleness).  ``lookup`` pads the batch to a
    D-divisible power-of-two bucket, runs the shard_map graph, and
    patches flagged rows (search escapes + exchange-capacity overflows)
    through the per-shard host views in O(#escapes).
    """

    def __init__(self, stacked: dict, statics: dict, bounds: np.ndarray,
                 router_params: np.ndarray, epochs: tuple,
                 min_bucket: int = 512):
        self.S = int(statics["n_shards"])
        self._stacked_np = stacked  # numpy originals feed the host views
        self.statics = statics
        self.epochs = tuple(epochs)
        self.min_bucket = int(min_bucket)
        n_dev = len(jax.devices())
        self.D = largest_divisor_leq(self.S, n_dev)
        from ..launch.mesh import make_mesh_for
        from ..dist.partitioning import pspec_for_axes
        self.mesh = make_mesh_for(self.D)
        # stacked (S, ...) arrays are "batch"-sharded over the mesh data
        # axis through the standard rule table; router tables replicate
        self._specs = {
            k: pspec_for_axes(("batch",) + (None,) * (v.ndim - 1),
                              self.mesh, shape=v.shape)
            for k, v in stacked.items()
        }
        self.stacked = {
            k: jax.device_put(v, NamedSharding(self.mesh, self._specs[k]))
            for k, v in stacked.items()
        }
        rep = NamedSharding(self.mesh, P())
        key_wide = statics["key_wide"]
        if self.S > 1:
            b64 = np.asarray(bounds, np.float64)
            bh, blo = _ops.split_key_pair(b64)
            self.bnd_hi = jax.device_put(bh, rep)
            self.bnd_lo = jax.device_put(
                blo if key_wide else np.zeros_like(blo), rep)
            self._bounds_rounded = _round_key_repr(b64, key_wide)
            self.r_trips = int(np.ceil(np.log2(max(self.S - 1, 2)))) + 1
        else:
            self.bnd_hi = jax.device_put(np.zeros(1, np.float32), rep)
            self.bnd_lo = jax.device_put(np.zeros(1, np.float32), rep)
            self._bounds_rounded = np.zeros(0, np.float64)
            self.r_trips = 1
        self.rparams = jax.device_put(
            np.asarray(router_params, np.float32), rep)
        self.slot_base = np.concatenate(
            [[0], np.cumsum(np.asarray(statics["n_slots"], np.int64))[:-1]])
        self._host_views: dict = {}
        self._compiled: dict = {}
        self._cap_boost: dict = {}
        self.stats = {"fanout_lookups": 0, "mispredicts": 0,
                      "routed": 0, "escapes": 0, "cap_overflows": 0}

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, shards, bounds, router_params, *,
              min_bucket: int = 512) -> "ShardFanout":
        """Stack + place the shard images of a list of ``Index``
        handles.  Raises ``FanoutUnavailable`` when the fused graph
        cannot serve them exactly (see module doc)."""
        for sh in shards:
            if getattr(sh.mech, "plm", None) is None:
                raise FanoutUnavailable(
                    f"shard mechanism {sh.method!r} exports no PLM")
            wide, exact = sh._key_caps()
            if wide and not exact:
                raise FanoutUnavailable(
                    "shard keys alias in the f32 hi/lo pair representation")
        try:
            stacked, statics = stack_shard_images(shards)
        except _ops._CapacityError as e:  # pragma: no cover - defensive
            raise FanoutUnavailable(str(e)) from None
        kw = statics["key_wide"]
        # the rounded shard boundaries must stay strictly interleaved
        # with the rounded shard contents, or routing (exact in rounded
        # space) could disagree with the single-device rounded search
        ext = np.array([_live_extent(sh.gapped) for sh in shards])
        firsts = _round_key_repr(ext[:, 0], kw)
        lasts = _round_key_repr(ext[:, 1], kw)
        if not (np.all(np.diff(firsts) > 0)
                and np.all(lasts[:-1] < firsts[1:])):
            raise FanoutUnavailable(
                "rounded shard boundaries are not strictly ordered")
        return cls(stacked, statics, bounds, router_params,
                   tuple(sh.epoch for sh in shards),
                   min_bucket=min_bucket)

    # ------------------------------------------------------------------
    def _shard_host_views(self, s: int) -> dict:
        """Lazily built host view of shard ``s``'s frozen image, shaped
        for ``resolve_escapes_host`` (exact in the device's rounded
        representation)."""
        v = self._host_views.get(s)
        if v is not None:
            return v
        st, a = self.statics, self._stacked_np
        sk = a["slot_key"][s].astype(np.float64)
        lk = a["link_keys"][s].astype(np.float64)
        pay = a["payload"][s].astype(np.int64)
        lp = a["link_payloads"][s].astype(np.int64)
        if st["key_wide"]:
            sk = sk + a["slot_key_lo"][s].astype(np.float64)
            lk = lk + a["link_keys_lo"][s].astype(np.float64)
        if st["wide"]:
            pay = (pay & 0xFFFFFFFF) | (
                a["payload_hi"][s].astype(np.int64) << 32)
            lp = (lp & 0xFFFFFFFF) | (
                a["link_payload_hi"][s].astype(np.int64) << 32)
        v = {"slot_key": sk, "payload": pay,
             "offsets": a["link_offsets"][s], "link_keys": lk,
             "link_payloads": lp, "max_chain": st["max_chain"],
             "key_wide": st["key_wide"]}
        self._host_views[s] = v
        return v

    def route_host(self, q64: np.ndarray) -> np.ndarray:
        """Exact host routing in the device's rounded representation —
        the authority the escape patch and the host fan-in path use."""
        if self.S == 1:
            return np.zeros(np.asarray(q64).shape[0], np.int64)
        qr = _round_key_repr(q64, self.statics["key_wide"])
        return np.searchsorted(self._bounds_rounded, qr,
                               side="right").astype(np.int64)

    # ------------------------------------------------------------------
    def _fn(self, cap: int):
        fn = self._compiled.get(cap)
        if fn is None:
            fn = self._build_fn(cap)
            self._compiled[cap] = fn
        return fn

    def _build_fn(self, cap: int):
        S, D = self.S, self.D
        s_loc = S // D
        st = self.statics
        trips, r_trips = st["trips"], self.r_trips
        max_chain, wide, key_wide = (st["max_chain"], st["wide"],
                                     st["key_wide"])

        def one_shard(q, ql, sk, skl, pay, payh, off, lk, lkl, lp, lph,
                      tbl, scl):
            slot, found, fb = _ops._fused_search(
                q, ql, sk, skl, tbl, scl, trips, key_wide)
            out, out_hi, resolved = _ops._epilogue(
                q, ql, slot, found, pay, payh, off, lk, lkl, lp, lph,
                max_chain, wide, key_wide)
            return out, out_hi, slot, resolved, fb

        def block(qh, ql, bnd_hi, bnd_lo, rparams, arrs):
            nq = qh.shape[0]
            dst, mis = _route_block(qh, ql, bnd_hi, bnd_lo, rparams, S,
                                    r_trips, key_wide)
            order = jnp.argsort(dst, stable=True)
            dsts = jnp.take(dst, order)
            qhs = jnp.take(qh, order)
            counts = jnp.zeros((S,), jnp.int32).at[dst].add(1)
            start = jnp.cumsum(counts) - counts
            pos = jnp.arange(nq, dtype=jnp.int32) - jnp.take(start, dsts)
            dropped = (pos >= cap) & jnp.isfinite(qhs)

            def exch_in(vals, fill):
                send = jnp.full((S, cap), fill, vals.dtype).at[
                    dsts, pos].set(vals, mode="drop")
                recv = jax.lax.all_to_all(send, "data", 0, 0, tiled=True)
                return recv.reshape(D, s_loc, cap).transpose(
                    1, 0, 2).reshape(s_loc, D * cap)

            rq_h = exch_in(qhs, jnp.float32(jnp.inf))
            rq_l = (exch_in(jnp.take(ql, order), jnp.float32(0))
                    if key_wide else jnp.zeros_like(rq_h))
            out, out_hi, slot, resolved, fb = jax.vmap(one_shard)(
                rq_h, rq_l, arrs["slot_key"], arrs["slot_key_lo"],
                arrs["payload"], arrs["payload_hi"], arrs["link_offsets"],
                arrs["link_keys"], arrs["link_keys_lo"],
                arrs["link_payloads"], arrs["link_payload_hi"],
                arrs["rank_table"], arrs["rank_scale"])

            def exch_back(vals):
                send = vals.reshape(s_loc, D, cap).transpose(
                    1, 0, 2).reshape(S, cap)
                return jax.lax.all_to_all(send, "data", 0, 0, tiled=True)

            pos_c = jnp.clip(pos, 0, cap - 1)
            inv = jnp.argsort(order)

            def home(vals):  # per-shard rows -> caller order
                return jnp.take(exch_back(vals)[dsts, pos_c], inv)

            flags = (resolved.astype(jnp.int8)
                     | (fb.astype(jnp.int8) << 1)).reshape(s_loc, D * cap)
            fl = home(flags)
            out_q = home(out.reshape(s_loc, D * cap))
            out_hi_q = (home(out_hi.reshape(s_loc, D * cap)) if wide
                        else out_q)
            slot_q = home(slot.reshape(s_loc, D * cap))
            fb_q = ((fl >> 1) & 1).astype(bool) | jnp.take(dropped, inv)
            found_q = (fl & 1).astype(bool) & ~fb_q
            n_drop = jnp.sum(dropped.astype(jnp.int32))
            return (out_q, out_hi_q, slot_q, found_q, fb_q, dst,
                    mis.reshape(1), n_drop.reshape(1))

        qspec = P("data")
        aspecs = {k: self._specs[k] for k in self.stacked}
        mapped = shard_map(
            block, mesh=self.mesh,
            in_specs=(qspec, qspec, P(None), P(None), P(None), aspecs),
            out_specs=(qspec, qspec, qspec, qspec, qspec, qspec,
                       P("data"), P("data")),
            check_rep=False)
        return jax.jit(mapped)

    # ------------------------------------------------------------------
    def _bucket(self, n: int) -> int:
        # D-divisible with a power-of-two per-device block, so each
        # (bucket, cap) pair compiles once and D need not be a pow2
        nq_loc = _ops._round_pow2(
            -(-max(n, self.min_bucket) // self.D))
        return self.D * nq_loc

    def _cap_for(self, bucket: int) -> int:
        nq_loc = bucket // self.D
        base = _ops._round_pow2(
            max(16, -(-2 * nq_loc // max(self.S, 1))))
        cap = base * self._cap_boost.get(bucket, 1)
        return min(cap, _ops._round_pow2(nq_loc))

    def lookup(self, q64: np.ndarray):
        """Fan-out lookup: ``(payload_i64, slot_i64 global, found,
        shard_of, n_escapes, n_mispredict)`` in caller order, exact
        (flagged rows host-patched)."""
        q64 = np.asarray(q64, np.float64)
        n = q64.shape[0]
        bucket = self._bucket(n)
        cap = self._cap_for(bucket)
        qp = np.full(bucket, np.inf, np.float64)
        qp[:n] = q64
        qh, ql = _ops._split_queries(qp, self.statics["key_wide"])
        if not self.statics["key_wide"]:
            ql = np.zeros(bucket, np.float32)
        out, out_hi, slot, found, fb, dst, mis, ndrop = self._fn(cap)(
            qh, ql, self.bnd_hi, self.bnd_lo, self.rparams, self.stacked)
        n_drop = int(np.sum(np.asarray(ndrop)))
        if n_drop:
            # sticky per-bucket escalation, like the engine's fallback
            # buffer: the flagged rows still resolve exactly (host
            # patch below); later calls get a wider exchange
            self._cap_boost[bucket] = min(
                self._cap_boost.get(bucket, 1) * 4, 64)
            self.stats["cap_overflows"] += 1
        pay = np.asarray(out[:n]).astype(np.int64)
        if self.statics["wide"]:
            pay = (np.asarray(out_hi[:n]).astype(np.int64) << 32) | (
                pay & 0xFFFFFFFF)
        slot_np = np.asarray(slot[:n]).astype(np.int64)
        found_np = np.array(np.asarray(found[:n], bool))
        fb_np = np.asarray(fb[:n], bool)
        shard_of = np.asarray(dst[:n]).astype(np.int64)
        idx = np.flatnonzero(fb_np)
        if idx.size:
            pay = np.array(pay)
            slot_np = np.array(slot_np)
            # patch against the shard the GRAPH routed to — routing is
            # exact, so this is also the host-rounded authority
            for s in np.unique(shard_of[idx]):
                rows = idx[shard_of[idx] == s]
                r, res, p = _ops.resolve_escapes_host(
                    self._shard_host_views(int(s)), q64[rows])
                pay[rows] = p
                slot_np[rows] = r
                found_np[rows] = res
        glob = slot_np >= 0
        slot_np = np.where(glob, slot_np + self.slot_base[shard_of], -1)
        self.stats["fanout_lookups"] += 1
        self.stats["routed"] += n
        self.stats["mispredicts"] += int(np.sum(np.asarray(mis)))
        self.stats["escapes"] += int(idx.size)
        return pay, slot_np, found_np, shard_of, int(idx.size), int(
            np.sum(np.asarray(mis)))
