"""Pure-jnp oracle + shared epilogue for the fused lookup kernel.

Semantics (shared with the Pallas kernel in ``lookup.py`` and the XLA
windowed backend in ``ops.py``):

Given a piecewise linear mechanism (segment tables) and the physical
sorted slot-key array (gapped array G, or the raw sorted key array in the
static case), for each query key q return

  * ``slot``  — rightmost slot with slot_key <= q (-1 if q below all keys)
  * ``found`` — slot_key[slot] == q (exact hit in the first-level array)

Chain resolution (linking arrays) happens outside the search in
``chain_hit_index`` / ``resolve_chains`` — a rolled ``lax.fori_loop``
scan over the CSR link tables (``max_chain`` trips, ONE copy of the scan
body in the graph), identical for oracle and kernel paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["lookup_ref", "predict_ref", "chain_hit_index", "resolve_chains"]


def predict_ref(queries, seg_first_key, seg_slope, seg_icept):
    """Segment routing + linear prediction (float32)."""
    seg = jnp.clip(
        jnp.searchsorted(seg_first_key, queries, side="right") - 1,
        0,
        seg_first_key.shape[0] - 1,
    )
    fk = jnp.take(seg_first_key, seg)
    return jnp.take(seg_slope, seg) * (queries - fk) + jnp.take(seg_icept, seg), seg


@functools.partial(jax.jit, static_argnames=())
def lookup_ref(queries, seg_first_key, seg_slope, seg_icept, slot_key):
    """Oracle: full-array searchsorted (ignores the mechanism's windows).

    The mechanism tables are accepted (and routed through) so the oracle
    has the same signature as the kernel wrapper; the ground-truth search
    itself is position-prediction-independent.
    """
    del seg_slope, seg_icept, seg_first_key
    slot = jnp.searchsorted(slot_key, queries, side="right").astype(jnp.int32) - 1
    safe = jnp.maximum(slot, 0)
    found = (slot >= 0) & (jnp.take(slot_key, safe) == queries)
    return slot, found


def chain_hit_index(
    queries,
    slot,
    found,
    link_offsets,
    link_keys,
    max_chain: int,
    queries_lo=None,
    link_keys_lo=None,
):
    """Index into the CSR link tables of the entry matching q, else -1.

    Per-slot chains are key-sorted, so the scan is a branchless bisect
    over each query's ``[start, end)`` CSR range — ``ceil(log2(max_chain
    + 1))`` rolled ``lax.fori_loop`` trips (ONE copy of the body in the
    graph; the old Python loop unrolled ``max_chain`` linear
    gather/compare/select stages).

    ``queries_lo``/``link_keys_lo`` switch every compare to the wide-key
    f32 hi/lo pair representation (lexicographic pair order == numeric
    order — see kernels.ops.split_key_pair); pass None for narrow keys.
    """
    n_q = queries.shape[0]
    miss = jnp.full((n_q,), -1, jnp.int32)
    if link_keys.shape[0] == 0 or max_chain <= 0:
        return miss
    wide = queries_lo is not None and link_keys_lo is not None
    l_max = link_keys.shape[0] - 1
    safe_slot = jnp.clip(slot, 0, link_offsets.shape[0] - 2)
    start = jnp.take(link_offsets, safe_slot)
    end = jnp.take(link_offsets, safe_slot + 1)
    scan = (slot >= 0) & ~found & (end > start)
    trips = int(max_chain).bit_length()  # == ceil(log2(max_chain + 1))

    def body(_, carry):
        lo, hi = carry
        upd = lo < hi
        mid = (lo + hi + 1) >> 1
        midc = jnp.clip(mid, 0, l_max)
        kh = jnp.take(link_keys, midc)
        if wide:
            kl = jnp.take(link_keys_lo, midc)
            go = (kh < queries) | ((kh == queries) & (kl <= queries_lo))
        else:
            go = kh <= queries
        lo = jnp.where(upd & go, mid, lo)
        hi = jnp.where(upd, jnp.where(go, hi, mid - 1), hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, trips, body, (start - 1, end - 1))
    loc = jnp.clip(lo, 0, l_max)
    eq = jnp.take(link_keys, loc) == queries
    if wide:
        eq = eq & (jnp.take(link_keys_lo, loc) == queries_lo)
    hit = scan & (lo >= start) & eq
    return jnp.where(hit, lo, miss)


def resolve_chains(
    queries,
    slot,
    found,
    payload,
    link_offsets,
    link_keys,
    link_payloads,
    max_chain: int,
):
    """Payloads per query: G hit -> payload[slot]; miss -> chain scan.

    -1 when the key is absent.  Shared by oracle and kernel paths; kept
    for API compatibility — the engine epilogue in ops.py uses
    ``chain_hit_index`` directly so the payload gather can be fused (and
    doubled for hi/lo 64-bit payload pairs).
    """
    safe_slot = jnp.clip(slot, 0, payload.shape[0] - 1)
    out = jnp.where(
        found, jnp.take(payload, safe_slot), jnp.asarray(-1, payload.dtype)
    )
    if link_keys.shape[0] == 0 or max_chain <= 0:
        return out
    hit = chain_hit_index(queries, slot, found, link_offsets, link_keys,
                          max_chain)
    return jnp.where(
        hit >= 0, jnp.take(link_payloads, jnp.maximum(hit, 0)), out
    )
