"""Pure-jnp oracle for the fused learned-index lookup kernel.

Semantics (shared with the Pallas kernel in ``lookup.py``):

Given a piecewise linear mechanism (segment tables) and the physical
sorted slot-key array (gapped array G, or the raw sorted key array in the
static case), for each query key q return

  * ``slot``  — rightmost slot with slot_key <= q (-1 if q below all keys)
  * ``found`` — slot_key[slot] == q (exact hit in the first-level array)

Chain resolution (linking arrays) happens outside the search in
``resolve_chains`` with a fixed-trip bounded scan over CSR link tables —
identical for oracle and kernel paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["lookup_ref", "predict_ref", "resolve_chains"]


def predict_ref(queries, seg_first_key, seg_slope, seg_icept):
    """Segment routing + linear prediction (float32)."""
    seg = jnp.clip(
        jnp.searchsorted(seg_first_key, queries, side="right") - 1,
        0,
        seg_first_key.shape[0] - 1,
    )
    fk = jnp.take(seg_first_key, seg)
    return jnp.take(seg_slope, seg) * (queries - fk) + jnp.take(seg_icept, seg), seg


@functools.partial(jax.jit, static_argnames=())
def lookup_ref(queries, seg_first_key, seg_slope, seg_icept, slot_key):
    """Oracle: full-array searchsorted (ignores the mechanism's windows).

    The mechanism tables are accepted (and routed through) so the oracle
    has the same signature as the kernel wrapper; the ground-truth search
    itself is position-prediction-independent.
    """
    del seg_slope, seg_icept, seg_first_key
    slot = jnp.searchsorted(slot_key, queries, side="right").astype(jnp.int32) - 1
    safe = jnp.maximum(slot, 0)
    found = (slot >= 0) & (jnp.take(slot_key, safe) == queries)
    return slot, found


def resolve_chains(
    queries,
    slot,
    found,
    payload,
    link_offsets,
    link_keys,
    link_payloads,
    max_chain: int,
):
    """Payloads (i32) per query: G hit -> payload[slot]; miss -> chain scan.

    Fixed-trip bounded scan (``max_chain`` iterations) over CSR link
    tables; -1 when the key is absent.  Shared by oracle and kernel paths.
    """
    n_q = queries.shape[0]
    safe_slot = jnp.clip(slot, 0, payload.shape[0] - 1)
    out = jnp.where(found, jnp.take(payload, safe_slot), jnp.int32(-1))
    valid = slot >= 0
    start = jnp.take(link_offsets, safe_slot)
    end = jnp.take(link_offsets, jnp.minimum(safe_slot + 1, link_offsets.shape[0] - 1))
    if link_keys.shape[0] == 0:
        return out
    for t in range(max_chain):
        idx = jnp.minimum(start + t, link_keys.shape[0] - 1)
        in_chain = valid & ~found & (start + t < end)
        hit = in_chain & (jnp.take(link_keys, idx) == queries)
        out = jnp.where(hit, jnp.take(link_payloads, idx), out)
    return out
