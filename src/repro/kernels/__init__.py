"""Device kernels for the paper's compute hot-spot: fused batched
learned-index lookup (predict + bounded rank-search over VMEM tiles).

Modules
-------
lookup.py: the FUSED single-dispatch kernel (radix routing + bounded
           search + CSR chain epilogue + payload gather + in-kernel
           fallback flag/compaction, f32 hi/lo pair aware) and the
           legacy multi-op window kernel, both pl.pallas_call +
           BlockSpec (+scalar-prefetch dynamic windows)
ops.py:    the fused XLA pipeline, the legacy multi-op pipeline,
           ``QueryEngine``, and the epoch-versioned freeze/delta-update
           + incremental bound/rank refresh entry points
ref.py:    pure-jnp oracle the kernels are validated against + the
           shared ``chain_hit_index`` fori_loop CSR scan (pair aware)
shard_fanout.py: the multi-device fan-out — stacked per-shard frozen
           images mesh-placed via ``repro.dist.partitioning``, one
           ``shard_map`` graph chaining route -> all-to-all exchange ->
           the per-shard fused search -> inverse-permutation gather
           (see "Shard fan-out contract" below).

The ``Index`` handle contract (who calls what)
----------------------------------------------
``repro.core.Index`` owns this layer.  It freezes host state ONCE
(``freeze_state`` -> ``QueryEngine`` + ``HostMirror``), then keeps the
resident device buffers current across host mutations by **epoch**:

* every host mutation bumps ``index.epoch``; the engine remembers the
  epoch it was frozen at;
* a stale device lookup first calls ``delta_update`` — it re-derives the
  padded numpy images (cheap), diffs them against the host mirror, and
  scatters ONLY changed elements into the device buffers.  Shapes and
  jit statics are frozen with headroom, so compiled executables survive;
* after a delta the handle INCREMENTALLY refreshes the derived read
  tables for just the touched key ranges: the fused path's bucket->rank
  rows (``QueryEngine.refresh_rank_rows``) and the per-segment window
  bounds (``query_window_bounds(segments=...)`` ->
  ``QueryEngine.refresh_bounds``) — so the compacted-fallback rate
  stays flat under churn instead of climbing until the policy refreeze.
  Skipped refreshes are SOUND: stale tables only raise fallbacks,
  never wrong results;
* ``delta_update`` declines — and the handle takes a full refreeze —
  when a capacity/static no longer holds (link storage, max-chain
  headroom, payload i32 width, key f32 width) or the diff would touch
  most of the buffers.

Backend decision table (mirrored by ``repro.core.BACKENDS``)
------------------------------------------------------------
=============  ==============  =====  ====================================
engine name    handle name     wide   search stage
=============  ==============  =====  ====================================
``fused``      fused           yes    THE default device path, one lean
                                      dispatch at every batch size:
                                      * TPU: fused Pallas kernel — in-
                                        kernel radix routing, windowed
                                        search over VMEM tiles, CSR chain
                                        epilogue, payload gather, per-tile
                                        fallback compaction;
                                      * CPU/GPU: fused XLA graph — one
                                        bucket->slot-rank table collapses
                                        route+predict+window into two
                                        gathers + a ~log2(p99 occupancy)
                                        bisect; escapes return as a MASK
                                        and are patched in O(#escapes)
                                        host numpy (no device compaction —
                                        XLA-CPU scatters/cumsums are
                                        scalar loops).
``pallas``     pallas          no     LEGACY multi-op kernel (debug/ref;
                                      ``interpret=True`` on CPU)
``xla``        xla-windowed    yes    legacy multi-op windowed bisect /
                                      flat rank count (debug/reference;
                                      non-forced requests below
                                      ``xla_min_bucket`` downgrade to the
                                      device oracle)
``oracle``     (device oracle) yes    full-array searchsorted/pair bisect
(host numpy)   numpy-oracle    yes    GappedArray.lookup_batch (default
                                      below ``min_device_batch``)
=============  ==============  =====  ====================================

Wide keys: beyond f32 exactness (2^24) keys ride an f32 hi/lo pair
(``split_key_pair``) — lexicographic pair order == numeric order, exact
for integer keys < 2^48.  BOTH fused implementations compare pairs end
to end, so wide keys (e.g. paged-KV composite keys) finally have a
device kernel path; only the legacy kernel is narrow-only.

Ingest backend contract (device-side §5.3, single dispatch)
-----------------------------------------------------------
Writes can be a single fused dispatch, like reads.  On an eligible
device-resident engine with the fused write graph enabled
(``Index.fused_ingest_enabled`` — auto: ON for Pallas/accelerator
engines, where one kernel beats two dispatches plus host round trips;
OFF for the fused-XLA CPU engine, where the graph's fixed O(state)
cost — full-array carried-key repair scan, functional whole-buffer
updates — loses to the sparse host delta at steady state, measured in
BENCH_ingest's ``fused_dispatch`` rows), ``Index.ingest`` issues ONE
device invocation (``ops_gap.fused_ingest``, surfaced as
``QueryEngine.fused_ingest``) whose graph chains four stages with no
host round trip between them:

1. **placement** — the shared per-key body
   (``gap_place.ingest_place_body``; composed from the Pallas kernel on
   TPU, inlined in the fused-XLA graph elsewhere) computes predicted
   slot, occupancy, run boundaries (``pv``/``ub``), bracket, escape;
2. **slot arm** — scatter the bracketed-free keys/payloads into their
   slots and repair the carried keys with one reverse pair-min scan
   (the associative-scan twin of ``GappedArray._repair_carried``);
3. **chain arm** — a device CSR merge: one pair bisect positions the
   sorted chain keys, a prefix-sum shift relocates every old entry, and
   the offsets advance by a cumsum — the in-graph twin of the host
   ``CSRLinks._merge`` single-allocation merge (no ``np.insert``);
4. **read-table refresh** — the touched bucket->rank rows recompute
   against the NEW slot keys and the touched segments' window bounds
   widen in-graph, so the committed engine needs no separate
   ``refresh_rank_rows``/``refresh_bounds`` upload.

The graph is **closure-trivial or abort**: it detects, in-graph, every
shape the host partition's demotion closure could act on — collision
groups, contested rows, D1/D4 demotions, duplicates (in-batch, slot,
or chain), chain/link capacity overflows, placement escapes — and on
any hit returns ``ok=False`` with the buffers UNTOUCHED.  Accepted
batches provably partition as ``slot = free & bracket``/``chain =
rest`` at the target ``ub``, which is exactly what the graph committed;
the handle then advances the authoritative host state through the
normal partition fed the same dispatch's primitives, adopts the device
output buffers (``QueryEngine.adopt_fused_state`` — nothing diffed or
re-uploaded; the mirror goes source-advanced/image-dirty and rebuilds
its padded images lazily on the next host-side delta), and reports
``device="fused"``.  Aborted batches reuse those primitives on the
host-partition + delta path — an abort never wastes the dispatch.

The two-dispatch path (place, then delta sync) remains for everything
the fused gates refuse: ``ops_gap.ingest_place`` / ``QueryEngine
.ingest_place`` computes the primitives alone, with the same contract:

* ``GappedArray.placement_primitives`` is the ORACLE — the device
  result, after the escape patch, must equal it bit-for-bit (property-
  tested in tests/test_ingest_place.py); the host partition then
  consumes either transparently (``insert_batch(..., placements=)``).
* Exactness is gated, not assumed: placement routes to the device when
  the stored AND batch keys are per-key pair-exact (integer keys <
  2^48 — every compare equals the host f64 compare), the mechanism's
  ``predict`` is its exported PLM (pgm/fiting), the device state is at
  the host epoch, and the slot count fits i32/f32 indexing.  A merely
  ALIAS-FREE wide stored set (continuous keys, pairwise distinguishable
  but not per-key reconstructible) no longer refuses outright: the
  device primitives are certified row-by-row on the host with exact
  f64 bracketing checks (``GappedArray.verify_placements``) and failing
  rows recomputed per-key — reported as ``placement="device-verified"``
  (this mode is NOT fused-eligible: certification is host work).
* Slot prediction runs in double-f32 (pair slopes/intercepts carried in
  ``IndexArrays.seg_slope_lo``/``seg_icept_lo``); keys whose prediction
  lands within a padded error band of a .5 rounding boundary return an
  escape MASK and are re-derived host-side in O(#escapes) — the same
  stale-safe escape philosophy as the fused lookup, applied to writes.
* The contested remainder (class C) still replays on the host: scalar
  §5.3 inserts are pointer-chasing by nature; the device's job is the
  O(batch x log) predict/search/classify stage, the host's the few
  order-dependent keys the per-key commutativity analysis cannot clear.

Shard fan-out contract (multi-device read path)
-----------------------------------------------
``repro.dist.ShardedIndex`` extends the decision table one level up:
``backend="fanout"`` (the default for batches >= ``min_device_batch``
when available) runs ONE ``shard_map`` dispatch over the mesh from
``launch.mesh`` — per-shard images stacked on the ``data`` axis by
``shard_fanout.stack_shard_images`` (consensus wide/key_wide statics,
padded to the max shard's shapes), routed by the learned two-segment
router with an in-graph exact bisect backstop (``_route_block``),
exchanged via counting-sort send buffers + ``lax.all_to_all``, searched
by the SAME ``_fused_search``/``_epilogue`` body as the single-device
fused path, and unsorted back by inverse permutation.

* **Exactness**: routing and search are exact in the ROUNDED key
  representation (f32 round-trip narrow, hi/lo pair sum wide); the
  learned router only prices the backstop.  Per-query escape flags ride
  the exchange home, and escaped/dropped rows are re-resolved through
  each owning shard's host views in O(#escapes) — the same stale-safe
  philosophy as the fused lookup, across shards.
* **Availability is gated, not assumed** (``ShardFanout.build`` raises
  ``FanoutUnavailable``): PLM-mechanism shards only, pair-exact wide
  key sets, strictly ordered rounded shard boundaries, and freezable
  capacities.  The handle then falls back to the exact grouped host
  route; only an explicit ``backend="fanout"`` request surfaces the
  refusal as an error.
* **Capacity, not correctness**: exchange buffers are sized by an
  occupancy heuristic with a sticky per-bucket boost; overflow drops
  are counted, flagged, and host-patched — skew costs escapes, never
  wrong answers.
* The fan-out serves a FROZEN shard set: any shard mutation (ingest,
  split) retags the epochs and the next large lookup rebuilds the
  stacked images (incremental per-shard delta into the stacked images
  is deferred — see ROADMAP).

Fused-path contract
-------------------
``engine.lookup(queries, queries_sorted=..., backend=...)`` returns
``(payloads, slot, found, fb_count)`` — ``found`` covers first-level AND
linking-chain hits (the ``LookupResult.found`` mask).

1. **Single dispatch**: the whole route -> search -> chain epilogue ->
   payload pipeline runs in one device invocation.  Escaped queries
   (rank-row staleness, p99-truncated bisect, tile-window misses) are
   flagged by a bracket validation that makes results exact INDEPENDENT
   of the routing tables, and re-resolved in O(#escapes): host numpy on
   the fused XLA path, a compacted fixed-capacity device buffer behind
   a ``lax.cond`` on the fused Pallas path.
2. **Small-batch regime**: the fused path is never downgraded — it owns
   every bucket size (the recorded crossover vs the device oracle in
   ``BENCH_kernel.json`` is the gate).
3. **Sort-aware scheduling**: the Pallas paths need ascending queries;
   callers that already issue sorted batches pass ``queries_sorted=True``
   and skip the lexsort/argsort round trip.  The fused XLA and oracle
   backends are permutation-free.
4. **Shape buckets**: query batches are padded (+inf tail — sorted stays
   sorted) up to power-of-two buckets so each bucket compiles once.
5. **Wide payloads**: int64 payloads are carried as an i32 hi/lo pair
   and reconstructed after the epilogue (``IndexArrays.wide``).

Serving & durability contract (how this layer is consumed live)
---------------------------------------------------------------
``repro.serving.EpochPipeline`` double-buffers the handle for
concurrent serving: lookups run against a pinned immutable snapshot
(the frozen first-level arrays + CSR image — ``GappedArray
.pin_snapshot``, O(1) pin, copy-on-write on the live side) while
ingest mutates the live index through the contracts above.  Two
consequences for THIS layer:

* the kernels never see snapshot state — snapshots serve via the host
  oracle path, which the backend decision table already requires to be
  bit-identical to every device backend, so snapshot isolation comes
  for free from the existing exactness contract;
* fused-ingest aborts stay cheap under serving: an aborted dispatch's
  primitives are reused host-side (never wasted), and when the abort
  reason is *localized* the handle commits the clean PREFIX of the
  batch through a second fused dispatch and routes only the remainder
  through the host path (``placement="device-split"``,
  ``IngestReport.split_commits``) — so one contested key no longer
  demotes a whole large batch off the device.

Durability (``repro.serving.wal``: CRC-framed write-ahead log +
``Index.save_snapshot`` checkpoints) is layered strictly ABOVE the
engine: recovery replays acked batches through the normal ``ingest``
entry point, so a recovered index re-derives device state through the
same freeze/delta/fused machinery — nothing in this layer needs to be
crash-aware.

Machine-checked invariants (``repro.analysis``)
-----------------------------------------------
Two contracts in this package are enforced by the repo's static
analyzer (``scripts/lint.sh`` -> ``python -m repro.analysis``, part of
tier-1), not just by convention:

* **trace-safety** (rules ``trace-host-sync``, ``trace-py-branch``,
  ``trace-dyn-shape``, ``trace-self-capture``, ``trace-np-call``):
  inside jit-compiled functions and ``fori_loop``/``scan``/``cond``
  bodies, no host syncs (``.block_until_ready()``, ``float()``/
  ``int()``/``bool()`` on tracers), no Python ``if``/``while`` on
  traced values (identity tests like ``x is None`` are exempt — they
  never concretize), no data-dependent ``.reshape``/``np.*`` on traced
  operands, and no ``self`` capture in traced closures (it pins host
  state into the compiled graph).  The checker threads taint
  interprocedurally, so the package's static-flag idiom (``key_wide``,
  ``n_slots``... passed from ``static_argnames`` roots through
  helpers) is understood, not suppressed.
* **pair-exactness** (rules ``pair-f64-const``, ``pair-raw-fma``): in
  ``gap_place.py`` / ``lookup.py`` / ``ops_gap.py``, no float64
  intermediates (TPU demotes them silently) and no raw ``a * b + c``
  where the hi/lo pair contract requires ``two_sum``/``two_prod``
  error-free transforms.  Deliberately-approximate sites carry an
  inline ``# repro-lint: disable=... -- why`` justification.

Migration notes
---------------
``QueryEngine.from_index(idx)`` + manual refreeze-after-mutation is the
legacy pattern; prefer holding a ``repro.core.Index`` and calling
``index.lookup`` / ``index.ingest`` — the handle schedules freezes,
delta updates, and incremental refreshes for you and returns typed
results.  ``from_learned_index`` remains the raw freeze (no headroom,
no mirror) for kernel tests and benchmarks.
"""

from .ops import (HostMirror, IndexArrays, QueryEngine, batched_lookup,
                  build_radix_router, build_rank_router, delta_update,
                  freeze_state, from_learned_index, keys_need_pair,
                  keys_pair_exact, pair_alias_free, split_key_pair)
from .ops_gap import (fused_ingest, gap_positions_device,
                      gap_positions_oracle, ingest_place)
from .ref import chain_hit_index, lookup_ref, predict_ref, resolve_chains
from .shard_fanout import (FanoutUnavailable, ShardFanout,
                           stack_shard_images)

__all__ = [
    "FanoutUnavailable",
    "HostMirror",
    "IndexArrays",
    "QueryEngine",
    "ShardFanout",
    "batched_lookup",
    "build_radix_router",
    "build_rank_router",
    "chain_hit_index",
    "delta_update",
    "freeze_state",
    "from_learned_index",
    "fused_ingest",
    "gap_positions_device",
    "gap_positions_oracle",
    "ingest_place",
    "keys_need_pair",
    "keys_pair_exact",
    "lookup_ref",
    "pair_alias_free",
    "predict_ref",
    "resolve_chains",
    "split_key_pair",
    "stack_shard_images",
]
