"""Device kernels for the paper's compute hot-spot: fused batched
learned-index lookup (predict + bounded rank-search over VMEM tiles).

Modules
-------
lookup.py: pl.pallas_call + BlockSpec (+scalar-prefetch dynamic windows)
ops.py:    the single-pass ``QueryEngine`` pipeline (sort-aware
           scheduling, compacted fallback, fused CSR epilogue)
ref.py:    pure-jnp oracle the kernel is validated against + the shared
           ``chain_hit_index`` fori_loop CSR scan.

QueryEngine API and the single-pass pipeline contract
-----------------------------------------------------
``QueryEngine(arrays, err_lo, err_hi)`` (or ``QueryEngine.from_index``)
wraps a frozen ``IndexArrays`` and serves ``engine.lookup(queries,
queries_sorted=...)`` -> ``(payloads, slot, found, fb_count)``.

1. **Single pass**: each query is resolved by exactly one bounded window
   search (Pallas kernel on TPU; XLA fixed-trip windowed bisect
   elsewhere).  The full-array oracle is evaluated ONLY over the
   compacted fallback buffer — capacity ``max(q_tile, ~2% of Q)``,
   shape-static — never over the whole batch.  If the buffer overflows
   (more flagged queries than capacity), a host-side escape hatch
   re-dispatches the batch to the oracle backend; this is counted in
   ``engine.stats["oracle_escapes"]`` and is rare by construction.
2. **Sort-aware scheduling**: the Pallas path needs ascending queries
   for its tile windows; callers that already issue sorted batches
   (e.g. serving page lookups) pass ``queries_sorted=True`` and skip the
   argsort + inverse-permutation round trip.  The XLA and oracle
   backends are permutation-free.
3. **Shape buckets**: query batches are padded (+inf tail — sorted stays
   sorted) up to power-of-two buckets so each bucket compiles once; the
   serving engine stops re-tracing per batch.
4. **Fused epilogue**: slot->payload gather and the CSR linking-array
   scan run in one stage (in the sorted domain on the Pallas path, so a
   single unsort gather finishes the batch).  The chain scan is a rolled
   ``lax.fori_loop`` — one graph copy regardless of ``max_chain``.
5. **Wide payloads**: int64 payloads are carried as an i32 hi/lo pair
   and reconstructed in the epilogue (``IndexArrays.wide``); narrow
   payloads pay nothing.
"""

from .ops import (IndexArrays, QueryEngine, batched_lookup,
                  from_learned_index)
from .ops_gap import gap_positions_device, gap_positions_oracle
from .ref import chain_hit_index, lookup_ref, predict_ref, resolve_chains

__all__ = [
    "IndexArrays",
    "QueryEngine",
    "batched_lookup",
    "chain_hit_index",
    "from_learned_index",
    "gap_positions_device",
    "gap_positions_oracle",
    "lookup_ref",
    "predict_ref",
    "resolve_chains",
]
