"""Device kernels for the paper's compute hot-spot: fused batched
learned-index lookup (predict + bounded rank-search over VMEM tiles).

Modules
-------
lookup.py: pl.pallas_call + BlockSpec (+scalar-prefetch dynamic windows)
ops.py:    the single-pass pipeline, ``QueryEngine``, and the epoch-
           versioned freeze/delta-update entry points
ref.py:    pure-jnp oracle the kernel is validated against + the shared
           ``chain_hit_index`` fori_loop CSR scan (hi/lo pair aware).

The ``Index`` handle contract (who calls what)
----------------------------------------------
``repro.core.Index`` owns this layer.  It freezes host state ONCE
(``freeze_state`` -> ``QueryEngine`` + ``HostMirror``), then keeps the
resident device buffers current across host mutations by **epoch**:

* every host mutation bumps ``index.epoch``; the engine remembers the
  epoch it was frozen at;
* a stale device lookup first calls ``delta_update`` — it re-derives the
  padded numpy images (cheap), diffs them against the host mirror, and
  scatters ONLY changed elements (slot_key/payload entries for slot
  placements, CSR link-table tails + shifted offsets for chain appends)
  into the device buffers.  Shapes and jit statics are frozen with
  headroom, so compiled executables survive;
* ``delta_update`` declines — and the handle takes a full refreeze —
  when a capacity/static no longer holds (link storage, max-chain
  headroom, payload i32 width, key f32 width) or the diff would touch
  most of the buffers.  Stale window bounds after a delta are SOUND:
  they only raise the compacted-fallback rate, never wrong results.

Backend capability table (mirrored by ``repro.core.BACKENDS``)
--------------------------------------------------------------
=============  ==============  ===========  ==============================
engine name    handle name     wide keys    search stage
=============  ==============  ===========  ==============================
``pallas``     pallas          no           TPU kernel, VMEM window tiles
                                            (``interpret=True`` on CPU)
``xla``        xla-windowed    yes          fixed-trip windowed bisect /
                                            loop-free flat rank count
``oracle``     (device oracle) yes          full-array searchsorted /
                                            pair bisect
(host numpy)   numpy-oracle    yes (f64)    GappedArray.lookup_batch
=============  ==============  ===========  ==============================

Wide keys: beyond f32 exactness (2^24) keys ride an f32 hi/lo pair
(``split_key_pair``) — lexicographic pair order == numeric order, exact
for integer keys < 2^48.  The Pallas kernel is narrow-only; the registry
routes wide indexes to the XLA backend.

Single-pass pipeline contract
-----------------------------
``engine.lookup(queries, queries_sorted=..., backend=...)`` returns
``(payloads, slot, found, fb_count)`` — ``found`` covers first-level AND
linking-chain hits (the ``LookupResult.found`` mask).

1. **Single pass**: each query is resolved by exactly one bounded window
   search.  The full-array oracle is evaluated ONLY over the compacted
   fallback buffer — capacity ``max(q_tile, ~2% of Q)``, shape-static —
   never over the whole batch.  If the buffer overflows, a host-side
   escape hatch re-dispatches the batch to the oracle backend (counted
   in ``engine.stats["oracle_escapes"]``; rare by construction).
2. **Sort-aware scheduling**: the Pallas path needs ascending queries;
   callers that already issue sorted batches pass ``queries_sorted=True``
   and skip the argsort + inverse-permutation round trip.  The XLA and
   oracle backends are permutation-free.
3. **Shape buckets**: query batches are padded (+inf tail — sorted stays
   sorted) up to power-of-two buckets so each bucket compiles once.
4. **Fused epilogue**: slot->payload gather and the CSR linking-array
   scan run in one stage; the chain scan is a rolled ``lax.fori_loop``
   bisect — one graph copy regardless of ``max_chain``.
5. **Wide payloads**: int64 payloads are carried as an i32 hi/lo pair
   and reconstructed in the epilogue (``IndexArrays.wide``).

Migration notes
---------------
``QueryEngine.from_index(idx)`` + manual refreeze-after-mutation is the
legacy pattern; prefer holding a ``repro.core.Index`` and calling
``index.lookup`` / ``index.ingest`` — the handle schedules freezes and
delta updates for you and returns typed results.  ``from_learned_index``
remains the raw freeze (no headroom, no mirror) for kernel tests and
benchmarks.
"""

from .ops import (HostMirror, IndexArrays, QueryEngine, batched_lookup,
                  delta_update, freeze_state, from_learned_index,
                  keys_need_pair, keys_pair_exact, pair_alias_free,
                  split_key_pair)
from .ops_gap import gap_positions_device, gap_positions_oracle
from .ref import chain_hit_index, lookup_ref, predict_ref, resolve_chains

__all__ = [
    "HostMirror",
    "IndexArrays",
    "QueryEngine",
    "batched_lookup",
    "chain_hit_index",
    "delta_update",
    "freeze_state",
    "from_learned_index",
    "gap_positions_device",
    "gap_positions_oracle",
    "keys_need_pair",
    "keys_pair_exact",
    "lookup_ref",
    "pair_alias_free",
    "predict_ref",
    "resolve_chains",
    "split_key_pair",
]
