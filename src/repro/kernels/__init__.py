# Pallas TPU kernels for the paper's compute hot-spot: fused batched
# learned-index lookup (predict + bounded rank-search over VMEM tiles).
# lookup.py: pl.pallas_call + BlockSpec (+scalar-prefetch dynamic windows)
# ops.py:    jitted end-to-end wrapper (sort, schedule, fallback, chains)
# ref.py:    pure-jnp oracle the kernel is validated against.

from .ops import IndexArrays, batched_lookup, from_learned_index
from .ops_gap import gap_positions_device, gap_positions_oracle
from .ref import lookup_ref, predict_ref, resolve_chains

__all__ = [
    "IndexArrays",
    "batched_lookup",
    "from_learned_index",
    "gap_positions_device",
    "gap_positions_oracle",
    "lookup_ref",
    "predict_ref",
    "resolve_chains",
]
